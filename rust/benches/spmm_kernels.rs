//! Bench: CPU SpMM kernel zoo across the dataset-analog graph family —
//! regenerates the Fig. 7 kernel-time comparison (exact/cuSPARSE role vs
//! GE-SpMM-analog vs sampled AFS/SFS/AES at several W).
//!
//! Run: `cargo bench --bench spmm_kernels`

use aes_spmm::bench::{print_header, print_result, Bencher};
use aes_spmm::gen;
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::{sample_ell, Strategy};
use aes_spmm::spmm::{csr_naive, csr_naive_par, csr_rowcache, ell_spmm_par, spmm_flops};

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let f = 64;
    let b = Bencher::default();

    // (name, nodes, avg_deg, gamma) — mirrors the small/large split.
    let workloads = [
        ("cora-like", 2708usize, 4.0, 2.5),
        ("arxiv-like", 4096, 14.0, 2.2),
        ("reddit-like", 2048, 160.0, 2.0),
        ("products-like", 8192, 50.0, 2.1),
    ];

    for (name, n, deg, gamma) in workloads {
        let mut rng = Pcg32::new(42);
        let g = gen::with_self_loops(&gen::chung_lu(n, deg, gamma, &mut rng));
        let feats: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let mut out = vec![0.0f32; n * f];
        let flops = spmm_flops(g.nnz(), f);

        print_header(&format!("{name}: n={n} nnz={} f={f}", g.nnz()));

        let r = b.run("exact csr (cuSPARSE role, 1 thread)", || {
            csr_naive(&g, &feats, f, &mut out)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));

        let r = b.run(format!("exact csr ({threads} threads)"), || {
            csr_naive_par(&g, &feats, f, &mut out, threads)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));

        let r = b.run("rowcache csr (GE-SpMM analog)", || {
            csr_rowcache(&g, &feats, f, &mut out)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));

        for w in [16usize, 64, 256] {
            for strat in Strategy::ALL {
                let r = b.run(format!("sampled {} w{w} (plan+spmm)", strat.name()), || {
                    let ell = sample_ell(&g, w, strat);
                    ell_spmm_par(&ell, &feats, f, &mut out, threads);
                });
                print_result(&r, None);
            }
        }
    }
}
