//! Bench: CPU SpMM kernel zoo across the dataset-analog graph family —
//! regenerates the Fig. 7 kernel-time comparison (exact/cuSPARSE role vs
//! GE-SpMM-analog vs sampled AFS/SFS/AES at several W), plus the exec
//! layer's dispatched pick so regressions in the dispatch heuristics show
//! up next to the kernels they choose between.
//!
//! Run: `cargo bench --bench spmm_kernels`
//! JSON baseline: `cargo bench --bench spmm_kernels -- --json [PATH]`
//! (default PATH `BENCH_spmm.json`) — future PRs diff this file for the
//! perf trajectory.

use std::collections::BTreeMap;
use std::sync::Arc;

use aes_spmm::bench::{print_header, print_result, BenchResult, Bencher};
use aes_spmm::exec::{self, ExecEnv, GraphProfile};
use aes_spmm::gen;
use aes_spmm::graph::Ell;
use aes_spmm::quant::ChunkedParams;
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::{sample_ell, Strategy};
use aes_spmm::spmm::{
    attention_scores, attention_scores_par, bcsr_spmm_par, csr_naive, csr_naive_par,
    csr_rowcache, csr_rowcache_at, csr_spmm_i8, dense_spmm_par, dense_tile_viable, ell_spmm_at,
    ell_spmm_i8, ell_spmm_par, gat_alpha_csr, gat_alpha_csr_par, gat_alpha_ell,
    segmented_max_csr_par, simd, spmm_flops, spmm_i8_flops, AdjQuant, BlockedCsr, DenseTile,
    BCSR_BLOCK_ROWS,
};
use aes_spmm::util::JsonValue;

struct Recorder {
    cases: Vec<(BenchResult, Option<f64>)>,
}

impl Recorder {
    fn push(&mut self, r: &BenchResult, gflops: Option<f64>) {
        self.cases.push((r.clone(), gflops));
    }

    fn to_json(&self) -> JsonValue {
        JsonValue::Arr(
            self.cases
                .iter()
                .map(|(r, gflops)| {
                    let mut obj = match r.to_json() {
                        JsonValue::Obj(m) => m,
                        _ => unreachable!("BenchResult::to_json returns an object"),
                    };
                    if let Some(g) = gflops {
                        obj.insert("gflops".to_string(), JsonValue::Num(*g));
                    }
                    JsonValue::Obj(obj)
                })
                .collect(),
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = args.iter().position(|a| a == "--json").map(|i| {
        args.get(i + 1)
            .filter(|p| !p.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "BENCH_spmm.json".to_string())
    });

    let env = ExecEnv::detect();
    let threads = env.threads;
    let f = 64;
    let b = Bencher::default();
    exec::warm_pool();

    // (name, nodes, avg_deg, gamma) — mirrors the small/large split.
    let workloads = [
        ("cora-like", 2708usize, 4.0, 2.5),
        ("arxiv-like", 4096, 14.0, 2.2),
        ("reddit-like", 2048, 160.0, 2.0),
        ("products-like", 8192, 50.0, 2.1),
    ];

    let mut report: BTreeMap<String, JsonValue> = BTreeMap::new();
    report.insert("bench".to_string(), JsonValue::Str("spmm_kernels".to_string()));
    report.insert("feat_dim".to_string(), JsonValue::Num(f as f64));
    report.insert("threads".to_string(), JsonValue::Num(threads as f64));
    let mut workload_json = Vec::new();

    for (name, n, deg, gamma) in workloads {
        let mut rng = Pcg32::new(42);
        let g = gen::with_self_loops(&gen::chung_lu(n, deg, gamma, &mut rng));
        let feats: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let mut out = vec![0.0f32; n * f];
        let flops = spmm_flops(g.nnz(), f);
        let mut rec = Recorder { cases: Vec::new() };

        print_header(&format!("{name}: n={n} nnz={} f={f}", g.nnz()));

        let r = b.run("exact csr (cuSPARSE role, 1 thread)", || {
            csr_naive(&g, &feats, f, &mut out)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
        rec.push(&r, Some(r.throughput(flops) / 1e9));

        let r = b.run(format!("exact csr ({threads} threads)"), || {
            csr_naive_par(&g, &feats, f, &mut out, threads)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
        rec.push(&r, Some(r.throughput(flops) / 1e9));
        let forced_csr_ns = r.median.as_nanos() as f64;

        let r = b.run("rowcache csr (GE-SpMM analog)", || {
            csr_rowcache(&g, &feats, f, &mut out)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
        rec.push(&r, Some(r.throughput(flops) / 1e9));

        // Scalar-vs-SIMD split on the same kernel: the detected level is
        // what `csr_rowcache` above already ran; this pins the scalar
        // arm so the vector speedup is a first-class diffable case.
        let lvl = simd::level();
        let r = b.run(format!("rowcache csr (forced scalar; detected {})", lvl.name()), || {
            csr_rowcache_at(simd::SimdLevel::Scalar, &g, &feats, f, &mut out)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
        rec.push(&r, Some(r.throughput(flops) / 1e9));

        // True INT8 compute on the exact operand: i8×u8→i32 MACs over
        // the requantized adjacency, u8 codes in place of fp32 features.
        // Throughput is reported in fp32-flop equivalents (the dispatch
        // cost model's like-units — see `spmm_i8_flops`).
        let params = ChunkedParams::of_rows(&feats, n, f, (n / 8).max(1));
        let qb = params.quantize_rows(&feats, f);
        let aq_csr = AdjQuant::from_csr(&g, &params);
        let i8_flops = spmm_i8_flops(g.nnz(), f);
        let r = b.run("exact csr i8-compute (1 thread)", || {
            csr_spmm_i8(&g, &aq_csr, &qb, f, &mut out)
        });
        print_result(&r, Some(("GFLOP/s-eq", r.throughput(i8_flops) / 1e9)));
        rec.push(&r, Some(r.throughput(i8_flops) / 1e9));

        // The exec layer's pick for this workload, run through the same
        // dispatcher the serving path uses.
        let picked = exec::select_kernel(&GraphProfile::of(&g), f, None, &env);
        let r = b.run(format!("dispatched exact → {}", picked.name()), || {
            exec::run_exact(picked, &g, &feats, f, &mut out, threads)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
        rec.push(&r, Some(r.throughput(flops) / 1e9));

        // --- Format zoo: the same exact operand forced through each
        // re-layout at the full thread budget ("exact csr (N threads)"
        // above is the forced-CSR bar), then the tuned dispatcher on
        // top. The in-memory cost model is the argmin of the forced
        // medians — built with the same `set_cell`/install path
        // `repro tune --out` + serving use — so by construction the
        // tuned case tracks the best single-format configuration on
        // every workload (`ci.sh --tune-only` asserts the case lands
        // in the JSON baseline).
        let mut forced = vec![(exec::KernelKind::CsrNaivePar, forced_csr_ns)];
        let bcsr = BlockedCsr::from_csr(&g, BCSR_BLOCK_ROWS);
        let r = b.run(format!("forced bcsr ({threads} threads)"), || {
            bcsr_spmm_par(&bcsr, &feats, f, &mut out, threads)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
        rec.push(&r, Some(r.throughput(flops) / 1e9));
        forced.push((exec::KernelKind::CsrBlockedPar, r.median.as_nanos() as f64));

        let dense =
            dense_tile_viable(&g, exec::DENSE_TILE_SLACK).then(|| DenseTile::from_csr(&g));
        if let Some(t) = &dense {
            let r = b.run(format!("forced dense ({threads} threads)"), || {
                dense_spmm_par(t, &feats, f, &mut out, threads)
            });
            print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
            rec.push(&r, Some(r.throughput(flops) / 1e9));
            forced.push((exec::KernelKind::ExactDensePar, r.median.as_nanos() as f64));
        }

        let profile = GraphProfile::of(&g);
        let best = forced
            .iter()
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .map(|&(k, _)| k)
            .expect("at least one forced case");
        let mut model = exec::CostModel::default();
        let bucket = exec::ProfileBucket::of(&profile, f);
        model.set_cell(&bucket, exec::Family::Exact, exec::KernelDomain::F32, best);
        let prev = exec::install_cost_model(Some(Arc::new(model)));
        let mask = exec::FormatMask { blocked: true, dense: dense.is_some() };
        let tuned =
            exec::select_kernel_tuned(&profile, f, None, &env, exec::KernelDomain::F32, mask);
        let run_tuned = |out: &mut [f32]| match tuned.format() {
            exec::FormatKind::Blocked => exec::run_blocked(tuned, &bcsr, &feats, f, out, threads),
            exec::FormatKind::Dense => {
                let t = dense.as_ref().expect("dense pick without a tile");
                exec::run_dense(tuned, t, &feats, f, out, threads)
            }
            _ => exec::run_exact(tuned, &g, &feats, f, out, threads),
        };
        let r = b.run(format!("tuned dispatch (exact) → {}", tuned.name()), || {
            run_tuned(&mut out)
        });
        print_result(&r, Some(("GFLOP/s", r.throughput(flops) / 1e9)));
        rec.push(&r, Some(r.throughput(flops) / 1e9));
        exec::install_cost_model(prev);

        for w in [16usize, 64, 256] {
            for strat in Strategy::ALL {
                let r = b.run(format!("sampled {} w{w} (plan+spmm)", strat.name()), || {
                    let ell = sample_ell(&g, w, strat);
                    ell_spmm_par(&ell, &feats, f, &mut out, threads);
                });
                print_result(&r, None);
                rec.push(&r, None);
            }
            // Dispatched sampled path over a pre-built plan (the warm-route
            // shape: sampling amortized by the plan cache).
            let ell: Ell = sample_ell(&g, w, Strategy::Aes);
            let picked = exec::select_kernel(&GraphProfile::of_ell(&ell), f, Some(w), &env);
            let r = b.run(format!("dispatched aes w{w} (warm plan) → {}", picked.name()), || {
                exec::run_ell(picked, &ell, &feats, f, &mut out, threads)
            });
            print_result(&r, None);
            rec.push(&r, None);

            // Scalar-vs-SIMD on the sampled kernel (serial, so the two
            // cases differ only in the vector arm).
            let r = b.run(format!("aes w{w} forced scalar (serial)"), || {
                ell_spmm_at(simd::SimdLevel::Scalar, &ell, &feats, f, &mut out)
            });
            print_result(&r, None);
            rec.push(&r, None);
            let r = b.run(format!("aes w{w} {} (serial)", simd::level().name()), || {
                ell_spmm_at(simd::level(), &ell, &feats, f, &mut out)
            });
            print_result(&r, None);
            rec.push(&r, None);

            // fp32-dequant vs true-INT8-compute on the same sampled
            // plan: the i8 case consumes u8 codes directly.
            let aq = AdjQuant::from_ell(&ell, &params);
            let r = b.run(format!("aes w{w} i8-compute (serial)"), || {
                ell_spmm_i8(&ell, &aq, &qb, f, &mut out)
            });
            print_result(&r, None);
            rec.push(&r, None);
        }

        // --- Segmented reductions: the model zoo's attention and
        // max-pool passes (docs/models.md). The α pipeline (per-node
        // scores → per-edge LeakyReLU logits → segmented softmax) is
        // GAT's extra cost over plain SpMM; the max-pool is SAGE's.
        let a_src: Vec<f32> = (0..f).map(|_| rng.f32() - 0.5).collect();
        let a_dst: Vec<f32> = (0..f).map(|_| rng.f32() - 0.5).collect();
        let r = b.run("gat scores (1 thread)", || {
            let _ = attention_scores(&feats, &a_src, n, f);
        });
        print_result(&r, None);
        rec.push(&r, None);
        let r = b.run(format!("gat scores ({threads} threads)"), || {
            let _ = attention_scores_par(&feats, &a_src, n, f, threads);
        });
        print_result(&r, None);
        rec.push(&r, None);
        let s_src = attention_scores(&feats, &a_src, n, f);
        let s_dst = attention_scores(&feats, &a_dst, n, f);
        let lvl = simd::level();
        let r = b.run("gat alpha csr (1 thread)", || {
            let _ = gat_alpha_csr(lvl, &g, &s_src, &s_dst);
        });
        print_result(&r, None);
        rec.push(&r, None);
        let r = b.run(format!("gat alpha csr ({threads} threads)"), || {
            let _ = gat_alpha_csr_par(lvl, &g, &s_src, &s_dst, threads);
        });
        print_result(&r, None);
        rec.push(&r, None);
        let ell = sample_ell(&g, 64, Strategy::Aes);
        let r = b.run("gat alpha aes w64 (sampled renormalize, 1 thread)", || {
            let _ = gat_alpha_ell(lvl, &ell, &s_src, &s_dst);
        });
        print_result(&r, None);
        rec.push(&r, None);
        let r = b.run(format!("sage max-pool csr ({threads} threads)"), || {
            segmented_max_csr_par(lvl, &g, &feats, f, &mut out, threads)
        });
        print_result(&r, None);
        rec.push(&r, None);

        let mut wl = BTreeMap::new();
        wl.insert("name".to_string(), JsonValue::Str(name.to_string()));
        wl.insert("n".to_string(), JsonValue::Num(n as f64));
        wl.insert("nnz".to_string(), JsonValue::Num(g.nnz() as f64));
        wl.insert("cases".to_string(), rec.to_json());
        workload_json.push(JsonValue::Obj(wl));
    }

    report.insert("workloads".to_string(), JsonValue::Arr(workload_json));
    if let Some(path) = json_path {
        let doc = JsonValue::Obj(report);
        match std::fs::write(&path, doc.to_string()) {
            Ok(()) => println!("\nwrote baseline {path}"),
            Err(e) => eprintln!("\nfailed to write {path}: {e}"),
        }
    }
}
