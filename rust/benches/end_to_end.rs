//! Bench: end-to-end inference latency through the AOT PJRT artifacts —
//! exact baseline vs sampled vs quantized, per dataset. Requires
//! `make artifacts` (skips gracefully when artifacts are missing).
//!
//! Run: `cargo bench --bench end_to_end`

use aes_spmm::bench::{print_header, print_result, Bencher};
use aes_spmm::quant::Precision;
use aes_spmm::runtime::{run_forward, Dataset, Engine, ForwardRequest, Weights};
use aes_spmm::sampling::Strategy;

fn main() {
    let artifacts = "artifacts";
    let engine = match Engine::new(artifacts) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping end_to_end bench (run `make artifacts` first): {e:#}");
            return;
        }
    };
    let b = Bencher::heavy();

    for ds_name in ["cora", "proteins", "products"] {
        let Ok(ds) = Dataset::load(artifacts, ds_name) else { continue };
        let weights = Weights::load(artifacts, "gcn", ds_name).unwrap();
        print_header(&format!("gcn on {ds_name} (n={}, nnz={})", ds.n, ds.nnz));

        let mut go = |label: &str, req: ForwardRequest| {
            // Warm the executable cache outside the timed region.
            run_forward(&engine, &ds, &weights, &req, None).unwrap();
            let r = b.run(label, || {
                run_forward(&engine, &ds, &weights, &req, None).unwrap()
            });
            print_result(&r, None);
        };

        go(
            "exact baseline (segment-sum)",
            ForwardRequest {
                model: "gcn".into(),
                dataset: ds_name.into(),
                width: None,
                strategy: Strategy::Aes,
                precision: Precision::F32,
            },
        );
        for w in [16usize, 64, 256] {
            go(
                &format!("aes w{w} (fused sample+spmm)"),
                ForwardRequest {
                    model: "gcn".into(),
                    dataset: ds_name.into(),
                    width: Some(w),
                    strategy: Strategy::Aes,
                    precision: Precision::F32,
                },
            );
        }
        go(
            "aes w64 + int8 (device dequant)",
            ForwardRequest {
                model: "gcn".into(),
                dataset: ds_name.into(),
                width: Some(64),
                strategy: Strategy::Aes,
                precision: Precision::U8Device,
            },
        );
    }
}
