//! Bench: the sampling planner itself — the index-computation cost that
//! separates AFS (one hash per slot) from SFS (no hashing) from AES
//! (one hash per sample). This is the paper's §3.3 overhead argument:
//! AES's speedup over AFS comes from fewer start-index computations.
//!
//! Run: `cargo bench --bench sampling`

use aes_spmm::bench::{black_box, print_header, print_result, Bencher};
use aes_spmm::gen;
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::{plan_row, sample_ell, sampling_rate_cdf, Strategy};

fn main() {
    let b = Bencher::default();

    // Pure index math per row, degree regimes from Table 1.
    print_header("plan_row: per-row index computation (1000 rows)");
    for deg in [8usize, 100, 1000, 10_000, 60_000] {
        for strat in Strategy::ALL {
            for w in [16usize, 128] {
                let r = b.run(format!("deg={deg} {} w{w}", strat.name()), || {
                    for _ in 0..1000 {
                        black_box(plan_row(black_box(deg), w, strat));
                    }
                });
                print_result(&r, None);
            }
        }
    }

    // Whole-graph ELL planning (the kernel's lines 5–14 on the host).
    let mut rng = Pcg32::new(3);
    let g = gen::with_self_loops(&gen::chung_lu(4096, 60.0, 2.0, &mut rng));
    print_header(&format!("sample_ell on n={} nnz={}", g.n_rows, g.nnz()));
    for w in [16usize, 64, 256] {
        for strat in Strategy::ALL {
            let r = b.run(format!("{} w{w}", strat.name()), || black_box(sample_ell(&g, w, strat)));
            print_result(&r, Some(("Medges/s", r.throughput(g.nnz()) / 1e6)));
        }
    }

    // Fig. 5 statistic cost.
    print_header("sampling_rate_cdf (Fig. 5 series)");
    for w in [16usize, 256] {
        let r = b.run(format!("aes w{w}"), || black_box(sampling_rate_cdf(&g, w, Strategy::Aes)));
        print_result(&r, None);
    }
}
