//! Bench: the feature-loading path — Table 3's premise, measured on this
//! machine. Compares the fp32 buffered baseline against the streaming
//! INT8 pipeline (mmap + lazy per-block dequant + async prefetch), and
//! demonstrates the prefetcher hiding next-batch staging behind the
//! current batch's SpMM.
//!
//! Run: `cargo bench --bench loading`
//! JSON baseline: `cargo bench --bench loading -- --json [PATH]`
//! (default PATH `BENCH_loading.json`). The JSON carries the cold/warm
//! staging times plus the staged-byte accounting — the acceptance signal
//! is `byte_reduction` (INT8 bytes vs fp32 bytes, 4× by construction,
//! mirroring the paper's byte shrink).

use std::collections::BTreeMap;
use std::sync::Arc;

use aes_spmm::bench::{black_box, print_header, print_result, BenchResult, Bencher};
use aes_spmm::exec::{PlanCache, Pool, Prefetcher};
use aes_spmm::gen;
use aes_spmm::quant::{ChunkedParams, FeatureStore, Features, LoadSource, Precision};
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::{sample_ell, Strategy};
use aes_spmm::spmm::ell_spmm_par;
use aes_spmm::tensor::{write_nbt, NbtFile, Tensor};
use aes_spmm::util::JsonValue;

const N: usize = 16_384;
const F: usize = 64;
const W: usize = 16;

fn write_dataset(dir: &std::path::Path) -> std::path::PathBuf {
    let mut rng = Pcg32::new(7);
    let feat: Vec<f32> = (0..N * F).map(|_| rng.f32() - 0.5).collect();
    let chunked = ChunkedParams::of_rows(&feat, N, F, 512);
    let pairs: Vec<f32> = chunked.chunks().iter().flat_map(|p| [p.x_min, p.x_max]).collect();
    let envelope = chunked.envelope();
    let mut nbt = NbtFile::new();
    nbt.insert("feat", Tensor::from_f32(&[N, F], &feat));
    nbt.insert("featq", Tensor::from_u8(&[N, F], &chunked.quantize_rows(&feat, F)));
    nbt.insert("qrange", Tensor::from_f32(&[2], &[envelope.x_min, envelope.x_max]));
    nbt.insert("qchunks", Tensor::from_f32(&[chunked.n_chunks(), 2], &pairs));
    let path = dir.join("bench_loading.nbt");
    write_nbt(&path, &nbt).unwrap();
    path
}

struct Recorder {
    cases: Vec<(BenchResult, usize)>,
}

impl Recorder {
    fn push(&mut self, r: &BenchResult, bytes_staged: usize) {
        self.cases.push((r.clone(), bytes_staged));
    }

    fn to_json(&self) -> JsonValue {
        JsonValue::Arr(
            self.cases
                .iter()
                .map(|(r, bytes)| {
                    let mut obj = match r.to_json() {
                        JsonValue::Obj(m) => m,
                        _ => unreachable!("BenchResult::to_json returns an object"),
                    };
                    obj.insert("bytes_staged".to_string(), JsonValue::Num(*bytes as f64));
                    JsonValue::Obj(obj)
                })
                .collect(),
        )
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let json_path = args.iter().position(|a| a == "--json").map(|i| {
        args.get(i + 1)
            .filter(|p| !p.starts_with("--"))
            .cloned()
            .unwrap_or_else(|| "BENCH_loading.json".to_string())
    });

    let dir = std::env::temp_dir().join(format!("bench_loading_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = write_dataset(&dir);
    let buffered = FeatureStore::open_buffered(&path).expect("open buffered store");
    let mapped = FeatureStore::open(&path).expect("open store");
    let threads = aes_spmm::exec::ExecEnv::detect().threads;
    aes_spmm::exec::warm_pool();

    let b = Bencher::default();
    let mut rec = Recorder { cases: Vec::new() };
    print_header(&format!(
        "feature loading: n={N} f={F} (fp32 {} MiB, int8 {} MiB, source {})",
        (N * F * 4) >> 20,
        (N * F) >> 20,
        mapped.source().name()
    ));

    // --- cold staging: the per-inference cost Table 3 times ---------------
    let mut fp32_bytes = 0usize;
    let r = b.run("fp32 cold load (buffered baseline)", || {
        let (f, s) = buffered.load(Precision::F32).unwrap();
        fp32_bytes = s.bytes_read;
        black_box(matches!(f, Features::Dense(_)));
    });
    let gibps = fp32_bytes as f64 / r.median.as_secs_f64() / (1u64 << 30) as f64;
    print_result(&r, Some(("GiB/s", gibps)));
    rec.push(&r, fp32_bytes);
    let fp32_cold = r.median;

    let mut int8_eager_bytes = 0usize;
    let r = b.run("int8 cold load (buffered)", || {
        let (_, s) = buffered.load(Precision::U8Device).unwrap();
        int8_eager_bytes = s.bytes_read;
    });
    print_result(&r, None);
    rec.push(&r, int8_eager_bytes);

    let r = b.run("fp32 cold load (mmap copy)", || {
        black_box(mapped.load(Precision::F32).unwrap().1.bytes_read);
    });
    print_result(&r, None);
    rec.push(&r, fp32_bytes);

    // The streamed cold path: stage (zero-copy handle) + lazily dequantize
    // every row-block, i.e. everything a full layer-1 pass would stage.
    let mut int8_stream_bytes = 0usize;
    let mut scratch = vec![0.0f32; N * F];
    let r = b.run("int8 stage + full lazy dequant (mmap)", || {
        let before = mapped.totals().bytes_read;
        let (f, _) = mapped.stage(Precision::U8Device).unwrap();
        match f {
            Features::Streamed(h) => {
                for row0 in (0..N).step_by(1024) {
                    let hi = (row0 + 1024).min(N);
                    h.fill_rows_f32(row0, &mut scratch[row0 * F..hi * F]);
                }
            }
            // No-mmap fallback: the eager load already decoded host-side
            // (chunk-encoded payloads come back Dense).
            _ => {}
        }
        int8_stream_bytes = (mapped.totals().bytes_read - before) as usize;
        black_box(scratch[0]);
    });
    print_result(&r, None);
    rec.push(&r, int8_stream_bytes);
    let int8_cold = r.median;

    // --- warm route: the plan cache hit path ------------------------------
    let cache: Arc<PlanCache<u32, Tensor>> = Arc::new(PlanCache::new(4));
    let (feats, _) = mapped.stage(Precision::U8Device).unwrap();
    let handle = match feats {
        Features::Streamed(h) => Some(h),
        _ => None,
    };
    if let Some(h) = handle.clone() {
        cache.insert(0, Arc::new(h.to_dense()));
        let r = b.run("warm route staging (plan-cache hit)", || {
            black_box(cache.get(&0).is_some());
        });
        print_result(&r, None);
        rec.push(&r, 0);
    }

    // --- prefetch overlap: hide next-batch staging behind this SpMM -------
    let mut rng = Pcg32::new(11);
    let g = gen::with_self_loops(&gen::chung_lu(N, 16.0, 2.1, &mut rng));
    let ell = sample_ell(&g, W, Strategy::Aes);
    let dense: Vec<f32> = (0..N * F).map(|_| rng.f32() - 0.5).collect();
    let mut out = vec![0.0f32; N * F];
    let mut overlapped = None;
    if let Some(h) = handle {
        let pf = Prefetcher::new(cache.clone(), Arc::new(Pool::new(1)));
        let hb = h.clone();
        let r = b.run("spmm + next-batch staging, sequential", || {
            black_box(hb.to_dense().shape[0]);
            ell_spmm_par(&ell, &dense, F, &mut out, threads);
        });
        print_result(&r, None);
        rec.push(&r, h.byte_len());
        let sequential = r.median;

        let r = b.run("spmm + next-batch staging, prefetch overlap", || {
            cache.invalidate(&1);
            let hp = h.clone();
            pf.prefetch(1, move || Ok::<_, std::io::Error>(hp.to_dense()));
            ell_spmm_par(&ell, &dense, F, &mut out, threads);
            let hp = h.clone();
            let (t, _) = pf.fetch(&1, move || Ok::<_, std::io::Error>(hp.to_dense())).unwrap();
            black_box(t.shape[0]);
        });
        print_result(&r, None);
        rec.push(&r, h.byte_len());
        println!(
            "  overlap hides {:.1}% of staging behind compute",
            100.0 * (1.0 - r.median.as_secs_f64() / sequential.as_secs_f64().max(1e-12))
        );
        overlapped = Some((sequential, r.median));
    }

    // --- report -----------------------------------------------------------
    let reduction = fp32_bytes as f64 / int8_stream_bytes.max(int8_eager_bytes).max(1) as f64;
    println!(
        "\nbytes staged: fp32 {} vs int8 {} -> {reduction:.2}x cut; cold {:?} -> {:?}",
        fp32_bytes,
        int8_stream_bytes.max(int8_eager_bytes),
        fp32_cold,
        int8_cold,
    );

    if let Some(path) = json_path {
        let mut report: BTreeMap<String, JsonValue> = BTreeMap::new();
        report.insert("bench".to_string(), JsonValue::Str("loading".to_string()));
        report.insert("n".to_string(), JsonValue::Num(N as f64));
        report.insert("feat_dim".to_string(), JsonValue::Num(F as f64));
        report.insert("threads".to_string(), JsonValue::Num(threads as f64));
        report.insert("source".to_string(), JsonValue::Str(mapped.source().name().to_string()));
        report.insert(
            "mmap_available".to_string(),
            JsonValue::Num((mapped.source() == LoadSource::Mmap) as usize as f64),
        );
        report.insert("fp32_bytes".to_string(), JsonValue::Num(fp32_bytes as f64));
        report.insert(
            "int8_bytes".to_string(),
            JsonValue::Num(int8_stream_bytes.max(int8_eager_bytes) as f64),
        );
        report.insert("byte_reduction".to_string(), JsonValue::Num(reduction));
        if let Some((seq, ovl)) = overlapped {
            report.insert(
                "sequential_stage_plus_spmm_ns".to_string(),
                JsonValue::Num(seq.as_nanos() as f64),
            );
            report.insert(
                "overlapped_stage_plus_spmm_ns".to_string(),
                JsonValue::Num(ovl.as_nanos() as f64),
            );
        }
        report.insert("cases".to_string(), rec.to_json());
        let doc = JsonValue::Obj(report);
        match std::fs::write(&path, doc.to_string()) {
            Ok(()) => println!("wrote baseline {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
