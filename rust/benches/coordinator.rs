//! Bench: coordinator overhead — batcher grouping latency, submit→reply
//! round trip with a no-op-sized workload, and the warm-route plan cache
//! against the seed's per-batch feature reload. L3 must not be the
//! bottleneck (DESIGN.md §Perf target: batching adds well under a
//! millisecond of overhead).
//!
//! Run: `cargo bench --bench coordinator`

use std::sync::mpsc;
use std::time::{Duration, Instant};

use aes_spmm::bench::{print_header, print_result, Bencher};
use aes_spmm::coordinator::{Batch, BatcherConfig, InferRequest, RouteKey};
use aes_spmm::exec::{prepare_plan, ExecEnv, ExecPlan, PlanCache, PlanSpec};
use aes_spmm::gen;
use aes_spmm::quant::{quantize, FeatureStore, Precision, QuantParams};
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::Strategy;
use aes_spmm::tensor::{write_nbt, NbtFile, Tensor};

fn key(w: usize) -> RouteKey {
    RouteKey {
        model: "gcn".into(),
        dataset: "cora".into(),
        width: Some(w),
        strategy: Strategy::Aes,
        precision: Precision::F32,
    }
}

/// Drive the batcher loop directly with a synthetic sink (no PJRT), so the
/// measured number is pure coordination overhead.
fn batcher_round_trip(n_requests: usize, max_batch: usize) -> Duration {
    let (in_tx, in_rx) = mpsc::channel::<InferRequest>();
    let (out_tx, out_rx) = mpsc::channel::<Batch>();
    let cfg = BatcherConfig { max_batch, max_delay: Duration::from_micros(500) };
    let h = std::thread::spawn(move || aes_spmm::coordinator::run_batcher(cfg, in_rx, out_tx));

    let sink = std::thread::spawn(move || {
        let mut served = 0usize;
        while let Ok(batch) = out_rx.recv() {
            for req in batch.requests {
                let _ = req.reply.send(aes_spmm::coordinator::InferResponse {
                    id: req.id,
                    predictions: Vec::new(),
                    latency: req.enqueued.elapsed(),
                    batch_size: 1,
                    error: None,
                });
                served += 1;
            }
            if served >= 1 {} // keep draining until channel closes
        }
    });

    let t0 = Instant::now();
    let mut replies = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let (tx, rx) = mpsc::channel();
        in_tx
            .send(InferRequest {
                id: i as u64,
                key: key(16 + (i % 3) * 16),
                nodes: vec![i % 100],
                enqueued: Instant::now(),
                reply: tx,
            })
            .unwrap();
        replies.push(rx);
    }
    for rx in replies {
        rx.recv().unwrap();
    }
    let d = t0.elapsed();
    drop(in_tx);
    h.join().unwrap();
    sink.join().unwrap();
    d
}

/// Warm-route plan resolution vs the seed's per-batch reload, over a
/// synthetic feature store (no artifacts needed): this is the acceptance
/// micro-bench for the exec-layer plan cache.
fn plan_cache_vs_reload() {
    let dir = std::env::temp_dir().join(format!("coordinator_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let n = 8192;
    let f = 64;
    let mut rng = Pcg32::new(4242);
    let csr = gen::with_self_loops(&gen::chung_lu(n, 12.0, 2.1, &mut rng));
    let feat: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
    let params = QuantParams::of(&feat);
    let mut nbt = NbtFile::new();
    nbt.insert("feat", Tensor::from_f32(&[n, f], &feat));
    nbt.insert("featq", Tensor::from_u8(&[n, f], &quantize(&feat, params)));
    nbt.insert("qrange", Tensor::from_f32(&[2], &[params.x_min, params.x_max]));
    let path = dir.join("data_bench.nbt");
    write_nbt(&path, &nbt).expect("write synthetic dataset");
    let fstore = FeatureStore::open(&path).expect("open feature store");

    let env = ExecEnv::detect();
    let build = || {
        let spec = PlanSpec {
            csr: &csr,
            width: Some(32),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: false,
            shard: None,
            shard_bounds: None,
            shard_cache: None,
        };
        prepare_plan(&fstore, Precision::F32, &spec, f, &env).expect("prepare plan")
    };

    let b = Bencher::default();
    print_header(&format!("route plan resolution (n={n}, f={f}, fp32 features)"));

    // The seed's behavior: every batch re-reads features and re-samples.
    let cold = b.run("per-batch rebuild (seed behavior)", || build());

    // The exec-layer path: one cold build, then cache hits.
    let cache: PlanCache<&'static str, ExecPlan> = PlanCache::new(8);
    cache.get_or_try_insert(&"route", || Ok::<_, anyhow::Error>(build())).unwrap();
    let warm = b.run("plan cache hit (warm route)", || {
        let (plan, hit) = cache
            .get_or_try_insert(&"route", || Ok::<_, anyhow::Error>(build()))
            .unwrap();
        assert!(hit);
        plan
    });
    print_result(&cold, None);
    print_result(&warm, None);
    println!(
        "warm route is {:.1}x faster than per-batch reload ({} storage loads total)",
        cold.median.as_secs_f64() / warm.median.as_secs_f64().max(1e-12),
        fstore.load_count(),
    );
}

fn main() {
    let b = Bencher::default();

    print_header("batcher round trip (no PJRT, pure coordination)");
    for (n, mb) in [(100usize, 16usize), (1000, 16), (1000, 64)] {
        let r = b.run(format!("{n} reqs, max_batch {mb}"), || batcher_round_trip(n, mb));
        print_result(&r, Some(("req/s", n as f64 / r.median.as_secs_f64())));
    }

    plan_cache_vs_reload();
}
