//! Bench: coordinator overhead — batcher grouping latency, submit→reply
//! round trip with a no-op-sized workload, and amortization behavior as
//! the offered load grows. L3 must not be the bottleneck (DESIGN.md §Perf
//! target: batching adds well under a millisecond of overhead).
//!
//! Run: `cargo bench --bench coordinator`

use std::sync::mpsc;
use std::time::{Duration, Instant};

use aes_spmm::bench::{print_header, print_result, Bencher};
use aes_spmm::coordinator::{Batch, BatcherConfig, InferRequest, RouteKey};
use aes_spmm::quant::Precision;
use aes_spmm::sampling::Strategy;

fn key(w: usize) -> RouteKey {
    RouteKey {
        model: "gcn".into(),
        dataset: "cora".into(),
        width: Some(w),
        strategy: Strategy::Aes,
        precision: Precision::F32,
    }
}

/// Drive the batcher loop directly with a synthetic sink (no PJRT), so the
/// measured number is pure coordination overhead.
fn batcher_round_trip(n_requests: usize, max_batch: usize) -> Duration {
    let (in_tx, in_rx) = mpsc::channel::<InferRequest>();
    let (out_tx, out_rx) = mpsc::channel::<Batch>();
    let cfg = BatcherConfig { max_batch, max_delay: Duration::from_micros(500) };
    let h = std::thread::spawn(move || aes_spmm::coordinator::run_batcher(cfg, in_rx, out_tx));

    let sink = std::thread::spawn(move || {
        let mut served = 0usize;
        while let Ok(batch) = out_rx.recv() {
            for req in batch.requests {
                let _ = req.reply.send(aes_spmm::coordinator::InferResponse {
                    id: req.id,
                    predictions: Vec::new(),
                    latency: req.enqueued.elapsed(),
                    batch_size: 1,
                    error: None,
                });
                served += 1;
            }
            if served >= 1 {} // keep draining until channel closes
        }
    });

    let t0 = Instant::now();
    let mut replies = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let (tx, rx) = mpsc::channel();
        in_tx
            .send(InferRequest {
                id: i as u64,
                key: key(16 + (i % 3) * 16),
                nodes: vec![i % 100],
                enqueued: Instant::now(),
                reply: tx,
            })
            .unwrap();
        replies.push(rx);
    }
    for rx in replies {
        rx.recv().unwrap();
    }
    let d = t0.elapsed();
    drop(in_tx);
    h.join().unwrap();
    sink.join().unwrap();
    d
}

fn main() {
    let b = Bencher::default();

    print_header("batcher round trip (no PJRT, pure coordination)");
    for (n, mb) in [(100usize, 16usize), (1000, 16), (1000, 64)] {
        let r = b.run(format!("{n} reqs, max_batch {mb}"), || batcher_round_trip(n, mb));
        print_result(&r, Some(("req/s", n as f64 / r.median.as_secs_f64())));
    }
}
