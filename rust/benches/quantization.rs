//! Bench: quantization/dequantization throughput and the feature-store
//! loading paths — the mechanism behind Table 3 (INT8 loading moves 4x
//! fewer bytes; host dequant must be cheap enough not to eat the win).
//!
//! Run: `cargo bench --bench quantization`

use aes_spmm::bench::{black_box, print_header, print_result, Bencher};
use aes_spmm::quant::{dequantize_into, quantize, QuantParams};
use aes_spmm::rng::Pcg32;
use aes_spmm::tensor::{write_nbt, NbtFile, Tensor};

fn main() {
    let b = Bencher::default();
    let mut rng = Pcg32::new(1);

    for (n, f) in [(2048usize, 64usize), (8192, 64), (8192, 256)] {
        let data: Vec<f32> = (0..n * f).map(|_| rng.f32() * 8.0 - 4.0).collect();
        let p = QuantParams::of(&data);
        let bytes = n * f * 4;

        print_header(&format!("feature tensor {n}x{f} ({} MB f32)", bytes / 1_000_000));

        let r = b.run("quantize (offline, Eq. 1)", || black_box(quantize(&data, p)));
        print_result(&r, Some(("GB/s", r.throughput(bytes) / 1e9)));

        let q = quantize(&data, p);
        let mut out = vec![0.0f32; q.len()];
        let r = b.run("dequantize_into (host, Eq. 2)", || {
            dequantize_into(&q, p, &mut out);
        });
        print_result(&r, Some(("GB/s", r.throughput(bytes) / 1e9)));
    }

    // Disk loading: fp32 vs u8 via the nbt container (the Table 3 stage).
    let dir = std::env::temp_dir().join("aes_spmm_quant_bench");
    std::fs::create_dir_all(&dir).unwrap();
    let (n, f) = (8192usize, 64usize);
    let data: Vec<f32> = (0..n * f).map(|_| rng.f32()).collect();
    let p = QuantParams::of(&data);
    let q = quantize(&data, p);
    let mut nbt = NbtFile::new();
    nbt.insert("feat", Tensor::from_f32(&[n, f], &data));
    nbt.insert("featq", Tensor::from_u8(&[n, f], &q));
    nbt.insert("qrange", Tensor::from_f32(&[2], &[p.x_min, p.x_max]));
    let path = dir.join("bench.nbt");
    write_nbt(&path, &nbt).unwrap();

    print_header("feature loading from storage (.nbt, 8192x64)");
    let r = b.run("load f32 tensor", || {
        let f = aes_spmm::tensor::read_nbt(&path).unwrap();
        black_box(f.get("feat").unwrap().byte_len())
    });
    print_result(&r, Some(("GB/s", r.throughput(n * f * 4) / 1e9)));
    let r = b.run("load u8 tensor (quantized path)", || {
        let f = aes_spmm::tensor::read_nbt(&path).unwrap();
        black_box(f.get("featq").unwrap().byte_len())
    });
    print_result(&r, Some(("GB/s", r.throughput(n * f) / 1e9)));
}
