//! Differential accuracy suite — every grid point of the conformance
//! harness forwards through the **coordinator** (plan cache, prefetcher,
//! sharded execution, host backend) and is asserted against the exact
//! oracle within the budget table; the INT8 streamed-vs-eager and
//! sharded-vs-unsharded invariants are additionally pinned as exact
//! (bitwise) assertions on raw logits.
//!
//! Runs with no artifacts and no PJRT runtime: the seeded conformance
//! datasets are generated on the fly (deterministically) and served on
//! [`Backend::Host`].

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use aes_spmm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, ModelStore, RouteKey,
};
use aes_spmm::eval::{
    oracle_forward, run_eval, width_grid, write_eval_datasets, PrecisionMode, SHARD_GRID,
};
use aes_spmm::graph::ShardSpec;
use aes_spmm::quant::Precision;
use aes_spmm::runtime::Backend;
use aes_spmm::sampling::Strategy;
use aes_spmm::util::argmax_f32;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("accuracy_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// A host coordinator over the conformance datasets with explicit
/// streaming/sharding knobs.
fn start(dir: &Path, names: &[String], streaming: bool, shards: usize) -> Coordinator {
    let store = Arc::new(ModelStore::load(dir, names, &["gcn".to_string()]).unwrap());
    Coordinator::start_with(
        Backend::Host,
        store,
        CoordinatorConfig {
            workers: 2,
            queue_depth: 128,
            batcher: BatcherConfig { max_batch: 8, max_delay: Duration::from_millis(1) },
            plan_cache_capacity: 64,
            prefetch_workers: 1,
            sharding: (shards > 1).then(|| ShardSpec::by_count(shards)),
            streaming,
            ..CoordinatorConfig::default()
        },
    )
}

fn key(dataset: &str, width: Option<usize>, strategy: Strategy, precision: Precision) -> RouteKey {
    RouteKey { model: "gcn".into(), dataset: dataset.into(), width, strategy, precision }
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: logit {i} differs ({x} vs {y})");
    }
}

/// The headline assertion: the full {strategy × width × precision ×
/// shards} grid, forwarded through the coordinator, sits inside the
/// budget table — and every cross-configuration check holds.
#[test]
fn full_grid_meets_the_budget_table() {
    let report = run_eval(&tmp("grid"), false).unwrap();
    let failures = report.failures();
    assert!(failures.is_empty(), "accuracy budget violations:\n{}", failures.join("\n"));

    // Full coverage: per dataset, 1 exact shape + widths×strategies
    // sampled shapes, × 3 precision modes × 2 shard counts.
    let sampled_widths = width_grid(false).iter().filter(|w| w.is_some()).count();
    let shapes = 1 + sampled_widths * Strategy::ALL.len();
    let expected = 2 * shapes * PrecisionMode::ALL.len() * SHARD_GRID.len();
    assert_eq!(report.configs.len(), expected, "grid coverage shrank");
    assert_eq!(report.datasets.len(), 2);

    // The three invariant families all ran.
    for needle in ["streamed == eager", "sharded == unsharded", "int8 vs fp32 delta"] {
        assert!(
            report.checks.iter().any(|c| c.name.contains(needle)),
            "missing check family {needle:?}"
        );
    }
    // Both sampling branches of sampling::shard_width were exercised.
    assert!(report.checks.iter().any(|c| c.name.contains("sampled branch")
        || c.name.contains("skewed shards sample")));
    assert!(report.checks.iter().any(|c| c.name.contains("exhaustive")));
    // The exact fp32 route is the oracle bit-for-bit (budget `bitwise`).
    for c in &report.configs {
        if c.width.is_none() && c.mode == PrecisionMode::F32 {
            assert!(c.metrics.bitwise_equal, "exact fp32 drifted from the oracle: {}", c.name());
        }
    }
}

/// INT8 streamed and eager staging produce bit-identical logits through
/// the real serving path — exact assertion, not a budget.
#[test]
fn int8_streamed_equals_eager_bitwise_through_the_coordinator() {
    let dir = tmp("stream");
    let names = write_eval_datasets(&dir).unwrap();
    let streaming = start(&dir, &names, true, 1);
    let eager = start(&dir, &names, false, 1);
    let shapes =
        [(Some(8), Strategy::Aes), (Some(32), Strategy::Sfs), (None, Strategy::Aes)];
    for name in &names {
        for (width, strategy) in shapes {
            let k = key(name, width, strategy, Precision::U8Device);
            let a = streaming.route_logits(&k).unwrap();
            let b = eager.route_logits(&k).unwrap();
            assert_bitwise(
                a.as_f32().unwrap(),
                b.as_f32().unwrap(),
                &format!("{name} {width:?}/{strategy:?} streamed vs eager"),
            );
        }
    }
    streaming.shutdown();
    eager.shutdown();
}

/// Sharded serving is bit-identical to unsharded serving for every
/// precision — the PR 3 guarantee as an exact assertion through the
/// coordinator.
#[test]
fn sharded_equals_unsharded_bitwise_through_the_coordinator() {
    let dir = tmp("shard");
    let names = write_eval_datasets(&dir).unwrap();
    let unsharded = start(&dir, &names, true, 1);
    let sharded = start(&dir, &names, true, 3);
    let shapes =
        [(None, Strategy::Aes), (Some(8), Strategy::Aes), (Some(32), Strategy::Afs)];
    for name in &names {
        for precision in [Precision::F32, Precision::U8Device, Precision::I8Compute] {
            for (width, strategy) in shapes {
                let k = key(name, width, strategy, precision);
                let a = unsharded.route_logits(&k).unwrap();
                let b = sharded.route_logits(&k).unwrap();
                assert_bitwise(
                    a.as_f32().unwrap(),
                    b.as_f32().unwrap(),
                    &format!("{name} {width:?}/{strategy:?}/{precision:?} sharded vs unsharded"),
                );
            }
        }
    }
    unsharded.shutdown();
    sharded.shutdown();
}

/// The exact fp32 route served by the coordinator IS the oracle,
/// bit-for-bit: dispatch, threading, plan caching, and prefetch change
/// nothing about the canonical FP order.
#[test]
fn exact_fp32_route_is_bitwise_equal_to_the_oracle() {
    let dir = tmp("oracle");
    let names = write_eval_datasets(&dir).unwrap();
    let store = Arc::new(ModelStore::load(&dir, &names, &["gcn".to_string()]).unwrap());
    let coord = start(&dir, &names, true, 1);
    for name in &names {
        let ds = store.dataset(name).unwrap();
        let weights = store.weights("gcn", name).unwrap();
        let want = oracle_forward(&ds, &weights).unwrap();
        // Serve twice: the second pass comes from the warm plan cache
        // and must not drift either.
        let exact = key(name, None, Strategy::Aes, Precision::F32);
        for round in 0..2 {
            let got = coord.route_logits(&exact).unwrap();
            assert_bitwise(
                &want,
                got.as_f32().unwrap(),
                &format!("{name} exact fp32 vs oracle (round {round})"),
            );
        }
    }
    coord.shutdown();
}

/// Batched predictions agree with the route's raw logits under the
/// deterministic argmax tie rule — the reply path adds no drift.
#[test]
fn batched_predictions_match_route_logits_argmax() {
    let dir = tmp("argmax");
    let names = write_eval_datasets(&dir).unwrap();
    let coord = start(&dir, &names, true, 1);
    let name = &names[0];
    let k = key(name, Some(8), Strategy::Aes, Precision::U8Device);
    let logits = coord.route_logits(&k).unwrap();
    let vals = logits.as_f32().unwrap();
    let classes = logits.shape[1];
    let nodes: Vec<usize> = (0..logits.shape[0]).step_by(11).collect();
    let resp = coord.infer(k, nodes.clone()).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.predictions.len(), nodes.len());
    for p in &resp.predictions {
        let want = argmax_f32(&vals[p.node * classes..(p.node + 1) * classes]) as i32;
        assert_eq!(p.class, want, "node {}", p.node);
    }
    coord.shutdown();
}
