//! Property tests for the sparse-format zoo (docs/dispatch.md).
//!
//! The dispatcher may rebuild any shard as blocked-CSR or a dense tile;
//! these properties pin the structural invariants that make that safe:
//! conversions are lossless round-trips preserving nnz, values, and the
//! canonical per-row edge order, and the layout bookkeeping (block
//! pointers, pitch) is internally consistent.

use aes_spmm::graph::{coo_to_csr, Csr};
use aes_spmm::rng::Pcg32;
use aes_spmm::spmm::{dense_tile_viable, BlockedCsr, DenseTile, BCSR_BLOCK_ROWS};

/// Run `f` over a family of seeded cases, tagging failures by seed.
fn forall(cases: u64, mut f: impl FnMut(u64, &mut Pcg32)) {
    for seed in 0..cases {
        let mut rng = Pcg32::new(0xF0_4000 + seed);
        f(seed, &mut rng);
    }
}

/// A random graph with duplicate-free rows is not guaranteed here —
/// `coo_to_csr` already canonicalises (sorts + merges), matching what
/// every production graph goes through before it reaches a format.
fn random_csr(rng: &mut Pcg32, n: usize, max_deg: usize) -> Csr {
    let mut triples = Vec::new();
    for i in 0..n {
        for _ in 0..rng.usize_below(max_deg + 1) {
            triples.push((i as i32, rng.usize_below(n) as i32, rng.f32() - 0.5));
        }
    }
    coo_to_csr(n, n, triples).unwrap()
}

#[test]
fn blocked_csr_round_trips_exactly() {
    forall(24, |seed, rng| {
        let n = 1 + rng.usize_below(200);
        let g = random_csr(rng, n, 1 + rng.usize_below(30));
        for h in [1, 3, BCSR_BLOCK_ROWS, n + 7] {
            let m = BlockedCsr::from_csr(&g, h);
            assert_eq!(m.nnz(), g.row_ptr[n] as usize, "seed {seed} h={h}: nnz");
            assert_eq!(m.to_csr(), g, "seed {seed} h={h}: round trip");
        }
    });
}

#[test]
fn blocked_csr_block_ptr_is_consistent_with_row_ptr() {
    forall(24, |seed, rng| {
        let n = 1 + rng.usize_below(200);
        let g = random_csr(rng, n, 1 + rng.usize_below(30));
        let h = 1 + rng.usize_below(2 * BCSR_BLOCK_ROWS);
        let m = BlockedCsr::from_csr(&g, h);
        assert_eq!(m.block_rows, h, "seed {seed}: height preserved");
        assert_eq!(m.block_ptr.len(), m.n_blocks() + 1, "seed {seed}: ptr len");
        for k in 0..=m.n_blocks() {
            let first_row = (k * h).min(n);
            assert_eq!(
                m.block_ptr[k], g.row_ptr[first_row] as usize,
                "seed {seed} h={h}: block_ptr[{k}] aligns with row_ptr"
            );
        }
        for i in 0..n {
            let r = m.row_range(i);
            assert_eq!(
                (r.start, r.end),
                (g.row_ptr[i] as usize, g.row_ptr[i + 1] as usize),
                "seed {seed} h={h}: row_range({i})"
            );
        }
    });
}

#[test]
fn dense_tile_round_trips_exactly() {
    forall(24, |seed, rng| {
        let n = 1 + rng.usize_below(120);
        let g = random_csr(rng, n, 1 + rng.usize_below(24));
        let t = DenseTile::from_csr(&g);
        assert_eq!(t.nnz(), g.row_ptr[n] as usize, "seed {seed}: nnz");
        assert_eq!(t.to_csr(), g, "seed {seed}: round trip");
    });
}

#[test]
fn dense_tile_pitch_covers_the_maximum_degree() {
    forall(24, |seed, rng| {
        let n = 1 + rng.usize_below(120);
        let g = random_csr(rng, n, 1 + rng.usize_below(24));
        let t = DenseTile::from_csr(&g);
        assert!(t.pitch >= g.max_degree().max(1), "seed {seed}: pitch >= max degree");
        assert_eq!(t.pitch % 8, 0, "seed {seed}: pitch keeps SIMD alignment");
        assert_eq!(t.val.len(), n * t.pitch, "seed {seed}: padded storage size");
        for i in 0..n {
            let deg = (g.row_ptr[i + 1] - g.row_ptr[i]) as usize;
            assert_eq!(t.row_nnz(i), deg, "seed {seed}: row_nnz({i})");
        }
    });
}

#[test]
fn dense_tile_viability_is_monotone_in_slack() {
    // If a graph fits a padding budget, it fits every looser budget —
    // the dispatcher relies on this when relaxing DENSE_TILE_SLACK.
    forall(24, |seed, rng| {
        let n = 1 + rng.usize_below(120);
        let g = random_csr(rng, n, 1 + rng.usize_below(24));
        let mut prev = false;
        for slack in 1..=16 {
            let v = dense_tile_viable(&g, slack);
            assert!(v || !prev, "seed {seed}: viability regressed at slack {slack}");
            prev = v;
        }
    });
}

#[test]
fn degenerate_graphs_survive_both_formats() {
    let empty = Csr::new(0, 4, vec![0], vec![], vec![]).unwrap();
    let lonely = coo_to_csr(5, 5, vec![(2, 3, 1.5f32)]).unwrap();
    for g in [&empty, &lonely] {
        for h in [1, BCSR_BLOCK_ROWS] {
            assert_eq!(BlockedCsr::from_csr(g, h).to_csr(), *g);
        }
        assert_eq!(DenseTile::from_csr(g).to_csr(), *g);
    }
}
