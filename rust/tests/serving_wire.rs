//! Wire-level serving conformance — the ISSUE 8 acceptance suite.
//! Boots a real [`WireServer`] on a loopback ephemeral port over the
//! seeded eval datasets ([`Backend::Host`], no artifacts, no PJRT) and
//! drives it through real TCP connections.
//!
//! Covers:
//! * the correctness anchor: TCP-served logits bitwise-identical to
//!   `route_logits` on a cold in-process coordinator, across the eval
//!   grid (dataset × {exact, sampled} × strategy × precision);
//! * `infer` over the wire agreeing with the argmax of the served
//!   logits, plus per-route latency histograms surfacing in the ops
//!   requests;
//! * admission control: requests past the high-water mark get an
//!   explicit `"shed"` response (never a silent drop or an error),
//!   the shed count lands in metrics, and already-admitted work still
//!   completes;
//! * `mutate` over the wire advancing the epoch with serving following
//!   bitwise;
//! * protocol robustness: malformed frames answered with `"error"`
//!   responses on a surviving connection, oversize frames dropping
//!   only that connection.
//!
//! ISSUE 9 additions:
//! * connection churn leaves no accumulated handles (the front-end
//!   reaps finished connection threads instead of retaining every
//!   JoinHandle + stream clone forever);
//! * the epoch label on a logits response always matches the served
//!   bits, even with a mutate racing the request;
//! * multi-process sharded serving: a router process scatter/gathering
//!   over two `shard-server` worker processes answers bitwise-identical
//!   to a single-process coordinator — including after a replicated
//!   delta and after a worker is killed (re-placement + replay).

use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

use aes_spmm::coordinator::wire::{self, WireRequest};
use aes_spmm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, ModelStore, NetConfig, RouteKey, WireServer,
};
use aes_spmm::eval::write_eval_datasets;
use aes_spmm::quant::Precision;
use aes_spmm::runtime::Backend;
use aes_spmm::sampling::Strategy;
use aes_spmm::util::{argmax_f32, JsonValue};

struct Served {
    server: WireServer,
    dir: PathBuf,
    names: Vec<String>,
}

/// Write the eval datasets into a fresh temp dir and boot a host-backend
/// coordinator behind a wire server on an ephemeral loopback port.
fn boot(tag: &str, net: NetConfig, batcher: BatcherConfig) -> Served {
    let dir = std::env::temp_dir().join(format!("serving_wire_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let names = write_eval_datasets(&dir).unwrap();
    let store = Arc::new(ModelStore::load(&dir, &names, &["gcn".to_string()]).unwrap());
    let coord = Arc::new(Coordinator::start_with(
        Backend::Host,
        store.clone(),
        CoordinatorConfig { workers: 2, batcher, ..CoordinatorConfig::default() },
    ));
    let server = WireServer::bind(coord, store, "127.0.0.1:0", net).unwrap();
    Served { server, dir, names }
}

fn connect(s: &Served) -> TcpStream {
    let stream = TcpStream::connect(s.server.local_addr()).unwrap();
    // Bugs must time out loudly, not hang the suite.
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    stream
}

fn ask(conn: &mut TcpStream, req: &WireRequest) -> JsonValue {
    wire::roundtrip(conn, req).unwrap()
}

fn route(name: &str, width: Option<usize>, strategy: Strategy, precision: Precision) -> RouteKey {
    RouteKey {
        model: "gcn".to_string(),
        dataset: name.to_string(),
        width,
        strategy,
        precision,
    }
}

/// Decode a `logits` response's `logits_bits` array.
fn wire_bits(resp: &JsonValue) -> Vec<u32> {
    resp.get("logits_bits")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_f64().unwrap() as u32)
        .collect()
}

fn in_process_bits(coord: &Coordinator, key: &RouteKey) -> Vec<u32> {
    coord
        .route_logits(key)
        .unwrap()
        .as_f32()
        .unwrap()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

/// The acceptance criterion: every eval-grid route served over TCP is
/// bitwise-identical to `route_logits` on a cold in-process coordinator
/// over the same files.
#[test]
fn wire_logits_are_bitwise_identical_to_in_process() {
    let s = boot("conformance", NetConfig::default(), BatcherConfig::default());
    let cold_store =
        Arc::new(ModelStore::load(&s.dir, &s.names, &["gcn".to_string()]).unwrap());
    let cold = Coordinator::start_with(
        Backend::Host,
        cold_store,
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() },
    );
    let mut conn = connect(&s);
    let shapes = [(None, Strategy::Aes), (Some(8), Strategy::Aes), (Some(8), Strategy::Sfs)];
    let precisions = [Precision::F32, Precision::U8Device, Precision::I8Compute];
    let mut id = 0u64;
    for name in &s.names {
        for &(width, strategy) in &shapes {
            for &precision in &precisions {
                let key = route(name, width, strategy, precision);
                id += 1;
                let resp = ask(&mut conn, &WireRequest::Logits { id, route: key.clone() });
                assert_eq!(
                    wire::response_status(&resp),
                    "ok",
                    "route {}: {}",
                    key.label(),
                    resp.to_string()
                );
                assert_eq!(wire::request_id(&resp), id);
                let rows = resp.get("rows").unwrap().as_usize().unwrap();
                let classes = resp.get("classes").unwrap().as_usize().unwrap();
                let bits = wire_bits(&resp);
                assert_eq!(bits.len(), rows * classes);
                assert_eq!(
                    bits,
                    in_process_bits(&cold, &key),
                    "route {}: TCP-served logits must be bitwise-identical to in-process",
                    key.label()
                );
            }
        }
    }
    cold.shutdown();
    s.server.shutdown();
}

/// `infer` over the wire is the argmax of the served logits; per-route
/// latency histograms surface through the `routes`/`metrics` ops
/// requests; client mistakes (out-of-range node, unknown dataset) are
/// error responses, not dropped connections or panics.
#[test]
fn wire_infer_matches_argmax_and_reports_route_latency() {
    let s = boot("infer", NetConfig::default(), BatcherConfig::default());
    let mut conn = connect(&s);
    let key = route(&s.names[0], Some(8), Strategy::Aes, Precision::U8Device);

    let resp = ask(&mut conn, &WireRequest::Logits { id: 1, route: key.clone() });
    assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
    let classes = resp.get("classes").unwrap().as_usize().unwrap();
    let vals: Vec<f32> = wire_bits(&resp).iter().map(|&b| f32::from_bits(b)).collect();

    let nodes = vec![0usize, 1, 7, 42, 159];
    let resp =
        ask(&mut conn, &WireRequest::Infer { id: 2, route: key.clone(), nodes: nodes.clone() });
    assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
    assert!(resp.get("batch_size").unwrap().as_usize().unwrap() >= 1);
    let preds = resp.get("predictions").unwrap().as_arr().unwrap();
    assert_eq!(preds.len(), nodes.len());
    for (pred, &node) in preds.iter().zip(&nodes) {
        assert_eq!(pred.get("node").unwrap().as_usize().unwrap(), node);
        let class = pred.get("class").unwrap().as_usize().unwrap();
        let row = &vals[node * classes..(node + 1) * classes];
        assert_eq!(class, argmax_f32(row), "node {node}: infer must be the logits argmax");
    }

    // Client mistakes are addressed error responses on a live connection.
    let resp = ask(&mut conn, &WireRequest::Infer { id: 3, route: key.clone(), nodes: vec![9999] });
    assert_eq!(wire::response_status(&resp), "error");
    assert!(resp.get("error").unwrap().as_str().unwrap().contains("out of range"));
    let resp = ask(
        &mut conn,
        &WireRequest::Logits {
            id: 4,
            route: route("nope", Some(8), Strategy::Aes, Precision::F32),
        },
    );
    assert_eq!(wire::response_status(&resp), "error");

    // The batched request shows up in the per-route histograms.
    let resp = ask(&mut conn, &WireRequest::Routes { id: 5 });
    assert_eq!(wire::response_status(&resp), "ok");
    let routes = resp.get("routes").unwrap().as_arr().unwrap();
    let entry = routes
        .iter()
        .find(|r| r.get("name").unwrap().as_str().unwrap() == key.label())
        .unwrap_or_else(|| panic!("route {} missing from routes response", key.label()));
    assert!(entry.get("requests").unwrap().as_usize().unwrap() >= 1);
    let p50 = entry.get("p50_us").unwrap().as_f64().unwrap();
    let p999 = entry.get("p999_us").unwrap().as_f64().unwrap();
    assert!(p999 >= p50, "quantiles must be ordered (p50 {p50}, p999 {p999})");

    let resp = ask(&mut conn, &WireRequest::Metrics { id: 6 });
    assert_eq!(wire::response_status(&resp), "ok");
    assert!(resp.get("completed").unwrap().as_usize().unwrap() >= 1);
    let per_route = resp.get("route_latency").unwrap();
    assert!(per_route.get(&key.label()).is_ok(), "metrics must carry the route histogram");
    s.server.shutdown();
}

/// Admission control under burst, made deterministic by a slow batcher
/// window: while one admitted request holds the single in-flight slot,
/// a second request is refused with a distinct `"shed"` status, the
/// refusal is counted in metrics, and the admitted request still
/// completes (shedding refuses new work, it never abandons admitted
/// work). Once the slot frees, traffic is admitted again.
#[test]
fn burst_past_high_water_sheds_explicitly_and_admitted_work_completes() {
    // max_delay 300ms + huge max_batch: an admitted infer pins the
    // in-flight gauge for ~300ms before the batcher flushes it.
    let s = boot(
        "burst",
        NetConfig { high_water: 1, ..NetConfig::default() },
        BatcherConfig { max_batch: 1000, max_delay: Duration::from_millis(300) },
    );
    let key = route(&s.names[0], Some(8), Strategy::Aes, Precision::F32);

    let slow = {
        let mut conn = connect(&s);
        let key = key.clone();
        std::thread::spawn(move || {
            ask(&mut conn, &WireRequest::Infer { id: 10, route: key, nodes: vec![0, 1] })
        })
    };
    // Well inside the 300ms window the slot is held: this one sheds.
    std::thread::sleep(Duration::from_millis(120));
    let mut conn = connect(&s);
    let resp = ask(&mut conn, &WireRequest::Infer { id: 11, route: key.clone(), nodes: vec![2] });
    assert_eq!(
        wire::response_status(&resp),
        "shed",
        "past the high-water mark the response must be an explicit shed: {}",
        resp.to_string()
    );
    assert!(resp.get("reason").unwrap().as_str().unwrap().contains("high-water"));
    assert!(resp.get("error").is_err(), "a shed is not an error");

    // The admitted request completes with real predictions.
    let resp = slow.join().unwrap();
    assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
    assert_eq!(resp.get("predictions").unwrap().as_arr().unwrap().len(), 2);

    // The refusal is visible in metrics; the slot is free again.
    let resp = ask(&mut conn, &WireRequest::Metrics { id: 12 });
    assert_eq!(resp.get("shed").unwrap().as_usize().unwrap(), 1);
    assert_eq!(resp.get("completed").unwrap().as_usize().unwrap(), 1);
    let resp = ask(&mut conn, &WireRequest::Infer { id: 13, route: key, nodes: vec![3] });
    assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
    s.server.shutdown();
}

/// `high_water = 0` sheds every data-plane request — while the ops
/// surface (status/metrics/routes) and the control plane (mutate) keep
/// answering, so an overloaded server stays observable and steerable.
#[test]
fn high_water_zero_sheds_data_plane_but_ops_still_answer() {
    let s = boot(
        "shed_all",
        NetConfig { high_water: 0, ..NetConfig::default() },
        BatcherConfig::default(),
    );
    let mut conn = connect(&s);
    let key = route(&s.names[0], Some(8), Strategy::Aes, Precision::F32);
    let resp = ask(&mut conn, &WireRequest::Infer { id: 1, route: key.clone(), nodes: vec![0] });
    assert_eq!(wire::response_status(&resp), "shed");
    let resp = ask(&mut conn, &WireRequest::Logits { id: 2, route: key });
    assert_eq!(wire::response_status(&resp), "shed");

    let resp = ask(&mut conn, &WireRequest::Status { id: 3 });
    assert_eq!(wire::response_status(&resp), "ok");
    assert_eq!(resp.get("high_water").unwrap().as_usize().unwrap(), 0);
    let datasets = resp.get("datasets").unwrap().as_arr().unwrap();
    assert_eq!(datasets.len(), s.names.len());
    let resp = ask(&mut conn, &WireRequest::Routes { id: 4 });
    assert_eq!(wire::response_status(&resp), "ok");
    let resp = ask(
        &mut conn,
        &WireRequest::Mutate {
            id: 5,
            dataset: s.names[0].clone(),
            ops: vec!["= 0 0 0.5".to_string()],
        },
    );
    assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());

    let resp = ask(&mut conn, &WireRequest::Metrics { id: 6 });
    assert_eq!(resp.get("shed").unwrap().as_usize().unwrap(), 2);
    s.server.shutdown();
}

/// Mutation over the wire: the delta lands (epoch advances, the report
/// comes back), and subsequent wire serving is bitwise-identical to a
/// cold in-process coordinator with the same delta applied.
#[test]
fn mutate_over_the_wire_advances_epoch_and_serving_follows() {
    let s = boot("mutate", NetConfig::default(), BatcherConfig::default());
    let name = s.names[0].clone();
    let key = route(&name, Some(8), Strategy::Aes, Precision::F32);
    let mut conn = connect(&s);
    // Warm the route at epoch 0 so the delta invalidates something.
    let resp = ask(&mut conn, &WireRequest::Logits { id: 1, route: key.clone() });
    assert_eq!(resp.get("epoch").unwrap().as_usize().unwrap(), 0);

    let ops = vec!["+ 0 159 0.01".to_string(), "- 1 1".to_string(), "# comment".to_string()];
    let resp = ask(
        &mut conn,
        &WireRequest::Mutate { id: 2, dataset: name.clone(), ops: ops.clone() },
    );
    assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
    assert_eq!(resp.get("epoch").unwrap().as_usize().unwrap(), 1);
    // The self-loop delete is certain; the (0, 159) edge counts as an
    // insert or — if the generator happened to draw it — a reweight.
    assert_eq!(resp.get("deleted").unwrap().as_usize().unwrap(), 1);
    let inserted = resp.get("inserted").unwrap().as_usize().unwrap();
    let reweighted = resp.get("reweighted").unwrap().as_usize().unwrap();
    assert_eq!(inserted + reweighted, 1);
    assert_eq!(resp.get("touched_rows").unwrap().as_usize().unwrap(), 2);

    let resp = ask(&mut conn, &WireRequest::Logits { id: 3, route: key.clone() });
    assert_eq!(resp.get("epoch").unwrap().as_usize().unwrap(), 1, "serving follows the epoch");
    let warm = wire_bits(&resp);

    let cold_store =
        Arc::new(ModelStore::load(&s.dir, &s.names, &["gcn".to_string()]).unwrap());
    let cold = Coordinator::start_with(
        Backend::Host,
        cold_store,
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() },
    );
    let delta = aes_spmm::graph::GraphDelta::parse(&ops.join("\n")).unwrap();
    cold.apply_delta(&name, &delta).unwrap();
    assert_eq!(
        warm,
        in_process_bits(&cold, &key),
        "post-mutation wire serving must match a cold rebuild bitwise"
    );
    cold.shutdown();
    s.server.shutdown();
}

/// Garbage in, addressed errors out — and only a frame the server
/// cannot trust (an oversize length announcement) costs the connection.
#[test]
fn malformed_frames_get_errors_and_oversize_drops_the_connection() {
    let s = boot(
        "garbage",
        NetConfig { max_frame: 1024, ..NetConfig::default() },
        BatcherConfig::default(),
    );
    let mut conn = connect(&s);

    // Not JSON: error response, connection survives.
    wire::write_frame(&mut conn, b"not json at all").unwrap();
    let body = wire::read_frame(&mut conn, wire::MAX_FRAME).unwrap().unwrap();
    let resp = aes_spmm::util::parse_json(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(wire::response_status(&resp), "error");

    // Wrong protocol version: error echoing the id, connection survives.
    wire::write_frame(&mut conn, br#"{"v":9,"type":"status","id":5}"#).unwrap();
    let body = wire::read_frame(&mut conn, wire::MAX_FRAME).unwrap().unwrap();
    let resp = aes_spmm::util::parse_json(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(wire::response_status(&resp), "error");
    assert_eq!(wire::request_id(&resp), 5);

    // Still serving on the same connection.
    let resp = ask(&mut conn, &WireRequest::Status { id: 6 });
    assert_eq!(wire::response_status(&resp), "ok");

    // A frame announcing more than the server's cap: the stream is no
    // longer trusted, so the server drops this connection...
    use std::io::Write;
    conn.write_all(&(4096u32).to_le_bytes()).unwrap();
    conn.write_all(&[0u8; 16]).unwrap();
    conn.flush().unwrap();
    let dropped = matches!(wire::read_frame(&mut conn, wire::MAX_FRAME), Ok(None) | Err(_));
    assert!(dropped, "an oversize frame must cost the connection");

    // ...and only that connection: a fresh one is served normally.
    let mut fresh = connect(&s);
    let resp = ask(&mut fresh, &WireRequest::Status { id: 7 });
    assert_eq!(wire::response_status(&resp), "ok");
    s.server.shutdown();
}

/// Connection-lifecycle hygiene: the accept loop used to retain a
/// JoinHandle plus a cloned TcpStream for every connection ever
/// accepted — a slow fd/thread leak under churn. Finished connection
/// threads must be reaped, so sequential connect/request/disconnect
/// cycles leave the tracked-connection count bounded (and visible in
/// `status`).
#[test]
fn connection_churn_does_not_accumulate_handles() {
    let s = boot("churn", NetConfig::default(), BatcherConfig::default());
    for i in 0..40u64 {
        let mut conn = connect(&s);
        let resp = ask(&mut conn, &WireRequest::Status { id: i + 1 });
        assert_eq!(wire::response_status(&resp), "ok");
        drop(conn);
    }
    // Give the closed sockets a beat to EOF their connection threads.
    std::thread::sleep(Duration::from_millis(300));
    let open = s.server.open_connections();
    assert!(
        open <= 8,
        "40 sequential connections left {open} tracked on the server — \
         finished connection threads are not being reaped"
    );
    assert_eq!(s.server.accept_errors(), 0, "healthy listener, no accept errors");

    // The same figures surface through the ops plane.
    let mut conn = connect(&s);
    let resp = ask(&mut conn, &WireRequest::Status { id: 99 });
    assert_eq!(wire::response_status(&resp), "ok");
    assert!(resp.get("connections").unwrap().as_usize().unwrap() <= 8);
    assert_eq!(resp.get("accept_errors").unwrap().as_usize().unwrap(), 0);
    s.server.shutdown();
}

/// The epoch-labeling race: `logits` responses used to read the
/// dataset epoch *before* executing the route, so a concurrent mutate
/// could label epoch-N+1 bits as epoch N (or vice versa). The fix
/// threads the epoch actually bound by the served plan into the
/// response — so whatever interleaving happens, the labeled epoch's
/// reference logits must equal the served bits, every time.
#[test]
fn logits_epoch_label_matches_served_bits_under_racing_mutates() {
    let s = boot("epoch_race", NetConfig::default(), BatcherConfig::default());
    let name = s.names[0].clone();
    let key = route(&name, Some(8), Strategy::Aes, Precision::F32);
    let rounds = 12usize;

    // Reference bits per epoch: epoch k = the first k reweights of the
    // (0, 0) self-loop applied to a cold coordinator. Weights > 1 can
    // never collide with a normalized-adjacency value (all in (0, 1]),
    // and are pairwise distinct — so every delta is a real change and
    // advances the epoch by exactly one.
    let weight = |k: usize| 1.0 + 0.5 * k as f32;
    let cold_store =
        Arc::new(ModelStore::load(&s.dir, &s.names, &["gcn".to_string()]).unwrap());
    let cold = Coordinator::start_with(
        Backend::Host,
        cold_store,
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() },
    );
    let mut reference = vec![in_process_bits(&cold, &key)];
    for k in 1..=rounds {
        let delta =
            aes_spmm::graph::GraphDelta::parse(&format!("= 0 0 {}", weight(k))).unwrap();
        cold.apply_delta(&name, &delta).unwrap();
        reference.push(in_process_bits(&cold, &key));
    }
    cold.shutdown();

    let mut conn = connect(&s);
    let mut id = 100u64;
    for k in 1..=rounds {
        // Race one mutate (on its own connection) against logits reads.
        let mutate = {
            let mut mconn = connect(&s);
            let name = name.clone();
            let ops = vec![format!("= 0 0 {}", weight(k))];
            std::thread::spawn(move || {
                let resp = ask(
                    &mut mconn,
                    &WireRequest::Mutate { id: 10_000 + k as u64, dataset: name, ops },
                );
                assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
            })
        };
        for _ in 0..4 {
            id += 1;
            let resp = ask(&mut conn, &WireRequest::Logits { id, route: key.clone() });
            assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
            let epoch = resp.get("epoch").unwrap().as_usize().unwrap();
            // Monotone epochs: only k-1 (not yet applied) or k (applied)
            // are reachable inside round k.
            assert!(epoch == k - 1 || epoch == k, "round {k} served epoch {epoch}");
            assert_eq!(
                wire_bits(&resp),
                reference[epoch],
                "round {k}: response labeled epoch {epoch} but the bits do not match \
                 that epoch's reference logits"
            );
        }
        mutate.join().unwrap();
    }
    s.server.shutdown();
}

/// Kill the child on drop so a failed assertion never leaks server
/// processes past the test.
struct Proc(Child);

impl Drop for Proc {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Wait for a serving process to publish its bound address (the
/// `--port-file` is written only after the bind succeeds).
fn wait_port(path: &Path, child: &mut Proc) -> String {
    for _ in 0..600 {
        if let Ok(s) = std::fs::read_to_string(path) {
            let s = s.trim();
            if !s.is_empty() {
                return s.to_string();
            }
        }
        if let Some(status) = child.0.try_wait().unwrap() {
            panic!("serving process exited ({status}) before writing {}", path.display());
        }
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("timed out waiting for port file {}", path.display());
}

fn spawn_repro(args: &[&str]) -> Proc {
    Proc(
        Command::new(env!("CARGO_BIN_EXE_repro"))
            .args(args)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawning repro"),
    )
}

/// The ISSUE 9 tentpole end-to-end: two `shard-server` worker processes
/// and a `router` process on loopback ephemeral ports. The router's
/// row-concatenated logits must be bitwise-identical to a cold
/// in-process coordinator — at boot, after a delta replicated through
/// the router's epoch-tagged log, and after one worker is killed (the
/// router re-places its row ranges on the survivor and replays the log
/// from the survivor's watermark).
#[test]
fn router_over_worker_processes_is_bitwise_and_survives_worker_death() {
    let dir =
        std::env::temp_dir().join(format!("serving_dist_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let mut workers = Vec::new();
    let mut worker_addrs = Vec::new();
    for i in 1..=2 {
        let port_file = dir.join(format!("worker{i}.port"));
        let _ = std::fs::remove_file(&port_file);
        let mut child = spawn_repro(&[
            "shard-server",
            "--listen",
            "127.0.0.1:0",
            "--max-seconds",
            "600",
            "--eval-data",
            dir.join(format!("worker{i}-data")).to_str().unwrap(),
            "--port-file",
            port_file.to_str().unwrap(),
        ]);
        worker_addrs.push(wait_port(&port_file, &mut child));
        workers.push(child);
    }
    let router_port = dir.join("router.port");
    let _ = std::fs::remove_file(&router_port);
    let mut router = spawn_repro(&[
        "router",
        "--listen",
        "127.0.0.1:0",
        "--max-seconds",
        "600",
        "--workers",
        &worker_addrs.join(","),
        "--port-file",
        router_port.to_str().unwrap(),
    ]);
    let router_addr = wait_port(&router_port, &mut router);
    let mut conn = TcpStream::connect(&router_addr).unwrap();
    conn.set_read_timeout(Some(Duration::from_secs(120))).unwrap();

    // The single-process reference over the same (deterministic) data.
    let ref_dir = dir.join("reference-data");
    std::fs::create_dir_all(&ref_dir).unwrap();
    let names = write_eval_datasets(&ref_dir).unwrap();
    let store = Arc::new(ModelStore::load(&ref_dir, &names, &["gcn".to_string()]).unwrap());
    let cold = Coordinator::start_with(
        Backend::Host,
        store,
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() },
    );
    let name = names[0].clone();
    let keys = [
        route(&name, None, Strategy::Aes, Precision::F32),
        route(&name, Some(8), Strategy::Aes, Precision::U8Device),
    ];

    let mut id = 0u64;
    let mut assert_round = |conn: &mut TcpStream, phase: &str, want_epoch: usize| {
        for key in &keys {
            id += 1;
            let resp = ask(conn, &WireRequest::Logits { id, route: key.clone() });
            assert_eq!(
                wire::response_status(&resp),
                "ok",
                "{phase} {}: {}",
                key.label(),
                resp.to_string()
            );
            assert_eq!(
                resp.get("epoch").unwrap().as_usize().unwrap(),
                want_epoch,
                "{phase}: router must serve epoch {want_epoch}"
            );
            assert_eq!(
                wire_bits(&resp),
                in_process_bits(&cold, key),
                "{phase} {}: router-merged logits must be bitwise-identical to the \
                 single-process coordinator",
                key.label()
            );
        }
    };

    // Boot: scatter/gather across both workers.
    assert_round(&mut conn, "boot", 0);

    // A delta through the router's replication log: every live worker
    // acks before the client does, so the next read serves epoch 1.
    // The reweight value sits above 1, outside the normalized-adjacency
    // range, so the delta can never be a no-op.
    let ops = vec!["= 0 0 1.5".to_string(), "+ 1 159 0.05".to_string()];
    let resp = ask(
        &mut conn,
        &WireRequest::Mutate { id: 1000, dataset: name.clone(), ops: ops.clone() },
    );
    assert_eq!(wire::response_status(&resp), "ok", "{}", resp.to_string());
    assert_eq!(resp.get("epoch").unwrap().as_usize().unwrap(), 1);
    let delta = aes_spmm::graph::GraphDelta::parse(&ops.join("\n")).unwrap();
    cold.apply_delta(&name, &delta).unwrap();
    assert_round(&mut conn, "post-delta", 1);

    // Kill worker 1. The next mutate marks it dead and still commits on
    // the survivor; reads re-place the dead worker's row ranges and
    // stay bitwise.
    drop(workers.remove(0));
    let ops = vec!["- 1 159".to_string()];
    let resp = ask(
        &mut conn,
        &WireRequest::Mutate { id: 1001, dataset: name.clone(), ops: ops.clone() },
    );
    assert_eq!(
        wire::response_status(&resp),
        "ok",
        "mutate must survive a worker death: {}",
        resp.to_string()
    );
    assert_eq!(resp.get("epoch").unwrap().as_usize().unwrap(), 2);
    let delta = aes_spmm::graph::GraphDelta::parse(&ops.join("\n")).unwrap();
    cold.apply_delta(&name, &delta).unwrap();
    assert_round(&mut conn, "post-failover", 2);

    // The failover shows in the router's ops plane.
    let resp = ask(&mut conn, &WireRequest::Status { id: 1002 });
    assert_eq!(wire::response_status(&resp), "ok");
    assert_eq!(
        resp.get("workers").unwrap().as_usize().unwrap(),
        1,
        "status must report exactly one live worker after the kill"
    );

    cold.shutdown();
    drop(router);
    drop(workers);
}
