//! Model-zoo acceptance — the layer-graph IR served end to end.
//! Runs with **no artifacts and no PJRT**: the seeded eval datasets are
//! written as `.nbt` (weights for every served model) and coordinators
//! serve on [`Backend::Host`].
//!
//! Covers:
//! * every served model's exact fp32 route is bitwise-equal to its own
//!   oracle (`eval::oracle_forward` interpreting the same IR program);
//! * sharded serving is bitwise-equal to unsharded for every model —
//!   the PR 3 guarantee extended across the zoo, including the
//!   attention (ones-family) operand;
//! * sampled and INT8-compute routes serve finite logits for non-GCN
//!   models (the i8 staging fast path is GCN-only; other models take
//!   the dequantized fp32 path);
//! * publish-time weight validation: a mis-shaped tensor fails
//!   `ModelStore::load` with the tensor named, instead of panicking
//!   inside a worker's matmul (regression for the store schema check);
//! * the store's model roster (what `status` advertises) lists exactly
//!   the loaded models.

use std::path::PathBuf;
use std::sync::Arc;

use aes_spmm::coordinator::{Coordinator, CoordinatorConfig, ModelStore, RouteKey};
use aes_spmm::eval::{
    oracle_forward, write_eval_datasets, EVAL_CLASSES, EVAL_FEATS, EVAL_HIDDEN,
};
use aes_spmm::graph::ShardSpec;
use aes_spmm::quant::Precision;
use aes_spmm::rng::Pcg32;
use aes_spmm::runtime::{Backend, SERVED_MODELS};
use aes_spmm::sampling::Strategy;
use aes_spmm::tensor::{write_nbt, NbtFile, Tensor};

fn eval_dir(tag: &str) -> (PathBuf, Vec<String>) {
    let dir = std::env::temp_dir().join(format!("model_zoo_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let names = write_eval_datasets(&dir).unwrap();
    (dir, names)
}

fn zoo_models() -> Vec<String> {
    SERVED_MODELS.iter().map(|m| m.to_string()).collect()
}

fn route(model: &str, dataset: &str, width: Option<usize>, precision: Precision) -> RouteKey {
    RouteKey {
        model: model.to_string(),
        dataset: dataset.to_string(),
        width,
        strategy: Strategy::Aes,
        precision,
    }
}

fn bits(coord: &Coordinator, key: &RouteKey) -> Vec<u32> {
    coord
        .route_logits(key)
        .unwrap_or_else(|e| panic!("route {}: {e:#}", key.label()))
        .as_f32()
        .unwrap()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

/// Every served model's exact fp32 route through the real coordinator
/// is bitwise-identical to the oracle interpreting the same IR program
/// — and a sharded coordinator agrees with both, exact and sampled.
#[test]
fn every_model_serves_bitwise_against_oracle_and_shards() {
    let (dir, names) = eval_dir("zoo");
    let store = Arc::new(ModelStore::load(&dir, &names, &zoo_models()).unwrap());
    let plain = Coordinator::start_with(
        Backend::Host,
        store.clone(),
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() },
    );
    let shard_store = Arc::new(ModelStore::load(&dir, &names, &zoo_models()).unwrap());
    let sharded = Coordinator::start_with(
        Backend::Host,
        shard_store,
        CoordinatorConfig {
            workers: 2,
            sharding: Some(ShardSpec { shards: Some(3), budget_bytes: 32 << 20 }),
            ..CoordinatorConfig::default()
        },
    );

    for name in &names {
        let ds = store.dataset(name).unwrap();
        for &model in SERVED_MODELS {
            let weights = store.weights(model, name).unwrap();
            let oracle: Vec<u32> = oracle_forward(ds.as_ref(), weights.as_ref())
                .unwrap()
                .iter()
                .map(|v| v.to_bits())
                .collect();

            let exact = route(model, name, None, Precision::F32);
            let served = bits(&plain, &exact);
            assert_eq!(
                served,
                oracle,
                "{}: exact fp32 through the serving stack must equal the oracle",
                exact.label()
            );
            assert_eq!(
                bits(&sharded, &exact),
                served,
                "{}: sharded must be bitwise-equal to unsharded",
                exact.label()
            );

            let sampled = route(model, name, Some(8), Precision::F32);
            assert_eq!(
                bits(&sharded, &sampled),
                bits(&plain, &sampled),
                "{}: sharded must be bitwise-equal to unsharded",
                sampled.label()
            );
        }
    }
    plain.shutdown();
    sharded.shutdown();
}

/// Quantized routes serve finite logits for every model: non-GCN
/// i8-compute takes the dequantized fp32 path (the integer staging fast
/// path applies only to the GCN program shape) rather than erroring.
#[test]
fn quantized_routes_serve_the_whole_zoo() {
    let (dir, names) = eval_dir("quant");
    let store = Arc::new(ModelStore::load(&dir, &names, &zoo_models()).unwrap());
    let coord = Coordinator::start_with(
        Backend::Host,
        store,
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() },
    );
    let name = &names[0];
    for &model in SERVED_MODELS {
        for precision in [Precision::U8Device, Precision::I8Compute] {
            let key = route(model, name, Some(8), precision);
            let logits = coord
                .route_logits(&key)
                .unwrap_or_else(|e| panic!("route {}: {e:#}", key.label()));
            let vals = logits.as_f32().unwrap();
            assert!(!vals.is_empty(), "{}", key.label());
            assert!(
                vals.iter().all(|v| v.is_finite()),
                "{}: non-finite logits",
                key.label()
            );
        }
    }
    coord.shutdown();
}

/// A mis-shaped weight tensor fails at publish time (`ModelStore::load`)
/// with the tensor and model named — never inside a worker.
#[test]
fn store_rejects_malformed_weights_naming_the_tensor() {
    let (dir, names) = eval_dir("malformed");
    let name = &names[0];
    let (f, h, c) = (EVAL_FEATS, EVAL_HIDDEN, EVAL_CLASSES);
    let mut rng = Pcg32::new(0xBAD);
    let mut t = |shape: &[usize]| {
        let len: usize = shape.iter().product();
        let vals: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
        Tensor::from_f32(shape, &vals)
    };

    // A GAT artifact whose destination attention vector is one entry
    // too long for the layer's hidden dim.
    let mut w = NbtFile::new();
    w.insert("w0", t(&[f, h]));
    w.insert("a0_src", t(&[h]));
    w.insert("a0_dst", t(&[h + 1]));
    w.insert("b0", t(&[h]));
    w.insert("w1", t(&[h, c]));
    w.insert("a1_src", t(&[c]));
    w.insert("a1_dst", t(&[c]));
    w.insert("b1", t(&[c]));
    w.insert("ideal_acc", Tensor::from_f32(&[1], &[0.5]));
    write_nbt(dir.join(format!("weights_gat_{name}.nbt")), &w).unwrap();

    let err = ModelStore::load(&dir, &[name.clone()], &["gat".to_string()])
        .err()
        .expect("mis-shaped weights must fail at load time");
    let msg = format!("{err:#}");
    assert!(msg.contains("a0_dst"), "error must name the tensor: {msg}");
    assert!(msg.contains("gat"), "error must name the model: {msg}");

    // The other models' untouched artifacts still load and validate.
    ModelStore::load(&dir, &names, &["gcn".to_string(), "sage".to_string()]).unwrap();
}

/// The store's roster (what the wire `status` response advertises as
/// `models`) lists exactly the loaded models, sorted.
#[test]
fn store_roster_reports_the_loaded_zoo() {
    let (dir, names) = eval_dir("roster");
    let store = ModelStore::load(&dir, &names, &zoo_models()).unwrap();
    assert_eq!(store.model_names(), vec!["gat", "gcn", "sage"]);
    let gcn_only = ModelStore::load(&dir, &names, &["gcn".to_string()]).unwrap();
    assert_eq!(gcn_only.model_names(), vec!["gcn"]);
}
