//! Integration tests for the sharding subsystem — the acceptance
//! criteria of the sharded-serving PR:
//!
//! * partition invariants: every row lands in exactly one shard, across
//!   budgets, explicit counts, and degenerate inputs (empty graph, a
//!   single mega-row exceeding the budget);
//! * sharded sampling matches the golden per-row plans bit-for-bit —
//!   sharding must not perturb the paper's Table 1 + Eq. 3 math;
//! * a sharded host forward (`shards >= 2`) is **bitwise equal** to the
//!   unsharded forward, exact and sampled, eager and streamed-INT8;
//! * the coordinator serves sharded routes correctly, reuses warm shard
//!   units across precisions, and drops them on invalidation.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use aes_spmm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, ModelStore, RouteKey,
};
use aes_spmm::exec::{prepare_plan, ExecEnv, PlanSpec, ShardSampling, ShardedPlan};
use aes_spmm::gen;
use aes_spmm::graph::{working_set_bytes, Csr, ShardPlan, ShardSpec};
use aes_spmm::quant::{quantize, FeatureStore, Precision, QuantParams};
use aes_spmm::rng::Pcg32;
use aes_spmm::runtime::{host_forward, Backend, Dataset, Weights};
use aes_spmm::sampling::{plan_row, Strategy};
use aes_spmm::tensor::{write_nbt, NbtFile, Tensor};
use aes_spmm::util::argmax_f32;

const N: usize = 180;
const FEATS: usize = 10;
const HIDDEN: usize = 8;
const CLASSES: usize = 4;

fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let len: usize = shape.iter().product();
    let vals: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
    Tensor::from_f32(shape, &vals)
}

/// Synthetic dataset + gcn weights, as `tests/exec_layer.rs` builds them.
fn synthetic_artifacts(tag: &str, name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sharding_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Pcg32::new(0xD0C);

    let g = gen::with_self_loops(&gen::chung_lu(N, 7.0, 1.9, &mut rng)).gcn_normalized();
    let nnz = g.nnz();
    let feat: Vec<f32> = (0..N * FEATS).map(|_| rng.f32() - 0.5).collect();
    let params = QuantParams::of(&feat);
    let labels: Vec<i32> = (0..N).map(|_| rng.usize_below(CLASSES) as i32).collect();
    let train_mask: Vec<u8> = (0..N).map(|_| (rng.f32() < 0.5) as u8).collect();

    let mut nbt = NbtFile::new();
    nbt.insert(
        "meta",
        Tensor::from_i64(&[4], &[N as i64, nnz as i64, FEATS as i64, CLASSES as i64]),
    );
    nbt.insert("row_ptr", Tensor::from_i32(&[N + 1], &g.row_ptr));
    nbt.insert("col_ind", Tensor::from_i32(&[nnz], &g.col_ind));
    nbt.insert("val_gcn", Tensor::from_f32(&[nnz], &g.val));
    nbt.insert("val_ones", Tensor::from_f32(&[nnz], &vec![1.0f32; nnz]));
    nbt.insert("feat", Tensor::from_f32(&[N, FEATS], &feat));
    nbt.insert("featq", Tensor::from_u8(&[N, FEATS], &quantize(&feat, params)));
    nbt.insert("qrange", Tensor::from_f32(&[2], &[params.x_min, params.x_max]));
    nbt.insert("labels", Tensor::from_i32(&[N], &labels));
    nbt.insert("train_mask", Tensor::from_u8(&[N], &train_mask));
    write_nbt(dir.join(format!("data_{name}.nbt")), &nbt).unwrap();

    let mut w = NbtFile::new();
    w.insert("w0", rand_tensor(&mut rng, &[FEATS, HIDDEN]));
    w.insert("b0", rand_tensor(&mut rng, &[HIDDEN]));
    w.insert("w1", rand_tensor(&mut rng, &[HIDDEN, CLASSES]));
    w.insert("b1", rand_tensor(&mut rng, &[CLASSES]));
    w.insert("ideal_acc", Tensor::from_f32(&[1], &[0.5]));
    write_nbt(dir.join(format!("weights_gcn_{name}.nbt")), &w).unwrap();
    dir
}

fn plan_spec<'a>(
    csr: &'a Csr,
    width: Option<usize>,
    stream: bool,
    shard: Option<ShardSpec>,
) -> PlanSpec<'a> {
    PlanSpec {
        csr,
        width,
        strategy: Strategy::Aes,
        host_ell: true,
        stream,
        shard,
        shard_bounds: None,
        shard_cache: None,
    }
}

/// Every row in exactly one shard, for explicit counts, byte budgets,
/// and degenerate shapes — the partition invariant suite.
#[test]
fn every_row_lands_in_exactly_one_shard() {
    let mut rng = Pcg32::new(7);
    let graphs: Vec<Csr> = vec![
        gen::chung_lu(257, 18.0, 1.8, &mut rng),
        gen::chung_lu(64, 3.0, 2.5, &mut rng),
        Csr::new(5, 5, vec![0; 6], vec![], vec![]).unwrap(), // no edges
    ];
    for g in &graphs {
        let total = working_set_bytes(g.n_rows, g.nnz());
        let specs = [
            ShardSpec::default(),
            ShardSpec::by_count(1),
            ShardSpec::by_count(4),
            ShardSpec::by_count(1000),
            ShardSpec::by_budget(1),
            ShardSpec::by_budget(total / 3 + 1),
            ShardSpec::by_budget(total * 10 + 1),
        ];
        for spec in specs {
            let plan = ShardPlan::partition(g, &spec);
            plan.validate().unwrap();
            let mut owner = vec![0u32; g.n_rows];
            for s in plan.shards() {
                for r in s.rows.clone() {
                    owner[r] += 1;
                }
            }
            assert!(
                owner.iter().all(|&c| c == 1),
                "{spec:?} on n={} must cover each row once",
                g.n_rows
            );
        }
    }
}

/// A row whose working set alone exceeds the budget gets its own shard
/// and nothing panics downstream of it.
#[test]
fn mega_row_is_isolated_not_split() {
    let heavy = 6000usize;
    let cols = 6000usize; // distinct columns — coo_to_csr dedupes repeats
    let mut triples: Vec<(i32, i32, f32)> = Vec::new();
    for r in 0..10i32 {
        triples.push((r, r % 7, 1.0));
    }
    for e in 0..heavy {
        triples.push((10, e as i32, 0.5));
    }
    for r in 11..20i32 {
        triples.push((r, (r * 3) % 50, 1.0));
    }
    let g = aes_spmm::graph::coo_to_csr(20, cols, triples).unwrap();
    let budget = working_set_bytes(1, 64);
    let plan = ShardPlan::partition(&g, &ShardSpec::by_budget(budget));
    plan.validate().unwrap();
    let host = plan.shards().iter().find(|s| s.rows.contains(&10)).unwrap();
    assert_eq!(host.csr.max_degree(), heavy);

    // The sharded execution built over it must still match unsharded —
    // wide features would tempt dispatch toward the row-cache kernel,
    // but the ROWCACHE_MAX_ROW_NNZ gate keeps the 6000-edge row on the
    // order-preserving naive kernel.
    let feats = 16usize;
    let b: Vec<f32> = (0..cols * feats).map(|i| (i as f32).sin()).collect();
    let sp =
        ShardedPlan::prepare(&g, &ShardSpec::by_budget(budget), None, Strategy::Aes, feats, None);
    assert!(sp.shard_count() >= 2);
    let mut want = vec![0.0f32; 20 * feats];
    aes_spmm::spmm::csr_naive(&g, &b, feats, &mut want);
    let mut got = vec![0.0f32; 20 * feats];
    sp.run(&b, feats, &mut got, &ExecEnv::with_threads(4));
    assert_eq!(want, got);
}

/// Sharding must not perturb the golden sampling math: a row of nnz 100
/// (or 600) at W=16 samples the same offsets whether its shard starts at
/// row 0 or somewhere in the middle of the graph — the per-row plan
/// depends only on (row_nnz, W, strategy).
#[test]
fn sharded_sampling_matches_the_golden_row_plans() {
    // Rows: 30 light rows, one golden 100-nnz row, 30 light, one golden
    // 600-nnz row, 30 light.
    let mut triples: Vec<(i32, i32, f32)> = Vec::new();
    let light = |r: i32, triples: &mut Vec<(i32, i32, f32)>| {
        for c in 0..3 {
            triples.push((r, (r + c) % 700, 1.0));
        }
    };
    for r in 0..30 {
        light(r, &mut triples);
    }
    for e in 0..100i32 {
        triples.push((30, e, e as f32));
    }
    for r in 31..61 {
        light(r, &mut triples);
    }
    for e in 0..600i32 {
        triples.push((61, e, (e * 2) as f32));
    }
    for r in 62..92 {
        light(r, &mut triples);
    }
    let g = aes_spmm::graph::coo_to_csr(92, 700, triples).unwrap();

    let sp = ShardedPlan::prepare(&g, &ShardSpec::by_count(5), Some(16), Strategy::Aes, 8, None);
    assert!(sp.shard_count() >= 2);
    for (global_row, golden_nnz) in [(30usize, 100usize), (61, 600)] {
        let unit = sp
            .units()
            .iter()
            .find(|u| u.rows.contains(&global_row))
            .expect("golden row must land in a shard");
        let ell = unit.ell.as_ref().expect("sampled route builds per-shard ELL");
        let local = global_row - unit.rows.start;
        let w = ell.width;
        let golden = plan_row(golden_nnz, 16, Strategy::Aes);
        assert_eq!(ell.slots[local] as usize, golden.len());
        let base = g.row_ptr[global_row] as usize;
        for (slot, &off) in golden.iter().enumerate() {
            assert_eq!(
                ell.col[local * w + slot],
                g.col_ind[base + off],
                "row {global_row} slot {slot} must follow the golden offset {off}"
            );
            assert_eq!(ell.val[local * w + slot], g.val[base + off]);
        }
    }
}

/// The headline acceptance test: a sharded host forward (eager fp32,
/// INT8, streamed INT8; exact and sampled) equals the unsharded forward
/// **bitwise** for shard counts >= 2.
#[test]
fn sharded_forward_is_bitwise_equal_to_unsharded() {
    let dir = synthetic_artifacts("bitwise", "tiny");
    let ds = Dataset::load(&dir, "tiny").unwrap();
    let weights = Weights::load(&dir, "gcn", "tiny").unwrap();
    let fstore = FeatureStore::open(dir.join("data_tiny.nbt")).unwrap();
    let env = ExecEnv::with_threads(4);

    for (width, precision, stream) in [
        (None, Precision::F32, false),
        (Some(4), Precision::F32, false),
        (Some(16), Precision::F32, false),
        (Some(4), Precision::U8Device, true), // streamed INT8 when mmap exists
    ] {
        let fwd = aes_spmm::runtime::ForwardRequest {
            model: "gcn".into(),
            dataset: "tiny".into(),
            width,
            strategy: Strategy::Aes,
            precision,
        };
        let base_spec = plan_spec(&ds.csr_gcn, width, stream, None);
        let base_plan = prepare_plan(&fstore, precision, &base_spec, FEATS, &env).unwrap();
        let want = host_forward(&ds, &weights, &fwd, None, Some(&base_plan), &env).unwrap();
        let want = want.logits.as_f32().unwrap().to_vec();

        for shards in [2usize, 3, 7] {
            let spec = plan_spec(&ds.csr_gcn, width, stream, Some(ShardSpec::by_count(shards)));
            let plan = prepare_plan(&fstore, precision, &spec, FEATS, &env).unwrap();
            let sp = plan.sharded.as_ref().expect("spec must shard the plan");
            assert_eq!(sp.shard_count(), shards);
            let got = host_forward(&ds, &weights, &fwd, None, Some(&plan), &env).unwrap();
            assert_eq!(
                want,
                got.logits.as_f32().unwrap(),
                "width {width:?} precision {precision:?} shards {shards}: \
                 concatenated shard outputs must equal the unsharded forward bitwise"
            );
        }
    }
}

/// Per-shard adaptivity end to end: a graph with a uniform head and a
/// skewed tail yields shards with different sampling modes and
/// different dispatched kernels — and still matches unsharded bitwise.
#[test]
fn adaptive_shards_diverge_and_stay_exact() {
    // Equal edge masses (120 × deg 4 head, 8 × deg 60 tail) pin the
    // 2-way quantile cut to the uniform/skewed boundary at row 120.
    let mut triples: Vec<(i32, i32, f32)> = Vec::new();
    for r in 0..120i32 {
        for c in 0..4 {
            triples.push((r, (r + c * 17) % 150, 0.25));
        }
    }
    for r in 120..128i32 {
        for e in 0..60 {
            triples.push((r, (e * 7) % 150, 0.125));
        }
    }
    let g = aes_spmm::graph::coo_to_csr(128, 150, triples).unwrap();
    let sp = ShardedPlan::prepare(&g, &ShardSpec::by_count(2), Some(8), Strategy::Aes, 32, None);
    assert_eq!(sp.shard_count(), 2);
    let head = &sp.units()[0];
    let tail = sp.units().last().unwrap();
    assert_eq!(head.rows, 0..120);
    assert!(matches!(head.sampling, ShardSampling::Exhaustive { width: 4 }));
    assert!(matches!(tail.sampling, ShardSampling::Sampled { width: 8, .. }));

    let b: Vec<f32> = (0..150 * 32).map(|i| ((i % 91) as f32) * 0.01 - 0.4).collect();
    let ell = aes_spmm::sampling::sample_ell(&g, 8, Strategy::Aes);
    let mut want = vec![0.0f32; 128 * 32];
    aes_spmm::spmm::ell_spmm(&ell, &b, 32, &mut want);
    let mut got = vec![0.0f32; 128 * 32];
    sp.run(&b, 32, &mut got, &ExecEnv::with_threads(4));
    assert_eq!(want, got);
}

fn start_sharded_coordinator(
    dir: &Path,
    name: &str,
    sharding: Option<ShardSpec>,
) -> (Coordinator, Arc<ModelStore>) {
    let store =
        Arc::new(ModelStore::load(dir, &[name.to_string()], &["gcn".to_string()]).unwrap());
    let coord = Coordinator::start_with(
        Backend::Host,
        store.clone(),
        CoordinatorConfig {
            workers: 2,
            queue_depth: 64,
            batcher: BatcherConfig { max_batch: 8, max_delay: Duration::from_millis(1) },
            plan_cache_capacity: 16,
            prefetch_workers: 1,
            sharding,
            ..CoordinatorConfig::default()
        },
    );
    (coord, store)
}

/// The coordinator serves sharded routes with answers equal to a direct
/// unsharded forward, warms shard units across precisions (a sibling
/// route's build samples zero shards), and drops units on invalidation.
#[test]
fn coordinator_serves_sharded_routes_and_reuses_units() {
    let dir = synthetic_artifacts("coord", "tiny");
    let ds = Dataset::load(&dir, "tiny").unwrap();
    let weights = Weights::load(&dir, "gcn", "tiny").unwrap();
    let (coord, _store) = start_sharded_coordinator(&dir, "tiny", Some(ShardSpec::by_count(3)));

    let key = |precision| RouteKey {
        model: "gcn".into(),
        dataset: "tiny".into(),
        width: Some(4),
        strategy: Strategy::Aes,
        precision,
    };

    // First route: all 3 units built cold.
    let nodes: Vec<usize> = (0..N).step_by(11).collect();
    let resp = coord.infer(key(Precision::F32), nodes.clone()).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let s1 = coord.shard_stats();
    assert_eq!(s1.resident, 3);
    assert_eq!(s1.misses, 3, "three cold shard builds");

    // Answers equal the direct unsharded forward.
    let fwd = key(Precision::F32).to_forward();
    let direct =
        host_forward(&ds, &weights, &fwd, None, None, &ExecEnv::with_threads(1)).unwrap();
    let logits = direct.logits.as_f32().unwrap();
    for p in &resp.predictions {
        let want = argmax_f32(&logits[p.node * CLASSES..(p.node + 1) * CLASSES]) as i32;
        assert_eq!(p.class, want, "node {}", p.node);
    }

    // Sibling precision: new plan, zero new shard builds.
    let resp = coord.infer(key(Precision::U8Device), vec![0, 5]).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    let s2 = coord.shard_stats();
    assert_eq!(s2.misses, 3, "warm units must serve the sibling route");
    assert!(s2.hits >= 3, "the sibling build must hit all three units (got {})", s2.hits);
    let snap = coord.metrics().snapshot();
    assert!(snap.sharded_batches >= 2);

    // Invalidation drops the dataset's units with the plan.
    assert!(coord.invalidate_route(&key(Precision::F32)));
    assert_eq!(coord.shard_stats().resident, 0, "republished dataset drops its shard units");
    let resp = coord.infer(key(Precision::F32), vec![1]).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(coord.shard_stats().resident, 3, "rebuilt after invalidation");
    coord.shutdown();
}
