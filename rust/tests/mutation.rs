//! Live-graph mutation through the full serving stack — the ISSUE 5
//! acceptance suite. Runs with **no artifacts and no PJRT**: synthetic
//! datasets are written as `.nbt` and the coordinator serves on
//! [`Backend::Host`].
//!
//! Covers:
//! * mutate-then-serve: after `apply_delta`, the sharded/streamed
//!   forward is bitwise-equal to a cold coordinator built directly on
//!   the mutated graph;
//! * shard-scoped invalidation: untouched shards are retained (proven
//!   via [`ShardCacheStats`]), touched shards re-sample;
//! * the delta edge cases: empty delta, delete-last-edge-in-row,
//!   insert into an empty row, a delta landing in a mega-row shard,
//!   and a delta flipping a shard between the `shard_width`
//!   uniform/skewed branches;
//! * working-set drift forcing a re-partition.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use aes_spmm::coordinator::{Coordinator, CoordinatorConfig, ModelStore, RouteKey};
use aes_spmm::exec::{
    PlanCache, ShardCacheRef, ShardKey, ShardLayout, ShardSampling, ShardUnit, ShardedPlan,
};
use aes_spmm::graph::{coo_to_csr, Csr, EdgeOp, GraphDelta, ShardSpec, VersionedCsr};
use aes_spmm::quant::{quantize, Precision, QuantParams};
use aes_spmm::rng::Pcg32;
use aes_spmm::runtime::{Backend, ModelVals};
use aes_spmm::sampling::Strategy;
use aes_spmm::tensor::{write_nbt, NbtFile, Tensor};

const FEATS: usize = 8;
const HIDDEN: usize = 6;
const CLASSES: usize = 4;

fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let len: usize = shape.iter().product();
    let vals: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
    Tensor::from_f32(shape, &vals)
}

/// Write `data_{name}.nbt` + `weights_gcn_{name}.nbt` for an arbitrary
/// square graph, returning the artifacts dir.
fn write_artifacts(tag: &str, name: &str, g: &Csr) -> PathBuf {
    assert_eq!(g.n_rows, g.n_cols, "serving datasets are square");
    let dir = std::env::temp_dir().join(format!("mutation_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let n = g.n_rows;
    let nnz = g.nnz();
    let mut rng = Pcg32::new(0xD117A);
    let feat: Vec<f32> = (0..n * FEATS).map(|_| rng.f32() - 0.5).collect();
    let params = QuantParams::of(&feat);
    let labels: Vec<i32> = (0..n).map(|_| rng.usize_below(CLASSES) as i32).collect();

    let mut nbt = NbtFile::new();
    nbt.insert(
        "meta",
        Tensor::from_i64(&[4], &[n as i64, nnz as i64, FEATS as i64, CLASSES as i64]),
    );
    nbt.insert("row_ptr", Tensor::from_i32(&[n + 1], &g.row_ptr));
    nbt.insert("col_ind", Tensor::from_i32(&[nnz], &g.col_ind));
    nbt.insert("val_gcn", Tensor::from_f32(&[nnz], &g.val));
    nbt.insert("val_ones", Tensor::from_f32(&[nnz], &vec![1.0f32; nnz]));
    nbt.insert("feat", Tensor::from_f32(&[n, FEATS], &feat));
    nbt.insert("featq", Tensor::from_u8(&[n, FEATS], &quantize(&feat, params)));
    nbt.insert("qrange", Tensor::from_f32(&[2], &[params.x_min, params.x_max]));
    nbt.insert("labels", Tensor::from_i32(&[n], &labels));
    nbt.insert("train_mask", Tensor::from_u8(&[n], &vec![0u8; n]));
    write_nbt(dir.join(format!("data_{name}.nbt")), &nbt).unwrap();

    let mut w = NbtFile::new();
    let mut wrng = Pcg32::new(0xD117B);
    w.insert("w0", rand_tensor(&mut wrng, &[FEATS, HIDDEN]));
    w.insert("b0", rand_tensor(&mut wrng, &[HIDDEN]));
    w.insert("w1", rand_tensor(&mut wrng, &[HIDDEN, CLASSES]));
    w.insert("b1", rand_tensor(&mut wrng, &[CLASSES]));
    w.insert("ideal_acc", Tensor::from_f32(&[1], &[0.5]));
    write_nbt(dir.join(format!("weights_gcn_{name}.nbt")), &w).unwrap();
    dir
}

/// A 90-node graph: 80 uniform rows (deg 4 + self-loop), one empty-ish
/// region, and two hub rows — shaped so a 3-way layout puts the hubs in
/// the last shard.
fn serving_graph() -> Csr {
    let n = 90usize;
    let mut triples: Vec<(i32, i32, f32)> = Vec::new();
    for r in 0..n as i32 {
        triples.push((r, r, 1.0)); // self-loop
    }
    for r in 0..80i32 {
        for k in 1..=4i32 {
            triples.push((r, (r + k * 17) % 90, 0.25));
        }
    }
    for r in 84..86i32 {
        for c in 0..40i32 {
            triples.push((r, (c * 2 + r) % 90, 0.1));
        }
    }
    coo_to_csr(n, n, triples).unwrap()
}

fn start(dir: &Path, name: &str, spec: ShardSpec) -> (Coordinator, Arc<ModelStore>) {
    let store = Arc::new(
        ModelStore::load(dir, &[name.to_string()], &["gcn".to_string()]).unwrap(),
    );
    let cfg = CoordinatorConfig {
        workers: 2,
        prefetch_workers: 1,
        sharding: Some(spec),
        ..CoordinatorConfig::default()
    };
    (Coordinator::start_with(Backend::Host, store.clone(), cfg), store)
}

fn route(name: &str, width: Option<usize>, precision: Precision) -> RouteKey {
    RouteKey {
        model: "gcn".to_string(),
        dataset: name.to_string(),
        width,
        strategy: Strategy::Aes,
        precision,
    }
}

fn logits_bits(coord: &Coordinator, key: &RouteKey) -> Vec<u32> {
    coord
        .route_logits(key)
        .unwrap()
        .as_f32()
        .unwrap()
        .iter()
        .map(|v| v.to_bits())
        .collect()
}

/// The acceptance criterion: after `apply_delta`, the sharded/streamed
/// forward is bitwise-equal to a cold coordinator built directly on the
/// mutated graph, and `ShardCacheStats` proves untouched shards were
/// retained. Sequences three deltas covering delete-last-edge-in-row
/// and insert-into-empty-row along the way.
#[test]
fn mutate_then_serve_is_bitwise_and_retains_untouched_shards() {
    let g = serving_graph();
    let dir = write_artifacts("serve", "live", &g);
    let (warm, _store) = start(&dir, "live", ShardSpec::by_count(3));
    let routes =
        [route("live", None, Precision::F32), route("live", Some(8), Precision::U8Device)];
    for k in &routes {
        warm.route_logits(k).unwrap();
    }
    assert_eq!(warm.shard_stats().resident, 6, "two unit families × three shards");

    // Row 2's full edge list (self-loop + 4 neighbors), for the
    // delete-last-edge case; all in shard 0.
    let row2: Vec<i32> = g.row_range(2).map(|e| g.col_ind[e]).collect();
    let deltas = vec![
        // Delta 1: weight update + a fresh edge, rows 0-1 (shard 0).
        GraphDelta::new(vec![
            EdgeOp::Reweight { row: 0, col: 0, weight: 0.75 },
            EdgeOp::Insert { row: 1, col: 89, weight: 0.2 },
        ]),
        // Delta 2: delete every edge of row 2 — the
        // delete-last-edge-in-row case ends with an empty row.
        GraphDelta::new(
            row2.iter().map(|&c| EdgeOp::Delete { row: 2, col: c }).collect(),
        ),
        // Delta 3: insert into the now-empty row 2.
        GraphDelta::new(vec![EdgeOp::Insert { row: 2, col: 50, weight: 0.3 }]),
    ];

    for (i, delta) in deltas.iter().enumerate() {
        let before = warm.shard_stats();
        let outcome = warm.apply_delta("live", delta).unwrap();
        assert_eq!(outcome.epoch, (i + 1) as u64);
        assert!(!outcome.repartitioned);
        // Both route families: exactly the touched shard re-sampled.
        assert_eq!(outcome.shards_resampled, 2, "delta {i}: one unit per family");
        assert_eq!(outcome.shards_retained, 4, "delta {i}: untouched shards stay warm");
        assert_eq!(outcome.plans_invalidated, 2);
        warm.wait_prefetch_idle();

        let warm_bits: Vec<Vec<u32>> = routes.iter().map(|k| logits_bits(&warm, k)).collect();
        let after = warm.shard_stats();
        assert_eq!(
            after.misses - before.misses,
            2,
            "delta {i}: only the touched shard rebuilds (per family)"
        );
        assert!(
            after.hits - before.hits >= 4,
            "delta {i}: the re-staged plans must reuse the retained units"
        );

        // Cold rebuild directly on the mutated graph.
        let (cold, _cs) = start(&dir, "live", ShardSpec::by_count(3));
        for d in &deltas[..=i] {
            cold.apply_delta("live", d).unwrap();
        }
        for (ri, k) in routes.iter().enumerate() {
            assert_eq!(
                warm_bits[ri],
                logits_bits(&cold, k),
                "delta {i}, route {}: warm serve must match a cold rebuild bitwise",
                k.label()
            );
        }
        cold.shutdown();
    }
    let snap = warm.metrics().snapshot();
    assert_eq!(snap.graph_epochs, 3);
    assert_eq!(snap.shards_resampled, 6);
    assert_eq!(snap.shards_retained, 12);
    // Row 2 is empty after delta 2 and refilled after delta 3.
    let ds = _store.dataset("live").unwrap();
    assert_eq!(ds.epoch, 3);
    assert_eq!(ds.csr_gcn.row_nnz(2), 1);
    warm.shutdown();
}

/// An empty (or all-no-op) delta keeps the epoch and every plan warm —
/// no invalidation, no re-sampling, no re-staging.
#[test]
fn noop_delta_keeps_everything_warm() {
    let g = serving_graph();
    let dir = write_artifacts("noop", "live", &g);
    let (coord, store) = start(&dir, "live", ShardSpec::by_count(3));
    let key = route("live", Some(8), Precision::F32);
    coord.route_logits(&key).unwrap();
    let before = coord.shard_stats();
    let fstore = store.feature_store("live").unwrap();
    let loads = fstore.load_count();

    let outcome = coord.apply_delta("live", &GraphDelta::default()).unwrap();
    assert_eq!(outcome.epoch, 0, "an empty delta must not advance the epoch");
    assert_eq!((outcome.shards_resampled, outcome.plans_invalidated), (0, 0));
    // A delta that names edges but changes nothing is equally free.
    let noop = GraphDelta::new(vec![EdgeOp::Delete { row: 3, col: 88 }]);
    let outcome = coord.apply_delta("live", &noop).unwrap();
    assert_eq!(outcome.epoch, 0);
    assert_eq!(outcome.report.noops, 1);

    coord.route_logits(&key).unwrap();
    let after = coord.shard_stats();
    assert_eq!(after.misses, before.misses, "no unit rebuilt");
    assert_eq!(fstore.load_count(), loads, "no feature re-staging");
    assert_eq!(coord.metrics().snapshot().graph_epochs, 0);
    coord.shutdown();
}

/// A wholesale republish (freshly loaded Dataset, epoch restarts at 0)
/// must never regress the published epoch: `publish_dataset` re-stamps
/// it past the current one, so plans built against the pre-republish
/// snapshot can never be served afterwards even if the publisher
/// forgot to bump anything itself.
#[test]
fn wholesale_republish_never_regresses_the_epoch() {
    let g = serving_graph();
    let dir = write_artifacts("republish", "live", &g);
    let (coord, store) = start(&dir, "live", ShardSpec::by_count(3));
    let key = route("live", Some(8), Precision::F32);
    coord.route_logits(&key).unwrap();
    let delta = GraphDelta::new(vec![EdgeOp::Reweight { row: 0, col: 0, weight: 0.9 }]);
    coord.apply_delta("live", &delta).unwrap();
    assert_eq!(store.dataset("live").unwrap().epoch, 1);

    // Operator rotates the files and republishes a fresh load.
    let fresh = aes_spmm::runtime::Dataset::load(&dir, "live").unwrap();
    assert_eq!(fresh.epoch, 0, "a fresh load restarts at epoch 0");
    store.publish_dataset("live", Arc::new(fresh)).unwrap();
    assert_eq!(
        store.dataset("live").unwrap().epoch,
        2,
        "publication must advance the epoch, never regress it"
    );
    // The epoch-1 plan (mutated weights) is unreachable at epoch 2:
    // serving rebuilds from the republished graph and matches a cold
    // coordinator on the same files bitwise.
    coord.wait_prefetch_idle();
    let bits = logits_bits(&coord, &key);
    let (cold, _cs) = start(&dir, "live", ShardSpec::by_count(3));
    assert_eq!(bits, logits_bits(&cold, &key));
    cold.shutdown();

    // The CAS variant publishes nothing when the expected epoch is
    // stale (apply_delta's guard against concurrent republishes).
    let current = store.dataset("live").unwrap();
    let next = Arc::new(aes_spmm::runtime::Dataset {
        epoch: current.epoch + 1,
        ..(*current).clone()
    });
    assert!(!store.publish_dataset_cas("live", current.epoch + 5, next.clone()).unwrap());
    assert_eq!(store.dataset("live").unwrap().epoch, current.epoch, "lost CAS changed nothing");
    assert!(store.publish_dataset_cas("live", current.epoch, next).unwrap());
    assert_eq!(store.dataset("live").unwrap().epoch, current.epoch + 1);
    coord.shutdown();
}

/// A delta landing in a mega-row shard re-samples only that shard, and
/// a delta that bloats a shard past its working-set budget forces a
/// re-partition (sticky layout dropped, everything rebuilt).
#[test]
fn mega_row_shard_delta_and_drift_repartition() {
    // Graph with a 300-edge mega row at 40 (n=60): budget-based
    // sharding isolates it.
    let n = 60usize;
    let mut triples: Vec<(i32, i32, f32)> = Vec::new();
    for r in 0..n as i32 {
        triples.push((r, r, 1.0));
        triples.push((r, (r + 1) % n as i32, 0.5));
    }
    for c in 0..50i32 {
        triples.push((40, c, 0.05));
    }
    let g = coo_to_csr(n, n, triples).unwrap();
    let budget = aes_spmm::graph::working_set_bytes(8, 24);
    let dir = write_artifacts("mega", "live", &g);
    let (coord, _store) = start(&dir, "live", ShardSpec::by_budget(budget));
    let key = route("live", Some(8), Precision::F32);
    coord.route_logits(&key).unwrap();
    let resident = coord.shard_stats().resident;
    assert!(resident >= 3, "budget sharding must cut several shards (got {resident})");

    // Touch only the mega row: exactly its shard re-samples, and even
    // though that shard was *born* over the byte budget, neither a
    // reweight nor a single insert forces a futile re-partition (the
    // drift floor gives born-over-budget shards 2× growth room).
    let delta = GraphDelta::new(vec![
        EdgeOp::Reweight { row: 40, col: 0, weight: 0.07 },
        EdgeOp::Insert { row: 40, col: 55, weight: 0.02 },
    ]);
    let outcome = coord.apply_delta("live", &delta).unwrap();
    assert!(!outcome.repartitioned, "one grown edge must not re-cut a mega-row shard");
    assert_eq!(outcome.shards_resampled, 1, "only the mega-row shard re-samples");
    assert_eq!(outcome.shards_retained, resident - 1);
    coord.wait_prefetch_idle();

    // Bitwise vs cold rebuild on the mutated graph.
    let warm_bits = logits_bits(&coord, &key);
    let (cold, _cs) = start(&dir, "live", ShardSpec::by_budget(budget));
    cold.apply_delta("live", &delta).unwrap();
    assert_eq!(warm_bits, logits_bits(&cold, &key));
    cold.shutdown();

    // Now bloat the light leading shard far past 2× its birth weight:
    // the layout is re-cut and every unit drops.
    let bloat = || -> GraphDelta {
        let mut ops = Vec::new();
        for r in 0..3i32 {
            for c in 0..50i32 {
                ops.push(EdgeOp::Insert { row: r, col: (c + 3) % n as i32, weight: 0.01 });
            }
        }
        GraphDelta::new(ops)
    };
    let outcome = coord.apply_delta("live", &bloat()).unwrap();
    assert!(outcome.repartitioned, "a ~150-edge insert into a ~24-edge shard must drift");
    assert_eq!(outcome.shards_retained, 0, "a re-partition retains nothing");
    assert_eq!(
        outcome.shards_resampled, resident,
        "a re-partition drops every resident unit"
    );
    coord.wait_prefetch_idle();
    // Serving still agrees with a cold rebuild after the re-cut.
    let warm_bits = logits_bits(&coord, &key);
    let (cold, _cs) = start(&dir, "live", ShardSpec::by_budget(budget));
    cold.apply_delta("live", &delta).unwrap();
    cold.apply_delta("live", &bloat()).unwrap();
    assert_eq!(warm_bits, logits_bits(&cold, &key));
    cold.shutdown();
    coord.shutdown();
}

/// Mutation can flip a shard between `shard_width`'s uniform and skewed
/// branches: inserting hub edges into a uniform (exhaustive-tile) shard
/// must re-evaluate the per-shard decision and come back `Sampled`.
#[test]
fn delta_flips_a_shard_between_width_branches() {
    // Uniform graph: every row deg 3 (self + 2), W=8 ⇒ every shard
    // exhaustive at a shrunken tile.
    let n = 48usize;
    let mut triples: Vec<(i32, i32, f32)> = Vec::new();
    for r in 0..n as i32 {
        triples.push((r, r, 1.0));
        triples.push((r, (r + 3) % n as i32, 0.5));
        triples.push((r, (r + 7) % n as i32, 0.25));
    }
    let g = coo_to_csr(n, n, triples).unwrap();
    let spec = ShardSpec::by_count(3);
    let layout = ShardLayout::of(&g, &spec);
    let cache: PlanCache<ShardKey, ShardUnit> = PlanCache::new(64);
    let cr =
        |epoch| Some(ShardCacheRef { units: &cache, tag: "live", epoch, vals: ModelVals::Gcn });

    let plan =
        ShardedPlan::prepare_with_bounds(&g, layout.bounds(), Some(8), Strategy::Aes, FEATS, cr(0));
    assert!(
        plan.units()
            .iter()
            .all(|u| matches!(u.sampling, ShardSampling::Exhaustive { .. })),
        "uniform shards start on the exhaustive branch"
    );

    // Delta: 12 extra edges on row 1 → its shard's max degree (15)
    // overflows W=8 → the skewed branch. (Simulate the coordinator's
    // scoped invalidation: drop the touched shard's units, re-tag the
    // rest, rebuild at the new epoch.)
    let v = VersionedCsr::new(g);
    let ops: Vec<EdgeOp> = (0..12i32)
        .map(|k| EdgeOp::Insert { row: 1, col: (10 + 3 * k) % n as i32, weight: 0.1 })
        .collect();
    let (next, report) = v.apply(&GraphDelta::new(ops)).unwrap();
    assert_eq!(report.touched_rows, vec![1]);
    let affected = layout.affected_shards(&report.touched_rows);
    assert_eq!(affected, vec![0]);
    let hot = (layout.bounds()[0].start, layout.bounds()[0].end);
    cache.advance_epoch(|k: &ShardKey| k.rows == hot, |k| k.rows != hot, 0, next.epoch());

    let plan = ShardedPlan::prepare_with_bounds(
        &g_ref(&next),
        layout.bounds(),
        Some(8),
        Strategy::Aes,
        FEATS,
        cr(next.epoch()),
    );
    assert_eq!(plan.warm_units(), 2, "untouched shards stay warm across the flip");
    let flipped = &plan.units()[0];
    assert!(
        matches!(flipped.sampling, ShardSampling::Sampled { width: 8, .. }),
        "the touched shard must re-evaluate shard_width and sample (got {:?})",
        flipped.sampling
    );
}

fn g_ref(v: &VersionedCsr) -> Csr {
    (**v.csr()).clone()
}
