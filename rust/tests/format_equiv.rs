//! Format-zoo conformance: every (format × precision × SIMD level ×
//! threading) cell must be **bitwise-identical** to the canonical CSR
//! scalar path. The tuned dispatcher (docs/dispatch.md) is free to pick
//! any admissible kernel per shard precisely because of this grid — a
//! cost model can cost speed, never bits.
//!
//! Shapes are adversarial on purpose: the empty graph, interspersed
//! empty rows, a mega-row far above `ROWCACHE_MAX_ROW_NNZ`, and feature
//! widths straddling every SIMD lane boundary (1/7/8/9/33).

use aes_spmm::exec::ROWCACHE_MAX_ROW_NNZ;
use aes_spmm::graph::{coo_to_csr, Csr};
use aes_spmm::quant::ChunkedParams;
use aes_spmm::rng::Pcg32;
use aes_spmm::spmm::simd::{self, SimdLevel};
use aes_spmm::spmm::{
    bcsr_spmm_at, bcsr_spmm_i8_at, bcsr_spmm_i8_par, bcsr_spmm_par, csr_naive, csr_spmm_i8_at,
    dense_spmm_at, dense_spmm_i8_at, dense_spmm_i8_par, dense_spmm_par, AdjQuant, BlockedCsr,
    DenseTile, BCSR_BLOCK_ROWS,
};

/// Feature widths straddling the 8-lane fp32 blocks (and the i8
/// gather's lane remainders): below, at, and just past a lane, plus the
/// single-column degenerate case and a 33-wide two-block remainder.
const FEATS: [usize; 5] = [1, 7, 8, 9, 33];

/// Block heights exercising degenerate (1), misaligned (5), and the
/// production height.
const HEIGHTS: [usize; 3] = [1, 5, BCSR_BLOCK_ROWS];

const THREADS: [usize; 2] = [2, 5];

fn assert_bitwise(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length mismatch");
    for (i, (w, g)) in want.iter().zip(got.iter()).enumerate() {
        assert!(w.to_bits() == g.to_bits(), "{what}: idx {i}: {w} vs {g} differ in bits");
    }
}

/// The adversarial graph family, with a label for failure messages.
fn adversarial_graphs() -> Vec<(&'static str, Csr)> {
    let mut rng = Pcg32::new(0xF0_0001);
    let mut out: Vec<(&'static str, Csr)> = Vec::new();

    // 0 rows, 0 edges — every loop bound degenerates.
    out.push(("empty graph", Csr::new(0, 4, vec![0], vec![], vec![]).unwrap()));

    // Every third row empty, the rest light — block/pitch bookkeeping
    // must skip holes without drifting its edge cursor.
    let mut triples = Vec::new();
    for i in 0..97usize {
        if i % 3 == 0 {
            continue;
        }
        for _ in 0..(1 + rng.usize_below(12)) {
            triples.push((i as i32, rng.usize_below(97) as i32, rng.f32() - 0.5));
        }
    }
    out.push(("empty rows", coo_to_csr(97, 97, triples).unwrap()));

    // One mega-row far above the rowcache bitwise gate, over a tail of
    // sparse rows — a worst case for both the blocked edge walk and the
    // dense pitch.
    let mega = 2 * ROWCACHE_MAX_ROW_NNZ + 88; // 600 for the 256 gate
    let mut triples = Vec::new();
    for c in 0..mega {
        triples.push((0i32, c as i32, rng.f32() - 0.5));
    }
    for i in 1..64usize {
        for _ in 0..3 {
            triples.push((i as i32, rng.usize_below(mega) as i32, rng.f32() - 0.5));
        }
    }
    out.push(("mega-row", coo_to_csr(64, mega, triples).unwrap()));

    // A plain random graph as the non-degenerate control.
    let mut triples = Vec::new();
    for i in 0..160usize {
        for _ in 0..(1 + rng.usize_below(24)) {
            triples.push((i as i32, rng.usize_below(160) as i32, rng.f32() - 0.5));
        }
    }
    out.push(("random", coo_to_csr(160, 160, triples).unwrap()));
    out
}

fn features(g: &Csr, f: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..g.n_cols * f).map(|_| rng.f32() - 0.5).collect()
}

#[test]
fn fp32_formats_bitwise_equal_to_csr_naive_everywhere() {
    let levels = [SimdLevel::Scalar, simd::level()];
    for (name, g) in adversarial_graphs() {
        for f in FEATS {
            let b = features(&g, f, 0xB17_0000 + f as u64);
            let mut want = vec![7.0f32; g.n_rows * f];
            csr_naive(&g, &b, f, &mut want);

            for h in HEIGHTS {
                let m = BlockedCsr::from_csr(&g, h);
                for lvl in levels {
                    let mut got = vec![7.0f32; g.n_rows * f];
                    bcsr_spmm_at(lvl, &m, &b, f, &mut got);
                    assert_bitwise(&want, &got, &format!("{name}: bcsr h={h} {lvl:?} f={f}"));
                }
                for t in THREADS {
                    let mut got = vec![7.0f32; g.n_rows * f];
                    bcsr_spmm_par(&m, &b, f, &mut got, t);
                    assert_bitwise(&want, &got, &format!("{name}: bcsr h={h} par{t} f={f}"));
                }
            }

            let tile = DenseTile::from_csr(&g);
            for lvl in levels {
                let mut got = vec![7.0f32; g.n_rows * f];
                dense_spmm_at(lvl, &tile, &b, f, &mut got);
                assert_bitwise(&want, &got, &format!("{name}: dense {lvl:?} f={f}"));
            }
            for t in THREADS {
                let mut got = vec![7.0f32; g.n_rows * f];
                dense_spmm_par(&tile, &b, f, &mut got, t);
                assert_bitwise(&want, &got, &format!("{name}: dense par{t} f={f}"));
            }
        }
    }
}

#[test]
fn i8_formats_bitwise_equal_to_csr_i8_scalar_everywhere() {
    let levels = [SimdLevel::Scalar, simd::level()];
    for (name, g) in adversarial_graphs() {
        for f in FEATS {
            let b = features(&g, f, 0xB17_8000 + f as u64);
            let chunk = (g.n_cols / 4).max(1);
            let params = ChunkedParams::of_rows(&b, g.n_cols, f, chunk);
            let qb = params.quantize_rows(&b, f);
            let aq = AdjQuant::from_csr(&g, &params);

            // Scalar CSR is the canon; the detected-SIMD CSR arm must
            // already match it bitwise (integer accumulation).
            let mut want = vec![7.0f32; g.n_rows * f];
            csr_spmm_i8_at(SimdLevel::Scalar, &g, &aq, &qb, f, &mut want);
            let mut got = vec![7.0f32; g.n_rows * f];
            csr_spmm_i8_at(simd::level(), &g, &aq, &qb, f, &mut got);
            assert_bitwise(&want, &got, &format!("{name}: csr i8 simd f={f}"));

            for h in HEIGHTS {
                let m = BlockedCsr::from_csr(&g, h);
                for lvl in levels {
                    let mut got = vec![7.0f32; g.n_rows * f];
                    bcsr_spmm_i8_at(lvl, &m, &aq, &qb, f, &mut got);
                    assert_bitwise(&want, &got, &format!("{name}: bcsr i8 h={h} {lvl:?} f={f}"));
                }
                for t in THREADS {
                    let mut got = vec![7.0f32; g.n_rows * f];
                    bcsr_spmm_i8_par(&m, &aq, &qb, f, &mut got, t);
                    assert_bitwise(&want, &got, &format!("{name}: bcsr i8 h={h} par{t} f={f}"));
                }
            }

            let tile = DenseTile::from_csr(&g);
            for lvl in levels {
                let mut got = vec![7.0f32; g.n_rows * f];
                dense_spmm_i8_at(lvl, &tile, &aq, &qb, f, &mut got);
                assert_bitwise(&want, &got, &format!("{name}: dense i8 {lvl:?} f={f}"));
            }
            for t in THREADS {
                let mut got = vec![7.0f32; g.n_rows * f];
                dense_spmm_i8_par(&tile, &aq, &qb, f, &mut got, t);
                assert_bitwise(&want, &got, &format!("{name}: dense i8 par{t} f={f}"));
            }
        }
    }
}

#[test]
fn mega_row_really_exceeds_the_rowcache_gate() {
    // Guard the fixture itself: if the adversarial family stops
    // covering the > ROWCACHE_MAX_ROW_NNZ regime, this fails before the
    // equivalence tests silently weaken.
    let g = adversarial_graphs()
        .into_iter()
        .find(|(n, _)| *n == "mega-row")
        .map(|(_, g)| g)
        .unwrap();
    assert!(g.max_degree() > ROWCACHE_MAX_ROW_NNZ);
}
