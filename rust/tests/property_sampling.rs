//! Property tests over the sampling/graph/quant substrates (hand-rolled
//! seeded-random harness — `proptest` is not in the offline registry).
//! Each property runs across a deterministic family of random cases; a
//! failure prints the seed for reproduction.

use aes_spmm::exec::{ShardSampling, ShardedPlan};
use aes_spmm::gen;
use aes_spmm::graph::{coo_to_csr, Csr, ShardSpec};
use aes_spmm::quant::{dequantize, max_quant_error, quantize, QuantParams};
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::{plan_row, sample_ell, sampling_rate, strategy_params, Strategy};
use aes_spmm::spmm::{csr_naive, ell_spmm};

/// Run `f` over `cases` seeded deterministic iterations.
fn forall(cases: u64, mut f: impl FnMut(u64, &mut Pcg32)) {
    for seed in 0..cases {
        let mut rng = Pcg32::new(0xA55_0000 + seed);
        f(seed, &mut rng);
    }
}

fn random_csr(rng: &mut Pcg32, n: usize, max_deg: usize) -> Csr {
    let mut triples = Vec::new();
    for i in 0..n {
        let deg = rng.usize_below(max_deg + 1);
        for _ in 0..deg {
            triples.push((i as i32, rng.usize_below(n) as i32, rng.f32() - 0.5));
        }
    }
    coo_to_csr(n, n, triples).unwrap()
}

#[test]
fn prop_plan_row_offsets_valid_for_all_regimes() {
    forall(200, |seed, rng| {
        let nnz = rng.usize_below(100_000);
        let width = [16, 32, 64, 128, 256, 512][rng.usize_below(6)];
        for strat in Strategy::ALL {
            let offs = plan_row(nnz, width, strat);
            let p = strategy_params(nnz, width, strat);
            assert_eq!(offs.len(), p.slots, "seed {seed}");
            assert!(p.slots <= width, "seed {seed}: slots exceed W");
            for &o in &offs {
                assert!(o < nnz.max(1), "seed {seed}: offset {o} out of row (nnz {nnz})");
            }
            // Runs of N consecutive offsets share the same hash start.
            for k in 0..p.slots {
                let s = k % p.sample_cnt;
                let j = k / p.sample_cnt;
                assert!(j < p.n, "seed {seed}: run index exceeds N");
                let _ = s;
            }
        }
    });
}

#[test]
fn prop_sample_ell_structurally_valid_and_deterministic() {
    forall(30, |seed, rng| {
        let n = 20 + rng.usize_below(200);
        let g = random_csr(rng, n, 200);
        let width = [16, 32, 64][rng.usize_below(3)];
        for strat in Strategy::ALL {
            let a = sample_ell(&g, width, strat);
            a.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            let b = sample_ell(&g, width, strat);
            assert_eq!(a, b, "seed {seed}: sampling must be deterministic");
            // Every sampled (col) must exist in the source row.
            for i in 0..n.min(20) {
                let row: std::collections::HashSet<i32> =
                    g.col_ind[g.row_range(i)].iter().copied().collect();
                for k in 0..a.slots[i] as usize {
                    assert!(
                        row.contains(&a.col[i * width + k]),
                        "seed {seed}: sampled col not in row"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_sampled_spmm_bounded_by_exact_mass() {
    // With non-negative values, each sampled row output never exceeds the
    // exact row output (sampling keeps a subset; duplicates can appear
    // only within a sample run, which stays bounded by slot count).
    forall(20, |seed, rng| {
        let n = 30 + rng.usize_below(80);
        let mut g = random_csr(rng, n, 60);
        for v in g.val.iter_mut() {
            *v = v.abs();
        }
        let f = 4;
        let b: Vec<f32> = (0..n * f).map(|_| rng.f32()).collect();
        let mut exact = vec![0.0f32; n * f];
        csr_naive(&g, &b, f, &mut exact);
        let wmax = g.max_degree().max(1);
        let ell = sample_ell(&g, wmax, Strategy::Aes);
        let mut sampled = vec![0.0f32; n * f];
        ell_spmm(&ell, &b, f, &mut sampled);
        for (i, (s, e)) in sampled.iter().zip(exact.iter()).enumerate() {
            assert!(
                *s <= *e + 1e-3,
                "seed {seed} idx {i}: full-width sample exceeded exact ({s} vs {e})"
            );
            assert!((s - e).abs() < 1e-3, "seed {seed}: full width must equal exact");
        }
    });
}

#[test]
fn prop_sampling_rate_bounds_and_monotonicity() {
    forall(15, |seed, rng| {
        let n = 50 + rng.usize_below(300);
        let deg = 2.0 + rng.f64() * 80.0;
        let g = gen::chung_lu(n, deg, 1.7 + rng.f64(), rng);
        for strat in Strategy::ALL {
            let mut last = 0.0;
            for w in [16, 32, 64, 128, 256, 1024] {
                let r = sampling_rate(&g, w, strat);
                assert!((0.0..=1.0).contains(&r), "seed {seed}");
                assert!(r >= last - 1e-12, "seed {seed}: rate must be monotone in W");
                last = r;
            }
            assert!(
                (sampling_rate(&g, g.max_degree().max(1), strat) - 1.0).abs() < 1e-12,
                "seed {seed}: W >= max degree keeps everything"
            );
        }
    });
}

#[test]
fn prop_quant_roundtrip_bound() {
    forall(50, |seed, rng| {
        let len = 1 + rng.usize_below(4096);
        let scale = 0.01 + rng.f32() * 100.0;
        let off = (rng.f32() - 0.5) * 50.0;
        let data: Vec<f32> = (0..len).map(|_| off + rng.f32() * scale).collect();
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        let back = dequantize(&q, p);
        let bound = max_quant_error(p) + 1e-5 * scale.max(1.0);
        for (x, y) in data.iter().zip(back.iter()) {
            assert!((x - y).abs() <= bound, "seed {seed}: {x} vs {y} (bound {bound})");
        }
    });
}

/// A graph with the requested degree profile: even seeds draw a
/// power-law Chung-Lu, odd seeds a uniform Erdős–Rényi — so every
/// sampling property below is driven over both profiles.
fn profiled_graph(seed: u64, n: usize, rng: &mut Pcg32) -> Csr {
    if seed % 2 == 0 {
        gen::chung_lu(n, 14.0, 1.8, rng)
    } else {
        gen::erdos_renyi(n, n * 6, rng)
    }
}

#[test]
fn prop_shard_tile_budgets_never_exceed_global_width() {
    // Shard-local tile widths (sampling::shard_width via the sharded
    // planner) must stay within the route's global W: a shard may
    // shrink its tile, never widen it.
    forall(12, |seed, rng| {
        let n = 40 + rng.usize_below(160);
        let g = profiled_graph(seed, n, rng);
        let shards = 1 + rng.usize_below(5);
        for w in [4usize, 16, 64] {
            let strat = Strategy::ALL[rng.usize_below(3)];
            let spec = ShardSpec::by_count(shards);
            let plan = ShardedPlan::prepare(&g, &spec, Some(w), strat, 8, None);
            for u in plan.units() {
                let tile = u.sampling.width().expect("sampled route units carry a width");
                assert!(tile <= w, "seed {seed}: shard tile {tile} exceeds global W {w}");
                let ell = u.ell.as_ref().expect("sampled route units carry an ELL");
                assert_eq!(ell.width, tile, "seed {seed}: ELL width disagrees with the tile");
                ell.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
            }
        }
    });
}

#[test]
fn prop_uniform_shards_sample_exhaustively() {
    // When every row of a shard fits the global tile, sampling must keep
    // EVERY edge: the shrunken-tile ELL holds each row's full edge list,
    // in CSR order.
    forall(10, |seed, rng| {
        let n = 30 + rng.usize_below(120);
        let g = profiled_graph(seed, n, rng);
        let w = g.max_degree().max(1) * 2; // every shard fits => exhaustive everywhere
        let spec = ShardSpec::by_count(4);
        let plan = ShardedPlan::prepare(&g, &spec, Some(w), Strategy::Aes, 8, None);
        for u in plan.units() {
            match u.sampling {
                ShardSampling::Exhaustive { width } => {
                    let ell = u.ell.as_ref().unwrap();
                    assert!(width <= w, "seed {seed}");
                    let mut kept = 0usize;
                    for li in 0..u.csr.n_rows {
                        let nnz = u.csr.row_nnz(li);
                        assert_eq!(ell.slots[li] as usize, nnz, "seed {seed} local row {li}");
                        let cols = &u.csr.col_ind[u.csr.row_range(li)];
                        for (k, &c) in cols.iter().enumerate() {
                            assert_eq!(ell.col[li * ell.width + k], c, "seed {seed}: edge dropped");
                        }
                        kept += nnz;
                    }
                    assert_eq!(kept, u.csr.nnz(), "seed {seed}: ELL must keep every edge");
                }
                other => panic!("seed {seed}: W >= max degree must be exhaustive, got {other:?}"),
            }
        }
    });
}

#[test]
fn prop_sampled_row_nnz_never_exceeds_original() {
    // Sampling keeps a subset: a row's ELL slot count never exceeds its
    // CSR nnz (nor W), for every strategy over both degree profiles.
    forall(16, |seed, rng| {
        let n = 30 + rng.usize_below(150);
        let g = profiled_graph(seed, n, rng);
        for strat in Strategy::ALL {
            for w in [4usize, 16, 64] {
                let ell = sample_ell(&g, w, strat);
                for i in 0..n {
                    let s = ell.slots[i] as usize;
                    assert!(
                        s <= g.row_nnz(i),
                        "seed {seed}: row {i} sampled {s} slots from {} edges",
                        g.row_nnz(i)
                    );
                    assert!(s <= w, "seed {seed}: row {i} overflows the tile");
                }
            }
        }
        // The same invariant at the planner level, across the regimes
        // (including the empty row, where slots must be 0).
        for strat in Strategy::ALL {
            for nnz in [0usize, 1, 7, 63, 64, 65, 4097] {
                let p = strategy_params(nnz, 64, strat);
                assert!(p.slots <= nnz, "seed {seed}: {nnz}-edge row planned {} slots", p.slots);
                assert!(p.slots <= 64, "seed {seed}: slots exceed W");
            }
        }
    });
}

#[test]
fn prop_generated_graphs_always_valid() {
    forall(12, |seed, rng| {
        let n = 20 + rng.usize_below(500);
        let g = match seed % 3 {
            0 => gen::erdos_renyi(n, n * 4, rng),
            1 => gen::chung_lu(n, 8.0, 2.0, rng),
            _ => {
                let (g, _) = gen::dc_sbm(
                    &gen::DcSbmConfig {
                        n,
                        avg_deg: 10.0,
                        gamma: 1.9,
                        communities: 4,
                        homophily: 0.7,
                    },
                    rng,
                );
                g
            }
        };
        g.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        let sl = gen::with_self_loops(&g);
        sl.validate().unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        assert_eq!(sl.transpose(), sl, "seed {seed}: symmetric after self loops");
    });
}
