//! Cost-model lifecycle tests: the golden fixture drives the documented
//! per-cell kernel choices, degraded documents fall back to heuristics
//! with a warning (never a panic), and the full accuracy-conformance
//! grid holds its budgets when scored through a tuned dispatcher.
//!
//! Every test that installs a model into the process-wide slot takes
//! `GLOBAL`, saves the previous installation, and restores it — tests in
//! this binary run concurrently and the slot is shared.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use aes_spmm::exec::{
    install_cost_model, install_cost_model_from, installed_fingerprint, CostModel, Density,
    ExecEnv, Family, FeatBand, FormatMask, GraphProfile, KernelDomain, KernelKind, ProfileBucket,
    Skew,
};

/// Serializes every test that touches the process-wide installed model.
static GLOBAL: Mutex<()> = Mutex::new(());

fn fixture_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/cost_model_v1.json")
}

fn profile(n_rows: usize, nnz: usize, max_nnz: usize) -> GraphProfile {
    GraphProfile { n_rows, nnz, mean_nnz: nnz as f64 / n_rows.max(1) as f64, max_nnz }
}

/// Buckets to `dense/uniform/wide` at feat 64: mean 100, max within 8×.
fn dense_uniform() -> GraphProfile {
    profile(1000, 100_000, 150)
}

/// Buckets to `sparse/uniform/narrow` at feat 16: mean 4, max within 8×.
fn sparse_uniform() -> GraphProfile {
    profile(1000, 4_000, 20)
}

/// Buckets to `mid/skewed/narrow` at feat 16: mean 16, max beyond 8×.
fn mid_skewed() -> GraphProfile {
    profile(1000, 16_000, 200)
}

#[test]
fn golden_fixture_loads_with_the_expected_cells() {
    let m = CostModel::load(&fixture_path()).unwrap();
    assert_eq!(m.len(), 5);
    assert_ne!(m.fingerprint(), 0);
    let expected = [
        ("dense/uniform/wide/exact/f32", KernelKind::CsrBlockedPar),
        ("dense/uniform/wide/exact/i8", KernelKind::ExactDenseI8Par),
        ("sparse/uniform/narrow/exact/f32", KernelKind::CsrRowCache),
        ("mid/skewed/narrow/sampled/f32", KernelKind::EllSampledPar),
        ("mid/skewed/narrow/sampled/i8", KernelKind::EllSampledI8),
    ];
    for (key, kind) in expected {
        assert_eq!(m.cell(key), Some(kind), "cell {key}");
    }
    // Measurements in the document are advisory and dropped on load;
    // the cells alone define the fingerprint.
    let choose = m.choose(&dense_uniform(), 64, None, KernelDomain::F32);
    assert_eq!(choose, Some(KernelKind::CsrBlockedPar));
}

#[test]
fn installed_fixture_steers_selection_per_cell() {
    let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    let model = Arc::new(CostModel::load(&fixture_path()).unwrap());
    let prev = install_cost_model(Some(model.clone()));
    let env = ExecEnv::with_threads(8);
    use aes_spmm::exec::select_kernel_tuned as tuned;

    // Measured buckets answer the fixture's picks when the layout is
    // materialized (mask ALL)...
    let got = tuned(&dense_uniform(), 64, None, &env, KernelDomain::F32, FormatMask::ALL);
    assert_eq!(got, KernelKind::CsrBlockedPar);
    let got = tuned(&dense_uniform(), 64, None, &env, KernelDomain::I8, FormatMask::ALL);
    assert_eq!(got, KernelKind::ExactDenseI8Par);
    let got = tuned(&mid_skewed(), 16, Some(16), &env, KernelDomain::F32, FormatMask::ALL);
    assert_eq!(got, KernelKind::EllSampledPar);
    let got = tuned(&mid_skewed(), 16, Some(16), &env, KernelDomain::I8, FormatMask::ALL);
    assert_eq!(got, KernelKind::EllSampledI8);
    // ...including classic-format picks the heuristics would not make
    // (mean 4 is far below the rowcache staging threshold).
    let got = tuned(&sparse_uniform(), 16, None, &env, KernelDomain::F32, FormatMask::CLASSIC);
    assert_eq!(got, KernelKind::CsrRowCache);

    // Unmeasured buckets fall back to the heuristics.
    let got = tuned(&dense_uniform(), 16, None, &env, KernelDomain::F32, FormatMask::ALL);
    assert_eq!(got, KernelKind::CsrNaivePar);

    install_cost_model(prev);
}

#[test]
fn inadmissible_picks_degrade_to_heuristics_not_panics() {
    let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    let model = Arc::new(CostModel::load(&fixture_path()).unwrap());
    let prev = install_cost_model(Some(model));
    use aes_spmm::exec::select_kernel_tuned as tuned;

    // The model's pick is blocked-format parallel; without the layout
    // (mask CLASSIC) and without threads it must degrade, not panic.
    let par = ExecEnv::with_threads(8);
    let got = tuned(&dense_uniform(), 64, None, &par, KernelDomain::F32, FormatMask::CLASSIC);
    assert_eq!(got, KernelKind::CsrNaivePar, "layout not materialized");
    let serial = ExecEnv::with_threads(1);
    let got = tuned(&dense_uniform(), 64, None, &serial, KernelDomain::F32, FormatMask::ALL);
    assert_eq!(got, KernelKind::CsrRowCache, "thread budget of 1");

    // The classic wrappers never return a format-zoo kernel, installed
    // model or not — their executors would panic on one.
    let got = aes_spmm::exec::select_kernel(&dense_uniform(), 64, None, &par);
    assert!(
        got.format() == aes_spmm::exec::FormatKind::Csr,
        "select_kernel returned format kernel {got:?}"
    );

    install_cost_model(prev);
}

#[test]
fn corrupt_or_stale_documents_warn_and_leave_heuristics_in_charge() {
    let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    let prev = install_cost_model(None);
    assert_eq!(installed_fingerprint(), 0);

    let dir = std::env::temp_dir().join(format!("cost_model_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // Missing file.
    assert!(!install_cost_model_from(&dir.join("absent.json")));
    assert_eq!(installed_fingerprint(), 0, "missing file must not install");
    // Unparseable garbage.
    let garbage = dir.join("garbage.json");
    std::fs::write(&garbage, "][ not json").unwrap();
    assert!(!install_cost_model_from(&garbage));
    assert_eq!(installed_fingerprint(), 0, "garbage must not install");
    // Stale schema version.
    let stale = dir.join("stale.json");
    std::fs::write(&stale, r#"{"schema":"aes-spmm-cost-model","version":999,"cells":{}}"#)
        .unwrap();
    assert!(!install_cost_model_from(&stale));
    assert_eq!(installed_fingerprint(), 0, "stale version must not install");

    // A failed install also leaves a previous *good* installation
    // untouched.
    assert!(install_cost_model_from(&fixture_path()));
    let good = installed_fingerprint();
    assert_ne!(good, 0);
    assert!(!install_cost_model_from(&stale));
    assert_eq!(installed_fingerprint(), good, "failed reload clobbered the model");

    install_cost_model(prev);
}

/// A model covering every bucket×family×domain cell with format-zoo (or
/// sampled) kernels, to force tuned dispatch through the new layouts.
fn zoo_everywhere() -> CostModel {
    let mut m = CostModel::default();
    for density in [Density::Sparse, Density::Mid, Density::Dense] {
        for skew in [Skew::Uniform, Skew::Skewed] {
            for feat in [FeatBand::Narrow, FeatBand::Wide] {
                let b = ProfileBucket { density, skew, feat };
                m.set_cell(&b, Family::Exact, KernelDomain::F32, KernelKind::CsrBlocked);
                m.set_cell(&b, Family::Exact, KernelDomain::I8, KernelKind::CsrBlockedI8);
                m.set_cell(&b, Family::Sampled, KernelDomain::F32, KernelKind::EllSampled);
                m.set_cell(&b, Family::Sampled, KernelDomain::I8, KernelKind::EllSampledI8);
            }
        }
    }
    m
}

/// The headline degradation-free guarantee: the accuracy-conformance
/// grid (real coordinator, budgets vs the exact oracle) passes with a
/// cost model that routes every exact shard through blocked-CSR — the
/// format zoo is bitwise-equal to canonical CSR, so a tuned dispatcher
/// can only change speed.
#[test]
fn eval_grid_holds_its_budgets_under_a_tuned_dispatcher() {
    let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
    let prev = install_cost_model(Some(Arc::new(zoo_everywhere())));
    assert_ne!(installed_fingerprint(), 0);

    let dir = std::env::temp_dir().join(format!("tuned_eval_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let report = aes_spmm::eval::run_eval(&dir, true);

    // Restore before asserting so a failure cannot leak the install.
    install_cost_model(prev);
    let report = report.unwrap();
    let failures = report.failures();
    assert!(failures.is_empty(), "tuned-dispatch budget violations:\n{}", failures.join("\n"));
}
