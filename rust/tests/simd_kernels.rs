//! SIMD dispatch and INT8-compute conformance at the public kernel
//! API — the docs/simd.md contracts checked from outside the crate:
//!
//! * every vector arm is **bitwise-identical** to the scalar arm
//!   (remainder lanes, empty rows, mega-rows included), so runtime
//!   dispatch can never move a logit bit;
//! * the `i8×u8→i32` kernels agree with dequantize-then-fp32 within
//!   the per-row requant error bound, under per-chunk feature scales;
//! * threading composes bitwise on the integer kernels exactly like it
//!   does on the fp32 ones.
//!
//! The grid-level counterpart (forced-scalar runs of the whole suite)
//! is CI's `scalar` job: `AES_SPMM_FORCE_SCALAR=1` pins `simd::level()`
//! process-wide, and the oracle's golden fixtures plus the bitwise
//! grid rows prove the scalar configuration serves identical logits.

use aes_spmm::gen;
use aes_spmm::graph::Csr;
use aes_spmm::quant::ChunkedParams;
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::{sample_ell, Strategy};
use aes_spmm::spmm::{
    csr_naive, csr_rowcache_at, csr_spmm_i8, csr_spmm_i8_at, csr_spmm_i8_par, ell_spmm_at,
    ell_spmm_i8, ell_spmm_i8_at, ell_spmm_i8_par, simd, AdjQuant,
};

fn graph_and_features(n: usize, deg: f64, f: usize, seed: u64) -> (Csr, Vec<f32>) {
    let mut rng = Pcg32::new(seed);
    let mut g = gen::with_self_loops(&gen::chung_lu(n, deg, 1.9, &mut rng));
    for v in g.val.iter_mut() {
        *v = rng.f32() - 0.5;
    }
    let b: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
    (g, b)
}

/// One row holding `nnz` edges — drives the tile/flush remainder paths
/// that graph generators rarely hit.
fn mega_row(nnz: usize, n_cols: usize, f: usize, seed: u64) -> (Csr, Vec<f32>) {
    let mut rng = Pcg32::new(seed);
    let col_ind: Vec<i32> = (0..nnz).map(|_| rng.usize_below(n_cols) as i32).collect();
    let val: Vec<f32> = (0..nnz).map(|_| rng.f32() - 0.5).collect();
    let g = Csr::new(1, n_cols, vec![0, nnz as i32], col_ind, val).unwrap();
    let b: Vec<f32> = (0..n_cols * f).map(|_| rng.f32() - 0.5).collect();
    (g, b)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} differs ({x} vs {y})");
    }
}

/// The detected arm equals the scalar arm bit-for-bit on the fp32
/// kernels — across feature widths that exercise full vector blocks,
/// remainder lanes, and the width-1 degenerate case.
#[test]
fn fp32_kernels_dispatch_bitwise_across_widths() {
    let lvl = simd::level();
    for f in [1usize, 3, 7, 8, 9, 16, 33, 64] {
        let (g, b) = graph_and_features(120, 9.0, f, 40 + f as u64);
        let n = g.n_rows;

        let mut scalar = vec![0.0f32; n * f];
        let mut vector = vec![0.0f32; n * f];
        csr_rowcache_at(simd::SimdLevel::Scalar, &g, &b, f, &mut scalar);
        csr_rowcache_at(lvl, &g, &b, f, &mut vector);
        assert_bitwise(&scalar, &vector, &format!("rowcache f={f} {}", lvl.name()));
        // And both equal the naive edge-order kernel: every row here
        // fits one staging tile (max degree < EDGE_TILE_MIN), where
        // the tile cannot change the accumulation order.
        let mut naive = vec![0.0f32; n * f];
        csr_naive(&g, &b, f, &mut naive);
        assert_bitwise(&naive, &scalar, &format!("rowcache vs naive f={f}"));

        for w in [4usize, 16] {
            let ell = sample_ell(&g, w, Strategy::Aes);
            let mut scalar = vec![0.0f32; n * f];
            let mut vector = vec![0.0f32; n * f];
            ell_spmm_at(simd::SimdLevel::Scalar, &ell, &b, f, &mut scalar);
            ell_spmm_at(lvl, &ell, &b, f, &mut vector);
            assert_bitwise(&scalar, &vector, &format!("ell f={f} w={w} {}", lvl.name()));
        }
    }
}

/// Empty rows (isolated nodes) and a mega-row crossing many staging
/// tiles dispatch bitwise too — the remainder machinery has no hidden
/// reorder.
#[test]
fn fp32_kernels_dispatch_bitwise_on_degenerate_shapes() {
    let lvl = simd::level();
    let f = 24usize;
    // chung_lu leaves low-weight nodes isolated: empty rows exist.
    let (g, b) = graph_and_features(300, 1.2, f, 77);
    assert!((0..g.n_rows).any(|i| g.row_nnz(i) == 0), "fixture lost its empty rows");
    let mut scalar = vec![0.0f32; g.n_rows * f];
    let mut vector = vec![0.0f32; g.n_rows * f];
    csr_rowcache_at(simd::SimdLevel::Scalar, &g, &b, f, &mut scalar);
    csr_rowcache_at(lvl, &g, &b, f, &mut vector);
    assert_bitwise(&scalar, &vector, "rowcache with empty rows");

    // One row of 10_000 edges: dozens of staging tiles plus a tail.
    // Tile boundaries are level-independent, so the arms still agree
    // bitwise; vs naive only closeness holds (per-tile partial sums
    // reassociate the row reduction — the dispatch gate keeps rows
    // this long on csr_naive for exactly that reason).
    let (g, b) = mega_row(10_000, 64, f, 78);
    let mut scalar = vec![0.0f32; f];
    let mut vector = vec![0.0f32; f];
    csr_rowcache_at(simd::SimdLevel::Scalar, &g, &b, f, &mut scalar);
    csr_rowcache_at(lvl, &g, &b, f, &mut vector);
    assert_bitwise(&scalar, &vector, "rowcache mega-row");
    let mut naive = vec![0.0f32; f];
    csr_naive(&g, &b, f, &mut naive);
    for k in 0..f {
        let d = (naive[k] - scalar[k]).abs();
        assert!(d <= 1e-2 * naive[k].abs().max(1.0), "mega-row col {k} drifted: {d}");
    }
}

/// Quantize features with per-chunk ranges; return the codes, the
/// params, and the exact dequantized fp32 view the dequant route sees.
fn quantized(
    b: &[f32],
    n: usize,
    f: usize,
    rows_per_chunk: usize,
) -> (Vec<u8>, ChunkedParams, Vec<f32>) {
    let params = ChunkedParams::of_rows(b, n, f, rows_per_chunk);
    let qb = params.quantize_rows(b, f);
    let mut deq = vec![0.0f32; n * f];
    params.dequantize_rows_into(&qb, 0, f, &mut deq);
    (qb, params, deq)
}

/// Integer kernels dispatch bitwise: scalar vs detected arm, ELL and
/// CSR, remainder widths included. Integer lanes are exact, so this
/// holds by construction — the test pins it against regressions.
#[test]
fn i8_kernels_dispatch_bitwise_across_widths() {
    let lvl = simd::level();
    for f in [1usize, 5, 8, 13, 32] {
        let (g, b) = graph_and_features(150, 12.0, f, 90 + f as u64);
        let n = g.n_rows;
        let (qb, params, _) = quantized(&b, n, f, 40);

        let aq = AdjQuant::from_csr(&g, &params);
        let mut scalar = vec![0.0f32; n * f];
        let mut vector = vec![0.0f32; n * f];
        csr_spmm_i8_at(simd::SimdLevel::Scalar, &g, &aq, &qb, f, &mut scalar);
        csr_spmm_i8_at(lvl, &g, &aq, &qb, f, &mut vector);
        assert_bitwise(&scalar, &vector, &format!("csr i8 f={f} {}", lvl.name()));

        let ell = sample_ell(&g, 8, Strategy::Aes);
        let aq = AdjQuant::from_ell(&ell, &params);
        let mut scalar = vec![0.0f32; n * f];
        let mut vector = vec![0.0f32; n * f];
        ell_spmm_i8_at(simd::SimdLevel::Scalar, &ell, &aq, &qb, f, &mut scalar);
        ell_spmm_i8_at(lvl, &ell, &aq, &qb, f, &mut vector);
        assert_bitwise(&scalar, &vector, &format!("ell i8 f={f} {}", lvl.name()));
    }
}

/// The quantized-domain kernels agree with dequantize-then-fp32 within
/// the per-row requant bound: the only error source past the shared
/// feature quantization is `|a_e - qa_e·row_scale| ≤ row_scale/2` per
/// edge, amplified by the u8 code magnitude (≤ 255).
#[test]
fn i8_compute_matches_dequant_route_within_requant_bound() {
    let f = 16usize;
    for (n, deg, chunk, seed) in [(200usize, 8.0, 50usize, 5u64), (300, 25.0, 37, 6)] {
        let (g, b) = graph_and_features(n, deg, f, seed);
        let (qb, params, deq) = quantized(&b, n, f, chunk);

        // The dequant route's exact aggregation over x̂.
        let mut want = vec![0.0f32; n * f];
        csr_naive(&g, &deq, f, &mut want);
        let aq = AdjQuant::from_csr(&g, &params);
        let mut got = vec![0.0f32; n * f];
        csr_spmm_i8(&g, &aq, &qb, f, &mut got);
        for i in 0..n {
            // Worst case: every edge's coefficient off by half a step,
            // every code at full scale (255), plus fp32 noise.
            let bound = aq.row_scale[i] * 0.5 * 255.0 * g.row_nnz(i) as f32 + 1e-3;
            for k in 0..f {
                let d = (want[i * f + k] - got[i * f + k]).abs();
                assert!(d <= bound, "row {i} col {k}: |{d}| > bound {bound}");
            }
        }

        // Same contract on a sampled plan.
        let ell = sample_ell(&g, 8, Strategy::Aes);
        let mut want = vec![0.0f32; n * f];
        aes_spmm::spmm::ell_spmm(&ell, &deq, f, &mut want);
        let aq = AdjQuant::from_ell(&ell, &params);
        let mut got = vec![0.0f32; n * f];
        ell_spmm_i8(&ell, &aq, &qb, f, &mut got);
        for i in 0..n {
            let bound = aq.row_scale[i] * 0.5 * 255.0 * ell.slots[i] as f32 + 1e-3;
            for k in 0..f {
                let d = (want[i * f + k] - got[i * f + k]).abs();
                assert!(d <= bound, "sampled row {i} col {k}: |{d}| > bound {bound}");
            }
        }
    }
}

/// Threaded INT8 kernels are bitwise-equal to serial at every thread
/// count — row partitioning cannot move a flush boundary (they are
/// row-local) or reorder an integer accumulation.
#[test]
fn i8_parallel_composes_bitwise() {
    let f = 12usize;
    let (g, b) = graph_and_features(400, 18.0, f, 101);
    let n = g.n_rows;
    let (qb, params, _) = quantized(&b, n, f, 64);

    let aq = AdjQuant::from_csr(&g, &params);
    let mut serial = vec![0.0f32; n * f];
    csr_spmm_i8(&g, &aq, &qb, f, &mut serial);
    for threads in [1usize, 2, 5, 8] {
        let mut par = vec![7.0f32; n * f];
        csr_spmm_i8_par(&g, &aq, &qb, f, &mut par, threads);
        assert_bitwise(&serial, &par, &format!("csr i8 par t={threads}"));
    }

    let ell = sample_ell(&g, 16, Strategy::Aes);
    let aq = AdjQuant::from_ell(&ell, &params);
    let mut serial = vec![0.0f32; n * f];
    ell_spmm_i8(&ell, &aq, &qb, f, &mut serial);
    for threads in [2usize, 7] {
        let mut par = vec![7.0f32; n * f];
        ell_spmm_i8_par(&ell, &aq, &qb, f, &mut par, threads);
        assert_bitwise(&serial, &par, &format!("ell i8 par t={threads}"));
    }
}
