//! Integration tests for the serving stack: coordinator over the real
//! engine + artifacts, checking batching semantics, correctness of the
//! answers, backpressure, and clean shutdown. Skipped without artifacts.

use std::sync::Arc;
use std::time::Duration;

use aes_spmm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, ModelStore, RouteKey, SubmitError,
};
use aes_spmm::quant::Precision;
use aes_spmm::runtime::Engine;
use aes_spmm::sampling::Strategy;

fn setup(workers: usize, queue: usize, max_batch: usize) -> Option<(Coordinator, Arc<ModelStore>)> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping coordinator integration test: run `make artifacts`");
        return None;
    }
    let engine = Arc::new(Engine::new("artifacts").unwrap());
    let store = Arc::new(
        ModelStore::load("artifacts", &["cora".into()], &["gcn".into()]).unwrap(),
    );
    let coord = Coordinator::start(
        engine,
        store.clone(),
        CoordinatorConfig {
            workers,
            queue_depth: queue,
            batcher: BatcherConfig { max_batch, max_delay: Duration::from_millis(1) },
            ..CoordinatorConfig::default()
        },
    );
    Some((coord, store))
}

fn key(width: usize) -> RouteKey {
    RouteKey {
        model: "gcn".into(),
        dataset: "cora".into(),
        width: Some(width),
        strategy: Strategy::Aes,
        precision: Precision::F32,
    }
}

#[test]
fn answers_are_correct_predictions() {
    let Some((coord, store)) = setup(1, 64, 8) else { return };
    let ds = store.dataset("cora").unwrap();
    // Ask for a handful of *training* nodes — the model fits those well,
    // so predictions should mostly match labels.
    let train_nodes: Vec<usize> =
        (0..ds.n).filter(|&i| ds.train_mask[i] == 1).take(32).collect();
    let resp = coord.infer(key(256), train_nodes.clone()).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.predictions.len(), train_nodes.len());
    let correct = resp
        .predictions
        .iter()
        .filter(|p| p.class == ds.labels[p.node])
        .count();
    assert!(
        correct as f64 / train_nodes.len() as f64 > 0.8,
        "train-node predictions should be mostly right ({correct}/{})",
        train_nodes.len()
    );
    coord.shutdown();
}

#[test]
fn batching_amortizes_same_route_requests() {
    let Some((coord, _store)) = setup(1, 256, 64) else { return };
    // Warm the executable cache so the burst lands in one steady window.
    coord.infer(key(16), vec![0]).unwrap();
    let mut rxs = Vec::new();
    for i in 0..40 {
        let (_, rx) = coord.submit(key(16), vec![i % 100]).unwrap();
        rxs.push(rx);
    }
    let mut max_batch_size = 0;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(resp.error.is_none());
        max_batch_size = max_batch_size.max(resp.batch_size);
    }
    assert!(
        max_batch_size > 1,
        "burst of same-route requests must share forward passes (max batch {max_batch_size})"
    );
    let m = coord.metrics().snapshot();
    assert!(m.batches < 41, "41 requests must not take 41+ executions");
    coord.shutdown();
}

#[test]
fn backpressure_rejects_when_full() {
    let Some((coord, _store)) = setup(1, 2, 1000) else { return };
    // Queue depth 2 and a slow worker: flood until Busy appears.
    let mut busy = false;
    let mut rxs = Vec::new();
    for i in 0..200 {
        match coord.submit(key(16), vec![i]) {
            Ok((_, rx)) => rxs.push(rx),
            Err(SubmitError::Busy) => {
                busy = true;
                break;
            }
            Err(e) => panic!("unexpected: {e}"),
        }
    }
    assert!(busy, "bounded queue must eventually reject");
    assert!(coord.metrics().snapshot().rejected >= 1);
    for rx in rxs {
        let _ = rx.recv_timeout(Duration::from_secs(60));
    }
    coord.shutdown();
}

#[test]
fn bad_route_fails_gracefully() {
    let Some((coord, _store)) = setup(1, 16, 4) else { return };
    let bad = RouteKey {
        model: "gcn".into(),
        dataset: "cora".into(),
        width: Some(999), // no such artifact
        strategy: Strategy::Aes,
        precision: Precision::F32,
    };
    let resp = coord.infer(bad, vec![0]).unwrap();
    assert!(resp.error.is_some(), "unknown width must produce an error reply");
    assert!(coord.metrics().snapshot().failed >= 1);
    // The coordinator keeps serving good routes afterwards.
    let ok = coord.infer(key(16), vec![1]).unwrap();
    assert!(ok.error.is_none());
    coord.shutdown();
}

#[test]
fn mixed_routes_complete() {
    let Some((coord, _store)) = setup(2, 256, 16) else { return };
    let mut rxs = Vec::new();
    for i in 0..24 {
        let w = [16, 64, 256][i % 3];
        let precision = if i % 2 == 0 { Precision::F32 } else { Precision::U8Device };
        let k = RouteKey { precision, ..key(w) };
        let (_, rx) = coord.submit(k, vec![i]).unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.predictions.len(), 1);
    }
    let snap = coord.metrics().snapshot();
    assert_eq!(snap.completed, 24 + snap.failed);
    coord.shutdown();
}
