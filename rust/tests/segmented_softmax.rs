//! Segmented-reduction conformance at the public kernel API — the
//! attention/max-pool counterparts of `tests/simd_kernels.rs`, pinning
//! the docs/models.md contracts from outside the crate:
//!
//! * every vector arm of the GAT softmax pipeline and the SAGE max-pool
//!   is **bitwise-identical** to the scalar arm (remainder widths,
//!   empty rows, single-edge rows, mega-rows included), so runtime
//!   dispatch can never move an attention coefficient;
//! * the segmented softmax is max-subtracted: saturating logits stay
//!   finite and shift-invariant;
//! * row partitioning (`_par`) composes bitwise — the property the
//!   sharded execution path inherits, since shard units cut on row
//!   boundaries exactly like the `_par` chunks here.
//!
//! The grid-level counterpart (forced-scalar runs of the whole suite)
//! is CI's `scalar` job: `AES_SPMM_FORCE_SCALAR=1` pins `simd::level()`
//! process-wide, and the per-model bitwise grid rows prove the scalar
//! configuration serves identical logits.

use aes_spmm::gen;
use aes_spmm::graph::Csr;
use aes_spmm::rng::Pcg32;
use aes_spmm::sampling::{sample_ell, Strategy};
use aes_spmm::spmm::{
    attention_scores, attention_scores_par, gat_alpha_csr, gat_alpha_csr_par, gat_alpha_ell,
    gat_alpha_ell_par, row_softmax, segmented_max_csr, segmented_max_csr_par, segmented_max_ell,
    segmented_max_ell_par, simd,
};

fn graph_and_scores(n: usize, deg: f64, seed: u64) -> (Csr, Vec<f32>, Vec<f32>) {
    let mut rng = Pcg32::new(seed);
    let g = gen::with_self_loops(&gen::chung_lu(n, deg, 1.9, &mut rng));
    let s_src: Vec<f32> = (0..g.n_rows).map(|_| rng.f32() - 0.5).collect();
    let s_dst: Vec<f32> = (0..g.n_cols).map(|_| rng.f32() - 0.5).collect();
    (g, s_src, s_dst)
}

fn assert_bitwise(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i} differs ({x} vs {y})");
    }
}

/// Empty segments are a no-op, single-edge segments are exactly 1.0
/// (not merely close), and saturating logits survive through the max
/// subtraction: `exp(e − m) ≤ 1` always, so a row of ±1e4 logits stays
/// finite and equals its shifted sibling bit for bit.
#[test]
fn softmax_segments_are_stable_at_the_edges() {
    let lvl = simd::level();
    row_softmax(lvl, &mut []);
    let mut one = vec![-3.5f32];
    row_softmax(lvl, &mut one);
    assert_eq!(one[0].to_bits(), 1.0f32.to_bits());

    // Logits a naive exp would overflow (exp(1e4) = inf in f32).
    let mut big = vec![1.0e4f32, 9.999e3, 37.0, -1.0e4];
    row_softmax(lvl, &mut big);
    assert!(big.iter().all(|a| a.is_finite() && *a >= 0.0), "{big:?}");
    let sum: f32 = big.iter().sum();
    assert!((sum - 1.0).abs() < 1e-6, "sum {sum}");
    // Shift invariance is exact: e − m sees identical differences.
    let mut shifted = vec![0.0f32, -1.0, -9.963e3, -2.0e4];
    row_softmax(lvl, &mut shifted);
    assert_bitwise(&big, &shifted, "shifted logits");
}

/// Scalar vs the detected arm on the full α pipeline (scores → logits →
/// softmax), CSR and ELL, plus `_par` at several thread counts — all
/// bitwise, on a graph that keeps empty and single-edge rows.
#[test]
fn alpha_pipeline_dispatches_bitwise_with_degenerate_rows() {
    let lvl = simd::level();
    // Low average degree leaves isolated (empty) and degree-1 rows.
    let (g, s_src, s_dst) = graph_and_scores(350, 1.3, 901);
    assert!((0..g.n_rows).any(|i| g.row_nnz(i) == 0), "fixture lost its empty rows");
    assert!((0..g.n_rows).any(|i| g.row_nnz(i) == 1), "fixture lost its single-edge rows");

    let scalar = gat_alpha_csr(simd::SimdLevel::Scalar, &g, &s_src, &s_dst);
    let vector = gat_alpha_csr(lvl, &g, &s_src, &s_dst);
    assert_bitwise(&scalar, &vector, "alpha csr");
    // Single-edge rows renormalize to exactly 1.
    for i in 0..g.n_rows {
        if g.row_nnz(i) == 1 {
            assert_eq!(scalar[g.row_ptr[i] as usize].to_bits(), 1.0f32.to_bits(), "row {i}");
        }
    }
    for threads in [1usize, 3, 8] {
        let par = gat_alpha_csr_par(lvl, &g, &s_src, &s_dst, threads);
        assert_bitwise(&scalar, &par, &format!("alpha csr par t={threads}"));
    }

    for w in [4usize, 16] {
        let ell = sample_ell(&g, w, Strategy::Aes);
        let scalar = gat_alpha_ell(simd::SimdLevel::Scalar, &ell, &s_src, &s_dst);
        let vector = gat_alpha_ell(lvl, &ell, &s_src, &s_dst);
        assert_bitwise(&scalar, &vector, &format!("alpha ell w={w}"));
        let par = gat_alpha_ell_par(lvl, &ell, &s_src, &s_dst, 5);
        assert_bitwise(&scalar, &par, &format!("alpha ell par w={w}"));
        // Padding slots stay exactly 0.0 (the Ell::validate contract
        // for the substituted plan).
        for i in 0..ell.n_rows {
            for k in ell.slots[i] as usize..w {
                assert_eq!(scalar[i * w + k].to_bits(), 0.0f32.to_bits(), "pad ({i},{k})");
            }
        }
    }
}

/// Per-node attention scores dispatch and thread bitwise across feature
/// widths that exercise full vector blocks, remainder lanes, and the
/// width-1 degenerate case.
#[test]
fn attention_scores_thread_bitwise_across_widths() {
    let mut rng = Pcg32::new(77);
    for d in [1usize, 3, 7, 8, 9, 16, 33] {
        let n = 217;
        let h: Vec<f32> = (0..n * d).map(|_| rng.f32() - 0.5).collect();
        let a: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
        let serial = attention_scores(&h, &a, n, d);
        for threads in [1usize, 4, 9] {
            let par = attention_scores_par(&h, &a, n, d, threads);
            assert_bitwise(&serial, &par, &format!("scores d={d} t={threads}"));
        }
    }
}

/// The SAGE max-pool dispatches bitwise across remainder feature
/// widths on CSR, ELL, and both `_par` variants; empty rows emit
/// exactly 0.0 in every arm.
#[test]
fn max_pool_dispatches_bitwise_across_widths() {
    let lvl = simd::level();
    let mut rng = Pcg32::new(31);
    let g = gen::with_self_loops(&gen::chung_lu(200, 7.0, 1.9, &mut rng));
    for f in [1usize, 3, 7, 8, 9, 16, 33] {
        let b: Vec<f32> = (0..g.n_cols * f).map(|_| rng.f32() - 0.5).collect();
        let mut scalar = vec![0.0f32; g.n_rows * f];
        let mut vector = vec![9.0f32; g.n_rows * f];
        segmented_max_csr(simd::SimdLevel::Scalar, &g, &b, f, &mut scalar);
        segmented_max_csr(lvl, &g, &b, f, &mut vector);
        assert_bitwise(&scalar, &vector, &format!("max csr f={f}"));
        let mut par = vec![9.0f32; g.n_rows * f];
        segmented_max_csr_par(lvl, &g, &b, f, &mut par, 5);
        assert_bitwise(&scalar, &par, &format!("max csr par f={f}"));

        let ell = sample_ell(&g, 8, Strategy::Aes);
        let mut scalar = vec![0.0f32; g.n_rows * f];
        let mut vector = vec![9.0f32; g.n_rows * f];
        segmented_max_ell(simd::SimdLevel::Scalar, &ell, &b, f, &mut scalar);
        segmented_max_ell(lvl, &ell, &b, f, &mut vector);
        assert_bitwise(&scalar, &vector, &format!("max ell f={f}"));
        let mut par = vec![9.0f32; g.n_rows * f];
        segmented_max_ell_par(lvl, &ell, &b, f, &mut par, 3);
        assert_bitwise(&scalar, &par, &format!("max ell par f={f}"));
    }
}

/// One row holding 40_000 edges — a segment longer than any staging
/// tile or flush interval in the SpMM core. The softmax stays a single
/// storage-order pass: scalar and vector arms agree bitwise, the
/// coefficients are a probability vector despite 40k-term fp32 sums.
#[test]
fn mega_row_softmax_is_dispatch_invariant_and_normalized() {
    let lvl = simd::level();
    let nnz = 40_000usize;
    let n_cols = 512usize;
    let mut rng = Pcg32::new(402);
    let col_ind: Vec<i32> = (0..nnz).map(|_| rng.usize_below(n_cols) as i32).collect();
    let g = Csr::new(1, n_cols, vec![0, nnz as i32], col_ind, vec![1.0; nnz]).unwrap();
    let s_src = vec![0.25f32];
    let s_dst: Vec<f32> = (0..n_cols).map(|_| 8.0 * (rng.f32() - 0.5)).collect();

    let scalar = gat_alpha_csr(simd::SimdLevel::Scalar, &g, &s_src, &s_dst);
    let vector = gat_alpha_csr(lvl, &g, &s_src, &s_dst);
    assert_bitwise(&scalar, &vector, "mega-row alpha");
    for threads in [2usize, 7] {
        let par = gat_alpha_csr_par(lvl, &g, &s_src, &s_dst, threads);
        assert_bitwise(&scalar, &par, &format!("mega-row alpha par t={threads}"));
    }
    assert!(scalar.iter().all(|a| a.is_finite() && *a >= 0.0));
    let sum: f64 = scalar.iter().map(|&a| a as f64).sum();
    assert!((sum - 1.0).abs() < 1e-2, "mega-row alpha sum {sum}");

    // The max-pool over the same segment dispatches bitwise too.
    let f = 9usize;
    let b: Vec<f32> = (0..n_cols * f).map(|_| rng.f32() - 0.5).collect();
    let mut s = vec![0.0f32; f];
    let mut v = vec![0.0f32; f];
    segmented_max_csr(simd::SimdLevel::Scalar, &g, &b, f, &mut s);
    segmented_max_csr(lvl, &g, &b, f, &mut v);
    assert_bitwise(&s, &v, "mega-row max pool");
}
