//! Integration tests for the exec layer through the full serving stack —
//! runnable with **no artifacts and no PJRT runtime**: a synthetic
//! dataset + trained-shape weights are written as `.nbt`, and the
//! coordinator runs on [`Backend::Host`] (dispatched CPU kernels).
//!
//! Covers the acceptance criteria of the exec-layer refactor and the
//! streaming feature pipeline:
//! * warm routes never touch the feature store (load count stays flat);
//! * the persistent pool serves every batch with a constant thread pool;
//! * host-backend answers match a direct substrate forward (including
//!   INT8 routes streamed zero-copy off the mmap);
//! * invalidation forces exactly one reload;
//! * with prefetch enabled, a warmed route serves with zero
//!   feature-store reads and the staged bytes land in the monotonic
//!   `LoadTotals`.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use aes_spmm::coordinator::{
    BatcherConfig, Coordinator, CoordinatorConfig, ModelStore, RouteKey,
};
use aes_spmm::gen;
use aes_spmm::quant::{quantize, Precision, QuantParams};
use aes_spmm::rng::Pcg32;
use aes_spmm::runtime::{host_forward, Backend, Dataset, Weights};
use aes_spmm::sampling::Strategy;
use aes_spmm::tensor::{write_nbt, NbtFile, Tensor};
use aes_spmm::util::argmax_f32;

const N: usize = 96;
const FEATS: usize = 12;
const HIDDEN: usize = 8;
const CLASSES: usize = 5;

fn rand_tensor(rng: &mut Pcg32, shape: &[usize]) -> Tensor {
    let len: usize = shape.iter().product();
    let vals: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
    Tensor::from_f32(shape, &vals)
}

/// Write `data_{name}.nbt` + `weights_gcn_{name}.nbt` with every key the
/// loaders require, and return the artifacts dir.
fn synthetic_artifacts(tag: &str, name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("exec_layer_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let mut rng = Pcg32::new(0xBEEF);

    let g = gen::with_self_loops(&gen::chung_lu(N, 6.0, 2.0, &mut rng)).gcn_normalized();
    let nnz = g.nnz();
    let feat: Vec<f32> = (0..N * FEATS).map(|_| rng.f32() - 0.5).collect();
    let params = QuantParams::of(&feat);
    let labels: Vec<i32> = (0..N).map(|_| rng.usize_below(CLASSES) as i32).collect();
    let train_mask: Vec<u8> = (0..N).map(|_| (rng.f32() < 0.5) as u8).collect();

    let mut nbt = NbtFile::new();
    nbt.insert(
        "meta",
        Tensor::from_i64(&[4], &[N as i64, nnz as i64, FEATS as i64, CLASSES as i64]),
    );
    nbt.insert("row_ptr", Tensor::from_i32(&[N + 1], &g.row_ptr));
    nbt.insert("col_ind", Tensor::from_i32(&[nnz], &g.col_ind));
    nbt.insert("val_gcn", Tensor::from_f32(&[nnz], &g.val));
    nbt.insert("val_ones", Tensor::from_f32(&[nnz], &vec![1.0f32; nnz]));
    nbt.insert("feat", Tensor::from_f32(&[N, FEATS], &feat));
    nbt.insert("featq", Tensor::from_u8(&[N, FEATS], &quantize(&feat, params)));
    nbt.insert("qrange", Tensor::from_f32(&[2], &[params.x_min, params.x_max]));
    nbt.insert("labels", Tensor::from_i32(&[N], &labels));
    nbt.insert("train_mask", Tensor::from_u8(&[N], &train_mask));
    write_nbt(dir.join(format!("data_{name}.nbt")), &nbt).unwrap();

    let mut w = NbtFile::new();
    w.insert("w0", rand_tensor(&mut rng, &[FEATS, HIDDEN]));
    w.insert("b0", rand_tensor(&mut rng, &[HIDDEN]));
    w.insert("w1", rand_tensor(&mut rng, &[HIDDEN, CLASSES]));
    w.insert("b1", rand_tensor(&mut rng, &[CLASSES]));
    w.insert("ideal_acc", Tensor::from_f32(&[1], &[0.5]));
    write_nbt(dir.join(format!("weights_gcn_{name}.nbt")), &w).unwrap();
    dir
}

fn start_host_coordinator(
    dir: &Path,
    name: &str,
    workers: usize,
) -> (Coordinator, Arc<ModelStore>) {
    let store =
        Arc::new(ModelStore::load(dir, &[name.to_string()], &["gcn".to_string()]).unwrap());
    let coord = Coordinator::start_with(
        Backend::Host,
        store.clone(),
        CoordinatorConfig {
            workers,
            queue_depth: 128,
            batcher: BatcherConfig { max_batch: 8, max_delay: Duration::from_millis(1) },
            plan_cache_capacity: 16,
            prefetch_workers: 1,
            ..CoordinatorConfig::default()
        },
    );
    (coord, store)
}

fn key(name: &str, width: Option<usize>, precision: Precision) -> RouteKey {
    RouteKey {
        model: "gcn".into(),
        dataset: name.into(),
        width,
        strategy: Strategy::Aes,
        precision,
    }
}

/// The headline acceptance test: repeated `infer` calls on one RouteKey
/// must hit storage exactly once — warm batches serve from the plan
/// cache.
#[test]
fn warm_route_never_rereads_features() {
    let dir = synthetic_artifacts("warm", "tiny");
    let (coord, store) = start_host_coordinator(&dir, "tiny", 2);
    let fstore = store.feature_store("tiny").unwrap();
    assert_eq!(fstore.load_count(), 0);

    let route = key("tiny", Some(4), Precision::F32);
    for i in 0..6 {
        let resp = coord.infer(route.clone(), vec![i, i + 1]).unwrap();
        assert!(resp.error.is_none(), "round {i}: {:?}", resp.error);
        assert_eq!(resp.predictions.len(), 2);
        assert_eq!(
            fstore.load_count(),
            1,
            "round {i}: warm route must not hit the feature store again"
        );
    }

    // A different precision is a different plan → exactly one more load.
    let resp = coord.infer(key("tiny", Some(4), Precision::U8Device), vec![0]).unwrap();
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(fstore.load_count(), 2);

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.plan_misses, 2, "one cold build per distinct plan");
    assert!(snap.plan_hits >= 5, "warm batches must be cache hits (got {})", snap.plan_hits);
    assert!(snap.failed == 0);
    coord.shutdown();
}

/// Coordinator answers must equal a direct host-substrate forward (no
/// cached plan) — same sampling plan, same dispatched kernels, same
/// argmax.
#[test]
fn host_backend_matches_direct_forward() {
    let dir = synthetic_artifacts("match", "tiny");
    let ds = Dataset::load(&dir, "tiny").unwrap();
    let weights = Weights::load(&dir, "gcn", "tiny").unwrap();
    let (coord, _store) = start_host_coordinator(&dir, "tiny", 2);

    for (width, precision) in [
        (Some(4), Precision::F32),
        (Some(16), Precision::F32),
        (None, Precision::F32),
        (Some(4), Precision::U8Device),
    ] {
        let route = key("tiny", width, precision);
        let nodes: Vec<usize> = (0..N).step_by(7).collect();
        let resp = coord.infer(route.clone(), nodes.clone()).unwrap();
        assert!(resp.error.is_none(), "{width:?}/{precision:?}: {:?}", resp.error);

        let features = match precision {
            Precision::F32 => None,
            _ => Some(&ds.featq),
        };
        let env = aes_spmm::exec::ExecEnv::with_threads(1);
        let direct =
            host_forward(&ds, &weights, &route.to_forward(), features, None, &env).unwrap();
        let logits = direct.logits.as_f32().unwrap();
        for p in &resp.predictions {
            let want = argmax_f32(&logits[p.node * CLASSES..(p.node + 1) * CLASSES]) as i32;
            assert_eq!(p.class, want, "node {} under {width:?}/{precision:?}", p.node);
        }
    }
    coord.shutdown();
}

/// The batch pool is spawned once: its worker count never changes across
/// load, and a burst of same-route requests shares forward passes.
#[test]
fn pool_stays_constant_and_batches_amortize() {
    let dir = synthetic_artifacts("pool", "tiny");
    let (coord, _store) = start_host_coordinator(&dir, "tiny", 3);
    assert_eq!(coord.pool_workers(), 3);

    // Warm the route so the burst lands in a steady window.
    coord.infer(key("tiny", Some(4), Precision::F32), vec![0]).unwrap();

    let mut rxs = Vec::new();
    for i in 0..40 {
        let (_, rx) = coord.submit(key("tiny", Some(4), Precision::F32), vec![i % N]).unwrap();
        rxs.push(rx);
    }
    let mut max_batch = 0usize;
    for rx in rxs {
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        max_batch = max_batch.max(resp.batch_size);
    }
    assert!(max_batch > 1, "same-route burst must share forward passes (max {max_batch})");
    assert_eq!(coord.pool_workers(), 3, "pool must not re-spawn under load");

    let snap = coord.metrics().snapshot();
    assert!(snap.batches < 41, "41 requests must not take 41+ executions");
    assert_eq!(snap.completed, 41);
    coord.shutdown();
}

/// Invalidation drops the dataset's cached plans; the next batch on the
/// route reloads exactly once and then stays warm again.
#[test]
fn invalidation_forces_one_reload() {
    let dir = synthetic_artifacts("invalidate", "tiny");
    let (coord, store) = start_host_coordinator(&dir, "tiny", 2);
    let fstore = store.feature_store("tiny").unwrap();

    let route = key("tiny", Some(4), Precision::F32);
    coord.infer(route.clone(), vec![0]).unwrap();
    coord.infer(route.clone(), vec![1]).unwrap();
    assert_eq!(fstore.load_count(), 1);
    assert_eq!(coord.plan_cache_len(), 1);

    assert!(coord.invalidate_route(&route));
    assert!(!coord.invalidate_route(&route), "second invalidate finds nothing");
    coord.infer(route.clone(), vec![2]).unwrap();
    assert_eq!(fstore.load_count(), 2, "invalidated route must reload exactly once");
    coord.infer(route, vec![3]).unwrap();
    assert_eq!(fstore.load_count(), 2, "and then stay warm again");
    coord.shutdown();
}

/// The streaming-pipeline acceptance test: an explicitly prefetched
/// route performs its one storage read on the prefetch pool, and serving
/// it afterwards triggers **zero** feature-store reads — every batch is
/// a plan-cache hit over the staged row-block handle, and the bytes the
/// streamed forwards dequantize are charged to the store's monotonic
/// totals.
#[test]
fn prefetched_route_serves_with_zero_feature_store_reads() {
    let dir = synthetic_artifacts("prefetch", "tiny");
    let (coord, store) = start_host_coordinator(&dir, "tiny", 2);
    let fstore = store.feature_store("tiny").unwrap();

    let route = key("tiny", Some(4), Precision::U8Device);
    assert!(coord.prefetch_route(&route), "cold route must schedule a build");
    assert!(!coord.prefetch_route(&route), "second request coalesces");
    coord.wait_prefetch_idle();
    assert_eq!(fstore.load_count(), 1, "the prefetcher performed the one cold read");
    let staged_before = fstore.totals().bytes_read;

    for i in 0..4 {
        let resp = coord.infer(route.clone(), vec![i]).unwrap();
        assert!(resp.error.is_none(), "round {i}: {:?}", resp.error);
    }
    assert_eq!(fstore.load_count(), 1, "warm route + prefetch = zero feature-store reads");

    let snap = coord.metrics().snapshot();
    assert_eq!(snap.plan_misses, 1, "the only build ran on the prefetch pool");
    assert_eq!(snap.plan_hits, 4, "every batch served from the cached plan");
    let stats = coord.prefetch_stats();
    assert_eq!(stats.scheduled, 1);
    assert_eq!(stats.completed, 1);
    assert!(stats.coalesced >= 5, "explicit re-prefetch + submit-path peeks coalesce");

    // If this platform streams (mmap available), each forward dequantized
    // the whole INT8 feature payload lazily — visible in the totals.
    let streamed = fstore.totals().bytes_read - staged_before;
    if fstore.source() == aes_spmm::quant::LoadSource::Mmap {
        assert_eq!(streamed, (4 * N * FEATS) as u64, "4 forwards × n×f quantized bytes");
    }
    coord.shutdown();
}

/// Exact (unsampled) routes flow through the same plan cache and the
/// dispatched exact kernels.
#[test]
fn exact_route_serves_and_caches() {
    let dir = synthetic_artifacts("exact", "tiny");
    let (coord, store) = start_host_coordinator(&dir, "tiny", 2);
    let fstore = store.feature_store("tiny").unwrap();

    for i in 0..3 {
        let resp = coord.infer(key("tiny", None, Precision::F32), vec![i]).unwrap();
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.predictions.len(), 1);
        let class = resp.predictions[0].class;
        assert!((0..CLASSES as i32).contains(&class));
    }
    assert_eq!(fstore.load_count(), 1);
    coord.shutdown();
}

/// Unknown routes fail gracefully and do not poison the cache or pool.
#[test]
fn bad_route_fails_gracefully_on_host() {
    let dir = synthetic_artifacts("bad", "tiny");
    let (coord, _store) = start_host_coordinator(&dir, "tiny", 2);

    let missing = key("nope", Some(4), Precision::F32);
    let resp = coord.infer(missing, vec![0]).unwrap();
    assert!(resp.error.is_some(), "unknown dataset must produce an error reply");

    // sage is not implemented on the host backend → error reply, not a hang.
    let mut sage = key("tiny", Some(4), Precision::F32);
    sage.model = "sage".into();
    let resp = coord.infer(sage, vec![0]).unwrap();
    assert!(resp.error.is_some());

    // The coordinator keeps serving good routes afterwards.
    let ok = coord.infer(key("tiny", Some(4), Precision::F32), vec![1]).unwrap();
    assert!(ok.error.is_none(), "{:?}", ok.error);
    assert!(coord.metrics().snapshot().failed >= 2);
    coord.shutdown();
}
