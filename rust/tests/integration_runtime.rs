//! Integration tests over the real AOT artifacts: load → compile →
//! execute through PJRT, and cross-check the numerics against the rust
//! CPU substrate (sampling planner + SpMM + dense MLP re-implementation).
//!
//! These tests require `make artifacts`; they are skipped (not failed)
//! when the artifacts directory is absent so `cargo test` works on a
//! fresh checkout.

use aes_spmm::quant::Precision;
use aes_spmm::runtime::{accuracy, run_forward, Dataset, Engine, ForwardRequest, Weights};
use aes_spmm::sampling::{sample_ell, Strategy};
use aes_spmm::spmm::ell_spmm;

fn artifacts_dir() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping integration test: run `make artifacts` first");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn manifest_loads_and_is_complete() {
    let dir = require_artifacts!();
    let engine = Engine::new(dir).unwrap();
    let m = engine.manifest();
    assert_eq!(m.datasets.len(), 6, "six benchmark datasets (Table 2)");
    // Every dataset × model × width must have sampled + quantized + baseline.
    for ds in m.datasets.keys() {
        for model in ["gcn", "sage"] {
            assert!(m.artifacts.contains_key(&format!("baseline_{model}_{ds}")));
            for w in &m.widths {
                assert!(m.artifacts.contains_key(&format!("model_{model}_{ds}_w{w}")));
                assert!(m.artifacts.contains_key(&format!("qmodel_{model}_{ds}_w{w}")));
            }
        }
    }
}

#[test]
fn dataset_consistency() {
    let dir = require_artifacts!();
    let engine = Engine::new(dir).unwrap();
    for name in engine.manifest().dataset_names() {
        let ds = Dataset::load(dir, &name).unwrap();
        ds.csr_gcn.validate().unwrap();
        assert_eq!(ds.labels.len(), ds.n);
        assert_eq!(ds.feat.shape, vec![ds.n, ds.feats]);
        assert_eq!(ds.featq.shape, vec![ds.n, ds.feats]);
        assert_eq!(ds.val_ones.len(), ds.nnz);
        // Self-loops present (GCN's A+I) ⇒ no empty rows.
        for i in 0..ds.n {
            assert!(ds.csr_gcn.row_nnz(i) >= 1, "{name}: node {i} has no edges");
        }
        // Quantized features reconstruct within the Eq. 2 bound.
        let q = ds.featq.as_u8().unwrap();
        let x = ds.feat.as_f32().unwrap();
        let bound = aes_spmm::quant::max_quant_error(ds.qparams) + 1e-5;
        for (qi, xi) in q.iter().zip(x.iter()).step_by(97) {
            let back =
                *qi as f32 * (ds.qparams.x_max - ds.qparams.x_min) / 255.0 + ds.qparams.x_min;
            assert!((back - xi).abs() <= bound);
        }
    }
}

/// The decisive numerics check: run the *sampled GCN artifact* (Pallas
/// sampling kernel inside) and reproduce its logits with the rust-side
/// substrate: plan → ELL → SpMM → dense MLP, layer by layer.
#[test]
fn pjrt_artifact_matches_rust_substrate() {
    let dir = require_artifacts!();
    let engine = Engine::new(dir).unwrap();
    let ds = Dataset::load(dir, "cora").unwrap();
    let weights = Weights::load(dir, "gcn", "cora").unwrap();
    let width = 16;
    let strategy = Strategy::Aes;

    let req = ForwardRequest {
        model: "gcn".into(),
        dataset: "cora".into(),
        width: Some(width),
        strategy,
        precision: Precision::F32,
    };
    let result = run_forward(&engine, &ds, &weights, &req, None).unwrap();
    let got = result.logits.as_f32().unwrap();

    // rust substrate forward: logits = agg(relu(agg(X W0)+b0) W1)+b1
    let w0 = weights.tensors[0].1.as_f32().unwrap();
    let b0 = weights.tensors[1].1.as_f32().unwrap();
    let w1 = weights.tensors[2].1.as_f32().unwrap();
    let b1 = weights.tensors[3].1.as_f32().unwrap();
    let (n, f, h, c) = (ds.n, ds.feats, b0.len(), ds.classes);

    let matmul = |a: &[f32], b: &[f32], m: usize, k: usize, nn: usize| {
        let mut out = vec![0.0f32; m * nn];
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                for j in 0..nn {
                    out[i * nn + j] += av * b[kk * nn + j];
                }
            }
        }
        out
    };

    let x = ds.feat.as_f32().unwrap();
    let xw = matmul(x, w0, n, f, h);
    let ell = sample_ell(&ds.csr_gcn, width, strategy);
    let mut agg1 = vec![0.0f32; n * h];
    ell_spmm(&ell, &xw, h, &mut agg1);
    for i in 0..n {
        for j in 0..h {
            agg1[i * h + j] = (agg1[i * h + j] + b0[j]).max(0.0);
        }
    }
    let hw = matmul(&agg1, w1, n, h, c);
    let mut logits = vec![0.0f32; n * c];
    ell_spmm(&ell, &hw, c, &mut logits);
    for i in 0..n {
        for j in 0..c {
            logits[i * c + j] += b1[j];
        }
    }

    let mut max_err = 0.0f32;
    for (a, b) in got.iter().zip(logits.iter()) {
        max_err = max_err.max((a - b).abs() / (1.0 + a.abs().max(b.abs())));
    }
    assert!(max_err < 2e-3, "PJRT vs rust substrate relative error {max_err}");
}

#[test]
fn strategies_differ_and_full_width_matches_baseline() {
    let dir = require_artifacts!();
    let engine = Engine::new(dir).unwrap();
    let ds = Dataset::load(dir, "proteins").unwrap();
    let weights = Weights::load(dir, "gcn", "proteins").unwrap();

    let run = |width: Option<usize>, strategy: Strategy| {
        let req = ForwardRequest {
            model: "gcn".into(),
            dataset: "proteins".into(),
            width,
            strategy,
            precision: Precision::F32,
        };
        let r = run_forward(&engine, &ds, &weights, &req, None).unwrap();
        accuracy(&ds, &r.logits).unwrap()
    };

    let ideal = run(None, Strategy::Aes);
    let sfs16 = run(Some(16), Strategy::Sfs);
    let aes256 = run(Some(256), Strategy::Aes);
    // Heavy sampling at W=16 must hurt a high-degree graph; AES at 256
    // must sit within 3pp of exact (the paper's tolerance story).
    assert!(ideal - sfs16 > 0.05, "SFS W=16 should lose >5pp (got {ideal} vs {sfs16})");
    assert!(ideal - aes256 < 0.03, "AES W=256 within 3pp (got {ideal} vs {aes256})");
}

#[test]
fn quantized_artifact_close_to_f32() {
    let dir = require_artifacts!();
    let engine = Engine::new(dir).unwrap();
    let ds = Dataset::load(dir, "pubmed").unwrap();
    let weights = Weights::load(dir, "gcn", "pubmed").unwrap();
    let mk = |precision| ForwardRequest {
        model: "gcn".into(),
        dataset: "pubmed".into(),
        width: Some(64),
        strategy: Strategy::Aes,
        precision,
    };
    let f32_acc = accuracy(
        &ds,
        &run_forward(&engine, &ds, &weights, &mk(Precision::F32), None).unwrap().logits,
    )
    .unwrap();
    let q_acc = accuracy(
        &ds,
        &run_forward(&engine, &ds, &weights, &mk(Precision::U8Device), None).unwrap().logits,
    )
    .unwrap();
    assert!(
        (f32_acc - q_acc).abs() < 0.01,
        "quantization delta must be <1pp: f32 {f32_acc} vs int8 {q_acc}"
    );
}

#[test]
fn engine_rejects_malformed_inputs() {
    let dir = require_artifacts!();
    let engine = Engine::new(dir).unwrap();
    let name = "model_gcn_cora_w16";
    // No inputs at all.
    assert!(engine.execute(name, &[]).is_err());
    // Unknown artifact.
    assert!(engine.execute("model_nope", &[]).is_err());
}
