//! Host-side forward pass — the rust substrate (sampling planner + SpMM
//! + dense MLP) promoted from a test-only cross-check to a first-class
//! execution backend.
//!
//! Aggregations route through [`crate::exec`]'s kernel dispatch, so the
//! same adaptive choice (naive / row-cache / parallel / ELL) serves the
//! CPU path that the compiled artifacts' fused kernel serves on device;
//! dense multiplies row-chunk across the same persistent pool. When the
//! coordinator passes a cached [`ExecPlan`], both the sampled ELL and
//! the graph profile come from the cache — no per-batch re-sampling or
//! re-profiling. This keeps the full serving stack runnable (and
//! testable end to end) on machines without a PJRT runtime.
//!
//! Numerics contract: on the exact fp32 path this forward is
//! bit-identical to [`crate::eval::oracle_forward`]'s canonical
//! reduction order at any thread count — every exact kernel, thread
//! chunk, and shard cut preserves per-row FP order, and the conformance
//! grid (`crate::eval`) checks the equality through the coordinator.

use std::ops::Range;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::exec::{
    run_ell, run_ell_i8, run_exact, run_exact_i8, select_kernel, select_kernel_i8, AdjQuantPlan,
    ExecEnv, ExecPlan, GraphProfile, ShardedPlan, PAR_MIN_FLOPS,
};
use crate::graph::Ell;
use crate::quant::{dequantize, ChunkedParams, FeatureHandle, Features, Precision};
use crate::sampling::sample_ell_par;
use crate::spmm::AdjQuant;
use crate::tensor::{DType, Tensor};

use super::dataset::{Dataset, Weights};
use super::engine::ExecStats;
use super::infer::{ForwardRequest, ForwardResult};

/// Multiply rows `row0..row0 + out_chunk.len()/n` of `A` into
/// `out_chunk`, skipping zero A entries (hidden activations are
/// sparse-ish after ReLU). The single inner loop every dense path —
/// thread-chunked, shard-chunked, streamed — shares, so per-row FP order
/// is identical regardless of how the rows were partitioned.
fn matmul_rows(a: &[f32], b: &[f32], k: usize, n: usize, row0: usize, out_chunk: &mut [f32]) {
    for (r, orow) in out_chunk.chunks_mut(n).enumerate() {
        let i = row0 + r;
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &x) in orow.iter_mut().zip(brow.iter()) {
                *o += av * x;
            }
        }
    }
}

/// Row-major `A[m,k] × B[k,n]`. Row chunks run on the persistent pool
/// when the flop count repays the fork-join.
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, env: &ExecEnv) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    let chunk_rows = if env.threads > 1 && flops >= PAR_MIN_FLOPS {
        m.div_ceil(env.threads).max(1)
    } else {
        m
    };
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(chunk_rows * n)
        .enumerate()
        .map(|(chunk_idx, out_chunk)| {
            Box::new(move || {
                matmul_rows(a, b, k, n, chunk_idx * chunk_rows, out_chunk);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
    out
}

/// Dense multiply with row chunks aligned to shard boundaries — one pool
/// task per shard, so the dense layers' working sets track the same
/// partition as the sharded aggregation. Per-row FP order (and therefore
/// the result) is identical to [`matmul`]; single-shard bound lists and
/// multiplies too small to repay the per-shard fork-join (the same
/// [`PAR_MIN_FLOPS`] gate the other dense paths use) fall back to the
/// thread-chunked path.
fn matmul_sharded(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bounds: &[Range<usize>],
    env: &ExecEnv,
) -> Vec<f32> {
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    if m == 0 || n == 0 || bounds.len() <= 1 || env.threads <= 1 || flops < PAR_MIN_FLOPS {
        return matmul(a, b, m, k, n, env);
    }
    let mut out = vec![0.0f32; m * n];
    let mut rest: &mut [f32] = &mut out;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(bounds.len());
    for rows in bounds {
        let (chunk, tail) = rest.split_at_mut(rows.len() * n);
        rest = tail;
        let row0 = rows.start;
        tasks.push(Box::new(move || {
            matmul_rows(a, b, k, n, row0, chunk);
        }));
    }
    crate::exec::global_pool().run(tasks);
    out
}

/// Layer-1 multiply over a streamed feature handle: each row chunk
/// dequantizes its own INT8 block into a chunk-local scratch buffer and
/// multiplies — dequantization is lazy, per row-block, inside the exec
/// worker, and the fp32 feature matrix never materializes whole. With
/// `bounds` (a sharded plan's row cuts), chunks align to the shard
/// boundaries instead of the thread heuristic, so each shard's feature
/// block stages exactly once per forward. Inner loops mirror [`matmul`]
/// exactly, so per-row FP order (and therefore the result) is identical
/// to the eager path given the same dequantized values — chunked either
/// way.
fn matmul_streamed(
    fh: &FeatureHandle,
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    env: &ExecEnv,
    bounds: Option<&[Range<usize>]>,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    // Row cuts: shard boundaries when sharded, else the thread
    // heuristic. Shard bounds are honored regardless of flop count —
    // unlike `matmul_sharded`'s fallback, the cut here also decides
    // which feature blocks get staged together, and per-shard staging
    // is the point of the partition; the total staged bytes are the
    // same either way.
    let cuts: Vec<Range<usize>> = match bounds {
        Some(bs) if bs.len() > 1 => bs.to_vec(),
        _ => {
            let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
            let chunk_rows = if env.threads > 1 && flops >= PAR_MIN_FLOPS {
                m.div_ceil(env.threads).max(1)
            } else {
                m
            };
            (0..m.div_ceil(chunk_rows))
                .map(|c| c * chunk_rows..((c + 1) * chunk_rows).min(m))
                .collect()
        }
    };
    let mut rest: &mut [f32] = &mut out;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(cuts.len());
    for rows in cuts {
        let (out_chunk, tail) = rest.split_at_mut(rows.len() * n);
        rest = tail;
        tasks.push(Box::new(move || {
            let mut xbuf = vec![0.0f32; rows.len() * k];
            fh.fill_rows_f32(rows.start, &mut xbuf);
            for (r, orow) in out_chunk.chunks_mut(n).enumerate() {
                let arow = &xbuf[r * k..(r + 1) * k];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &x) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * x;
                    }
                }
            }
        }));
    }
    crate::exec::global_pool().run(tasks);
    out
}

/// Run one full-graph GCN forward on the host:
/// `logits = Â(relu(Â(XW₀)+b₀)W₁)+b₁` with Â either exact or the route's
/// sampled ELL plan. `plan` (from the coordinator's cache) supplies the
/// sampled ELL and the operand profile; without it, a one-shot caller
/// pays one sampling + profiling pass here. When the plan carries a
/// [`ShardedPlan`], both aggregations fan out as per-shard tasks and the
/// dense multiplies chunk along the same shard row cuts
/// (`matmul_sharded`) — output bit-identical to the unsharded path.
///
/// `features` overrides the dataset tensor; a u8 tensor is dequantized
/// host-side with the dataset's Eq. 2 params (the CPU stand-in for the
/// on-device Pallas dequant). When the cached plan carries a
/// [`Features::Streamed`] handle (and no explicit `features` override),
/// layer 1 streams INT8 row-blocks straight off the mmap instead — the
/// `transfer` stat is then near-zero and the lazy dequant time lands
/// inside `execute` (and in the feature store's `LoadTotals`).
pub fn host_forward(
    ds: &Dataset,
    weights: &Weights,
    req: &ForwardRequest,
    features: Option<&Tensor>,
    plan: Option<&ExecPlan>,
    env: &ExecEnv,
) -> Result<ForwardResult> {
    if req.model != "gcn" {
        bail!("host backend implements the gcn forward only (requested {:?})", req.model);
    }

    // Stage the features (the host analog of the transfer stage). The
    // streamed path stages nothing here — blocks flow lazily in layer 1.
    let t0 = Instant::now();
    let streamed: Option<&FeatureHandle> = match (features, plan) {
        (None, Some(p)) => match &p.features {
            Features::Streamed(h) => Some(h),
            _ => None,
        },
        _ => None,
    };
    // True INT8 compute ([`Precision::I8Compute`]): layer 1 feeds the u8
    // codes straight into the `i8×u8→i32` kernels (aggregate-first:
    // `Â ×_i8 X`, then the dense W0), so no fp32 feature block is ever
    // staged. Codes come zero-copy from the plan's streamed handle, from
    // the coordinator's u8 override, or from the dataset's own `featq`
    // for plan-less callers; a dense-only representation (no codes, or a
    // plan without an [`AdjQuantPlan`]) falls back to the fp32 path.
    let i8_codes: Option<&[u8]> = if matches!(req.precision, Precision::I8Compute) {
        match (plan, streamed, features) {
            (Some(p), Some(h), _) if p.adj.is_some() => Some(h.quantized_rows(0, h.n_rows())),
            (Some(p), None, Some(t)) if p.adj.is_some() && t.dtype == DType::U8 => {
                Some(t.as_u8()?)
            }
            (Some(p), None, None) => match (&p.adj, &p.features) {
                (Some(_), Features::Quantized { q, .. }) => Some(q.as_u8()?),
                _ => None,
            },
            (None, _, None) if ds.featq.dtype == DType::U8 => Some(ds.featq.as_u8()?),
            _ => None,
        }
    } else {
        None
    };
    if let Some(qb) = i8_codes {
        if qb.len() != ds.n * ds.feats {
            bail!("quantized payload has {} codes, dataset needs {}", qb.len(), ds.n * ds.feats);
        }
    }
    let dequantized;
    let x: &[f32] = match (streamed, features) {
        (Some(h), _) => {
            if h.n_rows() != ds.n || h.feat_dim() != ds.feats {
                bail!(
                    "streamed features are [{}, {}], dataset needs [{}, {}]",
                    h.n_rows(),
                    h.feat_dim(),
                    ds.n,
                    ds.feats
                );
            }
            &[]
        }
        // Codes route: layer 1 never touches fp32 features.
        _ if i8_codes.is_some() => &[],
        (None, None) => ds.feat.as_f32()?,
        (None, Some(t)) if t.dtype == DType::F32 => t.as_f32()?,
        (None, Some(t)) if t.dtype == DType::U8 => {
            dequantized = dequantize(t.as_u8()?, ds.qparams);
            &dequantized
        }
        (None, Some(t)) => bail!("unsupported feature dtype {:?} for the host backend", t.dtype),
    };
    if streamed.is_none() && i8_codes.is_none() && x.len() != ds.n * ds.feats {
        bail!("feature tensor has {} values, dataset needs {}", x.len(), ds.n * ds.feats);
    }
    let transfer = t0.elapsed();

    let t1 = Instant::now();
    // Aggregation operand + its statistics: cached plan when available,
    // otherwise sampled/profiled once here. A sharded plan supersedes
    // the whole-graph operand — its units carry their own profiles.
    let sharded: Option<&ShardedPlan> = plan.and_then(|p| p.sharded.as_deref());
    let sampled;
    let (ell, profile): (Option<&Ell>, GraphProfile) = match (req.width, plan) {
        _ if sharded.is_some() => (None, plan.expect("sharded implies a plan").profile),
        (None, Some(p)) => (None, p.profile),
        (None, None) => (None, GraphProfile::of(&ds.csr_gcn)),
        (Some(_), Some(p)) if p.ell.is_some() => (p.ell.as_deref(), p.profile),
        (Some(w), _) => {
            let mut e = Ell::zeros(ds.csr_gcn.n_rows, ds.csr_gcn.n_cols, w);
            sample_ell_par(&ds.csr_gcn, w, req.strategy, &mut e, env.threads);
            sampled = e;
            (Some(&sampled), GraphProfile::of_ell(&sampled))
        }
    };
    let width = ell.map(|e| e.width);
    // i8 operand: the plan's cached [`AdjQuantPlan`]; plan-less callers
    // requantize here against the dataset's global Eq. 2 range — one
    // pass over the adjacency, the same cost class as the sampling pass
    // above.
    let local_adj;
    let i8_adj: Option<&AdjQuantPlan> = match (i8_codes, plan) {
        (Some(_), Some(p)) => p.adj.as_deref(),
        (Some(_), None) => {
            let params = ChunkedParams::uniform(ds.n, ds.qparams);
            let aq = match ell {
                Some(e) => AdjQuant::from_ell(e, &params),
                None => AdjQuant::from_csr(&ds.csr_gcn, &params),
            };
            local_adj = AdjQuantPlan { units: vec![aq] };
            Some(&local_adj)
        }
        (None, _) => None,
    };
    let aggregate = |b: &[f32], f_dim: usize, out: &mut [f32]| {
        // Sharded route: independent per-shard tasks, per-shard dispatch,
        // row-concatenation merge.
        if let Some(sp) = sharded {
            sp.run(b, f_dim, out, env);
            return;
        }
        // O(1) per-layer dispatch from the cached profile.
        let kind = select_kernel(&profile, f_dim, width, env);
        match ell {
            Some(e) => run_ell(kind, e, b, f_dim, out, env.threads),
            None => run_exact(kind, &ds.csr_gcn, b, f_dim, out, env.threads),
        }
    };
    // Dense layers chunk along the same row cuts as the shards.
    let shard_bounds = sharded.map(|sp| sp.bounds());

    // Weights in GCN_PARAM_ORDER: w0 [f,h], b0 [h], w1 [h,c], b1 [c].
    let w0 = weights.tensors[0].1.as_f32()?;
    let b0 = weights.tensors[1].1.as_f32()?;
    let w1 = weights.tensors[2].1.as_f32()?;
    let b1 = weights.tensors[3].1.as_f32()?;
    let (n, f, h, c) = (ds.n, ds.feats, b0.len(), ds.classes);
    if w0.len() != f * h || w1.len() != h * c || b1.len() != c {
        bail!("weight shapes inconsistent with dataset dims (f={f}, h={h}, c={c})");
    }

    // Layer 1: agg(X W0) + b0, ReLU. Streamed routes dequantize X lazily
    // per row-block inside the multiply's pool tasks. i8-compute routes
    // flip the order — `(Â ×_i8 X) W0` — so the integer kernels see the
    // raw codes; the two orders compute the same `Â X W0` product, and
    // the flip's FP effect is covered by the mode's accuracy budget
    // (`crate::eval::i8_compute_budget`).
    let mut hidden = if let (Some(qb), Some(adj)) = (i8_codes, i8_adj) {
        let mut agg_x = vec![0.0f32; n * f];
        if let Some(sp) = sharded {
            sp.run_i8(adj, qb, f, &mut agg_x, env);
        } else {
            // Unsharded plans (and the local fallback) carry one operand.
            let aq = &adj.units[0];
            let kind = select_kernel_i8(&profile, f, width, env);
            match ell {
                Some(e) => run_ell_i8(kind, e, aq, qb, f, &mut agg_x, env.threads),
                None => run_exact_i8(kind, &ds.csr_gcn, aq, qb, f, &mut agg_x, env.threads),
            }
        }
        match &shard_bounds {
            Some(bounds) => matmul_sharded(&agg_x, w0, n, f, h, bounds, env),
            None => matmul(&agg_x, w0, n, f, h, env),
        }
    } else {
        let xw = match (streamed, &shard_bounds) {
            (Some(fh), bounds) => matmul_streamed(fh, w0, n, f, h, env, bounds.as_deref()),
            (None, Some(bounds)) => matmul_sharded(x, w0, n, f, h, bounds, env),
            (None, None) => matmul(x, w0, n, f, h, env),
        };
        let mut agg = vec![0.0f32; n * h];
        aggregate(&xw, h, &mut agg);
        agg
    };
    for i in 0..n {
        for j in 0..h {
            hidden[i * h + j] = (hidden[i * h + j] + b0[j]).max(0.0);
        }
    }

    // Layer 2: agg(H W1) + b1.
    let hw = match &shard_bounds {
        Some(bounds) => matmul_sharded(&hidden, w1, n, h, c, bounds, env),
        None => matmul(&hidden, w1, n, h, c, env),
    };
    let mut logits = vec![0.0f32; n * c];
    aggregate(&hw, c, &mut logits);
    for i in 0..n {
        for j in 0..c {
            logits[i * c + j] += b1[j];
        }
    }
    let execute = t1.elapsed();

    Ok(ForwardResult {
        logits: Tensor::from_f32(&[n, c], &logits),
        stats: ExecStats { transfer, execute, fetch: Duration::ZERO },
    })
}

/// Does this request's precision produce a dense-f32-compatible host
/// path? (All current precisions do: u8 dequantizes host-side, and
/// i8-compute consumes the codes directly in the integer kernels.)
pub fn host_supports(req: &ForwardRequest) -> bool {
    req.model == "gcn"
        && matches!(
            req.precision,
            Precision::F32 | Precision::U8Device | Precision::U8Host | Precision::I8Compute
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] × [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let env = ExecEnv::with_threads(1);
        assert_eq!(matmul(&a, &b, 2, 2, 2, &env), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_skips_zeros_correctly() {
        let a = [0.0f32, 2.0, 0.0, 0.0];
        let b = [1.0f32, 1.0, 3.0, -1.0];
        let env = ExecEnv::with_threads(1);
        assert_eq!(matmul(&a, &b, 2, 2, 2, &env), vec![6.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = crate::rng::Pcg32::new(17);
        // 2*m*k*n = 4.2 MFLOP — above PAR_MIN_FLOPS, so the 8-thread env
        // actually chunks; row-parallelism keeps per-row FP order
        // identical to the serial path.
        let (m, k, n) = (256usize, 128usize, 64usize);
        assert!(2 * m * k * n >= PAR_MIN_FLOPS);
        let a: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
        let serial = matmul(&a, &b, m, k, n, &ExecEnv::with_threads(1));
        let par = matmul(&a, &b, m, k, n, &ExecEnv::with_threads(8));
        assert_eq!(serial.len(), par.len());
        for (s, p) in serial.iter().zip(par.iter()) {
            assert!((s - p).abs() <= 1e-6 * (1.0 + s.abs()));
        }
    }

    #[test]
    fn matmul_degenerate_dims() {
        let env = ExecEnv::with_threads(4);
        assert!(matmul(&[], &[], 0, 3, 3, &env).is_empty());
        assert_eq!(matmul(&[1.0, 2.0], &[], 2, 1, 0, &env), Vec::<f32>::new());
    }

    #[test]
    fn streamed_matmul_matches_eager_over_the_same_dequant() {
        use crate::quant::{ChunkedParams, FeatureStore, Features, Precision};
        use crate::tensor::{write_nbt, NbtFile};

        let (m, k, n) = (37usize, 8usize, 5usize);
        let mut rng = crate::rng::Pcg32::new(23);
        let feat: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
        let chunked = ChunkedParams::of_rows(&feat, m, k, 10);
        let q = chunked.quantize_rows(&feat, k);
        let pairs: Vec<f32> = chunked.chunks().iter().flat_map(|p| [p.x_min, p.x_max]).collect();
        let env_p = chunked.envelope();

        let mut nbt = NbtFile::new();
        nbt.insert("feat", Tensor::from_f32(&[m, k], &feat));
        nbt.insert("featq", Tensor::from_u8(&[m, k], &q));
        nbt.insert("qrange", Tensor::from_f32(&[2], &[env_p.x_min, env_p.x_max]));
        nbt.insert("qchunks", Tensor::from_f32(&[chunked.n_chunks(), 2], &pairs));
        let dir = std::env::temp_dir().join(format!("host_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("d.nbt");
        write_nbt(&path, &nbt).unwrap();

        let store = FeatureStore::open(&path).unwrap();
        let (feats, _) = store.stage(Precision::U8Device).unwrap();
        let Features::Streamed(fh) = feats else {
            return; // platform without mmap: streaming is compiled out
        };
        let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
        // Eager reference over the identical per-chunk dequant.
        let mut x = vec![0.0f32; m * k];
        chunked.dequantize_rows_into(&q, 0, k, &mut x);
        for threads in [1usize, 4] {
            let env = ExecEnv::with_threads(threads);
            let want = matmul(&x, &b, m, k, n, &env);
            let got = matmul_streamed(&fh, &b, m, k, n, &env, None);
            assert_eq!(want, got, "streamed layer-1 must be bit-identical ({threads} threads)");
        }
        // Shard-aligned chunking stages per-shard blocks but keeps the
        // result bit-identical too.
        let bounds = [0usize..11, 11..12, 12..30, 30..m];
        let env = ExecEnv::with_threads(4);
        let want = matmul(&x, &b, m, k, n, &env);
        let got = matmul_streamed(&fh, &b, m, k, n, &env, Some(&bounds));
        assert_eq!(want, got, "shard-chunked streamed multiply must be bit-identical");
    }

    #[test]
    fn sharded_matmul_is_bitwise_equal_to_matmul() {
        let mut rng = crate::rng::Pcg32::new(41);
        // Above PAR_MIN_FLOPS so the per-shard fan-out actually runs
        // (smaller multiplies fall back to the thread-chunked path).
        let (m, k, n) = (256usize, 128usize, 64usize);
        assert!(2 * m * k * n >= PAR_MIN_FLOPS);
        let a: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
        let env = ExecEnv::with_threads(4);
        let want = matmul(&a, &b, m, k, n, &env);
        // Uneven shard cuts, including a single-row shard.
        let bounds = [0usize..100, 100..101, 101..200, 200..m];
        let got = matmul_sharded(&a, &b, m, k, n, &bounds, &env);
        assert_eq!(want, got);
        // Single-bound lists fall back to the thread-chunked path.
        let got = matmul_sharded(&a, &b, m, k, n, &[0..m], &env);
        assert_eq!(want, got);
        // Sub-threshold multiplies fall back too — still bitwise equal.
        let (sm, sk, sn) = (19usize, 7usize, 5usize);
        let sa: Vec<f32> = (0..sm * sk).map(|_| rng.f32() - 0.5).collect();
        let sb: Vec<f32> = (0..sk * sn).map(|_| rng.f32() - 0.5).collect();
        let small_bounds = [0usize..4, 4..19];
        let want = matmul(&sa, &sb, sm, sk, sn, &env);
        let got = matmul_sharded(&sa, &sb, sm, sk, sn, &small_bounds, &env);
        assert_eq!(want, got);
    }

    // Full forward correctness is covered in tests/exec_layer.rs, which
    // builds a synthetic dataset + weights and cross-checks predictions
    // through the coordinator.
}
