//! Host-side forward pass — the rust substrate (sampling planner + SpMM
//! + dense MLP) promoted from a test-only cross-check to a first-class
//! execution backend.
//!
//! Since the model-zoo refactor this file is an **interpreter for the
//! layer-graph IR** ([`crate::runtime::ir`]): a model arrives as a
//! `Vec<LayerOp>` and every [`LayerOp::Aggregate`] routes through
//! [`crate::exec`]'s kernel dispatch — plan cache, sharded units, tuned
//! selection, SIMD/INT8 kernels — so GCN, GraphSAGE and GAT all serve
//! through the same machinery instead of private code paths. Dense
//! multiplies row-chunk across the same persistent pool. When the
//! coordinator passes a cached [`ExecPlan`], both the sampled ELL and
//! the graph profile come from the cache — no per-batch re-sampling or
//! re-profiling.
//!
//! Two peepholes keep the interpreted GCN bit-identical to (and as fast
//! as) the pre-IR hard-coded forward:
//!
//! * a `Linear` whose operand is the raw input register streams
//!   row-blocks off the feature handle ([`matmul_streamed`]) or chunks
//!   along shard bounds, exactly like the old layer 1;
//! * on the true-INT8 route, `Linear → Aggregate(Gcn)` over the input
//!   register flips to aggregate-first (`(Â ×_i8 X) W₀`) so the integer
//!   kernels see the raw codes. The flip requires the *GCN* aggregate —
//!   SAGE/GAT programs never trigger it and compute in fp32 over
//!   streamed/dequantized features.
//!
//! Numerics contract: on the exact fp32 path this interpreter is
//! bit-identical to [`crate::eval::oracle_forward`]'s canonical
//! reduction order at any thread count — every exact kernel, thread
//! chunk, and shard cut preserves per-row FP order, and the conformance
//! grid (`crate::eval`) checks the equality through the coordinator,
//! per model.

use std::ops::Range;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::exec::{
    run_ell, run_ell_i8, run_exact, run_exact_i8, select_kernel, select_kernel_i8, AdjQuantPlan,
    ExecEnv, ExecPlan, GraphProfile, ShardedPlan, PAR_MIN_FLOPS,
};
use crate::graph::{Csr, Ell};
use crate::quant::{dequantize, ChunkedParams, FeatureHandle, Features, Precision};
use crate::sampling::{sample_ell_par, strategy_params};
use crate::spmm::segmented::{
    attention_scores_par, gat_alpha_csr, gat_alpha_csr_par, gat_alpha_ell, gat_alpha_ell_par,
    segmented_max_csr, segmented_max_csr_par, segmented_max_ell, segmented_max_ell_par,
};
use crate::spmm::simd;
use crate::spmm::AdjQuant;
use crate::tensor::{DType, Tensor};

use super::dataset::{Dataset, Weights};
use super::engine::ExecStats;
use super::infer::{ForwardRequest, ForwardResult};
use super::ir::{model_ir, validate_weights, AggregateKind, LayerOp};

/// Multiply rows `row0..row0 + out_chunk.len()/n` of `A` into
/// `out_chunk`, skipping zero A entries (hidden activations are
/// sparse-ish after ReLU). The single inner loop every dense path —
/// thread-chunked, shard-chunked, streamed — shares, so per-row FP order
/// is identical regardless of how the rows were partitioned.
fn matmul_rows(a: &[f32], b: &[f32], k: usize, n: usize, row0: usize, out_chunk: &mut [f32]) {
    for (r, orow) in out_chunk.chunks_mut(n).enumerate() {
        let i = row0 + r;
        let arow = &a[i * k..(i + 1) * k];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &x) in orow.iter_mut().zip(brow.iter()) {
                *o += av * x;
            }
        }
    }
}

/// Row-major `A[m,k] × B[k,n]`. Row chunks run on the persistent pool
/// when the flop count repays the fork-join.
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, env: &ExecEnv) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    let chunk_rows = if env.threads > 1 && flops >= PAR_MIN_FLOPS {
        m.div_ceil(env.threads).max(1)
    } else {
        m
    };
    let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(chunk_rows * n)
        .enumerate()
        .map(|(chunk_idx, out_chunk)| {
            Box::new(move || {
                matmul_rows(a, b, k, n, chunk_idx * chunk_rows, out_chunk);
            }) as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    crate::exec::global_pool().run(tasks);
    out
}

/// Dense multiply with row chunks aligned to shard boundaries — one pool
/// task per shard, so the dense layers' working sets track the same
/// partition as the sharded aggregation. Per-row FP order (and therefore
/// the result) is identical to [`matmul`]; single-shard bound lists and
/// multiplies too small to repay the per-shard fork-join (the same
/// [`PAR_MIN_FLOPS`] gate the other dense paths use) fall back to the
/// thread-chunked path.
fn matmul_sharded(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    bounds: &[Range<usize>],
    env: &ExecEnv,
) -> Vec<f32> {
    let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
    if m == 0 || n == 0 || bounds.len() <= 1 || env.threads <= 1 || flops < PAR_MIN_FLOPS {
        return matmul(a, b, m, k, n, env);
    }
    let mut out = vec![0.0f32; m * n];
    let mut rest: &mut [f32] = &mut out;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(bounds.len());
    for rows in bounds {
        let (chunk, tail) = rest.split_at_mut(rows.len() * n);
        rest = tail;
        let row0 = rows.start;
        tasks.push(Box::new(move || {
            matmul_rows(a, b, k, n, row0, chunk);
        }));
    }
    crate::exec::global_pool().run(tasks);
    out
}

/// Input-register multiply over a streamed feature handle: each row
/// chunk dequantizes its own INT8 block into a chunk-local scratch
/// buffer and multiplies — dequantization is lazy, per row-block, inside
/// the exec worker, and the fp32 feature matrix never materializes
/// whole. With `bounds` (a sharded plan's row cuts), chunks align to the
/// shard boundaries instead of the thread heuristic, so each shard's
/// feature block stages exactly once per forward. Inner loops mirror
/// [`matmul`] exactly, so per-row FP order (and therefore the result) is
/// identical to the eager path given the same dequantized values —
/// chunked either way.
fn matmul_streamed(
    fh: &FeatureHandle,
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    env: &ExecEnv,
    bounds: Option<&[Range<usize>]>,
) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    if m == 0 || n == 0 {
        return out;
    }
    // Row cuts: shard boundaries when sharded, else the thread
    // heuristic. Shard bounds are honored regardless of flop count —
    // unlike `matmul_sharded`'s fallback, the cut here also decides
    // which feature blocks get staged together, and per-shard staging
    // is the point of the partition; the total staged bytes are the
    // same either way.
    let cuts: Vec<Range<usize>> = match bounds {
        Some(bs) if bs.len() > 1 => bs.to_vec(),
        _ => {
            let flops = 2usize.saturating_mul(m).saturating_mul(k).saturating_mul(n);
            let chunk_rows = if env.threads > 1 && flops >= PAR_MIN_FLOPS {
                m.div_ceil(env.threads).max(1)
            } else {
                m
            };
            (0..m.div_ceil(chunk_rows))
                .map(|c| c * chunk_rows..((c + 1) * chunk_rows).min(m))
                .collect()
        }
    };
    let mut rest: &mut [f32] = &mut out;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(cuts.len());
    for rows in cuts {
        let (out_chunk, tail) = rest.split_at_mut(rows.len() * n);
        rest = tail;
        tasks.push(Box::new(move || {
            let mut xbuf = vec![0.0f32; rows.len() * k];
            fh.fill_rows_f32(rows.start, &mut xbuf);
            for (r, orow) in out_chunk.chunks_mut(n).enumerate() {
                let arow = &xbuf[r * k..(r + 1) * k];
                for (kk, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[kk * n..(kk + 1) * n];
                    for (o, &x) in orow.iter_mut().zip(brow.iter()) {
                        *o += av * x;
                    }
                }
            }
        }));
    }
    crate::exec::global_pool().run(tasks);
    out
}

/// One value of the IR's two-register machine: the request's raw input
/// features (kept symbolic so `Linear` can stream/flip), or a
/// materialized row-major `[n, dim]` matrix.
enum Value {
    Input,
    Dense(Vec<f32>, usize),
}

/// Run one full-graph forward on the host by interpreting the model's
/// layer-graph IR, with the aggregation operand either exact or the
/// route's sampled ELL plan. `plan` (from the coordinator's cache)
/// supplies the sampled ELL and the operand profile; without it, a
/// one-shot caller pays one sampling + profiling pass here. When the
/// plan carries a [`ShardedPlan`], every aggregation fans out as
/// per-shard tasks and the dense multiplies chunk along the same shard
/// row cuts (`matmul_sharded`) — output bit-identical to the unsharded
/// path for every model (the GAT softmax is row-local; see
/// `docs/models.md`).
///
/// `features` overrides the dataset tensor; a u8 tensor is dequantized
/// host-side with the dataset's Eq. 2 params (the CPU stand-in for the
/// on-device Pallas dequant). When the cached plan carries a
/// [`Features::Streamed`] handle (and no explicit `features` override),
/// input-register multiplies stream INT8 row-blocks straight off the
/// mmap instead — the `transfer` stat is then near-zero and the lazy
/// dequant time lands inside `execute` (and in the feature store's
/// `LoadTotals`).
pub fn host_forward(
    ds: &Dataset,
    weights: &Weights,
    req: &ForwardRequest,
    features: Option<&Tensor>,
    plan: Option<&ExecPlan>,
    env: &ExecEnv,
) -> Result<ForwardResult> {
    let ops = model_ir(&req.model)?;
    if weights.model != req.model {
        bail!("weights are for model {:?}, request wants {:?}", weights.model, req.model);
    }
    // Shape-check the whole program up front: a bad artifact fails here
    // with the tensor's name instead of panicking inside `matmul`.
    validate_weights(&req.model, ds.feats, ds.classes, &weights.tensors)?;
    let tensor = |name: &str| -> Result<&Tensor> {
        weights
            .tensors
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, t)| t)
            .ok_or_else(|| anyhow!("missing weight tensor {name:?} for model {:?}", req.model))
    };
    // The i8 aggregate-first flip needs `Linear → Aggregate(Gcn)` over
    // the input register — resolve that property once.
    let flip_eligible = matches!(
        (ops.first(), ops.get(1)),
        (Some(LayerOp::Linear { .. }), Some(LayerOp::Aggregate { kind: AggregateKind::Gcn }))
    );
    let needs_mean = ops
        .iter()
        .any(|op| matches!(op, LayerOp::Aggregate { kind: AggregateKind::SageMean }));

    // Stage the features (the host analog of the transfer stage). The
    // streamed path stages nothing here — blocks flow lazily inside the
    // input-register multiplies.
    let t0 = Instant::now();
    let streamed: Option<&FeatureHandle> = match (features, plan) {
        (None, Some(p)) => match &p.features {
            Features::Streamed(h) => Some(h),
            _ => None,
        },
        _ => None,
    };
    // True INT8 compute ([`Precision::I8Compute`]): the flip feeds the
    // u8 codes straight into the `i8×u8→i32` kernels (aggregate-first:
    // `Â ×_i8 X`, then the dense W0), so no fp32 feature block is ever
    // staged. Codes come zero-copy from the plan's streamed handle, from
    // the coordinator's u8 override, or from the dataset's own `featq`
    // for plan-less callers; a dense-only representation (no codes, or a
    // plan without an [`AdjQuantPlan`]) — and any program that is not
    // flip-eligible — falls back to the fp32 path.
    let i8_codes: Option<&[u8]> = if matches!(req.precision, Precision::I8Compute) && flip_eligible
    {
        match (plan, streamed, features) {
            (Some(p), Some(h), _) if p.adj.is_some() => Some(h.quantized_rows(0, h.n_rows())),
            (Some(p), None, Some(t)) if p.adj.is_some() && t.dtype == DType::U8 => {
                Some(t.as_u8()?)
            }
            (Some(p), None, None) => match (&p.adj, &p.features) {
                (Some(_), Features::Quantized { q, .. }) => Some(q.as_u8()?),
                _ => None,
            },
            (None, _, None) if ds.featq.dtype == DType::U8 => Some(ds.featq.as_u8()?),
            _ => None,
        }
    } else {
        None
    };
    if let Some(qb) = i8_codes {
        if qb.len() != ds.n * ds.feats {
            bail!("quantized payload has {} codes, dataset needs {}", qb.len(), ds.n * ds.feats);
        }
    }
    let dequantized;
    let x: &[f32] = match (streamed, features) {
        (Some(h), _) => {
            if h.n_rows() != ds.n || h.feat_dim() != ds.feats {
                bail!(
                    "streamed features are [{}, {}], dataset needs [{}, {}]",
                    h.n_rows(),
                    h.feat_dim(),
                    ds.n,
                    ds.feats
                );
            }
            &[]
        }
        // Codes route: the input register never touches fp32 features.
        _ if i8_codes.is_some() => &[],
        (None, None) => ds.feat.as_f32()?,
        (None, Some(t)) if t.dtype == DType::F32 => t.as_f32()?,
        (None, Some(t)) if t.dtype == DType::U8 => {
            dequantized = dequantize(t.as_u8()?, ds.qparams);
            &dequantized
        }
        (None, Some(t)) => bail!("unsupported feature dtype {:?} for the host backend", t.dtype),
    };
    if streamed.is_none() && i8_codes.is_none() && x.len() != ds.n * ds.feats {
        bail!("feature tensor has {} values, dataset needs {}", x.len(), ds.n * ds.feats);
    }
    let transfer = t0.elapsed();

    let t1 = Instant::now();
    // Aggregation operand + its statistics: cached plan when available,
    // otherwise sampled/profiled once here. A sharded plan supersedes
    // the whole-graph operand — its units carry their own profiles.
    let sharded: Option<&ShardedPlan> = plan.and_then(|p| p.sharded.as_deref());
    // SageMean multiplies the all-ones value family; sampling is
    // structure-only, so the ones operand shares the GCN structure with
    // the values swapped. Built only when a host-local operand will
    // actually read values (a cached plan's ELL / shard units already
    // carry family values, and GAT/max ignore them).
    let ones_csr: Option<Csr> = if needs_mean
        && sharded.is_none()
        && !matches!((req.width, plan), (Some(_), Some(p)) if p.ell.is_some())
    {
        Some(Csr { val: ds.val_ones.clone(), ..ds.csr_gcn.clone() })
    } else {
        None
    };
    let base_csr: &Csr = ones_csr.as_ref().unwrap_or(&ds.csr_gcn);
    let sampled;
    let (ell, profile): (Option<&Ell>, GraphProfile) = match (req.width, plan) {
        _ if sharded.is_some() => (None, plan.expect("sharded implies a plan").profile),
        (None, Some(p)) => (None, p.profile),
        (None, None) => (None, GraphProfile::of(&ds.csr_gcn)),
        (Some(_), Some(p)) if p.ell.is_some() => (p.ell.as_deref(), p.profile),
        (Some(w), _) => {
            let mut e = Ell::zeros(base_csr.n_rows, base_csr.n_cols, w);
            sample_ell_par(base_csr, w, req.strategy, &mut e, env.threads);
            sampled = e;
            (Some(&sampled), GraphProfile::of_ell(&sampled))
        }
    };
    let width = ell.map(|e| e.width);
    // i8 operand: the plan's cached [`AdjQuantPlan`]; plan-less callers
    // requantize here against the dataset's global Eq. 2 range — one
    // pass over the adjacency, the same cost class as the sampling pass
    // above.
    let local_adj;
    let i8_adj: Option<&AdjQuantPlan> = match (i8_codes, plan) {
        (Some(_), Some(p)) => p.adj.as_deref(),
        (Some(_), None) => {
            let params = ChunkedParams::uniform(ds.n, ds.qparams);
            let aq = match ell {
                Some(e) => AdjQuant::from_ell(e, &params),
                None => AdjQuant::from_csr(&ds.csr_gcn, &params),
            };
            local_adj = AdjQuantPlan { units: vec![aq] };
            Some(&local_adj)
        }
        (None, _) => None,
    };
    // Weighted-sum aggregation over the route's operand (GCN's Â or
    // SAGE's ones): sharded fans out per-shard tasks with per-shard
    // dispatch and the row-concatenation merge; otherwise one O(1)
    // dispatch from the cached profile.
    let aggregate_sum = |b: &[f32], f_dim: usize, out: &mut [f32]| {
        if let Some(sp) = sharded {
            sp.run(b, f_dim, out, env);
            return;
        }
        let kind = select_kernel(&profile, f_dim, width, env);
        match ell {
            Some(e) => run_ell(kind, e, b, f_dim, out, env.threads),
            None => run_exact(kind, base_csr, b, f_dim, out, env.threads),
        }
    };
    // Edges actually summed into row `i` — the SageMean divisor. Pure
    // structure: sampled routes count the plan's slots (overlapping
    // draws included, matching `ell_spmm_mean`), exact routes the row's
    // nnz. Shard units reproduce the global decision (exhaustive units
    // keep every edge; sampled units use the global width/strategy), so
    // the divisor is identical sharded and unsharded.
    let sum_count = |i: usize| -> usize {
        let nnz = ds.csr_gcn.row_nnz(i);
        match req.width {
            Some(w) => strategy_params(nnz, w, req.strategy).slots,
            None => nnz,
        }
    };
    // Dense layers chunk along the same row cuts as the shards.
    let shard_bounds = sharded.map(|sp| sp.bounds());
    let n = ds.n;
    let lvl = simd::level();

    // Interpret the program.
    let mut cur = Value::Input;
    let mut saved: Option<Value> = None;
    let mut skip_next = false;
    let materialize_input = || -> Result<(Vec<f32>, usize)> {
        if let Some(fh) = streamed {
            let mut buf = vec![0.0f32; n * ds.feats];
            fh.fill_rows_f32(0, &mut buf);
            return Ok((buf, ds.feats));
        }
        if x.is_empty() && n * ds.feats != 0 {
            bail!("this op needs materialized input features, but only i8 codes are staged");
        }
        Ok((x.to_vec(), ds.feats))
    };
    for (idx, op) in ops.iter().enumerate() {
        if skip_next {
            skip_next = false;
            continue;
        }
        match op {
            LayerOp::Save => {
                saved = Some(match &cur {
                    Value::Input => Value::Input,
                    Value::Dense(d, dim) => Value::Dense(d.clone(), *dim),
                });
            }
            LayerOp::Swap => {
                let Some(s) = saved.take() else {
                    bail!("model {:?}: Swap with empty saved register", req.model);
                };
                saved = Some(std::mem::replace(&mut cur, s));
            }
            LayerOp::Add => {
                let Some(s) = &saved else {
                    bail!("model {:?}: Add with empty saved register", req.model);
                };
                let (sdata, sdim, owned);
                match s {
                    Value::Dense(d, dim) => {
                        sdata = d.as_slice();
                        sdim = *dim;
                    }
                    Value::Input => {
                        owned = materialize_input()?;
                        sdata = owned.0.as_slice();
                        sdim = owned.1;
                    }
                }
                let Value::Dense(c, cdim) = &mut cur else {
                    bail!("model {:?}: Add over the raw input register", req.model);
                };
                if *cdim != sdim {
                    bail!("model {:?}: Add joins dim {cdim} with saved dim {sdim}", req.model);
                }
                for (o, &v) in c.iter_mut().zip(sdata.iter()) {
                    *o += v;
                }
            }
            LayerOp::Concat => {
                let Some(s) = saved.take() else {
                    bail!("model {:?}: Concat with empty saved register", req.model);
                };
                let (sdata, sdim) = match s {
                    Value::Dense(d, dim) => (d, dim),
                    Value::Input => materialize_input()?,
                };
                let (cdata, cdim) = match std::mem::replace(&mut cur, Value::Input) {
                    Value::Dense(d, dim) => (d, dim),
                    Value::Input => materialize_input()?,
                };
                let dim = sdim + cdim;
                let mut joined = vec![0.0f32; n * dim];
                for i in 0..n {
                    joined[i * dim..i * dim + sdim]
                        .copy_from_slice(&sdata[i * sdim..(i + 1) * sdim]);
                    joined[i * dim + sdim..(i + 1) * dim]
                        .copy_from_slice(&cdata[i * cdim..(i + 1) * cdim]);
                }
                cur = Value::Dense(joined, dim);
            }
            LayerOp::Linear { weight } => {
                let wt = tensor(weight)?;
                let w = wt.as_f32()?;
                let (k, d_out) = (wt.shape[0], wt.shape[1]);
                cur = match &cur {
                    Value::Input => {
                        debug_assert_eq!(k, ds.feats);
                        // The i8 aggregate-first flip: `(Â ×_i8 X) W`
                        // replaces `Â (X W)` when the next op is the GCN
                        // aggregate and the integer operands are staged.
                        if let (Some(qb), Some(adj), Some(LayerOp::Aggregate { .. })) =
                            (i8_codes, i8_adj, ops.get(idx + 1))
                        {
                            let mut agg_x = vec![0.0f32; n * k];
                            if let Some(sp) = sharded {
                                sp.run_i8(adj, qb, k, &mut agg_x, env);
                            } else {
                                // Unsharded plans (and the local
                                // fallback) carry one operand.
                                let aq = &adj.units[0];
                                let kind = select_kernel_i8(&profile, k, width, env);
                                match ell {
                                    Some(e) => {
                                        run_ell_i8(kind, e, aq, qb, k, &mut agg_x, env.threads)
                                    }
                                    None => run_exact_i8(
                                        kind,
                                        &ds.csr_gcn,
                                        aq,
                                        qb,
                                        k,
                                        &mut agg_x,
                                        env.threads,
                                    ),
                                }
                            }
                            skip_next = true;
                            let out = match &shard_bounds {
                                Some(bounds) => matmul_sharded(&agg_x, w, n, k, d_out, bounds, env),
                                None => matmul(&agg_x, w, n, k, d_out, env),
                            };
                            Value::Dense(out, d_out)
                        } else {
                            let out = match (streamed, &shard_bounds) {
                                (Some(fh), bounds) => {
                                    matmul_streamed(fh, w, n, k, d_out, env, bounds.as_deref())
                                }
                                (None, Some(bounds)) => {
                                    matmul_sharded(x, w, n, k, d_out, bounds, env)
                                }
                                (None, None) => matmul(x, w, n, k, d_out, env),
                            };
                            Value::Dense(out, d_out)
                        }
                    }
                    Value::Dense(d, dim) => {
                        debug_assert_eq!(k, *dim);
                        let out = match &shard_bounds {
                            Some(bounds) => matmul_sharded(d, w, n, *dim, d_out, bounds, env),
                            None => matmul(d, w, n, *dim, d_out, env),
                        };
                        Value::Dense(out, d_out)
                    }
                };
            }
            LayerOp::Aggregate { kind } => {
                let Value::Dense(h, dim) = &cur else {
                    bail!(
                        "model {:?}: Aggregate over the raw input register is not supported",
                        req.model
                    );
                };
                let f_dim = *dim;
                let mut out = vec![0.0f32; n * f_dim];
                match kind {
                    AggregateKind::Gcn => aggregate_sum(h, f_dim, &mut out),
                    AggregateKind::SageMean => {
                        aggregate_sum(h, f_dim, &mut out);
                        for i in 0..n {
                            let d = sum_count(i).max(1) as f32;
                            for o in out[i * f_dim..(i + 1) * f_dim].iter_mut() {
                                *o /= d;
                            }
                        }
                    }
                    AggregateKind::SageMax => {
                        if let Some(sp) = sharded {
                            if let [unit] = sp.units() {
                                match &unit.ell {
                                    Some(e) => segmented_max_ell_par(
                                        lvl, e, h, f_dim, &mut out, env.threads,
                                    ),
                                    None => segmented_max_csr_par(
                                        lvl, &unit.csr, h, f_dim, &mut out, env.threads,
                                    ),
                                }
                            } else {
                                let mut rest: &mut [f32] = &mut out;
                                let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> =
                                    Vec::with_capacity(sp.units().len());
                                for unit in sp.units() {
                                    let (chunk, tail) =
                                        rest.split_at_mut(unit.rows.len() * f_dim);
                                    rest = tail;
                                    tasks.push(Box::new(move || match &unit.ell {
                                        Some(e) => segmented_max_ell(lvl, e, h, f_dim, chunk),
                                        None => segmented_max_csr(
                                            lvl, &unit.csr, h, f_dim, chunk,
                                        ),
                                    }));
                                }
                                crate::exec::global_pool().run(tasks);
                            }
                        } else {
                            match ell {
                                Some(e) => {
                                    segmented_max_ell_par(lvl, e, h, f_dim, &mut out, env.threads)
                                }
                                None => segmented_max_csr_par(
                                    lvl, base_csr, h, f_dim, &mut out, env.threads,
                                ),
                            }
                        }
                    }
                    AggregateKind::GatAttention { att_src, att_dst } => {
                        if ds.csr_gcn.n_cols != n {
                            bail!("GAT needs a square adjacency (self-attention over nodes)");
                        }
                        let a_src = tensor(att_src)?.as_f32()?;
                        let a_dst = tensor(att_dst)?.as_f32()?;
                        let s_src = attention_scores_par(h, a_src, n, f_dim, env.threads);
                        let s_dst = attention_scores_par(h, a_dst, n, f_dim, env.threads);
                        if let Some(sp) = sharded {
                            run_gat_sharded(sp, &s_src, &s_dst, h, f_dim, &mut out, env);
                        } else if let Some(e) = ell {
                            let alpha = gat_alpha_ell_par(lvl, e, &s_src, &s_dst, env.threads);
                            // Structural clone with α substituted —
                            // padding slots stay (0.0, 0), so the
                            // sampled operand contract holds.
                            let ae = Ell {
                                n_rows: e.n_rows,
                                n_cols: e.n_cols,
                                width: e.width,
                                val: alpha,
                                col: e.col.clone(),
                                slots: e.slots.clone(),
                            };
                            let kind = select_kernel(&profile, f_dim, width, env);
                            run_ell(kind, &ae, h, f_dim, &mut out, env.threads);
                        } else {
                            let alpha =
                                gat_alpha_csr_par(lvl, base_csr, &s_src, &s_dst, env.threads);
                            let ac = Csr {
                                n_rows: base_csr.n_rows,
                                n_cols: base_csr.n_cols,
                                row_ptr: base_csr.row_ptr.clone(),
                                col_ind: base_csr.col_ind.clone(),
                                val: alpha,
                            };
                            let kind = select_kernel(&profile, f_dim, width, env);
                            run_exact(kind, &ac, h, f_dim, &mut out, env.threads);
                        }
                    }
                }
                cur = Value::Dense(out, f_dim);
            }
            LayerOp::Bias { name } => {
                let b = tensor(name)?.as_f32()?;
                let Value::Dense(c, dim) = &mut cur else {
                    bail!("model {:?}: Bias over the raw input register", req.model);
                };
                let dim = *dim;
                for i in 0..n {
                    for j in 0..dim {
                        c[i * dim + j] += b[j];
                    }
                }
            }
            LayerOp::Relu => {
                let Value::Dense(c, _) = &mut cur else {
                    bail!("model {:?}: Relu over the raw input register", req.model);
                };
                for v in c.iter_mut() {
                    // Same expression the fused pre-IR layer used:
                    // `(h + b).max(0.0)` split into Bias then Relu is
                    // bitwise-identical.
                    *v = v.max(0.0);
                }
            }
        }
    }
    let Value::Dense(logits, c) = cur else {
        bail!("model {:?}: program left the raw input in the output register", req.model);
    };
    if c != ds.classes {
        bail!("model {:?}: program emitted dim {c}, dataset has {} classes", req.model, ds.classes);
    }
    let execute = t1.elapsed();

    Ok(ForwardResult {
        logits: Tensor::from_f32(&[n, c], &logits),
        stats: ExecStats { transfer, execute, fetch: Duration::ZERO },
    })
}

/// GAT aggregation over a sharded plan: per-unit α (the softmax is
/// row-local, so each unit normalizes exactly the rows it owns),
/// substituted into a structural clone of the unit's operand, executed
/// with the classic dispatch on the unit's cached profile — independent
/// tasks, row-concatenation merge, bitwise equal to the unsharded path.
fn run_gat_sharded(
    sp: &ShardedPlan,
    s_src: &[f32],
    s_dst: &[f32],
    h: &[f32],
    f_dim: usize,
    out: &mut [f32],
    env: &ExecEnv,
) {
    let lvl = simd::level();
    if let [unit] = sp.units() {
        // The shard is the whole graph — use the thread budget.
        let src = &s_src[unit.rows.clone()];
        match &unit.ell {
            Some(e) => {
                let alpha = gat_alpha_ell_par(lvl, e, src, s_dst, env.threads);
                let ae = Ell {
                    n_rows: e.n_rows,
                    n_cols: e.n_cols,
                    width: e.width,
                    val: alpha,
                    col: e.col.clone(),
                    slots: e.slots.clone(),
                };
                let kind = select_kernel(&unit.profile, f_dim, Some(e.width), env);
                run_ell(kind, &ae, h, f_dim, out, env.threads);
            }
            None => {
                let alpha = gat_alpha_csr_par(lvl, &unit.csr, src, s_dst, env.threads);
                let ac = Csr {
                    n_rows: unit.csr.n_rows,
                    n_cols: unit.csr.n_cols,
                    row_ptr: unit.csr.row_ptr.clone(),
                    col_ind: unit.csr.col_ind.clone(),
                    val: alpha,
                };
                let kind = select_kernel(&unit.profile, f_dim, None, env);
                run_exact(kind, &ac, h, f_dim, out, env.threads);
            }
        }
        return;
    }
    let serial = ExecEnv::with_threads(1);
    let mut rest = out;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(sp.units().len());
    for unit in sp.units() {
        let (chunk, tail) = rest.split_at_mut(unit.rows.len() * f_dim);
        rest = tail;
        let serial = &serial;
        tasks.push(Box::new(move || {
            let src = &s_src[unit.rows.clone()];
            match &unit.ell {
                Some(e) => {
                    let alpha = gat_alpha_ell(lvl, e, src, s_dst);
                    let ae = Ell {
                        n_rows: e.n_rows,
                        n_cols: e.n_cols,
                        width: e.width,
                        val: alpha,
                        col: e.col.clone(),
                        slots: e.slots.clone(),
                    };
                    let kind = select_kernel(&unit.profile, f_dim, Some(e.width), serial);
                    run_ell(kind, &ae, h, f_dim, chunk, 1);
                }
                None => {
                    let alpha = gat_alpha_csr(lvl, &unit.csr, src, s_dst);
                    let ac = Csr {
                        n_rows: unit.csr.n_rows,
                        n_cols: unit.csr.n_cols,
                        row_ptr: unit.csr.row_ptr.clone(),
                        col_ind: unit.csr.col_ind.clone(),
                        val: alpha,
                    };
                    let kind = select_kernel(&unit.profile, f_dim, None, serial);
                    run_exact(kind, &ac, h, f_dim, chunk, 1);
                }
            }
        }));
    }
    crate::exec::global_pool().run(tasks);
}

/// Can the host substrate serve this request? Any model with an IR
/// program, at every current precision: u8 dequantizes host-side, and
/// i8-compute consumes the codes directly in the integer kernels (GCN)
/// or falls back to fp32 compute over streamed/dequantized features
/// (models whose programs never trigger the aggregate-first flip).
pub fn host_supports(req: &ForwardRequest) -> bool {
    model_ir(&req.model).is_ok()
        && matches!(
            req.precision,
            Precision::F32 | Precision::U8Device | Precision::U8Host | Precision::I8Compute
        )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small_known() {
        // [1 2; 3 4] × [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let env = ExecEnv::with_threads(1);
        assert_eq!(matmul(&a, &b, 2, 2, 2, &env), vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matmul_skips_zeros_correctly() {
        let a = [0.0f32, 2.0, 0.0, 0.0];
        let b = [1.0f32, 1.0, 3.0, -1.0];
        let env = ExecEnv::with_threads(1);
        assert_eq!(matmul(&a, &b, 2, 2, 2, &env), vec![6.0, -2.0, 0.0, 0.0]);
    }

    #[test]
    fn parallel_matmul_matches_serial() {
        let mut rng = crate::rng::Pcg32::new(17);
        // 2*m*k*n = 4.2 MFLOP — above PAR_MIN_FLOPS, so the 8-thread env
        // actually chunks; row-parallelism keeps per-row FP order
        // identical to the serial path.
        let (m, k, n) = (256usize, 128usize, 64usize);
        assert!(2 * m * k * n >= PAR_MIN_FLOPS);
        let a: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
        let serial = matmul(&a, &b, m, k, n, &ExecEnv::with_threads(1));
        let par = matmul(&a, &b, m, k, n, &ExecEnv::with_threads(8));
        assert_eq!(serial.len(), par.len());
        for (s, p) in serial.iter().zip(par.iter()) {
            assert!((s - p).abs() <= 1e-6 * (1.0 + s.abs()));
        }
    }

    #[test]
    fn matmul_degenerate_dims() {
        let env = ExecEnv::with_threads(4);
        assert!(matmul(&[], &[], 0, 3, 3, &env).is_empty());
        assert_eq!(matmul(&[1.0, 2.0], &[], 2, 1, 0, &env), Vec::<f32>::new());
    }

    #[test]
    fn streamed_matmul_matches_eager_over_the_same_dequant() {
        use crate::quant::{ChunkedParams, FeatureStore, Features, Precision};
        use crate::tensor::{write_nbt, NbtFile};

        let (m, k, n) = (37usize, 8usize, 5usize);
        let mut rng = crate::rng::Pcg32::new(23);
        let feat: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
        let chunked = ChunkedParams::of_rows(&feat, m, k, 10);
        let q = chunked.quantize_rows(&feat, k);
        let pairs: Vec<f32> = chunked.chunks().iter().flat_map(|p| [p.x_min, p.x_max]).collect();
        let env_p = chunked.envelope();

        let mut nbt = NbtFile::new();
        nbt.insert("feat", Tensor::from_f32(&[m, k], &feat));
        nbt.insert("featq", Tensor::from_u8(&[m, k], &q));
        nbt.insert("qrange", Tensor::from_f32(&[2], &[env_p.x_min, env_p.x_max]));
        nbt.insert("qchunks", Tensor::from_f32(&[chunked.n_chunks(), 2], &pairs));
        let dir = std::env::temp_dir().join(format!("host_stream_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("d.nbt");
        write_nbt(&path, &nbt).unwrap();

        let store = FeatureStore::open(&path).unwrap();
        let (feats, _) = store.stage(Precision::U8Device).unwrap();
        let Features::Streamed(fh) = feats else {
            return; // platform without mmap: streaming is compiled out
        };
        let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
        // Eager reference over the identical per-chunk dequant.
        let mut x = vec![0.0f32; m * k];
        chunked.dequantize_rows_into(&q, 0, k, &mut x);
        for threads in [1usize, 4] {
            let env = ExecEnv::with_threads(threads);
            let want = matmul(&x, &b, m, k, n, &env);
            let got = matmul_streamed(&fh, &b, m, k, n, &env, None);
            assert_eq!(want, got, "streamed layer-1 must be bit-identical ({threads} threads)");
        }
        // Shard-aligned chunking stages per-shard blocks but keeps the
        // result bit-identical too.
        let bounds = [0usize..11, 11..12, 12..30, 30..m];
        let env = ExecEnv::with_threads(4);
        let want = matmul(&x, &b, m, k, n, &env);
        let got = matmul_streamed(&fh, &b, m, k, n, &env, Some(&bounds));
        assert_eq!(want, got, "shard-chunked streamed multiply must be bit-identical");
    }

    #[test]
    fn sharded_matmul_is_bitwise_equal_to_matmul() {
        let mut rng = crate::rng::Pcg32::new(41);
        // Above PAR_MIN_FLOPS so the per-shard fan-out actually runs
        // (smaller multiplies fall back to the thread-chunked path).
        let (m, k, n) = (256usize, 128usize, 64usize);
        assert!(2 * m * k * n >= PAR_MIN_FLOPS);
        let a: Vec<f32> = (0..m * k).map(|_| rng.f32() - 0.5).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.f32() - 0.5).collect();
        let env = ExecEnv::with_threads(4);
        let want = matmul(&a, &b, m, k, n, &env);
        // Uneven shard cuts, including a single-row shard.
        let bounds = [0usize..100, 100..101, 101..200, 200..m];
        let got = matmul_sharded(&a, &b, m, k, n, &bounds, &env);
        assert_eq!(want, got);
        // Single-bound lists fall back to the thread-chunked path.
        let got = matmul_sharded(&a, &b, m, k, n, &[0..m], &env);
        assert_eq!(want, got);
        // Sub-threshold multiplies fall back too — still bitwise equal.
        let (sm, sk, sn) = (19usize, 7usize, 5usize);
        let sa: Vec<f32> = (0..sm * sk).map(|_| rng.f32() - 0.5).collect();
        let sb: Vec<f32> = (0..sk * sn).map(|_| rng.f32() - 0.5).collect();
        let small_bounds = [0usize..4, 4..19];
        let want = matmul(&sa, &sb, sm, sk, sn, &env);
        let got = matmul_sharded(&sa, &sb, sm, sk, sn, &small_bounds, &env);
        assert_eq!(want, got);
    }

    // Full forward correctness is covered in tests/exec_layer.rs (GCN
    // through the coordinator) and tests/model_zoo.rs (per-model
    // interpreter vs oracle, sampled budgets, sharded equality).
}
