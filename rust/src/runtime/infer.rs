//! High-level inference: assemble artifact inputs from a [`Dataset`] +
//! [`Weights`], run the engine, and score accuracy. Shared by the
//! coordinator's workers, the experiment harness, and the examples.

use anyhow::{bail, Result};

use crate::quant::Precision;
use crate::sampling::Strategy;
use crate::tensor::Tensor;

use super::artifacts::{artifact_key, ArtifactKind};
use super::dataset::{Dataset, Weights};
use super::engine::{Engine, ExecStats};

/// One forward-pass request against a compiled artifact.
#[derive(Clone, Debug)]
pub struct ForwardRequest {
    pub model: String,
    pub dataset: String,
    /// None → exact baseline artifact; Some(w) → sampled artifact.
    pub width: Option<usize>,
    pub strategy: Strategy,
    pub precision: Precision,
}

impl ForwardRequest {
    pub fn artifact_name(&self) -> String {
        match (self.width, self.precision) {
            (None, _) => artifact_key(ArtifactKind::Baseline, &self.model, &self.dataset, 0),
            (Some(w), Precision::F32) | (Some(w), Precision::U8Host) => {
                artifact_key(ArtifactKind::Sampled, &self.model, &self.dataset, w)
            }
            // i8-compute shares the quantized artifact family (same
            // INT8 payload); only the host backend actually runs it —
            // see the guard in [`run_forward`].
            (Some(w), Precision::U8Device) | (Some(w), Precision::I8Compute) => {
                artifact_key(ArtifactKind::Quantized, &self.model, &self.dataset, w)
            }
        }
    }
}

/// Logits + timing from one forward pass.
#[derive(Debug)]
pub struct ForwardResult {
    pub logits: Tensor,
    pub stats: ExecStats,
}

/// Run one full-graph forward pass through the AOT artifact.
///
/// `features` overrides the dataset's stored features when provided (the
/// coordinator passes store-loaded features so load time is attributable);
/// otherwise the dataset's in-memory tensor is used.
pub fn run_forward(
    engine: &Engine,
    ds: &Dataset,
    weights: &Weights,
    req: &ForwardRequest,
    features: Option<&Tensor>,
) -> Result<ForwardResult> {
    use crate::runtime::Arg;

    if matches!(req.precision, Precision::I8Compute) {
        // No compiled artifact performs integer accumulation; the
        // precision exists for the host backend's i8×u8→i32 kernels.
        bail!("i8-compute is a host-backend precision; device artifacts dequantize in-kernel");
    }
    let name = req.artifact_name();
    let row_ptr = Tensor::from_i32(&[ds.n + 1], &ds.csr_gcn.row_ptr);
    let col_ind = Tensor::from_i32(&[ds.nnz], &ds.csr_gcn.col_ind);
    let val = Tensor::from_f32(&[ds.nnz], ds.val_for(&req.model));
    let strategy = Tensor::scalar_i32(req.strategy.code());
    let dsn = &ds.name;
    let val_key = format!("{dsn}/val_{}", if req.model == "gcn" { "gcn" } else { "ones" });

    // Graph structure + weights are device-cached (static across requests);
    // features and scalars are staged fresh per call.
    // Baseline artifacts have no row_ptr input (XLA would prune it — see
    // aot.py) and take per-edge row ids instead.
    let rp_key = format!("{dsn}/row_ptr");
    let ci_key = format!("{dsn}/col_ind");
    let mut inputs: Vec<Arg> = if req.width.is_none() {
        vec![Arg::Cached(&ci_key, &col_ind), Arg::Cached(&val_key, &val)]
    } else {
        vec![
            Arg::Cached(&rp_key, &row_ptr),
            Arg::Cached(&ci_key, &col_ind),
            Arg::Cached(&val_key, &val),
        ]
    };
    let row_ids_tensor;
    let ri_key = format!("{dsn}/row_ids");
    if req.width.is_none() {
        row_ids_tensor = Tensor::from_i32(&[ds.nnz], &ds.csr_gcn.row_ids());
        inputs.push(Arg::Cached(&ri_key, &row_ids_tensor));
    }

    let qmin;
    let qmax;
    let feat_key = format!("{dsn}/feat");
    let featq_key = format!("{dsn}/featq");
    match (req.width, req.precision) {
        (Some(_), Precision::U8Device) => {
            inputs.push(match features {
                Some(f) => Arg::Fresh(f),
                None => Arg::Cached(&featq_key, &ds.featq),
            });
            qmin = Tensor::scalar_f32(ds.qparams.x_min);
            qmax = Tensor::scalar_f32(ds.qparams.x_max);
            inputs.push(Arg::Fresh(&qmin));
            inputs.push(Arg::Fresh(&qmax));
        }
        (_, Precision::U8Host) if req.width.is_none() => {
            bail!("host-dequant baseline path not lowered; use F32 for baselines")
        }
        _ => {
            inputs.push(match features {
                Some(f) => Arg::Fresh(f),
                None => Arg::Cached(&feat_key, &ds.feat),
            });
        }
    }

    if req.width.is_some() {
        inputs.push(Arg::Fresh(&strategy));
    }
    let wkeys: Vec<String> = weights
        .tensors
        .iter()
        .map(|(k, _)| format!("{}/{dsn}/{k}", req.model))
        .collect();
    for ((_, t), key) in weights.tensors.iter().zip(wkeys.iter()) {
        inputs.push(Arg::Cached(key, t));
    }

    let (logits, stats) = engine.execute_args(&name, &inputs)?;
    Ok(ForwardResult { logits, stats })
}

/// Test-set accuracy of logits against dataset labels (argmax rule).
pub fn accuracy(ds: &Dataset, logits: &Tensor) -> Result<f64> {
    let vals = logits.as_f32()?;
    if logits.shape != [ds.n, ds.classes] {
        bail!("logits shape {:?} != [{}, {}]", logits.shape, ds.n, ds.classes);
    }
    let mut correct = 0usize;
    let mut total = 0usize;
    for i in 0..ds.n {
        if ds.train_mask[i] != 0 {
            continue;
        }
        let row = &vals[i * ds.classes..(i + 1) * ds.classes];
        let pred = crate::util::argmax_f32(row) as i32;
        correct += (pred == ds.labels[i]) as usize;
        total += 1;
    }
    Ok(correct as f64 / total.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_routing() {
        let mut req = ForwardRequest {
            model: "gcn".into(),
            dataset: "cora".into(),
            width: Some(64),
            strategy: Strategy::Aes,
            precision: Precision::F32,
        };
        assert_eq!(req.artifact_name(), "model_gcn_cora_w64");
        req.precision = Precision::U8Device;
        assert_eq!(req.artifact_name(), "qmodel_gcn_cora_w64");
        req.precision = Precision::I8Compute;
        assert_eq!(req.artifact_name(), "qmodel_gcn_cora_w64");
        req.width = None;
        assert_eq!(req.artifact_name(), "baseline_gcn_cora");
    }
}
