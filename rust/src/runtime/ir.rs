//! The layer-graph IR — models are data, not code paths.
//!
//! A model is a flat `Vec<LayerOp>` over a two-register machine:
//! `cur` (the value every op reads and writes) and `saved` (a scratch
//! register for skip/self branches). [`crate::runtime::host_forward`]
//! interprets the program, routing every [`LayerOp::Aggregate`] through
//! the exec-layer machinery (plan cache, sharded units, tuned dispatch,
//! SIMD/INT8 kernels); [`crate::eval::oracle_forward`] interprets the
//! same program with the canonical serial reduction order. One program,
//! two interpreters, cross-checked bit-for-bit on the exact fp32 route.
//!
//! # Programs
//!
//! | model     | per layer                                                         |
//! |-----------|-------------------------------------------------------------------|
//! | `gcn`     | `Linear(w) → Aggregate(Gcn) → Bias(b) → Relu?`                    |
//! | `sage`    | `Save → Linear(w_neigh) → Aggregate(SageMean) → Swap → Linear(w_self) → Add → Bias(b) → Relu?` |
//! | `sagemax` | as `sage` with `Aggregate(SageMax)`                               |
//! | `gat`     | `Linear(w) → Aggregate(GatAttention) → Bias(b) → Relu?`           |
//!
//! The GCN program replays the pre-IR hard-coded forward op for op, so
//! GCN through the interpreter is bit-identical to the golden fixtures.
//! The SAGE layer saves the input *before* the neighbor branch so both
//! `Linear`s run on the raw input — layer 1 streams rows through
//! [`crate::runtime::host_forward`]'s feature handle exactly like GCN.
//!
//! # Aggregation operands
//!
//! Sampling is structure-only ([`crate::sampling::strategy_params`] and
//! the Eq. 3 start index read row lengths, never values), so a sampled
//! plan depends on the model only through its **value family**
//! ([`ModelVals`]): GCN aggregates with Â entries (`csr_gcn`), every
//! other model with all-ones values (`val_ones`). `sage` and `gat`
//! therefore share plans and shard units; `PlanKey`/`ShardKey` carry the
//! family, not the model name.

use anyhow::{bail, Result};

use crate::tensor::Tensor;

use super::dataset::{GAT_PARAM_ORDER, GCN_PARAM_ORDER, SAGE_PARAM_ORDER};

/// Which reduction an [`LayerOp::Aggregate`] performs over the graph.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AggregateKind {
    /// `out[i] = Σ_e Â[i,j]·x[j]` — GCN-normalized weighted sum.
    Gcn,
    /// `out[i] = (Σ_e x[j]) / max(deg_i, 1)` — GraphSAGE mean, where
    /// `deg_i` counts the edges actually summed (sampled slots on the
    /// ELL route, `row_nnz` exact).
    SageMean,
    /// `out[i] = max_e x[j]` (elementwise), 0.0 for edgeless rows —
    /// GraphSAGE max-pooling.
    SageMax,
    /// GAT: per-edge logits `e_ij = LeakyReLU(a_src·h_i + a_dst·h_j)`,
    /// numerically-stable segmented row softmax → attention α, then
    /// `out[i] = Σ_e α_ij·x[j]` (see `docs/models.md`).
    GatAttention {
        /// Name of the `[d]` source-side attention vector tensor.
        att_src: String,
        /// Name of the `[d]` destination-side attention vector tensor.
        att_dst: String,
    },
}

/// One instruction of the two-register layer machine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerOp {
    /// `saved = cur` (copy).
    Save,
    /// Exchange `cur` and `saved`.
    Swap,
    /// `cur += saved` (elementwise; dims must match).
    Add,
    /// `cur = [saved ‖ cur]` per row (feature concat).
    Concat,
    /// `cur = cur × W` with `W = weights[name]`, shape `[d_in, d_out]`.
    Linear {
        /// Weight-tensor name in the model's artifact signature.
        weight: String,
    },
    /// Aggregate `cur` over the graph per [`AggregateKind`].
    Aggregate {
        /// Which graph reduction to run.
        kind: AggregateKind,
    },
    /// `cur[i, j] += b[j]` with `b = weights[name]`, shape `[d]`.
    Bias {
        /// Bias-tensor name in the model's artifact signature.
        name: String,
    },
    /// `cur = max(cur, 0.0)` elementwise.
    Relu,
}

/// Value family of a model's aggregation operand. Sampling is
/// structure-only, so plans/shard units are shared per family — this is
/// the `model_kind` component of `PlanKey` / `ShardKey`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelVals {
    /// Â entries (`Dataset::csr_gcn.val`) — the GCN operand.
    Gcn,
    /// All-ones values (`Dataset::val_ones`) — SAGE/GAT structural
    /// operand (GAT substitutes per-edge α at execution time).
    Ones,
}

impl ModelVals {
    /// Family of a model name (unknown names conservatively map to
    /// `Ones`; they are rejected earlier by [`model_ir`]).
    pub fn of(model: &str) -> ModelVals {
        if model == "gcn" {
            ModelVals::Gcn
        } else {
            ModelVals::Ones
        }
    }

    /// Stable lowercase label (cache-key display).
    pub fn name(self) -> &'static str {
        match self {
            ModelVals::Gcn => "gcn",
            ModelVals::Ones => "ones",
        }
    }
}

/// Every model the IR can express, servable end to end.
pub const KNOWN_MODELS: &[&str] = &["gcn", "sage", "sagemax", "gat"];

/// The models exposed on the serving/eval surface (`sagemax` is an IR +
/// oracle capability exercised by unit tests, not an artifact model).
pub const SERVED_MODELS: &[&str] = &["gcn", "sage", "gat"];

fn lin(w: &str) -> LayerOp {
    LayerOp::Linear { weight: w.into() }
}

fn sage_layer(kind: AggregateKind, w_self: &str, w_neigh: &str, b: &str, relu: bool) -> Vec<LayerOp> {
    let mut ops = vec![
        LayerOp::Save,
        lin(w_neigh),
        LayerOp::Aggregate { kind },
        LayerOp::Swap,
        lin(w_self),
        LayerOp::Add,
        LayerOp::Bias { name: b.into() },
    ];
    if relu {
        ops.push(LayerOp::Relu);
    }
    ops
}

/// The 2-layer program for `model`, or an error for unknown names.
pub fn model_ir(model: &str) -> Result<Vec<LayerOp>> {
    let agg = |kind: AggregateKind| LayerOp::Aggregate { kind };
    Ok(match model {
        "gcn" => vec![
            lin("w0"),
            agg(AggregateKind::Gcn),
            LayerOp::Bias { name: "b0".into() },
            LayerOp::Relu,
            lin("w1"),
            agg(AggregateKind::Gcn),
            LayerOp::Bias { name: "b1".into() },
        ],
        "sage" | "sagemax" => {
            let kind = || {
                if model == "sage" {
                    AggregateKind::SageMean
                } else {
                    AggregateKind::SageMax
                }
            };
            let mut ops = sage_layer(kind(), "w0_self", "w0_neigh", "b0", true);
            ops.extend(sage_layer(kind(), "w1_self", "w1_neigh", "b1", false));
            ops
        }
        "gat" => vec![
            lin("w0"),
            agg(AggregateKind::GatAttention { att_src: "a0_src".into(), att_dst: "a0_dst".into() }),
            LayerOp::Bias { name: "b0".into() },
            LayerOp::Relu,
            lin("w1"),
            agg(AggregateKind::GatAttention { att_src: "a1_src".into(), att_dst: "a1_dst".into() }),
            LayerOp::Bias { name: "b1".into() },
        ],
        other => bail!(
            "unknown model {other:?} (known: {})",
            KNOWN_MODELS.join(", ")
        ),
    })
}

/// Positional artifact signature of `model` (tensor names in file order).
pub fn param_order(model: &str) -> Result<&'static [&'static str]> {
    Ok(match model {
        "gcn" => GCN_PARAM_ORDER,
        "sage" | "sagemax" => SAGE_PARAM_ORDER,
        "gat" => GAT_PARAM_ORDER,
        other => bail!(
            "unknown model {other:?} (known: {})",
            KNOWN_MODELS.join(", ")
        ),
    })
}

/// Validate weight-tensor shapes against the model IR by symbolically
/// walking the program with a feature dim, exactly as the interpreter
/// will: `Linear` consumes `[d, d']`, `Bias` and attention vectors
/// consume `[d]`, `Add` needs the registers to agree, and the final dim
/// must equal `classes`. Errors name the offending tensor so a bad
/// artifact fails at publish time instead of panicking inside `matmul`.
pub fn validate_weights(
    model: &str,
    feats: usize,
    classes: usize,
    tensors: &[(String, Tensor)],
) -> Result<()> {
    let ops = model_ir(model)?;
    let get = |name: &str| -> Result<&Tensor> {
        tensors
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, t)| t)
            .ok_or_else(|| anyhow::anyhow!("model {model:?}: missing weight tensor {name:?}"))
    };
    let mut d = feats;
    let mut saved: Option<usize> = None;
    for op in &ops {
        match op {
            LayerOp::Save => saved = Some(d),
            LayerOp::Swap => {
                let Some(s) = saved else {
                    bail!("model {model:?}: Swap with empty saved register");
                };
                saved = Some(d);
                d = s;
            }
            LayerOp::Add => match saved {
                Some(s) if s == d => {}
                Some(s) => bail!(
                    "model {model:?}: Add joins dim {d} with saved dim {s} — branches disagree"
                ),
                None => bail!("model {model:?}: Add with empty saved register"),
            },
            LayerOp::Concat => {
                let Some(s) = saved else {
                    bail!("model {model:?}: Concat with empty saved register");
                };
                d += s;
            }
            LayerOp::Linear { weight } => {
                let t = get(weight)?;
                if t.shape.len() != 2 || t.shape[0] != d {
                    bail!(
                        "model {model:?}: weight {weight:?} has shape {:?}, expected [{d}, _]",
                        t.shape
                    );
                }
                d = t.shape[1];
            }
            LayerOp::Aggregate { kind } => {
                if let AggregateKind::GatAttention { att_src, att_dst } = kind {
                    for name in [att_src, att_dst] {
                        let t = get(name)?;
                        if t.elem_count() != d {
                            bail!(
                                "model {model:?}: attention vector {name:?} has shape {:?}, \
                                 expected [{d}]",
                                t.shape
                            );
                        }
                    }
                }
            }
            LayerOp::Bias { name } => {
                let t = get(name)?;
                if t.elem_count() != d {
                    bail!(
                        "model {model:?}: bias {name:?} has shape {:?}, expected [{d}]",
                        t.shape
                    );
                }
            }
            LayerOp::Relu => {}
        }
    }
    if d != classes {
        bail!("model {model:?}: program emits dim {d}, dataset has {classes} classes");
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize]) -> Tensor {
        let len: usize = shape.iter().product();
        Tensor::from_f32(shape, &vec![0.5; len])
    }

    fn gcn_weights(f: usize, h: usize, c: usize) -> Vec<(String, Tensor)> {
        vec![
            ("w0".into(), t(&[f, h])),
            ("b0".into(), t(&[h])),
            ("w1".into(), t(&[h, c])),
            ("b1".into(), t(&[c])),
        ]
    }

    #[test]
    fn every_known_model_has_a_program_and_signature() {
        for &m in KNOWN_MODELS {
            let ops = model_ir(m).unwrap();
            assert!(!ops.is_empty(), "{m}");
            assert!(!param_order(m).unwrap().is_empty(), "{m}");
        }
        assert!(model_ir("mlp").is_err());
        assert!(param_order("mlp").is_err());
    }

    #[test]
    fn gcn_program_replays_the_hardcoded_forward() {
        // The exact op order the pre-IR host_forward ran — pinned so the
        // bit-identity claim against the golden fixtures stays auditable.
        let ops = model_ir("gcn").unwrap();
        assert_eq!(
            ops,
            vec![
                LayerOp::Linear { weight: "w0".into() },
                LayerOp::Aggregate { kind: AggregateKind::Gcn },
                LayerOp::Bias { name: "b0".into() },
                LayerOp::Relu,
                LayerOp::Linear { weight: "w1".into() },
                LayerOp::Aggregate { kind: AggregateKind::Gcn },
                LayerOp::Bias { name: "b1".into() },
            ]
        );
    }

    #[test]
    fn value_families() {
        assert_eq!(ModelVals::of("gcn"), ModelVals::Gcn);
        assert_eq!(ModelVals::of("sage"), ModelVals::Ones);
        assert_eq!(ModelVals::of("gat"), ModelVals::Ones);
        assert_eq!(ModelVals::of("sagemax"), ModelVals::Ones);
    }

    #[test]
    fn validate_accepts_well_formed_weights() {
        let (f, h, c) = (8, 6, 4);
        validate_weights("gcn", f, c, &gcn_weights(f, h, c)).unwrap();
        let sage = vec![
            ("w0_self".into(), t(&[f, h])),
            ("w0_neigh".into(), t(&[f, h])),
            ("b0".into(), t(&[h])),
            ("w1_self".into(), t(&[h, c])),
            ("w1_neigh".into(), t(&[h, c])),
            ("b1".into(), t(&[c])),
        ];
        validate_weights("sage", f, c, &sage).unwrap();
        validate_weights("sagemax", f, c, &sage).unwrap();
        let gat = vec![
            ("w0".into(), t(&[f, h])),
            ("a0_src".into(), t(&[h])),
            ("a0_dst".into(), t(&[h])),
            ("b0".into(), t(&[h])),
            ("w1".into(), t(&[h, c])),
            ("a1_src".into(), t(&[c])),
            ("a1_dst".into(), t(&[c])),
            ("b1".into(), t(&[c])),
        ];
        validate_weights("gat", f, c, &gat).unwrap();
    }

    #[test]
    fn validate_names_the_offending_tensor() {
        let (f, h, c) = (8, 6, 4);
        // Transposed W0.
        let mut w = gcn_weights(f, h, c);
        w[0].1 = t(&[h, f]);
        let err = validate_weights("gcn", f, c, &w).unwrap_err().to_string();
        assert!(err.contains("w0"), "{err}");
        // Wrong bias length.
        let mut w = gcn_weights(f, h, c);
        w[1].1 = t(&[h + 1]);
        let err = validate_weights("gcn", f, c, &w).unwrap_err().to_string();
        assert!(err.contains("b0"), "{err}");
        // Missing tensor entirely.
        let mut w = gcn_weights(f, h, c);
        w.remove(2);
        let err = validate_weights("gcn", f, c, &w).unwrap_err().to_string();
        assert!(err.contains("w1"), "{err}");
        // Output dim disagrees with the dataset's class count.
        let err = validate_weights("gcn", f, c + 1, &gcn_weights(f, h, c))
            .unwrap_err()
            .to_string();
        assert!(err.contains("classes"), "{err}");
        // GAT attention vector at the wrong dim.
        let mut gat = vec![
            ("w0".into(), t(&[f, h])),
            ("a0_src".into(), t(&[h])),
            ("a0_dst".into(), t(&[h + 2])),
            ("b0".into(), t(&[h])),
            ("w1".into(), t(&[h, c])),
            ("a1_src".into(), t(&[c])),
            ("a1_dst".into(), t(&[c])),
            ("b1".into(), t(&[c])),
        ];
        let err = validate_weights("gat", f, c, &gat).unwrap_err().to_string();
        assert!(err.contains("a0_dst"), "{err}");
        gat[2].1 = t(&[h]);
        validate_weights("gat", f, c, &gat).unwrap();
    }

    #[test]
    fn sage_linears_run_on_the_raw_input() {
        // Both layer-1 Linears must see the input register so the
        // streamed-feature fast path applies: the program saves before
        // the neighbor branch and swaps back before the self branch.
        let ops = model_ir("sage").unwrap();
        assert_eq!(ops[0], LayerOp::Save);
        assert_eq!(ops[3], LayerOp::Swap);
        assert!(matches!(ops[1], LayerOp::Linear { .. }));
        assert!(matches!(ops[4], LayerOp::Linear { .. }));
    }
}
