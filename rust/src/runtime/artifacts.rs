//! Artifact registry — the typed view of `artifacts/manifest.json` written
//! by the AOT pipeline: which HLO files exist, their input signatures, and
//! the dataset metadata (including each model's exact-aggregation "ideal
//! accuracy", the Fig. 6 baseline).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::{dtype_from_name, DType};
use crate::util::{parse_json, JsonValue};

/// One expected input of a compiled artifact, in positional order.
#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

/// What a compiled artifact computes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// Exact segment-sum forward (plays cuSPARSE: the accuracy ideal).
    Baseline,
    /// Sampled forward (AES/AFS/SFS selected by the strategy scalar).
    Sampled,
    /// Sampled forward over INT8 features with on-device dequantization.
    Quantized,
}

impl ArtifactKind {
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "baseline" => ArtifactKind::Baseline,
            "sampled" => ArtifactKind::Sampled,
            "quantized" => ArtifactKind::Quantized,
            _ => bail!("unknown artifact kind {s:?}"),
        })
    }
}

/// Registry entry for one compiled HLO module.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub kind: ArtifactKind,
    /// Shared-memory width W (None for baselines).
    pub width: Option<usize>,
    pub inputs: Vec<InputSpec>,
    pub hlo_path: PathBuf,
}

/// Per-dataset metadata mirrored from the manifest.
#[derive(Clone, Debug)]
pub struct DatasetMeta {
    pub name: String,
    pub n: usize,
    pub nnz: usize,
    pub feats: usize,
    pub classes: usize,
    /// "small" | "large" — the paper's Table 2 grouping.
    pub scale: String,
    /// Exact-aggregation test accuracy per model (the Fig. 6 ideal).
    pub ideal_acc: BTreeMap<String, f64>,
    pub paper_nodes: usize,
    pub paper_avg_deg: f64,
}

/// The whole registry.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub datasets: BTreeMap<String, DatasetMeta>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    pub widths: Vec<usize>,
}

/// Canonical artifact name for a routing key.
pub fn artifact_key(kind: ArtifactKind, model: &str, dataset: &str, width: usize) -> String {
    match kind {
        ArtifactKind::Baseline => format!("baseline_{model}_{dataset}"),
        ArtifactKind::Sampled => format!("model_{model}_{dataset}_w{width}"),
        ArtifactKind::Quantized => format!("qmodel_{model}_{dataset}_w{width}"),
    }
}

impl Manifest {
    /// Load `manifest.json` from the artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let root = parse_json(&text)?;

        let mut datasets = BTreeMap::new();
        for (name, v) in root.get("datasets")?.as_obj()? {
            let mut ideal_acc = BTreeMap::new();
            for (m, acc) in v.get("ideal_acc")?.as_obj()? {
                ideal_acc.insert(m.clone(), acc.as_f64()?);
            }
            datasets.insert(
                name.clone(),
                DatasetMeta {
                    name: name.clone(),
                    n: v.get("n")?.as_usize()?,
                    nnz: v.get("nnz")?.as_usize()?,
                    feats: v.get("feats")?.as_usize()?,
                    classes: v.get("classes")?.as_usize()?,
                    scale: v.get("scale")?.as_str()?.to_string(),
                    ideal_acc,
                    paper_nodes: v.get("paper_nodes")?.as_usize()?,
                    paper_avg_deg: v.get("paper_avg_deg")?.as_f64()?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for (name, v) in root.get("artifacts")?.as_obj()? {
            let inputs = v
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(parse_input)
                .collect::<Result<Vec<_>>>()?;
            let width = match v.get("width") {
                Ok(w) => Some(w.as_usize()?),
                Err(_) => None,
            };
            let hlo_path = dir.join(format!("{name}.hlo.txt"));
            if !hlo_path.exists() {
                bail!("manifest lists {name} but {} is missing", hlo_path.display());
            }
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    kind: ArtifactKind::from_str(v.get("kind")?.as_str()?)?,
                    width,
                    inputs,
                    hlo_path,
                },
            );
        }

        let widths = root
            .get("widths")?
            .as_arr()?
            .iter()
            .map(|w| w.as_usize())
            .collect::<Result<Vec<_>>>()?;

        Ok(Manifest { dir, datasets, artifacts, widths })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }

    pub fn dataset(&self, name: &str) -> Result<&DatasetMeta> {
        self.datasets
            .get(name)
            .with_context(|| format!("dataset {name:?} not in manifest"))
    }

    /// Dataset names sorted small-scale first (paper's presentation order).
    pub fn dataset_names(&self) -> Vec<String> {
        let mut names: Vec<_> = self.datasets.keys().cloned().collect();
        names.sort_by_key(|n| (self.datasets[n].scale != "small", n.clone()));
        names
    }
}

fn parse_input(v: &JsonValue) -> Result<InputSpec> {
    Ok(InputSpec {
        name: v.get("name")?.as_str()?.to_string(),
        shape: v
            .get("shape")?
            .as_arr()?
            .iter()
            .map(|d| d.as_usize())
            .collect::<Result<Vec<_>>>()?,
        dtype: dtype_from_name(v.get("dtype")?.as_str()?)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_keys() {
        assert_eq!(artifact_key(ArtifactKind::Baseline, "gcn", "cora", 0), "baseline_gcn_cora");
        assert_eq!(
            artifact_key(ArtifactKind::Sampled, "sage", "reddit", 64),
            "model_sage_reddit_w64"
        );
        assert_eq!(
            artifact_key(ArtifactKind::Quantized, "gcn", "products", 128),
            "qmodel_gcn_products_w128"
        );
    }

    #[test]
    fn kind_parse() {
        assert_eq!(ArtifactKind::from_str("sampled").unwrap(), ArtifactKind::Sampled);
        assert!(ArtifactKind::from_str("nope").is_err());
    }
}
