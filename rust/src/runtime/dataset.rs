//! Dataset + weights loading from the `.nbt` artifacts.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::graph::Csr;
use crate::quant::QuantParams;
use crate::tensor::{read_nbt, Tensor};

/// Positional parameter order of each model's artifact signature — must
/// match `python/compile/model.py`'s `GCN_PARAM_ORDER` / `SAGE_PARAM_ORDER`.
pub const GCN_PARAM_ORDER: &[&str] = &["w0", "b0", "w1", "b1"];
pub const SAGE_PARAM_ORDER: &[&str] =
    &["w0_self", "w0_neigh", "b0", "w1_self", "w1_neigh", "b1"];
/// GAT: per-layer projection + the two halves of the attention vector
/// (`e_ij = LeakyReLU(a_srcᵀ h_i + a_dstᵀ h_j)`) + bias.
pub const GAT_PARAM_ORDER: &[&str] =
    &["w0", "a0_src", "a0_dst", "b0", "w1", "a1_src", "a1_dst", "b1"];

/// A fully loaded dataset: graph structure (CSR with self-loops), both
/// value arrays, f32 + INT8 features, labels, and the train/test split.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub n: usize,
    pub nnz: usize,
    pub feats: usize,
    pub classes: usize,
    /// Graph epoch: 0 as loaded, +1 per applied
    /// [`crate::graph::GraphDelta`] that changed anything. Plans and
    /// shard units are versioned against this — see `docs/mutation.md`.
    pub epoch: u64,
    /// Graph with GCN-normalized values (Â entries).
    pub csr_gcn: Csr,
    /// Same structure, all-ones values (GraphSAGE's mean numerator).
    pub val_ones: Vec<f32>,
    pub feat: Tensor,
    pub featq: Tensor,
    pub qparams: QuantParams,
    pub labels: Vec<i32>,
    pub train_mask: Vec<u8>,
}

impl Dataset {
    /// Load `data_{name}.nbt` from the artifacts directory.
    pub fn load(artifacts_dir: impl AsRef<Path>, name: &str) -> Result<Dataset> {
        let path = artifacts_dir.as_ref().join(format!("data_{name}.nbt"));
        let nbt = read_nbt(&path)?;
        let meta = nbt.get("meta")?.as_i64()?;
        let [n, nnz, feats, classes] = meta else {
            bail!("meta tensor must have 4 entries, got {}", meta.len());
        };
        let (n, nnz, feats, classes) =
            (*n as usize, *nnz as usize, *feats as usize, *classes as usize);
        let csr_gcn = Csr::from_nbt(&nbt, "val_gcn")?;
        if csr_gcn.n_rows != n || csr_gcn.nnz() != nnz {
            bail!("CSR dims disagree with meta for {name}");
        }
        let val_ones = nbt.get("val_ones")?.as_f32()?.to_vec();
        let qr = nbt.get("qrange")?.as_f32()?;
        Ok(Dataset {
            name: name.to_string(),
            n,
            nnz,
            feats,
            classes,
            epoch: 0,
            csr_gcn,
            val_ones,
            feat: nbt.get("feat")?.clone(),
            featq: nbt.get("featq")?.clone(),
            qparams: QuantParams { x_min: qr[0], x_max: qr[1] },
            labels: nbt.get("labels")?.as_i32()?.to_vec(),
            train_mask: nbt.get("train_mask")?.as_u8()?.to_vec(),
        })
    }

    /// CSR values for a model ("gcn" → normalized, "sage" → ones).
    pub fn val_for(&self, model: &str) -> &[f32] {
        if model == "gcn" {
            &self.csr_gcn.val
        } else {
            &self.val_ones
        }
    }

    /// Test-set node indices (the complement of the train mask).
    pub fn test_nodes(&self) -> Vec<usize> {
        (0..self.n).filter(|&i| self.train_mask[i] == 0).collect()
    }
}

/// Trained parameters for one (model, dataset), in artifact input order.
#[derive(Clone, Debug)]
pub struct Weights {
    pub model: String,
    pub tensors: Vec<(String, Tensor)>,
    /// Exact-aggregation test accuracy recorded at training time.
    pub ideal_acc: f32,
}

impl Weights {
    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str, dataset: &str) -> Result<Weights> {
        let path = artifacts_dir
            .as_ref()
            .join(format!("weights_{model}_{dataset}.nbt"));
        let nbt = read_nbt(&path)?;
        let order: &[&str] = super::ir::param_order(model)?;
        let tensors = order
            .iter()
            .map(|&k| Ok((k.to_string(), nbt.get(k)?.clone())))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("weights file {path:?}", path = path.display()))?;
        let ideal_acc = nbt.get("ideal_acc")?.as_f32()?[0];
        Ok(Weights { model: model.to_string(), tensors, ideal_acc })
    }

    /// Parameter tensors in positional order.
    pub fn in_order(&self) -> impl Iterator<Item = &Tensor> {
        self.tensors.iter().map(|(_, t)| t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Artifact-dependent loading is covered by tests/integration_runtime.rs
    // (requires `make artifacts`); here we only pin the parameter orders.
    #[test]
    fn param_orders_match_python() {
        assert_eq!(GCN_PARAM_ORDER, &["w0", "b0", "w1", "b1"]);
        assert_eq!(
            SAGE_PARAM_ORDER,
            &["w0_self", "w0_neigh", "b0", "w1_self", "w1_neigh", "b1"]
        );
        assert_eq!(
            GAT_PARAM_ORDER,
            &["w0", "a0_src", "a0_dst", "b0", "w1", "a1_src", "a1_dst", "b1"]
        );
    }
}
