//! The runtime — execution backends and artifact plumbing. This is the
//! only module that touches PJRT; everything above it deals in
//! [`crate::tensor::Tensor`]s.
//!
//! # Purpose
//!
//! Run one forward pass, wherever it can run: the compiled AOT artifacts
//! through PJRT (production), or the rust host substrate (CPU-only
//! machines, offline CI) — behind one [`Backend`] switch so the
//! coordinator does not care which.
//!
//! # Structure
//!
//! | unit        | role                                                  |
//! |-------------|-------------------------------------------------------|
//! | `artifacts` | manifest + artifact metadata produced by `python/compile/aot.py` |
//! | `dataset`   | [`Dataset`] / [`Weights`] loading from the `.nbt` artifacts |
//! | `engine`    | [`Engine`]: HLO text → `XlaComputation` → compile (cached) → execute |
//! | `backend`   | [`Backend`]: Pjrt (device) vs Host dispatch           |
//! | `host`      | [`host_forward`]: dispatched CPU forward — interprets the model IR, incl. lazy streamed-INT8 layer 1 |
//! | `infer`     | [`run_forward`] / [`accuracy`] request-level helpers  |
//! | `ir`        | [`model_ir`]: the layer-graph IR — models as `Vec<LayerOp>` data, plus weight-schema validation |
//!
//! # Rules
//!
//! * Pipeline per artifact: `HloModuleProto::from_text_file` →
//!   `XlaComputation::from_proto` → `client.compile` (cached) →
//!   `execute`.
//! * The host path must stay numerically cross-checkable against the
//!   artifacts — it shares the sampling planner and kernel dispatch with
//!   the serving stack, not a private reimplementation.
//! * Streamed feature handles are a host-backend feature: device
//!   artifacts receive one eagerly materialized tensor (the PJRT
//!   signature has no notion of lazy row-blocks).

mod artifacts;
mod backend;
mod dataset;
mod engine;
mod host;
mod infer;
pub mod ir;

pub use artifacts::{artifact_key, ArtifactKind, ArtifactMeta, DatasetMeta, InputSpec, Manifest};
pub use backend::Backend;
pub use dataset::{Dataset, Weights, GAT_PARAM_ORDER, GCN_PARAM_ORDER, SAGE_PARAM_ORDER};
pub use ir::{
    model_ir, param_order, validate_weights, AggregateKind, LayerOp, ModelVals, KNOWN_MODELS,
    SERVED_MODELS,
};
pub use engine::{Arg, Engine, ExecStats};
pub use host::{host_forward, host_supports};
pub use infer::{accuracy, run_forward, ForwardRequest, ForwardResult};
