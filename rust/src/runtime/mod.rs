//! The PJRT runtime — loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + `.nbt` tensors) and executes them
//! on the PJRT CPU client via the `xla` crate. This is the only module
//! that touches PJRT; everything above it deals in [`crate::tensor::Tensor`]s.
//!
//! Pipeline per artifact: `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` (cached) → `execute`.

mod artifacts;
mod backend;
mod dataset;
mod engine;
mod host;
mod infer;

pub use artifacts::{artifact_key, ArtifactKind, ArtifactMeta, DatasetMeta, InputSpec, Manifest};
pub use backend::Backend;
pub use dataset::{Dataset, Weights, GCN_PARAM_ORDER, SAGE_PARAM_ORDER};
pub use engine::{Arg, Engine, ExecStats};
pub use host::{host_forward, host_supports};
pub use infer::{accuracy, run_forward, ForwardRequest, ForwardResult};
