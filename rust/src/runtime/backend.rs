//! Execution backends — how a forward pass actually runs.
//!
//! The coordinator used to be hard-wired to the PJRT engine; now it
//! routes through a [`Backend`] so the same serving stack drives either
//! the compiled AOT artifacts (production) or the rust host substrate
//! (CPU-only machines, offline CI, the exec-layer integration tests).

use std::sync::Arc;

use anyhow::Result;

use crate::exec::{ExecEnv, ExecPlan};
use crate::tensor::Tensor;

use super::dataset::{Dataset, Weights};
use super::engine::Engine;
use super::host::host_forward;
use super::infer::{run_forward, ForwardRequest, ForwardResult};

/// Where forward passes execute.
#[derive(Clone)]
pub enum Backend {
    /// Compiled AOT artifacts through the PJRT engine (device sampling +
    /// on-device dequant — the paper's fused path).
    Pjrt(Arc<Engine>),
    /// The rust substrate: dispatched CPU SpMM + dense MLP. Needs no
    /// artifacts directory and no XLA runtime.
    Host,
}

impl Backend {
    /// True when aggregation happens on the host — such backends want the
    /// plan cache to carry a sampled ELL plan.
    pub fn aggregates_on_host(&self) -> bool {
        matches!(self, Backend::Host)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt(_) => "pjrt",
            Backend::Host => "host",
        }
    }

    /// Run one forward pass. `features` overrides the dataset tensor
    /// (the coordinator passes plan-cached features); `plan` is the
    /// route's cached execution plan (sampled ELL + operand profile),
    /// used by host aggregation only.
    pub fn forward(
        &self,
        ds: &Dataset,
        weights: &Weights,
        req: &ForwardRequest,
        features: Option<&Tensor>,
        plan: Option<&ExecPlan>,
        env: &ExecEnv,
    ) -> Result<ForwardResult> {
        match self {
            Backend::Pjrt(engine) => run_forward(engine, ds, weights, req, features),
            Backend::Host => host_forward(ds, weights, req, features, plan, env),
        }
    }
}

impl std::fmt::Debug for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}
