//! The PJRT engine: compile-once executable cache + instrumented execute.
//!
//! Safety note on `Send + Sync`: the `xla` crate's wrappers hold raw
//! pointers and are therefore `!Send` by default, but the underlying PJRT
//! CPU client and loaded executables are documented thread-safe in XLA
//! (concurrent `Execute` on one `PjRtLoadedExecutable` is the intended
//! multi-stream pattern, and `TfrtCpuClient` is internally synchronized).
//! We wrap them in [`Engine`] and assert `Send + Sync` so the coordinator
//! can execute from a worker pool; all `Literal` staging stays within the
//! calling thread.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

use super::artifacts::{ArtifactMeta, Manifest};

/// Timing breakdown of one artifact execution — the stages Fig. 3 plots.
///
/// PJRT executes asynchronously: `execute` measures dispatch, and the
/// device compute is absorbed into `fetch` (the output sync). Consumers
/// that want "compute time" should use `execute + fetch`; `transfer`
/// is the host→device staging of the fresh inputs.
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    /// Host→device staging (literal build + transfer) of fresh inputs.
    pub transfer: Duration,
    /// Execution dispatch (async; see struct docs).
    pub execute: Duration,
    /// Output sync + device→host fetch — includes the device compute.
    pub fetch: Duration,
}

impl ExecStats {
    pub fn total(&self) -> Duration {
        self.transfer + self.execute + self.fetch
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    meta: ArtifactMeta,
}

/// An artifact input: either staged fresh on every call (features,
/// strategy scalars) or cached on-device under a stable key (graph
/// structure, weights — the static majority of the input bytes).
pub enum Arg<'a> {
    Fresh(&'a Tensor),
    Cached(&'a str, &'a Tensor),
}

impl<'a> Arg<'a> {
    fn tensor(&self) -> &'a Tensor {
        match self {
            Arg::Fresh(t) | Arg::Cached(_, t) => t,
        }
    }
}

/// Compile-once, execute-many PJRT front end.
/// A staged device buffer plus the host literal backing it. PJRT's
/// host→device copy can be asynchronous, so the literal must stay alive
/// at least as long as the buffer may still be reading from it.
struct Staged {
    buffer: xla::PjRtBuffer,
    _literal: xla::Literal,
}

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Compiled>>>,
    /// Device-resident buffers for static inputs, keyed by caller key.
    buffers: Mutex<HashMap<String, Arc<Staged>>>,
}

// SAFETY: see module docs — PJRT CPU client/executables are thread-safe;
// per-call Literals never cross threads.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            buffers: Mutex::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Number of artifacts currently compiled.
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    fn compiled(&self, name: &str) -> Result<Arc<Compiled>> {
        if let Some(c) = self.cache.lock().unwrap().get(name) {
            return Ok(c.clone());
        }
        // Compile outside the lock (seconds-long; don't serialize callers
        // hitting different artifacts). A racing duplicate compile of the
        // same artifact is benign — last insert wins.
        let meta = self.manifest.artifact(name)?.clone();
        let hlo_path = meta
            .hlo_path
            .to_str()
            .context("non-utf8 artifact path")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&hlo_path)
            .with_context(|| format!("parsing HLO text {hlo_path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let compiled = Arc::new(Compiled { exe, meta });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Compile (or fetch cached) without executing — warm-up path.
    pub fn prepare(&self, name: &str) -> Result<()> {
        self.compiled(name).map(|_| ())
    }

    /// Validate inputs against the artifact signature, execute, and fetch
    /// the single (tupled) output as a host tensor.
    pub fn execute(&self, name: &str, inputs: &[&Tensor]) -> Result<(Tensor, ExecStats)> {
        let args: Vec<Arg> = inputs.iter().map(|t| Arg::Fresh(t)).collect();
        self.execute_args(name, &args)
    }

    /// Stage a tensor on device.
    ///
    /// Goes through a Literal rather than `buffer_from_host_raw_bytes`:
    /// the crate's raw-bytes path passes the `ElementType` discriminant
    /// where the C API expects a `PrimitiveType` value (S32 arrives as
    /// S16 and every buffer is half-sized). The literal is kept alive
    /// alongside the buffer because the host→device copy is async.
    fn stage(&self, t: &Tensor) -> Result<Staged> {
        let literal = t.to_literal()?;
        let buffer = self.client.buffer_from_host_literal(None, &literal)?;
        Ok(Staged { buffer, _literal: literal })
    }

    /// Device buffer for a cached input (staged once per key).
    fn cached_buffer(&self, key: &str, t: &Tensor) -> Result<Arc<Staged>> {
        if let Some(b) = self.buffers.lock().unwrap().get(key) {
            return Ok(b.clone());
        }
        let buf = Arc::new(self.stage(t)?);
        self.buffers.lock().unwrap().insert(key.to_string(), buf.clone());
        Ok(buf)
    }

    /// Execute with a mix of cached (device-resident) and fresh inputs —
    /// the hot path: graph structure + weights stay on device, only the
    /// per-request payload (features, scalars) crosses the link.
    pub fn execute_args(&self, name: &str, args: &[Arg]) -> Result<(Tensor, ExecStats)> {
        let compiled = self.compiled(name)?;
        let tensors: Vec<&Tensor> = args.iter().map(|a| a.tensor()).collect();
        validate_inputs(&compiled.meta, &tensors)?;
        let mut stats = ExecStats::default();

        let t0 = Instant::now();
        let mut buffers: Vec<Arc<Staged>> = Vec::with_capacity(args.len());
        for a in args {
            match a {
                Arg::Fresh(t) => buffers.push(Arc::new(self.stage(t)?)),
                Arg::Cached(key, t) => buffers.push(self.cached_buffer(key, t)?),
            }
        }
        stats.transfer = t0.elapsed();

        let t1 = Instant::now();
        let refs: Vec<&xla::PjRtBuffer> = buffers.iter().map(|b| &b.buffer).collect();
        let result = compiled.exe.execute_b(&refs)?;
        stats.execute = t1.elapsed();

        let t2 = Instant::now();
        let literal = result[0][0].to_literal_sync()?;
        // Artifacts are lowered with return_tuple=True → unwrap the 1-tuple.
        let out = literal.to_tuple1()?;
        let tensor = literal_to_tensor(&out)?;
        stats.fetch = t2.elapsed();
        Ok((tensor, stats))
    }

    /// Number of device-cached input buffers.
    pub fn cached_buffer_count(&self) -> usize {
        self.buffers.lock().unwrap().len()
    }
}

fn validate_inputs(meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != meta.inputs.len() {
        bail!(
            "{}: got {} inputs, artifact expects {} ({:?})",
            meta.name,
            inputs.len(),
            meta.inputs.len(),
            meta.inputs.iter().map(|i| i.name.as_str()).collect::<Vec<_>>()
        );
    }
    for (t, spec) in inputs.iter().zip(meta.inputs.iter()) {
        if t.dtype != spec.dtype {
            bail!(
                "{} input {:?}: dtype {:?} != expected {:?}",
                meta.name,
                spec.name,
                t.dtype,
                spec.dtype
            );
        }
        if t.shape != spec.shape {
            bail!(
                "{} input {:?}: shape {:?} != expected {:?}",
                meta.name,
                spec.name,
                t.shape,
                spec.shape
            );
        }
    }
    Ok(())
}

/// Convert a (non-tuple) literal back into a host [`Tensor`].
fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok(match shape.ty() {
        xla::ElementType::F32 => Tensor::from_f32(&dims, &lit.to_vec::<f32>()?),
        xla::ElementType::S32 => Tensor::from_i32(&dims, &lit.to_vec::<i32>()?),
        xla::ElementType::U8 => Tensor::from_u8(&dims, &lit.to_vec::<u8>()?),
        xla::ElementType::S64 => Tensor::from_i64(&dims, &lit.to_vec::<i64>()?),
        xla::ElementType::F64 => Tensor::from_f64(&dims, &lit.to_vec::<f64>()?),
        ty => bail!("unsupported output element type {ty:?}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::{ArtifactKind, InputSpec};
    use crate::tensor::DType;

    fn meta(inputs: Vec<InputSpec>) -> ArtifactMeta {
        ArtifactMeta {
            name: "t".into(),
            kind: ArtifactKind::Sampled,
            width: Some(16),
            inputs,
            hlo_path: "/dev/null".into(),
        }
    }

    #[test]
    fn input_validation() {
        let m = meta(vec![InputSpec { name: "x".into(), shape: vec![2, 2], dtype: DType::F32 }]);
        let good = Tensor::from_f32(&[2, 2], &[0.0; 4]);
        assert!(validate_inputs(&m, &[&good]).is_ok());
        let wrong_shape = Tensor::from_f32(&[4], &[0.0; 4]);
        assert!(validate_inputs(&m, &[&wrong_shape]).is_err());
        let wrong_dtype = Tensor::from_i32(&[2, 2], &[0; 4]);
        assert!(validate_inputs(&m, &[&wrong_dtype]).is_err());
        assert!(validate_inputs(&m, &[]).is_err());
    }
}
