//! Micro-benchmark harness — criterion is not in the offline registry, so
//! this provides the same core loop: warmup, timed iterations, and robust
//! statistics (median / p10 / p90), plus throughput helpers and a
//! markdown-ish report printer used by `cargo bench` targets.

use std::time::{Duration, Instant};

use crate::util::{fmt_duration, percentile};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    /// Items (e.g. nnz, bytes) per second at the median.
    pub fn throughput(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / self.median.as_secs_f64()
    }

    /// Flat JSON object for machine-readable baselines (`bench --json`).
    pub fn to_json(&self) -> crate::util::JsonValue {
        use crate::util::JsonValue;
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), JsonValue::Str(self.name.clone()));
        obj.insert("iters".to_string(), JsonValue::Num(self.iters as f64));
        obj.insert("p10_ns".to_string(), JsonValue::Num(self.p10.as_nanos() as f64));
        obj.insert("median_ns".to_string(), JsonValue::Num(self.median.as_nanos() as f64));
        obj.insert("p90_ns".to_string(), JsonValue::Num(self.p90.as_nanos() as f64));
        obj.insert("mean_ns".to_string(), JsonValue::Num(self.mean.as_nanos() as f64));
        JsonValue::Obj(obj)
    }
}

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop early once this much time has been spent measuring.
    pub budget: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            budget: Duration::from_secs(2),
        }
    }
}

impl Bencher {
    /// Quick preset for expensive end-to-end cases.
    pub fn heavy() -> Self {
        Self { warmup_iters: 1, min_iters: 5, max_iters: 50, budget: Duration::from_secs(5) }
    }

    /// Run `f` repeatedly; the closure's return value is black-boxed so
    /// the optimizer cannot delete the work.
    pub fn run<T>(&self, name: impl Into<String>, mut f: impl FnMut() -> T) -> BenchResult {
        for _ in 0..self.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.min_iters);
        let started = Instant::now();
        while samples.len() < self.max_iters
            && (samples.len() < self.min_iters || started.elapsed() < self.budget)
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed());
        }
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        BenchResult {
            name: name.into(),
            iters: samples.len(),
            median: percentile(&samples, 50.0),
            p10: percentile(&samples, 10.0),
            p90: percentile(&samples, 90.0),
            mean,
        }
    }
}

/// Prevent the optimizer from discarding a value (std::hint on stable).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a result table header.
pub fn print_header(title: &str) {
    println!("\n== {title} ==");
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}",
        "case", "iters", "p10", "median", "p90"
    );
}

/// Print one result row (optionally with a throughput annotation).
pub fn print_result(r: &BenchResult, throughput: Option<(&str, f64)>) {
    let tp = throughput
        .map(|(unit, v)| format!("  {:.3} {unit}", v))
        .unwrap_or_default();
    println!(
        "{:<44} {:>8} {:>12} {:>12} {:>12}{tp}",
        r.name,
        r.iters,
        fmt_duration(r.p10),
        fmt_duration(r.median),
        fmt_duration(r.p90),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let b = Bencher {
            warmup_iters: 1,
            min_iters: 5,
            max_iters: 20,
            budget: Duration::from_millis(200),
        };
        let r = b.run("sleep", || std::thread::sleep(Duration::from_millis(2)));
        assert!(r.median >= Duration::from_millis(2));
        assert!(r.iters >= 5);
        assert!(r.p90 >= r.p10);
    }

    #[test]
    fn respects_budget() {
        let b = Bencher {
            warmup_iters: 0,
            min_iters: 2,
            max_iters: 100_000,
            budget: Duration::from_millis(50),
        };
        let t0 = Instant::now();
        let r = b.run("spin", || (0..1000).sum::<u64>());
        assert!(t0.elapsed() < Duration::from_secs(2));
        assert!(r.iters >= 2);
    }

    #[test]
    fn json_roundtrips_through_the_codec() {
        let r = BenchResult {
            name: "case".into(),
            iters: 12,
            median: Duration::from_micros(5),
            p10: Duration::from_micros(4),
            p90: Duration::from_micros(9),
            mean: Duration::from_micros(6),
        };
        let text = r.to_json().to_string();
        let v = crate::util::parse_json(&text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "case");
        assert_eq!(v.get("median_ns").unwrap().as_usize().unwrap(), 5_000);
        assert_eq!(v.get("iters").unwrap().as_usize().unwrap(), 12);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "t".into(),
            iters: 1,
            median: Duration::from_secs(2),
            p10: Duration::ZERO,
            p90: Duration::ZERO,
            mean: Duration::from_secs(2),
        };
        assert!((r.throughput(4_000_000) - 2_000_000.0).abs() < 1.0);
    }
}
