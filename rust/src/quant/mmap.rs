//! Zero-copy `.nbt` reading — memory-map the dataset container and serve
//! tensor payloads as borrowed slices instead of buffered copies.
//!
//! The buffered loader ([`crate::tensor::read_nbt_tensor`]) copies the
//! whole feature payload into a fresh `Vec` on every cold route; at the
//! sizes the paper's Fig. 3 measures, that copy *is* the loading
//! bottleneck. [`MmapNbt`] maps the file read-only once, parses only the
//! container index, and then hands out `&[u8]` windows into the mapping —
//! the kernel's page cache becomes the feature cache, and INT8 feature
//! rows reach the dequant loop without ever being materialized as an
//! owned tensor.
//!
//! Rules of the road:
//! * payload slices are **byte** slices: `.nbt` payloads are unaligned,
//!   so `u8` tensors (the INT8 serving path) are zero-copy while wider
//!   dtypes must go through [`MmapNbt::tensor`], which copies into an
//!   aligned buffer — exactly the old buffered behavior;
//! * the mapping assumes the file is immutable while open. Artifacts are
//!   published atomically (temp file + rename, see
//!   [`crate::tensor::write_nbt`]), so a republish produces a *new* inode
//!   and live mappings stay valid;
//! * mapping can fail (platform without `mmap`, exotic filesystems,
//!   zero-length files). [`MmapNbt::open`] reports the error and callers
//!   fall back to the buffered reader — see
//!   [`FeatureStore::open`](crate::quant::FeatureStore::open).

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::tensor::{parse_nbt_index, DType, Tensor, TensorEntry};

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    //! The two raw syscalls we need, declared directly against the C
    //! library std already links (the offline registry has no `libc`
    //! crate). 64-bit unix only: the `off_t` argument is declared `i64`,
    //! which matches the LP64 ABI; other targets take the buffered
    //! fallback path instead of risking an ABI mismatch.
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const MAP_PRIVATE: c_int = 2;
    pub const MAP_FAILED: *mut c_void = usize::MAX as *mut c_void;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
    }
}

/// A read-only, private memory mapping of one file. Unmapped on drop.
struct Mapping {
    ptr: *const u8,
    len: usize,
}

// SAFETY: the mapping is PROT_READ + MAP_PRIVATE over a file our write
// path replaces only by rename (never truncates in place), so the bytes
// behind `ptr` are immutable for the mapping's lifetime — shared reads
// from any thread are safe.
unsafe impl Send for Mapping {}
unsafe impl Sync for Mapping {}

impl Mapping {
    #[cfg(all(unix, target_pointer_width = "64"))]
    fn of(file: &fs::File, len: usize) -> Result<Mapping> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            bail!("cannot map an empty file");
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == sys::MAP_FAILED {
            bail!("mmap failed: {}", std::io::Error::last_os_error());
        }
        Ok(Mapping { ptr: ptr as *const u8, len })
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn of(_file: &fs::File, _len: usize) -> Result<Mapping> {
        bail!("mmap is not available on this platform");
    }

    fn bytes(&self) -> &[u8] {
        // SAFETY: `ptr` spans exactly `len` mapped read-only bytes for as
        // long as `self` lives (unmapped only in Drop).
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl Drop for Mapping {
    fn drop(&mut self) {
        #[cfg(all(unix, target_pointer_width = "64"))]
        // SAFETY: `ptr`/`len` are exactly what mmap returned.
        unsafe {
            let _ = sys::munmap(self.ptr as *mut _, self.len);
        }
    }
}

/// A memory-mapped `.nbt` container: parsed index + zero-copy payload
/// access. Cheap to share behind an `Arc`; see the module docs for the
/// immutability contract.
pub struct MmapNbt {
    path: PathBuf,
    map: Mapping,
    entries: Vec<TensorEntry>,
}

impl MmapNbt {
    /// Map `path` read-only and parse the container index (no payload is
    /// copied or even touched — pages fault in lazily on first access).
    /// Errors when mapping is unsupported or the container is malformed;
    /// callers are expected to fall back to the buffered reader.
    pub fn open(path: impl AsRef<Path>) -> Result<MmapNbt> {
        let path = path.as_ref().to_path_buf();
        let file = fs::File::open(&path).with_context(|| format!("opening {}", path.display()))?;
        let len = file.metadata()?.len() as usize;
        let map = Mapping::of(&file, len).with_context(|| format!("mapping {}", path.display()))?;
        let entries =
            parse_nbt_index(map.bytes()).with_context(|| format!("indexing {}", path.display()))?;
        Ok(MmapNbt { path, map, entries })
    }

    /// The mapped file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total mapped bytes (the whole container).
    pub fn file_len(&self) -> usize {
        self.map.len
    }

    /// Names in container order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|e| e.name.as_str())
    }

    /// Whether the container holds a tensor called `name`.
    pub fn contains(&self, name: &str) -> bool {
        self.entries.iter().any(|e| e.name == name)
    }

    /// Index entry (dtype/shape/extent) for `name`.
    pub fn entry(&self, name: &str) -> Result<&TensorEntry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .with_context(|| format!("tensor {name:?} not in {}", self.path.display()))
    }

    /// The whole payload of `name`, zero-copy.
    pub fn bytes(&self, name: &str) -> Result<&[u8]> {
        let e = self.entry(name)?;
        Ok(&self.map.bytes()[e.offset..e.offset + e.len])
    }

    /// Rows `row0 .. row0 + n_rows` of a 2-D tensor, zero-copy. This is
    /// the streaming pipeline's unit of access: a sampled row-block's
    /// quantized bytes, straight out of the page cache.
    pub fn row_bytes(&self, name: &str, row0: usize, n_rows: usize) -> Result<&[u8]> {
        let e = self.entry(name)?;
        if e.shape.len() != 2 {
            bail!("tensor {name:?} is not 2-D (shape {:?})", e.shape);
        }
        let (rows, cols) = (e.shape[0], e.shape[1]);
        if row0 + n_rows > rows {
            bail!("rows {row0}..{} out of range (tensor has {rows})", row0 + n_rows);
        }
        let row_bytes = cols * e.dtype.size();
        let lo = e.offset + row0 * row_bytes;
        Ok(&self.map.bytes()[lo..lo + n_rows * row_bytes])
    }

    /// Materialize `name` as an owned, max-aligned [`Tensor`] — the
    /// compatibility path for dtypes wider than `u8` (payloads in the map
    /// are unaligned) and for consumers that need ownership.
    pub fn tensor(&self, name: &str) -> Result<Tensor> {
        let e = self.entry(name)?;
        let mut data = vec![0u8; e.len];
        data.copy_from_slice(&self.map.bytes()[e.offset..e.offset + e.len]);
        Ok(Tensor { dtype: e.dtype, shape: e.shape.clone(), data })
    }

    /// Like [`MmapNbt::bytes`] but validating the dtype first — the
    /// INT8 zero-copy view.
    pub fn u8_view(&self, name: &str) -> Result<&[u8]> {
        let e = self.entry(name)?;
        if e.dtype != DType::U8 {
            bail!("tensor {name:?} is {:?}, wanted U8 for a zero-copy view", e.dtype);
        }
        self.bytes(name)
    }
}

impl std::fmt::Debug for MmapNbt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapNbt")
            .field("path", &self.path)
            .field("file_len", &self.map.len)
            .field("tensors", &self.entries.len())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{write_nbt, NbtFile};

    fn fixture(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mmap_nbt_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut f = NbtFile::new();
        f.insert("feat", Tensor::from_f32(&[4, 3], &(0..12).map(|i| i as f32).collect::<Vec<_>>()));
        let q: Vec<u8> = (0..12).map(|i| i as u8 * 3).collect();
        f.insert("featq", Tensor::from_u8(&[4, 3], &q));
        f.insert("qrange", Tensor::from_f32(&[2], &[0.0, 1.0]));
        let p = dir.join("fixture.nbt");
        write_nbt(&p, &f).unwrap();
        p
    }

    // The container in CI is 64-bit linux; elsewhere the mapping path is
    // compiled out and `open` must fail cleanly (the fallback contract).
    #[cfg(all(unix, target_pointer_width = "64"))]
    #[test]
    fn mapped_views_match_buffered_reads() {
        let p = fixture("views");
        let m = MmapNbt::open(&p).unwrap();
        let buffered = crate::tensor::read_nbt(&p).unwrap();
        assert_eq!(m.names().collect::<Vec<_>>(), vec!["feat", "featq", "qrange"]);
        assert!(m.contains("featq") && !m.contains("nope"));
        // Zero-copy u8 view equals the buffered payload byte-for-byte.
        assert_eq!(m.u8_view("featq").unwrap(), buffered.get("featq").unwrap().as_u8().unwrap());
        // Aligned materialization round-trips wider dtypes.
        let t = m.tensor("feat").unwrap();
        assert_eq!(t.as_f32().unwrap(), buffered.get("feat").unwrap().as_f32().unwrap());
        assert_eq!(t.shape, vec![4, 3]);
        // Row-block slicing picks exactly the middle rows.
        let rows = m.row_bytes("featq", 1, 2).unwrap();
        assert_eq!(rows, &buffered.get("featq").unwrap().as_u8().unwrap()[3..9]);
        assert!(m.file_len() > 0);
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    #[test]
    fn row_bounds_and_shape_are_enforced() {
        let p = fixture("bounds");
        let m = MmapNbt::open(&p).unwrap();
        assert!(m.row_bytes("featq", 3, 2).is_err(), "past-the-end row range");
        assert!(m.row_bytes("qrange", 0, 1).is_err(), "1-D tensor has no rows");
        assert!(m.u8_view("feat").is_err(), "f32 payload must not get a u8 view");
        assert!(m.bytes("missing").is_err());
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    #[test]
    fn rejects_malformed_containers() {
        let dir = std::env::temp_dir().join(format!("mmap_nbt_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("garbage.nbt");
        std::fs::write(&p, b"this is not a container at all").unwrap();
        assert!(MmapNbt::open(&p).is_err());
        let empty = dir.join("empty.nbt");
        std::fs::write(&empty, b"").unwrap();
        assert!(MmapNbt::open(&empty).is_err(), "zero-length file cannot be mapped");
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    #[test]
    fn open_fails_cleanly_without_mmap() {
        let p = fixture("nommap");
        assert!(MmapNbt::open(&p).is_err());
    }
}
