//! The feature store — the data-loading stage whose cost Fig. 3 shows
//! dominating GNN inference, and which the paper's INT8 path shrinks by
//! 50.91–70.51 % (Table 3).
//!
//! `FeatureStore` owns the on-disk feature tensors for one dataset
//! (fp32 and u8 variants, both inside the dataset `.nbt`) and serves
//! them two ways:
//!
//! * [`FeatureStore::load`] — the eager path: one instrumented storage
//!   read producing an owned tensor (what Table 3 times per inference);
//! * [`FeatureStore::stage`] — the streaming path: when the container is
//!   memory-mapped and the precision is INT8, returns a zero-copy
//!   [`FeatureHandle`] whose rows dequantize lazily, per sampled
//!   row-block, inside the exec worker that consumes them
//!   ([`Features::Streamed`]). Falls back to `load` when mmap is
//!   unavailable or fp32 was requested.
//!
//! The store watches the file identity: datasets are republished
//! atomically (temp file + rename), and the next cold `load`/`stage`
//! after a republish re-opens metadata and mapping, so plan-cache
//! invalidation really does reload fresh bytes. Handles staged earlier
//! keep serving the publication they were staged from (their mapping
//! pins the old inode) — exactly what an in-flight request wants.
//!
//! Every byte that leaves the store — eager loads and streamed
//! row-blocks alike — lands in the monotonic [`LoadTotals`] counters, so
//! concurrent prefetchers and workers can be audited without locks.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

use anyhow::{bail, Context, Result};

use crate::tensor::{read_nbt, read_nbt_tensor, DType, Tensor};

use super::mmap::MmapNbt;
use super::scalar::{dequantize_into, ChunkedParams, QuantParams};

/// Which representation to load from storage. INT8 on-device dequant is
/// the serving default — the paper's quantized path; fp32 is the opt-in
/// baseline.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full-precision features (AFS/SFS rows of Table 3).
    F32,
    /// INT8 features, dequantized on device (quantization-based AES-SpMM).
    #[default]
    U8Device,
    /// INT8 features, dequantized on the host (CPU baseline path).
    U8Host,
    /// True INT8 compute: the u8 codes feed the integer-accumulating
    /// SpMM kernels directly (`crate::spmm::ell_spmm_i8`) — no fp32
    /// feature block ever materializes on the aggregation path.
    I8Compute,
}

impl Precision {
    /// Short label used in route keys and reports.
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::U8Device => "u8-device",
            Precision::U8Host => "u8-host",
            Precision::I8Compute => "i8-compute",
        }
    }

    /// Parse a [`Precision::name`] label back (CLI flags, report diffs).
    pub fn from_name(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "u8-device" => Some(Precision::U8Device),
            "u8-host" => Some(Precision::U8Host),
            "i8-compute" => Some(Precision::I8Compute),
            _ => None,
        }
    }
}

/// How feature bytes reached the host.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum LoadSource {
    /// Zero-copy slices out of a memory-mapped container.
    Mmap,
    /// The buffered fallback: a seek-past selective read per load.
    #[default]
    Buffered,
}

impl LoadSource {
    /// Short label for reports.
    pub fn name(self) -> &'static str {
        match self {
            LoadSource::Mmap => "mmap",
            LoadSource::Buffered => "buffered",
        }
    }
}

/// Timing + volume breakdown of one feature load.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    /// Bytes read from storage for the feature tensor. Zero for a
    /// streamed stage — streamed bytes accrue in [`LoadTotals`] as
    /// row-blocks are actually touched.
    pub bytes_read: usize,
    /// Wall time of the storage read + container decode (for a streamed
    /// stage: the index lookup + handle construction).
    pub read_time: Duration,
    /// Host-side dequantization time (zero when no host dequant ran;
    /// lazy for streamed handles, where it accrues in [`LoadTotals`]
    /// instead).
    pub dequant_time: Duration,
    /// Whether the bytes came off an mmap or the buffered fallback.
    pub source: LoadSource,
}

impl LoadStats {
    /// Read + host-dequant wall time of this load.
    pub fn total(&self) -> Duration {
        self.read_time + self.dequant_time
    }
}

/// Monotonic lifetime counters, updated atomically at every staging site.
///
/// The previous design filled a per-call `LoadStats` and left callers to
/// aggregate, which under the concurrent prefetcher meant bytes-read and
/// staging time were accumulated non-atomically (read-modify-write over
/// plain fields). Here each counter is its own `AtomicU64` bumped with
/// `fetch_add`: individual counters never go backwards and never lose
/// increments, at the cost of the pair being only eventually consistent
/// with each other — fine for throughput accounting.
#[derive(Debug, Default)]
struct StoreCounters {
    loads: AtomicU64,
    bytes_read: AtomicU64,
    stage_nanos: AtomicU64,
}

impl StoreCounters {
    fn record(&self, bytes: usize, elapsed: Duration) {
        self.bytes_read.fetch_add(bytes as u64, Ordering::Relaxed);
        self.stage_nanos.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// Lifetime totals across every load and streamed row-block of one store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LoadTotals {
    /// Storage-hitting operations (`load` + `stage` calls).
    pub loads: u64,
    /// Bytes staged to the host: eager payload reads plus every streamed
    /// row-block actually dequantized.
    pub bytes_read: u64,
    /// Cumulative staging wall time (reads + dequantization), summed
    /// across threads — overlapped work counts once per worker.
    pub stage_time: Duration,
}

/// Loaded features ready for the executor.
#[derive(Clone, Debug)]
pub enum Features {
    /// An owned fp32 tensor (eager fp32 load or host-side dequant).
    Dense(Tensor),
    /// An owned u8 tensor plus its single Eq. 2 range (device dequant;
    /// only produced for globally-calibrated containers — see
    /// [`FeatureStore::load`]).
    Quantized {
        /// The INT8 payload.
        q: Tensor,
        /// The range the payload was encoded with.
        params: QuantParams,
    },
    /// A zero-copy handle over the memory-mapped INT8 rows; dequantizes
    /// lazily, per row-block, inside the consumer.
    Streamed(FeatureHandle),
}

/// A zero-copy handle to one dataset's quantized feature rows.
///
/// Cheap to clone (two `Arc`s); lives inside cached
/// [`ExecPlan`](crate::exec::ExecPlan)s, so warm routes hold a window
/// into the page cache rather than a materialized tensor. Row-blocks are
/// dequantized on demand with per-chunk ranges via
/// [`FeatureHandle::fill_rows_f32`], which also charges the streamed
/// bytes and time to the owning store's [`LoadTotals`]. A handle pins
/// the publication it was staged from; republished datasets reach new
/// plans via the store, not via live handles.
#[derive(Clone, Debug)]
pub struct FeatureHandle {
    nbt: Arc<MmapNbt>,
    counters: Arc<StoreCounters>,
    n_rows: usize,
    feat_dim: usize,
    params: ChunkedParams,
}

impl FeatureHandle {
    /// Feature rows available.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Feature dimension (columns per row).
    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }

    /// The per-chunk dequantization ranges.
    pub fn params(&self) -> &ChunkedParams {
        &self.params
    }

    /// Size of the full quantized payload in bytes.
    pub fn byte_len(&self) -> usize {
        self.n_rows * self.feat_dim
    }

    /// The quantized bytes of rows `row0 .. row0 + n_rows`, zero-copy.
    ///
    /// Panics if the range exceeds [`FeatureHandle::n_rows`] — callers
    /// derive block bounds from this handle, so an overrun is a bug, not
    /// an I/O condition (the payload itself was validated at stage time).
    pub fn quantized_rows(&self, row0: usize, n_rows: usize) -> &[u8] {
        self.nbt
            .row_bytes("featq", row0, n_rows)
            .expect("featq extent validated when the handle was staged")
    }

    /// Dequantize rows `row0 ..` into `out` (whose length fixes the block
    /// height: `out.len() / feat_dim` rows). The streamed hot path: one
    /// borrow from the page cache, one LUT pass per chunk segment, and an
    /// atomic charge to the store's totals.
    pub fn fill_rows_f32(&self, row0: usize, out: &mut [f32]) {
        if self.feat_dim == 0 || out.is_empty() {
            return;
        }
        let t0 = Instant::now();
        let rows = out.len() / self.feat_dim;
        assert_eq!(out.len(), rows * self.feat_dim, "out is not whole feature rows");
        let q = self.quantized_rows(row0, rows);
        self.params.dequantize_rows_into(q, row0, self.feat_dim, out);
        self.counters.record(q.len(), t0.elapsed());
    }

    /// Materialize the whole tensor as fp32 through the same per-chunk
    /// path (compat for consumers that need ownership; counts as one
    /// full-tensor stage in the totals).
    pub fn to_dense(&self) -> Tensor {
        let mut out = vec![0.0f32; self.n_rows * self.feat_dim];
        self.fill_rows_f32(0, &mut out);
        Tensor::from_f32(&[self.n_rows, self.feat_dim], &out)
    }
}

/// Identity of the publication a snapshot was built from. Atomic
/// republication (temp file + rename) changes the inode — and usually
/// mtime/length — which is how cold loads detect it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct FileId {
    len: u64,
    mtime: Option<SystemTime>,
    ino: u64,
}

impl FileId {
    fn of(path: &Path) -> Option<FileId> {
        let md = std::fs::metadata(path).ok()?;
        #[cfg(unix)]
        let ino = std::os::unix::fs::MetadataExt::ino(&md);
        #[cfg(not(unix))]
        let ino = 0;
        Some(FileId { len: md.len(), mtime: md.modified().ok(), ino })
    }
}

/// One publication of the dataset file: parsed metadata + the reader.
struct Snapshot {
    shape: Vec<usize>,
    params: QuantParams,
    chunked: ChunkedParams,
    /// The zero-copy reader; `None` means every access takes the
    /// buffered fallback (`read_nbt_tensor`).
    mapped: Option<Arc<MmapNbt>>,
    identity: Option<FileId>,
}

impl Snapshot {
    fn build(path: &Path, try_mmap: bool) -> Result<Snapshot> {
        // Stat before parsing: if a rename lands between the stat and the
        // read, the stale identity makes the *next* cold load rebuild
        // again — an extra reopen, never stale data served as fresh.
        let identity = FileId::of(path);
        let mapped = if try_mmap { MmapNbt::open(path).ok().map(Arc::new) } else { None };
        let (shape, qrange, qchunks) = match &mapped {
            Some(m) => (
                m.entry("feat")?.shape.clone(),
                m.tensor("qrange")?,
                if m.contains("qchunks") { Some(m.tensor("qchunks")?) } else { None },
            ),
            None => {
                let nbt = read_nbt(path)?;
                (
                    nbt.get("feat")?.shape.clone(),
                    nbt.get("qrange")?.clone(),
                    nbt.get("qchunks").ok().cloned(),
                )
            }
        };
        let qr = qrange.as_f32()?;
        let params = QuantParams { x_min: qr[0], x_max: qr[1] };
        let n_rows = shape.first().copied().unwrap_or(0);
        let chunked = match qchunks {
            Some(t) => {
                let pairs = t.as_f32()?;
                let chunks = pairs
                    .chunks_exact(2)
                    .map(|p| QuantParams { x_min: p[0], x_max: p[1] })
                    .collect();
                ChunkedParams::from_chunks(n_rows, chunks)
                    .with_context(|| format!("qchunks of {}", path.display()))?
            }
            None => ChunkedParams::uniform(n_rows, params),
        };
        Ok(Snapshot { shape, params, chunked, mapped, identity })
    }

    fn source(&self) -> LoadSource {
        if self.mapped.is_some() {
            LoadSource::Mmap
        } else {
            LoadSource::Buffered
        }
    }
}

/// One dataset's feature storage.
pub struct FeatureStore {
    path: PathBuf,
    try_mmap: bool,
    snapshot: Mutex<Arc<Snapshot>>,
    counters: Arc<StoreCounters>,
}

impl FeatureStore {
    /// Open the store for a dataset `.nbt`: memory-map the container when
    /// the platform allows it (falling back silently to buffered reads
    /// otherwise) and read only the metadata — feature shape, the global
    /// `qrange`, and the optional per-chunk `qchunks` calibration.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        Self::open_inner(path.as_ref(), true)
    }

    /// Open with the mmap reader disabled: every load takes the buffered
    /// seek-past path. Benches use this to time the fallback; it is also
    /// the behavior [`FeatureStore::open`] degrades to without mmap.
    pub fn open_buffered(path: impl AsRef<Path>) -> Result<Self> {
        Self::open_inner(path.as_ref(), false)
    }

    fn open_inner(path: &Path, try_mmap: bool) -> Result<Self> {
        let snapshot = Arc::new(Snapshot::build(path, try_mmap)?);
        Ok(Self {
            path: path.to_path_buf(),
            try_mmap,
            snapshot: Mutex::new(snapshot),
            counters: Arc::new(StoreCounters::default()),
        })
    }

    /// The live publication; re-opened when the file on disk changed.
    /// Cold paths only — warm routes never reach the store at all.
    fn current(&self) -> Arc<Snapshot> {
        let mut snap = self.snapshot.lock().unwrap();
        let on_disk = FileId::of(&self.path);
        if on_disk.is_some() && on_disk != snap.identity {
            // Republished: reopen metadata + mapping so invalidated
            // routes rebuild from fresh bytes. If the rebuild fails
            // (mid-publish race), keep serving the previous publication;
            // the next cold load retries.
            if let Ok(next) = Snapshot::build(&self.path, self.try_mmap) {
                *snap = Arc::new(next);
            }
        }
        snap.clone()
    }

    /// How many times the store has hit storage (eager loads + stages).
    pub fn load_count(&self) -> u64 {
        self.counters.loads.load(Ordering::Relaxed)
    }

    /// Monotonic lifetime totals — safe to read while loads and streamed
    /// dequants are in flight on other threads.
    pub fn totals(&self) -> LoadTotals {
        LoadTotals {
            loads: self.counters.loads.load(Ordering::Relaxed),
            bytes_read: self.counters.bytes_read.load(Ordering::Relaxed),
            stage_time: Duration::from_nanos(self.counters.stage_nanos.load(Ordering::Relaxed)),
        }
    }

    /// Feature tensor shape (`[n_rows, feat_dim]`) of the last-opened
    /// publication.
    pub fn shape(&self) -> Vec<usize> {
        self.snapshot.lock().unwrap().shape.clone()
    }

    /// The global (envelope) quantization range of the last-opened
    /// publication.
    pub fn params(&self) -> QuantParams {
        self.snapshot.lock().unwrap().params
    }

    /// The per-chunk calibration (uniform when the container carries only
    /// the legacy global `qrange`).
    pub fn chunk_params(&self) -> ChunkedParams {
        self.snapshot.lock().unwrap().chunked.clone()
    }

    /// Which path feature bytes take out of this store.
    pub fn source(&self) -> LoadSource {
        self.snapshot.lock().unwrap().source()
    }

    /// Load features eagerly at the requested precision, instrumented.
    ///
    /// Note the payload is re-staged per call by design: this models the
    /// paper's per-inference feature loading (storage → host → device),
    /// which is exactly what Table 3 times. The executor keeps graph
    /// structure cached; features are the per-request payload. Serving
    /// paths that want the copy off the critical path use
    /// [`FeatureStore::stage`] instead.
    ///
    /// `U8Device` returns [`Features::Quantized`] only for
    /// globally-calibrated containers; chunk-encoded payloads have no
    /// single-range u8 form a device kernel could decode (Eq. 2 takes one
    /// range), so they decode host-side with the per-chunk ranges rather
    /// than shipping bytes that would dequantize wrongly.
    pub fn load(&self, precision: Precision) -> Result<(Features, LoadStats)> {
        let snap = self.current();
        self.load_from(&snap, precision)
    }

    fn load_from(&self, snap: &Snapshot, precision: Precision) -> Result<(Features, LoadStats)> {
        self.counters.loads.fetch_add(1, Ordering::Relaxed);
        let mut stats = LoadStats { source: snap.source(), ..LoadStats::default() };
        let t0 = Instant::now();
        let key = match precision {
            Precision::F32 => "feat",
            _ => "featq",
        };
        // Selective read: only the requested tensor's bytes move (a seek
        // -past read, or a copy out of the map), so the INT8 path really
        // stages 4x fewer bytes.
        let tensor = match &snap.mapped {
            Some(m) => m.tensor(key).context("feature tensor missing")?,
            None => read_nbt_tensor(&self.path, key).context("feature tensor missing")?,
        };
        stats.bytes_read = tensor.byte_len();
        stats.read_time = t0.elapsed();

        let feats = match precision {
            Precision::F32 => Features::Dense(tensor),
            Precision::U8Device | Precision::I8Compute if snap.chunked.n_chunks() <= 1 => {
                Features::Quantized { q: tensor, params: snap.params }
            }
            // U8Host — and U8Device/I8Compute over a chunk-encoded
            // payload, which has no single-range u8 form a single-range
            // consumer could decode — dequantize host-side with the
            // ranges the payload was actually encoded with. (I8Compute
            // then degrades to the fp32 aggregation path; the streaming
            // stage keeps the codes + per-chunk ranges together, which
            // is why i8-compute serving prefers `stage`.)
            _ => {
                let t1 = Instant::now();
                let q = tensor.as_u8()?;
                let mut out = vec![0.0f32; q.len()];
                if snap.chunked.n_chunks() > 1 && snap.shape.len() == 2 {
                    snap.chunked.dequantize_rows_into(q, 0, snap.shape[1], &mut out);
                } else {
                    dequantize_into(q, snap.params, &mut out);
                }
                stats.dequant_time = t1.elapsed();
                Features::Dense(Tensor::from_f32(&tensor.shape, &out))
            }
        };
        self.counters.record(stats.bytes_read, stats.total());
        Ok((feats, stats))
    }

    /// Stage features for serving — the streaming path.
    ///
    /// With the mmap reader available and an INT8 precision, returns a
    /// [`Features::Streamed`] handle: no payload bytes move now;
    /// row-blocks dequantize lazily (per-chunk Eq. 2) inside whichever
    /// exec worker consumes them. Anything else falls back to the eager
    /// [`FeatureStore::load`].
    pub fn stage(&self, precision: Precision) -> Result<(Features, LoadStats)> {
        let snap = self.current();
        let Some(m) = &snap.mapped else { return self.load_from(&snap, precision) };
        if matches!(precision, Precision::F32) {
            return self.load_from(&snap, precision);
        }
        self.counters.loads.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let entry = m.entry("featq").context("featq missing — quantize the dataset")?;
        if entry.dtype != DType::U8 {
            bail!("featq is {:?}, expected u8", entry.dtype);
        }
        if entry.shape != snap.shape || snap.shape.len() != 2 {
            bail!("featq shape {:?} disagrees with feat shape {:?}", entry.shape, snap.shape);
        }
        let handle = FeatureHandle {
            nbt: m.clone(),
            counters: self.counters.clone(),
            n_rows: snap.shape[0],
            feat_dim: snap.shape[1],
            params: snap.chunked.clone(),
        };
        let stats = LoadStats {
            bytes_read: 0,
            read_time: t0.elapsed(),
            dequant_time: Duration::ZERO,
            source: LoadSource::Mmap,
        };
        self.counters.record(0, stats.read_time);
        Ok((Features::Streamed(handle), stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize;
    use crate::tensor::{write_nbt, NbtFile};

    const N: usize = 64;
    const F: usize = 16;

    fn write_store_values(dir: &Path, chunked: Option<usize>, phase: f32) -> PathBuf {
        let feat: Vec<f32> = (0..N * F).map(|i| (i as f32 * 0.37 + phase).sin()).collect();
        let p = QuantParams::of(&feat);
        let mut nbt = NbtFile::new();
        nbt.insert("feat", Tensor::from_f32(&[N, F], &feat));
        nbt.insert("qrange", Tensor::from_f32(&[2], &[p.x_min, p.x_max]));
        match chunked {
            Some(rpc) => {
                let c = ChunkedParams::of_rows(&feat, N, F, rpc);
                let pairs: Vec<f32> = c.chunks().iter().flat_map(|q| [q.x_min, q.x_max]).collect();
                nbt.insert("featq", Tensor::from_u8(&[N, F], &c.quantize_rows(&feat, F)));
                nbt.insert("qchunks", Tensor::from_f32(&[c.n_chunks(), 2], &pairs));
            }
            None => {
                nbt.insert("featq", Tensor::from_u8(&[N, F], &quantize(&feat, p)));
            }
        }
        let path = dir.join("store_test.nbt");
        write_nbt(&path, &nbt).unwrap();
        path
    }

    fn write_store(dir: &Path, chunked: Option<usize>) -> PathBuf {
        write_store_values(dir, chunked, 0.0)
    }

    fn make_store(dir: &Path) -> FeatureStore {
        FeatureStore::open(write_store(dir, None)).unwrap()
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fstore_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn precision_names_roundtrip() {
        for p in [
            Precision::F32,
            Precision::U8Device,
            Precision::U8Host,
            Precision::I8Compute,
        ] {
            assert_eq!(Precision::from_name(p.name()), Some(p));
        }
        assert_eq!(Precision::from_name("int8"), None);
        assert_eq!(Precision::default(), Precision::U8Device);
    }

    #[test]
    fn f32_load_reads_4x_the_bytes() {
        let store = make_store(&tmp("bytes"));
        let (_, s32) = store.load(Precision::F32).unwrap();
        let (_, s8) = store.load(Precision::U8Device).unwrap();
        assert_eq!(s32.bytes_read, 4 * s8.bytes_read);
        assert_eq!(s8.dequant_time, Duration::ZERO);
    }

    #[test]
    fn host_dequant_approximates_f32() {
        let store = make_store(&tmp("dequant"));
        let (f32_feats, _) = store.load(Precision::F32).unwrap();
        let (host_feats, stats) = store.load(Precision::U8Host).unwrap();
        let (Features::Dense(a), Features::Dense(b)) = (f32_feats, host_feats) else {
            panic!("expected dense features");
        };
        let bound = crate::quant::max_quant_error(store.params()) + 1e-6;
        for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
            assert!((x - y).abs() <= bound);
        }
        assert!(stats.dequant_time > Duration::ZERO);
    }

    #[test]
    fn quantized_load_carries_params() {
        let store = make_store(&tmp("params"));
        let (f, _) = store.load(Precision::U8Device).unwrap();
        match f {
            Features::Quantized { q, params } => {
                assert_eq!(q.shape, store.shape());
                assert_eq!(params, store.params());
            }
            _ => panic!("expected quantized features"),
        }
    }

    #[test]
    fn buffered_fallback_matches_mapped_reads() {
        let dir = tmp("fallback");
        let path = write_store(&dir, None);
        let mapped = FeatureStore::open(&path).unwrap();
        let buffered = FeatureStore::open_buffered(&path).unwrap();
        assert_eq!(buffered.source(), LoadSource::Buffered);
        let (bf, bs) = buffered.load(Precision::F32).unwrap();
        let (mf, ms) = mapped.load(Precision::F32).unwrap();
        assert_eq!(bs.source, LoadSource::Buffered);
        let (Features::Dense(a), Features::Dense(b)) = (bf, mf) else { panic!() };
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
        assert_eq!(bs.bytes_read, ms.bytes_read, "same payload either way");
        // The buffered store's stage() degrades to an eager load.
        let (f, s) = buffered.stage(Precision::U8Device).unwrap();
        assert!(matches!(f, Features::Quantized { .. }));
        assert!(s.bytes_read > 0);
    }

    #[test]
    fn staged_handle_is_lazy_and_matches_eager_dequant() {
        let dir = tmp("staged");
        let path = write_store(&dir, Some(8));
        let store = FeatureStore::open(&path).unwrap();
        if store.source() != LoadSource::Mmap {
            return; // platform without mmap: stage() == load(), covered above
        }
        let before = store.totals();
        let (f, stats) = store.stage(Precision::U8Device).unwrap();
        let Features::Streamed(h) = f else { panic!("mmap store must stream INT8") };
        assert_eq!(stats.bytes_read, 0, "staging moves no payload bytes");
        assert_eq!(stats.source, LoadSource::Mmap);
        assert_eq!((h.n_rows(), h.feat_dim()), (N, F));
        assert_eq!(store.totals().bytes_read, before.bytes_read, "no bytes until a block is read");

        // Lazy per-block dequant equals the eager host dequant exactly.
        let (eager, _) = store.load(Precision::U8Host).unwrap();
        let Features::Dense(eager) = eager else { panic!() };
        let mut lazy = vec![0.0f32; N * F];
        for row0 in (0..N).step_by(8) {
            h.fill_rows_f32(row0, &mut lazy[row0 * F..(row0 + 8) * F]);
        }
        assert_eq!(&lazy, eager.as_f32().unwrap());
        // ...and the streamed bytes were charged to the totals.
        assert_eq!(
            store.totals().bytes_read - before.bytes_read,
            (2 * N * F) as u64, // one streamed pass + the eager u8 load
        );
        assert_eq!(h.to_dense().as_f32().unwrap(), eager.as_f32().unwrap());
    }

    #[test]
    fn chunked_u8device_load_decodes_host_side() {
        // A chunk-encoded payload has no single-range u8 representation:
        // the eager U8Device path must decode with the per-chunk ranges,
        // never ship codes that a single-range consumer would misread.
        let dir = tmp("chunked_dev");
        let path = write_store(&dir, Some(4));
        let stores = [
            FeatureStore::open(&path).unwrap(),
            FeatureStore::open_buffered(&path).unwrap(),
        ];
        for store in stores {
            let (orig, _) = store.load(Precision::F32).unwrap();
            let (dev, _) = store.load(Precision::U8Device).unwrap();
            let Features::Dense(orig) = orig else { panic!() };
            let Features::Dense(dev) = dev else {
                panic!("chunk-encoded U8Device must decode host-side, got {dev:?}")
            };
            let bound = store.chunk_params().max_error() + 1e-6;
            for (x, y) in orig.as_f32().unwrap().iter().zip(dev.as_f32().unwrap()) {
                assert!((x - y).abs() <= bound, "{x} vs {y} (bound {bound})");
            }
        }
    }

    #[test]
    fn chunked_store_tightens_the_error_bound() {
        let dir = tmp("chunked");
        let path = write_store(&dir, Some(4));
        let store = FeatureStore::open(&path).unwrap();
        assert_eq!(store.chunk_params().n_chunks(), N / 4);
        assert!(store.chunk_params().max_error() <= crate::quant::max_quant_error(store.params()));
        // U8Host dequant through the chunked path stays within the
        // per-chunk bound of the original data.
        let (dense, _) = store.load(Precision::F32).unwrap();
        let (host, _) = store.load(Precision::U8Host).unwrap();
        let (Features::Dense(a), Features::Dense(b)) = (dense, host) else { panic!() };
        let bound = store.chunk_params().max_error() + 1e-6;
        for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
            assert!((x - y).abs() <= bound);
        }
    }

    #[test]
    fn republished_file_reaches_the_next_cold_load() {
        let dir = tmp("republish");
        let path = write_store_values(&dir, None, 0.0);
        let store = FeatureStore::open(&path).unwrap();
        let (v1, _) = store.load(Precision::F32).unwrap();
        let Features::Dense(v1) = v1 else { panic!() };

        // A live handle (if streaming) pins the first publication.
        let staged = store.stage(Precision::U8Device).unwrap().0;

        // Atomic republish: same path, new inode, different values.
        write_store_values(&dir, None, 1.0);
        let (v2, _) = store.load(Precision::F32).unwrap();
        let Features::Dense(v2) = v2 else { panic!() };
        assert_ne!(
            v1.as_f32().unwrap(),
            v2.as_f32().unwrap(),
            "cold load after republish must serve the new bytes"
        );

        if let Features::Streamed(h) = staged {
            let old = h.to_dense();
            let bound = crate::quant::max_quant_error(QuantParams::of(v1.as_f32().unwrap())) + 1e-5;
            for (x, y) in v1.as_f32().unwrap().iter().zip(old.as_f32().unwrap()) {
                assert!((x - y).abs() <= bound, "old handle must keep serving its publication");
            }
        }
    }

    #[test]
    fn totals_stay_monotonic_under_concurrent_staging() {
        let dir = tmp("monotonic");
        let store = Arc::new(FeatureStore::open(write_store(&dir, None)).unwrap());
        let done = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let loaders: Vec<_> = (0..4)
            .map(|_| {
                let store = store.clone();
                std::thread::spawn(move || {
                    for _ in 0..8 {
                        store.load(Precision::U8Host).unwrap();
                    }
                })
            })
            .collect();
        // Poll while the loaders race: every observation must be
        // non-decreasing in every counter.
        let mut last = store.totals();
        while !done.load(Ordering::Relaxed) {
            let now = store.totals();
            assert!(now.loads >= last.loads);
            assert!(now.bytes_read >= last.bytes_read);
            assert!(now.stage_time >= last.stage_time);
            last = now;
            if loaders.iter().all(|h| h.is_finished()) {
                done.store(true, Ordering::Relaxed);
            }
            std::thread::yield_now();
        }
        for h in loaders {
            h.join().unwrap();
        }
        let t = store.totals();
        assert_eq!(t.loads, 32);
        assert_eq!(t.bytes_read, (32 * N * F) as u64, "no streamed byte lost or double-counted");
    }
}
