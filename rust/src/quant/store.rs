//! The feature store — the data-loading stage whose cost Fig. 3 shows
//! dominating GNN inference, and which the paper's INT8 path shrinks by
//! 50.91–70.51 % (Table 3).
//!
//! `FeatureStore` owns the on-disk feature tensors for one dataset
//! (fp32 and u8 variants, both inside the dataset `.nbt`) and exposes an
//! instrumented `load()` that measures the stages the paper measures:
//! bytes read from storage, host staging, and (for the quantized path)
//! the dequantization location — on-device (the `qmodel_*` artifacts run
//! the Pallas dequant kernel) or host-side (CPU baselines).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::tensor::{read_nbt, read_nbt_tensor, Tensor};

use super::scalar::{dequantize_into, QuantParams};

/// Which representation to load from storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Full-precision features (AFS/SFS rows of Table 3).
    F32,
    /// INT8 features, dequantized on device (quantization-based AES-SpMM).
    U8Device,
    /// INT8 features, dequantized on the host (CPU baseline path).
    U8Host,
}

impl Precision {
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::U8Device => "u8-device",
            Precision::U8Host => "u8-host",
        }
    }
}

/// Timing + volume breakdown of one feature load.
#[derive(Clone, Copy, Debug, Default)]
pub struct LoadStats {
    /// Bytes read from storage for the feature tensor.
    pub bytes_read: usize,
    /// Wall time of the storage read + container decode.
    pub read_time: Duration,
    /// Host-side dequantization time (zero for F32 / U8Device).
    pub dequant_time: Duration,
}

impl LoadStats {
    pub fn total(&self) -> Duration {
        self.read_time + self.dequant_time
    }
}

/// Loaded features ready for the executor: either an f32 tensor or a u8
/// tensor plus its quantization params (device dequant).
#[derive(Clone, Debug)]
pub enum Features {
    Dense(Tensor),
    Quantized { q: Tensor, params: QuantParams },
}

/// One dataset's feature storage.
pub struct FeatureStore {
    path: PathBuf,
    shape: Vec<usize>,
    params: QuantParams,
    /// Storage reads performed — the exec-layer plan cache asserts this
    /// stays flat on warm routes.
    loads: AtomicU64,
}

impl FeatureStore {
    /// Open the store for a dataset `.nbt`; reads only the metadata.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref().to_path_buf();
        let nbt = read_nbt(&path)?;
        let feat = nbt.get("feat")?;
        let qr = nbt.get("qrange")?.as_f32()?.to_vec();
        Ok(Self {
            path,
            shape: feat.shape.clone(),
            params: QuantParams { x_min: qr[0], x_max: qr[1] },
            loads: AtomicU64::new(0),
        })
    }

    /// How many times [`FeatureStore::load`] has hit storage.
    pub fn load_count(&self) -> u64 {
        self.loads.load(Ordering::Relaxed)
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn params(&self) -> QuantParams {
        self.params
    }

    /// Load features at the requested precision, instrumented.
    ///
    /// Note the whole container is re-read per call by design: this stage
    /// *models the paper's per-inference feature loading* (storage → host
    /// → device), which is exactly what Table 3 times. The executor keeps
    /// graph structure cached; features are the per-request payload.
    pub fn load(&self, precision: Precision) -> Result<(Features, LoadStats)> {
        self.loads.fetch_add(1, Ordering::Relaxed);
        let mut stats = LoadStats::default();
        let t0 = Instant::now();
        let key = match precision {
            Precision::F32 => "feat",
            _ => "featq",
        };
        // Selective read: seek past every other tensor in the container so
        // the INT8 path really moves 4x fewer bytes off storage.
        let tensor = read_nbt_tensor(&self.path, key).context("feature tensor missing")?;
        stats.bytes_read = tensor.byte_len();
        stats.read_time = t0.elapsed();

        let feats = match precision {
            Precision::F32 => Features::Dense(tensor),
            Precision::U8Device => Features::Quantized { q: tensor, params: self.params },
            Precision::U8Host => {
                let t1 = Instant::now();
                let q = tensor.as_u8()?;
                let mut out = vec![0.0f32; q.len()];
                dequantize_into(q, self.params, &mut out);
                stats.dequant_time = t1.elapsed();
                Features::Dense(Tensor::from_f32(&tensor.shape, &out))
            }
        };
        Ok((feats, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize;
    use crate::tensor::{write_nbt, NbtFile};

    fn make_store(dir: &Path) -> FeatureStore {
        let n = 64;
        let f = 16;
        let feat: Vec<f32> = (0..n * f).map(|i| (i as f32 * 0.37).sin()).collect();
        let p = QuantParams::of(&feat);
        let q = quantize(&feat, p);
        let mut nbt = NbtFile::new();
        nbt.insert("feat", Tensor::from_f32(&[n, f], &feat));
        nbt.insert("featq", Tensor::from_u8(&[n, f], &q));
        nbt.insert("qrange", Tensor::from_f32(&[2], &[p.x_min, p.x_max]));
        let path = dir.join("store_test.nbt");
        write_nbt(&path, &nbt).unwrap();
        FeatureStore::open(&path).unwrap()
    }

    #[test]
    fn f32_load_reads_4x_the_bytes() {
        let dir = std::env::temp_dir().join("fstore_test");
        std::fs::create_dir_all(&dir).unwrap();
        let store = make_store(&dir);
        let (_, s32) = store.load(Precision::F32).unwrap();
        let (_, s8) = store.load(Precision::U8Device).unwrap();
        assert_eq!(s32.bytes_read, 4 * s8.bytes_read);
        assert_eq!(s8.dequant_time, Duration::ZERO);
    }

    #[test]
    fn host_dequant_approximates_f32() {
        let dir = std::env::temp_dir().join("fstore_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let store = make_store(&dir);
        let (f32_feats, _) = store.load(Precision::F32).unwrap();
        let (host_feats, stats) = store.load(Precision::U8Host).unwrap();
        let (Features::Dense(a), Features::Dense(b)) = (f32_feats, host_feats) else {
            panic!("expected dense features");
        };
        let bound = crate::quant::max_quant_error(store.params()) + 1e-6;
        for (x, y) in a.as_f32().unwrap().iter().zip(b.as_f32().unwrap()) {
            assert!((x - y).abs() <= bound);
        }
        assert!(stats.dequant_time > Duration::ZERO);
    }

    #[test]
    fn quantized_load_carries_params() {
        let dir = std::env::temp_dir().join("fstore_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let store = make_store(&dir);
        let (f, _) = store.load(Precision::U8Device).unwrap();
        match f {
            Features::Quantized { q, params } => {
                assert_eq!(q.shape, store.shape());
                assert_eq!(params, store.params());
            }
            _ => panic!("expected quantized features"),
        }
    }
}
