//! Scalar INT8 quantization — Eq. 1 (quantize) and Eq. 2 (dequantize),
//! plus the per-row-block [`ChunkedParams`] the streaming feature
//! pipeline dequantizes with.

use anyhow::{bail, Result};

/// Quantization range parameters (`x_min`, `x_max` of Eq. 1/2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    /// Smallest representable value (maps to code 0).
    pub x_min: f32,
    /// Largest representable value (maps to code 255).
    pub x_max: f32,
}

impl QuantParams {
    /// The tight min/max range of `data` (the paper's offline Eq. 1
    /// calibration). Empty or non-finite input falls back to `[0, 1]`.
    pub fn of(data: &[f32]) -> QuantParams {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if !lo.is_finite() || !hi.is_finite() {
            return QuantParams { x_min: 0.0, x_max: 1.0 };
        }
        QuantParams { x_min: lo, x_max: hi }
    }

    /// The range span `x_max - x_min`, degenerate ranges clamped to 1.
    #[inline]
    pub fn scale(&self) -> f32 {
        let span = self.x_max - self.x_min;
        if span == 0.0 {
            1.0
        } else {
            span
        }
    }
}

const LEVELS: f32 = 255.0;

/// Eq. 1: `q = floor((x - x_min) / (x_max - x_min) * 255)`, clamped.
pub fn quantize(data: &[f32], p: QuantParams) -> Vec<u8> {
    let inv = LEVELS / p.scale();
    data.iter()
        .map(|&x| (((x - p.x_min) * inv).floor()).clamp(0.0, LEVELS) as u8)
        .collect()
}

/// Eq. 2: `x̂ = q * (x_max - x_min) / 255 + x_min`.
pub fn dequantize(q: &[u8], p: QuantParams) -> Vec<f32> {
    let mut out = vec![0.0f32; q.len()];
    dequantize_into(q, p, &mut out);
    out
}

/// Dequantize into a caller-owned buffer (hot path: no allocation).
pub fn dequantize_into(q: &[u8], p: QuantParams, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    let scale = p.scale() / LEVELS;
    // Tiny LUT beats per-element FMA on this path: 256 entries, fully
    // cache-resident, and autovectorizes to gathers-free shuffles.
    let mut lut = [0.0f32; 256];
    for (i, slot) in lut.iter_mut().enumerate() {
        *slot = i as f32 * scale + p.x_min;
    }
    for (o, &qi) in out.iter_mut().zip(q.iter()) {
        *o = lut[qi as usize];
    }
}

/// Worst-case reconstruction error of the scheme: one quantization step.
pub fn max_quant_error(p: QuantParams) -> f32 {
    p.scale() / LEVELS
}

/// Per-row-block quantization ranges — the streaming pipeline's unit of
/// lazy dequantization.
///
/// A feature matrix of `n_rows` rows is cut into chunks of
/// `rows_per_chunk` consecutive rows (the last chunk may be short), each
/// calibrated with its own Eq. 1 range. Tighter per-chunk ranges shrink
/// the one-step reconstruction error wherever feature magnitudes vary by
/// region, and — more importantly for serving — let a row-block be
/// dequantized on its own, without the whole-tensor range pass, inside
/// the exec worker that consumes it.
///
/// Serialized in the dataset `.nbt` as a `qchunks` f32 tensor of shape
/// `[n_chunks, 2]` ((min, max) pairs in row order) with
/// `rows_per_chunk = ceil(n_rows / n_chunks)`; containers without
/// `qchunks` degrade to one chunk covering every row (the legacy global
/// `qrange`), which reproduces the old numerics exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct ChunkedParams {
    rows_per_chunk: usize,
    n_rows: usize,
    chunks: Vec<QuantParams>,
}

impl ChunkedParams {
    /// One chunk covering all rows — byte-compatible with the legacy
    /// global `qrange` calibration.
    pub fn uniform(n_rows: usize, p: QuantParams) -> ChunkedParams {
        ChunkedParams { rows_per_chunk: n_rows.max(1), n_rows, chunks: vec![p] }
    }

    /// Calibrate per-chunk ranges over a row-major `[n_rows, width]`
    /// matrix (build-time Eq. 1, chunk by chunk). `rows_per_chunk` is a
    /// target: it is normalized to the serialization convention
    /// (`ceil(n_rows / n_chunks)`) so that encode, `qchunks` round-trip,
    /// and decode all agree on chunk boundaries — an un-normalized size
    /// (e.g. 512 rows over 1000) would silently shift the boundary rows
    /// onto a neighbouring chunk's range after a round-trip.
    pub fn of_rows(
        data: &[f32],
        n_rows: usize,
        width: usize,
        rows_per_chunk: usize,
    ) -> ChunkedParams {
        assert_eq!(data.len(), n_rows * width, "data is not [n_rows, width]");
        let requested = rows_per_chunk.max(1);
        let n_chunks = n_rows.div_ceil(requested).max(1);
        let rpc = n_rows.div_ceil(n_chunks).max(1);
        let chunks = (0..n_chunks)
            .map(|i| {
                let lo = i * rpc * width;
                let hi = ((i + 1) * rpc * width).min(data.len());
                QuantParams::of(&data[lo..hi])
            })
            .collect();
        ChunkedParams { rows_per_chunk: rpc, n_rows, chunks }
    }

    /// Rebuild from a deserialized chunk list (the `qchunks` tensor).
    /// Validates that the chunk count is consistent with `n_rows` under
    /// the `rows_per_chunk = ceil(n_rows / n_chunks)` convention.
    pub fn from_chunks(n_rows: usize, chunks: Vec<QuantParams>) -> Result<ChunkedParams> {
        if chunks.is_empty() {
            bail!("qchunks must hold at least one (min, max) pair");
        }
        let rpc = n_rows.div_ceil(chunks.len()).max(1);
        if n_rows.div_ceil(rpc).max(1) != chunks.len() {
            bail!(
                "{} chunks cannot tile {} rows evenly (ceil-division convention)",
                chunks.len(),
                n_rows
            );
        }
        Ok(ChunkedParams { rows_per_chunk: rpc, n_rows, chunks })
    }

    /// Rows covered by each chunk (the last chunk may be short).
    pub fn rows_per_chunk(&self) -> usize {
        self.rows_per_chunk
    }

    /// Total rows covered.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Number of chunks.
    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// The `(min, max)` pairs in row order, for serialization.
    pub fn chunks(&self) -> &[QuantParams] {
        &self.chunks
    }

    /// The range governing row `row`.
    pub fn for_row(&self, row: usize) -> QuantParams {
        assert!(row < self.n_rows, "row {row} out of {} rows", self.n_rows);
        self.chunks[row / self.rows_per_chunk]
    }

    /// The loosest envelope over every chunk (what a device kernel with a
    /// single-range Eq. 2 would have to use).
    pub fn envelope(&self) -> QuantParams {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for c in &self.chunks {
            lo = lo.min(c.x_min);
            hi = hi.max(c.x_max);
        }
        QuantParams { x_min: lo, x_max: hi }
    }

    /// Worst-case reconstruction error across all chunks — the bound
    /// lazy per-block dequantization is tested against.
    pub fn max_error(&self) -> f32 {
        self.chunks.iter().map(|&c| max_quant_error(c)).fold(0.0, f32::max)
    }

    /// Quantize a full `[n_rows, width]` matrix chunk by chunk (Eq. 1
    /// with each chunk's own range) — the build-time producer of the
    /// `featq` payload this struct later dequantizes.
    pub fn quantize_rows(&self, data: &[f32], width: usize) -> Vec<u8> {
        assert_eq!(data.len(), self.n_rows * width, "data is not [n_rows, width]");
        let mut out = Vec::with_capacity(data.len());
        for (i, p) in self.chunks.iter().enumerate() {
            let lo = i * self.rows_per_chunk * width;
            let hi = ((i + 1) * self.rows_per_chunk * width).min(data.len());
            out.extend(quantize(&data[lo..hi], *p));
        }
        out
    }

    /// Eq. 2 over the row-block `row0 .. row0 + q.len() / width`, each
    /// row with its own chunk's range. This is the hot lazy-dequant path:
    /// `q` is a borrowed (typically memory-mapped) INT8 row-block and
    /// `out` the worker's scratch buffer. Runs one LUT pass per chunk
    /// segment, so the cost matches the whole-tensor `dequantize_into`.
    pub fn dequantize_rows_into(&self, q: &[u8], row0: usize, width: usize, out: &mut [f32]) {
        assert_eq!(q.len(), out.len());
        if width == 0 || q.is_empty() {
            return;
        }
        assert_eq!(q.len() % width, 0, "block is not whole rows");
        let rows = q.len() / width;
        assert!(row0 + rows <= self.n_rows, "block past the last row");
        let mut r = 0usize;
        while r < rows {
            let chunk = (row0 + r) / self.rows_per_chunk;
            let chunk_end = (chunk + 1) * self.rows_per_chunk;
            let seg = (chunk_end - (row0 + r)).min(rows - r);
            let (lo, hi) = (r * width, (r + seg) * width);
            dequantize_into(&q[lo..hi], self.chunks[chunk], &mut out[lo..hi]);
            r += seg;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Pcg32::new(1);
        let data: Vec<f32> = (0..10_000).map(|_| (rng.f32() - 0.5) * 8.0).collect();
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        let back = dequantize(&q, p);
        let bound = max_quant_error(p) + 1e-6;
        for (x, y) in data.iter().zip(back.iter()) {
            assert!((x - y).abs() <= bound, "{x} vs {y} (bound {bound})");
        }
    }

    #[test]
    fn endpoints_map_to_extremes() {
        let data = vec![-2.0f32, 3.0];
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        assert_eq!(q, vec![0, 255]);
        let back = dequantize(&q, p);
        assert!((back[0] + 2.0).abs() < 1e-6);
        assert!((back[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn constant_input_is_stable() {
        let data = vec![1.5f32; 64];
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        let back = dequantize(&q, p);
        for y in back {
            assert!((y - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn matches_python_ref_semantics() {
        // Golden values computed with ref.quantize: x in [0,1], 11 points.
        let data: Vec<f32> = (0..=10).map(|i| i as f32 / 10.0).collect();
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        assert_eq!(q, vec![0, 25, 51, 76, 102, 127, 153, 178, 204, 229, 255]);
    }

    #[test]
    fn dequantize_into_no_alloc_path_matches() {
        let data = vec![0.1f32, 0.7, -0.3, 0.0];
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        let a = dequantize(&q, p);
        let mut b = vec![0.0; q.len()];
        dequantize_into(&q, p, &mut b);
        assert_eq!(a, b);
    }

    fn ramp(n_rows: usize, width: usize) -> Vec<f32> {
        // Row blocks with very different magnitudes, so per-chunk ranges
        // actually differ from the global envelope.
        (0..n_rows * width)
            .map(|i| {
                let row = i / width;
                (i as f32 * 0.13).sin() * (1.0 + row as f32)
            })
            .collect()
    }

    #[test]
    fn uniform_chunking_matches_global_params() {
        let data = ramp(10, 4);
        let p = QuantParams::of(&data);
        let c = ChunkedParams::uniform(10, p);
        assert_eq!((c.n_chunks(), c.rows_per_chunk(), c.n_rows()), (1, 10, 10));
        assert_eq!(c.quantize_rows(&data, 4), quantize(&data, p));
        let q = quantize(&data, p);
        let mut lazy = vec![0.0f32; q.len()];
        c.dequantize_rows_into(&q, 0, 4, &mut lazy);
        assert_eq!(lazy, dequantize(&q, p), "one chunk must reproduce the legacy numerics");
        assert_eq!(c.envelope(), p);
    }

    #[test]
    fn per_block_lazy_dequant_matches_whole_tensor_within_bound() {
        let (n_rows, width) = (23, 6); // deliberately not a chunk multiple
        let data = ramp(n_rows, width);
        let c = ChunkedParams::of_rows(&data, n_rows, width, 4);
        assert_eq!(c.n_chunks(), 6); // ceil(23 / 4)
        let q = c.quantize_rows(&data, width);

        // Whole-tensor dequant through the chunked path.
        let mut whole = vec![0.0f32; q.len()];
        c.dequantize_rows_into(&q, 0, width, &mut whole);
        // Lazy per-block dequant over ragged, chunk-straddling blocks.
        let mut lazy = vec![0.0f32; q.len()];
        let mut row = 0usize;
        for block in [3usize, 5, 1, 7, 4, 3] {
            let (lo, hi) = (row * width, (row + block) * width);
            c.dequantize_rows_into(&q[lo..hi], row, width, &mut lazy[lo..hi]);
            row += block;
        }
        assert_eq!(row, n_rows);
        assert_eq!(lazy, whole, "block boundaries must not change the numerics");

        // And both sit within the quantization error bound of the input.
        let bound = c.max_error() + 1e-6;
        for (x, y) in data.iter().zip(lazy.iter()) {
            assert!((x - y).abs() <= bound, "{x} vs {y} (bound {bound})");
        }
        // Per-chunk calibration is at least as tight as the global range.
        assert!(c.max_error() <= max_quant_error(QuantParams::of(&data)) + 1e-6);
    }

    #[test]
    fn of_rows_normalizes_to_the_serialization_convention() {
        // Requested 12 rows/chunk over 20 rows gives 2 chunks, but the
        // qchunks round-trip implies ceil(20/2) = 10 rows per chunk —
        // encode and decode must agree on that boundary, or rows 10..12
        // would decode with the wrong chunk's range after serialization.
        let data = ramp(20, 2);
        let c = ChunkedParams::of_rows(&data, 20, 2, 12);
        assert_eq!((c.n_chunks(), c.rows_per_chunk()), (2, 10));
        let rebuilt = ChunkedParams::from_chunks(20, c.chunks().to_vec()).unwrap();
        assert_eq!(rebuilt, c);
        let q = c.quantize_rows(&data, 2);
        let mut direct = vec![0.0f32; q.len()];
        c.dequantize_rows_into(&q, 0, 2, &mut direct);
        let mut roundtrip = vec![0.0f32; q.len()];
        rebuilt.dequantize_rows_into(&q, 0, 2, &mut roundtrip);
        assert_eq!(direct, roundtrip, "serialized params must decode identically");
        let bound = c.max_error() + 1e-6;
        for (x, y) in data.iter().zip(direct.iter()) {
            assert!((x - y).abs() <= bound);
        }
    }

    #[test]
    fn chunk_lookup_and_validation() {
        let data = ramp(10, 2);
        let c = ChunkedParams::of_rows(&data, 10, 2, 4); // chunks of 4,4,2 rows
        assert_eq!(c.n_chunks(), 3);
        assert_eq!(c.for_row(0), c.chunks()[0]);
        assert_eq!(c.for_row(7), c.chunks()[1]);
        assert_eq!(c.for_row(9), c.chunks()[2]);

        let rebuilt = ChunkedParams::from_chunks(10, c.chunks().to_vec()).unwrap();
        assert_eq!(rebuilt, c, "serialization convention must round-trip");
        assert!(ChunkedParams::from_chunks(10, vec![]).is_err());
        // 6 chunks cannot tile 10 rows under ceil-division (rpc 2 → 5 chunks).
        let p = QuantParams { x_min: 0.0, x_max: 1.0 };
        assert!(ChunkedParams::from_chunks(10, vec![p; 6]).is_err());
    }
}
