//! Scalar INT8 quantization — Eq. 1 (quantize) and Eq. 2 (dequantize).

/// Quantization range parameters (`x_min`, `x_max` of Eq. 1/2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantParams {
    pub x_min: f32,
    pub x_max: f32,
}

impl QuantParams {
    pub fn of(data: &[f32]) -> QuantParams {
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in data {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        if !lo.is_finite() || !hi.is_finite() {
            return QuantParams { x_min: 0.0, x_max: 1.0 };
        }
        QuantParams { x_min: lo, x_max: hi }
    }

    #[inline]
    pub fn scale(&self) -> f32 {
        let span = self.x_max - self.x_min;
        if span == 0.0 {
            1.0
        } else {
            span
        }
    }
}

const LEVELS: f32 = 255.0;

/// Eq. 1: `q = floor((x - x_min) / (x_max - x_min) * 255)`, clamped.
pub fn quantize(data: &[f32], p: QuantParams) -> Vec<u8> {
    let inv = LEVELS / p.scale();
    data.iter()
        .map(|&x| (((x - p.x_min) * inv).floor()).clamp(0.0, LEVELS) as u8)
        .collect()
}

/// Eq. 2: `x̂ = q * (x_max - x_min) / 255 + x_min`.
pub fn dequantize(q: &[u8], p: QuantParams) -> Vec<f32> {
    let mut out = vec![0.0f32; q.len()];
    dequantize_into(q, p, &mut out);
    out
}

/// Dequantize into a caller-owned buffer (hot path: no allocation).
pub fn dequantize_into(q: &[u8], p: QuantParams, out: &mut [f32]) {
    assert_eq!(q.len(), out.len());
    let scale = p.scale() / LEVELS;
    // Tiny LUT beats per-element FMA on this path: 256 entries, fully
    // cache-resident, and autovectorizes to gathers-free shuffles.
    let mut lut = [0.0f32; 256];
    for (i, slot) in lut.iter_mut().enumerate() {
        *slot = i as f32 * scale + p.x_min;
    }
    for (o, &qi) in out.iter_mut().zip(q.iter()) {
        *o = lut[qi as usize];
    }
}

/// Worst-case reconstruction error of the scheme: one quantization step.
pub fn max_quant_error(p: QuantParams) -> f32 {
    p.scale() / LEVELS
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Pcg32::new(1);
        let data: Vec<f32> = (0..10_000).map(|_| (rng.f32() - 0.5) * 8.0).collect();
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        let back = dequantize(&q, p);
        let bound = max_quant_error(p) + 1e-6;
        for (x, y) in data.iter().zip(back.iter()) {
            assert!((x - y).abs() <= bound, "{x} vs {y} (bound {bound})");
        }
    }

    #[test]
    fn endpoints_map_to_extremes() {
        let data = vec![-2.0f32, 3.0];
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        assert_eq!(q, vec![0, 255]);
        let back = dequantize(&q, p);
        assert!((back[0] + 2.0).abs() < 1e-6);
        assert!((back[1] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn constant_input_is_stable() {
        let data = vec![1.5f32; 64];
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        let back = dequantize(&q, p);
        for y in back {
            assert!((y - 1.5).abs() < 1e-6);
        }
    }

    #[test]
    fn matches_python_ref_semantics() {
        // Golden values computed with ref.quantize: x in [0,1], 11 points.
        let data: Vec<f32> = (0..=10).map(|i| i as f32 / 10.0).collect();
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        assert_eq!(q, vec![0, 25, 51, 76, 102, 127, 153, 178, 204, 229, 255]);
    }

    #[test]
    fn dequantize_into_no_alloc_path_matches() {
        let data = vec![0.1f32, 0.7, -0.3, 0.0];
        let p = QuantParams::of(&data);
        let q = quantize(&data, p);
        let a = dequantize(&q, p);
        let mut b = vec![0.0; q.len()];
        dequantize_into(&q, p, &mut b);
        assert_eq!(a, b);
    }
}
