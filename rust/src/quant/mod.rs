//! INT8 feature quantization and the streaming feature store —
//! the serving-side realization of the paper's Table 3 (§2.3, §3.1).
//!
//! # Purpose
//!
//! Feature loading, not compute, dominates GNN inference (Fig. 3); this
//! module owns everything between the dataset `.nbt` on disk and the
//! fp32 rows a kernel consumes: quantization math (Eq. 1/2), the
//! zero-copy container reader, and the instrumented store.
//!
//! # Structure
//!
//! | unit       | role                                                    |
//! |------------|---------------------------------------------------------|
//! | `scalar`   | Eq. 1/2 scalar codecs + per-row-block [`ChunkedParams`] |
//! | `mmap`     | [`MmapNbt`]: memory-mapped `.nbt`, zero-copy row-blocks |
//! | `store`    | [`FeatureStore`]: eager `load` / streaming `stage`, monotonic [`LoadTotals`] |
//!
//! # Rules
//!
//! * Quantization ranges are calibrated **offline** (Eq. 1, by the
//!   python pipeline or [`ChunkedParams::of_rows`]); the serving path
//!   only ever dequantizes.
//! * INT8 ([`Precision::U8Device`]) is the serving default; fp32 is the
//!   opt-in baseline — 4× the bytes off storage.
//! * Streamed handles borrow the page cache: containers must be
//!   republished atomically (`write_nbt`'s temp-file + rename), never
//!   truncated in place.
//! * Every staged byte is charged to the owning store's [`LoadTotals`]
//!   via atomic, individually monotonic counters — safe to audit while a
//!   prefetcher races the workers.

#![warn(missing_docs)]

mod mmap;
mod scalar;
mod store;

pub use mmap::MmapNbt;
pub use scalar::{
    dequantize, dequantize_into, max_quant_error, quantize, ChunkedParams, QuantParams,
};
pub use store::{
    FeatureHandle, FeatureStore, Features, LoadSource, LoadStats, LoadTotals, Precision,
};
