//! Feature quantization (paper §2.3, §3.1) and the instrumented feature
//! store behind Table 3 / Fig. 3.
//!
//! Quantization happens offline (Eq. 1, done at build time by the python
//! pipeline and mirrored here for rust-generated workloads); the inference
//! path loads the u8 representation — 4× fewer bytes — and either ships it
//! to the device for the on-device Pallas dequant kernel (Eq. 2) or
//! dequantizes host-side for the CPU baselines.

mod scalar;
mod store;

pub use scalar::{dequantize, dequantize_into, max_quant_error, quantize, QuantParams};
pub use store::{FeatureStore, Features, LoadStats, Precision};
