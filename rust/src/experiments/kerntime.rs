//! Shared SpMM kernel timing for Fig. 2 / Fig. 7: isolates the
//! *aggregation kernel* exactly as the paper does ("execution time
//! includes only the kernel time"). The sampled kernels time
//! sampling + multiply together, since AES-SpMM performs sampling inside
//! the kernel launch.

use std::time::Duration;

use crate::bench::Bencher;
use crate::graph::Csr;
use crate::rng::Pcg32;
use crate::sampling::{sample_ell_par, Strategy};
use crate::spmm::{csr_naive, csr_rowcache};

/// Thread budget, via the exec layer's single machine probe (call sites
/// must not re-detect parallelism ad hoc).
pub fn threads() -> usize {
    crate::exec::ExecEnv::detect().threads
}

fn bencher(quick: bool) -> Bencher {
    if quick {
        Bencher { warmup_iters: 1, min_iters: 3, max_iters: 10, budget: Duration::from_millis(300) }
    } else {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 60,
            budget: Duration::from_millis(1500),
        }
    }
}

/// Random dense feature matrix for kernel timing.
pub fn random_features(n: usize, f: usize, seed: u64) -> Vec<f32> {
    let mut rng = Pcg32::new(seed);
    (0..n * f).map(|_| rng.f32() - 0.5).collect()
}

/// Exact CSR SpMM (cuSPARSE role) median kernel time.
///
/// All kernel timings here are single-threaded so the ratios reflect the
/// *algorithmic* work (the paper compares kernels on the same GPU; mixing
/// thread counts would skew who-wins). The multi-threaded variants are
/// benchmarked separately in `benches/spmm_kernels.rs`.
pub fn time_exact(csr: &Csr, b: &[f32], f: usize, quick: bool) -> Duration {
    let mut out = vec![0.0f32; csr.n_rows * f];
    bencher(quick).run("exact", || csr_naive(csr, b, f, &mut out)).median
}

/// GE-SpMM analog (row caching + warp merging) median kernel time.
pub fn time_rowcache(csr: &Csr, b: &[f32], f: usize, quick: bool) -> Duration {
    let mut out = vec![0.0f32; csr.n_rows * f];
    bencher(quick).run("rowcache", || csr_rowcache(csr, b, f, &mut out)).median
}

/// Sampled kernel (sampling + multiply, like the fused GPU launch):
/// in-kernel sampling into a reused ELL tile (the shared-memory stand-in)
/// then the multiply, single thread, no allocation in the loop.
pub fn time_sampled(
    csr: &Csr,
    width: usize,
    strategy: Strategy,
    b: &[f32],
    f: usize,
    quick: bool,
) -> Duration {
    let mut out = vec![0.0f32; csr.n_rows * f];
    let mut ell = crate::graph::Ell::zeros(csr.n_rows, csr.n_cols, width);
    bencher(quick)
        .run("sampled", || {
            sample_ell_par(csr, width, strategy, &mut ell, 1);
            crate::spmm::ell_spmm(&ell, b, f, &mut out);
        })
        .median
}
