//! Fig. 3 — GCN inference time breakdown on reddit: feature loading vs
//! computing across W, for AFS and SFS. The paper's point: loading
//! dominates (70.78–92.07 %), motivating the quantization path.

use anyhow::Result;

use crate::quant::Precision;
use crate::runtime::{run_forward, Dataset, ForwardRequest, Weights};
use crate::sampling::Strategy;

use super::report::Table;
use super::ExpContext;

pub fn run_fig3(ctx: &ExpContext) -> Result<Table> {
    let ds_name = if ctx.quick { "cora" } else { "reddit" };
    let model = "gcn";
    let mut table = Table::new(
        "fig3",
        format!("{model} inference breakdown on {ds_name}: loading vs compute per W"),
        &["W", "scheme", "load (ms)", "compute (ms)", "compute %", "load %"],
    );
    let manifest = ctx.engine.manifest();
    let ds = Dataset::load(&manifest.dir, ds_name)?;
    let weights = Weights::load(&manifest.dir, model, ds_name)?;
    let fstore = crate::quant::FeatureStore::open(
        manifest.dir.join(format!("data_{ds_name}.nbt")),
    )?;

    for &w in &ctx.widths() {
        for strategy in [Strategy::Afs, Strategy::Sfs] {
            // Median of a few end-to-end (load + execute) repetitions.
            let reps = if ctx.quick { 2 } else { 5 };
            let mut loads = Vec::new();
            let mut computes = Vec::new();
            for _ in 0..reps {
                let (feats, lstats) = fstore.load(Precision::F32)?;
                let crate::quant::Features::Dense(feat) = feats else { unreachable!() };
                let req = ForwardRequest {
                    model: model.into(),
                    dataset: ds_name.into(),
                    width: Some(w),
                    strategy,
                    precision: Precision::F32,
                };
                let result = run_forward(&ctx.engine, &ds, &weights, &req, Some(&feat))?;
                loads.push(lstats.total());
                // Transfer is part of the loading story (host→device), as
                // in the paper's PCIe accounting.
                loads.push(result.stats.transfer);
                computes.push(result.stats.execute + result.stats.fetch);
            }
            let load: std::time::Duration = loads.iter().sum::<std::time::Duration>() / reps;
            let compute: std::time::Duration =
                computes.iter().sum::<std::time::Duration>() / reps;
            let total = (load + compute).as_secs_f64();
            table.push(vec![
                w.to_string(),
                strategy.name().to_string(),
                format!("{:.2}", load.as_secs_f64() * 1e3),
                format!("{:.2}", compute.as_secs_f64() * 1e3),
                format!("{:.1}%", 100.0 * compute.as_secs_f64() / total),
                format!("{:.1}%", 100.0 * load.as_secs_f64() / total),
            ]);
        }
    }
    table.print();
    super::report::write_report(&ctx.out_dir, &table)?;
    Ok(table)
}
