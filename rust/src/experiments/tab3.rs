//! Table 3 — feature loading time as a fraction of total inference time:
//! AFS and SFS load fp32 features; quantization-based AES-SpMM loads INT8
//! and dequantizes on device. The paper's claim in shape: the INT8 rows
//! sit well below the fp32 rows at every W (50.91–70.51 % less loading
//! time), with the ratio shrinking as W (compute) grows.

use anyhow::Result;

use crate::quant::{FeatureStore, Features, Precision};
use crate::runtime::{run_forward, Dataset, ForwardRequest, Weights};
use crate::sampling::Strategy;

use super::report::Table;
use super::ExpContext;

pub fn run_tab3(ctx: &ExpContext) -> Result<Table> {
    let mut table = Table::new(
        "tab3",
        "Feature loading time ratio (% of load+compute) and loading-time reduction of INT8 vs fp32",
        &["model", "dataset", "W", "afs %", "sfs %", "aes+int8 %", "bytes cut", "load cut", "src"],
    );
    let manifest = ctx.engine.manifest();
    let models: &[&str] = if ctx.quick { &["gcn"] } else { &["gcn", "sage"] };
    let datasets = if ctx.quick {
        vec!["cora".to_string()]
    } else {
        manifest.dataset_names()
    };
    let reps = if ctx.quick { 3 } else { 7 };

    for &model in models {
        for ds_name in &datasets {
            let ds = Dataset::load(&manifest.dir, ds_name)?;
            let weights = Weights::load(&manifest.dir, model, ds_name)?;
            let fstore = FeatureStore::open(manifest.dir.join(format!("data_{ds_name}.nbt")))?;
            for &w in &ctx.widths() {
                let mut pct = Vec::new();
                let mut f32_load = f64::INFINITY;
                let mut int8_load = f64::INFINITY;
                let mut f32_bytes = 0usize;
                let mut int8_bytes = 0usize;
                for (strategy, precision) in [
                    (Strategy::Afs, Precision::F32),
                    (Strategy::Sfs, Precision::F32),
                    (Strategy::Aes, Precision::U8Device),
                ] {
                    // Median over reps — single loads are dominated by
                    // page-cache / PJRT-staging jitter at these sizes.
                    let mut loads = Vec::with_capacity(reps);
                    let mut comps = Vec::with_capacity(reps);
                    for _ in 0..reps {
                        let (feats, lstats) = fstore.load(precision)?;
                        let feat = match feats {
                            Features::Dense(t) => t,
                            Features::Quantized { q, .. } => q,
                            // load() is the eager path; only stage() streams.
                            Features::Streamed(h) => h.to_dense(),
                        };
                        match precision {
                            Precision::F32 => f32_bytes = lstats.bytes_read,
                            _ => int8_bytes = lstats.bytes_read,
                        }
                        let req = ForwardRequest {
                            model: model.into(),
                            dataset: ds_name.clone(),
                            width: Some(w),
                            strategy,
                            precision,
                        };
                        let result = run_forward(&ctx.engine, &ds, &weights, &req, Some(&feat))?;
                        // Loading = storage read + host→device transfer
                        // (PCIe analog); compute = device execute + fetch.
                        loads.push((lstats.total() + result.stats.transfer).as_secs_f64());
                        comps.push((result.stats.execute + result.stats.fetch).as_secs_f64());
                    }
                    loads.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    comps.sort_by(|a, b| a.partial_cmp(b).unwrap());
                    let load_m = loads[loads.len() / 2];
                    let comp_m = comps[comps.len() / 2];
                    pct.push(100.0 * load_m / (load_m + comp_m));
                    match precision {
                        Precision::F32 => f32_load = f32_load.min(load_m),
                        _ => int8_load = load_m,
                    }
                }
                table.push(vec![
                    model.into(),
                    ds_name.clone(),
                    w.to_string(),
                    format!("{:.2}", pct[0]),
                    format!("{:.2}", pct[1]),
                    format!("{:.2}", pct[2]),
                    format!("-{:.1}%", 100.0 * (1.0 - int8_bytes as f64 / f32_bytes as f64)),
                    format!("{:+.1}%", 100.0 * (int8_load / f32_load - 1.0)),
                    fstore.source().name().to_string(),
                ]);
            }
        }
    }
    table.print();
    super::report::write_report(&ctx.out_dir, &table)?;
    Ok(table)
}
