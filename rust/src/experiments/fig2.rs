//! Fig. 2 — the motivation experiment: GCN on ogbn-proteins under the two
//! ES-SpMM extremes (AFS vs SFS). Shows the accuracy/speed imbalance:
//! AFS is accurate but slow (per-slot hashing), SFS fast but lossy
//! (prefix-concentrated edges). Accuracy comes from the AOT artifacts,
//! kernel speedup from the isolated CPU SpMM kernels (vs the cuSPARSE-role
//! exact kernel), mirroring the paper's kernel-time methodology.

use anyhow::Result;

use crate::quant::Precision;
use crate::runtime::{accuracy, run_forward, Dataset, ForwardRequest, Weights};
use crate::sampling::Strategy;

use super::kerntime::{random_features, time_exact, time_sampled};
use super::report::Table;
use super::ExpContext;

pub fn run_fig2(ctx: &ExpContext) -> Result<Table> {
    let ds_name = if ctx.quick { "cora" } else { "proteins" };
    let model = "gcn";
    let mut table = Table::new(
        "fig2",
        format!("AFS vs SFS on {ds_name} ({model}): accuracy and kernel speedup vs exact"),
        &["W", "scheme", "accuracy", "acc loss (pp)", "kernel speedup"],
    );

    let manifest = ctx.engine.manifest();
    let ds = Dataset::load(&manifest.dir, ds_name)?;
    let weights = Weights::load(&manifest.dir, model, ds_name)?;
    let ideal = weights.ideal_acc as f64;

    let f = ds.feats;
    let b = random_features(ds.n, f, 42);
    let exact = time_exact(&ds.csr_gcn, &b, f, ctx.quick);

    for &w in &ctx.widths() {
        for strategy in [Strategy::Afs, Strategy::Sfs] {
            let req = ForwardRequest {
                model: model.into(),
                dataset: ds_name.into(),
                width: Some(w),
                strategy,
                precision: Precision::F32,
            };
            let result = run_forward(&ctx.engine, &ds, &weights, &req, None)?;
            let acc = accuracy(&ds, &result.logits)?;
            let sampled = time_sampled(&ds.csr_gcn, w, strategy, &b, f, ctx.quick);
            table.push(vec![
                w.to_string(),
                strategy.name().to_string(),
                format!("{:.4}", acc),
                format!("{:+.2}", (ideal - acc) * 100.0),
                format!("{:.2}x", exact.as_secs_f64() / sampled.as_secs_f64()),
            ]);
        }
    }
    table.print();
    super::report::write_report(&ctx.out_dir, &table)?;
    Ok(table)
}
