//! Fig. 7 — SpMM kernel speedup over the cuSPARSE-role exact kernel:
//! GE-SpMM analog (row caching), AFS, SFS, and AES at each W. The shape
//! to reproduce: GE-SpMM a modest constant win; sampled kernels' speedup
//! grows with avg degree / W; AES ≥ AFS (less index math), close to SFS.

use anyhow::Result;

use crate::runtime::Dataset;
use crate::sampling::Strategy;

use super::kerntime::{random_features, time_exact, time_rowcache, time_sampled};
use super::report::Table;
use super::ExpContext;

pub fn run_fig7(ctx: &ExpContext) -> Result<Table> {
    let mut table = Table::new(
        "fig7",
        "SpMM kernel speedup vs exact (cuSPARSE role); sampled kernels include in-kernel sampling cost",
        &["dataset", "W", "ge-spmm", "afs", "sfs", "aes"],
    );
    let manifest = ctx.engine.manifest();
    let datasets = if ctx.quick {
        vec!["cora".to_string()]
    } else {
        manifest.dataset_names()
    };

    for ds_name in &datasets {
        let ds = Dataset::load(&manifest.dir, ds_name)?;
        let f = ds.feats;
        let b = random_features(ds.n, f, 7);
        let exact = time_exact(&ds.csr_gcn, &b, f, ctx.quick).as_secs_f64();
        let rowcache = time_rowcache(&ds.csr_gcn, &b, f, ctx.quick).as_secs_f64();
        for &w in &ctx.widths() {
            let t = |s: Strategy| {
                time_sampled(&ds.csr_gcn, w, s, &b, f, ctx.quick).as_secs_f64()
            };
            table.push(vec![
                ds_name.clone(),
                w.to_string(),
                format!("{:.2}x", exact / rowcache),
                format!("{:.2}x", exact / t(Strategy::Afs)),
                format!("{:.2}x", exact / t(Strategy::Sfs)),
                format!("{:.2}x", exact / t(Strategy::Aes)),
            ]);
        }
    }
    table.print();
    super::report::write_report(&ctx.out_dir, &table)?;
    Ok(table)
}
