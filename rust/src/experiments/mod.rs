//! Experiment harness — one runner per figure/table of the paper's
//! evaluation (DESIGN.md §6). Each runner prints the same rows/series the
//! paper reports and returns them as structured data for EXPERIMENTS.md.

mod fig2;
mod fig3;
mod fig5;
mod fig6;
mod fig7;
mod kerntime;
mod report;
mod tab1;
mod tab3;

pub use fig2::run_fig2;
pub use fig3::run_fig3;
pub use fig5::run_fig5;
pub use fig6::run_fig6;
pub use fig7::run_fig7;
pub use report::{write_report, Table};
pub use tab1::run_tab1;
pub use tab3::run_tab3;

use anyhow::{bail, Result};
use std::sync::Arc;

use crate::runtime::Engine;

/// Shared context for all experiment runners.
pub struct ExpContext {
    pub engine: Arc<Engine>,
    pub out_dir: std::path::PathBuf,
    /// Smaller sweeps for smoke runs (integration tests / CI).
    pub quick: bool,
}

impl ExpContext {
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>, quick: bool) -> Result<Self> {
        let engine = Arc::new(Engine::new(&artifacts_dir)?);
        let out_dir = artifacts_dir.as_ref().join("reports");
        std::fs::create_dir_all(&out_dir)?;
        Ok(Self { engine, out_dir, quick })
    }

    /// The W sweep to use (manifest widths, truncated in quick mode).
    pub fn widths(&self) -> Vec<usize> {
        let w = self.engine.manifest().widths.clone();
        if self.quick {
            w.into_iter().take(2).collect()
        } else {
            w
        }
    }
}

/// Dispatch an experiment by id ("fig2".."fig7", "tab1", "tab3", "all").
pub fn run(ctx: &ExpContext, id: &str) -> Result<Vec<Table>> {
    Ok(match id {
        "fig2" => vec![run_fig2(ctx)?],
        "fig3" => vec![run_fig3(ctx)?],
        "fig5" => vec![run_fig5(ctx)?],
        "fig6" => vec![run_fig6(ctx)?],
        "fig7" => vec![run_fig7(ctx)?],
        "tab1" => vec![run_tab1(ctx)?],
        "tab3" => vec![run_tab3(ctx)?],
        "all" => {
            let mut all = Vec::new();
            for id in ["tab1", "fig5", "fig2", "fig3", "fig6", "fig7", "tab3"] {
                all.extend(run(ctx, id)?);
            }
            all
        }
        _ => bail!("unknown experiment {id:?} (try fig2/fig3/fig5/fig6/fig7/tab1/tab3/all)"),
    })
}
