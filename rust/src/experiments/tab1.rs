//! Table 1 — the adaptive strategy table itself, plus a census of which
//! regime each dataset's rows actually land in at each W (this is the
//! mechanism behind every other result: the regime mix determines both
//! accuracy loss and sampling cost).

use anyhow::Result;

use crate::runtime::Dataset;
use crate::sampling::{strategy_params, Strategy};

use super::report::Table;
use super::ExpContext;

pub fn run_tab1(ctx: &ExpContext) -> Result<Table> {
    let mut table = Table::new(
        "tab1",
        "Table 1 census: fraction of rows per AES regime (R = row_nnz / W)",
        &[
            "dataset",
            "W",
            "R<=1 (all)",
            "R<=2 (N=W/4)",
            "R<=36 (N=W/8)",
            "R<=54 (N=W/16)",
            "R>54 (N=W/32)",
        ],
    );
    for ds_name in ctx.engine.manifest().dataset_names() {
        let ds = Dataset::load(&ctx.engine.manifest().dir, &ds_name)?;
        for &w in &ctx.widths() {
            let mut counts = [0usize; 5];
            for i in 0..ds.n {
                let nnz = ds.csr_gcn.row_nnz(i);
                let idx = if nnz <= w {
                    0
                } else if nnz <= 2 * w {
                    1
                } else if nnz <= 36 * w {
                    2
                } else if nnz <= 54 * w {
                    3
                } else {
                    4
                };
                counts[idx] += 1;
                // Cross-check the census against the canonical table.
                let p = strategy_params(nnz, w, Strategy::Aes);
                debug_assert!(p.slots <= w);
            }
            let mut row = vec![ds_name.clone(), w.to_string()];
            for c in counts {
                row.push(format!("{:.1}%", 100.0 * c as f64 / ds.n as f64));
            }
            table.push(row);
        }
    }
    table.print();
    super::report::write_report(&ctx.out_dir, &table)?;
    Ok(table)
}
