//! Report tables: a tiny tabular container the experiment runners fill,
//! printed to stdout as markdown and written to `artifacts/reports/*.md`
//! + `.csv` for EXPERIMENTS.md.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// A named table with a caption tying it to the paper artifact.
#[derive(Clone, Debug)]
pub struct Table {
    pub id: String,
    pub caption: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(id: impl Into<String>, caption: impl Into<String>, columns: &[&str]) -> Self {
        Self {
            id: id.into(),
            caption: caption.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.columns.len(), "row width mismatch in {}", self.id);
        self.rows.push(row);
    }

    /// Markdown rendering (what EXPERIMENTS.md embeds).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "### {} — {}\n", self.id, self.caption);
        let _ = writeln!(s, "| {} |", self.columns.join(" | "));
        let dashes = self.columns.iter().map(|_| "---").collect::<Vec<_>>().join("|");
        let _ = writeln!(s, "|{dashes}|");
        for row in &self.rows {
            let _ = writeln!(s, "| {} |", row.join(" | "));
        }
        s
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{}", self.columns.join(","));
        for row in &self.rows {
            let _ = writeln!(s, "{}", row.join(","));
        }
        s
    }

    pub fn print(&self) {
        println!("\n{}", self.to_markdown());
    }
}

/// Write a table as both markdown and CSV under `dir`.
pub fn write_report(dir: impl AsRef<Path>, table: &Table) -> Result<()> {
    let dir = dir.as_ref();
    std::fs::write(dir.join(format!("{}.md", table.id)), table.to_markdown())?;
    std::fs::write(dir.join(format!("{}.csv", table.id)), table.to_csv())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_and_csv() {
        let mut t = Table::new("t1", "demo", &["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.contains("| a | b |"));
        assert!(md.contains("| 1 | 2 |"));
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut t = Table::new("t", "d", &["a"]);
        t.push(vec!["1".into(), "2".into()]);
    }
}
