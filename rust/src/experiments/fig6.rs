//! Fig. 6 — inference accuracy of AES-SpMM vs AFS / SFS / the exact
//! baseline across models, datasets, and W — plus the quantized-AES
//! series (Fig. 6's "benefits of quantization" overlay). The paper's
//! claims to reproduce in shape: AES loss < 1 % by W=128 on large graphs,
//! AES ≥ SFS there, everything ≈ ideal on small graphs, quantization
//! delta ≤ 0.3 pp.

use anyhow::Result;

use crate::quant::Precision;
use crate::runtime::{accuracy, run_forward, Dataset, ForwardRequest, Weights};
use crate::sampling::Strategy;

use super::report::Table;
use super::ExpContext;

pub fn run_fig6(ctx: &ExpContext) -> Result<Table> {
    let mut table = Table::new(
        "fig6",
        "Inference accuracy by model/dataset/scheme/W (delta vs exact ideal, pp)",
        &["model", "dataset", "scheme", "W", "accuracy", "delta (pp)"],
    );
    let manifest = ctx.engine.manifest();
    let models: &[&str] = if ctx.quick { &["gcn"] } else { &["gcn", "sage"] };
    let datasets = if ctx.quick {
        vec!["cora".to_string()]
    } else {
        manifest.dataset_names()
    };

    for &model in models {
        for ds_name in &datasets {
            let ds = Dataset::load(&manifest.dir, ds_name)?;
            let weights = Weights::load(&manifest.dir, model, ds_name)?;

            // Exact baseline through the PJRT artifact (cuSPARSE role) —
            // confirms the ideal accuracy recorded at training time.
            let req = ForwardRequest {
                model: model.into(),
                dataset: ds_name.clone(),
                width: None,
                strategy: Strategy::Aes,
                precision: Precision::F32,
            };
            let result = run_forward(&ctx.engine, &ds, &weights, &req, None)?;
            let ideal = accuracy(&ds, &result.logits)?;
            table.push(vec![
                model.into(),
                ds_name.clone(),
                "exact".into(),
                "-".into(),
                format!("{:.4}", ideal),
                "0.00".into(),
            ]);

            for &w in &ctx.widths() {
                for (scheme, strategy, precision) in [
                    ("afs", Strategy::Afs, Precision::F32),
                    ("sfs", Strategy::Sfs, Precision::F32),
                    ("aes", Strategy::Aes, Precision::F32),
                    ("aes+int8", Strategy::Aes, Precision::U8Device),
                ] {
                    let req = ForwardRequest {
                        model: model.into(),
                        dataset: ds_name.clone(),
                        width: Some(w),
                        strategy,
                        precision,
                    };
                    let result = run_forward(&ctx.engine, &ds, &weights, &req, None)?;
                    let acc = accuracy(&ds, &result.logits)?;
                    table.push(vec![
                        model.into(),
                        ds_name.clone(),
                        scheme.into(),
                        w.to_string(),
                        format!("{:.4}", acc),
                        format!("{:+.2}", (acc - ideal) * 100.0),
                    ]);
                }
            }
        }
    }
    table.print();
    super::report::write_report(&ctx.out_dir, &table)?;
    Ok(table)
}
