//! Fig. 5 — CDF of the sampling rate for AES-SpMM at different W values
//! across datasets: the paper's evidence that small W suffices for small
//! graphs (rate > 80 % at W=16) while large graphs sample < 10 %.

use anyhow::Result;

use crate::runtime::Dataset;
use crate::sampling::{sampling_rate, sampling_rate_cdf, Strategy};

use super::report::Table;
use super::ExpContext;

pub fn run_fig5(ctx: &ExpContext) -> Result<Table> {
    let mut table = Table::new(
        "fig5",
        "Sampling rate of AES at each W: overall rate + per-row CDF deciles",
        &["dataset", "scale", "W", "overall rate", "p10", "p50", "p90"],
    );
    let manifest = ctx.engine.manifest();
    for ds_name in manifest.dataset_names() {
        let meta = manifest.dataset(&ds_name)?.clone();
        let ds = Dataset::load(&manifest.dir, &ds_name)?;
        for &w in &ctx.widths() {
            let rate = sampling_rate(&ds.csr_gcn, w, Strategy::Aes);
            let cdf = sampling_rate_cdf(&ds.csr_gcn, w, Strategy::Aes);
            let q = |p: f64| cdf[((p * (cdf.len() - 1) as f64) as usize).min(cdf.len() - 1)];
            table.push(vec![
                ds_name.clone(),
                meta.scale.clone(),
                w.to_string(),
                format!("{:.3}", rate),
                format!("{:.3}", q(0.1)),
                format!("{:.3}", q(0.5)),
                format!("{:.3}", q(0.9)),
            ]);
        }
    }
    table.print();
    super::report::write_report(&ctx.out_dir, &table)?;
    Ok(table)
}
