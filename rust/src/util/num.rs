//! Small numeric helpers shared across layers.

/// NaN-safe argmax over a logits row: NaN entries are treated as −∞,
/// and an all-NaN (or empty) row deterministically yields 0. The seed's
/// `partial_cmp(..).unwrap()` panicked the worker on the first NaN
/// logit.
///
/// **Tie-breaking contract: the lowest index wins.** A later entry
/// replaces the current best only under strict `>`, so equal values —
/// including the `-0.0` / `+0.0` pair, which compares equal — keep the
/// earliest index. This determinism is load-bearing: top-1 agreement in
/// [`crate::eval`] compares this function's output across the oracle
/// and every serving configuration, and an unstable tie rule would turn
/// exact-duplicate logits into phantom accuracy loss. Every consumer
/// (coordinator replies, `runtime::accuracy`, the eval metrics) routes
/// through here, so they agree on ties by construction.
pub fn argmax_f32(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_val = f32::NEG_INFINITY;
    let mut seen_finite = false;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        if !seen_finite || v > best_val {
            seen_finite = true;
            best = i;
            best_val = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_the_max() {
        assert_eq!(argmax_f32(&[0.1, 0.9, -1.0]), 1);
        assert_eq!(argmax_f32(&[3.0, 2.0, 1.0]), 0);
    }

    #[test]
    fn nan_entries_lose() {
        assert_eq!(argmax_f32(&[f32::NAN, 0.5, 0.2]), 1);
        assert_eq!(argmax_f32(&[0.5, f32::NAN, 0.9]), 2);
    }

    #[test]
    fn all_nan_or_empty_is_zero() {
        assert_eq!(argmax_f32(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_f32(&[]), 0);
    }

    #[test]
    fn neg_infinity_rows_still_deterministic() {
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn ties_break_low() {
        assert_eq!(argmax_f32(&[2.0, 2.0, 1.0]), 0);
        // The tie rule holds wherever the tied pair sits...
        assert_eq!(argmax_f32(&[1.0, 3.0, 3.0]), 1);
        assert_eq!(argmax_f32(&[3.0, 1.0, 3.0, 3.0]), 0);
        // ...across NaN gaps (NaN never becomes the incumbent)...
        assert_eq!(argmax_f32(&[f32::NAN, 2.0, 2.0]), 1);
        // ...and for the equal-comparing signed-zero pair.
        assert_eq!(argmax_f32(&[-0.0, 0.0]), 0);
        assert_eq!(argmax_f32(&[0.0, -0.0]), 0);
        // All-equal rows pick index 0, like an all-NaN row does.
        assert_eq!(argmax_f32(&[5.0; 8]), 0);
    }
}
