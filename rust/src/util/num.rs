//! Small numeric helpers shared across layers.

/// NaN-safe argmax over a logits row: NaN entries are treated as −∞,
/// ties break to the lowest index, and an all-NaN (or empty) row
/// deterministically yields 0. The seed's `partial_cmp(..).unwrap()`
/// panicked the worker on the first NaN logit.
pub fn argmax_f32(row: &[f32]) -> usize {
    let mut best = 0usize;
    let mut best_val = f32::NEG_INFINITY;
    let mut seen_finite = false;
    for (i, &v) in row.iter().enumerate() {
        if v.is_nan() {
            continue;
        }
        if !seen_finite || v > best_val {
            seen_finite = true;
            best = i;
            best_val = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn picks_the_max() {
        assert_eq!(argmax_f32(&[0.1, 0.9, -1.0]), 1);
        assert_eq!(argmax_f32(&[3.0, 2.0, 1.0]), 0);
    }

    #[test]
    fn nan_entries_lose() {
        assert_eq!(argmax_f32(&[f32::NAN, 0.5, 0.2]), 1);
        assert_eq!(argmax_f32(&[0.5, f32::NAN, 0.9]), 2);
    }

    #[test]
    fn all_nan_or_empty_is_zero() {
        assert_eq!(argmax_f32(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_f32(&[]), 0);
    }

    #[test]
    fn neg_infinity_rows_still_deterministic() {
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, f32::NEG_INFINITY]), 0);
        assert_eq!(argmax_f32(&[f32::NEG_INFINITY, -1.0]), 1);
    }

    #[test]
    fn ties_break_low() {
        assert_eq!(argmax_f32(&[2.0, 2.0, 1.0]), 0);
    }
}
