//! Minimal JSON codec — enough for `artifacts/manifest.json` and the
//! experiment report emitters. Recursive descent, UTF-8, no number
//! exotica beyond f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// A parsed JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Result<&JsonValue> {
        match self {
            JsonValue::Obj(m) => m.get(key).with_context(|| format!("missing key {key:?}")),
            _ => bail!("not an object (wanted key {key:?})"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_arr(&self) -> Result<&[JsonValue]> {
        match self {
            JsonValue::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            JsonValue::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            JsonValue::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    /// Serialize back to compact JSON text.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            JsonValue::Str(s) => write_escaped(s, out),
            JsonValue::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse_json(text: &str) -> Result<JsonValue> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied().context("unexpected end of input")
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<JsonValue> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(JsonValue::Str(self.string()?)),
            b't' => self.literal("true", JsonValue::Bool(true)),
            b'f' => self.literal("false", JsonValue::Bool(false)),
            b'n' => self.literal("null", JsonValue::Null),
            _ => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        self.skip_ws();
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(JsonValue::Obj(map));
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            map.insert(key, self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(JsonValue::Arr(out));
        }
        loop {
            out.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(out));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = *self.bytes.get(self.pos).context("unterminated string")?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.bytes.get(self.pos).context("bad escape")?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.bytes.get(self.pos..self.pos + 4).context("short \\u")?,
                            )?;
                            self.pos += 4;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("unknown escape \\{}", e as char),
                    }
                }
                _ => {
                    // Continue multi-byte UTF-8 sequences verbatim.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(JsonValue::Num(s.parse().with_context(|| format!("bad number {s:?}"))?))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shapes() {
        let v = parse_json(
            r#"{"artifacts": {"m": {"inputs": [{"name": "x", "shape": [2, 3], "dtype": "float32"}], "width": 16}}, "widths": [16, 32]}"#,
        )
        .unwrap();
        let m = v.get("artifacts").unwrap().get("m").unwrap();
        assert_eq!(m.get("width").unwrap().as_usize().unwrap(), 16);
        let inp = &m.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("name").unwrap().as_str().unwrap(), "x");
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,null,true,"s\n"],"b":{"c":-3}}"#;
        let v = parse_json(src).unwrap();
        assert_eq!(parse_json(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_json("{").is_err());
        assert!(parse_json("[1,]").is_err());
        assert!(parse_json("hello").is_err());
        assert!(parse_json(r#"{"a":1} trailing"#).is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse_json(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café ☕");
    }
}
