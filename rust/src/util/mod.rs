//! Small utilities: a dependency-free JSON codec (the offline registry has
//! no serde) and timing helpers shared by the bench + experiment harnesses.

mod json;
mod num;
mod timing;

pub use json::{parse_json, JsonValue};
pub use num::argmax_f32;
pub use timing::{fmt_duration, median, percentile, Stopwatch};
