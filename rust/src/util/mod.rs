//! Small utilities: a dependency-free JSON codec (the offline registry has
//! no serde), timing helpers shared by the bench + experiment harnesses,
//! and the argv helpers the CI gate binaries share.

mod cli;
mod json;
mod num;
mod timing;

pub use cli::{cli_flag_f64, cli_positionals, cli_require_known_flags};
pub use json::{parse_json, JsonValue};
pub use num::argmax_f32;
pub use timing::{fmt_duration, median, percentile, Stopwatch};
