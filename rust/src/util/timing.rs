//! Timing helpers for the bench + experiment harnesses.

use std::time::{Duration, Instant};

/// Accumulating stopwatch with named laps.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    laps: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Self { start: now, last: now, laps: Vec::new() }
    }

    /// Record the time since the previous lap under `name`.
    pub fn lap(&mut self, name: impl Into<String>) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.laps.push((name.into(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.last - self.start
    }

    pub fn laps(&self) -> &[(String, Duration)] {
        &self.laps
    }
}

/// Median of a sample (copies + sorts; bench-sized inputs only).
pub fn median(samples: &[Duration]) -> Duration {
    percentile(samples, 50.0)
}

/// Percentile (nearest-rank) of a sample.
pub fn percentile(samples: &[Duration], p: f64) -> Duration {
    if samples.is_empty() {
        return Duration::ZERO;
    }
    let mut v: Vec<Duration> = samples.to_vec();
    v.sort_unstable();
    let rank = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Human format: ns/µs/ms/s with 3 significant-ish digits.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}µs", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.3}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<Duration> = (1..=100).map(Duration::from_millis).collect();
        // nearest-rank: round(0.5 * 99) = 50 → the 51st value.
        assert_eq!(median(&xs), Duration::from_millis(51));
        assert_eq!(percentile(&xs, 0.0), Duration::from_millis(1));
        assert_eq!(percentile(&xs, 100.0), Duration::from_millis(100));
        assert_eq!(percentile(&[], 50.0), Duration::ZERO);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(Duration::from_nanos(12)), "12ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert!(fmt_duration(Duration::from_secs(2)).starts_with("2.000s"));
    }

    #[test]
    fn stopwatch_laps() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(1));
        let lap = sw.lap("a");
        assert!(lap >= Duration::from_millis(1));
        assert_eq!(sw.laps().len(), 1);
        assert!(sw.total() >= lap);
    }
}
