//! Tiny argv helpers shared by the CI gate binaries (`bench_diff`,
//! `acc_diff`): positional/flag splitting without a registry dependency.
//! Errors are plain `String`s — the gates print them and exit 2.

/// Everything that is not a `--flag` or a flag's value. Every gate flag
/// takes exactly one value, so a `--flag` consumes the next token.
pub fn cli_positionals(args: &[String]) -> Vec<&String> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if args[i].starts_with("--") {
            i += 2;
        } else {
            out.push(&args[i]);
            i += 1;
        }
    }
    out
}

/// Parse `--flag <f64>`, falling back to `default` when absent.
pub fn cli_flag_f64(args: &[String], flag: &str, default: f64) -> Result<f64, String> {
    match args.iter().position(|a| a == flag) {
        Some(i) => args
            .get(i + 1)
            .ok_or_else(|| format!("{flag} needs a value"))?
            .parse()
            .map_err(|e| format!("{flag}: {e}")),
        None => Ok(default),
    }
}

/// Reject `--` tokens the gate does not understand — `--flag=value`
/// syntax (the helpers above take space-separated values only) and
/// unknown flags. Without this, a mistyped `--threshold=0.5` would be
/// silently skipped and the gate would run with its default threshold,
/// which for a CI gate is worse than failing loudly.
pub fn cli_require_known_flags(args: &[String], known: &[&str]) -> Result<(), String> {
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(rest) = a.strip_prefix("--") {
            if rest.contains('=') {
                let name = rest.split('=').next().unwrap_or(rest);
                return Err(format!(
                    "--{name}=... syntax is not supported; pass the value \
                     space-separated: --{name} <value>"
                ));
            }
            if !known.contains(&a.as_str()) {
                return Err(format!("unknown flag {a} (known: {})", known.join(", ")));
            }
            i += 2;
        } else {
            i += 1;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn flag_values_are_not_positional() {
        // `--threshold 0.15` must consume its value, leaving exactly the
        // two paths as positionals.
        let args = argv(&["fresh.json", "base.json", "--threshold", "0.15", "--min-us", "50"]);
        assert_eq!(cli_positionals(&args), ["fresh.json", "base.json"]);
        assert_eq!(cli_flag_f64(&args, "--threshold", 0.99).unwrap(), 0.15);
        assert_eq!(cli_flag_f64(&args, "--min-us", 100.0).unwrap(), 50.0);
        assert_eq!(cli_flag_f64(&args, "--absent", 7.0).unwrap(), 7.0);
    }

    #[test]
    fn malformed_flags_error() {
        assert!(cli_flag_f64(&argv(&["--threshold"]), "--threshold", 0.0).is_err());
        assert!(cli_flag_f64(&argv(&["--threshold", "abc"]), "--threshold", 0.0).is_err());
    }

    #[test]
    fn unknown_and_equals_flags_fail_loudly() {
        let known = ["--threshold"];
        assert!(cli_require_known_flags(&argv(&["a", "--threshold", "0.5"]), &known).is_ok());
        // `--flag=value` must not be silently skipped.
        let err =
            cli_require_known_flags(&argv(&["--threshold=0.5"]), &known).unwrap_err();
        assert!(err.contains("space-separated"), "{err}");
        // An unknown flag must not silently swallow its neighbor.
        assert!(cli_require_known_flags(&argv(&["--verbose", "x"]), &known).is_err());
    }
}
