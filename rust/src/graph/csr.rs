//! Compressed Sparse Row storage (paper §2.2) — `row_ptr` / `col_ind` /
//! `val`, the format cuSPARSE, DGL, and the AES-SpMM kernel all consume
//! directly (no conversion on the inference path).

use anyhow::{bail, Context, Result};

use crate::tensor::NbtFile;

/// A sparse matrix in CSR form. For graphs, rows are destination nodes and
/// `col_ind[e]` is the source of edge `e` (so SpMM aggregates in-neighbors).
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<i32>,
    pub col_ind: Vec<i32>,
    pub val: Vec<f32>,
}

impl Csr {
    /// Build and validate. `row_ptr` must be monotone with
    /// `row_ptr[0] == 0`, `row_ptr[n] == nnz`, and all columns in range.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        row_ptr: Vec<i32>,
        col_ind: Vec<i32>,
        val: Vec<f32>,
    ) -> Result<Self> {
        let csr = Self { n_rows, n_cols, row_ptr, col_ind, val };
        csr.validate()?;
        Ok(csr)
    }

    pub fn validate(&self) -> Result<()> {
        if self.row_ptr.len() != self.n_rows + 1 {
            bail!("row_ptr len {} != n_rows+1 {}", self.row_ptr.len(), self.n_rows + 1);
        }
        if self.row_ptr[0] != 0 {
            bail!("row_ptr[0] = {} != 0", self.row_ptr[0]);
        }
        for i in 0..self.n_rows {
            if self.row_ptr[i + 1] < self.row_ptr[i] {
                bail!("row_ptr not monotone at row {i}");
            }
        }
        let nnz = *self.row_ptr.last().unwrap() as usize;
        if self.col_ind.len() != nnz || self.val.len() != nnz {
            bail!(
                "nnz mismatch: row_ptr says {nnz}, col_ind {} val {}",
                self.col_ind.len(),
                self.val.len()
            );
        }
        if let Some(&c) = self.col_ind.iter().find(|&&c| c < 0 || c as usize >= self.n_cols) {
            bail!("column index {c} out of range [0, {})", self.n_cols);
        }
        Ok(())
    }

    pub fn nnz(&self) -> usize {
        self.col_ind.len()
    }

    pub fn row_nnz(&self, row: usize) -> usize {
        (self.row_ptr[row + 1] - self.row_ptr[row]) as usize
    }

    /// Byte range of one row within col_ind/val.
    pub fn row_range(&self, row: usize) -> std::ops::Range<usize> {
        self.row_ptr[row] as usize..self.row_ptr[row + 1] as usize
    }

    pub fn avg_degree(&self) -> f64 {
        self.nnz() as f64 / self.n_rows.max(1) as f64
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n_rows).map(|i| self.row_nnz(i)).max().unwrap_or(0)
    }

    /// Sparsity in percent, as Table 2 reports it.
    pub fn sparsity_pct(&self) -> f64 {
        100.0 * self.nnz() as f64 / (self.n_rows as f64 * self.n_cols as f64)
    }

    /// Expand to per-edge row ids (input of the segment-sum baseline HLO).
    pub fn row_ids(&self) -> Vec<i32> {
        let mut ids = Vec::with_capacity(self.nnz());
        for i in 0..self.n_rows {
            ids.extend(std::iter::repeat(i as i32).take(self.row_nnz(i)));
        }
        ids
    }

    /// Load the CSR stored in a dataset `.nbt` (keys from datagen.py).
    pub fn from_nbt(nbt: &NbtFile, val_key: &str) -> Result<Self> {
        let row_ptr = nbt.get("row_ptr")?.as_i32()?.to_vec();
        let col_ind = nbt.get("col_ind")?.as_i32()?.to_vec();
        let val = nbt.get(val_key).with_context(|| format!("val key {val_key}"))?;
        let n = row_ptr.len() - 1;
        Csr::new(n, n, row_ptr, col_ind, val.as_f32()?.to_vec())
    }

    /// GCN symmetric normalization: val[e] = 1/sqrt(deg(row) * deg(col)).
    /// (Self-loops must already be present in the structure.)
    pub fn gcn_normalized(&self) -> Csr {
        let deg: Vec<f64> = (0..self.n_rows).map(|i| self.row_nnz(i).max(1) as f64).collect();
        let mut out = self.clone();
        for i in 0..self.n_rows {
            for e in self.row_range(i) {
                let j = self.col_ind[e] as usize;
                out.val[e] = (1.0 / (deg[i] * deg[j]).sqrt()) as f32;
            }
        }
        out
    }

    /// Transpose (also converts dst-major to src-major). O(nnz).
    pub fn transpose(&self) -> Csr {
        let mut deg = vec![0i32; self.n_cols];
        for &c in &self.col_ind {
            deg[c as usize] += 1;
        }
        let mut row_ptr = vec![0i32; self.n_cols + 1];
        for i in 0..self.n_cols {
            row_ptr[i + 1] = row_ptr[i] + deg[i];
        }
        let mut cursor: Vec<i32> = row_ptr[..self.n_cols].to_vec();
        let mut col_ind = vec![0i32; self.nnz()];
        let mut val = vec![0f32; self.nnz()];
        for i in 0..self.n_rows {
            for e in self.row_range(i) {
                let c = self.col_ind[e] as usize;
                let slot = cursor[c] as usize;
                cursor[c] += 1;
                col_ind[slot] = i as i32;
                val[slot] = self.val[e];
            }
        }
        Csr { n_rows: self.n_cols, n_cols: self.n_rows, row_ptr, col_ind, val }
    }
}

/// Build a CSR from COO triples (row, col, val). Sorts by (row, col) and
/// **deduplicates**: repeated `(row, col)` entries collapse to one edge
/// carrying the *last* weight in input order (last-write-wins — the same
/// rule [`crate::graph::GraphDelta`] applies when a delta re-inserts an
/// existing edge). The seed kept duplicates, which double-counted nnz in
/// every working-set and sampling budget the moment mutation could
/// re-insert an edge.
pub fn coo_to_csr(
    n_rows: usize,
    n_cols: usize,
    mut triples: Vec<(i32, i32, f32)>,
) -> Result<Csr> {
    // Stable sort: equal (row, col) keys keep input order, so dedup_by
    // keeping the later element implements last-write-wins.
    triples.sort_by_key(|&(r, c, _)| ((r as i64) << 32) | (c as i64 & 0xffff_ffff));
    triples.dedup_by(|later, earlier| {
        let dup = later.0 == earlier.0 && later.1 == earlier.1;
        if dup {
            // dedup_by drops `later`; keep its weight in the survivor.
            earlier.2 = later.2;
        }
        dup
    });
    let mut row_ptr = vec![0i32; n_rows + 1];
    for &(r, _, _) in &triples {
        if r < 0 || r as usize >= n_rows {
            bail!("row {r} out of range");
        }
        row_ptr[r as usize + 1] += 1;
    }
    for i in 0..n_rows {
        row_ptr[i + 1] += row_ptr[i];
    }
    let col_ind = triples.iter().map(|&(_, c, _)| c).collect();
    let val = triples.iter().map(|&(_, _, v)| v).collect();
    Csr::new(n_rows, n_cols, row_ptr, col_ind, val)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // 3x3: row0 {0:1.0, 2:2.0}, row1 {}, row2 {1:3.0}
        Csr::new(3, 3, vec![0, 2, 2, 3], vec![0, 2, 1], vec![1.0, 2.0, 3.0]).unwrap()
    }

    #[test]
    fn basic_accessors() {
        let m = sample();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(1), 0);
        assert_eq!(m.max_degree(), 2);
        assert!((m.avg_degree() - 1.0).abs() < 1e-12);
        assert_eq!(m.row_ids(), vec![0, 0, 2]);
    }

    #[test]
    fn validation_rejects_bad_inputs() {
        assert!(Csr::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err(), "short row_ptr");
        assert!(Csr::new(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]).is_err(), "non-monotone");
        assert!(Csr::new(2, 2, vec![0, 1, 2], vec![0, 5], vec![1.0, 1.0]).is_err(), "col range");
        assert!(Csr::new(2, 2, vec![1, 1, 2], vec![0], vec![1.0]).is_err(), "row_ptr[0] != 0");
    }

    #[test]
    fn coo_roundtrip() {
        let m = coo_to_csr(3, 3, vec![(2, 1, 3.0), (0, 0, 1.0), (0, 2, 2.0)]).unwrap();
        assert_eq!(m, sample());
    }

    #[test]
    fn coo_duplicates_collapse_last_write_wins() {
        let m = coo_to_csr(
            3,
            3,
            vec![
                (0, 2, 9.0), // overwritten below
                (2, 1, 3.0),
                (0, 0, 1.0),
                (0, 2, 2.0), // last write for (0, 2)
                (0, 0, 1.0), // exact duplicate
            ],
        )
        .unwrap();
        assert_eq!(m, sample(), "duplicates must collapse to the last weight");
        assert_eq!(m.nnz(), 3, "nnz counts unique (row, col) pairs");
    }

    #[test]
    fn transpose_twice_is_identity() {
        let m = sample();
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_moves_entries() {
        let t = sample().transpose();
        // (0,0,1.0) stays; (0,2,2.0) -> (2,0); (2,1,3.0) -> (1,2)
        assert_eq!(t.row_ptr, vec![0, 1, 2, 3]);
        assert_eq!(t.col_ind, vec![0, 2, 0]);
        assert_eq!(t.val, vec![1.0, 3.0, 2.0]);
    }

    #[test]
    fn gcn_normalization_symmetric_graph() {
        // 2-node graph with self loops + one edge both ways: all degs 2.
        let m = Csr::new(
            2,
            2,
            vec![0, 2, 4],
            vec![0, 1, 0, 1],
            vec![1.0; 4],
        )
        .unwrap();
        let g = m.gcn_normalized();
        for v in g.val {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }
}
