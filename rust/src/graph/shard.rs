//! Row-partitioned graph shards — the paper's shared-memory-width
//! argument applied one level up. AES-SpMM shapes each *row's* edge set
//! to fit a fixed fast-memory tile (W); a serving host has the same
//! problem per *worker*: the whole aggregation operand must fit an exec
//! worker's working set or the SpMM thrashes. [`ShardPlan::partition`]
//! cuts a CSR into contiguous row ranges sized against a configurable
//! working-set budget, balanced by edge mass over the [`degree_prefix`]
//! histogram — the same quantile-cut scheme the threaded kernels use
//! for thread chunks, promoted to a first-class, cacheable structure.
//!
//! Each [`GraphShard`] is a self-contained CSR (shard-local rows, global
//! columns), so a shard multiplied against the full feature matrix
//! yields exactly its rows of the full product: concatenating shard
//! outputs row-wise *is* the merge, with no combination arithmetic.

use std::ops::Range;

use anyhow::{bail, Result};

use super::stats::{balanced_cuts, degree_prefix, DegreeStats};
use super::Csr;

/// Bytes per stored CSR edge (f32 value + i32 column index).
const EDGE_BYTES: usize = 8;
/// Bytes of `row_ptr` overhead per row.
const ROW_BYTES: usize = 4;

/// Estimated resident bytes of a CSR row range: its edges plus its
/// `row_ptr` slice. The host analog of "does the row segment fit in
/// shared memory" — here, "does the shard fit a worker's working set".
/// (Feature rows are shared across shards and deliberately not charged.)
pub fn working_set_bytes(rows: usize, nnz: usize) -> usize {
    nnz * EDGE_BYTES + (rows + 1) * ROW_BYTES
}

/// How to cut a graph into shards.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardSpec {
    /// Explicit shard count (the coordinator's `--shards`); `None`
    /// derives the count from `budget_bytes`.
    pub shards: Option<usize>,
    /// Per-shard working-set budget in bytes (`--shard-budget`). Used
    /// when `shards` is `None`: the count becomes
    /// `ceil(total_working_set / budget)`. Best-effort — a single row
    /// larger than the budget still gets (exactly) one shard.
    pub budget_bytes: usize,
}

impl ShardSpec {
    /// Default per-shard working-set budget: 32 MiB, a typical per-core
    /// L2+L3 slice on the serving hosts this models.
    pub const DEFAULT_BUDGET: usize = 32 << 20;

    /// Fixed shard count (budget kept as the default for reporting).
    pub fn by_count(shards: usize) -> ShardSpec {
        ShardSpec { shards: Some(shards.max(1)), budget_bytes: Self::DEFAULT_BUDGET }
    }

    /// Derive the shard count from a working-set budget.
    pub fn by_budget(bytes: usize) -> ShardSpec {
        ShardSpec { shards: None, budget_bytes: bytes.max(1) }
    }
}

impl Default for ShardSpec {
    fn default() -> Self {
        ShardSpec { shards: None, budget_bytes: Self::DEFAULT_BUDGET }
    }
}

/// One contiguous row range of a graph, extracted as a self-contained
/// CSR. Rows are shard-local (`csr.n_rows == rows.len()`), columns stay
/// global (`csr.n_cols` is the full graph's), so the shard multiplies
/// against the full feature matrix directly.
#[derive(Clone, Debug, PartialEq)]
pub struct GraphShard {
    /// Position of this shard in the plan (0-based).
    pub index: usize,
    /// Global row range `[start, end)` this shard covers.
    pub rows: Range<usize>,
    /// The shard's rows as a standalone CSR.
    pub csr: Csr,
}

impl GraphShard {
    /// Rows in this shard.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Stored edges in this shard.
    pub fn nnz(&self) -> usize {
        self.csr.nnz()
    }

    /// Estimated resident bytes (see [`working_set_bytes`]).
    pub fn working_set_bytes(&self) -> usize {
        working_set_bytes(self.n_rows(), self.nnz())
    }

    /// Degree statistics of this shard's rows — the skew signal the
    /// per-shard sampling and kernel decisions key on.
    pub fn stats(&self) -> DegreeStats {
        DegreeStats::of(&self.csr)
    }
}

/// The partition of one graph into row shards. Invariants (checked by
/// [`ShardPlan::validate`] and the partitioner's construction): shards
/// are contiguous, disjoint, cover every row exactly once, and are
/// non-empty whenever the graph has rows.
#[derive(Clone, Debug)]
pub struct ShardPlan {
    n_rows: usize,
    n_cols: usize,
    shards: Vec<GraphShard>,
}

impl ShardPlan {
    /// Cut `csr` into shards per `spec`.
    ///
    /// The shard count is `spec.shards` if given, else
    /// `ceil(total_working_set / budget)`, clamped to `[1, n_rows]` —
    /// a row is never split, so a single mega-row exceeding the budget
    /// simply becomes its own (over-budget) shard. Cut points are
    /// edge-mass quantiles over the degree prefix histogram, the same
    /// balancing the threaded kernels use; an all-zero-nnz graph falls
    /// back to even row counts.
    pub fn partition(csr: &Csr, spec: &ShardSpec) -> ShardPlan {
        if csr.n_rows == 0 {
            let empty = Csr::new(0, csr.n_cols, vec![0], Vec::new(), Vec::new())
                .expect("the empty CSR is valid");
            let shard = GraphShard { index: 0, rows: 0..0, csr: empty };
            return ShardPlan { n_rows: 0, n_cols: csr.n_cols, shards: vec![shard] };
        }
        let shards = partition_bounds(csr, spec)
            .into_iter()
            .enumerate()
            .map(|(index, rows)| GraphShard {
                index,
                rows: rows.clone(),
                csr: extract_rows(csr, rows),
            })
            .collect();
        ShardPlan { n_rows: csr.n_rows, n_cols: csr.n_cols, shards }
    }

    /// Re-extract shards along **fixed** cut points instead of deriving
    /// new quantile cuts — the live-mutation path. A mutated graph must
    /// keep its serving partition (so untouched shards stay cache-warm
    /// and [`crate::exec::ShardKey`]s keep matching) until the
    /// coordinator decides a shard drifted past its working-set budget
    /// and re-partitions explicitly.
    ///
    /// `bounds` must be the contiguous disjoint cover of `0..n_rows`
    /// that a previous [`ShardPlan::partition`] produced (row counts
    /// never change under edge deltas); panics otherwise — a mismatch
    /// means the caller's sticky layout is for a different graph.
    pub fn partition_fixed(csr: &Csr, bounds: &[Range<usize>]) -> ShardPlan {
        assert!(!bounds.is_empty(), "a shard layout holds at least one range");
        let mut next = 0usize;
        for r in bounds {
            assert_eq!(r.start, next, "shard layout ranges must be contiguous");
            next = r.end;
        }
        assert_eq!(next, csr.n_rows, "shard layout must cover the graph's rows");
        let shards = bounds
            .iter()
            .enumerate()
            .map(|(index, rows)| GraphShard {
                index,
                rows: rows.clone(),
                csr: extract_rows(csr, rows.clone()),
            })
            .collect();
        ShardPlan { n_rows: csr.n_rows, n_cols: csr.n_cols, shards }
    }

    /// The shards, in row order.
    pub fn shards(&self) -> &[GraphShard] {
        &self.shards
    }

    /// Consume the plan, yielding owned shards (in row order).
    pub fn into_shards(self) -> Vec<GraphShard> {
        self.shards
    }

    /// Number of shards (always ≥ 1).
    pub fn len(&self) -> usize {
        self.shards.len()
    }

    /// Whether the plan holds no shards (never true for plans built by
    /// [`ShardPlan::partition`], which emits at least one).
    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    /// Rows of the partitioned graph.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Columns of the partitioned graph (global — shared by all shards).
    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Check the partition invariants: contiguous disjoint cover of
    /// `0..n_rows`, non-empty shards (unless the graph is empty), and
    /// each shard a valid standalone CSR with matching dimensions.
    pub fn validate(&self) -> Result<()> {
        if self.shards.is_empty() {
            bail!("a shard plan must hold at least one shard");
        }
        let mut next = 0usize;
        for s in &self.shards {
            if s.rows.start != next {
                bail!("shard {} starts at {} (expected {next})", s.index, s.rows.start);
            }
            if s.rows.is_empty() && self.n_rows > 0 {
                bail!("shard {} is empty", s.index);
            }
            if s.csr.n_rows != s.rows.len() || s.csr.n_cols != self.n_cols {
                bail!("shard {} CSR dims disagree with its row range", s.index);
            }
            s.csr.validate()?;
            next = s.rows.end;
        }
        if next != self.n_rows {
            bail!("shards cover rows 0..{next}, graph has {}", self.n_rows);
        }
        Ok(())
    }
}

/// Just the cut points [`ShardPlan::partition`] would use — no shard
/// extraction, O(n_rows). The one source of truth for the cuts: the
/// sticky serving layouts (`crate::exec::ShardLayout`) derive bounds
/// here without paying the per-shard CSR copies, and `partition`
/// extracts along the same cuts.
pub fn partition_bounds(csr: &Csr, spec: &ShardSpec) -> Vec<Range<usize>> {
    let n = csr.n_rows;
    if n == 0 {
        return vec![0..0];
    }
    let prefix = degree_prefix(csr);
    let total = prefix[n];
    let want = match spec.shards {
        Some(k) => k,
        None => working_set_bytes(n, total).div_ceil(spec.budget_bytes.max(1)),
    };
    balanced_cuts(&prefix, want)
}

/// Slice `rows` out of `csr` as a standalone CSR (local rows, global
/// columns). O(shard nnz).
fn extract_rows(csr: &Csr, rows: Range<usize>) -> Csr {
    let base = csr.row_ptr[rows.start];
    let lo = base as usize;
    let hi = csr.row_ptr[rows.end] as usize;
    let row_ptr: Vec<i32> = csr.row_ptr[rows.start..=rows.end].iter().map(|&p| p - base).collect();
    Csr::new(
        rows.len(),
        csr.n_cols,
        row_ptr,
        csr.col_ind[lo..hi].to_vec(),
        csr.val[lo..hi].to_vec(),
    )
    .expect("a row slice of a valid CSR is valid")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Pcg32;

    fn cover_exactly_once(plan: &ShardPlan) {
        plan.validate().unwrap();
        let mut owner = vec![0usize; plan.n_rows()];
        for s in plan.shards() {
            for r in s.rows.clone() {
                owner[r] += 1;
            }
        }
        assert!(owner.iter().all(|&c| c == 1), "every row in exactly one shard");
    }

    #[test]
    fn partition_by_count_covers_and_balances() {
        let mut rng = Pcg32::new(3);
        let g = gen::chung_lu(500, 20.0, 1.8, &mut rng);
        for k in [1usize, 2, 3, 7, 16] {
            let plan = ShardPlan::partition(&g, &ShardSpec::by_count(k));
            assert_eq!(plan.len(), k.min(g.n_rows));
            cover_exactly_once(&plan);
            // Shard rows reproduce the original rows bit-for-bit.
            for s in plan.shards() {
                for (li, gi) in s.rows.clone().enumerate() {
                    assert_eq!(s.csr.row_nnz(li), g.row_nnz(gi));
                    let lr = s.csr.row_range(li);
                    let gr = g.row_range(gi);
                    assert_eq!(&s.csr.col_ind[lr.clone()], &g.col_ind[gr.clone()]);
                    assert_eq!(&s.csr.val[lr], &g.val[gr]);
                }
            }
        }
    }

    #[test]
    fn partition_by_budget_respects_the_budget_on_average() {
        let mut rng = Pcg32::new(9);
        let g = gen::chung_lu(2000, 30.0, 2.0, &mut rng);
        let total = working_set_bytes(g.n_rows, g.nnz());
        let budget = total / 5;
        let plan = ShardPlan::partition(&g, &ShardSpec::by_budget(budget));
        assert!(plan.len() >= 5, "5× the budget needs ≥5 shards (got {})", plan.len());
        cover_exactly_once(&plan);
        // Quantile cuts keep shards near the budget (2× slack for row
        // granularity).
        for s in plan.shards() {
            assert!(
                s.working_set_bytes() <= budget * 2,
                "shard {} holds {}B against a {budget}B budget",
                s.index,
                s.working_set_bytes()
            );
        }
    }

    #[test]
    fn mega_row_exceeding_the_budget_gets_its_own_shard() {
        // Row 1 alone dwarfs the budget; the partitioner must isolate it
        // without panicking or splitting it.
        let row_ptr = vec![0i32, 2, 10_002, 10_004, 10_006];
        let nnz = *row_ptr.last().unwrap() as usize;
        let col_ind: Vec<i32> = (0..nnz).map(|e| (e % 4) as i32).collect();
        let g = Csr::new(4, 4, row_ptr, col_ind, vec![1.0; nnz]).unwrap();
        let budget = working_set_bytes(1, 100); // far below the mega row
        let plan = ShardPlan::partition(&g, &ShardSpec::by_budget(budget));
        cover_exactly_once(&plan);
        let mega = plan.shards().iter().find(|s| s.rows.contains(&1)).unwrap();
        assert!(mega.working_set_bytes() > budget, "mega shard is over budget by design");
        // The light rows are not trapped behind it.
        assert!(plan.len() >= 2);
    }

    #[test]
    fn degenerate_graphs_partition_without_panic() {
        // Empty graph → one empty shard.
        let g = Csr::new(0, 7, vec![0], vec![], vec![]).unwrap();
        let plan = ShardPlan::partition(&g, &ShardSpec::by_count(4));
        assert_eq!(plan.len(), 1);
        assert_eq!(plan.shards()[0].rows, 0..0);
        plan.validate().unwrap();

        // Single row, many shards requested → one shard.
        let g = Csr::new(1, 2, vec![0, 2], vec![0, 1], vec![1.0, 2.0]).unwrap();
        let plan = ShardPlan::partition(&g, &ShardSpec::by_count(8));
        assert_eq!(plan.len(), 1);
        cover_exactly_once(&plan);

        // All-empty rows: zero edge mass falls back to even row cuts.
        let g = Csr::new(9, 9, vec![0; 10], vec![], vec![]).unwrap();
        let plan = ShardPlan::partition(&g, &ShardSpec::by_count(3));
        assert_eq!(plan.len(), 3);
        cover_exactly_once(&plan);
    }

    #[test]
    fn shard_stats_expose_skew() {
        // Uniform head (40 rows × deg 4 = 160 edges) and heavy tail
        // (2 rows × deg 80 = 160 edges): equal masses put the 2-way
        // quantile cut exactly on the boundary, so the tail shard's max
        // degree dwarfs the head shard's.
        let mut triples = Vec::new();
        for r in 0..40 {
            for c in 0..4 {
                triples.push((r as i32, c as i32, 1.0));
            }
        }
        for c in 0..80 {
            // Distinct columns per row — coo_to_csr dedupes repeats.
            triples.push((40, c, 1.0));
            triples.push((41, (c + 7) % 100, 1.0));
        }
        let g = crate::graph::coo_to_csr(42, 100, triples).unwrap();
        let plan = ShardPlan::partition(&g, &ShardSpec::by_count(2));
        cover_exactly_once(&plan);
        assert_eq!(plan.shards()[0].rows, 0..40);
        let head = plan.shards()[0].stats();
        let tail = plan.shards().last().unwrap().stats();
        assert!(tail.max > head.max * 10, "tail max {} vs head max {}", tail.max, head.max);
    }

    #[test]
    fn partition_fixed_reuses_cuts_across_content_changes() {
        let mut rng = Pcg32::new(5);
        let g = gen::chung_lu(300, 12.0, 2.0, &mut rng);
        let plan = ShardPlan::partition(&g, &ShardSpec::by_count(4));
        let bounds: Vec<Range<usize>> = plan.shards().iter().map(|s| s.rows.clone()).collect();

        // Same graph, fixed cuts: identical shards.
        let fixed = ShardPlan::partition_fixed(&g, &bounds);
        fixed.validate().unwrap();
        assert_eq!(plan.shards(), fixed.shards());

        // Mutated content (one edge reweighted) keeps the cuts even
        // though fresh quantile cuts might move.
        let mut g2 = g.clone();
        g2.val[0] += 1.0;
        let fixed2 = ShardPlan::partition_fixed(&g2, &bounds);
        fixed2.validate().unwrap();
        assert_eq!(
            fixed2.shards().iter().map(|s| s.rows.clone()).collect::<Vec<_>>(),
            bounds
        );
        // Untouched shards are content-identical to the original's.
        assert_eq!(fixed2.shards()[1], plan.shards()[1]);
    }

    #[test]
    #[should_panic(expected = "cover the graph's rows")]
    fn partition_fixed_rejects_mismatched_layouts() {
        let g = Csr::new(3, 3, vec![0, 1, 2, 3], vec![0, 1, 2], vec![1.0; 3]).unwrap();
        let _ = ShardPlan::partition_fixed(&g, &[0..2]);
    }

    #[test]
    fn working_set_model_is_monotone() {
        assert!(working_set_bytes(10, 100) < working_set_bytes(10, 200));
        assert!(working_set_bytes(10, 100) < working_set_bytes(20, 100));
        assert_eq!(working_set_bytes(0, 0), ROW_BYTES);
    }
}
