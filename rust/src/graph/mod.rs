//! Graph structures: CSR (the kernel input format, §2.2 of the paper),
//! ELL (the sampled fixed-width form that models the shared-memory tile),
//! COO↔CSR conversion, validation, degree statistics, the
//! working-set-budgeted row shard partitioner (the host-level analog of
//! the shared-memory width — see `docs/sharding.md`), and epoch-versioned
//! live-graph deltas (`docs/mutation.md`).

mod csr;
mod delta;
mod ell;
mod shard;
mod stats;

pub use csr::{coo_to_csr, Csr};
pub use delta::{DeltaReport, EdgeOp, GraphDelta, VersionedCsr};
pub use ell::Ell;
pub use shard::{partition_bounds, working_set_bytes, GraphShard, ShardPlan, ShardSpec};
pub use stats::{balanced_cuts, degree_cdf, degree_prefix, DegreeStats};
