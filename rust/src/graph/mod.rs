//! Graph structures: CSR (the kernel input format, §2.2 of the paper),
//! ELL (the sampled fixed-width form that models the shared-memory tile),
//! COO↔CSR conversion, validation, and degree statistics.

mod csr;
mod ell;
mod stats;

pub use csr::{coo_to_csr, Csr};
pub use ell::Ell;
pub use stats::{degree_cdf, DegreeStats};
