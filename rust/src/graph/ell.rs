//! ELL (padded fixed-width) storage — the host-side model of the paper's
//! shared-memory tile: every row holds exactly `width` (val, col) slots,
//! padding slots are (0.0, 0). The sampling planners in [`crate::sampling`]
//! produce this form; [`crate::spmm::ell`] multiplies it.

use anyhow::{bail, Result};

/// Fixed-width sampled matrix. `slots[i]` counts valid entries in row `i`
/// (matching the `slots` output of the L1 `aes_sample` kernel).
#[derive(Clone, Debug, PartialEq)]
pub struct Ell {
    pub n_rows: usize,
    pub n_cols: usize,
    pub width: usize,
    /// Row-major `[n_rows * width]` values; padding = 0.0.
    pub val: Vec<f32>,
    /// Row-major `[n_rows * width]` column indices; padding = 0.
    pub col: Vec<i32>,
    /// Valid slots per row.
    pub slots: Vec<i32>,
}

impl Ell {
    pub fn zeros(n_rows: usize, n_cols: usize, width: usize) -> Self {
        Self {
            n_rows,
            n_cols,
            width,
            val: vec![0.0; n_rows * width],
            col: vec![0; n_rows * width],
            slots: vec![0; n_rows],
        }
    }

    pub fn row_val(&self, row: usize) -> &[f32] {
        &self.val[row * self.width..(row + 1) * self.width]
    }

    pub fn row_col(&self, row: usize) -> &[i32] {
        &self.col[row * self.width..(row + 1) * self.width]
    }

    /// Total valid slots (the "kept edges" numerator of Fig. 5, before
    /// capping draws at row_nnz).
    pub fn total_slots(&self) -> usize {
        self.slots.iter().map(|&s| s as usize).sum()
    }

    pub fn validate(&self) -> Result<()> {
        if self.val.len() != self.n_rows * self.width
            || self.col.len() != self.n_rows * self.width
            || self.slots.len() != self.n_rows
        {
            bail!("ELL buffer sizes inconsistent with n_rows={} width={}", self.n_rows, self.width);
        }
        for (i, &s) in self.slots.iter().enumerate() {
            if s < 0 || s as usize > self.width {
                bail!("row {i}: slots {s} outside [0, {}]", self.width);
            }
        }
        if let Some(&c) = self.col.iter().find(|&&c| c < 0 || c as usize >= self.n_cols) {
            bail!("ELL column {c} out of range [0, {})", self.n_cols);
        }
        // Padding slots must be exactly (0.0, 0) so the dense multiply can
        // skip masking.
        for i in 0..self.n_rows {
            let s = self.slots[i] as usize;
            for k in s..self.width {
                if self.val[i * self.width + k] != 0.0 || self.col[i * self.width + k] != 0 {
                    bail!("row {i} slot {k}: padding not zeroed");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_is_valid() {
        let e = Ell::zeros(4, 4, 8);
        e.validate().unwrap();
        assert_eq!(e.total_slots(), 0);
    }

    #[test]
    fn validate_catches_dirty_padding() {
        let mut e = Ell::zeros(2, 2, 4);
        e.slots[0] = 1;
        e.val[0] = 2.0;
        e.col[0] = 1;
        e.validate().unwrap();
        e.val[3] = 5.0; // padding slot
        assert!(e.validate().is_err());
    }

    #[test]
    fn validate_catches_out_of_range() {
        let mut e = Ell::zeros(2, 2, 2);
        e.slots[1] = 3; // > width
        assert!(e.validate().is_err());
        let mut e = Ell::zeros(2, 2, 2);
        e.col[0] = 9;
        e.slots[0] = 1;
        assert!(e.validate().is_err());
    }

    #[test]
    fn row_views() {
        let mut e = Ell::zeros(2, 3, 2);
        e.val.copy_from_slice(&[1.0, 2.0, 3.0, 0.0]);
        e.col.copy_from_slice(&[0, 1, 2, 0]);
        e.slots = vec![2, 1];
        assert_eq!(e.row_val(0), &[1.0, 2.0]);
        assert_eq!(e.row_col(1), &[2, 0]);
        assert_eq!(e.total_slots(), 3);
    }
}
