//! Epoch-versioned live-graph deltas — the mutation half of the serving
//! story. The paper's sampling (and ES-SpMM's cache-first sampling
//! before it) assumes a static graph; a served graph gains edges, loses
//! edges, and re-weights them while plans are warm. This module defines
//! the mutation unit ([`GraphDelta`]), the versioned structure it
//! applies to ([`VersionedCsr`]: a CSR plus a monotonically increasing
//! **epoch**), and the change summary ([`DeltaReport`]) the coordinator
//! uses for shard-scoped invalidation (`docs/mutation.md`).
//!
//! Semantics (all deterministic, all order-preserving):
//! * **Insert** of an absent `(row, col)` appends the edge at the row's
//!   tail; insert of a present edge is last-write-wins on the weight
//!   (counted as a reweight) — the same dedup rule
//!   [`crate::graph::coo_to_csr`] applies at construction time.
//! * **Delete** removes the edge; deleting an absent edge is a counted
//!   no-op. Deleting a row's last edge leaves a valid empty row —
//!   "node deletion" is expressed as deleting its edges.
//! * **Reweight** updates a present edge's value in place; reweighting
//!   an absent edge is a counted no-op (it does *not* insert).
//! * Surviving edges keep their stored order, so untouched rows are
//!   byte-identical and a touched row's surviving prefix keeps its FP
//!   aggregation order.
//! * Delta values are final stored values (for GCN routes, the
//!   republished Â entries). Re-normalization is the publisher's
//!   concern: a weight policy that depends on degrees must emit the
//!   corresponding reweights itself.
//! * A delta that changes nothing (empty, or all no-ops) does **not**
//!   advance the epoch — callers can use `report.changed()` to skip
//!   invalidation entirely.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::Csr;

/// One edge mutation. Rows/columns are global node ids.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeOp {
    /// Add `(row, col)` with `weight`; last-write-wins if present.
    Insert {
        /// Destination row.
        row: i32,
        /// Source column.
        col: i32,
        /// Stored edge value.
        weight: f32,
    },
    /// Remove `(row, col)`; no-op if absent.
    Delete {
        /// Destination row.
        row: i32,
        /// Source column.
        col: i32,
    },
    /// Set the value of a present `(row, col)`; no-op if absent.
    Reweight {
        /// Destination row.
        row: i32,
        /// Source column.
        col: i32,
        /// New stored edge value.
        weight: f32,
    },
}

impl EdgeOp {
    /// The destination row this op names.
    pub fn row(&self) -> i32 {
        match *self {
            EdgeOp::Insert { row, .. }
            | EdgeOp::Delete { row, .. }
            | EdgeOp::Reweight { row, .. } => row,
        }
    }

    /// The source column this op names.
    pub fn col(&self) -> i32 {
        match *self {
            EdgeOp::Insert { col, .. }
            | EdgeOp::Delete { col, .. }
            | EdgeOp::Reweight { col, .. } => col,
        }
    }
}

/// An ordered batch of edge mutations, applied atomically as one epoch.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GraphDelta {
    /// Ops in application order (later ops win within a batch).
    pub ops: Vec<EdgeOp>,
}

impl GraphDelta {
    /// Wrap an op list.
    pub fn new(ops: Vec<EdgeOp>) -> GraphDelta {
        GraphDelta { ops }
    }

    /// Whether the batch holds no ops.
    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Ops in the batch.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    /// Parse the CLI/file format (`repro mutate --edges FILE`): one op
    /// per line, `#` comments and blank lines ignored.
    ///
    /// ```text
    /// + ROW COL WEIGHT    # insert (reweight if the edge exists)
    /// - ROW COL           # delete (no-op if absent)
    /// = ROW COL WEIGHT    # reweight (no-op if absent)
    /// ```
    pub fn parse(text: &str) -> Result<GraphDelta> {
        let mut ops = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.split_whitespace();
            let op = parts.next().unwrap_or("");
            let ctx = || format!("delta line {}: {raw:?}", lineno + 1);
            let mut num = |what: &str| -> Result<i32> {
                parts
                    .next()
                    .with_context(|| format!("{}: missing {what}", ctx()))?
                    .parse::<i32>()
                    .with_context(|| format!("{}: {what} must be an integer", ctx()))
            };
            let (row, col) = (num("row")?, num("col")?);
            let weight = |parts: &mut std::str::SplitWhitespace<'_>| -> Result<f32> {
                parts
                    .next()
                    .with_context(|| format!("{}: missing weight", ctx()))?
                    .parse::<f32>()
                    .with_context(|| format!("{}: weight must be a float", ctx()))
            };
            let parsed = match op {
                "+" => EdgeOp::Insert { row, col, weight: weight(&mut parts)? },
                "-" => EdgeOp::Delete { row, col },
                "=" => EdgeOp::Reweight { row, col, weight: weight(&mut parts)? },
                other => bail!("{}: unknown op {other:?} (expected + - =)", ctx()),
            };
            if let Some(extra) = parts.next() {
                bail!("{}: trailing token {extra:?}", ctx());
            }
            ops.push(parsed);
        }
        Ok(GraphDelta { ops })
    }

    /// Read and [`GraphDelta::parse`] a delta file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<GraphDelta> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading delta file {}", path.display()))?;
        Self::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    /// Splice this delta into a borrowed CSR. Returns the mutated graph
    /// (`None` when nothing changed — empty or all-no-op deltas) and
    /// the change report. O(nnz + ops); the input is never copied or
    /// modified. This is the allocation-minimal entry the coordinator
    /// uses; [`VersionedCsr::apply`] layers epoch bookkeeping on top.
    pub fn apply_to(&self, csr: &Csr) -> Result<(Option<Csr>, DeltaReport)> {
        let mut report = DeltaReport {
            nnz_before: csr.nnz(),
            nnz_after: csr.nnz(),
            ..DeltaReport::default()
        };
        // Validate every op before touching anything: a delta applies
        // atomically or not at all.
        for op in &self.ops {
            let (r, c) = (op.row(), op.col());
            if r < 0 || r as usize >= csr.n_rows {
                bail!("delta row {r} out of range [0, {})", csr.n_rows);
            }
            if c < 0 || c as usize >= csr.n_cols {
                bail!("delta col {c} out of range [0, {})", csr.n_cols);
            }
        }
        let mut by_row: BTreeMap<usize, Vec<&EdgeOp>> = BTreeMap::new();
        for op in &self.ops {
            by_row.entry(op.row() as usize).or_default().push(op);
        }

        // Splice touched rows; copy untouched ranges wholesale.
        let mut row_ptr = Vec::with_capacity(csr.n_rows + 1);
        let mut col_ind = Vec::with_capacity(csr.nnz());
        let mut val = Vec::with_capacity(csr.nnz());
        row_ptr.push(0i32);
        let mut touched = Vec::with_capacity(by_row.len());
        for row in 0..csr.n_rows {
            let range = csr.row_range(row);
            match by_row.get(&row) {
                None => {
                    col_ind.extend_from_slice(&csr.col_ind[range.clone()]);
                    val.extend_from_slice(&csr.val[range]);
                }
                Some(ops) => {
                    let mut cols: Vec<i32> = csr.col_ind[range.clone()].to_vec();
                    let mut vals: Vec<f32> = csr.val[range].to_vec();
                    let mut changed = false;
                    for op in ops {
                        let at = cols.iter().position(|&c| c == op.col());
                        match (op, at) {
                            (EdgeOp::Insert { weight, .. }, Some(i))
                            | (EdgeOp::Reweight { weight, .. }, Some(i)) => {
                                // Value-only change; bitwise-identical
                                // rewrites still count (simpler contract,
                                // and rare enough not to matter).
                                vals[i] = *weight;
                                report.reweighted += 1;
                                changed = true;
                            }
                            (EdgeOp::Insert { col, weight, .. }, None) => {
                                cols.push(*col);
                                vals.push(*weight);
                                report.inserted += 1;
                                changed = true;
                            }
                            (EdgeOp::Delete { .. }, Some(i)) => {
                                cols.remove(i);
                                vals.remove(i);
                                report.deleted += 1;
                                changed = true;
                            }
                            (EdgeOp::Delete { .. }, None) | (EdgeOp::Reweight { .. }, None) => {
                                report.noops += 1;
                            }
                        }
                    }
                    if changed {
                        touched.push(row);
                    }
                    col_ind.extend_from_slice(&cols);
                    val.extend_from_slice(&vals);
                }
            }
            row_ptr.push(col_ind.len() as i32);
        }

        if touched.is_empty() {
            return Ok((None, report));
        }
        report.touched_rows = touched;
        report.nnz_after = col_ind.len();
        let next = Csr::new(csr.n_rows, csr.n_cols, row_ptr, col_ind, val)
            .context("delta splice produced an invalid CSR")?;
        Ok((Some(next), report))
    }
}

/// What one [`VersionedCsr::apply`] actually changed — the coordinator's
/// invalidation input.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeltaReport {
    /// Rows whose edge set or values actually changed (sorted, unique).
    /// No-op rows are *not* listed: they need no invalidation.
    pub touched_rows: Vec<usize>,
    /// Edges added (absent before).
    pub inserted: usize,
    /// Edges removed.
    pub deleted: usize,
    /// Edge values updated in place (including insert-of-present).
    pub reweighted: usize,
    /// Ops that matched nothing (delete/reweight of an absent edge).
    pub noops: usize,
    /// Stored edges before the splice.
    pub nnz_before: usize,
    /// Stored edges after the splice.
    pub nnz_after: usize,
}

impl DeltaReport {
    /// Whether the delta changed anything (structure or values). A
    /// no-change apply keeps the epoch, so nothing needs invalidating.
    pub fn changed(&self) -> bool {
        !self.touched_rows.is_empty()
    }
}

/// A CSR with an epoch — the unit the serving stack versions plans
/// against. Epoch 0 is the loaded graph; every changing
/// [`VersionedCsr::apply`] produces a **new** value at epoch + 1 (the
/// previous epoch stays valid for readers still holding it — mutation
/// is publish-by-replacement, never in place).
#[derive(Clone, Debug)]
pub struct VersionedCsr {
    csr: Arc<Csr>,
    epoch: u64,
}

impl VersionedCsr {
    /// Wrap a freshly loaded graph at epoch 0.
    pub fn new(csr: Csr) -> VersionedCsr {
        VersionedCsr { csr: Arc::new(csr), epoch: 0 }
    }

    /// Wrap an existing graph at a known epoch (the coordinator rebuilds
    /// these from [`crate::runtime::Dataset`] state).
    pub fn with_epoch(csr: Arc<Csr>, epoch: u64) -> VersionedCsr {
        VersionedCsr { csr, epoch }
    }

    /// The graph at this epoch.
    pub fn csr(&self) -> &Arc<Csr> {
        &self.csr
    }

    /// The epoch of this value.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Apply a delta, producing the next epoch's graph and the change
    /// report (see [`GraphDelta::apply_to`] for the splice semantics).
    /// The receiver is untouched (readers holding epoch N keep a
    /// consistent graph); a delta that changes nothing returns a clone
    /// at the **same** epoch with `report.changed() == false`.
    pub fn apply(&self, delta: &GraphDelta) -> Result<(VersionedCsr, DeltaReport)> {
        match delta.apply_to(&self.csr)? {
            // Nothing changed: keep the epoch (and the Arc) — callers
            // skip invalidation entirely.
            (None, report) => Ok((self.clone(), report)),
            (Some(next), report) => {
                Ok((VersionedCsr { csr: Arc::new(next), epoch: self.epoch + 1 }, report))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> VersionedCsr {
        // 4x4: row0 {0:1.0, 2:2.0}, row1 {1:3.0}, row2 {}, row3 {3:4.0}
        VersionedCsr::new(
            Csr::new(
                4,
                4,
                vec![0, 2, 3, 3, 4],
                vec![0, 2, 1, 3],
                vec![1.0, 2.0, 3.0, 4.0],
            )
            .unwrap(),
        )
    }

    #[test]
    fn insert_delete_reweight_splice() {
        let v = base();
        let delta = GraphDelta::new(vec![
            EdgeOp::Insert { row: 0, col: 3, weight: 9.0 }, // append to row 0
            EdgeOp::Delete { row: 1, col: 1 },              // empties row 1
            EdgeOp::Insert { row: 2, col: 0, weight: 7.0 }, // into empty row
            EdgeOp::Reweight { row: 3, col: 3, weight: 5.0 },
        ]);
        let (next, report) = v.apply(&delta).unwrap();
        assert_eq!(next.epoch(), 1);
        assert_eq!(report.touched_rows, vec![0, 1, 2, 3]);
        assert_eq!((report.inserted, report.deleted, report.reweighted), (2, 1, 1));
        assert_eq!(report.noops, 0);
        assert_eq!((report.nnz_before, report.nnz_after), (4, 5));
        let g = next.csr();
        g.validate().unwrap();
        assert_eq!(g.row_ptr, vec![0, 3, 3, 4, 5]);
        // Surviving edges keep stored order; the insert appends.
        assert_eq!(g.col_ind, vec![0, 2, 3, 0, 3]);
        assert_eq!(g.val, vec![1.0, 2.0, 9.0, 7.0, 5.0]);
        // The source epoch is untouched (publish-by-replacement).
        assert_eq!(v.epoch(), 0);
        assert_eq!(v.csr().nnz(), 4);
    }

    #[test]
    fn insert_of_present_edge_is_last_write_wins() {
        let v = base();
        let delta = GraphDelta::new(vec![
            EdgeOp::Insert { row: 0, col: 2, weight: 8.0 },
            EdgeOp::Insert { row: 0, col: 2, weight: 6.5 },
        ]);
        let (next, report) = v.apply(&delta).unwrap();
        assert_eq!(report.inserted, 0);
        assert_eq!(report.reweighted, 2);
        assert_eq!(next.csr().nnz(), 4, "re-inserting must not duplicate the edge");
        assert_eq!(next.csr().val[1], 6.5);
    }

    #[test]
    fn noop_delta_keeps_the_epoch() {
        let v = base();
        // Empty delta.
        let (same, report) = v.apply(&GraphDelta::default()).unwrap();
        assert_eq!(same.epoch(), 0);
        assert!(!report.changed());
        // All-noop delta (delete/reweight of absent edges).
        let delta = GraphDelta::new(vec![
            EdgeOp::Delete { row: 2, col: 2 },
            EdgeOp::Reweight { row: 0, col: 1, weight: 1.0 },
        ]);
        let (same, report) = v.apply(&delta).unwrap();
        assert_eq!(same.epoch(), 0, "no-op deltas must not advance the epoch");
        assert!(!report.changed());
        assert_eq!(report.noops, 2);
        assert!(Arc::ptr_eq(same.csr(), v.csr()), "no-change apply shares the graph");
    }

    #[test]
    fn delete_last_edge_leaves_a_valid_empty_row() {
        let v = base();
        let delta = GraphDelta::new(vec![EdgeOp::Delete { row: 3, col: 3 }]);
        let (next, report) = v.apply(&delta).unwrap();
        assert_eq!(report.touched_rows, vec![3]);
        let g = next.csr();
        g.validate().unwrap();
        assert_eq!(g.row_nnz(3), 0);
        assert_eq!(g.nnz(), 3);
        // And the row can be refilled in a later epoch.
        let delta = GraphDelta::new(vec![EdgeOp::Insert { row: 3, col: 0, weight: 1.5 }]);
        let (refilled, _) = next.apply(&delta).unwrap();
        assert_eq!(refilled.epoch(), 2);
        assert_eq!(refilled.csr().row_nnz(3), 1);
    }

    #[test]
    fn out_of_range_ops_fail_atomically() {
        let v = base();
        let delta = GraphDelta::new(vec![
            EdgeOp::Insert { row: 0, col: 1, weight: 1.0 }, // valid...
            EdgeOp::Delete { row: 9, col: 0 },              // ...but this is not
        ]);
        assert!(v.apply(&delta).is_err());
        let delta = GraphDelta::new(vec![EdgeOp::Insert { row: 0, col: -1, weight: 1.0 }]);
        assert!(v.apply(&delta).is_err());
        assert_eq!(v.csr().nnz(), 4, "a failed apply changes nothing");
    }

    #[test]
    fn parse_round_trips_the_file_format() {
        let text = "\
            # weight rotation\n\
            + 0 3 0.25\n\
            - 1 1      # drop the hub edge\n\
            = 3 3 1.5\n\
            \n";
        let delta = GraphDelta::parse(text).unwrap();
        assert_eq!(
            delta.ops,
            vec![
                EdgeOp::Insert { row: 0, col: 3, weight: 0.25 },
                EdgeOp::Delete { row: 1, col: 1 },
                EdgeOp::Reweight { row: 3, col: 3, weight: 1.5 },
            ]
        );
        assert!(GraphDelta::parse("? 1 2").is_err(), "unknown op");
        assert!(GraphDelta::parse("+ 1 2").is_err(), "insert without weight");
        assert!(GraphDelta::parse("- 1 2 3.0").is_err(), "trailing token");
        assert!(GraphDelta::parse("+ a 2 1.0").is_err(), "non-integer row");
    }

    #[test]
    fn epochs_chain_across_applies() {
        let v = base();
        let d1 = GraphDelta::new(vec![EdgeOp::Insert { row: 2, col: 1, weight: 1.0 }]);
        let d2 = GraphDelta::new(vec![EdgeOp::Delete { row: 2, col: 1 }]);
        let (a, _) = v.apply(&d1).unwrap();
        let (b, _) = a.apply(&d2).unwrap();
        assert_eq!((a.epoch(), b.epoch()), (1, 2));
        // Structure returns to the original; the epoch does not.
        assert_eq!(b.csr().col_ind, v.csr().col_ind);
        assert_eq!(b.csr().row_ptr, v.csr().row_ptr);
    }
}
