//! Degree statistics — drives the Fig. 5 analysis (how the degree
//! distribution interacts with the shared-memory width W) and the Table 2
//! dataset summary printed by `repro inspect`.

use super::Csr;

/// Summary statistics over row degrees.
#[derive(Clone, Debug, PartialEq)]
pub struct DegreeStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub median: usize,
    pub p90: usize,
    pub p99: usize,
    /// Fraction of rows with degree <= W, for each probe width.
    pub frac_within: Vec<(usize, f64)>,
}

const PROBE_WIDTHS: [usize; 7] = [16, 32, 64, 128, 256, 512, 1024];

impl DegreeStats {
    pub fn of(csr: &Csr) -> Self {
        let mut degs: Vec<usize> = (0..csr.n_rows).map(|i| csr.row_nnz(i)).collect();
        degs.sort_unstable();
        let n = degs.len().max(1);
        let pick = |q: f64| degs[((q * (n - 1) as f64).round() as usize).min(n - 1)];
        let frac_within = PROBE_WIDTHS
            .iter()
            .map(|&w| {
                let cnt = degs.partition_point(|&d| d <= w);
                (w, cnt as f64 / n as f64)
            })
            .collect();
        DegreeStats {
            min: *degs.first().unwrap_or(&0),
            max: *degs.last().unwrap_or(&0),
            mean: degs.iter().sum::<usize>() as f64 / n as f64,
            median: pick(0.5),
            p90: pick(0.9),
            p99: pick(0.99),
            frac_within,
        }
    }
}

/// Row-degree prefix sums: `prefix[i]` is the total nnz of rows `< i`
/// (length `n_rows + 1`). The balanced-cut substrate shared by the
/// threaded SpMM chunkers and the shard partitioner: a k-quantile cut
/// over this prefix yields row ranges with roughly equal edge mass.
pub fn degree_prefix(csr: &Csr) -> Vec<usize> {
    let mut prefix = Vec::with_capacity(csr.n_rows + 1);
    prefix.push(0usize);
    for i in 0..csr.n_rows {
        let p = prefix[i] + csr.row_nnz(i);
        prefix.push(p);
    }
    prefix
}

/// Cut `0..n` (where `n = prefix.len() - 1`) into at most `parts`
/// contiguous, **non-empty** ranges with roughly equal mass, where
/// `prefix` is a mass prefix sum (e.g. [`degree_prefix`]). The shared
/// balanced-cut substrate behind both the threaded SpMM chunkers and
/// the shard partitioner: cut points are mass quantiles
/// (`partition_point` over the prefix), zero total mass falls back to
/// even row counts, and `parts` is clamped to `[1, n]` so no range is
/// ever empty (an item is never split across ranges). `n == 0` yields
/// one empty range.
pub fn balanced_cuts(prefix: &[usize], parts: usize) -> Vec<std::ops::Range<usize>> {
    let n = prefix.len().saturating_sub(1);
    if n == 0 {
        return vec![0..0];
    }
    let total = prefix[n];
    let parts = parts.clamp(1, n);
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for k in 1..=parts {
        let end = if k == parts {
            n
        } else if total == 0 {
            // No mass to balance — cut by item count.
            n * k / parts
        } else {
            // First index whose prefix mass reaches the k-th quantile.
            let target = (total * k).div_ceil(parts);
            prefix.partition_point(|&p| p < target)
        };
        // Keep every range non-empty and leave ≥1 item per remaining
        // range.
        let end = end.max(start + 1).min(n - (parts - k));
        out.push(start..end);
        start = end;
    }
    out
}

/// Empirical CDF of row degrees evaluated at each degree in `points`.
pub fn degree_cdf(csr: &Csr, points: &[usize]) -> Vec<f64> {
    let mut degs: Vec<usize> = (0..csr.n_rows).map(|i| csr.row_nnz(i)).collect();
    degs.sort_unstable();
    let n = degs.len().max(1) as f64;
    points
        .iter()
        .map(|&p| degs.partition_point(|&d| d <= p) as f64 / n)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line_graph(n: usize) -> Csr {
        // Row i has exactly i nonzeros (col 0 repeated) for easy checking.
        let mut row_ptr = vec![0i32];
        let mut col = Vec::new();
        for i in 0..n {
            for _ in 0..i {
                col.push(0);
            }
            row_ptr.push(col.len() as i32);
        }
        let val = vec![1.0; col.len()];
        Csr::new(n, n, row_ptr, col, val).unwrap()
    }

    #[test]
    fn stats_on_known_degrees() {
        let g = line_graph(101); // degrees 0..=100
        let s = DegreeStats::of(&g);
        assert_eq!(s.min, 0);
        assert_eq!(s.max, 100);
        assert_eq!(s.median, 50);
        assert!((s.mean - 50.0).abs() < 1e-9);
        assert_eq!(s.p90, 90);
        // 17 of 101 rows have degree <= 16
        let w16 = s.frac_within.iter().find(|&&(w, _)| w == 16).unwrap().1;
        assert!((w16 - 17.0 / 101.0).abs() < 1e-12);
    }

    #[test]
    fn balanced_cuts_cover_disjointly() {
        // Thorough degenerate-input coverage lives with the two callers
        // (spmm::threaded chunk tests, graph::shard partition tests);
        // this pins the direct contract.
        let prefix = [0usize, 5, 5, 105, 108, 111, 114, 164, 165];
        for parts in 1..=8 {
            let cuts = balanced_cuts(&prefix, parts);
            assert!(cuts.len() <= parts);
            let mut next = 0;
            for c in &cuts {
                assert_eq!(c.start, next);
                assert!(!c.is_empty());
                next = c.end;
            }
            assert_eq!(next, 8);
        }
        assert_eq!(balanced_cuts(&[0], 4), vec![0..0]);
        assert_eq!(balanced_cuts(&[], 4), vec![0..0]);
    }

    #[test]
    fn prefix_matches_row_nnz() {
        let g = line_graph(20); // degrees 0..=19
        let p = degree_prefix(&g);
        assert_eq!(p.len(), 21);
        assert_eq!(p[0], 0);
        for i in 0..20 {
            assert_eq!(p[i + 1] - p[i], g.row_nnz(i));
        }
        assert_eq!(*p.last().unwrap(), g.nnz());
    }

    #[test]
    fn cdf_monotone_and_bounded() {
        let g = line_graph(50);
        let pts: Vec<usize> = (0..60).collect();
        let cdf = degree_cdf(&g, &pts);
        for w in cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(cdf.iter().all(|&c| (0.0..=1.0).contains(&c)));
        assert_eq!(*cdf.last().unwrap(), 1.0);
    }
}
