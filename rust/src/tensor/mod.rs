//! Host tensors and the `.nbt` interchange container.
//!
//! `.nbt` (named binary tensors) is the build-time ↔ run-time interchange
//! format shared with `python/compile/nbt.py`; see that file for the exact
//! byte layout. Round-trip compatibility is covered by golden-file tests.

mod nbt;

pub(crate) use nbt::parse_nbt_index;
pub use nbt::{read_nbt, read_nbt_tensor, write_nbt, NbtFile, TensorEntry};

use anyhow::{bail, Result};

/// Element types supported by the container (codes shared with python).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32 = 0,
    I32 = 1,
    U8 = 2,
    I64 = 3,
    F64 = 4,
    I8 = 5,
}

impl DType {
    pub fn from_code(code: u32) -> Result<Self> {
        Ok(match code {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::U8,
            3 => DType::I64,
            4 => DType::F64,
            5 => DType::I8,
            _ => bail!("unknown dtype code {code}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::U8 | DType::I8 => 1,
            DType::F32 | DType::I32 => 4,
            DType::I64 | DType::F64 => 8,
        }
    }

    /// The matching PJRT element type.
    pub fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I32 => xla::ElementType::S32,
            DType::U8 => xla::ElementType::U8,
            DType::I64 => xla::ElementType::S64,
            DType::F64 => xla::ElementType::F64,
            DType::I8 => xla::ElementType::S8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I32 => "int32",
            DType::U8 => "uint8",
            DType::I64 => "int64",
            DType::F64 => "float64",
            DType::I8 => "int8",
        }
    }
}

/// Parse the numpy-style dtype names the python manifest uses.
pub fn dtype_from_name(name: &str) -> Result<DType> {
    Ok(match name {
        "float32" => DType::F32,
        "int32" => DType::I32,
        "uint8" => DType::U8,
        "int64" => DType::I64,
        "float64" => DType::F64,
        "int8" => DType::I8,
        _ => bail!("unknown dtype name {name:?}"),
    })
}

/// A host tensor: dtype + shape + raw little-endian payload.
///
/// Deliberately untyped at rest (artifact inputs are heterogeneous); typed
/// views are borrowed via [`Tensor::as_f32`] etc., which validate dtype.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub data: Vec<u8>,
}

macro_rules! typed_view {
    ($as_fn:ident, $from_fn:ident, $ty:ty, $dt:expr) => {
        pub fn $as_fn(&self) -> Result<&[$ty]> {
            if self.dtype != $dt {
                bail!("dtype mismatch: have {:?}, want {:?}", self.dtype, $dt);
            }
            // Payloads come from Vec<u8> reads; alignment of 1-byte-backed
            // buffers is not guaranteed, so go through bytemuck-style
            // manual checks.
            let ptr = self.data.as_ptr();
            if (ptr as usize) % std::mem::align_of::<$ty>() != 0 {
                bail!("unaligned tensor payload");
            }
            Ok(unsafe {
                std::slice::from_raw_parts(
                    ptr as *const $ty,
                    self.data.len() / std::mem::size_of::<$ty>(),
                )
            })
        }

        pub fn $from_fn(shape: &[usize], values: &[$ty]) -> Tensor {
            assert_eq!(
                shape.iter().product::<usize>(),
                values.len(),
                "shape/value count mismatch"
            );
            let mut data = Vec::with_capacity(values.len() * std::mem::size_of::<$ty>());
            for v in values {
                data.extend_from_slice(&v.to_le_bytes());
            }
            Tensor { dtype: $dt, shape: shape.to_vec(), data }
        }
    };
}

impl Tensor {
    typed_view!(as_f32, from_f32, f32, DType::F32);
    typed_view!(as_i32, from_i32, i32, DType::I32);
    typed_view!(as_i64, from_i64, i64, DType::I64);
    typed_view!(as_f64, from_f64, f64, DType::F64);

    pub fn from_u8(shape: &[usize], values: &[u8]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        Tensor { dtype: DType::U8, shape: shape.to_vec(), data: values.to_vec() }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        if self.dtype != DType::U8 {
            bail!("dtype mismatch: have {:?}, want U8", self.dtype);
        }
        Ok(&self.data)
    }

    /// Scalar convenience: one-element f32 tensor.
    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(&[1], &[v])
    }

    /// Scalar convenience: one-element i32 tensor (strategy selector).
    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::from_i32(&[1], &[v])
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.data.len()
    }

    /// Build the PJRT literal for this tensor (host → device staging).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            self.dtype.element_type(),
            &self.shape,
            &self.data,
        )?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_views_roundtrip() {
        let t = Tensor::from_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(t.elem_count(), 6);
        assert_eq!(t.byte_len(), 24);
        assert!(t.as_i32().is_err(), "wrong-dtype view must fail");
    }

    #[test]
    fn dtype_codes_match_python() {
        for (code, dt) in [
            (0, DType::F32),
            (1, DType::I32),
            (2, DType::U8),
            (3, DType::I64),
            (4, DType::F64),
            (5, DType::I8),
        ] {
            assert_eq!(DType::from_code(code).unwrap(), dt);
            assert_eq!(dt as u32, code);
        }
        assert!(DType::from_code(99).is_err());
    }

    #[test]
    fn scalar_helpers() {
        assert_eq!(Tensor::scalar_i32(2).as_i32().unwrap(), &[2]);
        assert_eq!(Tensor::scalar_f32(0.5).as_f32().unwrap(), &[0.5]);
    }
}
