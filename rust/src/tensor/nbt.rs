//! `.nbt` container codec — rust mirror of `python/compile/nbt.py`.
//!
//! Layout (little endian):
//! ```text
//! magic  b"NBTC"
//! u32    tensor count
//! per tensor:
//!   u16  name length, then utf-8 name bytes
//!   u32  dtype code, u32 ndim, ndim * u64 dims
//!   u64  payload byte length, then raw row-major LE payload
//! ```

use std::fs;
use std::io::{Read as _, Seek as _, SeekFrom, Write as _};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{DType, Tensor};

const MAGIC: &[u8; 4] = b"NBTC";

/// An ordered set of named tensors (order preserved from the writer).
#[derive(Default, Debug)]
pub struct NbtFile {
    names: Vec<String>,
    tensors: Vec<Tensor>,
}

impl NbtFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: impl Into<String>, tensor: Tensor) {
        self.names.push(name.into());
        self.tensors.push(tensor);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| &self.tensors[i])
            .with_context(|| format!("tensor {name:?} not in container (have {:?})", self.names))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.names.iter().any(|n| n == name)
    }

    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.names.iter().map(|s| s.as_str()).zip(self.tensors.iter())
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.off + n > self.buf.len() {
            bail!("truncated .nbt file at offset {}", self.off);
        }
        let s = &self.buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Read an `.nbt` container from disk.
pub fn read_nbt(path: impl AsRef<Path>) -> Result<NbtFile> {
    let path = path.as_ref();
    let buf = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    parse_nbt(&buf).with_context(|| format!("parsing {}", path.display()))
}

/// Location + metadata of one tensor inside a container buffer — the
/// zero-copy index [`crate::quant::MmapNbt`] serves payload slices from.
/// `offset`/`len` address the raw row-major LE payload inside the file.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorEntry {
    /// Tensor name as written by the producer.
    pub name: String,
    /// Element type of the payload.
    pub dtype: DType,
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// Payload byte offset from the start of the container.
    pub offset: usize,
    /// Payload byte length (validated against `shape` × dtype size).
    pub len: usize,
}

/// Walk a container buffer and return the tensor index without copying
/// any payload. Validates magic, shape/payload agreement, and bounds.
pub(crate) fn parse_nbt_index(buf: &[u8]) -> Result<Vec<TensorEntry>> {
    let mut c = Cursor { buf, off: 0 };
    if c.take(4)? != MAGIC {
        bail!("bad magic (not an NBTC container)");
    }
    let count = c.u32()?;
    let mut out = Vec::with_capacity(count as usize);
    for _ in 0..count {
        let nlen = c.u16()? as usize;
        let name = std::str::from_utf8(c.take(nlen)?)?.to_string();
        let dtype = DType::from_code(c.u32()?)?;
        let ndim = c.u32()? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u64()? as usize);
        }
        let plen = c.u64()? as usize;
        let expected = shape.iter().product::<usize>() * dtype.size();
        if plen != expected {
            bail!("tensor {name:?}: payload {plen} bytes, shape implies {expected}");
        }
        let offset = c.off;
        c.take(plen)?; // bounds-check the payload without copying it
        out.push(TensorEntry { name, dtype, shape, offset, len: plen });
    }
    Ok(out)
}

pub(crate) fn parse_nbt(buf: &[u8]) -> Result<NbtFile> {
    let mut out = NbtFile::new();
    for e in parse_nbt_index(buf)? {
        // Copy into a fresh Vec so the payload is max-aligned (a slice at
        // the file offset may be arbitrarily aligned otherwise).
        let mut data = vec![0u8; e.len];
        data.copy_from_slice(&buf[e.offset..e.offset + e.len]);
        out.insert(e.name, Tensor { dtype: e.dtype, shape: e.shape, data });
    }
    Ok(out)
}

/// Read a single named tensor from an `.nbt` container, seeking past all
/// other payloads — the hot feature-loading path reads only the bytes of
/// the tensor it needs (this is what makes the INT8 path actually move 4x
/// fewer bytes off storage, Table 3's premise).
pub fn read_nbt_tensor(path: impl AsRef<Path>, name: &str) -> Result<Tensor> {
    let path = path.as_ref();
    let mut f = fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut head = [0u8; 8];
    f.read_exact(&mut head)?;
    if &head[..4] != MAGIC {
        bail!("{}: bad magic", path.display());
    }
    let count = u32::from_le_bytes(head[4..8].try_into().unwrap());
    for _ in 0..count {
        let mut nlen_b = [0u8; 2];
        f.read_exact(&mut nlen_b)?;
        let nlen = u16::from_le_bytes(nlen_b) as usize;
        let mut name_b = vec![0u8; nlen];
        f.read_exact(&mut name_b)?;
        let mut meta = [0u8; 8];
        f.read_exact(&mut meta)?;
        let code = u32::from_le_bytes(meta[..4].try_into().unwrap());
        let ndim = u32::from_le_bytes(meta[4..8].try_into().unwrap()) as usize;
        let mut dims = vec![0u8; ndim * 8];
        f.read_exact(&mut dims)?;
        let mut plen_b = [0u8; 8];
        f.read_exact(&mut plen_b)?;
        let plen = u64::from_le_bytes(plen_b) as usize;
        if name_b == name.as_bytes() {
            let dtype = DType::from_code(code)?;
            let shape: Vec<usize> = dims
                .chunks_exact(8)
                .map(|c| u64::from_le_bytes(c.try_into().unwrap()) as usize)
                .collect();
            if plen != shape.iter().product::<usize>() * dtype.size() {
                bail!("tensor {name:?}: payload/shape mismatch");
            }
            let mut data = vec![0u8; plen];
            f.read_exact(&mut data)?;
            return Ok(Tensor { dtype, shape, data });
        }
        f.seek(SeekFrom::Current(plen as i64))?;
    }
    bail!("tensor {name:?} not found in {}", path.display())
}

/// Write an `.nbt` container to disk (atomic: temp file + rename).
pub fn write_nbt(path: impl AsRef<Path>, file: &NbtFile) -> Result<()> {
    let path = path.as_ref();
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    buf.extend_from_slice(&(file.len() as u32).to_le_bytes());
    for (name, t) in file.iter() {
        let nb = name.as_bytes();
        buf.extend_from_slice(&(nb.len() as u16).to_le_bytes());
        buf.extend_from_slice(nb);
        buf.extend_from_slice(&(t.dtype as u32).to_le_bytes());
        buf.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for d in &t.shape {
            buf.extend_from_slice(&(*d as u64).to_le_bytes());
        }
        buf.extend_from_slice(&(t.data.len() as u64).to_le_bytes());
        buf.extend_from_slice(&t.data);
    }
    let tmp = path.with_extension("nbt.tmp");
    let mut f = fs::File::create(&tmp).with_context(|| format!("creating {}", tmp.display()))?;
    f.write_all(&buf)?;
    f.sync_all()?;
    drop(f);
    fs::rename(&tmp, path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut f = NbtFile::new();
        f.insert("a", Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]));
        f.insert("b", Tensor::from_i32(&[3], &[-1, 0, 7]));
        f.insert("q", Tensor::from_u8(&[4], &[0, 128, 200, 255]));
        let dir = std::env::temp_dir().join("nbt_test_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.nbt");
        write_nbt(&p, &f).unwrap();
        let g = read_nbt(&p).unwrap();
        assert_eq!(g.names(), f.names());
        assert_eq!(g.get("a").unwrap().as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(g.get("b").unwrap().as_i32().unwrap(), &[-1, 0, 7]);
        assert_eq!(g.get("q").unwrap().as_u8().unwrap(), &[0, 128, 200, 255]);
        assert!(g.get("missing").is_err());
    }

    #[test]
    fn index_addresses_the_same_payloads_the_parser_copies() {
        let mut f = NbtFile::new();
        f.insert("a", Tensor::from_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]));
        f.insert("q", Tensor::from_u8(&[3], &[7, 8, 9]));
        let dir = std::env::temp_dir().join("nbt_test_idx");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.nbt");
        write_nbt(&p, &f).unwrap();
        let buf = std::fs::read(&p).unwrap();
        let idx = parse_nbt_index(&buf).unwrap();
        assert_eq!(idx.len(), 2);
        for (entry, (name, tensor)) in idx.iter().zip(f.iter()) {
            assert_eq!(entry.name, name);
            assert_eq!(entry.dtype, tensor.dtype);
            assert_eq!(entry.shape, tensor.shape);
            assert_eq!(&buf[entry.offset..entry.offset + entry.len], &tensor.data[..]);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse_nbt(b"NOPE").is_err());
        assert!(parse_nbt(b"NBTC").is_err()); // truncated count
        // count says 1 but no tensor follows
        let mut buf = b"NBTC".to_vec();
        buf.extend_from_slice(&1u32.to_le_bytes());
        assert!(parse_nbt(&buf).is_err());
    }

    #[test]
    fn payload_length_validated() {
        // Hand-build a tensor whose payload length disagrees with shape.
        let mut buf = b"NBTC".to_vec();
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&1u16.to_le_bytes());
        buf.push(b'x');
        buf.extend_from_slice(&0u32.to_le_bytes()); // f32
        buf.extend_from_slice(&1u32.to_le_bytes()); // ndim 1
        buf.extend_from_slice(&4u64.to_le_bytes()); // 4 elements => 16 bytes
        buf.extend_from_slice(&8u64.to_le_bytes()); // but claim 8
        buf.extend_from_slice(&[0u8; 8]);
        assert!(parse_nbt(&buf).is_err());
    }
}
