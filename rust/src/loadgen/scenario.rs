//! Load-generation scenarios: what traffic to offer a wire server.
//!
//! A scenario is a small JSON document (`repro loadgen --scenario
//! FILE`); every field is optional and defaults to the built-in
//! closed-loop scenario. docs/serving.md carries the schema.

use std::time::Duration;

use anyhow::{Context, Result};

use crate::coordinator::wire::route_from_json;
use crate::coordinator::RouteKey;
use crate::util::{parse_json, JsonValue};

/// How workers offer load.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Arrival {
    /// Each connection keeps exactly one request in flight: the next
    /// send waits for the previous reply. Measures capacity.
    Closed,
    /// Requests are scheduled at `rate_rps` (split across connections,
    /// exponential inter-arrivals) regardless of completions; latency
    /// is measured from the *scheduled* send time, so queueing delay
    /// under overload is visible (no coordinated omission).
    Open {
        /// Aggregate offered request rate across all connections.
        rate_rps: f64,
    },
}

/// One load-generation run's shape.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Scenario name, stamped into BENCH_serving.json.
    pub name: String,
    /// Concurrent client connections (closed-loop: also the number of
    /// requests in flight).
    pub connections: usize,
    /// Traffic offered before measurement starts (cache/plan warm-up).
    pub warmup: Duration,
    /// The measured window.
    pub duration: Duration,
    pub arrival: Arrival,
    /// Power-law (Zipf) exponent over route popularity ranks: route i
    /// (0-based) gets weight 1/(i+1)^alpha. 0 = uniform.
    pub alpha: f64,
    /// Nodes classified per request.
    pub nodes_per_request: usize,
    /// Base RNG seed; worker i derives its own stream from it.
    pub seed: u64,
    /// Models the derived default grid fans over when `routes` is
    /// empty. Defaults to `["gcn"]` so old scenario files keep their
    /// exact traffic mix; add `"sage"`/`"gat"` to offer zoo traffic.
    /// Every listed model must be in the server's `status` roster.
    pub models: Vec<String>,
    /// Explicit routes. Empty = derive the default grid from the
    /// server's `status` response (`models` above × widths {exact, 8} ×
    /// strategies {aes, sfs} × precisions {u8-device, f32}).
    pub routes: Vec<RouteKey>,
    /// Optional concurrent mutate stream: period between deltas.
    pub mutate_period: Option<Duration>,
    /// Dataset the mutate stream targets (default: the server's first).
    pub mutate_dataset: Option<String>,
}

impl Default for Scenario {
    fn default() -> Self {
        Scenario {
            name: "default".into(),
            connections: 8,
            warmup: Duration::from_millis(1000),
            duration: Duration::from_millis(4000),
            arrival: Arrival::Closed,
            alpha: 1.1,
            nodes_per_request: 8,
            seed: 0x5EED_CAFE,
            models: vec!["gcn".into()],
            routes: Vec::new(),
            mutate_period: None,
            mutate_dataset: None,
        }
    }
}

impl Scenario {
    /// Shrink to the CI-friendly quick shape (~1.5s of traffic).
    pub fn quick(&mut self) {
        self.connections = self.connections.min(4);
        self.warmup = Duration::from_millis(300);
        self.duration = Duration::from_millis(1200);
    }

    /// Parse a scenario document; absent fields keep their defaults.
    pub fn from_json(text: &str) -> Result<Scenario> {
        let doc = parse_json(text).context("scenario file is not JSON")?;
        let mut s = Scenario::default();
        if let Ok(v) = doc.get("name") {
            s.name = v.as_str()?.to_string();
        }
        if let Ok(v) = doc.get("connections") {
            s.connections = v.as_usize().context("connections must be an integer")?;
        }
        if let Ok(v) = doc.get("warmup_ms") {
            s.warmup = Duration::from_millis(v.as_f64()? as u64);
        }
        if let Ok(v) = doc.get("duration_ms") {
            s.duration = Duration::from_millis(v.as_f64()? as u64);
        }
        if let Ok(v) = doc.get("arrival") {
            s.arrival = match v.as_str()? {
                "closed" => Arrival::Closed,
                "open" => Arrival::Open {
                    rate_rps: doc
                        .get("rate_rps")
                        .context("open arrival needs rate_rps")?
                        .as_f64()?,
                },
                other => anyhow::bail!("arrival must be closed|open, got {other:?}"),
            };
        }
        if let Ok(v) = doc.get("alpha") {
            s.alpha = v.as_f64()?;
        }
        if let Ok(v) = doc.get("nodes_per_request") {
            s.nodes_per_request =
                v.as_usize().context("nodes_per_request must be an integer")?.max(1);
        }
        if let Ok(v) = doc.get("seed") {
            s.seed = v.as_f64()? as u64;
        }
        if let Ok(v) = doc.get("models") {
            s.models = v
                .as_arr()?
                .iter()
                .map(|m| Ok(m.as_str().context("models: entries must be strings")?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            if s.models.is_empty() {
                anyhow::bail!("models must name at least one model");
            }
        }
        if let Ok(v) = doc.get("routes") {
            s.routes = v
                .as_arr()?
                .iter()
                .map(route_from_json)
                .collect::<Result<Vec<_>>>()
                .context("routes: each entry needs model/dataset/width/strategy/precision")?;
        }
        if let Ok(v) = doc.get("mutate_period_ms") {
            s.mutate_period = Some(Duration::from_millis(v.as_f64()? as u64));
        }
        if let Ok(v) = doc.get("mutate_dataset") {
            s.mutate_dataset = Some(v.as_str()?.to_string());
        }
        if s.connections == 0 {
            anyhow::bail!("connections must be at least 1");
        }
        Ok(s)
    }
}

/// Power-law route popularity: rank i gets weight 1/(i+1)^alpha,
/// sampled by inverse-CDF lookup on a uniform draw.
#[derive(Clone, Debug)]
pub struct Popularity {
    cdf: Vec<f64>,
}

impl Popularity {
    pub fn new(k: usize, alpha: f64) -> Popularity {
        assert!(k > 0, "popularity over zero routes");
        let weights: Vec<f64> = (0..k).map(|i| 1.0 / ((i + 1) as f64).powf(alpha)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Popularity { cdf }
    }

    /// Map a uniform draw in [0, 1) to a route rank.
    pub fn sample(&self, u: f64) -> usize {
        self.cdf.partition_point(|&c| c <= u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_quick() {
        let mut s = Scenario::default();
        assert_eq!(s.arrival, Arrival::Closed);
        assert!(s.routes.is_empty());
        s.quick();
        assert!(s.duration <= Duration::from_millis(1200));
        assert!(s.connections <= 4);
    }

    #[test]
    fn parses_a_full_scenario() {
        let s = Scenario::from_json(
            r#"{"name":"spike","connections":16,"warmup_ms":100,"duration_ms":500,
                "arrival":"open","rate_rps":200.5,"alpha":0.0,"nodes_per_request":4,
                "seed":42,"mutate_period_ms":50,"mutate_dataset":"evalpow",
                "models":["gcn","gat"],
                "routes":[{"model":"gcn","dataset":"evalpow","width":8,
                           "strategy":"aes","precision":"f32"}]}"#,
        )
        .unwrap();
        assert_eq!(s.name, "spike");
        assert_eq!(s.connections, 16);
        assert_eq!(s.arrival, Arrival::Open { rate_rps: 200.5 });
        assert_eq!(s.models, vec!["gcn".to_string(), "gat".to_string()]);
        assert_eq!(s.routes.len(), 1);
        assert_eq!(s.routes[0].label(), "gcn/evalpow/w8/aes/f32");
        assert_eq!(s.mutate_period, Some(Duration::from_millis(50)));
        assert_eq!(s.mutate_dataset.as_deref(), Some("evalpow"));
    }

    #[test]
    fn rejects_bad_scenarios() {
        assert!(Scenario::from_json("not json").is_err());
        assert!(Scenario::from_json(r#"{"arrival":"open"}"#).is_err());
        assert!(Scenario::from_json(r#"{"connections":0}"#).is_err());
        assert!(Scenario::from_json(r#"{"arrival":"sideways"}"#).is_err());
        assert!(Scenario::from_json(r#"{"models":[]}"#).is_err());
    }

    #[test]
    fn popularity_is_a_cdf_and_skews_hot() {
        let p = Popularity::new(8, 1.1);
        assert!((p.cdf.last().unwrap() - 1.0).abs() < 1e-9);
        for w in p.cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        // Rank 0 takes the largest share; u=0 maps to it.
        assert_eq!(p.sample(0.0), 0);
        assert!(p.cdf[0] > 1.0 / 8.0);
        // The top of the range maps to the last rank, never out of bounds.
        assert_eq!(p.sample(0.999_999_999), 7);
        // Uniform when alpha = 0.
        let u = Popularity::new(4, 0.0);
        assert!((u.cdf[0] - 0.25).abs() < 1e-9);
    }
}
