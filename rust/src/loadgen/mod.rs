//! Closed/open-loop load generation against a live wire server
//! (`repro loadgen --addr HOST:PORT`).
//!
//! Worker threads (one TCP connection each) offer `infer` traffic with
//! power-law route popularity, optionally alongside a concurrent
//! `mutate` stream, through a warmup-then-measure window. Quantiles
//! are computed client-side from the exact per-request samples (not
//! the server's bucketed histograms), so BENCH_serving.json gates on
//! what a client actually observed; shed responses are counted
//! separately and never pollute the latency distribution.
//!
//! The report lands in the same schema family `tools/bench_diff.rs`
//! diffs: per-workload `cases` carrying `median_ns` (latency, lower is
//! better) or `value` + `"direction": "higher"` (throughput), so the
//! CI serving job can gate regressions in either direction
//! (docs/serving.md).

mod scenario;

pub use scenario::{Arrival, Popularity, Scenario};

use std::collections::BTreeMap;
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::wire::{self, WireRequest};
use crate::coordinator::RouteKey;
use crate::quant::Precision;
use crate::rng::Pcg32;
use crate::sampling::Strategy;
use crate::util::{percentile, JsonValue};

/// One request's outcome, as the client saw it.
#[derive(Clone, Copy, Debug)]
struct Sample {
    route: usize,
    /// 0 = ok, 1 = shed, 2 = error.
    status: u8,
    latency: Duration,
    /// Whether the request was *scheduled* inside the measure window.
    measured: bool,
}

/// Per-route (or aggregate) results over the measured window.
#[derive(Clone, Debug)]
pub struct RouteReport {
    pub name: String,
    pub completed: u64,
    pub shed: u64,
    pub errors: u64,
    pub throughput_rps: f64,
    pub p50: Duration,
    pub p99: Duration,
    pub p999: Duration,
    pub mean: Duration,
}

/// The whole run's results, ready for printing and BENCH_serving.json.
#[derive(Clone, Debug)]
pub struct LoadReport {
    pub scenario: String,
    pub connections: usize,
    pub warmup: Duration,
    pub duration: Duration,
    pub arrival: String,
    pub alpha: f64,
    pub mutations: u64,
    pub aggregate: RouteReport,
    pub routes: Vec<RouteReport>,
}

fn digest(name: &str, samples: &[(Duration, u8)], window: Duration) -> RouteReport {
    let ok: Vec<Duration> =
        samples.iter().filter(|(_, s)| *s == 0).map(|(d, _)| *d).collect();
    let completed = ok.len() as u64;
    let mean = if ok.is_empty() {
        Duration::ZERO
    } else {
        ok.iter().sum::<Duration>() / ok.len() as u32
    };
    RouteReport {
        name: name.to_string(),
        completed,
        shed: samples.iter().filter(|(_, s)| *s == 1).count() as u64,
        errors: samples.iter().filter(|(_, s)| *s == 2).count() as u64,
        throughput_rps: completed as f64 / window.as_secs_f64().max(1e-9),
        p50: percentile(&ok, 50.0),
        p99: percentile(&ok, 99.0),
        p999: percentile(&ok, 99.9),
        mean,
    }
}

impl LoadReport {
    /// The BENCH_serving.json document (schema: docs/serving.md).
    pub fn to_json(&self) -> JsonValue {
        self.to_json_prefixed(None)
    }

    /// [`LoadReport::to_json`] with every workload name prefixed
    /// (`"sharded-router aggregate"`, …) so two topologies' workloads
    /// can coexist in one trajectory file without name collisions —
    /// bench_diff matches workloads by name.
    pub fn to_json_prefixed(&self, prefix: Option<&str>) -> JsonValue {
        fn case_ns(name: &str, d: Duration) -> JsonValue {
            JsonValue::Obj(
                [
                    ("name".to_string(), JsonValue::Str(name.to_string())),
                    ("median_ns".to_string(), JsonValue::Num(d.as_nanos() as f64)),
                ]
                .into_iter()
                .collect(),
            )
        }
        fn workload(r: &RouteReport, prefix: Option<&str>, with_throughput_case: bool) -> JsonValue {
            let name = match prefix {
                Some(p) => format!("{p} {}", r.name),
                None => r.name.clone(),
            };
            let mut cases = vec![
                case_ns("latency p50", r.p50),
                case_ns("latency p99", r.p99),
                case_ns("latency p999", r.p999),
            ];
            if with_throughput_case {
                cases.push(JsonValue::Obj(
                    [
                        ("name".to_string(), JsonValue::Str("throughput".to_string())),
                        ("value".to_string(), JsonValue::Num(r.throughput_rps)),
                        ("direction".to_string(), JsonValue::Str("higher".to_string())),
                        ("unit".to_string(), JsonValue::Str("req/s".to_string())),
                    ]
                    .into_iter()
                    .collect(),
                ));
            }
            let map: BTreeMap<String, JsonValue> = [
                ("name".to_string(), JsonValue::Str(name)),
                ("completed".to_string(), JsonValue::Num(r.completed as f64)),
                ("shed".to_string(), JsonValue::Num(r.shed as f64)),
                ("errors".to_string(), JsonValue::Num(r.errors as f64)),
                ("throughput_rps".to_string(), JsonValue::Num(r.throughput_rps)),
                ("cases".to_string(), JsonValue::Arr(cases)),
            ]
            .into_iter()
            .collect();
            JsonValue::Obj(map)
        }
        let mut workloads = vec![workload(&self.aggregate, prefix, true)];
        workloads.extend(self.routes.iter().map(|r| workload(r, prefix, false)));
        JsonValue::Obj(
            [
                ("bench".to_string(), JsonValue::Str("serving".to_string())),
                ("schema_version".to_string(), JsonValue::Num(1.0)),
                ("scenario".to_string(), JsonValue::Str(self.scenario.clone())),
                ("connections".to_string(), JsonValue::Num(self.connections as f64)),
                ("warmup_s".to_string(), JsonValue::Num(self.warmup.as_secs_f64())),
                ("duration_s".to_string(), JsonValue::Num(self.duration.as_secs_f64())),
                ("arrival".to_string(), JsonValue::Str(self.arrival.clone())),
                ("alpha".to_string(), JsonValue::Num(self.alpha)),
                ("mutations".to_string(), JsonValue::Num(self.mutations as f64)),
                ("workloads".to_string(), JsonValue::Arr(workloads)),
            ]
            .into_iter()
            .collect(),
        )
    }

    /// Human summary.
    pub fn print(&self) {
        use crate::util::fmt_duration as fd;
        println!(
            "serving load: scenario {} | {} arrival | {} conns | {:.1}s measured \
             ({:.1}s warmup)",
            self.scenario,
            self.arrival,
            self.connections,
            self.duration.as_secs_f64(),
            self.warmup.as_secs_f64(),
        );
        let a = &self.aggregate;
        println!(
            "aggregate: {} ok ({:.1} req/s) | {} shed | {} errors | p50 {} p99 {} p999 {}",
            a.completed,
            a.throughput_rps,
            a.shed,
            a.errors,
            fd(a.p50),
            fd(a.p99),
            fd(a.p999),
        );
        for r in &self.routes {
            println!(
                "  {}: {} ok ({:.1} req/s) | {} shed | p50 {} p99 {} p999 {}",
                r.name,
                r.completed,
                r.throughput_rps,
                r.shed,
                fd(r.p50),
                fd(r.p99),
                fd(r.p999),
            );
        }
        if self.mutations > 0 {
            println!("mutations applied: {}", self.mutations);
        }
    }
}

/// Merge a fresh BENCH_serving.json document into an existing one:
/// workloads sharing a name are replaced by the fresh run, new names
/// are appended, and everything else in `existing` survives. This is
/// how one trajectory file carries both the single-server and the
/// sharded-router loadgen passes — bench_diff matches workloads by
/// name, so each topology gates independently.
pub fn merge_bench_json(existing: &str, fresh: &JsonValue) -> Result<JsonValue> {
    let base = crate::util::parse_json(existing).context("parsing existing bench JSON")?;
    let JsonValue::Obj(mut base_map) = base else {
        bail!("existing bench JSON is not an object");
    };
    let bench = base_map.get("bench").and_then(|b| b.as_str().ok()).unwrap_or("");
    if bench != "serving" {
        bail!("existing bench JSON is a {bench:?} bench, not serving");
    }
    let mut merged = match base_map.remove("workloads") {
        Some(JsonValue::Arr(w)) => w,
        _ => Vec::new(),
    };
    let fresh_workloads = fresh
        .get("workloads")
        .context("fresh bench JSON: missing workloads")?
        .as_arr()?
        .to_vec();
    for w in fresh_workloads {
        let name = w.get("name").ok().and_then(|n| n.as_str().ok()).unwrap_or("").to_string();
        if let Some(slot) = merged.iter_mut().find(|m| {
            m.get("name").ok().and_then(|n| n.as_str().ok()).unwrap_or("") == name
        }) {
            *slot = w;
        } else {
            merged.push(w);
        }
    }
    base_map.insert("workloads".to_string(), JsonValue::Arr(merged));
    Ok(JsonValue::Obj(base_map))
}

/// Ask the server which datasets (name → node count) and models it
/// serves. A status response without a `models` field (pre-zoo server)
/// is read as serving GCN only.
fn fetch_status(stream: &mut TcpStream) -> Result<(Vec<(String, usize)>, Vec<String>)> {
    let resp = wire::roundtrip(stream, &WireRequest::Status { id: 0 })?;
    if wire::response_status(&resp) != "ok" {
        bail!("status request failed: {}", resp.to_string());
    }
    let mut out = Vec::new();
    for ds in resp.get("datasets")?.as_arr()? {
        out.push((ds.get("name")?.as_str()?.to_string(), ds.get("nodes")?.as_usize()?));
    }
    if out.is_empty() {
        bail!("server reports no datasets");
    }
    let models = match resp.get("models") {
        Ok(v) => v
            .as_arr()?
            .iter()
            .map(|m| Ok(m.as_str()?.to_string()))
            .collect::<Result<Vec<_>>>()?,
        Err(_) => vec!["gcn".to_string()],
    };
    Ok((out, models))
}

/// The default route grid over the server's datasets: the scenario's
/// models × {exact + w8} × strategies aes/sfs (sampled routes only —
/// strategy is moot for exact) × precisions u8-device/f32.
fn default_routes(datasets: &[(String, usize)], models: &[String]) -> Vec<RouteKey> {
    let mut routes = Vec::new();
    for model in models {
        for (ds, _) in datasets {
            for precision in [Precision::U8Device, Precision::F32] {
                routes.push(RouteKey {
                    model: model.clone(),
                    dataset: ds.clone(),
                    width: None,
                    strategy: Strategy::Aes,
                    precision,
                });
                for strategy in [Strategy::Aes, Strategy::Sfs] {
                    routes.push(RouteKey {
                        model: model.clone(),
                        dataset: ds.clone(),
                        width: Some(8),
                        strategy,
                        precision,
                    });
                }
            }
        }
    }
    routes
}

/// Sleep until `deadline` in small chunks, bailing early on `stop`.
fn sleep_until(deadline: Instant, stop: &AtomicBool) {
    loop {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let now = Instant::now();
        if now >= deadline {
            return;
        }
        std::thread::sleep((deadline - now).min(Duration::from_millis(20)));
    }
}

struct WorkerArgs {
    addr: String,
    routes: Vec<RouteKey>,
    node_counts: Vec<usize>,
    popularity: Popularity,
    arrival: Arrival,
    connections: usize,
    nodes_per_request: usize,
    seed: u64,
    t0: Instant,
    window_start: Duration,
    window_end: Duration,
}

fn worker(args: Arc<WorkerArgs>, index: usize, stop: Arc<AtomicBool>) -> Vec<Sample> {
    let mut samples = Vec::new();
    let Ok(mut stream) = TcpStream::connect(args.addr.as_str()) else {
        return samples;
    };
    let _ = stream.set_nodelay(true);
    let mut rng = Pcg32::new(args.seed.wrapping_add(0x9E37_79B9 * (index as u64 + 1)));
    let per_conn_rate = match args.arrival {
        Arrival::Open { rate_rps } => rate_rps / args.connections as f64,
        Arrival::Closed => 0.0,
    };
    let mut next = args.t0;
    let mut id = 0u64;
    while !stop.load(Ordering::Acquire) {
        // Open arrival: stick to the schedule; latency includes any
        // send delay when the server falls behind.
        let scheduled = match args.arrival {
            Arrival::Open { .. } => {
                sleep_until(next, &stop);
                if stop.load(Ordering::Acquire) {
                    break;
                }
                let sched = next;
                let draw = (1.0 - rng.f64()).max(1e-12);
                next += Duration::from_secs_f64(-draw.ln() / per_conn_rate.max(1e-9));
                sched
            }
            Arrival::Closed => Instant::now(),
        };
        let route_idx = args.popularity.sample(rng.f64());
        let n = args.node_counts[route_idx];
        let nodes =
            (0..args.nodes_per_request).map(|_| rng.usize_below(n)).collect::<Vec<_>>();
        id += 1;
        let req =
            WireRequest::Infer { id, route: args.routes[route_idx].clone(), nodes };
        let sent = scheduled.max(args.t0);
        let resp = match wire::roundtrip(&mut stream, &req) {
            Ok(r) => r,
            // Connection torn down (server shutdown/reset): stop this
            // worker; nothing to record for the aborted request.
            Err(_) => break,
        };
        let latency = sent.elapsed();
        let offset = sent - args.t0;
        let measured = offset >= args.window_start && offset < args.window_end;
        let status = match wire::response_status(&resp) {
            "ok" => 0,
            "shed" => 1,
            _ => 2,
        };
        samples.push(Sample { route: route_idx, status, latency, measured });
        if status == 1 {
            // Back off briefly after a shed: hammering an overloaded
            // server just burns both sides' CPU.
            std::thread::sleep(Duration::from_micros(500));
        }
    }
    samples
}

/// Concurrent mutate stream: alternately insert and delete one edge of
/// the target dataset every `period`, counting applied deltas.
fn mutate_stream(
    addr: String,
    dataset: String,
    nodes: usize,
    period: Duration,
    stop: Arc<AtomicBool>,
    applied: Arc<AtomicU64>,
) {
    let Ok(mut stream) = TcpStream::connect(addr.as_str()) else {
        return;
    };
    let mut insert = true;
    let mut id = 0u64;
    while !stop.load(Ordering::Acquire) {
        sleep_until(Instant::now() + period, &stop);
        if stop.load(Ordering::Acquire) {
            return;
        }
        let op = if insert {
            format!("+ 0 {} 0.01", nodes - 1)
        } else {
            format!("- 0 {}", nodes - 1)
        };
        insert = !insert;
        id += 1;
        let req = WireRequest::Mutate { id, dataset: dataset.clone(), ops: vec![op] };
        match wire::roundtrip(&mut stream, &req) {
            Ok(resp) if wire::response_status(&resp) == "ok" => {
                applied.fetch_add(1, Ordering::Relaxed);
            }
            Ok(_) => {}
            Err(_) => return,
        }
    }
}

/// Run a scenario against a live server and aggregate the results.
pub fn run_loadgen(addr: &str, scenario: &Scenario) -> Result<LoadReport> {
    let mut control = TcpStream::connect(addr)
        .with_context(|| format!("connecting to {addr} (is `repro serve --listen` up?)"))?;
    let (datasets, served_models) = fetch_status(&mut control)?;
    drop(control);

    let routes = if scenario.routes.is_empty() {
        for m in &scenario.models {
            if !served_models.iter().any(|s| s == m) {
                bail!(
                    "scenario model {m:?} is not in the server's roster \
                     (serving: {served_models:?})"
                );
            }
        }
        default_routes(&datasets, &scenario.models)
    } else {
        scenario.routes.clone()
    };
    let node_counts = routes
        .iter()
        .map(|r| {
            datasets
                .iter()
                .find(|(name, _)| *name == r.dataset)
                .map(|(_, n)| *n)
                .with_context(|| {
                    format!("route {} targets a dataset the server does not serve", r.label())
                })
        })
        .collect::<Result<Vec<_>>>()?;

    let stop = Arc::new(AtomicBool::new(false));
    let t0 = Instant::now();
    let args = Arc::new(WorkerArgs {
        addr: addr.to_string(),
        routes: routes.clone(),
        node_counts,
        popularity: Popularity::new(routes.len(), scenario.alpha),
        arrival: scenario.arrival,
        connections: scenario.connections,
        nodes_per_request: scenario.nodes_per_request,
        seed: scenario.seed,
        t0,
        window_start: scenario.warmup,
        window_end: scenario.warmup + scenario.duration,
    });

    let workers: Vec<_> = (0..scenario.connections)
        .map(|i| {
            let args = args.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name(format!("loadgen-{i}"))
                .spawn(move || worker(args, i, stop))
                .context("spawning loadgen worker")
        })
        .collect::<Result<Vec<_>>>()?;

    let mutations = Arc::new(AtomicU64::new(0));
    let mutator = scenario
        .mutate_period
        .map(|period| -> Result<_> {
            let dataset = scenario
                .mutate_dataset
                .clone()
                .unwrap_or_else(|| datasets[0].0.clone());
            let nodes = datasets
                .iter()
                .find(|(name, _)| *name == dataset)
                .map(|(_, n)| *n)
                .with_context(|| format!("mutate dataset {dataset} not served"))?;
            let (addr, stop, applied) =
                (addr.to_string(), stop.clone(), mutations.clone());
            std::thread::Builder::new()
                .name("loadgen-mutate".into())
                .spawn(move || mutate_stream(addr, dataset, nodes, period, stop, applied))
                .context("spawning mutate stream")
        })
        .transpose()?;

    sleep_until(t0 + scenario.warmup + scenario.duration, &AtomicBool::new(false));
    stop.store(true, Ordering::Release);
    let mut samples: Vec<Sample> = Vec::new();
    for w in workers {
        samples.extend(w.join().unwrap_or_default());
    }
    if let Some(m) = mutator {
        let _ = m.join();
    }

    let measured: Vec<&Sample> = samples.iter().filter(|s| s.measured).collect();
    let all: Vec<(Duration, u8)> = measured.iter().map(|s| (s.latency, s.status)).collect();
    let aggregate = digest("aggregate", &all, scenario.duration);
    let route_reports = routes
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let own: Vec<(Duration, u8)> = measured
                .iter()
                .filter(|s| s.route == i)
                .map(|s| (s.latency, s.status))
                .collect();
            digest(&format!("route {}", r.label()), &own, scenario.duration)
        })
        .collect();

    if aggregate.completed == 0 && aggregate.shed == 0 {
        bail!(
            "no requests completed inside the measure window — the warmup ({:?}) \
             may be shorter than the first plan build",
            scenario.warmup
        );
    }

    Ok(LoadReport {
        scenario: scenario.name.clone(),
        connections: scenario.connections,
        warmup: scenario.warmup,
        duration: scenario.duration,
        arrival: match scenario.arrival {
            Arrival::Closed => "closed".to_string(),
            Arrival::Open { rate_rps } => format!("open@{rate_rps}rps"),
        },
        alpha: scenario.alpha,
        mutations: mutations.load(Ordering::Relaxed),
        aggregate,
        routes: route_reports,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> LoadReport {
        let mk = |name: &str, completed: u64| RouteReport {
            name: name.into(),
            completed,
            shed: 2,
            errors: 0,
            throughput_rps: completed as f64 / 2.0,
            p50: Duration::from_micros(900),
            p99: Duration::from_millis(4),
            p999: Duration::from_millis(9),
            mean: Duration::from_millis(1),
        };
        LoadReport {
            scenario: "default".into(),
            connections: 4,
            warmup: Duration::from_millis(300),
            duration: Duration::from_secs(2),
            arrival: "closed".into(),
            alpha: 1.1,
            mutations: 3,
            aggregate: mk("aggregate", 100),
            routes: vec![mk("route gcn/evalpow/w8/aes/u8-device", 60)],
        }
    }

    #[test]
    fn report_json_carries_the_gate_schema() {
        let doc = sample_report().to_json();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), "serving");
        let workloads = doc.get("workloads").unwrap().as_arr().unwrap();
        assert_eq!(workloads.len(), 2);
        let agg = &workloads[0];
        assert_eq!(agg.get("name").unwrap().as_str().unwrap(), "aggregate");
        assert_eq!(agg.get("shed").unwrap().as_usize().unwrap(), 2);
        let cases = agg.get("cases").unwrap().as_arr().unwrap();
        // p50/p99/p999 latency cases + the direction-tagged throughput.
        assert_eq!(cases.len(), 4);
        assert_eq!(cases[0].get("median_ns").unwrap().as_f64().unwrap(), 900_000.0);
        let tp = &cases[3];
        assert_eq!(tp.get("direction").unwrap().as_str().unwrap(), "higher");
        assert_eq!(tp.get("value").unwrap().as_f64().unwrap(), 50.0);
        // Per-route workloads carry latency cases only (their share of
        // traffic follows popularity, so throughput would be noise).
        let route_cases = workloads[1].get("cases").unwrap().as_arr().unwrap();
        assert_eq!(route_cases.len(), 3);
        // Round-trips through the JSON codec.
        let text = doc.to_string();
        assert!(crate::util::parse_json(&text).is_ok());
    }

    #[test]
    fn prefixed_report_renames_every_workload() {
        let doc = sample_report().to_json_prefixed(Some("sharded-router"));
        let workloads = doc.get("workloads").unwrap().as_arr().unwrap();
        assert_eq!(
            workloads[0].get("name").unwrap().as_str().unwrap(),
            "sharded-router aggregate"
        );
        for w in workloads {
            assert!(w.get("name").unwrap().as_str().unwrap().starts_with("sharded-router "));
        }
    }

    #[test]
    fn merge_appends_new_workloads_and_replaces_same_name_runs() {
        let base = sample_report().to_json();
        let sharded = sample_report().to_json_prefixed(Some("sharded-router"));
        let merged = merge_bench_json(&base.to_string(), &sharded).unwrap();
        let names: Vec<String> = merged
            .get("workloads")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|w| w.get("name").unwrap().as_str().unwrap().to_string())
            .collect();
        // Single-server workloads survive, prefixed ones join them.
        assert_eq!(names.len(), 4);
        assert!(names.contains(&"aggregate".to_string()));
        assert!(names.contains(&"sharded-router aggregate".to_string()));
        // Re-merging the same prefixed run replaces, never duplicates.
        let again = merge_bench_json(&merged.to_string(), &sharded).unwrap();
        assert_eq!(again.get("workloads").unwrap().as_arr().unwrap().len(), 4);
        // A non-serving base is refused rather than silently mangled.
        assert!(merge_bench_json(r#"{"bench":"spmm","workloads":[]}"#, &sharded).is_err());
    }

    #[test]
    fn digest_separates_statuses_and_is_zero_safe() {
        let samples = vec![
            (Duration::from_millis(1), 0u8),
            (Duration::from_millis(3), 0u8),
            (Duration::from_millis(2), 1u8),
            (Duration::from_millis(9), 2u8),
        ];
        let r = digest("x", &samples, Duration::from_secs(1));
        assert_eq!((r.completed, r.shed, r.errors), (2, 1, 1));
        // Quantiles come from ok samples only.
        assert!(r.p999 <= Duration::from_millis(3));
        assert!((r.throughput_rps - 2.0).abs() < 1e-9);
        let empty = digest("y", &[], Duration::from_secs(1));
        assert_eq!(empty.completed, 0);
        assert_eq!(empty.p50, Duration::ZERO);
    }

    #[test]
    fn default_grid_covers_both_precisions_and_skips_exact_duplicates() {
        let datasets = [("evalpow".to_string(), 160), ("evaluni".to_string(), 160)];
        let routes = default_routes(&datasets, &["gcn".to_string()]);
        assert_eq!(routes.len(), 12);
        let labels: Vec<String> = routes.iter().map(|r| r.label()).collect();
        assert!(labels.contains(&"gcn/evalpow/exact/aes/f32".to_string()));
        assert!(labels.contains(&"gcn/evaluni/w8/sfs/u8-device".to_string()));
        // No exact/sfs duplicate of exact/aes.
        assert!(!labels.iter().any(|l| l.contains("exact/sfs")));
        // All labels unique.
        let unique: std::collections::BTreeSet<_> = labels.iter().collect();
        assert_eq!(unique.len(), labels.len());
        // The model axis fans the same grid per model, still collision-free.
        let zoo = default_routes(&datasets, &["gcn".to_string(), "gat".to_string()]);
        assert_eq!(zoo.len(), 24);
        let zoo_labels: Vec<String> = zoo.iter().map(|r| r.label()).collect();
        assert!(zoo_labels.contains(&"gat/evalpow/w8/aes/f32".to_string()));
        let unique: std::collections::BTreeSet<_> = zoo_labels.iter().collect();
        assert_eq!(unique.len(), zoo_labels.len());
    }
}
