//! The conformance harness: run the {model × strategy × width ×
//! precision × shards} grid through the **real serving path** —
//! coordinator plan cache, prefetcher, sharded execution, host backend —
//! and score every configuration against its model's exact oracle.
//!
//! Four coordinators serve the grid, one per (streaming, sharding)
//! corner, so the INT8-eager vs INT8-streamed and sharded vs unsharded
//! axes each exercise a genuinely different serving configuration
//! rather than a test-only side path. Logits come back through
//! [`Coordinator::route_logits`], which resolves plans exactly the way
//! a batch worker does.

use std::collections::HashMap;
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::wire::{self, WireRequest};
use crate::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, ModelStore, RouteKey};
use crate::exec::{ShardLayout, ShardSampling, ShardedPlan};
use crate::experiments::Table;
use crate::graph::{EdgeOp, GraphDelta, ShardSpec};
use crate::quant::Precision;
use crate::runtime::{accuracy, Backend, Dataset, SERVED_MODELS};
use crate::sampling::Strategy;
use crate::tensor::Tensor;
use crate::util::{argmax_f32, JsonValue};

use super::budget::{
    budget_for, i8_compute_budget, i8_compute_delta_budget, quant_delta_budget, Budget,
};
use super::dataset::{write_eval_datasets, DegreeProfile, EVAL_DATASETS};
use super::metrics::{compare_logits, AccuracyMetrics};
use super::oracle::oracle_forward;

/// Shard counts in the grid (1 = the unsharded plan path).
pub const SHARD_GRID: [usize; 2] = [1, 3];

/// Sampled tile widths in the grid (`None` = exact aggregation). The
/// quick sweep drops the wide tile.
pub fn width_grid(quick: bool) -> Vec<Option<usize>> {
    if quick {
        vec![None, Some(8)]
    } else {
        vec![None, Some(8), Some(32)]
    }
}

/// Models on the grid — the whole served zoo. The quick sweep keeps GCN
/// plus one non-GCN model (GAT, whose per-edge attention exercises the
/// segmented-softmax kernels end to end) so IR dispatch never loses
/// smoke coverage.
pub fn model_grid(quick: bool) -> Vec<&'static str> {
    if quick {
        vec!["gcn", "gat"]
    } else {
        SERVED_MODELS.to_vec()
    }
}

/// How features reach the forward — the precision axis of the grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PrecisionMode {
    /// fp32 features (the baseline; never streamed).
    F32,
    /// INT8 features, staged eagerly (`CoordinatorConfig::streaming`
    /// off).
    U8Eager,
    /// INT8 features, streamed zero-copy with lazy per-block dequant
    /// (the serving default).
    U8Streamed,
    /// True INT8 compute: streamed INT8 codes fed straight to the
    /// integer-accumulating SpMM kernels over a requantized adjacency
    /// (`crate::spmm::ell_spmm_i8`) — no fp32 feature block is ever
    /// materialized on the aggregation path.
    I8Compute,
}

impl PrecisionMode {
    /// Every grid point on the precision axis.
    pub const ALL: [PrecisionMode; 4] = [
        PrecisionMode::F32,
        PrecisionMode::U8Eager,
        PrecisionMode::U8Streamed,
        PrecisionMode::I8Compute,
    ];

    /// The route-key precision this mode submits as.
    pub fn precision(self) -> Precision {
        match self {
            PrecisionMode::F32 => Precision::F32,
            PrecisionMode::U8Eager | PrecisionMode::U8Streamed => Precision::U8Device,
            PrecisionMode::I8Compute => Precision::I8Compute,
        }
    }

    /// Whether this mode's features stream (zero-copy; lazy per-block
    /// dequant for `U8Streamed`, raw-code access for `I8Compute`).
    pub fn streamed(self) -> bool {
        matches!(self, PrecisionMode::U8Streamed | PrecisionMode::I8Compute)
    }

    /// Which coordinator serves this mode: everything except eager INT8
    /// rides the streaming coordinator — fp32 never streams
    /// (`FeatureStore::stage` falls back to an eager load), so putting
    /// it there keeps it on the serving-default configuration. Distinct
    /// from [`PrecisionMode::streamed`]; the grid loop and the
    /// serving-path probes must agree on this or they would compare
    /// logits from two different coordinators' plan caches.
    pub fn streaming_coordinator(self) -> bool {
        !matches!(self, PrecisionMode::U8Eager)
    }

    /// Whether features are INT8-quantized (the quant budget applies).
    pub fn quantized(self) -> bool {
        !matches!(self, PrecisionMode::F32)
    }

    /// The oracle-relative budget this mode's configurations are held
    /// to (i8-compute stacks the edge-requant increment on the dequant
    /// route's budget).
    pub fn budget(self, width: Option<usize>) -> Budget {
        match self {
            PrecisionMode::I8Compute => i8_compute_budget(width),
            _ => budget_for(width, self.quantized()),
        }
    }

    /// Stable label for reports.
    pub fn name(self) -> &'static str {
        match self {
            PrecisionMode::F32 => "f32",
            PrecisionMode::U8Eager => "u8-eager",
            PrecisionMode::U8Streamed => "u8-streamed",
            PrecisionMode::I8Compute => "i8-compute",
        }
    }
}

/// One grid point's outcome.
#[derive(Clone, Debug)]
pub struct ConfigResult {
    /// Conformance dataset name.
    pub dataset: String,
    /// Served model (`gcn` / `sage` / `gat`).
    pub model: String,
    /// Edge-sampling strategy (ignored by exact routes).
    pub strategy: Strategy,
    /// Sampling width (`None` = exact aggregation).
    pub width: Option<usize>,
    /// Precision-axis grid point.
    pub mode: PrecisionMode,
    /// Shard count the serving coordinator partitioned into.
    pub shards: usize,
    /// Differential metrics vs the oracle.
    pub metrics: AccuracyMetrics,
    /// The budget this configuration is held to.
    pub budget: Budget,
    /// Whether `metrics` sit inside `budget`.
    pub pass: bool,
    /// Label accuracy of this configuration's logits (context only).
    pub label_accuracy: f64,
    /// Label accuracy of the oracle on the same dataset (context only).
    pub oracle_accuracy: f64,
}

impl ConfigResult {
    /// Stable configuration id (the gate keys on it).
    pub fn name(&self) -> String {
        let shape = shape_label(self.width, self.strategy);
        format!(
            "{}/{}/{}/{}/shards{}",
            self.dataset, self.model, shape, self.mode.name(), self.shards
        )
    }
}

/// The width/strategy part of a configuration or check id — one
/// formatter, so config names and check names can never desynchronize
/// (acc_diff keys its baseline diff on these strings).
fn shape_label(width: Option<usize>, strategy: Strategy) -> String {
    match width {
        Some(w) => format!("{}-w{w}", strategy.name()),
        None => "exact".to_string(),
    }
}

/// One cross-configuration invariant (bitwise or pairwise-budget check).
#[derive(Clone, Debug)]
pub struct EvalCheck {
    /// Stable check id.
    pub name: String,
    /// Whether the invariant held.
    pub pass: bool,
    /// Human-readable evidence (counts, deltas).
    pub detail: String,
}

/// Per-dataset context carried into the report.
#[derive(Clone, Debug)]
pub struct DatasetSummary {
    /// Dataset name.
    pub name: String,
    /// Node count.
    pub nodes: usize,
    /// Class count.
    pub classes: usize,
    /// Longest row (drives which sampling branches fire).
    pub max_degree: usize,
    /// Label accuracy of the oracle forward.
    pub oracle_accuracy: f64,
}

/// The full conformance report: every grid configuration plus the
/// cross-configuration checks.
#[derive(Clone, Debug, Default)]
pub struct EvalReport {
    /// Per-dataset context.
    pub datasets: Vec<DatasetSummary>,
    /// One entry per grid point.
    pub configs: Vec<ConfigResult>,
    /// Cross-configuration invariants.
    pub checks: Vec<EvalCheck>,
}

impl EvalReport {
    /// Whether every configuration and every check passed.
    pub fn pass(&self) -> bool {
        self.configs.iter().all(|c| c.pass) && self.checks.iter().all(|c| c.pass)
    }

    /// Failure descriptions (empty when [`EvalReport::pass`]).
    pub fn failures(&self) -> Vec<String> {
        let mut out = Vec::new();
        for c in &self.configs {
            if !c.pass {
                out.push(format!(
                    "config {}: {} of {} rows disagree with the oracle (top-1 {:.4}, \
                     max |delta| {:.3e}) outside budget [{}]",
                    c.name(),
                    c.metrics.disagreeing,
                    c.metrics.rows,
                    c.metrics.top1_agreement,
                    c.metrics.max_abs_delta,
                    c.budget.label()
                ));
            }
        }
        for c in &self.checks {
            if !c.pass {
                out.push(format!("check {}: {}", c.name, c.detail));
            }
        }
        out
    }

    /// The report as a flat JSON document (`ACC_eval.json`), consumed by
    /// `tools/acc_diff.rs`.
    pub fn to_json(&self) -> JsonValue {
        use std::collections::BTreeMap;
        let num = JsonValue::Num;
        let mut root = BTreeMap::new();
        root.insert("report".to_string(), JsonValue::Str("acc_eval".to_string()));
        root.insert("version".to_string(), JsonValue::Num(1.0));
        root.insert("pass".to_string(), JsonValue::Bool(self.pass()));
        root.insert(
            "datasets".to_string(),
            JsonValue::Arr(
                self.datasets
                    .iter()
                    .map(|d| {
                        let mut m = BTreeMap::new();
                        m.insert("name".to_string(), JsonValue::Str(d.name.clone()));
                        m.insert("nodes".to_string(), num(d.nodes as f64));
                        m.insert("classes".to_string(), num(d.classes as f64));
                        m.insert("max_degree".to_string(), num(d.max_degree as f64));
                        m.insert("oracle_accuracy".to_string(), num(d.oracle_accuracy));
                        JsonValue::Obj(m)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "configs".to_string(),
            JsonValue::Arr(
                self.configs
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("name".to_string(), JsonValue::Str(c.name()));
                        m.insert("dataset".to_string(), JsonValue::Str(c.dataset.clone()));
                        m.insert("model".to_string(), JsonValue::Str(c.model.clone()));
                        m.insert(
                            "strategy".to_string(),
                            JsonValue::Str(c.strategy.name().to_string()),
                        );
                        m.insert(
                            "width".to_string(),
                            c.width.map(|w| num(w as f64)).unwrap_or(JsonValue::Null),
                        );
                        m.insert(
                            "precision".to_string(),
                            JsonValue::Str(c.mode.name().to_string()),
                        );
                        m.insert("shards".to_string(), num(c.shards as f64));
                        m.insert("rows".to_string(), num(c.metrics.rows as f64));
                        m.insert(
                            "disagreeing_rows".to_string(),
                            num(c.metrics.disagreeing as f64),
                        );
                        m.insert("top1_agreement".to_string(), num(c.metrics.top1_agreement));
                        m.insert("mean_rel_l2".to_string(), num(c.metrics.mean_rel_l2));
                        m.insert("max_rel_l2".to_string(), num(c.metrics.max_rel_l2));
                        m.insert(
                            "max_abs_delta".to_string(),
                            num(f64::from(c.metrics.max_abs_delta)),
                        );
                        m.insert(
                            "bitwise_equal".to_string(),
                            JsonValue::Bool(c.metrics.bitwise_equal),
                        );
                        m.insert("budget_top1_loss".to_string(), num(c.budget.max_top1_loss));
                        m.insert(
                            "budget_slack_rows".to_string(),
                            num(c.budget.slack_rows as f64),
                        );
                        m.insert("budget_bitwise".to_string(), JsonValue::Bool(c.budget.bitwise));
                        m.insert("label_accuracy".to_string(), num(c.label_accuracy));
                        m.insert("oracle_accuracy".to_string(), num(c.oracle_accuracy));
                        m.insert("pass".to_string(), JsonValue::Bool(c.pass));
                        JsonValue::Obj(m)
                    })
                    .collect(),
            ),
        );
        root.insert(
            "checks".to_string(),
            JsonValue::Arr(
                self.checks
                    .iter()
                    .map(|c| {
                        let mut m = BTreeMap::new();
                        m.insert("name".to_string(), JsonValue::Str(c.name.clone()));
                        m.insert("pass".to_string(), JsonValue::Bool(c.pass));
                        m.insert("detail".to_string(), JsonValue::Str(c.detail.clone()));
                        JsonValue::Obj(m)
                    })
                    .collect(),
            ),
        );
        JsonValue::Obj(root)
    }

    /// The report as a printable table (one row per configuration).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "acc_eval",
            "accuracy conformance vs the exact oracle (paper Tables 4-6 budgets)",
            &["config", "top-1", "flips", "max rel L2", "max |delta|", "budget", "pass"],
        );
        for c in &self.configs {
            t.push(vec![
                c.name(),
                format!("{:.4}", c.metrics.top1_agreement),
                format!("{}/{}", c.metrics.disagreeing, c.metrics.rows),
                format!("{:.3e}", c.metrics.max_rel_l2),
                format!("{:.3e}", c.metrics.max_abs_delta),
                c.budget.label(),
                if c.pass { "yes".to_string() } else { "NO".to_string() },
            ]);
        }
        t
    }
}

/// Bitwise comparison of two logit vectors, with a count of differing
/// elements for check details.
fn bits_equal(a: &[f32], b: &[f32]) -> (bool, usize) {
    if a.len() != b.len() {
        return (false, a.len().max(b.len()));
    }
    let differing = a.iter().zip(b.iter()).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
    (differing == 0, differing)
}

/// Bank key: one grid point's logits — (dataset, model, strategy,
/// width, precision mode, shards).
type BankKey = (String, String, Strategy, Option<usize>, PrecisionMode, usize);

/// Run the conformance grid under `dir` (datasets are (re)written there
/// deterministically). `quick` trims the width axis for smoke runs.
pub fn run_eval(dir: &Path, quick: bool) -> Result<EvalReport> {
    // Record which dispatch regime scored this grid: a tuned run must
    // satisfy the same budgets as the heuristic run (the format zoo is
    // bitwise-equal to CSR, and this grid is what checks that claim).
    match crate::exec::installed_fingerprint() {
        0 => println!("dispatch: heuristics (no cost model installed)"),
        fp => println!("dispatch: tuned (cost model fingerprint {fp:#018x})"),
    }
    let names = write_eval_datasets(dir)?;
    let models = model_grid(quick);
    let model_names: Vec<String> = models.iter().map(|m| m.to_string()).collect();
    let store = Arc::new(ModelStore::load(dir, &names, &model_names)?);

    // One coordinator per (streaming, shards) corner of the grid.
    let mut coords: HashMap<(bool, usize), Coordinator> = HashMap::new();
    for &shards in &SHARD_GRID {
        for streaming in [false, true] {
            let cfg = CoordinatorConfig {
                workers: 2,
                queue_depth: 256,
                plan_cache_capacity: 128,
                prefetch_workers: 1,
                sharding: (shards > 1).then(|| ShardSpec::by_count(shards)),
                streaming,
                batcher: BatcherConfig { max_batch: 8, max_delay: Duration::from_millis(1) },
                ..CoordinatorConfig::default()
            };
            let coord = Coordinator::start_with(Backend::Host, store.clone(), cfg);
            coords.insert((streaming, shards), coord);
        }
    }

    let widths = width_grid(quick);
    // Route shapes: the exact route (strategy-independent, keep one),
    // then every (width, strategy) pair.
    let mut shapes: Vec<(Option<usize>, Strategy)> = vec![(None, Strategy::Aes)];
    for w in widths.iter().filter_map(|w| *w) {
        for s in Strategy::ALL {
            shapes.push((Some(w), s));
        }
    }

    let mut report = EvalReport::default();
    let mut bank: HashMap<BankKey, Vec<f32>> = HashMap::new();

    for spec in &EVAL_DATASETS {
        let name = spec.name;
        let ds = store.dataset(name)?;
        // One exact oracle per served model — every grid point scores
        // against *its* model's unsampled fp32 forward.
        let mut oracles: HashMap<&str, (Vec<f32>, f64)> = HashMap::new();
        for &model in &models {
            let weights = store.weights(model, name)?;
            let oracle = oracle_forward(&ds, &weights)?;
            let oracle_t = Tensor::from_f32(&[ds.n, ds.classes], &oracle);
            let acc = accuracy(&ds, &oracle_t)?;
            oracles.insert(model, (oracle, acc));
        }
        let gcn_oracle_acc = oracles["gcn"].1;
        report.datasets.push(DatasetSummary {
            name: name.to_string(),
            nodes: ds.n,
            classes: ds.classes,
            max_degree: ds.csr_gcn.max_degree(),
            oracle_accuracy: gcn_oracle_acc,
        });

        // The grid proper.
        for &model in &models {
            let (oracle, oracle_acc) = &oracles[model];
            for &(width, strategy) in &shapes {
                for mode in PrecisionMode::ALL {
                    for &shards in &SHARD_GRID {
                        let coord = &coords[&(mode.streaming_coordinator(), shards)];
                        let key = RouteKey {
                            model: model.to_string(),
                            dataset: name.to_string(),
                            width,
                            strategy,
                            precision: mode.precision(),
                        };
                        let logits_t = coord
                            .route_logits(&key)
                            .with_context(|| format!("route {} (shards {shards})", key.label()))?;
                        let logits = logits_t.as_f32()?.to_vec();
                        let metrics = compare_logits(oracle, &logits, ds.n, ds.classes);
                        let budget = mode.budget(width);
                        report.configs.push(ConfigResult {
                            dataset: name.to_string(),
                            model: model.to_string(),
                            strategy,
                            width,
                            mode,
                            shards,
                            metrics,
                            budget,
                            pass: budget.admits(&metrics),
                            label_accuracy: accuracy(&ds, &logits_t)?,
                            oracle_accuracy: *oracle_acc,
                        });
                        bank.insert(
                            (name.to_string(), model.to_string(), strategy, width, mode, shards),
                            logits,
                        );
                    }
                }
            }
        }

        // Cross-configuration invariants, per model.
        for &model in &models {
            push_pairwise_checks(&mut report, &bank, name, model, &shapes, &ds);
        }
        push_shard_branch_checks(&mut report, spec.profile, name, &ds);
        push_serving_path_checks(&mut report, &coords, &bank, name, &ds)?;
        // Live mutation: dedicated coordinators (apply_delta advances
        // the store's epoch, which must not touch the grid's stores).
        push_mutation_checks(&mut report, dir, name, quick)?;
    }

    // Multi-process topology: the conformance routes served through a
    // router + two shard-server processes over loopback, bitwise vs a
    // single-process coordinator — including after mid-serving deltas
    // and after a worker death (re-placement + replication-log replay).
    push_distributed_checks(&mut report, dir, &names, quick)?;

    for (_, c) in coords {
        c.shutdown();
    }
    Ok(report)
}

/// Deterministic deltas for the mutate-then-serve scenario, derived
/// from the dataset's own structure: one value-level delta and one
/// structural delta, both confined to the first rows (a single shard of
/// the 3-way layout) so shard retention is observable.
fn eval_deltas(ds: &Dataset) -> Vec<GraphDelta> {
    let g = &ds.csr_gcn;
    let first_edge = |row: usize| -> Option<(i32, f32)> {
        g.row_range(row).next().map(|e| (g.col_ind[e], g.val[e]))
    };
    let (c0, v0) = first_edge(0).expect("eval graphs have self-loops");
    let (c1, _) = first_edge(1).expect("eval graphs have self-loops");
    vec![
        // Delta 1: reweight one edge of row 0, insert a fresh edge on
        // row 1 (new column: the last node, weights stay Â-scale).
        GraphDelta::new(vec![
            EdgeOp::Reweight { row: 0, col: c0, weight: v0 * 0.5 },
            EdgeOp::Insert { row: 1, col: (ds.n - 1) as i32, weight: 0.05 },
        ]),
        // Delta 2: delete the edge delta 1 inserted and one original
        // edge of row 1 — exercising delete-after-insert across epochs.
        GraphDelta::new(vec![
            EdgeOp::Delete { row: 1, col: (ds.n - 1) as i32 },
            EdgeOp::Delete { row: 1, col: c1 },
        ]),
    ]
}

/// The mutate-then-serve guarantee through the real serving stack:
/// after each [`Coordinator::apply_delta`], the warm (sharded,
/// streaming) coordinator's forward must be **bitwise-equal** to a cold
/// coordinator built directly on the mutated graph — and the warm
/// coordinator must prove (via [`crate::coordinator::ShardCacheStats`])
/// that it kept every untouched shard's unit instead of re-sampling it.
/// The quick sweep keeps the scenario (it is the only coverage of the
/// mutation path in `--quick` CI smoke runs) but trims it to a single
/// delta, halving the cold-coordinator replays.
fn push_mutation_checks(
    report: &mut EvalReport,
    dir: &Path,
    name: &str,
    quick: bool,
) -> Result<()> {
    let names = vec![name.to_string()];
    let models = vec!["gcn".to_string()];
    let shards = SHARD_GRID[1];
    let cfg = CoordinatorConfig {
        workers: 2,
        queue_depth: 64,
        prefetch_workers: 1,
        sharding: Some(ShardSpec::by_count(shards)),
        streaming: true,
        ..CoordinatorConfig::default()
    };
    let store = Arc::new(ModelStore::load(dir, &names, &models)?);
    let warm = Coordinator::start_with(Backend::Host, store.clone(), cfg.clone());
    let ds = store.dataset(name)?;
    // Two route families (exact + sampled) so retention counts cover
    // both unit families; INT8-streamed rides the same units.
    let routes = [
        (None, Strategy::Aes, Precision::F32),
        (Some(8), Strategy::Aes, Precision::U8Device),
    ];
    let route_key = |(width, strategy, precision): (Option<usize>, Strategy, Precision)| RouteKey {
        model: "gcn".to_string(),
        dataset: name.to_string(),
        width,
        strategy,
        precision,
    };
    for &r in &routes {
        warm.route_logits(&route_key(r))?;
    }
    // The warm coordinator's sticky layout is derived deterministically
    // from (csr, spec) at first build; recompute it here so the
    // retention expectations track the actual cuts instead of assuming
    // which shard the touched rows land in.
    let layout = ShardLayout::of(&ds.csr_gcn, &ShardSpec::by_count(shards));

    let mut deltas = eval_deltas(&ds);
    if quick {
        deltas.truncate(1);
    }
    for (i, delta) in deltas.iter().enumerate() {
        let before = warm.shard_stats();
        let outcome = warm.apply_delta(name, delta)?;
        warm.wait_prefetch_idle();
        let mut warm_logits = Vec::new();
        for &r in &routes {
            warm_logits.push(warm.route_logits(&route_key(r))?.as_f32()?.to_vec());
        }
        let after = warm.shard_stats();

        // Cold oracle: a fresh coordinator that never served the
        // pre-mutation graph, fed the same delta prefix.
        let cold_store = Arc::new(ModelStore::load(dir, &names, &models)?);
        let cold = Coordinator::start_with(Backend::Host, cold_store, cfg.clone());
        for d in &deltas[..=i] {
            cold.apply_delta(name, d)?;
        }
        for (ri, &r) in routes.iter().enumerate() {
            let key = route_key(r);
            let want = cold.route_logits(&key)?.as_f32()?.to_vec();
            let (equal, differing) = bits_equal(&want, &warm_logits[ri]);
            report.checks.push(EvalCheck {
                name: format!(
                    "mutate-then-serve bitwise ({name}/{}/delta{})",
                    shape_label(key.width, key.strategy),
                    i + 1
                ),
                pass: equal,
                detail: format!(
                    "{differing} logit(s) differ vs a cold coordinator on the mutated graph \
                     (epoch {})",
                    outcome.epoch
                ),
            });
        }
        cold.shutdown();

        // Retention: per route family, exactly the shards the delta's
        // touched rows land in (per the sticky layout) re-sample; the
        // rest stay warm. Deltas are shaped to leave at least one
        // untouched shard, so retention is observable.
        let affected = layout.affected_shards(&outcome.report.touched_rows).len();
        let families = routes.len();
        let untouched = layout.shard_count() - affected;
        let misses = after.misses - before.misses;
        let hits = after.hits - before.hits;
        let expect_misses = (families * affected) as u64;
        let expect_hits = (families * untouched) as u64;
        report.checks.push(EvalCheck {
            name: format!("mutation retains untouched shards ({name}/delta{})", i + 1),
            pass: untouched > 0
                && outcome.shards_resampled == families * affected
                && outcome.shards_retained == families * untouched
                && misses == expect_misses
                && hits >= expect_hits
                && !outcome.repartitioned,
            detail: format!(
                "{affected}/{} shard(s) touched; resampled {} (want {}), retained {} \
                 (want {}), unit misses {misses} (want {expect_misses}), unit hits {hits} \
                 (want ≥{expect_hits})",
                layout.shard_count(),
                outcome.shards_resampled,
                families * affected,
                outcome.shards_retained,
                families * untouched
            ),
        });
    }
    warm.shutdown();
    Ok(())
}

/// Locate the `repro` binary for the multi-process topology checks:
/// `AES_SPMM_REPRO_BIN` wins, then the current executable when the
/// harness runs inside `repro eval` itself, then a `repro` sibling of
/// the current executable (covers `target/<profile>/deps/<test>-<hash>`
/// integration-test binaries, whose grandparent dir holds the bin).
fn find_repro_binary() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("AES_SPMM_REPRO_BIN") {
        let p = PathBuf::from(p);
        if p.is_file() {
            return Some(p);
        }
    }
    let exe = std::env::current_exe().ok()?;
    if exe.file_stem().is_some_and(|s| s == "repro") {
        return Some(exe);
    }
    let mut dir = exe.parent()?;
    for _ in 0..2 {
        let cand = dir.join("repro");
        if cand.is_file() {
            return Some(cand);
        }
        dir = dir.parent()?;
    }
    None
}

/// Child processes of the distributed pass; killed on drop so a failing
/// check (or any `?` on the way) never leaks servers past the harness.
struct Fleet {
    children: Vec<Child>,
}

impl Drop for Fleet {
    fn drop(&mut self) {
        for c in &mut self.children {
            let _ = c.kill();
            let _ = c.wait();
        }
    }
}

/// Wait for a serving process to publish its resolved ephemeral port
/// (`--port-file` is written only after the bind succeeds), failing
/// fast if the child exits first.
fn poll_port_file(path: &Path, child: &mut Child) -> Result<String> {
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        if let Ok(s) = std::fs::read_to_string(path) {
            let s = s.trim();
            if !s.is_empty() {
                return Ok(s.to_string());
            }
        }
        if let Some(status) = child.try_wait().context("polling serving child")? {
            bail!("serving process exited ({status}) before writing {}", path.display());
        }
        if Instant::now() >= deadline {
            bail!("timed out waiting for port file {}", path.display());
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// A delta's ops in the wire `mutate` line format (`docs/mutation.md`) —
/// `{}` on `f32` prints the shortest round-tripping decimal, so the
/// worker-side [`GraphDelta::parse`] recovers the exact weights.
fn delta_lines(delta: &GraphDelta) -> Vec<String> {
    delta
        .ops
        .iter()
        .map(|op| match *op {
            EdgeOp::Insert { row, col, weight } => format!("+ {row} {col} {weight}"),
            EdgeOp::Delete { row, col } => format!("- {row} {col}"),
            EdgeOp::Reweight { row, col, weight } => format!("= {row} {col} {weight}"),
        })
        .collect()
}

/// Decode a wire `logits` response's `logits_bits` array.
fn response_bits(resp: &JsonValue) -> Result<Vec<u32>> {
    resp.get("logits_bits")?
        .as_arr()?
        .iter()
        .map(|v| Ok(v.as_f64()? as u32))
        .collect()
}

/// Bitwise comparison over raw `f32::to_bits` words (the wire carries
/// bits, not floats — decoding to `f32` first would conflate NaN
/// payloads).
fn bits_diff(a: &[u32], b: &[u32]) -> (bool, usize) {
    if a.len() != b.len() {
        return (false, a.len().max(b.len()));
    }
    let differing = a.iter().zip(b.iter()).filter(|(x, y)| x != y).count();
    (differing == 0, differing)
}

/// The tentpole acceptance pass: serve conformance routes through a
/// **3-process topology** — a router scatter/gathering over two
/// `shard-server` worker processes on loopback — and hold the result
/// bitwise-equal to a single-process coordinator over the same files.
/// Three phases:
///
/// 1. cold reads across the route shapes (scatter → row-concat merge);
/// 2. a mid-serving delta through the router's replication log (every
///    worker acks before the client does → read-your-writes), reads
///    re-compared against a cold coordinator with the delta applied;
/// 3. a worker kill: the router re-places the dead worker's row ranges
///    on the survivor and replays the delta log from its watermark —
///    a subsequent mutate and all reads must still be bitwise.
///
/// Runs only when the `repro` binary is discoverable
/// ([`find_repro_binary`]); otherwise records an explicitly-labelled
/// skip so the report never silently loses the coverage.
fn push_distributed_checks(
    report: &mut EvalReport,
    dir: &Path,
    names: &[String],
    quick: bool,
) -> Result<()> {
    let Some(bin) = find_repro_binary() else {
        report.checks.push(EvalCheck {
            name: "distributed topology (router + 2 shard servers)".to_string(),
            pass: true,
            detail: "skipped: repro binary not found (set AES_SPMM_REPRO_BIN to run the \
                     3-process conformance pass)"
                .to_string(),
        });
        return Ok(());
    };

    let base = dir.join("dist");
    std::fs::create_dir_all(&base)
        .with_context(|| format!("creating {}", base.display()))?;
    let mut fleet = Fleet { children: Vec::new() };

    // Two shard-server workers, each regenerating the (deterministic)
    // eval datasets into a private dir — identical bytes to `dir`, no
    // write races between processes.
    let mut port_files = Vec::new();
    for i in 1..=2usize {
        let port_file = base.join(format!("worker{i}.port"));
        let _ = std::fs::remove_file(&port_file);
        let child = Command::new(&bin)
            .args(["shard-server", "--listen", "127.0.0.1:0", "--max-seconds", "600"])
            .arg("--eval-data")
            .arg(base.join(format!("worker{i}-data")))
            .arg("--port-file")
            .arg(&port_file)
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .with_context(|| format!("spawning shard-server {i} ({})", bin.display()))?;
        fleet.children.push(child);
        port_files.push(port_file);
    }
    let mut worker_addrs = Vec::new();
    for (i, pf) in port_files.iter().enumerate() {
        worker_addrs.push(poll_port_file(pf, &mut fleet.children[i])?);
    }

    let router_port = base.join("router.port");
    let _ = std::fs::remove_file(&router_port);
    let child = Command::new(&bin)
        .args(["router", "--listen", "127.0.0.1:0", "--max-seconds", "600"])
        .arg("--workers")
        .arg(worker_addrs.join(","))
        .arg("--port-file")
        .arg(&router_port)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .with_context(|| format!("spawning router ({})", bin.display()))?;
    fleet.children.push(child);
    let router_addr = poll_port_file(&router_port, fleet.children.last_mut().unwrap())?;

    let mut conn = TcpStream::connect(&router_addr)
        .with_context(|| format!("connecting to router at {router_addr}"))?;
    conn.set_read_timeout(Some(Duration::from_secs(120)))?;

    // The single-process oracle: same files, same serving stack, one
    // process. The grid's own bitwise invariants (sharded == unsharded,
    // streamed == eager) make the exact config immaterial.
    let models = vec!["gcn".to_string()];
    let cold_store = Arc::new(ModelStore::load(dir, names, &models)?);
    let cold = Coordinator::start_with(
        Backend::Host,
        cold_store.clone(),
        CoordinatorConfig { workers: 2, ..CoordinatorConfig::default() },
    );
    let coord_bits = |key: &RouteKey| -> Result<Vec<u32>> {
        Ok(cold.route_logits(key)?.as_f32()?.iter().map(|v| v.to_bits()).collect())
    };

    let check_names: &[String] = if quick { &names[..1] } else { names };
    let shapes = [
        (None, Strategy::Aes, Precision::F32),
        (Some(8), Strategy::Aes, Precision::U8Device),
    ];
    let route_key = |name: &str, shape: (Option<usize>, Strategy, Precision)| RouteKey {
        model: "gcn".to_string(),
        dataset: name.to_string(),
        width: shape.0,
        strategy: shape.1,
        precision: shape.2,
    };
    // Takes `report`/`id` as parameters (not captures) so the phases
    // between rounds can push their own checks without fighting the
    // closure's borrows.
    let compare_round = |conn: &mut TcpStream,
                         report: &mut EvalReport,
                         id: &mut u64,
                         phase: &str,
                         want_epoch: u64|
     -> Result<()> {
        for name in check_names {
            for &shape in &shapes {
                let key = route_key(name, shape);
                *id += 1;
                let resp =
                    wire::roundtrip(conn, &WireRequest::Logits { id: *id, route: key.clone() })
                        .with_context(|| format!("router logits ({phase}, {})", key.label()))?;
                let (pass, detail) = if wire::response_status(&resp) != "ok" {
                    (false, format!("router answered {}", resp.to_string()))
                } else {
                    let got = response_bits(&resp)?;
                    let want = coord_bits(&key)?;
                    let (equal, differing) = bits_diff(&got, &want);
                    let epoch = resp.get("epoch")?.as_usize()? as u64;
                    // Non-mutated datasets stay at epoch 0 regardless
                    // of the phase's head on the mutated one.
                    let expect = if name == &check_names[0] { want_epoch } else { 0 };
                    if epoch != expect {
                        (false, format!("router served epoch {epoch}, expected {expect}"))
                    } else {
                        (
                            equal,
                            format!(
                                "{differing} logit(s) differ vs the single-process \
                                 coordinator (epoch {epoch})"
                            ),
                        )
                    }
                };
                report.checks.push(EvalCheck {
                    name: format!(
                        "distributed == single-process bitwise ({phase}, {name}/{})",
                        shape_label(key.width, key.strategy)
                    ),
                    pass,
                    detail,
                });
            }
        }
        Ok(())
    };

    // Phase 1: cold reads through scatter/gather.
    let mut id = 0u64;
    compare_round(&mut conn, report, &mut id, "boot", 0)?;

    // Phase 2: a mid-serving delta through the replication log. The
    // router acks only after every live worker acks, so the very next
    // read must already serve the new epoch (read-your-writes).
    let target = &check_names[0];
    let ds = cold_store.dataset(target)?;
    let deltas = eval_deltas(&ds);
    id += 1;
    let resp = wire::roundtrip(
        &mut conn,
        &WireRequest::Mutate {
            id,
            dataset: target.clone(),
            ops: delta_lines(&deltas[0]),
        },
    )
    .context("router mutate (delta 1)")?;
    let mutate_ok = wire::response_status(&resp) == "ok"
        && resp.get("epoch").and_then(|e| e.as_usize()).unwrap_or(0) == 1;
    report.checks.push(EvalCheck {
        name: "distributed mutate replicates (delta 1)".to_string(),
        pass: mutate_ok,
        detail: format!("router answered {}", resp.to_string()),
    });
    cold.apply_delta(target, &deltas[0])?;
    compare_round(&mut conn, report, &mut id, "post-delta", 1)?;

    // Phase 3: worker death. Kill worker 1; the router must mark it
    // dead on the next failed call, re-place its row ranges on the
    // survivor, and catch the inheritor up from the delta log — then
    // a further mutate and every read stay bitwise.
    fleet.children[0].kill().context("killing shard worker 1")?;
    let _ = fleet.children[0].wait();
    id += 1;
    let resp = wire::roundtrip(
        &mut conn,
        &WireRequest::Mutate {
            id,
            dataset: target.clone(),
            ops: delta_lines(&deltas[1]),
        },
    )
    .context("router mutate (delta 2, after worker kill)")?;
    let mutate_ok = wire::response_status(&resp) == "ok"
        && resp.get("epoch").and_then(|e| e.as_usize()).unwrap_or(0) == 2;
    report.checks.push(EvalCheck {
        name: "distributed mutate survives worker death (delta 2)".to_string(),
        pass: mutate_ok,
        detail: format!("router answered {}", resp.to_string()),
    });
    cold.apply_delta(target, &deltas[1])?;
    compare_round(&mut conn, report, &mut id, "post-failover", 2)?;

    // The failover is visible in the router's ops surface.
    id += 1;
    let resp = wire::roundtrip(&mut conn, &WireRequest::Status { id })
        .context("router status after failover")?;
    let live = resp.get("workers").and_then(|w| w.as_usize()).unwrap_or(usize::MAX);
    report.checks.push(EvalCheck {
        name: "router reports the dead worker".to_string(),
        pass: wire::response_status(&resp) == "ok" && live == 1,
        detail: format!("status reports {live} live worker(s), want 1"),
    });

    cold.shutdown();
    drop(fleet);
    Ok(())
}

/// Streamed-vs-eager and sharded-vs-unsharded bitwise checks plus the
/// pairwise quantization budget, for every shape of one (dataset,
/// model) pair.
fn push_pairwise_checks(
    report: &mut EvalReport,
    bank: &HashMap<BankKey, Vec<f32>>,
    name: &str,
    model: &str,
    shapes: &[(Option<usize>, Strategy)],
    ds: &Dataset,
) {
    let bk = |strategy, width, mode, shards| {
        (name.to_string(), model.to_string(), strategy, width, mode, shards)
    };
    for &(width, strategy) in shapes {
        let shape = shape_label(width, strategy);
        for &shards in &SHARD_GRID {
            // INT8 streamed ≡ INT8 eager (bitwise, the PR 2 contract).
            let eager = &bank[&bk(strategy, width, PrecisionMode::U8Eager, shards)];
            let streamed = &bank[&bk(strategy, width, PrecisionMode::U8Streamed, shards)];
            let (equal, differing) = bits_equal(eager, streamed);
            report.checks.push(EvalCheck {
                name: format!("int8 streamed == eager ({name}/{model}/{shape}/shards{shards})"),
                pass: equal,
                detail: format!("{differing} logit(s) differ at the bit level"),
            });
            // Quantization adds ≤ 0.3% vs the fp32 sibling.
            let f32_logits = &bank[&bk(strategy, width, PrecisionMode::F32, shards)];
            let m = compare_logits(f32_logits, eager, ds.n, ds.classes);
            let budget = quant_delta_budget();
            report.checks.push(EvalCheck {
                name: format!("int8 vs fp32 delta ({name}/{model}/{shape}/shards{shards})"),
                pass: budget.admits(&m),
                detail: format!(
                    "{} of {} rows flip vs fp32 (allowed {})",
                    m.disagreeing,
                    m.rows,
                    budget.allowed_disagreements(m.rows)
                ),
            });
            // True INT8 compute adds ≤ 0.3% on top of the dequant route
            // (the edge-coefficient requant is a second Eq. 1-style
            // rounding — see docs/simd.md). Non-GCN programs are not
            // flip-eligible and serve I8Compute on the dequant path, so
            // there the comparison is bitwise in practice — still inside
            // this looser budget.
            let i8c = &bank[&bk(strategy, width, PrecisionMode::I8Compute, shards)];
            let m = compare_logits(eager, i8c, ds.n, ds.classes);
            let budget = i8_compute_delta_budget();
            report.checks.push(EvalCheck {
                name: format!(
                    "i8-compute vs int8-dequant delta ({name}/{model}/{shape}/shards{shards})"
                ),
                pass: budget.admits(&m),
                detail: format!(
                    "{} of {} rows flip vs the dequant sibling (allowed {})",
                    m.disagreeing,
                    m.rows,
                    budget.allowed_disagreements(m.rows)
                ),
            });
        }
        // Sharding adds exactly zero — the budget-table entry for this
        // invariant (`shard_delta_budget`) is bitwise, so the check is a
        // plain bit comparison.
        for mode in PrecisionMode::ALL {
            let unsharded = &bank[&bk(strategy, width, mode, SHARD_GRID[0])];
            let sharded = &bank[&bk(strategy, width, mode, SHARD_GRID[1])];
            let (equal, differing) = bits_equal(unsharded, sharded);
            report.checks.push(EvalCheck {
                name: format!("sharded == unsharded ({name}/{model}/{shape}/{})", mode.name()),
                pass: equal,
                detail: format!("{differing} logit(s) differ at the bit level"),
            });
        }
    }
}

/// Both branches of [`crate::sampling::shard_width`] must fire on the
/// conformance datasets: skewed shards keep the full tile and sample,
/// uniform shards shrink to an exhaustive tile.
fn push_shard_branch_checks(
    report: &mut EvalReport,
    profile: DegreeProfile,
    name: &str,
    ds: &Dataset,
) {
    match profile {
        DegreeProfile::PowerLaw => {
            let plan = ShardedPlan::prepare(
                &ds.csr_gcn,
                &ShardSpec::by_count(3),
                Some(8),
                Strategy::Aes,
                ds.feats,
                None,
            );
            let sampled = plan
                .units()
                .iter()
                .filter(|u| matches!(u.sampling, ShardSampling::Sampled { .. }))
                .count();
            report.checks.push(EvalCheck {
                name: format!("skewed shards sample at full W ({name}, W=8)"),
                pass: sampled > 0,
                detail: format!(
                    "{sampled} of {} shard(s) took the sampled branch",
                    plan.shard_count()
                ),
            });
        }
        DegreeProfile::Uniform => {
            let plan = ShardedPlan::prepare(
                &ds.csr_gcn,
                &ShardSpec::by_count(3),
                Some(64),
                Strategy::Aes,
                ds.feats,
                None,
            );
            let exhaustive = plan
                .units()
                .iter()
                .filter(|u| matches!(u.sampling, ShardSampling::Exhaustive { .. }))
                .count();
            report.checks.push(EvalCheck {
                name: format!("uniform shards shrink to exhaustive tiles ({name}, W=64)"),
                pass: exhaustive > 0,
                detail: format!(
                    "{exhaustive} of {} shard(s) took the exhaustive branch",
                    plan.shard_count()
                ),
            });
        }
    }
}

/// The batched request path must agree with the logits the plan served:
/// per-node predictions are the NaN-safe argmax of the route's logits.
fn push_serving_path_checks(
    report: &mut EvalReport,
    coords: &HashMap<(bool, usize), Coordinator>,
    bank: &HashMap<BankKey, Vec<f32>>,
    name: &str,
    ds: &Dataset,
) -> Result<()> {
    // `gat` is on every model grid (quick included), so its probe's
    // bank entry always exists.
    let probes: [(&str, Option<usize>, Strategy, PrecisionMode, usize); 4] = [
        ("gcn", None, Strategy::Aes, PrecisionMode::F32, SHARD_GRID[0]),
        ("gcn", Some(8), Strategy::Aes, PrecisionMode::U8Streamed, SHARD_GRID[0]),
        ("gcn", Some(8), Strategy::Sfs, PrecisionMode::F32, SHARD_GRID[1]),
        ("gat", Some(8), Strategy::Aes, PrecisionMode::F32, SHARD_GRID[1]),
    ];
    for (model, width, strategy, mode, shards) in probes {
        let coord = &coords[&(mode.streaming_coordinator(), shards)];
        let key = RouteKey {
            model: model.to_string(),
            dataset: name.to_string(),
            width,
            strategy,
            precision: mode.precision(),
        };
        let nodes: Vec<usize> = (0..ds.n).step_by(17).collect();
        let resp = coord.infer(key, nodes.clone())?;
        let logits =
            &bank[&(name.to_string(), model.to_string(), strategy, width, mode, shards)];
        let mismatches = match &resp.error {
            Some(_) => nodes.len(),
            None => resp
                .predictions
                .iter()
                .filter(|p| {
                    let row = &logits[p.node * ds.classes..(p.node + 1) * ds.classes];
                    p.class != argmax_f32(row) as i32
                })
                .count(),
        };
        let shape = shape_label(width, strategy);
        report.checks.push(EvalCheck {
            name: format!(
                "batched predictions == route logits argmax \
                 ({name}/{model}/{shape}/{}/shards{shards})",
                mode.name()
            ),
            pass: resp.error.is_none() && mismatches == 0,
            detail: match resp.error {
                Some(e) => format!("request failed: {e}"),
                None => format!("{mismatches} of {} prediction(s) mismatch", nodes.len()),
            },
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_modes_map_to_route_precisions() {
        assert_eq!(PrecisionMode::ALL.len(), 4);
        assert_eq!(PrecisionMode::F32.precision(), Precision::F32);
        assert_eq!(PrecisionMode::U8Eager.precision(), Precision::U8Device);
        assert_eq!(PrecisionMode::U8Streamed.precision(), Precision::U8Device);
        assert_eq!(PrecisionMode::I8Compute.precision(), Precision::I8Compute);
        assert!(PrecisionMode::U8Streamed.streamed());
        assert!(PrecisionMode::I8Compute.streamed());
        assert!(!PrecisionMode::U8Eager.streamed());
        assert!(PrecisionMode::U8Eager.quantized() && !PrecisionMode::F32.quantized());
        assert!(PrecisionMode::I8Compute.quantized());
        // fp32 rides the streaming coordinator (stage falls back to an
        // eager load for fp32); only eager INT8 uses the eager one.
        assert!(PrecisionMode::F32.streaming_coordinator());
        assert!(PrecisionMode::U8Streamed.streaming_coordinator());
        assert!(PrecisionMode::I8Compute.streaming_coordinator());
        assert!(!PrecisionMode::U8Eager.streaming_coordinator());
    }

    #[test]
    fn mode_budgets_match_the_budget_table() {
        for width in [None, Some(8)] {
            assert_eq!(PrecisionMode::F32.budget(width), budget_for(width, false));
            assert_eq!(PrecisionMode::U8Eager.budget(width), budget_for(width, true));
            assert_eq!(PrecisionMode::I8Compute.budget(width), i8_compute_budget(width));
        }
        assert!(
            PrecisionMode::I8Compute.budget(Some(8)).max_top1_loss
                > PrecisionMode::U8Streamed.budget(Some(8)).max_top1_loss
        );
    }

    #[test]
    fn config_names_are_stable() {
        let c = ConfigResult {
            dataset: "evalpow".into(),
            model: "gcn".into(),
            strategy: Strategy::Aes,
            width: Some(8),
            mode: PrecisionMode::U8Streamed,
            shards: 3,
            metrics: compare_logits(&[], &[], 0, 1),
            budget: budget_for(Some(8), true),
            pass: true,
            label_accuracy: 0.0,
            oracle_accuracy: 0.0,
        };
        assert_eq!(c.name(), "evalpow/gcn/aes-w8/u8-streamed/shards3");
        let exact = ConfigResult {
            model: "gat".into(),
            width: None,
            mode: PrecisionMode::F32,
            shards: 1,
            ..c
        };
        assert_eq!(exact.name(), "evalpow/gat/exact/f32/shards1");
    }

    #[test]
    fn width_grid_sizes() {
        assert_eq!(width_grid(true).len(), 2);
        assert_eq!(width_grid(false).len(), 3);
        assert!(width_grid(false).contains(&None));
    }

    #[test]
    fn model_grid_covers_the_served_zoo() {
        assert_eq!(model_grid(false), SERVED_MODELS);
        let quick = model_grid(true);
        assert_eq!(quick, ["gcn", "gat"], "quick keeps GCN plus one non-GCN model");
        assert!(quick.iter().all(|m| SERVED_MODELS.contains(m)));
    }

    #[test]
    fn report_json_has_the_gate_contract() {
        let mut report = EvalReport::default();
        report.configs.push(ConfigResult {
            dataset: "d".into(),
            model: "sage".into(),
            strategy: Strategy::Sfs,
            width: None,
            mode: PrecisionMode::F32,
            shards: 1,
            metrics: compare_logits(&[1.0, 0.0], &[1.0, 0.0], 1, 2),
            budget: Budget::bitwise(),
            pass: true,
            label_accuracy: 1.0,
            oracle_accuracy: 1.0,
        });
        report.checks.push(EvalCheck { name: "c".into(), pass: true, detail: "ok".into() });
        let text = report.to_json().to_string();
        let doc = crate::util::parse_json(&text).unwrap();
        assert!(matches!(doc.get("pass").unwrap(), JsonValue::Bool(true)));
        let configs = doc.get("configs").unwrap().as_arr().unwrap();
        assert_eq!(configs.len(), 1);
        assert_eq!(configs[0].get("name").unwrap().as_str().unwrap(), "d/sage/exact/f32/shards1");
        assert_eq!(configs[0].get("model").unwrap().as_str().unwrap(), "sage");
        assert_eq!(configs[0].get("top1_agreement").unwrap().as_f64().unwrap(), 1.0);
        assert!(report.failures().is_empty());
        // A failing config surfaces in failures() and flips pass().
        report.configs[0].pass = false;
        assert!(!report.pass());
        assert_eq!(report.failures().len(), 1);
    }

    // run_eval itself is covered end to end by tests/accuracy.rs.
}
