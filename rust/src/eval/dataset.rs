//! Seeded conformance datasets — deterministic synthetic graphs the
//! accuracy grid runs over.
//!
//! Two degree profiles, so both branches of
//! [`crate::sampling::shard_width`] get exercised: a **power-law** DC-SBM
//! (hubs overflow every grid width → skewed shards keep the full tile
//! and sample) and a **uniform** DC-SBM (rows fit modest widths →
//! uniform shards shrink to an exhaustive tile).
//!
//! The construction is deliberately *homophilous*: community labels,
//! features carrying a one-hot community signal plus small noise, and
//! weights that pass that signal through both layers. That mirrors the
//! regime the paper's accuracy claims are made in — GNN inputs where
//! neighbors agree — and gives the logits wide margins, so edge sampling
//! (a subset of mostly same-community neighbors) and INT8 rounding
//! (≤ 1/255 of the feature range) perturb predictions about as much as
//! they perturb the paper's benchmarks. Purely random features would
//! instead measure sampling noise on margin-free logits, which no
//! serving stack could keep within the paper's budgets.
//!
//! Everything is derived from fixed seeds: the same binary produces the
//! same graphs, the same plans, and therefore bit-identical logits on
//! every run and machine.

use std::path::Path;

use anyhow::Result;

use crate::gen::{self, DcSbmConfig};
use crate::quant::{quantize, QuantParams};
use crate::rng::Pcg32;
use crate::tensor::{write_nbt, NbtFile, Tensor};

/// Nodes per conformance dataset.
pub const EVAL_NODES: usize = 160;
/// Feature dimension.
pub const EVAL_FEATS: usize = 8;
/// Hidden dimension of the synthetic GCN weights.
pub const EVAL_HIDDEN: usize = 6;
/// Classes (= DC-SBM communities).
pub const EVAL_CLASSES: usize = 4;
/// Target average degree before self-loops.
pub const EVAL_AVG_DEG: f64 = 10.0;

/// Degree profile of a conformance dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DegreeProfile {
    /// Power-law expected degrees (hubs overflow the sampling widths).
    PowerLaw,
    /// Uniform expected degrees (rows fit modest tile widths).
    Uniform,
}

/// One conformance dataset: name, degree profile, generator seed.
#[derive(Clone, Copy, Debug)]
pub struct EvalDatasetSpec {
    /// Dataset name (`data_<name>.nbt` / `weights_<model>_<name>.nbt`).
    pub name: &'static str,
    /// Degree profile driving the DC-SBM generator.
    pub profile: DegreeProfile,
    /// Seed for every random draw in the dataset.
    pub seed: u64,
}

/// The fixed conformance-dataset roster.
pub const EVAL_DATASETS: [EvalDatasetSpec; 2] = [
    EvalDatasetSpec { name: "evalpow", profile: DegreeProfile::PowerLaw, seed: 0xACC_0001 },
    EvalDatasetSpec { name: "evaluni", profile: DegreeProfile::Uniform, seed: 0xACC_0002 },
];

/// Write one conformance dataset (`data_*.nbt` plus one weights file
/// per served model) under `dir`. Fully deterministic in `spec.seed`.
pub fn write_eval_dataset(dir: &Path, spec: &EvalDatasetSpec) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let (n, f, h, c) = (EVAL_NODES, EVAL_FEATS, EVAL_HIDDEN, EVAL_CLASSES);
    let mut rng = Pcg32::new(spec.seed);
    let gamma = match spec.profile {
        DegreeProfile::PowerLaw => 1.8,
        DegreeProfile::Uniform => 0.0,
    };
    let cfg = DcSbmConfig {
        n,
        avg_deg: EVAL_AVG_DEG,
        gamma,
        communities: c,
        homophily: 0.9,
    };
    let (raw, comm) = gen::dc_sbm(&cfg, &mut rng);
    let g = gen::with_self_loops(&raw).gcn_normalized();
    let nnz = g.nnz();

    // Features: strictly positive noise plus a one-hot community bump —
    // no exact zeros, so the host's zero-skipping multiply and the
    // oracle's plain multiply see identical FP sequences.
    let mut feat = vec![0.0f32; n * f];
    for (i, &label) in comm.iter().enumerate() {
        for j in 0..f {
            feat[i * f + j] = 0.02 + 0.08 * rng.f32();
        }
        feat[i * f + label as usize] += 1.0;
    }
    let params = QuantParams::of(&feat);
    let featq = quantize(&feat, params);

    let mut nbt = NbtFile::new();
    nbt.insert(
        "meta",
        Tensor::from_i64(&[4], &[n as i64, nnz as i64, f as i64, c as i64]),
    );
    nbt.insert("row_ptr", Tensor::from_i32(&[n + 1], &g.row_ptr));
    nbt.insert("col_ind", Tensor::from_i32(&[nnz], &g.col_ind));
    nbt.insert("val_gcn", Tensor::from_f32(&[nnz], &g.val));
    nbt.insert("val_ones", Tensor::from_f32(&[nnz], &vec![1.0f32; nnz]));
    nbt.insert("feat", Tensor::from_f32(&[n, f], &feat));
    nbt.insert("featq", Tensor::from_u8(&[n, f], &featq));
    nbt.insert("qrange", Tensor::from_f32(&[2], &[params.x_min, params.x_max]));
    nbt.insert("labels", Tensor::from_i32(&[n], &comm));
    nbt.insert("train_mask", Tensor::from_u8(&[n], &vec![0u8; n]));
    write_nbt(dir.join(format!("data_{}.nbt", spec.name)), &nbt)?;

    // Weights: class-preserving diagonals plus small off-diagonal noise.
    // Biases are kept strictly nonzero so no pre-ReLU value can land on
    // an exact -0.0 (the one case where the oracle's branch-ReLU and the
    // platform's maxNum could disagree on the sign of zero).
    let mut w0 = vec![0.0f32; f * h];
    for slot in w0.iter_mut() {
        *slot = 0.01 * (rng.f32() - 0.5);
    }
    for j in 0..c.min(h) {
        w0[j * h + j] += 1.0;
    }
    let b0: Vec<f32> = (0..h).map(|_| -0.04 - 0.02 * rng.f32()).collect();
    let mut w1 = vec![0.0f32; h * c];
    for slot in w1.iter_mut() {
        *slot = 0.01 * (rng.f32() - 0.5);
    }
    for j in 0..c.min(h) {
        w1[j * c + j] += 1.0;
    }
    let b1: Vec<f32> = (0..c).map(|_| 0.005 * (rng.f32() - 0.5)).collect();

    let mut w = NbtFile::new();
    w.insert("w0", Tensor::from_f32(&[f, h], &w0));
    w.insert("b0", Tensor::from_f32(&[h], &b0));
    w.insert("w1", Tensor::from_f32(&[h, c], &w1));
    w.insert("b1", Tensor::from_f32(&[c], &b1));
    w.insert("ideal_acc", Tensor::from_f32(&[1], &[1.0]));
    write_nbt(dir.join(format!("weights_gcn_{}.nbt", spec.name)), &w)?;

    // Model-zoo weights. These draw from *fresh* seeded streams (never
    // the stream above), so adding a model can never perturb the bytes
    // of `data_*.nbt` / `weights_gcn_*.nbt` — the golden GCN fixtures in
    // tests/fixtures/ stay valid verbatim.
    write_sage_weights(dir, spec, &mut Pcg32::new(spec.seed ^ 0x5A6E_0000))?;
    write_gat_weights(dir, spec, &mut Pcg32::new(spec.seed ^ 0x6A70_0000))?;
    Ok(())
}

/// `[rows, cols]` class-preserving map: `scale` on the leading diagonal
/// plus ±0.005 noise — the same margin-friendly shape as the GCN
/// weights, so sampled/quantized runs stay inside the paper budgets.
fn diag_noise(rng: &mut Pcg32, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
    let mut w = vec![0.0f32; rows * cols];
    for slot in w.iter_mut() {
        *slot = 0.01 * (rng.f32() - 0.5);
    }
    for j in 0..rows.min(cols).min(EVAL_CLASSES) {
        w[j * cols + j] += scale;
    }
    w
}

/// GraphSAGE-mean weights: the self branch carries the node's own
/// community signal at full strength, the neighbor branch reinforces it
/// at half strength (homophilous neighbors agree, so the mean over any
/// sampled subset points the same way — which is what keeps the sampled
/// top-1 loss inside [`super::SAMPLING_TOP1_LOSS`]).
fn write_sage_weights(dir: &Path, spec: &EvalDatasetSpec, rng: &mut Pcg32) -> Result<()> {
    let (f, h, c) = (EVAL_FEATS, EVAL_HIDDEN, EVAL_CLASSES);
    let mut w = NbtFile::new();
    w.insert("w0_self", Tensor::from_f32(&[f, h], &diag_noise(rng, f, h, 1.0)));
    w.insert("w0_neigh", Tensor::from_f32(&[f, h], &diag_noise(rng, f, h, 0.5)));
    let b0: Vec<f32> = (0..h).map(|_| -0.04 - 0.02 * rng.f32()).collect();
    w.insert("b0", Tensor::from_f32(&[h], &b0));
    w.insert("w1_self", Tensor::from_f32(&[h, c], &diag_noise(rng, h, c, 1.0)));
    w.insert("w1_neigh", Tensor::from_f32(&[h, c], &diag_noise(rng, h, c, 0.5)));
    let b1: Vec<f32> = (0..c).map(|_| 0.005 * (rng.f32() - 0.5)).collect();
    w.insert("b1", Tensor::from_f32(&[c], &b1));
    w.insert("ideal_acc", Tensor::from_f32(&[1], &[1.0]));
    write_nbt(dir.join(format!("weights_sage_{}.nbt", spec.name)), &w)?;
    Ok(())
}

/// GAT weights: GCN-shaped projections, attention vectors of *tiny*
/// magnitude (±0.02) — logits near zero make α near-uniform, so dropping
/// sampled edges renormalizes to nearly the same convex combination and
/// accuracy degrades smoothly rather than hinging on one hot edge.
fn write_gat_weights(dir: &Path, spec: &EvalDatasetSpec, rng: &mut Pcg32) -> Result<()> {
    let (f, h, c) = (EVAL_FEATS, EVAL_HIDDEN, EVAL_CLASSES);
    let att = |rng: &mut Pcg32, d: usize| -> Vec<f32> {
        (0..d).map(|_| 0.04 * (rng.f32() - 0.5)).collect()
    };
    let mut w = NbtFile::new();
    w.insert("w0", Tensor::from_f32(&[f, h], &diag_noise(rng, f, h, 1.0)));
    w.insert("a0_src", Tensor::from_f32(&[h], &att(rng, h)));
    w.insert("a0_dst", Tensor::from_f32(&[h], &att(rng, h)));
    let b0: Vec<f32> = (0..h).map(|_| -0.04 - 0.02 * rng.f32()).collect();
    w.insert("b0", Tensor::from_f32(&[h], &b0));
    w.insert("w1", Tensor::from_f32(&[h, c], &diag_noise(rng, h, c, 1.0)));
    w.insert("a1_src", Tensor::from_f32(&[c], &att(rng, c)));
    w.insert("a1_dst", Tensor::from_f32(&[c], &att(rng, c)));
    let b1: Vec<f32> = (0..c).map(|_| 0.005 * (rng.f32() - 0.5)).collect();
    w.insert("b1", Tensor::from_f32(&[c], &b1));
    w.insert("ideal_acc", Tensor::from_f32(&[1], &[1.0]));
    write_nbt(dir.join(format!("weights_gat_{}.nbt", spec.name)), &w)?;
    Ok(())
}

/// Write every conformance dataset under `dir`; returns their names.
pub fn write_eval_datasets(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::with_capacity(EVAL_DATASETS.len());
    for spec in &EVAL_DATASETS {
        write_eval_dataset(dir, spec)?;
        names.push(spec.name.to_string());
    }
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Dataset, Weights};

    fn tmp(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("eval_ds_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn datasets_load_and_are_deterministic() {
        let dir = tmp("det");
        let names = write_eval_datasets(&dir).unwrap();
        assert_eq!(names, ["evalpow", "evaluni"]);
        let a = Dataset::load(&dir, "evalpow").unwrap();
        // Rewriting produces byte-identical data.
        write_eval_datasets(&dir).unwrap();
        let b = Dataset::load(&dir, "evalpow").unwrap();
        assert_eq!(a.csr_gcn, b.csr_gcn);
        assert_eq!(a.feat.as_f32().unwrap(), b.feat.as_f32().unwrap());
        assert_eq!(a.labels, b.labels);
        let w = Weights::load(&dir, "gcn", "evalpow").unwrap();
        assert_eq!(w.tensors.len(), 4);
        // The whole served zoo loads and passes schema validation.
        for model in crate::runtime::SERVED_MODELS {
            let w = Weights::load(&dir, model, "evaluni").unwrap();
            crate::runtime::validate_weights(model, EVAL_FEATS, EVAL_CLASSES, &w.tensors)
                .unwrap();
        }
    }

    #[test]
    fn profiles_differ_in_skew() {
        let dir = tmp("skew");
        write_eval_datasets(&dir).unwrap();
        let pow = Dataset::load(&dir, "evalpow").unwrap();
        let uni = Dataset::load(&dir, "evaluni").unwrap();
        assert_eq!(pow.n, EVAL_NODES);
        // The power-law profile's hubs tower over the uniform profile's
        // longest row, and both overflow the aggressive grid width (8).
        assert!(pow.csr_gcn.max_degree() > uni.csr_gcn.max_degree());
        assert!(pow.csr_gcn.max_degree() > 8);
        assert!(uni.csr_gcn.max_degree() > 8);
        // No exact zeros in features (the zero-skip FP argument).
        assert!(pow.feat.as_f32().unwrap().iter().all(|&x| x > 0.0));
    }
}
