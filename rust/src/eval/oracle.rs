//! The exact oracle — ground truth for every serving configuration.
//!
//! `oracle_forward` runs the unsampled fp32 GCN forward with one
//! **canonical reduction order**, fixed here and nowhere else:
//!
//! * dense multiplies accumulate each output element over `k` ascending;
//! * aggregations accumulate each output row over its CSR edges in
//!   storage order;
//! * everything is serial — no dispatch, no pool, no chunking — so the
//!   oracle cannot drift when the execution layer changes.
//!
//! The host substrate's exact fp32 forward is *engineered* to match this
//! order bit-for-bit (per-row FP order is preserved by every exact
//! kernel, thread partitioning, and shard cut — see `docs/sharding.md`),
//! and `tests/accuracy.rs` checks that equality through the coordinator.
//! The golden fixtures under `tests/fixtures/` pin the oracle itself
//! against drift (`tests/oracle_regression.rs`).
//!
//! ReLU is written as `if v > 0.0 { v } else { 0.0 }` rather than
//! `f32::max`, so a `-0.0` or NaN pre-activation normalizes to `+0.0`
//! deterministically regardless of how the platform's `maxNum` breaks
//! the `±0.0` tie.

use anyhow::{bail, Result};

use crate::graph::Csr;
use crate::runtime::{Dataset, Weights};

/// Canonical dense multiply: row-major `A[m,k] × B[k,n]`, each output
/// element accumulated strictly over `k` ascending, serially.
pub fn oracle_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A is not [m, k]");
    assert_eq!(b.len(), k * n, "B is not [k, n]");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &x) in out[i * n..(i + 1) * n].iter_mut().zip(brow.iter()) {
                *o += av * x;
            }
        }
    }
    out
}

/// Canonical exact aggregation: `out[i, :] += val[e] · B[col[e], :]` for
/// each edge `e` of row `i` in CSR storage order, rows serially. `out`
/// must be `n_rows × f` and is cleared first.
pub fn oracle_aggregate(csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f, "B is not [n_cols, f]");
    assert_eq!(out.len(), csr.n_rows * f, "out is not [n_rows, f]");
    out.fill(0.0);
    for i in 0..csr.n_rows {
        let row_out = &mut out[i * f..(i + 1) * f];
        for e in csr.row_range(i) {
            let v = csr.val[e];
            let col = csr.col_ind[e] as usize;
            let brow = &b[col * f..col * f + f];
            for (o, &x) in row_out.iter_mut().zip(brow.iter()) {
                *o += v * x;
            }
        }
    }
}

/// The exact oracle forward:
/// `logits = Â(relu(Â(X W₀) + b₀) W₁) + b₁` with `Â = ds.csr_gcn`,
/// fp32 features, no sampling, no quantization, canonical reduction
/// order throughout. Returns row-major `[n, classes]` logits.
pub fn oracle_forward(ds: &Dataset, weights: &Weights) -> Result<Vec<f32>> {
    if weights.model != "gcn" {
        bail!("the oracle implements the gcn forward only (got {:?})", weights.model);
    }
    let x = ds.feat.as_f32()?;
    if x.len() != ds.n * ds.feats {
        bail!("feature tensor has {} values, dataset needs {}", x.len(), ds.n * ds.feats);
    }
    // Weights in GCN_PARAM_ORDER: w0 [f,h], b0 [h], w1 [h,c], b1 [c].
    let w0 = weights.tensors[0].1.as_f32()?;
    let b0 = weights.tensors[1].1.as_f32()?;
    let w1 = weights.tensors[2].1.as_f32()?;
    let b1 = weights.tensors[3].1.as_f32()?;
    let (n, f, h, c) = (ds.n, ds.feats, b0.len(), ds.classes);
    if w0.len() != f * h || w1.len() != h * c || b1.len() != c {
        bail!("weight shapes inconsistent with dataset dims (f={f}, h={h}, c={c})");
    }

    // Layer 1: relu(Â (X W0) + b0).
    let xw = oracle_matmul(x, w0, n, f, h);
    let mut hidden = vec![0.0f32; n * h];
    oracle_aggregate(&ds.csr_gcn, &xw, h, &mut hidden);
    for i in 0..n {
        for j in 0..h {
            let v = hidden[i * h + j] + b0[j];
            hidden[i * h + j] = if v > 0.0 { v } else { 0.0 };
        }
    }

    // Layer 2: Â (H W1) + b1.
    let hw = oracle_matmul(&hidden, w1, n, h, c);
    let mut logits = vec![0.0f32; n * c];
    oracle_aggregate(&ds.csr_gcn, &hw, c, &mut logits);
    for i in 0..n {
        for j in 0..c {
            logits[i * c + j] += b1[j];
        }
    }
    Ok(logits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecEnv;
    use crate::gen;
    use crate::quant::{quantize, QuantParams};
    use crate::rng::Pcg32;
    use crate::runtime::host_forward;
    use crate::sampling::Strategy;
    use crate::tensor::Tensor;

    #[test]
    fn oracle_matmul_known_values() {
        // [1 2; 3 4] × [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        assert_eq!(oracle_matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
        // Zero-row multiply: shapes must still agree, output is empty.
        assert!(oracle_matmul(&[], &[0.0f32; 9], 0, 3, 3).is_empty());
    }

    #[test]
    fn oracle_aggregate_is_bitwise_csr_naive() {
        let mut rng = Pcg32::new(91);
        let mut g = gen::chung_lu(220, 14.0, 1.9, &mut rng);
        for v in g.val.iter_mut() {
            *v = rng.f32() - 0.5;
        }
        let f = 7;
        let b: Vec<f32> = (0..g.n_cols * f).map(|_| rng.f32() - 0.5).collect();
        let mut want = vec![0.0f32; g.n_rows * f];
        crate::spmm::csr_naive(&g, &b, f, &mut want);
        let mut got = vec![7.0f32; g.n_rows * f]; // dirty: must be cleared
        oracle_aggregate(&g, &b, f, &mut got);
        assert_eq!(want, got, "the canonical order IS csr_naive's order");
    }

    /// Build an in-memory synthetic dataset + weights (no files).
    fn synthetic(seed: u64, n: usize, f: usize, h: usize, c: usize) -> (Dataset, Weights) {
        let mut rng = Pcg32::new(seed);
        let g = gen::with_self_loops(&gen::chung_lu(n, 6.0, 2.0, &mut rng)).gcn_normalized();
        let nnz = g.nnz();
        let feat: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let params = QuantParams::of(&feat);
        let featq = quantize(&feat, params);
        let ds = Dataset {
            name: "synth".to_string(),
            n,
            nnz,
            feats: f,
            classes: c,
            epoch: 0,
            val_ones: vec![1.0; nnz],
            csr_gcn: g,
            feat: Tensor::from_f32(&[n, f], &feat),
            featq: Tensor::from_u8(&[n, f], &featq),
            qparams: params,
            labels: (0..n).map(|_| rng.usize_below(c) as i32).collect(),
            train_mask: vec![0; n],
        };
        let t = |shape: &[usize], rng: &mut Pcg32| {
            let len: usize = shape.iter().product();
            let vals: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
            Tensor::from_f32(shape, &vals)
        };
        let weights = Weights {
            model: "gcn".into(),
            tensors: vec![
                ("w0".into(), t(&[f, h], &mut rng)),
                ("b0".into(), t(&[h], &mut rng)),
                ("w1".into(), t(&[h, c], &mut rng)),
                ("b1".into(), t(&[c], &mut rng)),
            ],
            ideal_acc: 0.5,
        };
        (ds, weights)
    }

    #[test]
    fn oracle_is_deterministic() {
        let (ds, w) = synthetic(7, 90, 6, 5, 4);
        let a = oracle_forward(&ds, &w).unwrap();
        let b = oracle_forward(&ds, &w).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 90 * 4);
    }

    #[test]
    fn host_exact_fp32_forward_is_bitwise_equal_to_the_oracle() {
        // The dispatch/threading-independence claim: whatever exact
        // kernel and thread count the host substrate picks, per-row FP
        // order equals the canonical order.
        let (ds, w) = synthetic(13, 120, 9, 7, 5);
        let want = oracle_forward(&ds, &w).unwrap();
        let req = crate::runtime::ForwardRequest {
            model: "gcn".into(),
            dataset: ds.name.clone(),
            width: None,
            strategy: Strategy::Aes,
            precision: crate::quant::Precision::F32,
        };
        for threads in [1usize, 4] {
            let env = ExecEnv::with_threads(threads);
            let got = host_forward(&ds, &w, &req, None, None, &env).unwrap();
            let got = got.logits.as_f32().unwrap();
            assert_eq!(got.len(), want.len());
            for (i, (g, o)) in got.iter().zip(want.iter()).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    o.to_bits(),
                    "logit {i} differs from the oracle at {threads} threads ({g} vs {o})"
                );
            }
        }
    }

    #[test]
    fn oracle_rejects_non_gcn_models() {
        let (ds, mut w) = synthetic(3, 20, 4, 3, 2);
        w.model = "sage".into();
        assert!(oracle_forward(&ds, &w).is_err());
    }
}
