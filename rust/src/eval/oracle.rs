//! The exact oracle — ground truth for every serving configuration.
//!
//! `oracle_forward` interprets the model's layer-graph IR
//! ([`crate::runtime::ir`]) with the unsampled fp32 operand and one
//! **canonical reduction order**, fixed here and nowhere else:
//!
//! * dense multiplies accumulate each output element over `k` ascending;
//! * aggregations accumulate each output row over its CSR edges in
//!   storage order (sum, max-select, and the GAT α passes alike);
//! * everything is serial — no dispatch, no pool, no chunking — so the
//!   oracle cannot drift when the execution layer changes.
//!
//! The host substrate's exact fp32 forward is *engineered* to match this
//! order bit-for-bit for every model (per-row FP order is preserved by
//! every exact kernel, thread partitioning, and shard cut — see
//! `docs/sharding.md` and `docs/models.md`), and `tests/accuracy.rs`
//! checks that equality through the coordinator. The golden fixtures
//! under `tests/fixtures/` pin the oracle itself against drift
//! (`tests/oracle_regression.rs`).
//!
//! ReLU is written as `if v > 0.0 { v } else { 0.0 }` rather than
//! `f32::max`, so a `-0.0` or NaN pre-activation normalizes to `+0.0`
//! deterministically regardless of how the platform's `maxNum` breaks
//! the `±0.0` tie. The GAT softmax is spelled out inline — scalar max
//! fold, scalar `exp`, storage-order sum, per-edge divide — as an
//! independent cross-check of `spmm::segmented`'s arms, not a call into
//! them.

use anyhow::{anyhow, bail, Result};

use crate::graph::Csr;
use crate::runtime::ir::{model_ir, validate_weights, AggregateKind, LayerOp};
use crate::runtime::{Dataset, Weights};
use crate::spmm::{attention_scores, leaky_relu};
use crate::tensor::Tensor;

/// Canonical dense multiply: row-major `A[m,k] × B[k,n]`, each output
/// element accumulated strictly over `k` ascending, serially.
pub fn oracle_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A is not [m, k]");
    assert_eq!(b.len(), k * n, "B is not [k, n]");
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &x) in out[i * n..(i + 1) * n].iter_mut().zip(brow.iter()) {
                *o += av * x;
            }
        }
    }
    out
}

/// Canonical exact aggregation: `out[i, :] += val[e] · B[col[e], :]` for
/// each edge `e` of row `i` in CSR storage order, rows serially. `out`
/// must be `n_rows × f` and is cleared first.
pub fn oracle_aggregate(csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f, "B is not [n_cols, f]");
    assert_eq!(out.len(), csr.n_rows * f, "out is not [n_rows, f]");
    out.fill(0.0);
    for i in 0..csr.n_rows {
        let row_out = &mut out[i * f..(i + 1) * f];
        for e in csr.row_range(i) {
            let v = csr.val[e];
            let col = csr.col_ind[e] as usize;
            let brow = &b[col * f..col * f + f];
            for (o, &x) in row_out.iter_mut().zip(brow.iter()) {
                *o += v * x;
            }
        }
    }
}

/// Canonical max-pool aggregation (GraphSAGE max): start from the first
/// neighbor's features and select `if x > acc { x }` edge by edge in
/// storage order — `0.0` for edgeless rows, and all-negative features
/// pool to their (negative) max. Values are ignored.
pub fn oracle_max_aggregate(csr: &Csr, b: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(b.len(), csr.n_cols * f, "B is not [n_cols, f]");
    assert_eq!(out.len(), csr.n_rows * f, "out is not [n_rows, f]");
    for i in 0..csr.n_rows {
        let row_out = &mut out[i * f..(i + 1) * f];
        let mut edges = csr.row_range(i);
        let Some(e0) = edges.next() else {
            row_out.fill(0.0);
            continue;
        };
        let c0 = csr.col_ind[e0] as usize;
        row_out.copy_from_slice(&b[c0 * f..c0 * f + f]);
        for e in edges {
            let col = csr.col_ind[e] as usize;
            let brow = &b[col * f..col * f + f];
            for (o, &x) in row_out.iter_mut().zip(brow.iter()) {
                if x > *o {
                    *o = x;
                }
            }
        }
    }
}

/// Canonical GAT attention coefficients: per-edge
/// `LeakyReLU(s_src[i] + s_dst[col])` logits in storage order, then the
/// numerically-stable row softmax spelled out scalar — max fold, `exp`,
/// storage-order denominator, per-edge divide. Single-edge rows get
/// exactly `1.0` (`exp(0)/exp(0)`); empty rows contribute no entries.
pub fn oracle_gat_alpha(csr: &Csr, s_src: &[f32], s_dst: &[f32]) -> Vec<f32> {
    assert_eq!(s_src.len(), csr.n_rows, "s_src is not [n_rows]");
    assert_eq!(s_dst.len(), csr.n_cols, "s_dst is not [n_cols]");
    let mut alpha = vec![0.0f32; csr.val.len()];
    for i in 0..csr.n_rows {
        let lo = csr.row_ptr[i] as usize;
        let hi = csr.row_ptr[i + 1] as usize;
        if lo == hi {
            continue;
        }
        let seg = &mut alpha[lo..hi];
        for (a, e) in seg.iter_mut().zip(lo..hi) {
            *a = leaky_relu(s_src[i] + s_dst[csr.col_ind[e] as usize]);
        }
        let mut m = f32::NEG_INFINITY;
        for &e in seg.iter() {
            if e > m {
                m = e;
            }
        }
        let mut denom = 0.0f32;
        for e in seg.iter_mut() {
            *e = (*e - m).exp();
            denom += *e;
        }
        for e in seg.iter_mut() {
            *e /= denom;
        }
    }
    alpha
}

/// The exact oracle forward: interpret `weights.model`'s IR program with
/// the unsampled operand, fp32 features, no quantization, canonical
/// reduction order throughout. For `gcn` this is
/// `logits = Â(relu(Â(X W₀) + b₀) W₁) + b₁` with `Â = ds.csr_gcn` —
/// exactly the pre-IR oracle, op for op. Returns row-major
/// `[n, classes]` logits.
pub fn oracle_forward(ds: &Dataset, weights: &Weights) -> Result<Vec<f32>> {
    let ops = model_ir(&weights.model)?;
    validate_weights(&weights.model, ds.feats, ds.classes, &weights.tensors)?;
    let x = ds.feat.as_f32()?;
    if x.len() != ds.n * ds.feats {
        bail!("feature tensor has {} values, dataset needs {}", x.len(), ds.n * ds.feats);
    }
    let tensor = |name: &str| -> Result<&Tensor> {
        weights
            .tensors
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, t)| t)
            .ok_or_else(|| anyhow!("missing weight tensor {name:?} for model {:?}", weights.model))
    };
    let needs_ones = ops
        .iter()
        .any(|op| matches!(op, LayerOp::Aggregate { kind: AggregateKind::SageMean }));
    let ones_csr =
        needs_ones.then(|| Csr { val: ds.val_ones.clone(), ..ds.csr_gcn.clone() });
    let n = ds.n;

    let mut cur: (Vec<f32>, usize) = (x.to_vec(), ds.feats);
    let mut saved: Option<(Vec<f32>, usize)> = None;
    for op in &ops {
        match op {
            LayerOp::Save => saved = Some(cur.clone()),
            LayerOp::Swap => {
                let Some(s) = saved.take() else {
                    bail!("model {:?}: Swap with empty saved register", weights.model);
                };
                saved = Some(std::mem::replace(&mut cur, s));
            }
            LayerOp::Add => {
                let Some((sdata, sdim)) = &saved else {
                    bail!("model {:?}: Add with empty saved register", weights.model);
                };
                if *sdim != cur.1 {
                    bail!(
                        "model {:?}: Add joins dim {} with saved dim {sdim}",
                        weights.model,
                        cur.1
                    );
                }
                for (o, &v) in cur.0.iter_mut().zip(sdata.iter()) {
                    *o += v;
                }
            }
            LayerOp::Concat => {
                let Some((sdata, sdim)) = saved.take() else {
                    bail!("model {:?}: Concat with empty saved register", weights.model);
                };
                let (cdata, cdim) = std::mem::replace(&mut cur, (Vec::new(), 0));
                let dim = sdim + cdim;
                let mut joined = vec![0.0f32; n * dim];
                for i in 0..n {
                    joined[i * dim..i * dim + sdim]
                        .copy_from_slice(&sdata[i * sdim..(i + 1) * sdim]);
                    joined[i * dim + sdim..(i + 1) * dim]
                        .copy_from_slice(&cdata[i * cdim..(i + 1) * cdim]);
                }
                cur = (joined, dim);
            }
            LayerOp::Linear { weight } => {
                let wt = tensor(weight)?;
                let w = wt.as_f32()?;
                let (k, d_out) = (wt.shape[0], wt.shape[1]);
                cur = (oracle_matmul(&cur.0, w, n, k, d_out), d_out);
            }
            LayerOp::Aggregate { kind } => {
                let (h, dim) = &cur;
                let f = *dim;
                let mut out = vec![0.0f32; n * f];
                match kind {
                    AggregateKind::Gcn => oracle_aggregate(&ds.csr_gcn, h, f, &mut out),
                    AggregateKind::SageMean => {
                        let ones = ones_csr.as_ref().expect("needs_ones covers SageMean");
                        oracle_aggregate(ones, h, f, &mut out);
                        for i in 0..n {
                            let d = ds.csr_gcn.row_nnz(i).max(1) as f32;
                            for o in out[i * f..(i + 1) * f].iter_mut() {
                                *o /= d;
                            }
                        }
                    }
                    AggregateKind::SageMax => {
                        oracle_max_aggregate(&ds.csr_gcn, h, f, &mut out)
                    }
                    AggregateKind::GatAttention { att_src, att_dst } => {
                        if ds.csr_gcn.n_cols != n {
                            bail!("GAT needs a square adjacency (self-attention over nodes)");
                        }
                        let a_src = tensor(att_src)?.as_f32()?;
                        let a_dst = tensor(att_dst)?.as_f32()?;
                        let s_src = attention_scores(h, a_src, n, f);
                        let s_dst = attention_scores(h, a_dst, n, f);
                        let alpha = oracle_gat_alpha(&ds.csr_gcn, &s_src, &s_dst);
                        let ac = Csr {
                            n_rows: ds.csr_gcn.n_rows,
                            n_cols: ds.csr_gcn.n_cols,
                            row_ptr: ds.csr_gcn.row_ptr.clone(),
                            col_ind: ds.csr_gcn.col_ind.clone(),
                            val: alpha,
                        };
                        oracle_aggregate(&ac, h, f, &mut out);
                    }
                }
                cur = (out, f);
            }
            LayerOp::Bias { name } => {
                let b = tensor(name)?.as_f32()?;
                let dim = cur.1;
                for i in 0..n {
                    for j in 0..dim {
                        cur.0[i * dim + j] += b[j];
                    }
                }
            }
            LayerOp::Relu => {
                for v in cur.0.iter_mut() {
                    *v = if *v > 0.0 { *v } else { 0.0 };
                }
            }
        }
    }
    if cur.1 != ds.classes {
        bail!(
            "model {:?}: program emitted dim {}, dataset has {} classes",
            weights.model,
            cur.1,
            ds.classes
        );
    }
    Ok(cur.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecEnv;
    use crate::gen;
    use crate::quant::{quantize, QuantParams};
    use crate::rng::Pcg32;
    use crate::runtime::{host_forward, KNOWN_MODELS};
    use crate::sampling::Strategy;
    use crate::tensor::Tensor;

    #[test]
    fn oracle_matmul_known_values() {
        // [1 2; 3 4] × [5 6; 7 8] = [19 22; 43 50]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        assert_eq!(oracle_matmul(&a, &b, 2, 2, 2), vec![19.0, 22.0, 43.0, 50.0]);
        // Zero-row multiply: shapes must still agree, output is empty.
        assert!(oracle_matmul(&[], &[0.0f32; 9], 0, 3, 3).is_empty());
    }

    #[test]
    fn oracle_aggregate_is_bitwise_csr_naive() {
        let mut rng = Pcg32::new(91);
        let mut g = gen::chung_lu(220, 14.0, 1.9, &mut rng);
        for v in g.val.iter_mut() {
            *v = rng.f32() - 0.5;
        }
        let f = 7;
        let b: Vec<f32> = (0..g.n_cols * f).map(|_| rng.f32() - 0.5).collect();
        let mut want = vec![0.0f32; g.n_rows * f];
        crate::spmm::csr_naive(&g, &b, f, &mut want);
        let mut got = vec![7.0f32; g.n_rows * f]; // dirty: must be cleared
        oracle_aggregate(&g, &b, f, &mut got);
        assert_eq!(want, got, "the canonical order IS csr_naive's order");
    }

    /// Build an in-memory synthetic dataset (no files).
    fn synthetic_dataset(rng: &mut Pcg32, n: usize, f: usize, c: usize) -> Dataset {
        let g = gen::with_self_loops(&gen::chung_lu(n, 6.0, 2.0, rng)).gcn_normalized();
        let nnz = g.nnz();
        let feat: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let params = QuantParams::of(&feat);
        let featq = quantize(&feat, params);
        Dataset {
            name: "synth".to_string(),
            n,
            nnz,
            feats: f,
            classes: c,
            epoch: 0,
            val_ones: vec![1.0; nnz],
            csr_gcn: g,
            feat: Tensor::from_f32(&[n, f], &feat),
            featq: Tensor::from_u8(&[n, f], &featq),
            qparams: params,
            labels: (0..n).map(|_| rng.usize_below(c) as i32).collect(),
            train_mask: vec![0; n],
        }
    }

    /// Random weights matching `model`'s artifact signature.
    fn synthetic_weights(rng: &mut Pcg32, model: &str, f: usize, h: usize, c: usize) -> Weights {
        let t = |shape: &[usize], rng: &mut Pcg32| {
            let len: usize = shape.iter().product();
            let vals: Vec<f32> = (0..len).map(|_| rng.f32() - 0.5).collect();
            Tensor::from_f32(shape, &vals)
        };
        let shape = |name: &str| -> Vec<usize> {
            match name {
                "w0" | "w0_self" | "w0_neigh" => vec![f, h],
                "w1" | "w1_self" | "w1_neigh" => vec![h, c],
                "b0" | "a0_src" | "a0_dst" => vec![h],
                "b1" | "a1_src" | "a1_dst" => vec![c],
                other => panic!("unknown tensor {other}"),
            }
        };
        let tensors = crate::runtime::param_order(model)
            .unwrap()
            .iter()
            .map(|&name| (name.to_string(), t(&shape(name), rng)))
            .collect();
        Weights { model: model.to_string(), tensors, ideal_acc: 0.5 }
    }

    /// Build an in-memory synthetic dataset + GCN weights (no files).
    fn synthetic(seed: u64, n: usize, f: usize, h: usize, c: usize) -> (Dataset, Weights) {
        let mut rng = Pcg32::new(seed);
        let ds = synthetic_dataset(&mut rng, n, f, c);
        let weights = synthetic_weights(&mut rng, "gcn", f, h, c);
        (ds, weights)
    }

    #[test]
    fn oracle_is_deterministic() {
        let (ds, w) = synthetic(7, 90, 6, 5, 4);
        let a = oracle_forward(&ds, &w).unwrap();
        let b = oracle_forward(&ds, &w).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 90 * 4);
    }

    #[test]
    fn host_exact_fp32_forward_is_bitwise_equal_to_the_oracle() {
        // The dispatch/threading-independence claim, for every model the
        // IR can express: whatever exact kernel and thread count the
        // host substrate picks, per-row FP order equals the canonical
        // order.
        let mut rng = Pcg32::new(13);
        let ds = synthetic_dataset(&mut rng, 120, 9, 5);
        for &model in KNOWN_MODELS {
            let w = synthetic_weights(&mut rng, model, 9, 7, 5);
            let want = oracle_forward(&ds, &w).unwrap();
            let req = crate::runtime::ForwardRequest {
                model: model.into(),
                dataset: ds.name.clone(),
                width: None,
                strategy: Strategy::Aes,
                precision: crate::quant::Precision::F32,
            };
            for threads in [1usize, 4] {
                let env = ExecEnv::with_threads(threads);
                let got = host_forward(&ds, &w, &req, None, None, &env).unwrap();
                let got = got.logits.as_f32().unwrap();
                assert_eq!(got.len(), want.len());
                for (i, (g, o)) in got.iter().zip(want.iter()).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        o.to_bits(),
                        "{model}: logit {i} differs from the oracle at {threads} threads \
                         ({g} vs {o})"
                    );
                }
            }
        }
    }

    #[test]
    fn sage_mean_divides_by_the_full_degree_on_the_exact_route() {
        // One isolated row (self-loop only) and one busy row: the mean
        // divisor is row_nnz on the exact route, and the all-ones
        // operand (not Â) feeds the numerator.
        let mut rng = Pcg32::new(29);
        let ds = synthetic_dataset(&mut rng, 40, 4, 3);
        let w = synthetic_weights(&mut rng, "sage", 4, 5, 3);
        let logits = oracle_forward(&ds, &w).unwrap();
        assert_eq!(logits.len(), 40 * 3);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn oracle_rejects_unknown_models() {
        let (ds, mut w) = synthetic(3, 20, 4, 3, 2);
        w.model = "mlp".into();
        assert!(oracle_forward(&ds, &w).is_err());
        // A known model whose weights don't match its schema is rejected
        // by shape validation, not a panic inside matmul.
        let (ds, mut w) = synthetic(4, 20, 4, 3, 2);
        w.model = "sage".into(); // gcn-shaped tensors under a sage name
        assert!(oracle_forward(&ds, &w).is_err());
    }
}
