//! The accuracy-budget table — the paper's claims, encoded as checkable
//! per-configuration thresholds.
//!
//! | claim | source | budget here |
//! |-------|--------|-------------|
//! | adaptive sampling loses < 1% top-1 accuracy | Tables 4–5 | sampled routes: ≤ 1% of rows may flip vs the oracle |
//! | INT8 quantization adds ≤ 0.3% on top | Table 6 | quantized routes: 0.3% added to the route's sampling budget, and ≤ 0.3% of rows may flip vs the fp32 sibling |
//! | sharding changes nothing | docs/sharding.md | bitwise equality — the PR 3 guarantee as a checked invariant |
//! | streamed INT8 ≡ eager INT8 | docs/nbt-format.md | bitwise equality |
//! | exact fp32 ≡ oracle | eval::oracle | bitwise equality (dispatch/threading independence) |
//!
//! The seeded conformance datasets are small (a few hundred rows), so
//! each fractional budget carries a small absolute `slack_rows`
//! allowance: one flipped row on 160 nodes is already 0.6%, which would
//! make the paper's percentage thresholds quantization noise at this
//! scale. The fractions are the contract; the slack only de-flakes the
//! small-sample regime (see docs/accuracy.md).
//!
//! The budgets are **model-independent**: every served model (GCN,
//! GraphSAGE-mean, GAT — `docs/models.md`) is held to the same rows of
//! this table. The exact fp32 row in particular means each model's IR
//! program through the serving stack must be bitwise-equal to its own
//! oracle, and GAT's sampled routes must renormalize attention over the
//! surviving edges well enough to stay inside the sampling row.

use super::metrics::AccuracyMetrics;

/// Sampled routes may lose at most this top-1 fraction vs the oracle
/// (paper Tables 4–5: < 1% accuracy loss).
pub const SAMPLING_TOP1_LOSS: f64 = 0.01;

/// INT8 quantization may add at most this top-1 fraction on top of the
/// route's sampling budget (paper Table 6: ≤ 0.3% extra).
pub const QUANT_EXTRA_TOP1_LOSS: f64 = 0.003;

/// True INT8 *compute* (integer-accumulating SpMM over a requantized
/// adjacency — `crate::spmm::ell_spmm_i8`) may add at most this top-1
/// fraction on top of the INT8-dequant route: the edge-coefficient
/// requant is a second Eq. 1-style rounding, held to the same ≤ 0.3%
/// increment Table 6 allows the first.
pub const I8_COMPUTE_EXTRA_TOP1_LOSS: f64 = 0.003;

/// One configuration's accuracy budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Budget {
    /// Max fraction of rows whose top-1 class may disagree.
    pub max_top1_loss: f64,
    /// Absolute extra disagreeing rows tolerated on the small seeded
    /// datasets (0 for bitwise budgets).
    pub slack_rows: usize,
    /// Bit-for-bit equality required (`max_top1_loss`/`slack_rows` are
    /// then irrelevant).
    pub bitwise: bool,
}

impl Budget {
    /// The zero-tolerance budget: every logit bit must match.
    pub fn bitwise() -> Budget {
        Budget { max_top1_loss: 0.0, slack_rows: 0, bitwise: true }
    }

    /// How many disagreeing rows this budget admits over `rows`.
    pub fn allowed_disagreements(&self, rows: usize) -> usize {
        if self.bitwise {
            0
        } else {
            (self.max_top1_loss * rows as f64).ceil() as usize + self.slack_rows
        }
    }

    /// Whether the measured metrics sit inside this budget.
    pub fn admits(&self, m: &AccuracyMetrics) -> bool {
        if self.bitwise {
            m.bitwise_equal
        } else {
            m.disagreeing <= self.allowed_disagreements(m.rows)
        }
    }

    /// Human-readable budget label for reports and failure messages.
    pub fn label(&self) -> String {
        if self.bitwise {
            "bitwise".to_string()
        } else {
            format!(
                "top-1 loss <= {:.1}% (+{} row slack)",
                self.max_top1_loss * 100.0,
                self.slack_rows
            )
        }
    }
}

/// The per-configuration budget vs the **oracle**, keyed by what the
/// route does to the numbers: `width` (`None` = exact aggregation) and
/// whether features are INT8-quantized.
pub fn budget_for(width: Option<usize>, quantized: bool) -> Budget {
    match (width, quantized) {
        // Exact fp32 is the oracle's own computation routed through the
        // serving stack — any bit of drift is a dispatch/threading bug.
        (None, false) => Budget::bitwise(),
        // Exact INT8: quantization is the only error source.
        (None, true) => {
            Budget { max_top1_loss: QUANT_EXTRA_TOP1_LOSS, slack_rows: 1, bitwise: false }
        }
        // Sampled fp32: the paper's < 1% sampling claim.
        (Some(_), false) => {
            Budget { max_top1_loss: SAMPLING_TOP1_LOSS, slack_rows: 2, bitwise: false }
        }
        // Sampled INT8: sampling plus the quantization increment.
        (Some(_), true) => Budget {
            max_top1_loss: SAMPLING_TOP1_LOSS + QUANT_EXTRA_TOP1_LOSS,
            slack_rows: 3,
            bitwise: false,
        },
    }
}

/// Budget for an i8-compute route vs the **oracle**: the route stacks
/// the sampling loss (when sampled), the feature-quantization increment,
/// and the edge-coefficient requant increment.
pub fn i8_compute_budget(width: Option<usize>) -> Budget {
    let base = budget_for(width, true);
    Budget {
        max_top1_loss: base.max_top1_loss + I8_COMPUTE_EXTRA_TOP1_LOSS,
        slack_rows: base.slack_rows + 1,
        bitwise: false,
    }
}

/// The pairwise "quantization adds ≤ 0.3%" budget: INT8 logits measured
/// against the route's **fp32 sibling** (not the oracle), isolating the
/// quantization increment from the shared sampling error.
pub fn quant_delta_budget() -> Budget {
    Budget { max_top1_loss: QUANT_EXTRA_TOP1_LOSS, slack_rows: 1, bitwise: false }
}

/// The pairwise "true INT8 compute adds ≤ 0.3%" budget: i8-compute
/// logits measured against the route's **INT8-dequant sibling**
/// (U8Eager), isolating the integer-accumulation increment from the
/// shared sampling and feature-quantization error.
pub fn i8_compute_delta_budget() -> Budget {
    Budget { max_top1_loss: I8_COMPUTE_EXTRA_TOP1_LOSS, slack_rows: 1, bitwise: false }
}

/// The pairwise sharding budget: a sharded forward against its
/// unsharded sibling must be bitwise identical — sharding adds exactly
/// zero accuracy cost.
pub fn shard_delta_budget() -> Budget {
    Budget::bitwise()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::compare_logits;

    #[test]
    fn allowed_counts_scale_with_rows() {
        let b = budget_for(Some(16), false);
        // ceil(1% of 160) + 2 slack = 4.
        assert_eq!(b.allowed_disagreements(160), 4);
        // ceil(1% of 10_000) + 2 = 102 — the fraction dominates at scale.
        assert_eq!(b.allowed_disagreements(10_000), 102);
        let q = budget_for(Some(16), true);
        assert!(q.max_top1_loss > b.max_top1_loss);
        assert_eq!(budget_for(None, false), Budget::bitwise());
        assert_eq!(Budget::bitwise().allowed_disagreements(1_000_000), 0);
    }

    #[test]
    fn bitwise_budget_admits_only_bitwise_metrics() {
        let reference = [1.0f32, 0.0, 0.0, 1.0];
        let b = Budget::bitwise();
        assert!(b.admits(&compare_logits(&reference, &reference, 2, 2)));
        let close = [1.0f32, 0.0000001, 0.0, 1.0];
        assert!(!b.admits(&compare_logits(&reference, &close, 2, 2)));
    }

    #[test]
    fn fractional_budget_counts_disagreements() {
        // 100 rows, budget 1% + 2 slack → up to 3 flips pass, 4 fail.
        let b = budget_for(Some(8), false);
        let logits = [1.0f32; 200];
        let mut m = compare_logits(&logits, &logits, 100, 2);
        m.disagreeing = 3;
        assert!(b.admits(&m));
        m.disagreeing = 4;
        assert!(!b.admits(&m));
    }

    #[test]
    fn quant_and_shard_delta_budgets() {
        assert_eq!(quant_delta_budget().max_top1_loss, QUANT_EXTRA_TOP1_LOSS);
        assert!(!quant_delta_budget().bitwise);
        assert!(shard_delta_budget().bitwise);
        assert!(budget_for(Some(4), true).max_top1_loss > SAMPLING_TOP1_LOSS);
    }

    #[test]
    fn i8_compute_budgets_stack_on_the_dequant_route() {
        // Oracle budget: dequant route's allowance + the requant
        // increment, one extra slack row.
        let dequant = budget_for(Some(8), true);
        let i8 = i8_compute_budget(Some(8));
        assert!((i8.max_top1_loss - dequant.max_top1_loss - I8_COMPUTE_EXTRA_TOP1_LOSS).abs() < 1e-12);
        assert_eq!(i8.slack_rows, dequant.slack_rows + 1);
        assert!(!i8.bitwise);
        // Exact i8-compute: quant + requant only, no sampling term.
        let exact = i8_compute_budget(None);
        assert!((exact.max_top1_loss - (QUANT_EXTRA_TOP1_LOSS + I8_COMPUTE_EXTRA_TOP1_LOSS)).abs() < 1e-12);
        // Pairwise vs the dequant sibling: the requant increment alone.
        assert_eq!(i8_compute_delta_budget().max_top1_loss, I8_COMPUTE_EXTRA_TOP1_LOSS);
        assert!(!i8_compute_delta_budget().bitwise);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Budget::bitwise().label(), "bitwise");
        assert_eq!(budget_for(Some(8), false).label(), "top-1 loss <= 1.0% (+2 row slack)");
    }
}
