//! Accuracy conformance — the exact oracle, differential metrics, the
//! paper's accuracy-budget table, and the grid harness behind
//! `repro eval` / `ACC_eval.json` / `tools/acc_diff.rs`.
//!
//! # Purpose
//!
//! The paper's claim is two-sided: speed **and** accuracy (< 1% top-1
//! loss from adaptive sampling, ≤ 0.3% extra from INT8 — Tables 4–6).
//! `bench_diff` gates the speed side; this module gives the accuracy
//! side the same treatment: an in-tree exact oracle, per-configuration
//! budgets, and a CI regression gate (see docs/accuracy.md).
//!
//! # Structure
//!
//! | unit      | role                                                    |
//! |-----------|---------------------------------------------------------|
//! | `oracle`  | [`oracle_forward`]: the unsampled fp32 forward of any IR model in one canonical FP reduction order — ground truth for every configuration |
//! | `metrics` | [`compare_logits`] → [`AccuracyMetrics`]: top-1 agreement, per-row relative L2, max elementwise delta, bitwise flag |
//! | `budget`  | [`budget_for`] + the pairwise budgets: the paper's claims as checkable thresholds |
//! | `dataset` | seeded homophilous DC-SBM conformance datasets (power-law + uniform degree profiles) |
//! | `harness` | [`run_eval`]: the {model × strategy × width × precision × shards} grid through the real coordinator, plus cross-config invariants |
//!
//! # Rules
//!
//! * The oracle's reduction order is defined **here** and changes only
//!   with a deliberate refresh of the golden fixtures
//!   (`tests/fixtures/`, pinned by `tests/oracle_regression.rs`).
//! * Grid forwards go through [`crate::coordinator::Coordinator`] — the
//!   real plan cache / prefetcher / sharded execution — never a side
//!   path; a conformance pass that skipped the serving stack would
//!   certify nothing.
//! * Budgets may gain slack only with a paper-table justification in
//!   docs/accuracy.md; the golden fixtures catch oracle drift even if
//!   the budget table is later loosened.

#![warn(missing_docs)]

mod budget;
mod dataset;
mod harness;
mod metrics;
mod oracle;

pub use budget::{
    budget_for, i8_compute_budget, i8_compute_delta_budget, quant_delta_budget,
    shard_delta_budget, Budget, I8_COMPUTE_EXTRA_TOP1_LOSS, QUANT_EXTRA_TOP1_LOSS,
    SAMPLING_TOP1_LOSS,
};
pub use dataset::{
    write_eval_dataset, write_eval_datasets, DegreeProfile, EvalDatasetSpec, EVAL_AVG_DEG,
    EVAL_CLASSES, EVAL_DATASETS, EVAL_FEATS, EVAL_HIDDEN, EVAL_NODES,
};
pub use harness::{
    model_grid, run_eval, width_grid, ConfigResult, DatasetSummary, EvalCheck, EvalReport,
    PrecisionMode, SHARD_GRID,
};
pub use metrics::{compare_logits, AccuracyMetrics};
pub use oracle::{
    oracle_aggregate, oracle_forward, oracle_gat_alpha, oracle_matmul, oracle_max_aggregate,
};
