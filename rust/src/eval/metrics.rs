//! Differential accuracy metrics — how far a configuration's logits sit
//! from the oracle's.
//!
//! Three views, matching how the paper reports accuracy:
//!
//! * **top-1 agreement** — fraction of rows whose argmax class equals
//!   the reference's (the paper's accuracy metric, measured against the
//!   exact forward instead of labels, so it isolates the serving
//!   stack's error from model quality);
//! * **per-row relative L2** — `‖got_i − ref_i‖₂ / (‖ref_i‖₂ + ε)`,
//!   reported as mean and max over rows;
//! * **max elementwise delta** and a **bitwise** flag (`f32::to_bits`
//!   equality, so `−0.0 ≠ +0.0` and NaNs never sneak through).

use crate::util::argmax_f32;

/// Shields the per-row relative L2 against all-zero reference rows.
const REL_L2_EPS: f64 = 1e-12;

/// Differential metrics of one configuration against a reference
/// (usually the oracle). Produced by [`compare_logits`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AccuracyMetrics {
    /// Rows compared.
    pub rows: usize,
    /// Rows whose top-1 class disagrees with the reference
    /// (deterministic ties: [`argmax_f32`] breaks to the lowest index).
    pub disagreeing: usize,
    /// `1 − disagreeing / rows` (1.0 for an empty comparison).
    pub top1_agreement: f64,
    /// Mean over rows of the relative L2 error.
    pub mean_rel_l2: f64,
    /// Max over rows of the relative L2 error.
    pub max_rel_l2: f64,
    /// Largest `|got − ref|` over all elements (NaN deltas force the
    /// bitwise flag off instead of propagating here).
    pub max_abs_delta: f32,
    /// Every element identical at the bit level (`to_bits` equality).
    pub bitwise_equal: bool,
}

/// Compare `got` against `reference`, both row-major `[rows, classes]`.
pub fn compare_logits(
    reference: &[f32],
    got: &[f32],
    rows: usize,
    classes: usize,
) -> AccuracyMetrics {
    assert_eq!(reference.len(), rows * classes, "reference is not [rows, classes]");
    assert_eq!(got.len(), rows * classes, "got is not [rows, classes]");
    let mut disagreeing = 0usize;
    let mut sum_rel = 0.0f64;
    let mut max_rel = 0.0f64;
    let mut max_abs = 0.0f32;
    let mut bitwise = true;
    for r in 0..rows {
        let a = &reference[r * classes..(r + 1) * classes];
        let g = &got[r * classes..(r + 1) * classes];
        if argmax_f32(a) != argmax_f32(g) {
            disagreeing += 1;
        }
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (x, y) in a.iter().zip(g.iter()) {
            let d = f64::from(*y) - f64::from(*x);
            num += d * d;
            den += f64::from(*x) * f64::from(*x);
            let ad = (y - x).abs();
            if ad > max_abs {
                max_abs = ad;
            }
            if x.to_bits() != y.to_bits() {
                bitwise = false;
            }
        }
        let rel = num.sqrt() / (den.sqrt() + REL_L2_EPS);
        sum_rel += rel;
        if rel > max_rel {
            max_rel = rel;
        }
    }
    AccuracyMetrics {
        rows,
        disagreeing,
        top1_agreement: 1.0 - disagreeing as f64 / rows.max(1) as f64,
        mean_rel_l2: sum_rel / rows.max(1) as f64,
        max_rel_l2: max_rel,
        max_abs_delta: max_abs,
        bitwise_equal: bitwise,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_logits_are_perfect() {
        let a = [0.1f32, 0.9, -1.0, 3.0, 2.0, 1.0];
        let m = compare_logits(&a, &a, 2, 3);
        assert_eq!(m.disagreeing, 0);
        assert_eq!(m.top1_agreement, 1.0);
        assert_eq!(m.max_abs_delta, 0.0);
        assert_eq!((m.mean_rel_l2, m.max_rel_l2), (0.0, 0.0));
        assert!(m.bitwise_equal);
    }

    #[test]
    fn flipped_rows_are_counted() {
        let reference = [1.0f32, 0.0, 0.0, 1.0, 1.0, 0.0];
        // Row 0 keeps its argmax, row 1 flips, row 2 keeps.
        let got = [0.9f32, 0.1, 0.3, 0.2, 0.95, 0.05];
        let m = compare_logits(&reference, &got, 3, 2);
        assert_eq!(m.disagreeing, 1);
        assert!((m.top1_agreement - 2.0 / 3.0).abs() < 1e-12);
        assert!(!m.bitwise_equal);
        assert!(m.max_abs_delta > 0.0);
        assert!(m.max_rel_l2 >= m.mean_rel_l2);
    }

    #[test]
    fn small_perturbations_keep_top1_but_not_bitwise() {
        let reference = [2.0f32, 1.0, 0.5, 3.0];
        let got = [2.0f32, 1.0001, 0.5, 3.0];
        let m = compare_logits(&reference, &got, 2, 2);
        assert_eq!(m.disagreeing, 0);
        assert!(!m.bitwise_equal);
        assert!(m.max_abs_delta > 0.0 && m.max_abs_delta < 0.001);
    }

    #[test]
    fn bitwise_distinguishes_signed_zero() {
        let m = compare_logits(&[0.0f32, 1.0], &[-0.0f32, 1.0], 1, 2);
        assert!(!m.bitwise_equal, "to_bits must see -0.0 != +0.0");
        assert_eq!(m.max_abs_delta, 0.0);
        assert_eq!(m.disagreeing, 0);
    }

    #[test]
    fn nan_never_passes_bitwise() {
        let m = compare_logits(&[1.0f32, 2.0], &[1.0f32, f32::NAN], 1, 2);
        assert!(!m.bitwise_equal);
        // NaN delta is ignored by max_abs_delta (the flag carries it).
        assert_eq!(m.max_abs_delta, 0.0);
    }

    #[test]
    fn empty_comparison_is_vacuously_perfect() {
        let m = compare_logits(&[], &[], 0, 4);
        assert_eq!(m.top1_agreement, 1.0);
        assert!(m.bitwise_equal);
    }
}
