//! Per-route execution plans and the cache that keeps them warm.
//!
//! The seed re-read the feature tensor from disk on *every batch* — that
//! models the paper's per-inference loading cost (Table 3), but a serving
//! system should pay it once per route and then serve from memory. An
//! [`ExecPlan`] bundles everything `execute_route` needs that is
//! per-route rather than per-batch: the staged features — on the
//! streaming path a zero-copy row-block handle rather than an eagerly
//! materialized tensor — the sampled ELL plan for host-side aggregation,
//! the dispatched kernel choice, and the load-stage timing recorded at
//! the cold miss.
//!
//! [`PlanCache`] is a small sharded-free LRU keyed by whatever the caller
//! routes on. Policy:
//! * cold miss → the builder runs (and its `load_time` is charged to
//!   that batch); concurrent misses on one key may build twice — both
//!   results are valid, last insert wins (same idiom as the engine's
//!   compile cache);
//! * hit → no disk, no sampling, `load_time` reported as zero;
//! * capacity overflow → least-recently-used entry is evicted;
//! * [`PlanCache::invalidate`] / [`PlanCache::clear`] drop entries when
//!   a dataset is republished.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::graph::{Csr, Ell, ShardSpec};
use crate::quant::{FeatureStore, Features, LoadStats, Precision};
use crate::sampling::{sample_ell_par, Strategy};

use super::dispatch::{select_kernel, ExecEnv, GraphProfile, KernelKind};
use super::sharded::{ShardKey, ShardUnit, ShardedPlan};

/// Everything per-route that the hot path should not rebuild per batch.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    /// Features at the route's precision: dense f32, u8+params, or a
    /// streamed zero-copy handle (lazy per-block dequant in the worker).
    pub features: Features,
    /// Load-stage breakdown measured when this plan was built.
    pub load_stats: LoadStats,
    /// Statistics of the aggregation operand (the sampled ELL when one
    /// was built, else the CSR) — hot-path consumers dispatch per layer
    /// from this instead of re-scanning the graph every batch.
    pub profile: GraphProfile,
    /// Kernel picked for the route's input-dim aggregation (observability
    /// + benches; per-layer execution re-selects from `profile`, an O(1)
    /// decision).
    pub kernel: KernelKind,
    /// Sampled fixed-width plan (present when the route samples and the
    /// backend aggregates on the host, and sharding is off).
    pub ell: Option<Arc<Ell>>,
    /// Sharded execution plan (host aggregation with sharding enabled):
    /// per-shard sampled ELL + per-shard dispatch, executed as
    /// independent pool tasks with a row-concatenation merge. When set,
    /// `ell` is `None` and `profile`/`kernel` describe the unsharded
    /// operand (observability only — execution dispatches per shard).
    pub sharded: Option<Arc<ShardedPlan>>,
}

/// What to prepare for a route.
pub struct PlanSpec<'a> {
    /// Graph the route aggregates over (drives kernel dispatch).
    pub csr: &'a Csr,
    /// `Some(w)` for sampled routes, `None` for exact aggregation.
    pub width: Option<usize>,
    /// Edge-sampling strategy for sampled routes.
    pub strategy: Strategy,
    /// Build the host-side ELL plan (true for CPU-aggregating backends;
    /// false when a device artifact performs fused in-kernel sampling).
    pub host_ell: bool,
    /// Stage features through [`FeatureStore::stage`] — the plan then
    /// holds a zero-copy row-block handle ([`Features::Streamed`]) that
    /// dequantizes lazily inside the exec worker, instead of an eagerly
    /// materialized tensor. Set for host-aggregating backends; device
    /// backends keep the eager load (the artifact wants one owned
    /// tensor).
    pub stream: bool,
    /// Row-shard host aggregation: partition the operand into
    /// working-set-budgeted [`crate::graph::GraphShard`]s with per-shard
    /// sampling and dispatch. `None` keeps the single-working-set path.
    /// Only meaningful with `host_ell`-style host aggregation.
    pub shard: Option<ShardSpec>,
    /// Shard-unit cache plus the graph's identity tag: warm routes reuse
    /// prepared units, and a build of a partially-warm route samples
    /// only the cold shards. `None` builds units uncached.
    pub shard_cache: Option<(&'a PlanCache<ShardKey, ShardUnit>, &'a str)>,
}

/// Build a route's plan: one instrumented feature load (or zero-copy
/// stage), one kernel choice, and (optionally) one parallel sampling
/// pass.
pub fn prepare_plan(
    fstore: &FeatureStore,
    precision: Precision,
    spec: &PlanSpec<'_>,
    feat_dim: usize,
    env: &ExecEnv,
) -> Result<ExecPlan> {
    let (features, load_stats) =
        if spec.stream { fstore.stage(precision)? } else { fstore.load(precision)? };
    let (profile, ell, sharded) = match (spec.host_ell, spec.shard, spec.width) {
        (true, Some(shard_spec), _) => {
            let plan = ShardedPlan::prepare(
                spec.csr,
                &shard_spec,
                spec.width,
                spec.strategy,
                feat_dim,
                spec.shard_cache,
            );
            (GraphProfile::of(spec.csr), None, Some(Arc::new(plan)))
        }
        (true, None, Some(width)) => {
            let mut ell = Ell::zeros(spec.csr.n_rows, spec.csr.n_cols, width);
            sample_ell_par(spec.csr, width, spec.strategy, &mut ell, env.threads);
            (GraphProfile::of_ell(&ell), Some(Arc::new(ell)), None)
        }
        _ => (GraphProfile::of(spec.csr), None, None),
    };
    let kernel = select_kernel(&profile, feat_dim, spec.width, env);
    Ok(ExecPlan { features, load_stats, profile, kernel, ell, sharded })
}

struct Entry<V> {
    value: Arc<V>,
    last_used: u64,
}

struct Inner<K, V> {
    map: HashMap<K, Entry<V>>,
    tick: u64,
    /// Bumped by `invalidate`/`clear` under this same lock; a cold build
    /// that straddles a bump is served to its caller but **not**
    /// inserted, so invalidation can never be undone by an in-flight
    /// build of pre-invalidation data.
    generation: u64,
}

/// A bounded LRU cache with hit/miss/eviction counters.
pub struct PlanCache<K, V> {
    inner: Mutex<Inner<K, V>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl<K: Eq + Hash + Clone, V> PlanCache<K, V> {
    /// `capacity` is clamped to at least 1.
    pub fn new(capacity: usize) -> PlanCache<K, V> {
        PlanCache {
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0, generation: 0 }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    /// Look up without counting a hit or miss and without refreshing LRU
    /// recency — the prefetcher's duty-cycle check (a peek must not make
    /// an entry look hot or skew the hit-rate metrics).
    pub fn peek(&self, key: &K) -> Option<Arc<V>> {
        self.inner.lock().unwrap().map.get(key).map(|e| e.value.clone())
    }

    /// Look up without building. Counts a hit or miss.
    pub fn get(&self, key: &K) -> Option<Arc<V>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(entry) => {
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Return the cached value, or build-and-insert it. The builder runs
    /// outside the lock (a cold feature load takes milliseconds; other
    /// routes must not stall behind it). Returns `(value, was_hit)`.
    ///
    /// If `invalidate`/`clear` fires while the builder runs, the result
    /// is returned to this caller but not cached — the next lookup
    /// rebuilds from post-invalidation data.
    pub fn get_or_try_insert<E>(
        &self,
        key: &K,
        build: impl FnOnce() -> std::result::Result<V, E>,
    ) -> std::result::Result<(Arc<V>, bool), E> {
        if let Some(v) = self.get(key) {
            return Ok((v, true));
        }
        let generation = self.inner.lock().unwrap().generation;
        let value = Arc::new(build()?);
        // Insert and generation-check under one lock acquisition: an
        // invalidation cannot interleave between the check and the
        // insert.
        let mut inner = self.inner.lock().unwrap();
        if inner.generation == generation {
            let value = value.clone();
            Self::insert_locked(&mut inner, self.capacity, &self.evictions, key.clone(), value);
        }
        drop(inner);
        Ok((value, false))
    }

    /// Insert (replacing any previous entry), evicting LRU on overflow.
    pub fn insert(&self, key: K, value: Arc<V>) {
        let mut inner = self.inner.lock().unwrap();
        Self::insert_locked(&mut inner, self.capacity, &self.evictions, key, value);
    }

    fn insert_locked(
        inner: &mut Inner<K, V>,
        capacity: usize,
        evictions: &AtomicU64,
        key: K,
        value: Arc<V>,
    ) {
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(key, Entry { value, last_used: tick });
        while inner.map.len() > capacity {
            let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            inner.map.remove(&oldest);
            evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drop one key (e.g. its dataset was republished). Returns whether
    /// an entry existed. Also fences out in-flight builds (see
    /// [`PlanCache::get_or_try_insert`]).
    pub fn invalidate(&self, key: &K) -> bool {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        inner.map.remove(key).is_some()
    }

    /// Drop every key matching `pred` — e.g. all shard units of one
    /// republished dataset — and fence out in-flight builds. Returns how
    /// many entries were dropped.
    pub fn invalidate_matching(&self, pred: impl Fn(&K) -> bool) -> usize {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        let before = inner.map.len();
        inner.map.retain(|k, _| !pred(k));
        before - inner.map.len()
    }

    /// Drop everything and fence out in-flight builds.
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        inner.map.clear();
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The (clamped) capacity this cache evicts beyond.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that found nothing (including the build path's recheck).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped by LRU overflow.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::quant::{quantize, QuantParams};
    use crate::rng::Pcg32;
    use crate::tensor::{write_nbt, NbtFile, Tensor};
    use std::path::PathBuf;

    #[test]
    fn hit_miss_and_counters() {
        let cache: PlanCache<String, u32> = PlanCache::new(4);
        assert!(cache.get(&"a".to_string()).is_none());
        let (v, hit) = cache
            .get_or_try_insert(&"a".to_string(), || Ok::<_, std::io::Error>(7))
            .unwrap();
        assert_eq!((*v, hit), (7, false));
        let (v, hit) = cache
            .get_or_try_insert(&"a".to_string(), || panic!("must not rebuild on hit"))
            .unwrap_or_else(|e: std::io::Error| panic!("{e}"));
        assert_eq!((*v, hit), (7, true));
        assert_eq!(cache.hits(), 1);
        // One explicit lookup-miss plus one build-path miss.
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn build_errors_propagate_and_cache_nothing() {
        let cache: PlanCache<u32, u32> = PlanCache::new(4);
        let err = cache
            .get_or_try_insert(&1, || Err::<u32, _>("nope"))
            .unwrap_err();
        assert_eq!(err, "nope");
        assert!(cache.is_empty());
    }

    #[test]
    fn lru_eviction() {
        let cache: PlanCache<u32, u32> = PlanCache::new(2);
        cache.insert(1, Arc::new(10));
        cache.insert(2, Arc::new(20));
        assert!(cache.get(&1).is_some()); // 1 is now most recent
        cache.insert(3, Arc::new(30)); // evicts 2
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&2).is_none());
        assert!(cache.get(&1).is_some());
        assert!(cache.get(&3).is_some());
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn invalidate_during_build_is_not_resurrected() {
        let cache: PlanCache<u32, u32> = PlanCache::new(4);
        // The builder races an invalidation (simulated by invalidating
        // from inside the build): the stale result must be returned to
        // the caller but never cached.
        let (v, hit) = cache
            .get_or_try_insert(&1, || {
                cache.invalidate(&1);
                Ok::<_, std::io::Error>(5)
            })
            .unwrap();
        assert_eq!((*v, hit), (5, false));
        assert!(cache.get(&1).is_none(), "stale in-flight build must not be cached");
        // A later build (post-invalidation data) caches normally.
        cache.get_or_try_insert(&1, || Ok::<_, std::io::Error>(6)).unwrap();
        assert_eq!(*cache.get(&1).unwrap(), 6);
    }

    #[test]
    fn invalidate_and_clear() {
        let cache: PlanCache<u32, u32> = PlanCache::new(4);
        cache.insert(1, Arc::new(1));
        cache.insert(2, Arc::new(2));
        assert!(cache.invalidate(&1));
        assert!(!cache.invalidate(&1));
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    fn synthetic_store(tag: &str) -> (PathBuf, FeatureStore, Csr) {
        let dir = std::env::temp_dir().join(format!("plan_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let n = 128;
        let f = 8;
        let mut rng = Pcg32::new(77);
        let csr = gen::with_self_loops(&gen::chung_lu(n, 6.0, 2.0, &mut rng));
        let feat: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let params = QuantParams::of(&feat);
        let q = quantize(&feat, params);
        let mut nbt = NbtFile::new();
        nbt.insert("feat", Tensor::from_f32(&[n, f], &feat));
        nbt.insert("featq", Tensor::from_u8(&[n, f], &q));
        nbt.insert("qrange", Tensor::from_f32(&[2], &[params.x_min, params.x_max]));
        let path = dir.join("data_synth.nbt");
        write_nbt(&path, &nbt).unwrap();
        (path.clone(), FeatureStore::open(&path).unwrap(), csr)
    }

    #[test]
    fn prepare_plan_builds_features_kernel_and_ell() {
        let (_path, store, csr) = synthetic_store("full");
        let env = ExecEnv::with_threads(2);
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: false,
            shard: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::F32, &spec, 8, &env).unwrap();
        assert!(matches!(plan.features, Features::Dense(_)));
        assert!(plan.kernel.is_sampled());
        let ell = plan.ell.expect("host_ell requested");
        assert_eq!(ell.width, 4);
        ell.validate().unwrap();
        assert!(plan.load_stats.bytes_read > 0);
        // The cached profile describes the sampled operand, so per-layer
        // dispatch needs no graph re-scan.
        assert_eq!(plan.profile.n_rows, csr.n_rows);
        assert_eq!(plan.profile.nnz, ell.total_slots());
        assert!(plan.profile.max_nnz <= 4);

        // Device-style spec: no host ELL even for a sampled width.
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: false,
            stream: false,
            shard: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::U8Device, &spec, 8, &env).unwrap();
        assert!(plan.ell.is_none());
        assert!(matches!(plan.features, Features::Quantized { .. }));
    }

    #[test]
    fn streamed_plan_holds_a_row_block_handle() {
        let (_path, store, csr) = synthetic_store("stream");
        let env = ExecEnv::with_threads(1);
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: true,
            shard: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::U8Device, &spec, 8, &env).unwrap();
        match &plan.features {
            // mmap available: the cached plan holds a handle, and no
            // payload bytes moved at build time.
            Features::Streamed(h) => {
                assert_eq!((h.n_rows(), h.feat_dim()), (128, 8));
                assert_eq!(plan.load_stats.bytes_read, 0);
                let mut block = vec![0.0f32; 4 * 8];
                h.fill_rows_f32(0, &mut block);
                assert!(block.iter().all(|v| v.is_finite()));
            }
            // no mmap on this platform: the documented eager fallback.
            other => assert!(matches!(other, Features::Quantized { .. }), "{other:?}"),
        }
        // fp32 never streams — the fallback keeps the old contract.
        let plan = prepare_plan(&store, Precision::F32, &spec, 8, &env).unwrap();
        assert!(matches!(plan.features, Features::Dense(_)));
    }

    #[test]
    fn invalidate_matching_drops_by_predicate_and_fences() {
        let cache: PlanCache<(u32, u32), u32> = PlanCache::new(8);
        for k in 0..6u32 {
            cache.insert((k % 2, k), Arc::new(k));
        }
        assert_eq!(cache.invalidate_matching(|&(family, _)| family == 0), 3);
        assert_eq!(cache.len(), 3);
        assert!(cache.peek(&(0, 0)).is_none());
        assert!(cache.peek(&(1, 1)).is_some());
        // The generation bump fences in-flight builds like invalidate().
        let (v, _) = cache
            .get_or_try_insert(&(0, 0), || {
                cache.invalidate_matching(|_| false); // bump, drop nothing
                Ok::<_, std::io::Error>(9)
            })
            .unwrap();
        assert_eq!(*v, 9);
        assert!(cache.peek(&(0, 0)).is_none(), "straddling build must not land");
    }

    #[test]
    fn sharded_spec_builds_a_sharded_plan() {
        use crate::exec::{ShardKey, ShardUnit};
        use crate::graph::ShardSpec;

        let (_path, store, csr) = synthetic_store("sharded");
        let env = ExecEnv::with_threads(2);
        let units: PlanCache<ShardKey, ShardUnit> = PlanCache::new(32);
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: false,
            shard: Some(ShardSpec::by_count(3)),
            shard_cache: Some((&units, "synth")),
        };
        let plan = prepare_plan(&store, Precision::F32, &spec, 8, &env).unwrap();
        let sharded = plan.sharded.as_ref().expect("shard spec must shard the plan");
        assert_eq!(sharded.shard_count(), 3);
        assert_eq!(sharded.warm_units(), 0);
        assert!(plan.ell.is_none(), "the sharded plan replaces the whole-graph ELL");
        assert_eq!(units.len(), 3);

        // A second precision over the same route: plan rebuilt, every
        // shard unit warm — the shard-aware prefetch contract.
        let plan = prepare_plan(&store, Precision::U8Device, &spec, 8, &env).unwrap();
        assert_eq!(plan.sharded.unwrap().warm_units(), 3);
    }

    #[test]
    fn peek_neither_counts_nor_touches_recency() {
        let cache: PlanCache<u32, u32> = PlanCache::new(2);
        assert!(cache.peek(&1).is_none());
        cache.insert(1, Arc::new(10));
        cache.insert(2, Arc::new(20));
        assert_eq!(*cache.peek(&1).unwrap(), 10);
        assert_eq!((cache.hits(), cache.misses()), (0, 0), "peek is metric-silent");
        // Peeking 1 must NOT have refreshed it: inserting 3 evicts 1
        // (the least recently *used*), not 2.
        cache.insert(3, Arc::new(30));
        assert!(cache.peek(&1).is_none());
        assert!(cache.peek(&2).is_some());
    }

    #[test]
    fn cached_plan_skips_the_feature_store() {
        let (_path, store, csr) = synthetic_store("skip");
        let env = ExecEnv::with_threads(1);
        let cache: PlanCache<&'static str, ExecPlan> = PlanCache::new(4);
        let build = |precision| {
            let spec = PlanSpec {
                csr: &csr,
                width: Some(4),
                strategy: Strategy::Aes,
                host_ell: true,
                stream: false,
                shard: None,
                shard_cache: None,
            };
            prepare_plan(&store, precision, &spec, 8, &env)
        };
        for round in 0..5 {
            let (_, hit) = cache.get_or_try_insert(&"route", || build(Precision::F32)).unwrap();
            assert_eq!(hit, round > 0);
        }
        // The store was touched exactly once despite five executions.
        assert_eq!(store.load_count(), 1);
        let (_, hit) = cache.get_or_try_insert(&"route8", || build(Precision::U8Device)).unwrap();
        assert!(!hit);
        assert_eq!(store.load_count(), 2);
    }
}
