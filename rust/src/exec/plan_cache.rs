//! Per-route execution plans and the cache that keeps them warm.
//!
//! The seed re-read the feature tensor from disk on *every batch* — that
//! models the paper's per-inference loading cost (Table 3), but a serving
//! system should pay it once per route and then serve from memory. An
//! [`ExecPlan`] bundles everything `execute_route` needs that is
//! per-route rather than per-batch: the staged features — on the
//! streaming path a zero-copy row-block handle rather than an eagerly
//! materialized tensor — the sampled ELL plan for host-side aggregation,
//! the dispatched kernel choice, and the load-stage timing recorded at
//! the cold miss.
//!
//! [`PlanCache`] is a small sharded-free LRU keyed by whatever the caller
//! routes on. Policy:
//! * cold miss → the builder runs (and its `load_time` is charged to
//!   that batch); concurrent misses on one key may build twice — both
//!   results are valid, last insert wins (same idiom as the engine's
//!   compile cache). On the **versioned** API the tie-break is
//!   newest-epoch wins instead: a build against a superseded graph
//!   epoch can never clobber (or be served over) the rebuilt plan —
//!   the live-mutation correctness contract (`docs/mutation.md`);
//! * hit → no disk, no sampling, `load_time` reported as zero;
//! * capacity overflow → least-recently-used entry is evicted;
//! * [`PlanCache::invalidate`] / [`PlanCache::clear`] drop entries when
//!   a dataset is republished.

use std::collections::HashMap;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::graph::{Csr, Ell, ShardSpec};
use crate::quant::{ChunkedParams, FeatureStore, Features, LoadStats, Precision};
use crate::sampling::{sample_ell_par, Strategy};
use crate::spmm::AdjQuant;

use super::dispatch::{select_kernel, select_kernel_i8, ExecEnv, GraphProfile, KernelKind};
use super::sharded::{ShardCacheRef, ShardedPlan};

/// Everything per-route that the hot path should not rebuild per batch.
#[derive(Clone, Debug)]
pub struct ExecPlan {
    /// Features at the route's precision: dense f32, u8+params, or a
    /// streamed zero-copy handle (lazy per-block dequant in the worker).
    pub features: Features,
    /// Load-stage breakdown measured when this plan was built.
    pub load_stats: LoadStats,
    /// Statistics of the aggregation operand (the sampled ELL when one
    /// was built, else the CSR) — hot-path consumers dispatch per layer
    /// from this instead of re-scanning the graph every batch.
    pub profile: GraphProfile,
    /// Kernel picked for the route's input-dim aggregation (observability
    /// + benches; per-layer execution re-selects from `profile`, an O(1)
    /// decision).
    pub kernel: KernelKind,
    /// Sampled fixed-width plan (present when the route samples and the
    /// backend aggregates on the host, and sharding is off).
    pub ell: Option<Arc<Ell>>,
    /// Sharded execution plan (host aggregation with sharding enabled):
    /// per-shard sampled ELL + per-shard dispatch, executed as
    /// independent pool tasks with a row-concatenation merge. When set,
    /// `ell` is `None` and `profile`/`kernel` describe the unsharded
    /// operand (observability only — execution dispatches per shard).
    pub sharded: Option<Arc<ShardedPlan>>,
    /// Requantized adjacency for true-INT8-compute routes
    /// ([`Precision::I8Compute`]): the [`AdjQuant`] operands the
    /// `i8×u8→i32` kernels consume, built once here from the staged
    /// features' chunk ranges. `None` at every other precision — and
    /// when the staged features carry no codes (dense-only container),
    /// in which case the executor falls back to fp32 aggregation.
    pub adj: Option<Arc<AdjQuantPlan>>,
}

/// Requantized adjacency operands for one i8-compute route — parallel
/// to the plan's execution structure: a single entry for unsharded
/// plans (over the sampled ELL when present, else the exact CSR), one
/// entry per [`super::ShardUnit`] in unit order for sharded plans.
/// Depends only on the adjacency and the feature chunk ranges, so it is
/// built at plan-preparation time and reused across batches.
#[derive(Clone, Debug)]
pub struct AdjQuantPlan {
    /// Per-unit requantized adjacencies, in unit (row) order.
    pub units: Vec<AdjQuant>,
}

/// What to prepare for a route.
pub struct PlanSpec<'a> {
    /// Graph the route aggregates over (drives kernel dispatch).
    pub csr: &'a Csr,
    /// `Some(w)` for sampled routes, `None` for exact aggregation.
    pub width: Option<usize>,
    /// Edge-sampling strategy for sampled routes.
    pub strategy: Strategy,
    /// Build the host-side ELL plan (true for CPU-aggregating backends;
    /// false when a device artifact performs fused in-kernel sampling).
    pub host_ell: bool,
    /// Stage features through [`FeatureStore::stage`] — the plan then
    /// holds a zero-copy row-block handle ([`Features::Streamed`]) that
    /// dequantizes lazily inside the exec worker, instead of an eagerly
    /// materialized tensor. Set for host-aggregating backends; device
    /// backends keep the eager load (the artifact wants one owned
    /// tensor).
    pub stream: bool,
    /// Row-shard host aggregation: partition the operand into
    /// working-set-budgeted [`crate::graph::GraphShard`]s with per-shard
    /// sampling and dispatch. `None` keeps the single-working-set path.
    /// Only meaningful with `host_ell`-style host aggregation.
    pub shard: Option<ShardSpec>,
    /// Fixed shard cut points from a sticky [`super::ShardLayout`] —
    /// the live-mutation path, where the partition must survive epochs
    /// so untouched shard units stay warm. `None` derives fresh
    /// quantile cuts from `shard` (the static-graph behavior).
    pub shard_bounds: Option<&'a [std::ops::Range<usize>]>,
    /// Shard-unit cache reference (cache + graph identity tag + graph
    /// epoch): warm routes reuse prepared units, and a build of a
    /// partially-warm route samples only the cold shards. `None` builds
    /// units uncached.
    pub shard_cache: Option<ShardCacheRef<'a>>,
}

/// Build a route's plan: one instrumented feature load (or zero-copy
/// stage), one kernel choice, and (optionally) one parallel sampling
/// pass.
pub fn prepare_plan(
    fstore: &FeatureStore,
    precision: Precision,
    spec: &PlanSpec<'_>,
    feat_dim: usize,
    env: &ExecEnv,
) -> Result<ExecPlan> {
    let (features, load_stats) =
        if spec.stream { fstore.stage(precision)? } else { fstore.load(precision)? };
    let (profile, ell, sharded) = match (spec.host_ell, spec.shard, spec.width) {
        (true, Some(shard_spec), _) => {
            let plan = match spec.shard_bounds {
                // Sticky layout (live mutation): reuse the serving cuts
                // so untouched shard units keep their keys.
                Some(bounds) => ShardedPlan::prepare_with_bounds(
                    spec.csr,
                    bounds,
                    spec.width,
                    spec.strategy,
                    feat_dim,
                    spec.shard_cache,
                ),
                None => ShardedPlan::prepare(
                    spec.csr,
                    &shard_spec,
                    spec.width,
                    spec.strategy,
                    feat_dim,
                    spec.shard_cache,
                ),
            };
            (GraphProfile::of(spec.csr), None, Some(Arc::new(plan)))
        }
        (true, None, Some(width)) => {
            let mut ell = Ell::zeros(spec.csr.n_rows, spec.csr.n_cols, width);
            sample_ell_par(spec.csr, width, spec.strategy, &mut ell, env.threads);
            (GraphProfile::of_ell(&ell), Some(Arc::new(ell)), None)
        }
        _ => (GraphProfile::of(spec.csr), None, None),
    };
    let adj = if precision == Precision::I8Compute && spec.host_ell {
        i8_chunk_params(&features, spec.csr.n_cols).map(|params| {
            let units = match (&sharded, &ell) {
                (Some(sh), _) => sh
                    .units()
                    .iter()
                    .map(|u| match &u.ell {
                        Some(e) => AdjQuant::from_ell(e, &params),
                        None => AdjQuant::from_csr(&u.csr, &params),
                    })
                    .collect(),
                (None, Some(e)) => vec![AdjQuant::from_ell(e, &params)],
                (None, None) => vec![AdjQuant::from_csr(spec.csr, &params)],
            };
            Arc::new(AdjQuantPlan { units })
        })
    } else {
        None
    };
    let kernel = match &adj {
        Some(_) => select_kernel_i8(&profile, feat_dim, spec.width, env),
        None => select_kernel(&profile, feat_dim, spec.width, env),
    };
    Ok(ExecPlan { features, load_stats, profile, kernel, ell, sharded, adj })
}

/// The per-chunk feature ranges an i8-compute route folds into its
/// [`AdjQuant`] — available whenever the staged representation still
/// carries u8 codes. A dense-only representation has nothing to fold,
/// so the route degrades to fp32 aggregation (`None`).
fn i8_chunk_params(features: &Features, n_nodes: usize) -> Option<ChunkedParams> {
    match features {
        Features::Streamed(h) => Some(h.params().clone()),
        Features::Quantized { q, params } => {
            let rows = q.shape.first().copied().unwrap_or(n_nodes);
            Some(ChunkedParams::uniform(rows, *params))
        }
        Features::Dense(_) => None,
    }
}

struct Entry<V> {
    value: Arc<V>,
    last_used: u64,
    /// Graph epoch this value was built against (0 for unversioned
    /// inserts). Versioned lookups require an exact match; see the
    /// `*_versioned` methods.
    epoch: u64,
}

struct Inner<K, V> {
    map: HashMap<K, Entry<V>>,
    tick: u64,
    /// Bumped by `invalidate`/`clear` under this same lock; a cold build
    /// that straddles a bump is served to its caller but **not**
    /// inserted, so invalidation can never be undone by an in-flight
    /// build of pre-invalidation data.
    ///
    /// The generation fence alone is a *time* fence: it only catches
    /// builds whose snapshot predates the bump. A builder that bound its
    /// input data before a mutation but took its snapshot after the
    /// mutation's bump sails through — which is why versioned entries
    /// exist: the **epoch** tag travels with the data itself, so a stale
    /// value is unreachable at the new epoch no matter how the fence
    /// race resolved.
    generation: u64,
}

/// A bounded LRU cache with hit/miss/eviction counters.
///
/// Two usage modes, per cache instance (don't mix them on one cache):
/// * **Unversioned** (`get`/`insert`/`get_or_try_insert`): the original
///   contract — last insert wins, invalidation generation-fences
///   in-flight builds.
/// * **Versioned** (`*_versioned`): every entry carries the graph epoch
///   it was built against. A lookup at epoch `e` hits only an entry
///   tagged `e`; an *older* entry is dropped as stale (counted in
///   [`PlanCache::stale`]); a *newer* entry is left resident (the
///   reader, not the entry, is behind). Inserts are **newest-epoch
///   wins**: a build tagged `e` never replaces a resident entry tagged
///   `> e`, so a builder that started against epoch N cannot clobber
///   the rebuilt N+1 plan — the live-mutation correctness contract
///   (`docs/mutation.md`).
pub struct PlanCache<K, V> {
    inner: Mutex<Inner<K, V>>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    stale: AtomicU64,
}

impl<K: Eq + Hash + Clone, V> PlanCache<K, V> {
    /// `capacity` is clamped to at least 1.
    pub fn new(capacity: usize) -> PlanCache<K, V> {
        PlanCache {
            inner: Mutex::new(Inner { map: HashMap::new(), tick: 0, generation: 0 }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            stale: AtomicU64::new(0),
        }
    }

    /// Snapshot the invalidation generation — taken by a builder
    /// **before** it reads any input state, and passed back to
    /// [`PlanCache::try_insert_versioned`] so the insert can be refused
    /// if any invalidation fired in between.
    pub fn generation(&self) -> u64 {
        self.inner.lock().unwrap().generation
    }

    /// Look up without counting a hit or miss and without refreshing LRU
    /// recency — the prefetcher's duty-cycle check (a peek must not make
    /// an entry look hot or skew the hit-rate metrics).
    pub fn peek(&self, key: &K) -> Option<Arc<V>> {
        self.inner.lock().unwrap().map.get(key).map(|e| e.value.clone())
    }

    /// [`PlanCache::peek`] restricted to entries tagged exactly `epoch`.
    /// Pure read: a mismatched entry is neither dropped nor counted.
    pub fn peek_versioned(&self, key: &K, epoch: u64) -> Option<Arc<V>> {
        let inner = self.inner.lock().unwrap();
        inner.map.get(key).filter(|e| e.epoch == epoch).map(|e| e.value.clone())
    }

    /// Versioned lookup. Hit iff the resident entry is tagged exactly
    /// `epoch`. Any other tag misses **without evicting the entry**:
    /// * tagged *older*: superseded data — unreachable (counted in
    ///   [`PlanCache::stale`] per encounter), but left resident because
    ///   a mutation's `advance_epoch` may still be on its way to re-tag
    ///   it (untouched-shard revalidation); an eager drop here would
    ///   let a reader racing the publish→advance window destroy the
    ///   retained-shard win. The entry is reclaimed by the rebuild's
    ///   replacing insert, by `advance_epoch`/invalidation, or by LRU.
    /// * tagged *newer*: the **reader** bound an old epoch; it rebuilds
    ///   from its own snapshot and its insert is refused by
    ///   newest-epoch-wins.
    pub fn get_versioned(&self, key: &K, epoch: u64) -> Option<Arc<V>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get(key).map(|e| e.epoch) {
            Some(tagged) if tagged == epoch => {
                let entry = inner.map.get_mut(key).expect("checked above");
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.value.clone())
            }
            Some(tagged) => {
                if tagged < epoch {
                    self.stale.fetch_add(1, Ordering::Relaxed);
                }
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Fenced, epoch-tagged insert: lands only if (a) no invalidation
    /// fired since the builder's `generation` snapshot and (b) no
    /// resident entry carries a newer epoch. Returns whether the value
    /// was inserted. This is the extension of the generation fence that
    /// closes the stale-insert race: even when a stale builder's
    /// snapshot postdates the invalidation bump (so (a) passes), its
    /// epoch tag keeps the value unreachable at the advanced epoch, and
    /// (b) keeps it from clobbering an already-rebuilt plan.
    pub fn try_insert_versioned(
        &self,
        key: &K,
        value: Arc<V>,
        epoch: u64,
        generation: u64,
    ) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.generation != generation {
            return false;
        }
        if let Some(existing) = inner.map.get(key) {
            if existing.epoch > epoch {
                return false;
            }
        }
        Self::insert_locked(&mut inner, self.capacity, &self.evictions, key.clone(), value, epoch);
        true
    }

    /// Versioned variant of [`PlanCache::get_or_try_insert`]: the caller
    /// binds `epoch` to the input data **before** building (fetch the
    /// dataset once, read its epoch, build from that same snapshot), so
    /// the entry's tag always matches the data actually read.
    pub fn get_or_try_insert_versioned<E>(
        &self,
        key: &K,
        epoch: u64,
        build: impl FnOnce() -> std::result::Result<V, E>,
    ) -> std::result::Result<(Arc<V>, bool), E> {
        if let Some(v) = self.get_versioned(key, epoch) {
            return Ok((v, true));
        }
        let generation = self.generation();
        let value = Arc::new(build()?);
        self.try_insert_versioned(key, value.clone(), epoch, generation);
        Ok((value, false))
    }

    /// Atomically advance matching entries across an epoch boundary —
    /// the mutation path's scoped invalidation, in **one** lock
    /// acquisition so no insert can interleave between the drop and the
    /// re-tag:
    /// * entries matching `drop` are removed (and the generation is
    ///   bumped, fencing in-flight builds like an invalidate);
    /// * surviving entries matching `keep` that are tagged **exactly**
    ///   `from_epoch` are re-tagged to `to_epoch` — "this entry's
    ///   content is byte-identical at the new epoch" revalidation.
    ///
    /// The `from_epoch` check is load-bearing: an entry tagged with any
    /// *other* epoch was built from a graph this boundary knows nothing
    /// about (e.g. a racing stale build that landed moments ago), and
    /// promoting it would serve superseded data at the new epoch. Such
    /// entries are left untouched — unreachable by versioned lookups
    /// (which keep them resident), reclaimed by a rebuild's replacing
    /// insert or by LRU.
    ///
    /// Returns `(dropped, retagged)`.
    pub fn advance_epoch(
        &self,
        drop: impl Fn(&K) -> bool,
        keep: impl Fn(&K) -> bool,
        from_epoch: u64,
        to_epoch: u64,
    ) -> (usize, usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        let dropped: Vec<K> = inner.map.keys().filter(|k| drop(k)).cloned().collect();
        for k in &dropped {
            inner.map.remove(k);
        }
        let mut retagged = 0usize;
        for (k, e) in inner.map.iter_mut() {
            if e.epoch == from_epoch && keep(k) {
                e.epoch = to_epoch;
                retagged += 1;
            }
        }
        (dropped.len(), retagged)
    }

    /// Look up without building. Counts a hit or miss.
    pub fn get(&self, key: &K) -> Option<Arc<V>> {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(entry) => {
                entry.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(entry.value.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Return the cached value, or build-and-insert it. The builder runs
    /// outside the lock (a cold feature load takes milliseconds; other
    /// routes must not stall behind it). Returns `(value, was_hit)`.
    ///
    /// If `invalidate`/`clear` fires while the builder runs, the result
    /// is returned to this caller but not cached — the next lookup
    /// rebuilds from post-invalidation data.
    pub fn get_or_try_insert<E>(
        &self,
        key: &K,
        build: impl FnOnce() -> std::result::Result<V, E>,
    ) -> std::result::Result<(Arc<V>, bool), E> {
        if let Some(v) = self.get(key) {
            return Ok((v, true));
        }
        let generation = self.inner.lock().unwrap().generation;
        let value = Arc::new(build()?);
        // Insert and generation-check under one lock acquisition: an
        // invalidation cannot interleave between the check and the
        // insert.
        let mut inner = self.inner.lock().unwrap();
        if inner.generation == generation {
            let value = value.clone();
            Self::insert_locked(&mut inner, self.capacity, &self.evictions, key.clone(), value, 0);
        }
        drop(inner);
        Ok((value, false))
    }

    /// Insert (replacing any previous entry), evicting LRU on overflow.
    /// Unversioned (entries tagged epoch 0).
    pub fn insert(&self, key: K, value: Arc<V>) {
        let mut inner = self.inner.lock().unwrap();
        Self::insert_locked(&mut inner, self.capacity, &self.evictions, key, value, 0);
    }

    fn insert_locked(
        inner: &mut Inner<K, V>,
        capacity: usize,
        evictions: &AtomicU64,
        key: K,
        value: Arc<V>,
        epoch: u64,
    ) {
        inner.tick += 1;
        let tick = inner.tick;
        inner.map.insert(key, Entry { value, last_used: tick, epoch });
        while inner.map.len() > capacity {
            let Some(oldest) = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone())
            else {
                break;
            };
            inner.map.remove(&oldest);
            evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drop one key (e.g. its dataset was republished). Returns whether
    /// an entry existed. Also fences out in-flight builds (see
    /// [`PlanCache::get_or_try_insert`]).
    pub fn invalidate(&self, key: &K) -> bool {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        inner.map.remove(key).is_some()
    }

    /// Drop every key matching `pred` — e.g. all shard units of one
    /// republished dataset — and fence out in-flight builds. Returns how
    /// many entries were dropped. (Allocation-free; use
    /// [`PlanCache::take_matching`] when the dropped keys themselves are
    /// needed.)
    pub fn invalidate_matching(&self, pred: impl Fn(&K) -> bool) -> usize {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        let before = inner.map.len();
        inner.map.retain(|k, _| !pred(k));
        before - inner.map.len()
    }

    /// [`PlanCache::invalidate_matching`] that also returns the dropped
    /// keys — the mutation path re-stages exactly the routes it evicted.
    pub fn take_matching(&self, pred: impl Fn(&K) -> bool) -> Vec<K> {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        let taken: Vec<K> = inner.map.keys().filter(|k| pred(k)).cloned().collect();
        for k in &taken {
            inner.map.remove(k);
        }
        taken
    }

    /// Drop everything and fence out in-flight builds.
    pub fn clear(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.generation += 1;
        inner.map.clear();
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// Whether the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The (clamped) capacity this cache evicts beyond.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that found nothing (including the build path's recheck).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Entries dropped by LRU overflow.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Versioned lookups that found the resident entry tagged with a
    /// superseded epoch (stale data a mutation left behind; counted per
    /// encounter — the entry stays resident until replaced, re-tagged,
    /// or evicted).
    pub fn stale(&self) -> u64 {
        self.stale.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::quant::{quantize, QuantParams};
    use crate::rng::Pcg32;
    use crate::tensor::{write_nbt, NbtFile, Tensor};
    use std::path::PathBuf;

    #[test]
    fn hit_miss_and_counters() {
        let cache: PlanCache<String, u32> = PlanCache::new(4);
        assert!(cache.get(&"a".to_string()).is_none());
        let (v, hit) = cache
            .get_or_try_insert(&"a".to_string(), || Ok::<_, std::io::Error>(7))
            .unwrap();
        assert_eq!((*v, hit), (7, false));
        let (v, hit) = cache
            .get_or_try_insert(&"a".to_string(), || panic!("must not rebuild on hit"))
            .unwrap_or_else(|e: std::io::Error| panic!("{e}"));
        assert_eq!((*v, hit), (7, true));
        assert_eq!(cache.hits(), 1);
        // One explicit lookup-miss plus one build-path miss.
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn build_errors_propagate_and_cache_nothing() {
        let cache: PlanCache<u32, u32> = PlanCache::new(4);
        let err = cache
            .get_or_try_insert(&1, || Err::<u32, _>("nope"))
            .unwrap_err();
        assert_eq!(err, "nope");
        assert!(cache.is_empty());
    }

    #[test]
    fn lru_eviction() {
        let cache: PlanCache<u32, u32> = PlanCache::new(2);
        cache.insert(1, Arc::new(10));
        cache.insert(2, Arc::new(20));
        assert!(cache.get(&1).is_some()); // 1 is now most recent
        cache.insert(3, Arc::new(30)); // evicts 2
        assert_eq!(cache.len(), 2);
        assert!(cache.get(&2).is_none());
        assert!(cache.get(&1).is_some());
        assert!(cache.get(&3).is_some());
        assert_eq!(cache.evictions(), 1);
    }

    #[test]
    fn invalidate_during_build_is_not_resurrected() {
        let cache: PlanCache<u32, u32> = PlanCache::new(4);
        // The builder races an invalidation (simulated by invalidating
        // from inside the build): the stale result must be returned to
        // the caller but never cached.
        let (v, hit) = cache
            .get_or_try_insert(&1, || {
                cache.invalidate(&1);
                Ok::<_, std::io::Error>(5)
            })
            .unwrap();
        assert_eq!((*v, hit), (5, false));
        assert!(cache.get(&1).is_none(), "stale in-flight build must not be cached");
        // A later build (post-invalidation data) caches normally.
        cache.get_or_try_insert(&1, || Ok::<_, std::io::Error>(6)).unwrap();
        assert_eq!(*cache.get(&1).unwrap(), 6);
    }

    #[test]
    fn invalidate_and_clear() {
        let cache: PlanCache<u32, u32> = PlanCache::new(4);
        cache.insert(1, Arc::new(1));
        cache.insert(2, Arc::new(2));
        assert!(cache.invalidate(&1));
        assert!(!cache.invalidate(&1));
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    fn synthetic_store(tag: &str) -> (PathBuf, FeatureStore, Csr) {
        let dir = std::env::temp_dir().join(format!("plan_cache_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let n = 128;
        let f = 8;
        let mut rng = Pcg32::new(77);
        let csr = gen::with_self_loops(&gen::chung_lu(n, 6.0, 2.0, &mut rng));
        let feat: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
        let params = QuantParams::of(&feat);
        let q = quantize(&feat, params);
        let mut nbt = NbtFile::new();
        nbt.insert("feat", Tensor::from_f32(&[n, f], &feat));
        nbt.insert("featq", Tensor::from_u8(&[n, f], &q));
        nbt.insert("qrange", Tensor::from_f32(&[2], &[params.x_min, params.x_max]));
        let path = dir.join("data_synth.nbt");
        write_nbt(&path, &nbt).unwrap();
        (path.clone(), FeatureStore::open(&path).unwrap(), csr)
    }

    #[test]
    fn prepare_plan_builds_features_kernel_and_ell() {
        let (_path, store, csr) = synthetic_store("full");
        let env = ExecEnv::with_threads(2);
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: false,
            shard: None,
            shard_bounds: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::F32, &spec, 8, &env).unwrap();
        assert!(matches!(plan.features, Features::Dense(_)));
        assert!(plan.kernel.is_sampled());
        let ell = plan.ell.expect("host_ell requested");
        assert_eq!(ell.width, 4);
        ell.validate().unwrap();
        assert!(plan.load_stats.bytes_read > 0);
        // The cached profile describes the sampled operand, so per-layer
        // dispatch needs no graph re-scan.
        assert_eq!(plan.profile.n_rows, csr.n_rows);
        assert_eq!(plan.profile.nnz, ell.total_slots());
        assert!(plan.profile.max_nnz <= 4);

        // Device-style spec: no host ELL even for a sampled width.
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: false,
            stream: false,
            shard: None,
            shard_bounds: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::U8Device, &spec, 8, &env).unwrap();
        assert!(plan.ell.is_none());
        assert!(matches!(plan.features, Features::Quantized { .. }));
    }

    #[test]
    fn streamed_plan_holds_a_row_block_handle() {
        let (_path, store, csr) = synthetic_store("stream");
        let env = ExecEnv::with_threads(1);
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: true,
            shard: None,
            shard_bounds: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::U8Device, &spec, 8, &env).unwrap();
        match &plan.features {
            // mmap available: the cached plan holds a handle, and no
            // payload bytes moved at build time.
            Features::Streamed(h) => {
                assert_eq!((h.n_rows(), h.feat_dim()), (128, 8));
                assert_eq!(plan.load_stats.bytes_read, 0);
                let mut block = vec![0.0f32; 4 * 8];
                h.fill_rows_f32(0, &mut block);
                assert!(block.iter().all(|v| v.is_finite()));
            }
            // no mmap on this platform: the documented eager fallback.
            other => assert!(matches!(other, Features::Quantized { .. }), "{other:?}"),
        }
        // fp32 never streams — the fallback keeps the old contract.
        let plan = prepare_plan(&store, Precision::F32, &spec, 8, &env).unwrap();
        assert!(matches!(plan.features, Features::Dense(_)));
    }

    #[test]
    fn i8_compute_plan_carries_requantized_adjacency() {
        let (_path, store, csr) = synthetic_store("i8plan");
        let env = ExecEnv::with_threads(2);
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: true,
            shard: None,
            shard_bounds: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::I8Compute, &spec, 8, &env).unwrap();
        let adj = plan.adj.expect("i8-compute host plan must build AdjQuant");
        assert_eq!(adj.units.len(), 1, "unsharded plan carries one operand");
        assert_eq!(adj.units[0].row_scale.len(), csr.n_rows);
        assert!(plan.kernel.is_i8(), "observed kernel is from the i8 family");

        // Sharded route: one operand per shard unit, row-aligned.
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: true,
            shard: Some(ShardSpec::by_count(3)),
            shard_bounds: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::I8Compute, &spec, 8, &env).unwrap();
        let sharded = plan.sharded.expect("sharded requested");
        let adj = plan.adj.expect("sharded i8 plan builds per-unit operands");
        assert_eq!(adj.units.len(), sharded.shard_count());
        for (u, aq) in sharded.units().iter().zip(adj.units.iter()) {
            assert_eq!(aq.row_scale.len(), u.rows.len());
        }

        // Every other precision leaves the field empty.
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: false,
            shard: None,
            shard_bounds: None,
            shard_cache: None,
        };
        let plan = prepare_plan(&store, Precision::F32, &spec, 8, &env).unwrap();
        assert!(plan.adj.is_none());
    }

    #[test]
    fn invalidate_matching_drops_by_predicate_and_fences() {
        let cache: PlanCache<(u32, u32), u32> = PlanCache::new(8);
        for k in 0..6u32 {
            cache.insert((k % 2, k), Arc::new(k));
        }
        assert_eq!(cache.invalidate_matching(|&(family, _)| family == 0), 3);
        assert_eq!(cache.len(), 3);
        assert!(cache.peek(&(0, 0)).is_none());
        assert!(cache.peek(&(1, 1)).is_some());
        // The generation bump fences in-flight builds like invalidate().
        let (v, _) = cache
            .get_or_try_insert(&(0, 0), || {
                cache.invalidate_matching(|_| false); // bump, drop nothing
                Ok::<_, std::io::Error>(9)
            })
            .unwrap();
        assert_eq!(*v, 9);
        assert!(cache.peek(&(0, 0)).is_none(), "straddling build must not land");
    }

    /// The stale-insert regression (ISSUE 5's headline bugfix). The
    /// pre-fix cache had only the time-based generation fence, which
    /// misses the mutation TOCTOU: a builder binds its input graph at
    /// epoch N, the dataset advances to N+1 (publish happens *before*
    /// the cache invalidation, and the builder's generation snapshot can
    /// land *after* the bump), and the stale build then inserts under
    /// "last insert wins" — resurrecting a pre-mutation plan that the
    /// epoch-blind `get` happily serves forever. With epoch-versioned
    /// entries both halves close: the stale value is unreachable at
    /// N+1, and it can never clobber an already-rebuilt N+1 entry.
    #[test]
    fn stale_build_cannot_resurrect_after_epoch_advance() {
        // Half 1: builder bound epoch 0, delta already invalidated
        // (generation bumped) BEFORE the builder's cache transaction —
        // the exact interleaving the bare fence cannot see.
        let cache: PlanCache<&str, u32> = PlanCache::new(4);
        cache.invalidate_matching(|_| true); // the delta's scoped invalidation
        let (v, hit) = cache
            .get_or_try_insert_versioned(&"route", 0, || Ok::<_, std::io::Error>(7))
            .unwrap();
        // The pre-mutation caller is still served its (consistent,
        // epoch-0) result...
        assert_eq!((*v, hit), (7, false));
        // ...but lookups at the advanced epoch must NOT see it. Pre-fix
        // (epoch-blind get after a plain get_or_try_insert) this
        // returned the stale 7.
        assert!(
            cache.get_versioned(&"route", 1).is_none(),
            "stale plan resurrected: built against epoch 0, served at epoch 1"
        );
        assert_eq!(cache.stale(), 1, "the stale encounter is counted");
        // The entry stays resident (an advance_epoch may still re-tag
        // it) but a rebuild at the new epoch replaces it.
        let (v, hit) = cache
            .get_or_try_insert_versioned(&"route", 1, || Ok::<_, std::io::Error>(8))
            .unwrap();
        assert_eq!((*v, hit), (8, false));
        assert_eq!(cache.get_versioned(&"route", 1).as_deref(), Some(&8));

        // Half 2: the route was already rebuilt at epoch 1 (the
        // post-delta restage) while the stale build was in flight; the
        // stale insert must not clobber it ("last insert wins" did).
        let cache: PlanCache<&str, u32> = PlanCache::new(4);
        let (v, _) = cache
            .get_or_try_insert_versioned(&"route", 0, || {
                // Mid-build: delta applies and the restage lands N+1.
                cache.try_insert_versioned(&"route", Arc::new(99), 1, cache.generation());
                Ok::<_, std::io::Error>(7)
            })
            .unwrap();
        assert_eq!(*v, 7, "the stale builder's caller still gets its own result");
        assert_eq!(
            cache.get_versioned(&"route", 1).as_deref(),
            Some(&99),
            "newest-epoch-wins: the rebuilt plan survives the stale insert"
        );
    }

    #[test]
    fn versioned_lookups_keep_newer_entries_for_stale_readers() {
        let cache: PlanCache<&str, u32> = PlanCache::new(4);
        assert!(cache.try_insert_versioned(&"k", Arc::new(5), 3, cache.generation()));
        // A reader still bound to epoch 2 misses but must not evict the
        // newer value.
        assert!(cache.get_versioned(&"k", 2).is_none());
        assert_eq!(cache.get_versioned(&"k", 3).as_deref(), Some(&5));
        assert_eq!(cache.stale(), 0, "newer-than-reader entries are not stale");
        // peek_versioned is metric-silent and epoch-exact.
        assert!(cache.peek_versioned(&"k", 2).is_none());
        assert!(cache.peek_versioned(&"k", 3).is_some());
    }

    #[test]
    fn advance_epoch_drops_and_revalidates_atomically() {
        let cache: PlanCache<(u32, u32), u32> = PlanCache::new(8);
        for k in 0..4u32 {
            cache.try_insert_versioned(&(k % 2, k), Arc::new(k), 0, cache.generation());
        }
        // The delta touched family 0 only: family 0 drops, family 1 is
        // revalidated at epoch 1 — one atomic boundary.
        let gen_before = cache.generation();
        let (dropped, retagged) =
            cache.advance_epoch(|&(fam, _)| fam == 0, |&(fam, _)| fam == 1, 0, 1);
        assert_eq!((dropped, retagged), (2, 2));
        assert_eq!(cache.generation(), gen_before + 1, "the drop half fences builds");
        assert_eq!(cache.get_versioned(&(1, 1), 1).as_deref(), Some(&1));
        assert!(cache.get_versioned(&(0, 0), 1).is_none());
    }

    /// A racing stale build must not be *promoted* across an epoch
    /// boundary: advance_epoch only re-tags entries verifiably at the
    /// superseded epoch, so an entry tagged with any other epoch (a
    /// stale insert that slipped in post-fence) stays unreachable.
    #[test]
    fn advance_epoch_never_promotes_entries_from_other_epochs() {
        let cache: PlanCache<u32, u32> = PlanCache::new(8);
        // Entry at the current epoch 1, plus a stale straggler still
        // tagged 0 (a pre-mutation build that landed late).
        cache.try_insert_versioned(&1, Arc::new(10), 1, cache.generation());
        cache.try_insert_versioned(&2, Arc::new(99), 0, cache.generation());
        let (dropped, retagged) = cache.advance_epoch(|_| false, |_| true, 1, 2);
        assert_eq!((dropped, retagged), (0, 1), "only the epoch-1 entry is promoted");
        assert_eq!(cache.get_versioned(&1, 2).as_deref(), Some(&10));
        assert!(
            cache.get_versioned(&2, 2).is_none(),
            "the stale epoch-0 entry must not be served at epoch 2"
        );
    }

    #[test]
    fn take_matching_returns_the_dropped_keys_and_fences() {
        let cache: PlanCache<u32, u32> = PlanCache::new(8);
        for k in 0..5u32 {
            cache.insert(k, Arc::new(k));
        }
        let gen_before = cache.generation();
        let mut taken = cache.take_matching(|&k| k % 2 == 0);
        taken.sort_unstable();
        assert_eq!(taken, vec![0, 2, 4]);
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.generation(), gen_before + 1, "take fences like invalidate");
    }

    #[test]
    fn sharded_spec_builds_a_sharded_plan() {
        use crate::exec::{ShardKey, ShardUnit};
        use crate::graph::ShardSpec;

        let (_path, store, csr) = synthetic_store("sharded");
        let env = ExecEnv::with_threads(2);
        let units: PlanCache<ShardKey, ShardUnit> = PlanCache::new(32);
        let spec = PlanSpec {
            csr: &csr,
            width: Some(4),
            strategy: Strategy::Aes,
            host_ell: true,
            stream: false,
            shard: Some(ShardSpec::by_count(3)),
            shard_bounds: None,
            shard_cache: Some(ShardCacheRef {
                units: &units,
                tag: "synth",
                epoch: 0,
                vals: crate::runtime::ir::ModelVals::Gcn,
            }),
        };
        let plan = prepare_plan(&store, Precision::F32, &spec, 8, &env).unwrap();
        let sharded = plan.sharded.as_ref().expect("shard spec must shard the plan");
        assert_eq!(sharded.shard_count(), 3);
        assert_eq!(sharded.warm_units(), 0);
        assert!(plan.ell.is_none(), "the sharded plan replaces the whole-graph ELL");
        assert_eq!(units.len(), 3);

        // A second precision over the same route: plan rebuilt, every
        // shard unit warm — the shard-aware prefetch contract.
        let plan = prepare_plan(&store, Precision::U8Device, &spec, 8, &env).unwrap();
        assert_eq!(plan.sharded.unwrap().warm_units(), 3);
    }

    #[test]
    fn peek_neither_counts_nor_touches_recency() {
        let cache: PlanCache<u32, u32> = PlanCache::new(2);
        assert!(cache.peek(&1).is_none());
        cache.insert(1, Arc::new(10));
        cache.insert(2, Arc::new(20));
        assert_eq!(*cache.peek(&1).unwrap(), 10);
        assert_eq!((cache.hits(), cache.misses()), (0, 0), "peek is metric-silent");
        // Peeking 1 must NOT have refreshed it: inserting 3 evicts 1
        // (the least recently *used*), not 2.
        cache.insert(3, Arc::new(30));
        assert!(cache.peek(&1).is_none());
        assert!(cache.peek(&2).is_some());
    }

    #[test]
    fn cached_plan_skips_the_feature_store() {
        let (_path, store, csr) = synthetic_store("skip");
        let env = ExecEnv::with_threads(1);
        let cache: PlanCache<&'static str, ExecPlan> = PlanCache::new(4);
        let build = |precision| {
            let spec = PlanSpec {
                csr: &csr,
                width: Some(4),
                strategy: Strategy::Aes,
                host_ell: true,
                stream: false,
                shard: None,
                shard_bounds: None,
                shard_cache: None,
            };
            prepare_plan(&store, precision, &spec, 8, &env)
        };
        for round in 0..5 {
            let (_, hit) = cache.get_or_try_insert(&"route", || build(Precision::F32)).unwrap();
            assert_eq!(hit, round > 0);
        }
        // The store was touched exactly once despite five executions.
        assert_eq!(store.load_count(), 1);
        let (_, hit) = cache.get_or_try_insert(&"route8", || build(Precision::U8Device)).unwrap();
        assert!(!hit);
        assert_eq!(store.load_count(), 2);
    }
}
