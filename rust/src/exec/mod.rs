//! The execution layer — kernel dispatch, the persistent worker pool,
//! per-route plan caching, and async plan prefetch.
//!
//! # Purpose
//!
//! Everything above the raw kernels routes SpMM work through here; this
//! is where the serving stack turns the paper's two levers — adaptive
//! sampling and INT8 loading — into scheduling decisions.
//!
//! # Structure
//!
//! | unit         | role                                                  |
//! |--------------|-------------------------------------------------------|
//! | `dispatch`   | [`select_kernel`]: pick from the CPU SpMM zoo using graph statistics, feature dim, and the thread budget — the host-side analog of the paper's adaptive strategy table |
//! | `pool`       | [`Pool`]: spawn-once workers, per-worker queues + work stealing; replaces per-call `std::thread::scope` and the old lock-contended coordinator loop |
//! | `plan_cache` | [`PlanCache`] + [`ExecPlan`]: per-route staged features (zero-copy row-block handles on the streaming path), sampled ELL, kernel choice — behind an LRU with generation-fenced invalidation and epoch-versioned entries (live-graph mutation, `docs/mutation.md`) |
//! | `sharded`    | [`ShardedPlan`] + [`ShardUnit`]: working-set-budgeted row shards with per-shard sampling + dispatch, executed as independent pool tasks and merged by row concatenation; units cached per [`ShardKey`] so warm routes rebuild only cold shards; [`ShardLayout`] freezes the cuts across epochs so deltas re-sample only touched shards |
//! | `prefetch`   | [`Prefetcher`]: build the next route's plan on a private pool so feature staging overlaps the current batch's SpMM |
//! | `tune`       | [`CostModel`] + [`run_tune`]: measured kernel×format×precision selection table over quantized shard profiles (`repro tune`), installed process-wide and consulted by [`select_kernel_tuned`] with heuristic fallback (`docs/dispatch.md`) |
//!
//! # Rules
//!
//! * Kernels never probe the machine themselves — thread budgets flow
//!   down through [`ExecEnv`].
//! * Never call [`Pool::run`] from a task on the *same* pool; layered
//!   pools (coordinator → prefetch → global compute) are the intended
//!   topology, and the prefetcher documents why its pool is private.
//! * Plans are immutable once cached; republishing a dataset goes
//!   through `invalidate`, which also fences out in-flight builds.

#![warn(missing_docs)]

mod dispatch;
mod plan_cache;
mod pool;
mod prefetch;
mod sharded;
mod tune;

pub use dispatch::{
    run_blocked, run_blocked_i8, run_dense, run_dense_i8, run_ell, run_ell_i8, run_exact,
    run_exact_i8, select_kernel, select_kernel_i8, select_kernel_tuned, spmm_ell, spmm_exact,
    warm_pool, ExecEnv, FormatKind, FormatMask, GraphProfile, KernelDomain, KernelKind,
    PAR_MIN_FLOPS, ROWCACHE_MAX_ROW_NNZ, ROWCACHE_MIN_FEAT, ROWCACHE_MIN_MEAN_NNZ,
};
pub use tune::{
    cell_key, install_cost_model, install_cost_model_from, installed_cost_model,
    installed_fingerprint, run_tune, CostModel, Density, Family, FeatBand, ProfileBucket, Skew,
    TuneOptions, COST_MODEL_SCHEMA, COST_MODEL_VERSION, DENSE_TILE_SLACK,
};
pub use plan_cache::{prepare_plan, AdjQuantPlan, ExecPlan, PlanCache, PlanSpec};
pub use pool::{global as global_pool, Pool};
pub use prefetch::{PrefetchStats, PrefetchTicket, Prefetcher};
pub use sharded::{ShardCacheRef, ShardKey, ShardLayout, ShardSampling, ShardUnit, ShardedPlan};
