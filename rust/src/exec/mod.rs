//! The execution layer — kernel dispatch, the persistent worker pool,
//! and per-route plan caching (DESIGN: unified execution substrate).
//!
//! Everything above the raw kernels routes SpMM work through here:
//!
//! * [`dispatch`] — picks a kernel from graph statistics, feature dim,
//!   and the thread budget (the host-side analog of the paper's adaptive
//!   strategy table), replacing hard-coded kernel picks at call sites.
//! * [`pool`] — spawn-once worker pool with per-worker queues and work
//!   stealing; replaces per-call `std::thread::scope` in the SpMM /
//!   sampling kernels and the lock-contended worker loop in the
//!   coordinator.
//! * [`plan_cache`] — per-route [`ExecPlan`]s (loaded/quantized feature
//!   tensor, sampled ELL plan, kernel choice) behind an LRU, so warm
//!   routes stop re-reading features from disk every batch.

mod dispatch;
mod plan_cache;
mod pool;

pub use dispatch::{
    run_ell, run_exact, select_kernel, spmm_ell, spmm_exact, warm_pool, ExecEnv, GraphProfile,
    KernelKind, PAR_MIN_FLOPS, ROWCACHE_MIN_FEAT, ROWCACHE_MIN_MEAN_NNZ,
};
pub use plan_cache::{prepare_plan, ExecPlan, PlanCache, PlanSpec};
pub use pool::{global as global_pool, Pool};
