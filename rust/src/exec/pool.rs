//! Persistent worker pool — spawn once, park/unpark, per-worker queues
//! with work stealing.
//!
//! Replaces the two thread-management patterns the seed used on hot
//! paths: `std::thread::scope` (which spawns and joins OS threads on
//! every SpMM call) and the coordinator's `Mutex<Receiver>` loop (every
//! worker contending one lock for every batch). Here each worker owns a
//! deque; submits round-robin across them and idle workers steal from
//! their neighbours' tails, so an uneven split cannot strand work.
//!
//! Two entry points:
//! * [`Pool::spawn`] — detached `'static` job (coordinator batches).
//! * [`Pool::run`] — scoped fork-join over *borrowed* tasks (the SpMM /
//!   sampling row chunks). Blocks until every task finished; the caller
//!   executes one task inline, so progress is guaranteed even on a
//!   single-worker pool.
//!
//! Do not call [`Pool::run`] from inside a task running on the *same*
//! pool: the caller would block a worker slot while waiting. Layered use
//! (coordinator pool tasks fan out onto the global compute pool) is fine
//! and is exactly the intended topology.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    /// One deque per worker; submit round-robins, owners pop the front,
    /// thieves pop the back.
    queues: Vec<Mutex<VecDeque<Job>>>,
    /// Gate for sleep/wake handshakes (guards no data).
    gate: Mutex<()>,
    /// Signalled on submit and shutdown.
    work: Condvar,
    /// Signalled when `in_flight` drains to zero.
    idle: Condvar,
    shutdown: AtomicBool,
    /// Jobs submitted but not yet finished (queued + executing).
    in_flight: AtomicUsize,
    /// Round-robin submit cursor.
    next: AtomicUsize,
}

impl Shared {
    fn pop(&self, home: usize) -> Option<Job> {
        if let Some(job) = self.queues[home].lock().unwrap().pop_front() {
            return Some(job);
        }
        let n = self.queues.len();
        for k in 1..n {
            if let Some(job) = self.queues[(home + k) % n].lock().unwrap().pop_back() {
                return Some(job);
            }
        }
        None
    }

    fn queues_empty(&self) -> bool {
        self.queues.iter().all(|q| q.lock().unwrap().is_empty())
    }

    fn finish_one(&self) {
        if self.in_flight.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _gate = self.gate.lock().unwrap();
            self.idle.notify_all();
        }
    }
}

/// Completion latch for one [`Pool::run`] call.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

impl Latch {
    fn new(count: usize) -> Latch {
        Latch {
            remaining: Mutex::new(count),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn count_down(&self, ok: bool) {
        if !ok {
            self.panicked.store(true, Ordering::Release);
        }
        let mut left = self.remaining.lock().unwrap();
        *left -= 1;
        if *left == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut left = self.remaining.lock().unwrap();
        while *left > 0 {
            left = self.done.wait(left).unwrap();
        }
    }
}

/// Waits for the latch even if the caller's inline task panics, so no
/// borrowed task can outlive the `run` frame it borrows from.
struct WaitGuard<'a>(&'a Latch);

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// The persistent pool. Dropping it drains every queued job, then joins
/// the workers.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Spawn `threads.max(1)` parked workers.
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queues: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            gate: Mutex::new(()),
            work: Condvar::new(),
            idle: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            next: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|home| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("exec-pool-{home}"))
                    .spawn(move || worker_loop(&shared, home))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool { shared, workers }
    }

    /// Worker threads owned by this pool (fixed at construction).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Jobs submitted but not yet finished.
    pub fn in_flight(&self) -> usize {
        self.shared.in_flight.load(Ordering::Acquire)
    }

    /// Queue a detached job. Panics inside the job are caught (the worker
    /// survives); use [`Pool::run`] when you need panic propagation.
    pub fn spawn(&self, job: impl FnOnce() + Send + 'static) {
        self.submit(Box::new(job));
    }

    fn submit(&self, job: Job) {
        debug_assert!(!self.shared.shutdown.load(Ordering::Acquire), "submit after shutdown");
        self.shared.in_flight.fetch_add(1, Ordering::AcqRel);
        let slot = self.shared.next.fetch_add(1, Ordering::Relaxed) % self.shared.queues.len();
        self.shared.queues[slot].lock().unwrap().push_back(job);
        // Notify under the gate so a worker checking-then-waiting cannot
        // miss this submission.
        let _gate = self.shared.gate.lock().unwrap();
        self.shared.work.notify_one();
    }

    /// Scoped fork-join: execute borrowed tasks on the pool and block
    /// until all of them completed. The last task runs inline on the
    /// caller. Panics in any task are re-raised here after the join.
    pub fn run<'env>(&self, mut tasks: Vec<Box<dyn FnOnce() + Send + 'env>>) {
        let Some(inline) = tasks.pop() else { return };
        let latch = Arc::new(Latch::new(tasks.len()));
        let guard = WaitGuard(&latch);
        for task in tasks {
            let latch = latch.clone();
            let wrapped: Box<dyn FnOnce() + Send + 'env> = Box::new(move || {
                let ok = catch_unwind(AssertUnwindSafe(task)).is_ok();
                latch.count_down(ok);
            });
            // SAFETY: the borrowed lifetime is erased, but `guard` (and the
            // explicit wait below) blocks this frame until every wrapped
            // task has run — including when `inline` panics — so no task
            // can observe its borrows after they expire. The fat-pointer
            // layout of `Box<dyn FnOnce() + Send>` is lifetime-invariant.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 'env>, Job>(wrapped)
            };
            self.submit(job);
        }
        inline();
        drop(guard); // waits for the pool-side tasks
        if latch.panicked.load(Ordering::Acquire) {
            panic!("exec::Pool task panicked");
        }
    }

    /// Block until every submitted job has finished (the coordinator's
    /// drain-on-shutdown step).
    pub fn wait_idle(&self) {
        let mut gate = self.shared.gate.lock().unwrap();
        while self.shared.in_flight.load(Ordering::Acquire) > 0 {
            let (next, _) = self
                .shared
                .idle
                .wait_timeout(gate, Duration::from_millis(10))
                .unwrap();
            gate = next;
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        {
            let _gate = self.shared.gate.lock().unwrap();
            self.shared.work.notify_all();
        }
        for worker in self.workers.drain(..) {
            let _ = worker.join();
        }
    }
}

fn worker_loop(shared: &Shared, home: usize) {
    loop {
        if let Some(job) = shared.pop(home) {
            // Detached jobs must not kill the worker; `run` re-raises
            // panics on the caller via its latch.
            let _ = catch_unwind(AssertUnwindSafe(job));
            shared.finish_one();
            continue;
        }
        if shared.shutdown.load(Ordering::Acquire) {
            // Queues are drained (checked above) — exit.
            return;
        }
        let gate = shared.gate.lock().unwrap();
        // Re-check under the gate: submits notify while holding it, so a
        // job pushed between our pop attempt and here cannot be missed.
        if !shared.queues_empty() || shared.shutdown.load(Ordering::Acquire) {
            continue;
        }
        // Timeout is belt-and-braces against lost wakeups.
        let _ = shared.work.wait_timeout(gate, Duration::from_millis(50)).unwrap();
    }
}

/// The process-wide compute pool used by the data-parallel kernels
/// (SpMM row chunks, parallel sampling). Sized to the machine once;
/// callers asking for more parallelism than this simply queue.
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| Pool::new(super::ExecEnv::detect().threads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::sync::atomic::AtomicU64;
    use std::thread::ThreadId;

    #[test]
    fn runs_borrowed_tasks_to_completion() {
        let pool = Pool::new(4);
        let mut data = vec![0u64; 64];
        let chunks: Vec<&mut [u64]> = data.chunks_mut(8).collect();
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = chunks
            .into_iter()
            .enumerate()
            .map(|(k, chunk)| {
                Box::new(move || {
                    for (i, slot) in chunk.iter_mut().enumerate() {
                        *slot = (k * 8 + i) as u64;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(tasks);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn reuses_the_same_threads_across_calls() {
        let pool = Pool::new(3);
        let seen: Mutex<HashSet<ThreadId>> = Mutex::new(HashSet::new());
        for _ in 0..50 {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..6)
                .map(|_| {
                    Box::new(|| {
                        seen.lock().unwrap().insert(std::thread::current().id());
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(tasks);
        }
        // 50 scoped invocations × 6 tasks, but only pool workers + the
        // caller ever execute — the pool does not spawn per call.
        let distinct = seen.lock().unwrap().len();
        assert!(
            distinct <= pool.worker_count() + 1,
            "expected ≤ {} distinct threads, saw {distinct}",
            pool.worker_count() + 1
        );
    }

    #[test]
    fn spawn_and_wait_idle_drain() {
        let pool = Pool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let counter = counter.clone();
            pool.spawn(move || {
                counter.fetch_add(1, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
        assert_eq!(pool.in_flight(), 0);
    }

    #[test]
    fn drop_drains_queued_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = Pool::new(2);
            for _ in 0..50 {
                let counter = counter.clone();
                pool.spawn(move || {
                    counter.fetch_add(1, Ordering::Relaxed);
                });
            }
        } // drop joins after draining
        assert_eq!(counter.load(Ordering::Relaxed), 50);
    }

    #[test]
    fn single_worker_pool_makes_progress() {
        let pool = Pool::new(1);
        let mut out = vec![0u32; 4];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .iter_mut()
            .map(|slot| {
                Box::new(move || {
                    *slot = 9;
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run(tasks);
        assert_eq!(out, vec![9; 4]);
    }

    #[test]
    fn run_propagates_task_panics() {
        let pool = Pool::new(2);
        let result = catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|i| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("boom");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run(tasks);
        }));
        assert!(result.is_err(), "panic in a pool task must surface to the caller");
        // The pool stays usable afterwards.
        let flag = AtomicBool::new(false);
        pool.run(vec![Box::new(|| {
            flag.store(true, Ordering::Relaxed);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert!(flag.load(Ordering::Relaxed));
    }

    #[test]
    fn global_pool_is_shared() {
        assert!(std::ptr::eq(global(), global()));
        assert!(global().worker_count() >= 1);
    }
}
