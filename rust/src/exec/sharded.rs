//! Sharded execution plans — run one SpMM as independent per-shard
//! tasks on the persistent pool, with per-shard adaptive sampling and
//! per-shard kernel dispatch.
//!
//! The plan cache made routes cheap to *re*-execute; this makes a single
//! execution scale past one working set. A [`ShardedPlan`] holds one
//! prepared [`ShardUnit`] per [`crate::graph::GraphShard`]: the shard's
//! CSR slice, its sampled ELL at a **shard-local** tile width
//! ([`crate::sampling::shard_width`]), and the kernel the dispatcher
//! picked from the *shard's* statistics — so a skewed shard can run the
//! sampled ELL kernel while a uniform neighbor keeps every edge in a
//! shrunken exhaustive tile, and an exact route's long-row shard can
//! take the row-cache kernel while its short-row shards stay naive.
//!
//! Execution fans the units out as independent tasks on the global pool
//! and merges by row concatenation: each unit owns a disjoint row slice
//! of the output, so the merge is the `split_at_mut` — no combination
//! arithmetic, and per-row FP order identical to the unsharded kernels
//! (see `docs/sharding.md` for the exactness argument). That bitwise
//! guarantee is a **checked invariant**, not just a doc claim: the
//! accuracy-conformance grid (`crate::eval`, `tests/accuracy.rs`)
//! asserts sharded == unsharded logits bit-for-bit through the
//! coordinator for every strategy/width/precision it serves.
//!
//! Units are cached in a [`PlanCache<ShardKey, ShardUnit>`] shared
//! across routes: units depend only on (graph, value family, width,
//! strategy, row range) — not on precision or feature representation
//! — so a second
//! route over the same graph finds every unit warm, and a prefetch of a
//! partially-warm route builds **only the cold shards**. Under live
//! mutation the same machinery is the retention lever: resolution is
//! epoch-versioned (via [`ShardCacheRef`]), the serving partition is
//! frozen in a sticky [`ShardLayout`] so keys stay stable across
//! epochs, a delta invalidates only the units of shards it touched and
//! re-tags the rest to the new epoch — re-sampling (and re-running
//! [`crate::sampling::shard_width`]'s uniform/skewed decision) exactly
//! where the graph changed (`docs/mutation.md`).

use std::convert::Infallible;
use std::ops::Range;
use std::sync::Arc;

use crate::graph::{working_set_bytes, Csr, Ell, GraphShard, ShardPlan, ShardSpec};
use crate::runtime::ir::ModelVals;
use crate::sampling::{sample_ell, shard_width, Strategy, FP32_EDGE_BYTES};
use crate::spmm::{dense_tile_viable, AdjQuant, BlockedCsr, DenseTile, BCSR_BLOCK_ROWS};

use super::dispatch::{
    run_blocked, run_blocked_i8, run_dense, run_dense_i8, run_ell, run_ell_i8, run_exact,
    run_exact_i8, select_kernel_tuned, ExecEnv, FormatKind, FormatMask, GraphProfile, KernelDomain,
    KernelKind,
};
use super::plan_cache::{AdjQuantPlan, PlanCache};
use super::pool;
use super::tune;

/// Borrowed handle to the shared shard-unit cache, plus the identity of
/// the graph the units are for: the dataset `tag` and the graph `epoch`
/// the requesting route is bound to. Unit lookups and inserts go through
/// the cache's **versioned** API, so a unit built against a superseded
/// epoch can neither be served nor clobber a rebuilt one (see
/// `docs/mutation.md`).
#[derive(Clone, Copy)]
pub struct ShardCacheRef<'a> {
    /// The shared unit cache.
    pub units: &'a PlanCache<ShardKey, ShardUnit>,
    /// Graph identity (the coordinator uses the dataset name).
    pub tag: &'a str,
    /// Graph epoch the requesting route's dataset snapshot carries.
    pub epoch: u64,
    /// Value family of the operand the route aggregates with. Units
    /// carry their CSR slice's **values**, so a GCN-normalized (Â)
    /// route and an all-ones (GraphSAGE mean) route over the same graph
    /// must never share a unit — the family is part of the key.
    pub vals: ModelVals,
}

/// The sticky serving partition of one dataset: cut points derived once
/// (from the graph as first served) and reused across epochs, so a
/// delta's shard-scoped invalidation has stable [`ShardKey`]s to aim at
/// and untouched units stay warm. Re-cut only when
/// [`ShardLayout::drifted`] reports a touched shard outgrew its
/// working-set budget.
#[derive(Clone, Debug)]
pub struct ShardLayout {
    bounds: Vec<Range<usize>>,
    /// Per-shard drift budgets, parallel to `bounds`.
    budgets: Vec<usize>,
}

impl ShardLayout {
    /// Derive the cut points `ShardPlan::partition` would use (via
    /// [`crate::graph::partition_bounds`] — no shard extraction, so
    /// creating a layout is O(n_rows), not O(nnz) of copies) and
    /// freeze them, with a **per-shard** drift budget:
    /// * budget-based specs allow each shard 2× the configured working
    ///   set (the same slack the partitioner's row granularity already
    ///   implies), floored at 2× that shard's *birth* working set — so
    ///   a mega-row shard that is born over budget still gets growth
    ///   room instead of forcing a futile full re-partition on every
    ///   delta that touches it (re-cutting cannot shrink an
    ///   unsplittable row);
    /// * count-based specs (whose byte budget is only the reporting
    ///   default) allow each shard 2× its own birth working set.
    pub fn of(csr: &Csr, spec: &ShardSpec) -> ShardLayout {
        let bounds = crate::graph::partition_bounds(csr, spec);
        let budgets = bounds
            .iter()
            .map(|r| {
                let nnz = (csr.row_ptr[r.end] - csr.row_ptr[r.start]) as usize;
                let birth_slack = working_set_bytes(r.len(), nnz).saturating_mul(2).max(1);
                match spec.shards {
                    Some(_) => birth_slack,
                    None => spec.budget_bytes.saturating_mul(2).max(birth_slack),
                }
            })
            .collect();
        ShardLayout { bounds, budgets }
    }

    /// The frozen cut points, in row order.
    pub fn bounds(&self) -> &[Range<usize>] {
        &self.bounds
    }

    /// Shards in the layout.
    pub fn shard_count(&self) -> usize {
        self.bounds.len()
    }

    /// Rows this layout covers (the graph's row count at freeze time).
    /// A published graph whose row count no longer matches cannot use
    /// this layout — callers must rebuild it.
    pub fn n_rows(&self) -> usize {
        self.bounds.last().map(|r| r.end).unwrap_or(0)
    }

    /// Whether this layout's cuts apply to `csr` (edge deltas never
    /// change the row count, so a mismatch means a wholesale republish
    /// swapped in a differently-shaped graph).
    pub fn covers(&self, csr: &Csr) -> bool {
        self.n_rows() == csr.n_rows
    }

    /// Map **sorted** touched row ids to the indices of the shards that
    /// contain them (sorted, unique) — the delta's invalidation scope.
    pub fn affected_shards(&self, touched_rows: &[usize]) -> Vec<usize> {
        debug_assert!(touched_rows.windows(2).all(|w| w[0] <= w[1]));
        let mut out = Vec::new();
        let mut shard = 0usize;
        for &row in touched_rows {
            while shard < self.bounds.len() && self.bounds[shard].end <= row {
                shard += 1;
            }
            if shard >= self.bounds.len() {
                break; // rows past the layout (caller validated ranges)
            }
            if self.bounds[shard].contains(&row) && out.last() != Some(&shard) {
                out.push(shard);
            }
        }
        out
    }

    /// Whether any of the `affected` shards' working sets now exceed
    /// their per-shard budget under the mutated graph — the signal to
    /// throw the cuts away and re-partition (invalidating every unit).
    /// Callers must have checked [`ShardLayout::covers`] first.
    pub fn drifted(&self, csr: &Csr, affected: &[usize]) -> bool {
        debug_assert!(self.covers(csr), "drift check against a layout for another graph");
        affected.iter().any(|&i| {
            let r = &self.bounds[i];
            let nnz = (csr.row_ptr[r.end] - csr.row_ptr[r.start]) as usize;
            working_set_bytes(r.len(), nnz) > self.budgets[i]
        })
    }
}

/// Cache key for one prepared [`ShardUnit`]. Deliberately excludes
/// precision and feature state: units are pure graph structure, shared
/// by every route over the same operand.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShardKey {
    /// Graph identity (the coordinator uses the dataset name).
    pub tag: String,
    /// The route's global sampling width (`None` = exact aggregation).
    pub width: Option<usize>,
    /// Sampling strategy; normalized to `None` for exact units, which
    /// are strategy-independent.
    pub strategy: Option<Strategy>,
    /// Global row range `[start, end)` the unit covers.
    pub rows: (usize, usize),
    /// Value family of the aggregation operand (Â vs all-ones) — the
    /// unit's CSR/ELL slices carry these values, so families must not
    /// alias. Not encoded in `tag`, which names the graph *structure*.
    pub vals: ModelVals,
    /// Fingerprint of the cost model installed when the key was made
    /// (0 = heuristics). Units record which selection table shaped
    /// their materialized formats, so swapping in a new model (or
    /// uninstalling one) can never serve a unit tuned for the old one.
    pub model: u64,
}

impl ShardKey {
    /// Normalized constructor (drops the strategy for exact units);
    /// stamps the currently installed cost-model fingerprint.
    pub fn new(
        tag: &str,
        width: Option<usize>,
        strategy: Strategy,
        rows: &Range<usize>,
        vals: ModelVals,
    ) -> ShardKey {
        ShardKey {
            tag: tag.to_string(),
            width,
            strategy: width.map(|_| strategy),
            rows: (rows.start, rows.end),
            vals,
            model: tune::installed_fingerprint(),
        }
    }
}

/// How a shard's edges are treated — the per-shard sampling decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardSampling {
    /// Exact aggregation (the route has no sampling width).
    Exact,
    /// Every row fits the global tile: sampling keeps all edges, and the
    /// tile shrank to the shard-local `width` (≤ the global W).
    Exhaustive {
        /// Shard-local ELL width.
        width: usize,
    },
    /// Rows overflow the tile: the route's strategy decides which edges
    /// survive (paper Table 1 + Eq. 3), at the full global width.
    Sampled {
        /// Global ELL width (unshrunken — sampled rows must match the
        /// unsharded plan bit-for-bit).
        width: usize,
        /// The route's edge-sampling strategy.
        strategy: Strategy,
    },
}

impl ShardSampling {
    /// The unit's ELL width (`None` for exact units).
    pub fn width(&self) -> Option<usize> {
        match self {
            ShardSampling::Exact => None,
            ShardSampling::Exhaustive { width } | ShardSampling::Sampled { width, .. } => {
                Some(*width)
            }
        }
    }

    /// Stable label for logs and metrics.
    pub fn name(&self) -> &'static str {
        match self {
            ShardSampling::Exact => "exact",
            ShardSampling::Exhaustive { .. } => "exhaustive",
            ShardSampling::Sampled { .. } => "sampled",
        }
    }
}

/// One shard, fully prepared for execution.
#[derive(Clone, Debug)]
pub struct ShardUnit {
    /// Global row range this unit computes.
    pub rows: Range<usize>,
    /// The shard's rows as a standalone CSR (global columns).
    pub csr: Csr,
    /// Sampled fixed-width plan (`None` for exact units).
    pub ell: Option<Ell>,
    /// The per-shard sampling decision.
    pub sampling: ShardSampling,
    /// Statistics of the unit's aggregation operand (the ELL when
    /// sampled, else the CSR slice) — per-layer dispatch reads this.
    pub profile: GraphProfile,
    /// Blocked-CSR re-layout of the shard, materialized at build time
    /// when the (cost-model-aware) selector wants it for either
    /// precision domain. `None` for sampled units.
    pub bcsr: Option<BlockedCsr>,
    /// Dense-tile re-layout of the shard, materialized when viable
    /// ([`crate::exec::DENSE_TILE_SLACK`]) *and* selected. `None` for
    /// sampled units.
    pub dense: Option<DenseTile>,
    /// Kernel dispatched from the shard's profile at the plan's input
    /// feature dim (observability; execution re-selects per layer, an
    /// O(1) decision). Always a serial kernel — shards *are* the
    /// parallelism.
    pub kernel: KernelKind,
}

impl ShardUnit {
    /// Which re-layouts this unit materialized — the per-shard format
    /// mask execution passes back into [`select_kernel_tuned`], so a
    /// cost model installed *after* the unit was built can never pick a
    /// layout the unit doesn't have.
    pub fn format_mask(&self) -> FormatMask {
        FormatMask { blocked: self.bcsr.is_some(), dense: self.dense.is_some() }
    }
}

/// Build one unit: per-shard tile width, per-shard sampling, per-shard
/// format materialization, per-shard dispatch.
fn build_unit(
    shard: GraphShard,
    width: Option<usize>,
    strategy: Strategy,
    feat_dim: usize,
) -> ShardUnit {
    let serial = ExecEnv::with_threads(1);
    let (ell, sampling) = match width {
        None => (None, ShardSampling::Exact),
        Some(w) => {
            let max_deg = shard.csr.max_degree();
            // Always the fp32 edge budget: units are shared across
            // precision siblings, so the tile decision must not depend
            // on the route's precision (see `sampling::shard_width`).
            let local = shard_width(w, max_deg, FP32_EDGE_BYTES);
            let sampling = if max_deg <= local {
                ShardSampling::Exhaustive { width: local }
            } else {
                ShardSampling::Sampled { width: local, strategy }
            };
            (Some(sample_ell(&shard.csr, local, strategy)), sampling)
        }
    };
    let profile = match &ell {
        Some(e) => GraphProfile::of_ell(e),
        None => GraphProfile::of(&shard.csr),
    };
    // Materialize alternative layouts only when the cost-model-aware
    // selector would actually run them for some precision domain —
    // units are shared across precision siblings, so probe both. With
    // no model installed the heuristics never pick a format kernel and
    // exact units stay plain CSR, bit-identical to the pre-tuned build.
    let (bcsr, dense) = match &ell {
        Some(_) => (None, None),
        None => {
            let probe = FormatMask {
                blocked: true,
                dense: dense_tile_viable(&shard.csr, tune::DENSE_TILE_SLACK),
            };
            let picks = [
                select_kernel_tuned(&profile, feat_dim, None, &serial, KernelDomain::F32, probe),
                select_kernel_tuned(&profile, feat_dim, None, &serial, KernelDomain::I8, probe),
            ];
            let want = |fk: FormatKind| picks.iter().any(|k| k.format() == fk);
            let bcsr = want(FormatKind::Blocked)
                .then(|| BlockedCsr::from_csr(&shard.csr, BCSR_BLOCK_ROWS));
            let dense = want(FormatKind::Dense).then(|| DenseTile::from_csr(&shard.csr));
            (bcsr, dense)
        }
    };
    let mask = FormatMask { blocked: bcsr.is_some(), dense: dense.is_some() };
    let kernel =
        select_kernel_tuned(&profile, feat_dim, sampling.width(), &serial, KernelDomain::F32, mask);
    ShardUnit { rows: shard.rows, csr: shard.csr, ell, sampling, profile, bcsr, dense, kernel }
}

/// Execute one unit's fp32 aggregation, routing on the chosen kernel's
/// operand format. The selector only returns format kernels inside the
/// unit's [`ShardUnit::format_mask`], so the `expect`s are structural.
fn run_unit(
    unit: &ShardUnit,
    kind: KernelKind,
    b: &[f32],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind.format() {
        FormatKind::Ell => {
            let e = unit.ell.as_ref().expect("sampled kernel on an exact unit");
            run_ell(kind, e, b, f, out, threads)
        }
        FormatKind::Csr => run_exact(kind, &unit.csr, b, f, out, threads),
        FormatKind::Blocked => {
            let m = unit.bcsr.as_ref().expect("blocked layout not materialized");
            run_blocked(kind, m, b, f, out, threads)
        }
        FormatKind::Dense => {
            let t = unit.dense.as_ref().expect("dense layout not materialized");
            run_dense(kind, t, b, f, out, threads)
        }
    }
}

/// [`run_unit`] in the quantized domain. `aq` is the unit's CSR- (or
/// ELL-) ordered requantized adjacency; the blocked and dense layouts
/// preserve canonical CSR edge order, so the same CSR-order `aq`
/// addresses them too.
fn run_unit_i8(
    unit: &ShardUnit,
    kind: KernelKind,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind.format() {
        FormatKind::Ell => {
            let e = unit.ell.as_ref().expect("sampled kernel on an exact unit");
            run_ell_i8(kind, e, aq, qb, f, out, threads)
        }
        FormatKind::Csr => run_exact_i8(kind, &unit.csr, aq, qb, f, out, threads),
        FormatKind::Blocked => {
            let m = unit.bcsr.as_ref().expect("blocked layout not materialized");
            run_blocked_i8(kind, m, aq, qb, f, out, threads)
        }
        FormatKind::Dense => {
            let t = unit.dense.as_ref().expect("dense layout not materialized");
            run_dense_i8(kind, t, aq, qb, f, out, threads)
        }
    }
}

/// Resolve one shard's unit: through the shared cache when one is
/// given (warm units skip re-sampling), else built directly. Returns
/// the unit and whether it came warm. Cached resolution is **epoch
/// versioned**: a warm hit requires the unit's tag to match the route's
/// graph epoch (deltas re-tag untouched shards instead of rebuilding
/// them), and a build bound to a superseded epoch can never land over a
/// newer unit.
fn resolve_unit(
    shard: GraphShard,
    width: Option<usize>,
    strategy: Strategy,
    feat_dim: usize,
    cache: Option<ShardCacheRef<'_>>,
) -> (Arc<ShardUnit>, bool) {
    match cache {
        Some(cr) => {
            let key = ShardKey::new(cr.tag, width, strategy, &shard.rows, cr.vals);
            cr.units
                .get_or_try_insert_versioned(&key, cr.epoch, || {
                    Ok::<_, Infallible>(build_unit(shard, width, strategy, feat_dim))
                })
                .unwrap()
        }
        None => (Arc::new(build_unit(shard, width, strategy, feat_dim)), false),
    }
}

/// A route's sharded execution plan: prepared units covering the whole
/// graph, in row order.
#[derive(Debug)]
pub struct ShardedPlan {
    n_rows: usize,
    n_cols: usize,
    units: Vec<Arc<ShardUnit>>,
    warm_units: usize,
}

impl ShardedPlan {
    /// Partition `csr` per `spec` and prepare every unit (sampling +
    /// dispatch), fanning unit builds out on the global pool.
    ///
    /// With a `cache`, each unit goes through the cache's versioned
    /// lookup keyed by [`ShardKey`] at the [`ShardCacheRef`]'s epoch:
    /// warm units are reused without re-sampling, so only cold shards
    /// pay a build — the shard-aware prefetch contract.
    pub fn prepare(
        csr: &Csr,
        spec: &ShardSpec,
        width: Option<usize>,
        strategy: Strategy,
        feat_dim: usize,
        cache: Option<ShardCacheRef<'_>>,
    ) -> ShardedPlan {
        let plan = ShardPlan::partition(csr, spec);
        Self::from_partition(plan, width, strategy, feat_dim, cache)
    }

    /// [`ShardedPlan::prepare`] along **fixed** cut points from a sticky
    /// [`ShardLayout`] — the live-mutation path: a mutated graph keeps
    /// its serving partition so untouched shards' [`ShardKey`]s keep
    /// matching (and their units stay warm) until the coordinator
    /// re-partitions on drift.
    pub fn prepare_with_bounds(
        csr: &Csr,
        bounds: &[Range<usize>],
        width: Option<usize>,
        strategy: Strategy,
        feat_dim: usize,
        cache: Option<ShardCacheRef<'_>>,
    ) -> ShardedPlan {
        let plan = ShardPlan::partition_fixed(csr, bounds);
        Self::from_partition(plan, width, strategy, feat_dim, cache)
    }

    fn from_partition(
        plan: ShardPlan,
        width: Option<usize>,
        strategy: Strategy,
        feat_dim: usize,
        cache: Option<ShardCacheRef<'_>>,
    ) -> ShardedPlan {
        let (n_rows, n_cols) = (plan.n_rows(), plan.n_cols());
        let shards = plan.into_shards();
        let mut slots: Vec<Option<(Arc<ShardUnit>, bool)>> =
            (0..shards.len()).map(|_| None).collect();
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .zip(shards)
            .map(|(slot, shard)| {
                Box::new(move || {
                    *slot = Some(resolve_unit(shard, width, strategy, feat_dim, cache));
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::global().run(tasks);

        let mut units = Vec::with_capacity(slots.len());
        let mut warm_units = 0usize;
        for slot in slots {
            let (unit, hit) = slot.expect("every shard build task ran");
            warm_units += hit as usize;
            units.push(unit);
        }
        ShardedPlan { n_rows, n_cols, units, warm_units }
    }

    /// Shards in this plan (≥ 1).
    pub fn shard_count(&self) -> usize {
        self.units.len()
    }

    /// Units that came warm from the shard cache when this plan was
    /// assembled (`shard_count - warm_units` were built cold).
    pub fn warm_units(&self) -> usize {
        self.warm_units
    }

    /// The prepared units, in row order.
    pub fn units(&self) -> &[Arc<ShardUnit>] {
        &self.units
    }

    /// Rows of the full graph (the concatenated output height).
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Global row bounds of each unit — the dense layers chunk their
    /// multiplies along the same cuts (`matmul_sharded`).
    pub fn bounds(&self) -> Vec<Range<usize>> {
        self.units.iter().map(|u| u.rows.clone()).collect()
    }

    /// Execute one aggregation over the plan: every unit runs as an
    /// independent task on the global pool, writing its own disjoint row
    /// slice of `out` (the row-concatenation merge). Per-unit kernels
    /// are re-selected from the cached profiles for this layer's
    /// `f`, restricted to the serial families — the shards are the
    /// parallelism. A single-unit plan runs inline with the caller's
    /// full thread budget instead.
    ///
    /// Must not be called from a task already on the global pool (the
    /// same layering rule as [`crate::exec::Pool::run`]).
    pub fn run(&self, b: &[f32], f: usize, out: &mut [f32], env: &ExecEnv) {
        assert_eq!(b.len(), self.n_cols * f);
        assert_eq!(out.len(), self.n_rows * f);
        if let [unit] = self.units.as_slice() {
            // The shard is the whole graph — use the thread budget.
            let width = unit.sampling.width();
            let mask = unit.format_mask();
            let kind =
                select_kernel_tuned(&unit.profile, f, width, env, KernelDomain::F32, mask);
            run_unit(unit, kind, b, f, out, env.threads);
            return;
        }
        let serial = ExecEnv::with_threads(1);
        let mut rest = out;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(self.units.len());
        for unit in &self.units {
            let (chunk, tail) = rest.split_at_mut(unit.rows.len() * f);
            rest = tail;
            tasks.push(Box::new(move || {
                let width = unit.sampling.width();
                let mask = unit.format_mask();
                let kind =
                    select_kernel_tuned(&unit.profile, f, width, &serial, KernelDomain::F32, mask);
                run_unit(unit, kind, b, f, chunk, 1);
            }));
        }
        pool::global().run(tasks);
    }

    /// [`ShardedPlan::run`] in the quantized domain: every unit runs its
    /// `i8×u8→i32` kernel over the matching [`AdjQuantPlan`] entry and
    /// the shared u8 feature codes, writing its disjoint row slice.
    /// Integer accumulation is exact, so the row-concatenation merge is
    /// bitwise-identical to the unsharded i8 kernels by construction.
    pub fn run_i8(
        &self,
        adj: &AdjQuantPlan,
        qb: &[u8],
        f: usize,
        out: &mut [f32],
        env: &ExecEnv,
    ) {
        assert_eq!(qb.len(), self.n_cols * f);
        assert_eq!(out.len(), self.n_rows * f);
        assert_eq!(
            adj.units.len(),
            self.units.len(),
            "AdjQuantPlan must carry one operand per shard unit"
        );
        if let ([unit], [aq]) = (self.units.as_slice(), adj.units.as_slice()) {
            let width = unit.sampling.width();
            let mask = unit.format_mask();
            let kind = select_kernel_tuned(&unit.profile, f, width, env, KernelDomain::I8, mask);
            run_unit_i8(unit, kind, aq, qb, f, out, env.threads);
            return;
        }
        let serial = ExecEnv::with_threads(1);
        let mut rest = out;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(self.units.len());
        for (unit, aq) in self.units.iter().zip(adj.units.iter()) {
            let (chunk, tail) = rest.split_at_mut(unit.rows.len() * f);
            rest = tail;
            tasks.push(Box::new(move || {
                let width = unit.sampling.width();
                let mask = unit.format_mask();
                let kind =
                    select_kernel_tuned(&unit.profile, f, width, &serial, KernelDomain::I8, mask);
                run_unit_i8(unit, kind, aq, qb, f, chunk, 1);
            }));
        }
        pool::global().run(tasks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Pcg32;
    use crate::spmm::testutil::random_graph_and_features;

    #[test]
    fn sharded_exact_run_is_bitwise_equal_to_unsharded() {
        // Dispatch never picks a kernel whose per-row FP order diverges
        // (rowcache is gated on ROWCACHE_MAX_ROW_NNZ), so the
        // row-concatenated merge is bitwise — see docs/sharding.md.
        let (g, b) = random_graph_and_features(250, 25.0, 16, 5);
        let env = ExecEnv::with_threads(4);
        let mut want = vec![0.0f32; g.n_rows * 16];
        crate::spmm::csr_naive(&g, &b, 16, &mut want);
        for k in [1usize, 2, 5, 9] {
            let plan = ShardedPlan::prepare(
                &g,
                &ShardSpec::by_count(k),
                None,
                Strategy::Aes,
                16,
                None,
            );
            assert_eq!(plan.shard_count(), k.min(g.n_rows));
            let mut got = vec![7.0f32; g.n_rows * 16];
            plan.run(&b, 16, &mut got, &env);
            assert_eq!(want, got, "exact sharded run must concatenate bit-for-bit (k={k})");
        }
    }

    #[test]
    fn sharded_sampled_run_is_bitwise_equal_to_unsharded() {
        let (g, b) = random_graph_and_features(350, 50.0, 8, 6);
        let env = ExecEnv::with_threads(4);
        for w in [8usize, 16] {
            for strat in Strategy::ALL {
                let ell = sample_ell(&g, w, strat);
                let mut want = vec![0.0f32; g.n_rows * 8];
                crate::spmm::ell_spmm(&ell, &b, 8, &mut want);
                let plan =
                    ShardedPlan::prepare(&g, &ShardSpec::by_count(4), Some(w), strat, 8, None);
                let mut got = vec![0.0f32; g.n_rows * 8];
                plan.run(&b, 8, &mut got, &env);
                assert_eq!(want, got, "sampled sharded run (w={w}, {strat:?})");
            }
        }
    }

    #[test]
    fn sharded_i8_run_is_bitwise_equal_to_unsharded() {
        // AdjQuant rows depend only on that row's (val, col) segment and
        // the feature chunk ranges, and integer accumulation is exact,
        // so per-shard requantization + row concatenation reproduces the
        // unsharded i8 kernels bit-for-bit.
        let (g, b) = random_graph_and_features(300, 30.0, 8, 11);
        let params = crate::quant::ChunkedParams::of_rows(&b, 300, 8, 50);
        let qb = params.quantize_rows(&b, 8);
        let env = ExecEnv::with_threads(4);
        for width in [None, Some(8usize)] {
            let mut want = vec![0.0f32; g.n_rows * 8];
            match width {
                Some(w) => {
                    let ell = sample_ell(&g, w, Strategy::Aes);
                    let aq = crate::spmm::AdjQuant::from_ell(&ell, &params);
                    crate::spmm::ell_spmm_i8(&ell, &aq, &qb, 8, &mut want);
                }
                None => {
                    let aq = crate::spmm::AdjQuant::from_csr(&g, &params);
                    crate::spmm::csr_spmm_i8(&g, &aq, &qb, 8, &mut want);
                }
            }
            for k in [1usize, 3, 5] {
                let plan = ShardedPlan::prepare(
                    &g,
                    &ShardSpec::by_count(k),
                    width,
                    Strategy::Aes,
                    8,
                    None,
                );
                let adj = AdjQuantPlan {
                    units: plan
                        .units()
                        .iter()
                        .map(|u| match &u.ell {
                            Some(e) => crate::spmm::AdjQuant::from_ell(e, &params),
                            None => crate::spmm::AdjQuant::from_csr(&u.csr, &params),
                        })
                        .collect(),
                };
                let mut got = vec![7.0f32; g.n_rows * 8];
                plan.run_i8(&adj, &qb, 8, &mut got, &env);
                assert_eq!(want, got, "i8 sharded run (width={width:?}, k={k})");
            }
        }
    }

    #[test]
    fn skewed_and_uniform_shards_pick_different_modes() {
        // Head: 60 uniform rows × deg 4 (240 edges). Tail: 4 rows ×
        // deg 60 (240 edges) — equal masses so the 2-way quantile cut
        // lands exactly on the uniform/skewed boundary.
        let mut triples = Vec::new();
        for r in 0..60i32 {
            for c in 0..4 {
                triples.push((r, c, 1.0));
            }
        }
        for r in 60..64i32 {
            for c in 0..60 {
                triples.push((r, (c * 3) % 200, 1.0));
            }
        }
        let g = crate::graph::coo_to_csr(64, 200, triples).unwrap();
        let plan =
            ShardedPlan::prepare(&g, &ShardSpec::by_count(2), Some(16), Strategy::Aes, 64, None);
        assert_eq!(plan.shard_count(), 2);
        let head = &plan.units()[0];
        let tail = plan.units().last().unwrap();
        // Uniform shard: exhaustive sampling in a shrunken tile.
        assert_eq!(head.sampling, ShardSampling::Exhaustive { width: 4 });
        // Skewed shard: the route's strategy at the full width.
        assert_eq!(
            tail.sampling,
            ShardSampling::Sampled { width: 16, strategy: Strategy::Aes }
        );
        assert!(head.kernel.is_sampled() && tail.kernel.is_sampled());
        assert!(!head.kernel.is_parallel() && !tail.kernel.is_parallel());
        assert_ne!(head.profile.max_nnz, tail.profile.max_nnz);
    }

    fn cache_ref<'a>(
        cache: &'a PlanCache<ShardKey, ShardUnit>,
        epoch: u64,
    ) -> Option<ShardCacheRef<'a>> {
        Some(ShardCacheRef { units: cache, tag: "ds", epoch, vals: ModelVals::Gcn })
    }

    #[test]
    fn shard_cache_reuses_units_across_routes_and_builds_only_cold_shards() {
        let mut rng = Pcg32::new(12);
        let g = gen::chung_lu(300, 20.0, 1.9, &mut rng);
        let cache: PlanCache<ShardKey, ShardUnit> = PlanCache::new(64);
        let spec = ShardSpec::by_count(4);

        let cold =
            ShardedPlan::prepare(&g, &spec, Some(8), Strategy::Aes, 16, cache_ref(&cache, 0));
        assert_eq!(cold.warm_units(), 0);
        assert_eq!(cache.len(), 4);

        // Same route again (e.g. another precision): every unit warm.
        let warm =
            ShardedPlan::prepare(&g, &spec, Some(8), Strategy::Aes, 16, cache_ref(&cache, 0));
        assert_eq!(warm.warm_units(), 4, "a warm route must not rebuild any shard");

        // A different width is a different unit family: all cold again,
        // but the old units stay resident.
        let other =
            ShardedPlan::prepare(&g, &spec, Some(16), Strategy::Aes, 16, cache_ref(&cache, 0));
        assert_eq!(other.warm_units(), 0);
        assert_eq!(cache.len(), 8);

        // Exact units ignore the strategy (normalized key).
        let a = ShardKey::new("ds", None, Strategy::Aes, &(0..10), ModelVals::Gcn);
        let b = ShardKey::new("ds", None, Strategy::Sfs, &(0..10), ModelVals::Gcn);
        assert_eq!(a, b);

        // ...but the operand's value family is never collapsed: an
        // all-ones (SAGE-mean) unit must not alias the Â unit.
        let ones = ShardKey::new("ds", None, Strategy::Aes, &(0..10), ModelVals::Ones);
        assert_ne!(a, ones);
    }

    #[test]
    fn unit_resolution_is_epoch_versioned() {
        let mut rng = Pcg32::new(21);
        let g = gen::chung_lu(200, 15.0, 2.0, &mut rng);
        let cache: PlanCache<ShardKey, ShardUnit> = PlanCache::new(64);
        let spec = ShardSpec::by_count(3);
        let layout = ShardLayout::of(&g, &spec);

        let cold = ShardedPlan::prepare_with_bounds(
            &g,
            layout.bounds(),
            Some(8),
            Strategy::Aes,
            16,
            cache_ref(&cache, 0),
        );
        assert_eq!((cold.shard_count(), cold.warm_units()), (3, 0));

        // A route bound to a newer epoch must not be served epoch-0
        // units: everything rebuilds...
        let bumped = ShardedPlan::prepare_with_bounds(
            &g,
            layout.bounds(),
            Some(8),
            Strategy::Aes,
            16,
            cache_ref(&cache, 1),
        );
        assert_eq!(bumped.warm_units(), 0, "epoch-0 units are stale at epoch 1");

        // ...unless the delta re-tagged them (untouched shards): then the
        // same lookups come warm.
        cache.advance_epoch(|_| false, |k| k.tag == "ds", 1, 2);
        let retagged = ShardedPlan::prepare_with_bounds(
            &g,
            layout.bounds(),
            Some(8),
            Strategy::Aes,
            16,
            cache_ref(&cache, 2),
        );
        assert_eq!(retagged.warm_units(), 3, "re-tagged units serve the new epoch");
    }

    #[test]
    fn layout_maps_touched_rows_to_shards_and_detects_drift() {
        let mut rng = Pcg32::new(33);
        let g = gen::chung_lu(400, 10.0, 2.1, &mut rng);
        let layout = ShardLayout::of(&g, &ShardSpec::by_count(4));
        assert_eq!(layout.shard_count(), 4);
        let bounds = layout.bounds().to_vec();

        // Row → owning shard, duplicates collapse, order preserved.
        let mid = |r: &Range<usize>| (r.start + r.end) / 2;
        let touched = vec![0, 1, mid(&bounds[2]), bounds[3].start, g.n_rows - 1];
        assert_eq!(layout.affected_shards(&touched), vec![0, 2, 3]);
        assert_eq!(layout.affected_shards(&[]), Vec::<usize>::new());

        // No drift under a value-only mutation...
        assert!(!layout.drifted(&g, &[0, 1, 2, 3]));
        // ...but a shard bloated past 2× its birth working set trips
        // it: pour ~30 extra distinct edges into every shard-0 row
        // (far more than the ~10 it was born with).
        let mut triples: Vec<(i32, i32, f32)> = Vec::new();
        for r in 0..g.n_rows {
            for e in g.row_range(r) {
                triples.push((r as i32, g.col_ind[e], g.val[e]));
            }
        }
        for r in bounds[0].clone() {
            for k in 0..30usize {
                triples.push((r as i32, ((r * 7 + k * 13) % g.n_cols) as i32, 0.5));
            }
        }
        let bloated = crate::graph::coo_to_csr(g.n_rows, g.n_cols, triples).unwrap();
        assert!(layout.drifted(&bloated, &[0]), "a 3×-grown shard must trip the drift check");
        assert!(!layout.drifted(&bloated, &[1, 2, 3]), "other shards did not drift");
    }

    #[test]
    fn empty_graph_plan_runs_without_panic() {
        let g = Csr::new(0, 3, vec![0], vec![], vec![]).unwrap();
        let plan = ShardedPlan::prepare(&g, &ShardSpec::default(), Some(4), Strategy::Aes, 4, None);
        assert_eq!(plan.shard_count(), 1);
        let b = vec![1.0f32; 3 * 4];
        let mut out = Vec::new();
        plan.run(&b, 4, &mut out, &ExecEnv::with_threads(2));
        assert!(out.is_empty());
    }
}
