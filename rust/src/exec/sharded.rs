//! Sharded execution plans — run one SpMM as independent per-shard
//! tasks on the persistent pool, with per-shard adaptive sampling and
//! per-shard kernel dispatch.
//!
//! The plan cache made routes cheap to *re*-execute; this makes a single
//! execution scale past one working set. A [`ShardedPlan`] holds one
//! prepared [`ShardUnit`] per [`crate::graph::GraphShard`]: the shard's
//! CSR slice, its sampled ELL at a **shard-local** tile width
//! ([`crate::sampling::shard_width`]), and the kernel the dispatcher
//! picked from the *shard's* statistics — so a skewed shard can run the
//! sampled ELL kernel while a uniform neighbor keeps every edge in a
//! shrunken exhaustive tile, and an exact route's long-row shard can
//! take the row-cache kernel while its short-row shards stay naive.
//!
//! Execution fans the units out as independent tasks on the global pool
//! and merges by row concatenation: each unit owns a disjoint row slice
//! of the output, so the merge is the `split_at_mut` — no combination
//! arithmetic, and per-row FP order identical to the unsharded kernels
//! (see `docs/sharding.md` for the exactness argument). That bitwise
//! guarantee is a **checked invariant**, not just a doc claim: the
//! accuracy-conformance grid (`crate::eval`, `tests/accuracy.rs`)
//! asserts sharded == unsharded logits bit-for-bit through the
//! coordinator for every strategy/width/precision it serves.
//!
//! Units are cached in a [`PlanCache<ShardKey, ShardUnit>`] shared
//! across routes: units depend only on (graph, width, strategy, row
//! range) — not on precision or feature representation — so a second
//! route over the same graph finds every unit warm, and a prefetch of a
//! partially-warm route builds **only the cold shards**.

use std::convert::Infallible;
use std::ops::Range;
use std::sync::Arc;

use crate::graph::{Csr, Ell, GraphShard, ShardPlan, ShardSpec};
use crate::sampling::{sample_ell, shard_width, Strategy};

use super::dispatch::{run_ell, run_exact, select_kernel, ExecEnv, GraphProfile, KernelKind};
use super::plan_cache::PlanCache;
use super::pool;

/// Cache key for one prepared [`ShardUnit`]. Deliberately excludes
/// precision and feature state: units are pure graph structure, shared
/// by every route over the same operand.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ShardKey {
    /// Graph identity (the coordinator uses the dataset name).
    pub tag: String,
    /// The route's global sampling width (`None` = exact aggregation).
    pub width: Option<usize>,
    /// Sampling strategy; normalized to `None` for exact units, which
    /// are strategy-independent.
    pub strategy: Option<Strategy>,
    /// Global row range `[start, end)` the unit covers.
    pub rows: (usize, usize),
}

impl ShardKey {
    /// Normalized constructor (drops the strategy for exact units).
    pub fn new(
        tag: &str,
        width: Option<usize>,
        strategy: Strategy,
        rows: &Range<usize>,
    ) -> ShardKey {
        ShardKey {
            tag: tag.to_string(),
            width,
            strategy: width.map(|_| strategy),
            rows: (rows.start, rows.end),
        }
    }
}

/// How a shard's edges are treated — the per-shard sampling decision.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardSampling {
    /// Exact aggregation (the route has no sampling width).
    Exact,
    /// Every row fits the global tile: sampling keeps all edges, and the
    /// tile shrank to the shard-local `width` (≤ the global W).
    Exhaustive {
        /// Shard-local ELL width.
        width: usize,
    },
    /// Rows overflow the tile: the route's strategy decides which edges
    /// survive (paper Table 1 + Eq. 3), at the full global width.
    Sampled {
        /// Global ELL width (unshrunken — sampled rows must match the
        /// unsharded plan bit-for-bit).
        width: usize,
        /// The route's edge-sampling strategy.
        strategy: Strategy,
    },
}

impl ShardSampling {
    /// The unit's ELL width (`None` for exact units).
    pub fn width(&self) -> Option<usize> {
        match self {
            ShardSampling::Exact => None,
            ShardSampling::Exhaustive { width } | ShardSampling::Sampled { width, .. } => {
                Some(*width)
            }
        }
    }

    /// Stable label for logs and metrics.
    pub fn name(&self) -> &'static str {
        match self {
            ShardSampling::Exact => "exact",
            ShardSampling::Exhaustive { .. } => "exhaustive",
            ShardSampling::Sampled { .. } => "sampled",
        }
    }
}

/// One shard, fully prepared for execution.
#[derive(Clone, Debug)]
pub struct ShardUnit {
    /// Global row range this unit computes.
    pub rows: Range<usize>,
    /// The shard's rows as a standalone CSR (global columns).
    pub csr: Csr,
    /// Sampled fixed-width plan (`None` for exact units).
    pub ell: Option<Ell>,
    /// The per-shard sampling decision.
    pub sampling: ShardSampling,
    /// Statistics of the unit's aggregation operand (the ELL when
    /// sampled, else the CSR slice) — per-layer dispatch reads this.
    pub profile: GraphProfile,
    /// Kernel dispatched from the shard's profile at the plan's input
    /// feature dim (observability; execution re-selects per layer, an
    /// O(1) decision). Always a serial kernel — shards *are* the
    /// parallelism.
    pub kernel: KernelKind,
}

/// Build one unit: per-shard tile width, per-shard sampling, per-shard
/// dispatch.
fn build_unit(
    shard: GraphShard,
    width: Option<usize>,
    strategy: Strategy,
    feat_dim: usize,
) -> ShardUnit {
    let serial = ExecEnv::with_threads(1);
    let (ell, sampling) = match width {
        None => (None, ShardSampling::Exact),
        Some(w) => {
            let max_deg = shard.csr.max_degree();
            let local = shard_width(w, max_deg);
            let sampling = if max_deg <= local {
                ShardSampling::Exhaustive { width: local }
            } else {
                ShardSampling::Sampled { width: local, strategy }
            };
            (Some(sample_ell(&shard.csr, local, strategy)), sampling)
        }
    };
    let profile = match &ell {
        Some(e) => GraphProfile::of_ell(e),
        None => GraphProfile::of(&shard.csr),
    };
    let kernel = select_kernel(&profile, feat_dim, sampling.width(), &serial);
    ShardUnit { rows: shard.rows, csr: shard.csr, ell, sampling, profile, kernel }
}

/// Resolve one shard's unit: through the shared cache when one is
/// given (warm units skip re-sampling), else built directly. Returns
/// the unit and whether it came warm.
fn resolve_unit(
    shard: GraphShard,
    width: Option<usize>,
    strategy: Strategy,
    feat_dim: usize,
    cache: Option<(&PlanCache<ShardKey, ShardUnit>, &str)>,
) -> (Arc<ShardUnit>, bool) {
    match cache {
        Some((units, tag)) => {
            let key = ShardKey::new(tag, width, strategy, &shard.rows);
            units
                .get_or_try_insert(&key, || {
                    Ok::<_, Infallible>(build_unit(shard, width, strategy, feat_dim))
                })
                .unwrap()
        }
        None => (Arc::new(build_unit(shard, width, strategy, feat_dim)), false),
    }
}

/// A route's sharded execution plan: prepared units covering the whole
/// graph, in row order.
#[derive(Debug)]
pub struct ShardedPlan {
    n_rows: usize,
    n_cols: usize,
    units: Vec<Arc<ShardUnit>>,
    warm_units: usize,
}

impl ShardedPlan {
    /// Partition `csr` per `spec` and prepare every unit (sampling +
    /// dispatch), fanning unit builds out on the global pool.
    ///
    /// With a `cache`, each unit goes through
    /// [`PlanCache::get_or_try_insert`] keyed by [`ShardKey`]: warm
    /// units are reused without re-sampling, so only cold shards pay a
    /// build — the shard-aware prefetch contract. The `&str` is the
    /// graph identity tag (dataset name).
    pub fn prepare(
        csr: &Csr,
        spec: &ShardSpec,
        width: Option<usize>,
        strategy: Strategy,
        feat_dim: usize,
        cache: Option<(&PlanCache<ShardKey, ShardUnit>, &str)>,
    ) -> ShardedPlan {
        let plan = ShardPlan::partition(csr, spec);
        let (n_rows, n_cols) = (plan.n_rows(), plan.n_cols());
        let shards = plan.into_shards();
        let mut slots: Vec<Option<(Arc<ShardUnit>, bool)>> =
            (0..shards.len()).map(|_| None).collect();
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .zip(shards)
            .map(|(slot, shard)| {
                Box::new(move || {
                    *slot = Some(resolve_unit(shard, width, strategy, feat_dim, cache));
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool::global().run(tasks);

        let mut units = Vec::with_capacity(slots.len());
        let mut warm_units = 0usize;
        for slot in slots {
            let (unit, hit) = slot.expect("every shard build task ran");
            warm_units += hit as usize;
            units.push(unit);
        }
        ShardedPlan { n_rows, n_cols, units, warm_units }
    }

    /// Shards in this plan (≥ 1).
    pub fn shard_count(&self) -> usize {
        self.units.len()
    }

    /// Units that came warm from the shard cache when this plan was
    /// assembled (`shard_count - warm_units` were built cold).
    pub fn warm_units(&self) -> usize {
        self.warm_units
    }

    /// The prepared units, in row order.
    pub fn units(&self) -> &[Arc<ShardUnit>] {
        &self.units
    }

    /// Rows of the full graph (the concatenated output height).
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Global row bounds of each unit — the dense layers chunk their
    /// multiplies along the same cuts (`matmul_sharded`).
    pub fn bounds(&self) -> Vec<Range<usize>> {
        self.units.iter().map(|u| u.rows.clone()).collect()
    }

    /// Execute one aggregation over the plan: every unit runs as an
    /// independent task on the global pool, writing its own disjoint row
    /// slice of `out` (the row-concatenation merge). Per-unit kernels
    /// are re-selected from the cached profiles for this layer's
    /// `f`, restricted to the serial families — the shards are the
    /// parallelism. A single-unit plan runs inline with the caller's
    /// full thread budget instead.
    ///
    /// Must not be called from a task already on the global pool (the
    /// same layering rule as [`crate::exec::Pool::run`]).
    pub fn run(&self, b: &[f32], f: usize, out: &mut [f32], env: &ExecEnv) {
        assert_eq!(b.len(), self.n_cols * f);
        assert_eq!(out.len(), self.n_rows * f);
        if let [unit] = self.units.as_slice() {
            // The shard is the whole graph — use the thread budget.
            let kind = select_kernel(&unit.profile, f, unit.sampling.width(), env);
            match &unit.ell {
                Some(e) => run_ell(kind, e, b, f, out, env.threads),
                None => run_exact(kind, &unit.csr, b, f, out, env.threads),
            }
            return;
        }
        let serial = ExecEnv::with_threads(1);
        let mut rest = out;
        let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(self.units.len());
        for unit in &self.units {
            let (chunk, tail) = rest.split_at_mut(unit.rows.len() * f);
            rest = tail;
            tasks.push(Box::new(move || {
                let kind = select_kernel(&unit.profile, f, unit.sampling.width(), &serial);
                match &unit.ell {
                    Some(e) => run_ell(kind, e, b, f, chunk, 1),
                    None => run_exact(kind, &unit.csr, b, f, chunk, 1),
                }
            }));
        }
        pool::global().run(tasks);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Pcg32;
    use crate::spmm::testutil::random_graph_and_features;

    #[test]
    fn sharded_exact_run_is_bitwise_equal_to_unsharded() {
        // Dispatch never picks a kernel whose per-row FP order diverges
        // (rowcache is gated on ROWCACHE_MAX_ROW_NNZ), so the
        // row-concatenated merge is bitwise — see docs/sharding.md.
        let (g, b) = random_graph_and_features(250, 25.0, 16, 5);
        let env = ExecEnv::with_threads(4);
        let mut want = vec![0.0f32; g.n_rows * 16];
        crate::spmm::csr_naive(&g, &b, 16, &mut want);
        for k in [1usize, 2, 5, 9] {
            let plan = ShardedPlan::prepare(
                &g,
                &ShardSpec::by_count(k),
                None,
                Strategy::Aes,
                16,
                None,
            );
            assert_eq!(plan.shard_count(), k.min(g.n_rows));
            let mut got = vec![7.0f32; g.n_rows * 16];
            plan.run(&b, 16, &mut got, &env);
            assert_eq!(want, got, "exact sharded run must concatenate bit-for-bit (k={k})");
        }
    }

    #[test]
    fn sharded_sampled_run_is_bitwise_equal_to_unsharded() {
        let (g, b) = random_graph_and_features(350, 50.0, 8, 6);
        let env = ExecEnv::with_threads(4);
        for w in [8usize, 16] {
            for strat in Strategy::ALL {
                let ell = sample_ell(&g, w, strat);
                let mut want = vec![0.0f32; g.n_rows * 8];
                crate::spmm::ell_spmm(&ell, &b, 8, &mut want);
                let plan =
                    ShardedPlan::prepare(&g, &ShardSpec::by_count(4), Some(w), strat, 8, None);
                let mut got = vec![0.0f32; g.n_rows * 8];
                plan.run(&b, 8, &mut got, &env);
                assert_eq!(want, got, "sampled sharded run (w={w}, {strat:?})");
            }
        }
    }

    #[test]
    fn skewed_and_uniform_shards_pick_different_modes() {
        // Head: 60 uniform rows × deg 4 (240 edges). Tail: 4 rows ×
        // deg 60 (240 edges) — equal masses so the 2-way quantile cut
        // lands exactly on the uniform/skewed boundary.
        let mut triples = Vec::new();
        for r in 0..60i32 {
            for c in 0..4 {
                triples.push((r, c, 1.0));
            }
        }
        for r in 60..64i32 {
            for c in 0..60 {
                triples.push((r, (c * 3) % 200, 1.0));
            }
        }
        let g = crate::graph::coo_to_csr(64, 200, triples).unwrap();
        let plan =
            ShardedPlan::prepare(&g, &ShardSpec::by_count(2), Some(16), Strategy::Aes, 64, None);
        assert_eq!(plan.shard_count(), 2);
        let head = &plan.units()[0];
        let tail = plan.units().last().unwrap();
        // Uniform shard: exhaustive sampling in a shrunken tile.
        assert_eq!(head.sampling, ShardSampling::Exhaustive { width: 4 });
        // Skewed shard: the route's strategy at the full width.
        assert_eq!(
            tail.sampling,
            ShardSampling::Sampled { width: 16, strategy: Strategy::Aes }
        );
        assert!(head.kernel.is_sampled() && tail.kernel.is_sampled());
        assert!(!head.kernel.is_parallel() && !tail.kernel.is_parallel());
        assert_ne!(head.profile.max_nnz, tail.profile.max_nnz);
    }

    #[test]
    fn shard_cache_reuses_units_across_routes_and_builds_only_cold_shards() {
        let mut rng = Pcg32::new(12);
        let g = gen::chung_lu(300, 20.0, 1.9, &mut rng);
        let cache: PlanCache<ShardKey, ShardUnit> = PlanCache::new(64);
        let spec = ShardSpec::by_count(4);

        let cold =
            ShardedPlan::prepare(&g, &spec, Some(8), Strategy::Aes, 16, Some((&cache, "ds")));
        assert_eq!(cold.warm_units(), 0);
        assert_eq!(cache.len(), 4);

        // Same route again (e.g. another precision): every unit warm.
        let warm =
            ShardedPlan::prepare(&g, &spec, Some(8), Strategy::Aes, 16, Some((&cache, "ds")));
        assert_eq!(warm.warm_units(), 4, "a warm route must not rebuild any shard");

        // A different width is a different unit family: all cold again,
        // but the old units stay resident.
        let other =
            ShardedPlan::prepare(&g, &spec, Some(16), Strategy::Aes, 16, Some((&cache, "ds")));
        assert_eq!(other.warm_units(), 0);
        assert_eq!(cache.len(), 8);

        // Exact units ignore the strategy (normalized key).
        let a = ShardKey::new("ds", None, Strategy::Aes, &(0..10));
        let b = ShardKey::new("ds", None, Strategy::Sfs, &(0..10));
        assert_eq!(a, b);
    }

    #[test]
    fn empty_graph_plan_runs_without_panic() {
        let g = Csr::new(0, 3, vec![0], vec![], vec![]).unwrap();
        let plan = ShardedPlan::prepare(&g, &ShardSpec::default(), Some(4), Strategy::Aes, 4, None);
        assert_eq!(plan.shard_count(), 1);
        let b = vec![1.0f32; 3 * 4];
        let mut out = Vec::new();
        plan.run(&b, 4, &mut out, &ExecEnv::with_threads(2));
        assert!(out.is_empty());
    }
}
