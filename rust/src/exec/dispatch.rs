//! Adaptive kernel dispatch — the host-side analog of the paper's
//! strategy table: instead of every call site hard-coding a kernel, callers
//! describe the input (graph statistics, feature dim, sampling width)
//! and the dispatcher picks among the CPU SpMM zoo.
//!
//! Selection mirrors how the GPU kernels win on the GPU:
//! * sampled routes (width given) always run the ELL kernel — the whole
//!   point of sampling is the fixed-width tile;
//! * large flop counts amortize the pool fork-join, so they go parallel;
//! * long rows with a wide feature dim favor the GE-SpMM-analog row
//!   cache (tile staging + register blocks), short rows do not repay the
//!   staging and keep the naive kernel.
//!
//! When a measured cost model is installed (`repro tune`, `exec::tune`),
//! [`select_kernel_tuned`] consults it first — per shard, keyed by the
//! profile's bucket — and the heuristics above become the fallback for
//! unmeasured buckets or inadmissible picks. The classic entry points
//! [`select_kernel`] / [`select_kernel_i8`] are thin wrappers over the
//! same selector restricted to the classic CSR/ELL families, so callers
//! that execute through [`run_exact`] / [`run_ell`] can never receive a
//! format-zoo kernel they cannot run. Every format choice is a pure
//! performance decision: all admissible kernels for a cell are
//! bitwise-identical (`tests/format_equiv.rs`), so a model can only make
//! serving faster or slower — never different (docs/dispatch.md).

use crate::graph::{Csr, Ell};
use crate::spmm::{AdjQuant, BlockedCsr, DenseTile};

use super::pool;

/// Execution environment: the thread budget kernels may use. Detected
/// once and passed down, so every layer agrees on the machine size
/// instead of re-probing `available_parallelism` at each call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecEnv {
    /// Thread budget data-parallel kernels may fan out to.
    pub threads: usize,
}

impl ExecEnv {
    /// Probe the machine.
    pub fn detect() -> ExecEnv {
        ExecEnv {
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }

    /// Fixed thread budget (tests, single-thread baselines).
    pub fn with_threads(threads: usize) -> ExecEnv {
        ExecEnv { threads: threads.max(1) }
    }
}

impl Default for ExecEnv {
    fn default() -> Self {
        ExecEnv::detect()
    }
}

/// The CPU kernel zoo, as dispatch targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Exact CSR, single thread (cuSPARSE role).
    CsrNaive,
    /// Exact CSR, row-chunked across the pool.
    CsrNaivePar,
    /// GE-SpMM analog: row caching + warp-merged feature blocks.
    CsrRowCache,
    /// Sampled fixed-width multiply, single thread.
    EllSampled,
    /// Sampled fixed-width multiply, row-chunked across the pool.
    EllSampledPar,
    /// Exact CSR in the quantized domain (`i8×u8→i32`), single thread.
    CsrI8,
    /// Exact CSR in the quantized domain, row-chunked across the pool.
    CsrI8Par,
    /// Sampled fixed-width multiply in the quantized domain, single
    /// thread.
    EllSampledI8,
    /// Sampled fixed-width multiply in the quantized domain,
    /// row-chunked across the pool.
    EllSampledI8Par,
    /// Exact blocked-CSR (fixed-height row blocks), single thread.
    CsrBlocked,
    /// Exact blocked-CSR, row-chunked across the pool.
    CsrBlockedPar,
    /// Exact dense-tile (fixed-pitch row slabs), single thread.
    ExactDense,
    /// Exact dense-tile, row-chunked across the pool.
    ExactDensePar,
    /// Exact blocked-CSR in the quantized domain, single thread.
    CsrBlockedI8,
    /// Exact blocked-CSR in the quantized domain, row-chunked.
    CsrBlockedI8Par,
    /// Exact dense-tile in the quantized domain, single thread.
    ExactDenseI8,
    /// Exact dense-tile in the quantized domain, row-chunked.
    ExactDenseI8Par,
}

/// The operand layout a [`KernelKind`] consumes — what dispatch must
/// have materialized before it can run the kernel.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FormatKind {
    /// Plain CSR (always available; the canonical layout).
    Csr,
    /// Blocked-CSR ([`crate::spmm::BlockedCsr`]).
    Blocked,
    /// Dense tile ([`crate::spmm::DenseTile`]).
    Dense,
    /// Sampled fixed-width ELL.
    Ell,
}

/// Which optional operand layouts the caller has materialized for this
/// input. The selector only returns a format-zoo kernel when its layout
/// is available; CSR and ELL are implied by the call family.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FormatMask {
    /// A [`crate::spmm::BlockedCsr`] of the operand exists.
    pub blocked: bool,
    /// A [`crate::spmm::DenseTile`] of the operand exists.
    pub dense: bool,
}

impl FormatMask {
    /// Classic CSR/ELL only — what [`select_kernel`] /
    /// [`select_kernel_i8`] pass, so legacy callers never receive a
    /// kernel they cannot execute.
    pub const CLASSIC: FormatMask = FormatMask { blocked: false, dense: false };
    /// Every format materialized (the autotuner's configuration).
    pub const ALL: FormatMask = FormatMask { blocked: true, dense: true };
}

/// The accumulation domain a kernel is selected for — fp32 or the
/// quantized `i8×u8→i32` path. Folding the two selectors over one
/// domain-parameterized core is what keeps their thresholds from
/// drifting apart.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelDomain {
    /// fp32 accumulation.
    F32,
    /// Quantized `i8×u8→i32` accumulation.
    I8,
}

impl KernelDomain {
    /// Stable label used in cost-model cell keys.
    pub fn name(self) -> &'static str {
        match self {
            KernelDomain::F32 => "f32",
            KernelDomain::I8 => "i8",
        }
    }
}

impl KernelKind {
    /// Every dispatch target, for enumeration (autotuner candidates,
    /// name round-trip tests).
    pub const ALL: [KernelKind; 17] = [
        KernelKind::CsrNaive,
        KernelKind::CsrNaivePar,
        KernelKind::CsrRowCache,
        KernelKind::EllSampled,
        KernelKind::EllSampledPar,
        KernelKind::CsrI8,
        KernelKind::CsrI8Par,
        KernelKind::EllSampledI8,
        KernelKind::EllSampledI8Par,
        KernelKind::CsrBlocked,
        KernelKind::CsrBlockedPar,
        KernelKind::ExactDense,
        KernelKind::ExactDensePar,
        KernelKind::CsrBlockedI8,
        KernelKind::CsrBlockedI8Par,
        KernelKind::ExactDenseI8,
        KernelKind::ExactDenseI8Par,
    ];

    /// Stable label used in benches, logs, reports, and cost-model
    /// cells.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::CsrNaive => "csr_naive",
            KernelKind::CsrNaivePar => "csr_naive_par",
            KernelKind::CsrRowCache => "csr_rowcache",
            KernelKind::EllSampled => "ell_spmm",
            KernelKind::EllSampledPar => "ell_spmm_par",
            KernelKind::CsrI8 => "csr_spmm_i8",
            KernelKind::CsrI8Par => "csr_spmm_i8_par",
            KernelKind::EllSampledI8 => "ell_spmm_i8",
            KernelKind::EllSampledI8Par => "ell_spmm_i8_par",
            KernelKind::CsrBlocked => "bcsr_spmm",
            KernelKind::CsrBlockedPar => "bcsr_spmm_par",
            KernelKind::ExactDense => "dense_spmm",
            KernelKind::ExactDensePar => "dense_spmm_par",
            KernelKind::CsrBlockedI8 => "bcsr_spmm_i8",
            KernelKind::CsrBlockedI8Par => "bcsr_spmm_i8_par",
            KernelKind::ExactDenseI8 => "dense_spmm_i8",
            KernelKind::ExactDenseI8Par => "dense_spmm_i8_par",
        }
    }

    /// Inverse of [`KernelKind::name`] — how cost-model JSON cells come
    /// back to dispatch targets. Unknown names are `None` (a stale or
    /// corrupt model must degrade, never panic).
    pub fn parse(name: &str) -> Option<KernelKind> {
        KernelKind::ALL.into_iter().find(|k| k.name() == name)
    }

    /// Whether the kernel row-chunks across the pool.
    pub fn is_parallel(self) -> bool {
        matches!(
            self,
            KernelKind::CsrNaivePar
                | KernelKind::EllSampledPar
                | KernelKind::CsrI8Par
                | KernelKind::EllSampledI8Par
                | KernelKind::CsrBlockedPar
                | KernelKind::ExactDensePar
                | KernelKind::CsrBlockedI8Par
                | KernelKind::ExactDenseI8Par
        )
    }

    /// Whether the kernel consumes a sampled (ELL) operand.
    pub fn is_sampled(self) -> bool {
        matches!(
            self,
            KernelKind::EllSampled
                | KernelKind::EllSampledPar
                | KernelKind::EllSampledI8
                | KernelKind::EllSampledI8Par
        )
    }

    /// Whether the kernel accumulates in the quantized (`i8×u8→i32`)
    /// domain instead of fp32.
    pub fn is_i8(self) -> bool {
        matches!(
            self,
            KernelKind::CsrI8
                | KernelKind::CsrI8Par
                | KernelKind::EllSampledI8
                | KernelKind::EllSampledI8Par
                | KernelKind::CsrBlockedI8
                | KernelKind::CsrBlockedI8Par
                | KernelKind::ExactDenseI8
                | KernelKind::ExactDenseI8Par
        )
    }

    /// The operand layout this kernel consumes.
    pub fn format(self) -> FormatKind {
        match self {
            KernelKind::CsrNaive
            | KernelKind::CsrNaivePar
            | KernelKind::CsrRowCache
            | KernelKind::CsrI8
            | KernelKind::CsrI8Par => FormatKind::Csr,
            KernelKind::EllSampled
            | KernelKind::EllSampledPar
            | KernelKind::EllSampledI8
            | KernelKind::EllSampledI8Par => FormatKind::Ell,
            KernelKind::CsrBlocked
            | KernelKind::CsrBlockedPar
            | KernelKind::CsrBlockedI8
            | KernelKind::CsrBlockedI8Par => FormatKind::Blocked,
            KernelKind::ExactDense
            | KernelKind::ExactDensePar
            | KernelKind::ExactDenseI8
            | KernelKind::ExactDenseI8Par => FormatKind::Dense,
        }
    }
}

/// The graph statistics dispatch decides on. Cheap to compute (one pass
/// over row lengths) and cached inside an `ExecPlan` for serving routes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GraphProfile {
    /// Rows of the aggregation operand.
    pub n_rows: usize,
    /// Stored entries (kept slots for a sampled operand).
    pub nnz: usize,
    /// Mean entries per row.
    pub mean_nnz: f64,
    /// Longest row.
    pub max_nnz: usize,
}

impl GraphProfile {
    /// Profile an exact CSR operand.
    pub fn of(csr: &Csr) -> GraphProfile {
        GraphProfile {
            n_rows: csr.n_rows,
            nnz: csr.nnz(),
            mean_nnz: csr.avg_degree(),
            max_nnz: csr.max_degree(),
        }
    }

    /// Profile a sampled fixed-width (ELL) operand.
    pub fn of_ell(ell: &Ell) -> GraphProfile {
        let nnz = ell.total_slots();
        let max_nnz = ell.slots.iter().map(|&s| s as usize).max().unwrap_or(0);
        GraphProfile {
            n_rows: ell.n_rows,
            nnz,
            mean_nnz: nnz as f64 / ell.n_rows.max(1) as f64,
            max_nnz,
        }
    }
}

/// Mean row nnz above which the row-cache tile repays its staging — the
/// host analog of "the row segment fits and stays in shared memory".
pub const ROWCACHE_MIN_MEAN_NNZ: f64 = 16.0;

/// Feature-dim floor for the row-cache kernel's warp-merged register
/// blocks (FBLOCK in `spmm::csr`); below it the blocks never fill.
pub const ROWCACHE_MIN_FEAT: usize = 8;

/// Longest row the row-cache kernel is dispatched for. Rows within one
/// tile accumulate in plain edge order — bitwise-identical to the naive
/// kernel — while longer rows introduce per-tile partial sums. Keeping
/// dispatch inside the tile makes **every** exact kernel per-row
/// FP-order identical, so serial / parallel / sharded execution can mix
/// kernel choices freely and still concatenate bit-for-bit (the sharded
/// serving guarantee, `docs/sharding.md`).
pub const ROWCACHE_MAX_ROW_NNZ: usize = crate::spmm::ROWCACHE_TILE;

/// Flop count where chunked threading amortizes the pool fork-join
/// (~tens of µs of multiply per chunk at CPU rates).
pub const PAR_MIN_FLOPS: usize = 2_000_000;

/// Whether `kind` may be returned for this selection: right family for
/// the route, right domain, a thread budget that supports it, its
/// operand layout materialized, and — for the row-cache kernel — the
/// bitwise gate intact. Cost-model picks that fail this check degrade
/// to the heuristics; it is the contract that a tuned model can only
/// change *speed*, never executability or numerics.
pub(crate) fn admissible(
    kind: KernelKind,
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    env: &ExecEnv,
    domain: KernelDomain,
    mask: FormatMask,
) -> bool {
    if kind.is_sampled() != width.is_some() {
        return false;
    }
    if kind.is_i8() != (domain == KernelDomain::I8) {
        return false;
    }
    if kind.is_parallel() && env.threads <= 1 {
        return false;
    }
    match kind.format() {
        FormatKind::Blocked if !mask.blocked => return false,
        FormatKind::Dense if !mask.dense => return false,
        _ => {}
    }
    // Bitwise gate, not a perf gate: multi-tile rowcache rows change the
    // per-row FP accumulation order, which would break the exact-family
    // bitwise-equality contract every other admissible kernel obeys.
    if kind == KernelKind::CsrRowCache && profile.max_nnz > ROWCACHE_MAX_ROW_NNZ {
        return false;
    }
    true
}

/// The hand-tuned fallback: one selector parameterized by domain, so
/// the fp32 and i8 thresholds are literally the same code path (the
/// flop estimate is scaled to like units via
/// [`crate::spmm::spmm_i8_flops`] — integer MACs are ~2x cheaper, so an
/// i8 workload must be twice as large before the pool fork-join
/// amortizes). The rowcache arm only exists in the fp32 domain: the i8
/// kernels have no fp32 staging tile.
fn select_heuristic(
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    env: &ExecEnv,
    domain: KernelDomain,
) -> KernelKind {
    let kept = match width {
        // Sampling keeps at most `w` edges per row.
        Some(w) => profile.nnz.min(profile.n_rows.saturating_mul(w)),
        None => profile.nnz,
    };
    let flops = match domain {
        KernelDomain::F32 => crate::spmm::spmm_flops(kept, feat_dim),
        KernelDomain::I8 => crate::spmm::spmm_i8_flops(kept, feat_dim),
    };
    let par = env.threads > 1 && flops >= PAR_MIN_FLOPS;
    match (width, domain) {
        (Some(_), KernelDomain::F32) => {
            if par {
                KernelKind::EllSampledPar
            } else {
                KernelKind::EllSampled
            }
        }
        (Some(_), KernelDomain::I8) => {
            if par {
                KernelKind::EllSampledI8Par
            } else {
                KernelKind::EllSampledI8
            }
        }
        (None, KernelDomain::F32) => {
            if par {
                KernelKind::CsrNaivePar
            } else if profile.mean_nnz >= ROWCACHE_MIN_MEAN_NNZ
                && feat_dim >= ROWCACHE_MIN_FEAT
                && profile.max_nnz <= ROWCACHE_MAX_ROW_NNZ
            {
                KernelKind::CsrRowCache
            } else {
                KernelKind::CsrNaive
            }
        }
        (None, KernelDomain::I8) => {
            if par {
                KernelKind::CsrI8Par
            } else {
                KernelKind::CsrI8
            }
        }
    }
}

/// Pick a kernel for one SpMM with the full selector: the installed
/// cost model first (per-shard, keyed by the profile's bucket — see
/// [`super::tune`]), the hand-tuned heuristics when no model is
/// installed, the bucket is unmeasured, or the model's pick is not
/// [`admissible`] for this call (wrong family, thread budget of 1, an
/// operand layout the caller did not materialize, a violated bitwise
/// gate). `mask` declares which format-zoo layouts the caller can
/// execute.
pub fn select_kernel_tuned(
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    env: &ExecEnv,
    domain: KernelDomain,
    mask: FormatMask,
) -> KernelKind {
    if let Some(kind) = super::tune::consult(profile, feat_dim, width, domain) {
        if admissible(kind, profile, feat_dim, width, env, domain, mask) {
            return kind;
        }
    }
    select_heuristic(profile, feat_dim, width, env, domain)
}

/// Pick a kernel for one SpMM. `width = None` means exact aggregation;
/// `Some(w)` means the route is sampled to ELL width `w`.
///
/// Classic-family entry point: restricted to CSR/ELL kernels (mask
/// [`FormatMask::CLASSIC`]) so callers that execute through
/// [`run_exact`] / [`run_ell`] always receive a kernel those executors
/// accept. An installed cost model still steers the classic choices
/// (serial vs parallel vs rowcache).
pub fn select_kernel(
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    env: &ExecEnv,
) -> KernelKind {
    select_kernel_tuned(profile, feat_dim, width, env, KernelDomain::F32, FormatMask::CLASSIC)
}

/// [`select_kernel`] for the quantized domain — same selector core, so
/// the i8 thresholds can never drift from fp32.
pub fn select_kernel_i8(
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    env: &ExecEnv,
) -> KernelKind {
    select_kernel_tuned(profile, feat_dim, width, env, KernelDomain::I8, FormatMask::CLASSIC)
}

/// Execute an exact SpMM through an explicit kernel choice.
///
/// Panics if `kind` is a sampled (ELL) kernel — the caller routed a CSR
/// input to the wrong family.
pub fn run_exact(
    kind: KernelKind,
    csr: &Csr,
    b: &[f32],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::CsrNaive => crate::spmm::csr_naive(csr, b, f, out),
        KernelKind::CsrRowCache => crate::spmm::csr_rowcache(csr, b, f, out),
        KernelKind::CsrNaivePar => crate::spmm::csr_naive_par(csr, b, f, out, threads),
        other => panic!("{} is not an exact CSR kernel", other.name()),
    }
}

/// Execute a sampled (ELL) SpMM through an explicit kernel choice.
pub fn run_ell(kind: KernelKind, ell: &Ell, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    match kind {
        KernelKind::EllSampled => crate::spmm::ell_spmm(ell, b, f, out),
        KernelKind::EllSampledPar => crate::spmm::ell_spmm_par(ell, b, f, out, threads),
        other => panic!("{} is not a sampled ELL kernel", other.name()),
    }
}

/// Execute an exact SpMM in the quantized domain (`qb` is the row-major
/// u8 feature codes, `aq` the requantized adjacency).
///
/// Panics if `kind` is not an exact i8 kernel.
pub fn run_exact_i8(
    kind: KernelKind,
    csr: &Csr,
    aq: &crate::spmm::AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::CsrI8 => crate::spmm::csr_spmm_i8(csr, aq, qb, f, out),
        KernelKind::CsrI8Par => crate::spmm::csr_spmm_i8_par(csr, aq, qb, f, out, threads),
        other => panic!("{} is not an exact i8 kernel", other.name()),
    }
}

/// Execute a sampled (ELL) SpMM in the quantized domain.
///
/// Panics if `kind` is not a sampled i8 kernel.
pub fn run_ell_i8(
    kind: KernelKind,
    ell: &Ell,
    aq: &crate::spmm::AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::EllSampledI8 => crate::spmm::ell_spmm_i8(ell, aq, qb, f, out),
        KernelKind::EllSampledI8Par => crate::spmm::ell_spmm_i8_par(ell, aq, qb, f, out, threads),
        other => panic!("{} is not a sampled i8 kernel", other.name()),
    }
}

/// Execute an exact SpMM over a blocked-CSR operand.
///
/// Panics if `kind` is not a blocked-CSR fp32 kernel.
pub fn run_blocked(
    kind: KernelKind,
    m: &BlockedCsr,
    b: &[f32],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::CsrBlocked => crate::spmm::bcsr_spmm(m, b, f, out),
        KernelKind::CsrBlockedPar => crate::spmm::bcsr_spmm_par(m, b, f, out, threads),
        other => panic!("{} is not a blocked-CSR fp32 kernel", other.name()),
    }
}

/// Execute an exact SpMM over a dense-tile operand.
///
/// Panics if `kind` is not a dense-tile fp32 kernel.
pub fn run_dense(
    kind: KernelKind,
    t: &DenseTile,
    b: &[f32],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::ExactDense => crate::spmm::dense_spmm(t, b, f, out),
        KernelKind::ExactDensePar => crate::spmm::dense_spmm_par(t, b, f, out, threads),
        other => panic!("{} is not a dense-tile fp32 kernel", other.name()),
    }
}

/// Execute a quantized-domain SpMM over a blocked-CSR operand (`aq` in
/// CSR nnz order, exactly as [`run_exact_i8`] consumes it).
///
/// Panics if `kind` is not a blocked-CSR i8 kernel.
#[allow(clippy::too_many_arguments)]
pub fn run_blocked_i8(
    kind: KernelKind,
    m: &BlockedCsr,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::CsrBlockedI8 => crate::spmm::bcsr_spmm_i8(m, aq, qb, f, out),
        KernelKind::CsrBlockedI8Par => crate::spmm::bcsr_spmm_i8_par(m, aq, qb, f, out, threads),
        other => panic!("{} is not a blocked-CSR i8 kernel", other.name()),
    }
}

/// Execute a quantized-domain SpMM over a dense-tile operand.
///
/// Panics if `kind` is not a dense-tile i8 kernel.
#[allow(clippy::too_many_arguments)]
pub fn run_dense_i8(
    kind: KernelKind,
    t: &DenseTile,
    aq: &AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::ExactDenseI8 => crate::spmm::dense_spmm_i8(t, aq, qb, f, out),
        KernelKind::ExactDenseI8Par => crate::spmm::dense_spmm_i8_par(t, aq, qb, f, out, threads),
        other => panic!("{} is not a dense-tile i8 kernel", other.name()),
    }
}

/// Select-and-run an exact SpMM; returns the choice made (callers log or
/// assert on it).
pub fn spmm_exact(csr: &Csr, b: &[f32], f: usize, out: &mut [f32], env: &ExecEnv) -> KernelKind {
    let kind = select_kernel(&GraphProfile::of(csr), f, None, env);
    run_exact(kind, csr, b, f, out, env.threads);
    kind
}

/// Select-and-run a sampled SpMM over a prepared ELL plan.
pub fn spmm_ell(ell: &Ell, b: &[f32], f: usize, out: &mut [f32], env: &ExecEnv) -> KernelKind {
    let kind = select_kernel(&GraphProfile::of_ell(ell), f, Some(ell.width), env);
    run_ell(kind, ell, b, f, out, env.threads);
    kind
}

/// Convenience used by benches/tests: make sure the global compute pool
/// exists before timing, so pool spin-up never lands inside a sample.
pub fn warm_pool() {
    pool::global();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Pcg32;
    use crate::spmm::testutil::{assert_close, random_graph_and_features};

    fn profile(n_rows: usize, nnz: usize) -> GraphProfile {
        GraphProfile {
            n_rows,
            nnz,
            mean_nnz: nnz as f64 / n_rows.max(1) as f64,
            max_nnz: nnz / n_rows.max(1) * 4,
        }
    }

    #[test]
    fn dispatch_matrix_exact() {
        let multi = ExecEnv::with_threads(8);
        let single = ExecEnv::with_threads(1);

        // Tiny graph, narrow features → naive.
        assert_eq!(select_kernel(&profile(100, 500), 4, None, &multi), KernelKind::CsrNaive);
        // Long rows + wide features but small total → rowcache.
        assert_eq!(select_kernel(&profile(100, 5_000), 16, None, &multi), KernelKind::CsrRowCache);
        // Long rows but features below the register block → naive.
        assert_eq!(select_kernel(&profile(100, 5_000), 4, None, &multi), KernelKind::CsrNaive);
        // Long rows + wide features but a row beyond the tile → naive:
        // multi-tile rowcache changes per-row FP order, which would break
        // the sharded/unsharded bitwise guarantee.
        let over_tile = GraphProfile {
            n_rows: 100,
            nnz: 5_000,
            mean_nnz: 50.0,
            max_nnz: ROWCACHE_MAX_ROW_NNZ + 1,
        };
        assert_eq!(select_kernel(&over_tile, 16, None, &multi), KernelKind::CsrNaive);
        // Big total flops + threads → parallel.
        assert_eq!(
            select_kernel(&profile(100_000, 2_000_000), 64, None, &multi),
            KernelKind::CsrNaivePar
        );
        // Same workload, one thread → never parallel.
        assert_ne!(
            select_kernel(&profile(100_000, 2_000_000), 64, None, &single),
            KernelKind::CsrNaivePar
        );
    }

    #[test]
    fn dispatch_matrix_sampled() {
        let multi = ExecEnv::with_threads(8);
        let single = ExecEnv::with_threads(1);

        // Sampled routes always land on an ELL kernel.
        for (n, nnz, f) in [(100usize, 400usize, 8usize), (200_000, 8_000_000, 128)] {
            let kind = select_kernel(&profile(n, nnz), f, Some(32), &multi);
            assert!(kind.is_sampled(), "{kind:?}");
        }
        // Small sampled workload stays serial; huge goes parallel.
        assert_eq!(select_kernel(&profile(100, 400), 8, Some(32), &multi), KernelKind::EllSampled);
        assert_eq!(
            select_kernel(&profile(200_000, 8_000_000), 128, Some(32), &multi),
            KernelKind::EllSampledPar
        );
        // The width cap bounds the kept-edge estimate: a graph whose nnz
        // dwarfs n_rows*w must not be scored by its raw nnz.
        let narrow = select_kernel(&profile(100, 10_000_000), 8, Some(4), &multi);
        assert_eq!(narrow, KernelKind::EllSampled);
        // One thread → serial regardless of size.
        assert_eq!(
            select_kernel(&profile(200_000, 8_000_000), 128, Some(32), &single),
            KernelKind::EllSampled
        );
    }

    #[test]
    fn dispatch_matrix_i8_compares_like_units() {
        let multi = ExecEnv::with_threads(8);
        let single = ExecEnv::with_threads(1);

        // Integer MACs are ~2x cheaper, so a workload that just crosses
        // the fp32 parallel threshold (2·nnz·f = 2.56 M flops) stays
        // serial in the i8 domain…
        let p = profile(100_000, 20_000);
        assert_eq!(select_kernel(&p, 64, None, &multi), KernelKind::CsrNaivePar);
        assert_eq!(select_kernel_i8(&p, 64, None, &multi), KernelKind::CsrI8);
        // …and twice that workload forks in both domains.
        let p2 = profile(100_000, 40_000);
        assert_eq!(select_kernel_i8(&p2, 64, None, &multi), KernelKind::CsrI8Par);

        // Sampled routes always land on an ELL i8 kernel, same width cap.
        assert_eq!(
            select_kernel_i8(&profile(100, 400), 8, Some(32), &multi),
            KernelKind::EllSampledI8
        );
        assert_eq!(
            select_kernel_i8(&profile(200_000, 8_000_000), 128, Some(32), &multi),
            KernelKind::EllSampledI8Par
        );
        assert_eq!(
            select_kernel_i8(&profile(200_000, 8_000_000), 128, Some(32), &single),
            KernelKind::EllSampledI8
        );
        for kind in [
            KernelKind::CsrI8,
            KernelKind::CsrI8Par,
            KernelKind::EllSampledI8,
            KernelKind::EllSampledI8Par,
        ] {
            assert!(kind.is_i8());
        }
        assert!(!KernelKind::CsrRowCache.is_i8());
    }

    #[test]
    fn dispatched_i8_execution_matches_direct_kernels() {
        use crate::quant::ChunkedParams;
        let (g, b) = random_graph_and_features(200, 15.0, 12, 23);
        let params = ChunkedParams::of_rows(&b, 200, 12, 64);
        let qb = params.quantize_rows(&b, 12);
        let ell = crate::sampling::sample_ell(&g, 8, crate::sampling::Strategy::Aes);
        let aq = crate::spmm::AdjQuant::from_ell(&ell, &params);
        let mut want = vec![0.0f32; 200 * 12];
        crate::spmm::ell_spmm_i8(&ell, &aq, &qb, 12, &mut want);
        for env in [ExecEnv::with_threads(1), ExecEnv::with_threads(4)] {
            let kind = select_kernel_i8(&GraphProfile::of_ell(&ell), 12, Some(8), &env);
            let mut got = vec![0.0f32; 200 * 12];
            run_ell_i8(kind, &ell, &aq, &qb, 12, &mut got, env.threads);
            assert_eq!(want, got, "i8 dispatch must not change a bit");
        }
        let caq = crate::spmm::AdjQuant::from_csr(&g, &params);
        let mut cwant = vec![0.0f32; 200 * 12];
        crate::spmm::csr_spmm_i8(&g, &caq, &qb, 12, &mut cwant);
        let env = ExecEnv::with_threads(4);
        let kind = select_kernel_i8(&GraphProfile::of(&g), 12, None, &env);
        let mut cgot = vec![0.0f32; 200 * 12];
        run_exact_i8(kind, &g, &caq, &qb, 12, &mut cgot, env.threads);
        assert_eq!(cwant, cgot);
    }

    #[test]
    fn profiles_match_structures() {
        let mut rng = Pcg32::new(3);
        let csr = gen::chung_lu(300, 12.0, 2.0, &mut rng);
        let p = GraphProfile::of(&csr);
        assert_eq!(p.n_rows, 300);
        assert_eq!(p.nnz, csr.nnz());
        assert_eq!(p.max_nnz, csr.max_degree());

        let ell = crate::sampling::sample_ell(&csr, 8, crate::sampling::Strategy::Aes);
        let pe = GraphProfile::of_ell(&ell);
        assert_eq!(pe.n_rows, 300);
        assert_eq!(pe.nnz, ell.total_slots());
        assert!(pe.max_nnz <= 8);
    }

    #[test]
    fn dispatched_execution_matches_reference() {
        let (g, b) = random_graph_and_features(400, 30.0, 16, 11);
        let mut want = vec![0.0f32; g.n_rows * 16];
        crate::spmm::csr_naive(&g, &b, 16, &mut want);
        for threads in [1usize, 8] {
            let env = ExecEnv::with_threads(threads);
            let mut got = vec![0.0f32; g.n_rows * 16];
            let kind = spmm_exact(&g, &b, 16, &mut got, &env);
            assert!(!kind.is_sampled());
            assert_close(&want, &got, 1e-6);
        }
    }

    #[test]
    fn kernel_names_round_trip() {
        for kind in KernelKind::ALL {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind), "{kind:?}");
        }
        assert_eq!(KernelKind::parse("no_such_kernel"), None);
        // Format classification is consistent with the executor families.
        for kind in KernelKind::ALL {
            match kind.format() {
                FormatKind::Ell => assert!(kind.is_sampled()),
                _ => assert!(!kind.is_sampled()),
            }
        }
    }

    #[test]
    fn admissibility_gates_family_domain_threads_and_formats() {
        use KernelDomain::{F32, I8};
        let multi = ExecEnv::with_threads(8);
        let single = ExecEnv::with_threads(1);
        let all = FormatMask::ALL;
        let classic = FormatMask::CLASSIC;
        let p = profile(100, 5_000);
        let adm = |k: KernelKind, p: &GraphProfile, w: Option<usize>, e: &ExecEnv, d, m| {
            admissible(k, p, 16, w, e, d, m)
        };

        // Family: sampled kernels need a width, exact kernels reject one.
        assert!(!adm(KernelKind::EllSampled, &p, None, &multi, F32, all));
        assert!(!adm(KernelKind::CsrNaive, &p, Some(8), &multi, F32, all));
        // Domain: an i8 kernel never serves an fp32 selection.
        assert!(!adm(KernelKind::CsrI8, &p, None, &multi, F32, all));
        assert!(adm(KernelKind::CsrI8, &p, None, &multi, I8, all));
        // Threads: parallel kernels need a budget > 1.
        assert!(!adm(KernelKind::CsrBlockedPar, &p, None, &single, F32, all));
        // Formats: the mask gates the zoo, never plain CSR.
        assert!(adm(KernelKind::CsrBlocked, &p, None, &multi, F32, all));
        assert!(!adm(KernelKind::CsrBlocked, &p, None, &multi, F32, classic));
        assert!(!adm(KernelKind::ExactDense, &p, None, &multi, F32, classic));
        assert!(adm(KernelKind::CsrNaive, &p, None, &multi, F32, classic));
        // The rowcache bitwise gate survives tuned selection.
        let over = GraphProfile {
            n_rows: 100,
            nnz: 5_000,
            mean_nnz: 50.0,
            max_nnz: ROWCACHE_MAX_ROW_NNZ + 1,
        };
        assert!(!adm(KernelKind::CsrRowCache, &over, None, &multi, F32, all));
    }

    #[test]
    fn tuned_selector_without_model_is_the_heuristic() {
        use KernelDomain::{F32, I8};
        // No model installed in lib unit tests, so the tuned selector
        // (with any mask) must reproduce the heuristics exactly — the
        // fallback path the golden-fixture tests rely on.
        let all = FormatMask::ALL;
        let envs = [ExecEnv::with_threads(1), ExecEnv::with_threads(8)];
        for env in &envs {
            for (n, nnz) in [(100usize, 500usize), (100, 5_000), (100_000, 2_000_000)] {
                for f in [4usize, 64] {
                    for width in [None, Some(16)] {
                        let p = profile(n, nnz);
                        assert_eq!(
                            select_kernel_tuned(&p, f, width, env, F32, all),
                            select_kernel(&p, f, width, env)
                        );
                        assert_eq!(
                            select_kernel_tuned(&p, f, width, env, I8, all),
                            select_kernel_i8(&p, f, width, env)
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn format_executors_match_csr_bitwise() {
        let (g, b) = random_graph_and_features(250, 20.0, 12, 9);
        let mut want = vec![0.0f32; g.n_rows * 12];
        crate::spmm::csr_naive(&g, &b, 12, &mut want);
        let m = crate::spmm::BlockedCsr::from_csr(&g, crate::spmm::BCSR_BLOCK_ROWS);
        let t = crate::spmm::DenseTile::from_csr(&g);
        let mut got = vec![1.0f32; g.n_rows * 12];
        run_blocked(KernelKind::CsrBlocked, &m, &b, 12, &mut got, 1);
        assert_eq!(want, got);
        run_blocked(KernelKind::CsrBlockedPar, &m, &b, 12, &mut got, 4);
        assert_eq!(want, got);
        run_dense(KernelKind::ExactDense, &t, &b, 12, &mut got, 1);
        assert_eq!(want, got);
        run_dense(KernelKind::ExactDensePar, &t, &b, 12, &mut got, 4);
        assert_eq!(want, got);
    }

    #[test]
    fn dispatched_ell_matches_reference() {
        let (g, b) = random_graph_and_features(300, 40.0, 8, 12);
        let ell = crate::sampling::sample_ell(&g, 16, crate::sampling::Strategy::Aes);
        let mut want = vec![0.0f32; g.n_rows * 8];
        crate::spmm::ell_spmm(&ell, &b, 8, &mut want);
        for threads in [1usize, 4] {
            let env = ExecEnv::with_threads(threads);
            let mut got = vec![0.0f32; g.n_rows * 8];
            let kind = spmm_ell(&ell, &b, 8, &mut got, &env);
            assert!(kind.is_sampled());
            assert_close(&want, &got, 1e-6);
        }
    }
}
