//! Adaptive kernel dispatch — the host-side analog of the paper's
//! strategy table: instead of every call site hard-coding a kernel, callers
//! describe the input (graph statistics, feature dim, sampling width)
//! and the dispatcher picks among the CPU SpMM zoo.
//!
//! Selection mirrors how the GPU kernels win on the GPU:
//! * sampled routes (width given) always run the ELL kernel — the whole
//!   point of sampling is the fixed-width tile;
//! * large flop counts amortize the pool fork-join, so they go parallel;
//! * long rows with a wide feature dim favor the GE-SpMM-analog row
//!   cache (tile staging + register blocks), short rows do not repay the
//!   staging and keep the naive kernel.

use crate::graph::{Csr, Ell};

use super::pool;

/// Execution environment: the thread budget kernels may use. Detected
/// once and passed down, so every layer agrees on the machine size
/// instead of re-probing `available_parallelism` at each call site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecEnv {
    /// Thread budget data-parallel kernels may fan out to.
    pub threads: usize,
}

impl ExecEnv {
    /// Probe the machine.
    pub fn detect() -> ExecEnv {
        ExecEnv {
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        }
    }

    /// Fixed thread budget (tests, single-thread baselines).
    pub fn with_threads(threads: usize) -> ExecEnv {
        ExecEnv { threads: threads.max(1) }
    }
}

impl Default for ExecEnv {
    fn default() -> Self {
        ExecEnv::detect()
    }
}

/// The CPU kernel zoo, as dispatch targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KernelKind {
    /// Exact CSR, single thread (cuSPARSE role).
    CsrNaive,
    /// Exact CSR, row-chunked across the pool.
    CsrNaivePar,
    /// GE-SpMM analog: row caching + warp-merged feature blocks.
    CsrRowCache,
    /// Sampled fixed-width multiply, single thread.
    EllSampled,
    /// Sampled fixed-width multiply, row-chunked across the pool.
    EllSampledPar,
    /// Exact CSR in the quantized domain (`i8×u8→i32`), single thread.
    CsrI8,
    /// Exact CSR in the quantized domain, row-chunked across the pool.
    CsrI8Par,
    /// Sampled fixed-width multiply in the quantized domain, single
    /// thread.
    EllSampledI8,
    /// Sampled fixed-width multiply in the quantized domain,
    /// row-chunked across the pool.
    EllSampledI8Par,
}

impl KernelKind {
    /// Stable label used in benches, logs, and reports.
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::CsrNaive => "csr_naive",
            KernelKind::CsrNaivePar => "csr_naive_par",
            KernelKind::CsrRowCache => "csr_rowcache",
            KernelKind::EllSampled => "ell_spmm",
            KernelKind::EllSampledPar => "ell_spmm_par",
            KernelKind::CsrI8 => "csr_spmm_i8",
            KernelKind::CsrI8Par => "csr_spmm_i8_par",
            KernelKind::EllSampledI8 => "ell_spmm_i8",
            KernelKind::EllSampledI8Par => "ell_spmm_i8_par",
        }
    }

    /// Whether the kernel row-chunks across the pool.
    pub fn is_parallel(self) -> bool {
        matches!(
            self,
            KernelKind::CsrNaivePar
                | KernelKind::EllSampledPar
                | KernelKind::CsrI8Par
                | KernelKind::EllSampledI8Par
        )
    }

    /// Whether the kernel consumes a sampled (ELL) operand.
    pub fn is_sampled(self) -> bool {
        matches!(
            self,
            KernelKind::EllSampled
                | KernelKind::EllSampledPar
                | KernelKind::EllSampledI8
                | KernelKind::EllSampledI8Par
        )
    }

    /// Whether the kernel accumulates in the quantized (`i8×u8→i32`)
    /// domain instead of fp32.
    pub fn is_i8(self) -> bool {
        matches!(
            self,
            KernelKind::CsrI8
                | KernelKind::CsrI8Par
                | KernelKind::EllSampledI8
                | KernelKind::EllSampledI8Par
        )
    }
}

/// The graph statistics dispatch decides on. Cheap to compute (one pass
/// over row lengths) and cached inside an `ExecPlan` for serving routes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GraphProfile {
    /// Rows of the aggregation operand.
    pub n_rows: usize,
    /// Stored entries (kept slots for a sampled operand).
    pub nnz: usize,
    /// Mean entries per row.
    pub mean_nnz: f64,
    /// Longest row.
    pub max_nnz: usize,
}

impl GraphProfile {
    /// Profile an exact CSR operand.
    pub fn of(csr: &Csr) -> GraphProfile {
        GraphProfile {
            n_rows: csr.n_rows,
            nnz: csr.nnz(),
            mean_nnz: csr.avg_degree(),
            max_nnz: csr.max_degree(),
        }
    }

    /// Profile a sampled fixed-width (ELL) operand.
    pub fn of_ell(ell: &Ell) -> GraphProfile {
        let nnz = ell.total_slots();
        let max_nnz = ell.slots.iter().map(|&s| s as usize).max().unwrap_or(0);
        GraphProfile {
            n_rows: ell.n_rows,
            nnz,
            mean_nnz: nnz as f64 / ell.n_rows.max(1) as f64,
            max_nnz,
        }
    }
}

/// Mean row nnz above which the row-cache tile repays its staging — the
/// host analog of "the row segment fits and stays in shared memory".
pub const ROWCACHE_MIN_MEAN_NNZ: f64 = 16.0;

/// Feature-dim floor for the row-cache kernel's warp-merged register
/// blocks (FBLOCK in `spmm::csr`); below it the blocks never fill.
pub const ROWCACHE_MIN_FEAT: usize = 8;

/// Longest row the row-cache kernel is dispatched for. Rows within one
/// tile accumulate in plain edge order — bitwise-identical to the naive
/// kernel — while longer rows introduce per-tile partial sums. Keeping
/// dispatch inside the tile makes **every** exact kernel per-row
/// FP-order identical, so serial / parallel / sharded execution can mix
/// kernel choices freely and still concatenate bit-for-bit (the sharded
/// serving guarantee, `docs/sharding.md`).
pub const ROWCACHE_MAX_ROW_NNZ: usize = crate::spmm::ROWCACHE_TILE;

/// Flop count where chunked threading amortizes the pool fork-join
/// (~tens of µs of multiply per chunk at CPU rates).
pub const PAR_MIN_FLOPS: usize = 2_000_000;

/// Pick a kernel for one SpMM. `width = None` means exact aggregation;
/// `Some(w)` means the route is sampled to ELL width `w`.
pub fn select_kernel(
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    env: &ExecEnv,
) -> KernelKind {
    match width {
        Some(w) => {
            // Sampling keeps at most `w` edges per row.
            let kept = profile.nnz.min(profile.n_rows.saturating_mul(w));
            let flops = 2usize.saturating_mul(kept).saturating_mul(feat_dim);
            if env.threads > 1 && flops >= PAR_MIN_FLOPS {
                KernelKind::EllSampledPar
            } else {
                KernelKind::EllSampled
            }
        }
        None => {
            let flops = 2usize.saturating_mul(profile.nnz).saturating_mul(feat_dim);
            if env.threads > 1 && flops >= PAR_MIN_FLOPS {
                KernelKind::CsrNaivePar
            } else if profile.mean_nnz >= ROWCACHE_MIN_MEAN_NNZ
                && feat_dim >= ROWCACHE_MIN_FEAT
                && profile.max_nnz <= ROWCACHE_MAX_ROW_NNZ
            {
                KernelKind::CsrRowCache
            } else {
                KernelKind::CsrNaive
            }
        }
    }
}

/// Pick a kernel for one SpMM executed in the quantized domain. Mirrors
/// [`select_kernel`] with the flop estimate scaled by
/// [`crate::spmm::spmm_i8_flops`]: integer MACs are ~2x cheaper per
/// nnz, so a workload must be twice as large before the pool fork-join
/// amortizes — [`PAR_MIN_FLOPS`] compares like units. The rowcache gate
/// does not apply: the i8 kernels have no fp32 staging tile.
pub fn select_kernel_i8(
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    env: &ExecEnv,
) -> KernelKind {
    match width {
        Some(w) => {
            let kept = profile.nnz.min(profile.n_rows.saturating_mul(w));
            let flops = crate::spmm::spmm_i8_flops(kept, feat_dim);
            if env.threads > 1 && flops >= PAR_MIN_FLOPS {
                KernelKind::EllSampledI8Par
            } else {
                KernelKind::EllSampledI8
            }
        }
        None => {
            let flops = crate::spmm::spmm_i8_flops(profile.nnz, feat_dim);
            if env.threads > 1 && flops >= PAR_MIN_FLOPS {
                KernelKind::CsrI8Par
            } else {
                KernelKind::CsrI8
            }
        }
    }
}

/// Execute an exact SpMM through an explicit kernel choice.
///
/// Panics if `kind` is a sampled (ELL) kernel — the caller routed a CSR
/// input to the wrong family.
pub fn run_exact(
    kind: KernelKind,
    csr: &Csr,
    b: &[f32],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::CsrNaive => crate::spmm::csr_naive(csr, b, f, out),
        KernelKind::CsrRowCache => crate::spmm::csr_rowcache(csr, b, f, out),
        KernelKind::CsrNaivePar => crate::spmm::csr_naive_par(csr, b, f, out, threads),
        other => panic!("{} is not an exact CSR kernel", other.name()),
    }
}

/// Execute a sampled (ELL) SpMM through an explicit kernel choice.
pub fn run_ell(kind: KernelKind, ell: &Ell, b: &[f32], f: usize, out: &mut [f32], threads: usize) {
    match kind {
        KernelKind::EllSampled => crate::spmm::ell_spmm(ell, b, f, out),
        KernelKind::EllSampledPar => crate::spmm::ell_spmm_par(ell, b, f, out, threads),
        other => panic!("{} is not a sampled ELL kernel", other.name()),
    }
}

/// Execute an exact SpMM in the quantized domain (`qb` is the row-major
/// u8 feature codes, `aq` the requantized adjacency).
///
/// Panics if `kind` is not an exact i8 kernel.
pub fn run_exact_i8(
    kind: KernelKind,
    csr: &Csr,
    aq: &crate::spmm::AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::CsrI8 => crate::spmm::csr_spmm_i8(csr, aq, qb, f, out),
        KernelKind::CsrI8Par => crate::spmm::csr_spmm_i8_par(csr, aq, qb, f, out, threads),
        other => panic!("{} is not an exact i8 kernel", other.name()),
    }
}

/// Execute a sampled (ELL) SpMM in the quantized domain.
///
/// Panics if `kind` is not a sampled i8 kernel.
pub fn run_ell_i8(
    kind: KernelKind,
    ell: &Ell,
    aq: &crate::spmm::AdjQuant,
    qb: &[u8],
    f: usize,
    out: &mut [f32],
    threads: usize,
) {
    match kind {
        KernelKind::EllSampledI8 => crate::spmm::ell_spmm_i8(ell, aq, qb, f, out),
        KernelKind::EllSampledI8Par => crate::spmm::ell_spmm_i8_par(ell, aq, qb, f, out, threads),
        other => panic!("{} is not a sampled i8 kernel", other.name()),
    }
}

/// Select-and-run an exact SpMM; returns the choice made (callers log or
/// assert on it).
pub fn spmm_exact(csr: &Csr, b: &[f32], f: usize, out: &mut [f32], env: &ExecEnv) -> KernelKind {
    let kind = select_kernel(&GraphProfile::of(csr), f, None, env);
    run_exact(kind, csr, b, f, out, env.threads);
    kind
}

/// Select-and-run a sampled SpMM over a prepared ELL plan.
pub fn spmm_ell(ell: &Ell, b: &[f32], f: usize, out: &mut [f32], env: &ExecEnv) -> KernelKind {
    let kind = select_kernel(&GraphProfile::of_ell(ell), f, Some(ell.width), env);
    run_ell(kind, ell, b, f, out, env.threads);
    kind
}

/// Convenience used by benches/tests: make sure the global compute pool
/// exists before timing, so pool spin-up never lands inside a sample.
pub fn warm_pool() {
    pool::global();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Pcg32;
    use crate::spmm::testutil::{assert_close, random_graph_and_features};

    fn profile(n_rows: usize, nnz: usize) -> GraphProfile {
        GraphProfile {
            n_rows,
            nnz,
            mean_nnz: nnz as f64 / n_rows.max(1) as f64,
            max_nnz: nnz / n_rows.max(1) * 4,
        }
    }

    #[test]
    fn dispatch_matrix_exact() {
        let multi = ExecEnv::with_threads(8);
        let single = ExecEnv::with_threads(1);

        // Tiny graph, narrow features → naive.
        assert_eq!(select_kernel(&profile(100, 500), 4, None, &multi), KernelKind::CsrNaive);
        // Long rows + wide features but small total → rowcache.
        assert_eq!(select_kernel(&profile(100, 5_000), 16, None, &multi), KernelKind::CsrRowCache);
        // Long rows but features below the register block → naive.
        assert_eq!(select_kernel(&profile(100, 5_000), 4, None, &multi), KernelKind::CsrNaive);
        // Long rows + wide features but a row beyond the tile → naive:
        // multi-tile rowcache changes per-row FP order, which would break
        // the sharded/unsharded bitwise guarantee.
        let over_tile = GraphProfile {
            n_rows: 100,
            nnz: 5_000,
            mean_nnz: 50.0,
            max_nnz: ROWCACHE_MAX_ROW_NNZ + 1,
        };
        assert_eq!(select_kernel(&over_tile, 16, None, &multi), KernelKind::CsrNaive);
        // Big total flops + threads → parallel.
        assert_eq!(
            select_kernel(&profile(100_000, 2_000_000), 64, None, &multi),
            KernelKind::CsrNaivePar
        );
        // Same workload, one thread → never parallel.
        assert_ne!(
            select_kernel(&profile(100_000, 2_000_000), 64, None, &single),
            KernelKind::CsrNaivePar
        );
    }

    #[test]
    fn dispatch_matrix_sampled() {
        let multi = ExecEnv::with_threads(8);
        let single = ExecEnv::with_threads(1);

        // Sampled routes always land on an ELL kernel.
        for (n, nnz, f) in [(100usize, 400usize, 8usize), (200_000, 8_000_000, 128)] {
            let kind = select_kernel(&profile(n, nnz), f, Some(32), &multi);
            assert!(kind.is_sampled(), "{kind:?}");
        }
        // Small sampled workload stays serial; huge goes parallel.
        assert_eq!(select_kernel(&profile(100, 400), 8, Some(32), &multi), KernelKind::EllSampled);
        assert_eq!(
            select_kernel(&profile(200_000, 8_000_000), 128, Some(32), &multi),
            KernelKind::EllSampledPar
        );
        // The width cap bounds the kept-edge estimate: a graph whose nnz
        // dwarfs n_rows*w must not be scored by its raw nnz.
        let narrow = select_kernel(&profile(100, 10_000_000), 8, Some(4), &multi);
        assert_eq!(narrow, KernelKind::EllSampled);
        // One thread → serial regardless of size.
        assert_eq!(
            select_kernel(&profile(200_000, 8_000_000), 128, Some(32), &single),
            KernelKind::EllSampled
        );
    }

    #[test]
    fn dispatch_matrix_i8_compares_like_units() {
        let multi = ExecEnv::with_threads(8);
        let single = ExecEnv::with_threads(1);

        // Integer MACs are ~2x cheaper, so a workload that just crosses
        // the fp32 parallel threshold (2·nnz·f = 2.56 M flops) stays
        // serial in the i8 domain…
        let p = profile(100_000, 20_000);
        assert_eq!(select_kernel(&p, 64, None, &multi), KernelKind::CsrNaivePar);
        assert_eq!(select_kernel_i8(&p, 64, None, &multi), KernelKind::CsrI8);
        // …and twice that workload forks in both domains.
        let p2 = profile(100_000, 40_000);
        assert_eq!(select_kernel_i8(&p2, 64, None, &multi), KernelKind::CsrI8Par);

        // Sampled routes always land on an ELL i8 kernel, same width cap.
        assert_eq!(select_kernel_i8(&profile(100, 400), 8, Some(32), &multi), KernelKind::EllSampledI8);
        assert_eq!(
            select_kernel_i8(&profile(200_000, 8_000_000), 128, Some(32), &multi),
            KernelKind::EllSampledI8Par
        );
        assert_eq!(
            select_kernel_i8(&profile(200_000, 8_000_000), 128, Some(32), &single),
            KernelKind::EllSampledI8
        );
        for kind in [
            KernelKind::CsrI8,
            KernelKind::CsrI8Par,
            KernelKind::EllSampledI8,
            KernelKind::EllSampledI8Par,
        ] {
            assert!(kind.is_i8());
        }
        assert!(!KernelKind::CsrRowCache.is_i8());
    }

    #[test]
    fn dispatched_i8_execution_matches_direct_kernels() {
        use crate::quant::ChunkedParams;
        let (g, b) = random_graph_and_features(200, 15.0, 12, 23);
        let params = ChunkedParams::of_rows(&b, 200, 12, 64);
        let qb = params.quantize_rows(&b, 12);
        let ell = crate::sampling::sample_ell(&g, 8, crate::sampling::Strategy::Aes);
        let aq = crate::spmm::AdjQuant::from_ell(&ell, &params);
        let mut want = vec![0.0f32; 200 * 12];
        crate::spmm::ell_spmm_i8(&ell, &aq, &qb, 12, &mut want);
        for env in [ExecEnv::with_threads(1), ExecEnv::with_threads(4)] {
            let kind = select_kernel_i8(&GraphProfile::of_ell(&ell), 12, Some(8), &env);
            let mut got = vec![0.0f32; 200 * 12];
            run_ell_i8(kind, &ell, &aq, &qb, 12, &mut got, env.threads);
            assert_eq!(want, got, "i8 dispatch must not change a bit");
        }
        let caq = crate::spmm::AdjQuant::from_csr(&g, &params);
        let mut cwant = vec![0.0f32; 200 * 12];
        crate::spmm::csr_spmm_i8(&g, &caq, &qb, 12, &mut cwant);
        let env = ExecEnv::with_threads(4);
        let kind = select_kernel_i8(&GraphProfile::of(&g), 12, None, &env);
        let mut cgot = vec![0.0f32; 200 * 12];
        run_exact_i8(kind, &g, &caq, &qb, 12, &mut cgot, env.threads);
        assert_eq!(cwant, cgot);
    }

    #[test]
    fn profiles_match_structures() {
        let mut rng = Pcg32::new(3);
        let csr = gen::chung_lu(300, 12.0, 2.0, &mut rng);
        let p = GraphProfile::of(&csr);
        assert_eq!(p.n_rows, 300);
        assert_eq!(p.nnz, csr.nnz());
        assert_eq!(p.max_nnz, csr.max_degree());

        let ell = crate::sampling::sample_ell(&csr, 8, crate::sampling::Strategy::Aes);
        let pe = GraphProfile::of_ell(&ell);
        assert_eq!(pe.n_rows, 300);
        assert_eq!(pe.nnz, ell.total_slots());
        assert!(pe.max_nnz <= 8);
    }

    #[test]
    fn dispatched_execution_matches_reference() {
        let (g, b) = random_graph_and_features(400, 30.0, 16, 11);
        let mut want = vec![0.0f32; g.n_rows * 16];
        crate::spmm::csr_naive(&g, &b, 16, &mut want);
        for threads in [1usize, 8] {
            let env = ExecEnv::with_threads(threads);
            let mut got = vec![0.0f32; g.n_rows * 16];
            let kind = spmm_exact(&g, &b, 16, &mut got, &env);
            assert!(!kind.is_sampled());
            assert_close(&want, &got, 1e-6);
        }
    }

    #[test]
    fn dispatched_ell_matches_reference() {
        let (g, b) = random_graph_and_features(300, 40.0, 8, 12);
        let ell = crate::sampling::sample_ell(&g, 16, crate::sampling::Strategy::Aes);
        let mut want = vec![0.0f32; g.n_rows * 8];
        crate::spmm::ell_spmm(&ell, &b, 8, &mut want);
        for threads in [1usize, 4] {
            let env = ExecEnv::with_threads(threads);
            let mut got = vec![0.0f32; g.n_rows * 8];
            let kind = spmm_ell(&ell, &b, 8, &mut got, &env);
            assert!(kind.is_sampled());
            assert_close(&want, &got, 1e-6);
        }
    }
}
