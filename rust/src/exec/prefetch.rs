//! Async plan prefetch — overlap the next batch's feature staging with
//! the current batch's SpMM.
//!
//! The plan cache removed *repeated* cold loads; this removes the cold
//! load from the critical path entirely. When a request is admitted, its
//! route's plan build (feature stage + sampling + dispatch) is handed to
//! a dedicated [`Pool`], so by the time the batcher's delay window closes
//! and a worker picks the batch up, staging has been running concurrently
//! with whatever SpMM the workers were already executing — the paper's
//! "loading hides behind compute" shape (Table 3) applied to serving.
//!
//! Coordination contract:
//! * one in-flight build per key — duplicate requests coalesce;
//! * completed builds land in the shared [`PlanCache`] through its
//!   generation-checked insert, so an `invalidate` racing a prefetch can
//!   never be undone by a stale build;
//! * consumers call [`Prefetcher::fetch`]: cache hit, else wait for the
//!   in-flight build, else build inline — so a consumer never duplicates
//!   a staging read that is already running;
//! * the prefetcher **must not** share its pool with its consumers: a
//!   worker blocking in `fetch` while its own pool owes it the build
//!   would deadlock. The coordinator gives the prefetcher a private pool.
//! * prefetch is shard-aware by composition: a sharded route's build
//!   resolves each [`super::ShardUnit`] through the shared shard-unit
//!   cache, so prefetching a partially-warm route stages features and
//!   samples **only the cold shards** — warm units are never rebuilt.

use std::collections::HashSet;
use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use super::plan_cache::PlanCache;
use super::pool::Pool;

/// Point-in-time prefetcher counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PrefetchStats {
    /// Builds handed to the prefetch pool.
    pub scheduled: u64,
    /// Builds that finished and populated (or re-validated) the cache.
    pub completed: u64,
    /// Requests skipped because the plan was already cached or already
    /// being built.
    pub coalesced: u64,
    /// Builds whose builder errored; the route's next execution rebuilds
    /// inline and surfaces the error to its caller.
    pub errors: u64,
}

/// State shared between the handle, the waiters, and the pool jobs.
///
/// Deliberately does NOT own the pool: a job closure holds an
/// `Arc<Inner>`, and if `Inner` owned the pool, a worker dropping the
/// last `Arc` would run the pool's drop (join-all-workers) on one of its
/// own workers. The pool lives in the [`Prefetcher`] handle instead, so
/// its teardown always happens on a consumer thread.
struct Inner<K, V> {
    cache: Arc<PlanCache<K, V>>,
    /// Keys currently being built (queued or running). Guards the
    /// wait/notify handshake in [`Prefetcher::fetch`].
    inflight: Mutex<HashSet<K>>,
    /// Signalled whenever a key leaves `inflight`.
    done: Condvar,
    scheduled: AtomicU64,
    completed: AtomicU64,
    coalesced: AtomicU64,
    errors: AtomicU64,
}

/// Clears the in-flight mark and wakes waiters even if the builder
/// panics (the pool catches the panic; waiters must not block forever).
struct InflightGuard<'a, K: Eq + Hash, V> {
    owner: &'a Inner<K, V>,
    key: &'a K,
}

impl<K: Eq + Hash, V> Drop for InflightGuard<'_, K, V> {
    fn drop(&mut self) {
        let mut inflight = self.owner.inflight.lock().unwrap();
        inflight.remove(self.key);
        // Notify under the lock so a fetch() checking-then-waiting cannot
        // miss the wakeup.
        self.owner.done.notify_all();
    }
}

/// A claimed in-flight slot for one key, from [`Prefetcher::begin`].
///
/// Exactly one of two things must happen to it: [`PrefetchTicket::commit`]
/// schedules the build on the prefetch pool, or dropping the ticket
/// releases the claim and wakes any consumer that was waiting on it
/// (they fall back to building inline).
pub struct PrefetchTicket<'a, K: Eq + Hash, V> {
    owner: &'a Prefetcher<K, V>,
    key: Option<K>,
}

impl<'a, K, V> PrefetchTicket<'a, K, V>
where
    K: Eq + Hash + Clone + Send + Sync + 'static,
    V: Send + Sync + 'static,
{
    /// Schedule the claimed build on the prefetch pool.
    pub fn commit<E>(mut self, build: impl FnOnce() -> Result<V, E> + Send + 'static)
    where
        E: Send + 'static,
    {
        let key = self.key.take().expect("a ticket commits at most once");
        let owner = self.owner;
        owner.inner.scheduled.fetch_add(1, Ordering::Relaxed);
        let job_inner = owner.inner.clone();
        owner.pool.spawn(move || {
            let _guard = InflightGuard { owner: &job_inner, key: &key };
            // The generation-checked insert path: a hit (someone built it
            // inline meanwhile) is fine, an invalidation mid-build keeps
            // the stale result out of the cache.
            match job_inner.cache.get_or_try_insert(&key, build) {
                Ok(_) => job_inner.completed.fetch_add(1, Ordering::Relaxed),
                Err(_) => job_inner.errors.fetch_add(1, Ordering::Relaxed),
            };
        });
    }

    /// Epoch-versioned [`PrefetchTicket::commit`]: the builder returns
    /// `(value, epoch)` where `epoch` is read from the same input
    /// snapshot the value was built from (fetch the dataset once, build
    /// from it, report its epoch). The generation snapshot is taken
    /// **before** the builder runs — the fence half — and the insert is
    /// epoch-tagged with newest-epoch-wins — the versioning half, which
    /// holds even when a mutation interleaves between the two (see
    /// `docs/mutation.md`).
    pub fn commit_versioned<E>(
        mut self,
        build: impl FnOnce() -> Result<(V, u64), E> + Send + 'static,
    ) where
        E: Send + 'static,
    {
        let key = self.key.take().expect("a ticket commits at most once");
        let owner = self.owner;
        owner.inner.scheduled.fetch_add(1, Ordering::Relaxed);
        let job_inner = owner.inner.clone();
        owner.pool.spawn(move || {
            let _guard = InflightGuard { owner: &job_inner, key: &key };
            let generation = job_inner.cache.generation();
            match build() {
                Ok((value, epoch)) => {
                    job_inner.cache.try_insert_versioned(&key, Arc::new(value), epoch, generation);
                    job_inner.completed.fetch_add(1, Ordering::Relaxed)
                }
                Err(_) => job_inner.errors.fetch_add(1, Ordering::Relaxed),
            };
        });
    }
}

impl<K: Eq + Hash, V> Drop for PrefetchTicket<'_, K, V> {
    fn drop(&mut self) {
        // Not committed: release the claim and wake waiters.
        if let Some(key) = self.key.take() {
            let mut inflight = self.owner.inner.inflight.lock().unwrap();
            inflight.remove(&key);
            self.owner.inner.done.notify_all();
        }
    }
}

/// Stages values into a [`PlanCache`] ahead of need, one in-flight build
/// per key, on a pool of its own. Cheap to clone — clones share state.
pub struct Prefetcher<K, V> {
    inner: Arc<Inner<K, V>>,
    pool: Arc<Pool>,
}

impl<K, V> Clone for Prefetcher<K, V> {
    fn clone(&self) -> Self {
        Prefetcher { inner: self.inner.clone(), pool: self.pool.clone() }
    }
}

impl<K, V> Prefetcher<K, V>
where
    K: Eq + Hash + Clone + Send + Sync + 'static,
    V: Send + Sync + 'static,
{
    /// Wrap `cache` with a prefetcher running builds on `pool`. The pool
    /// must be private to the prefetcher (see the module rules).
    pub fn new(cache: Arc<PlanCache<K, V>>, pool: Arc<Pool>) -> Prefetcher<K, V> {
        Prefetcher {
            inner: Arc::new(Inner {
                cache,
                inflight: Mutex::new(HashSet::new()),
                done: Condvar::new(),
                scheduled: AtomicU64::new(0),
                completed: AtomicU64::new(0),
                coalesced: AtomicU64::new(0),
                errors: AtomicU64::new(0),
            }),
            pool,
        }
    }

    /// Claim the in-flight slot for `key` without scheduling anything
    /// yet. Returns `None` (counting a coalesced request) when the key is
    /// already cached or already claimed. Commit the ticket to schedule
    /// the build; dropping it releases the claim (consumers waiting on
    /// the key fall back to inline builds). The claim/commit split lets
    /// an admission path claim *before* its enqueue — so a consumer
    /// racing ahead waits instead of double-building — while still
    /// scheduling no storage work for requests that end up rejected.
    pub fn begin(&self, key: K) -> Option<PrefetchTicket<'_, K, V>> {
        if self.inner.cache.peek(&key).is_some() {
            self.inner.coalesced.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.claim(key)
    }

    /// Epoch-aware [`Prefetcher::begin`]: coalesces only on a cached
    /// value tagged exactly `epoch`. A resident entry at any *other*
    /// epoch does not suppress the claim — it is useless to consumers
    /// at `epoch`, and letting it coalesce would push the rebuild onto
    /// the consumer's critical path (the epoch-blind `begin` has
    /// exactly that blind spot after a mutation races a stale insert).
    pub fn begin_versioned(&self, key: K, epoch: u64) -> Option<PrefetchTicket<'_, K, V>> {
        if self.inner.cache.peek_versioned(&key, epoch).is_some() {
            self.inner.coalesced.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        self.claim(key)
    }

    fn claim(&self, key: K) -> Option<PrefetchTicket<'_, K, V>> {
        if !self.inner.inflight.lock().unwrap().insert(key.clone()) {
            self.inner.coalesced.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        Some(PrefetchTicket { owner: self, key: Some(key) })
    }

    /// Begin building `key` in the background. Returns `true` when a job
    /// was scheduled, `false` when it coalesced onto the cached value or
    /// an already-in-flight build.
    pub fn prefetch<E>(
        &self,
        key: K,
        build: impl FnOnce() -> Result<V, E> + Send + 'static,
    ) -> bool
    where
        E: Send + 'static,
    {
        match self.begin(key) {
            Some(ticket) => {
                ticket.commit(build);
                true
            }
            None => false,
        }
    }

    /// The consumer side: cached value (hit), else wait for an in-flight
    /// prefetch of `key`, else build inline. Returns `(value, was_hit)`
    /// where a hit means no inline build ran — including values a
    /// prefetch finished while we waited.
    pub fn fetch<E>(
        &self,
        key: &K,
        build: impl FnOnce() -> Result<V, E>,
    ) -> Result<(Arc<V>, bool), E> {
        let inner = &self.inner;
        // One metric-counted lookup per fetch; the wait loop below
        // re-checks with `peek` so a slow build does not inflate the
        // cache's miss counter (or touch LRU recency) once per poll.
        if let Some(v) = inner.cache.get(key) {
            return Ok((v, true));
        }
        loop {
            {
                let inflight = inner.inflight.lock().unwrap();
                if !inflight.contains(key) {
                    drop(inflight);
                    // Nobody building: a final metric-silent re-check (a
                    // build may have landed since the counted lookup),
                    // else build inline. An inline build may race a
                    // brand-new prefetch of the same key — both builds
                    // are valid and the cache's last insert wins, the
                    // same idiom get_or_try_insert documents.
                    if let Some(v) = inner.cache.peek(key) {
                        return Ok((v, true));
                    }
                    return inner.cache.get_or_try_insert(key, build);
                }
                // An in-flight build inserts into the cache *before*
                // clearing its mark, so waking (or timing out) and
                // re-checking never misses a finished build; the timeout
                // guards against a build that died without a notify.
                let _unused =
                    inner.done.wait_timeout(inflight, Duration::from_millis(50)).unwrap();
            }
            if let Some(v) = inner.cache.peek(key) {
                return Ok((v, true));
            }
        }
    }

    /// Epoch-versioned [`Prefetcher::fetch`]: the caller binds `epoch`
    /// from the dataset snapshot it will execute against, so a plan
    /// built for a superseded epoch can never be served — it reads as a
    /// miss (the entry stays resident until the rebuild's insert
    /// replaces it; see [`PlanCache::get_versioned`]) — and a plan
    /// built for a *newer* epoch is left for newer readers while this
    /// caller rebuilds inline from its own snapshot (whose insert then
    /// defers to the newer entry).
    pub fn fetch_versioned<E>(
        &self,
        key: &K,
        epoch: u64,
        build: impl FnOnce() -> Result<V, E>,
    ) -> Result<(Arc<V>, bool), E> {
        let inner = &self.inner;
        if let Some(v) = inner.cache.get_versioned(key, epoch) {
            return Ok((v, true));
        }
        loop {
            {
                let inflight = inner.inflight.lock().unwrap();
                if !inflight.contains(key) {
                    drop(inflight);
                    if let Some(v) = inner.cache.peek_versioned(key, epoch) {
                        return Ok((v, true));
                    }
                    return inner.cache.get_or_try_insert_versioned(key, epoch, build);
                }
                let _unused =
                    inner.done.wait_timeout(inflight, Duration::from_millis(50)).unwrap();
            }
            if let Some(v) = inner.cache.peek_versioned(key, epoch) {
                return Ok((v, true));
            }
        }
    }

    /// Keys currently being built.
    pub fn in_flight(&self) -> usize {
        self.inner.inflight.lock().unwrap().len()
    }

    /// Block until no build is queued or running (shutdown, tests).
    ///
    /// Also drains the underlying pool, so on return every job closure —
    /// and everything it captured — has been dropped. Callers may tear
    /// down state the builders referenced immediately afterwards.
    pub fn wait_idle(&self) {
        {
            let mut inflight = self.inner.inflight.lock().unwrap();
            while !inflight.is_empty() {
                let (next, _) =
                    self.inner.done.wait_timeout(inflight, Duration::from_millis(10)).unwrap();
                inflight = next;
            }
        }
        self.pool.wait_idle();
    }

    /// Counter snapshot.
    pub fn stats(&self) -> PrefetchStats {
        PrefetchStats {
            scheduled: self.inner.scheduled.load(Ordering::Relaxed),
            completed: self.inner.completed.load(Ordering::Relaxed),
            coalesced: self.inner.coalesced.load(Ordering::Relaxed),
            errors: self.inner.errors.load(Ordering::Relaxed),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn setup(capacity: usize) -> (Arc<PlanCache<u32, u64>>, Prefetcher<u32, u64>) {
        let cache = Arc::new(PlanCache::new(capacity));
        let pf = Prefetcher::new(cache.clone(), Arc::new(Pool::new(2)));
        (cache, pf)
    }

    #[test]
    fn prefetch_populates_the_cache_once() {
        let (cache, pf) = setup(4);
        let builds = Arc::new(AtomicUsize::new(0));
        for _ in 0..5 {
            let builds = builds.clone();
            pf.prefetch(7, move || {
                builds.fetch_add(1, Ordering::Relaxed);
                Ok::<_, std::io::Error>(42)
            });
        }
        pf.wait_idle();
        assert_eq!(builds.load(Ordering::Relaxed), 1, "duplicates must coalesce");
        assert_eq!(*cache.peek(&7).unwrap(), 42);
        let s = pf.stats();
        assert_eq!(s.scheduled, 1);
        assert_eq!(s.completed, 1);
        assert_eq!(s.coalesced, 4);
        // A fetch after the prefetch is a pure hit — no inline build.
        let (v, hit) = pf
            .fetch(&7, || panic!("must not rebuild"))
            .unwrap_or_else(|e: std::io::Error| panic!("{e}"));
        assert_eq!((*v, hit), (42, true));
    }

    #[test]
    fn fetch_waits_for_an_in_flight_build_instead_of_duplicating_it() {
        let (_cache, pf) = setup(4);
        let builds = Arc::new(AtomicUsize::new(0));
        {
            let builds = builds.clone();
            pf.prefetch(1, move || {
                std::thread::sleep(Duration::from_millis(60));
                builds.fetch_add(1, Ordering::Relaxed);
                Ok::<_, std::io::Error>(9)
            });
        }
        // Consumer arrives while the build sleeps: it must block, then
        // see the prefetched value as a hit.
        let (v, hit) = pf
            .fetch(&1, || {
                builds.fetch_add(1, Ordering::Relaxed);
                Ok::<_, std::io::Error>(100)
            })
            .unwrap();
        assert_eq!((*v, hit), (9, true));
        assert_eq!(builds.load(Ordering::Relaxed), 1, "exactly one staging read");
    }

    #[test]
    fn builder_errors_leave_the_cache_clean_and_unblock_consumers() {
        let (cache, pf) = setup(4);
        pf.prefetch(3, || Err::<u64, _>("storage gone"));
        pf.wait_idle();
        assert_eq!(pf.stats().errors, 1);
        assert!(cache.peek(&3).is_none());
        // The consumer rebuilds inline and gets a working value.
        let (v, hit) = pf.fetch(&3, || Ok::<_, &str>(5)).unwrap();
        assert_eq!((*v, hit), (5, false));
        // An inline error propagates to the consumer.
        assert_eq!(pf.fetch(&4, || Err::<u64, _>("nope")).unwrap_err(), "nope");
    }

    #[test]
    fn invalidation_racing_a_prefetch_is_not_resurrected() {
        let (cache, pf) = setup(4);
        {
            let cache = cache.clone();
            pf.prefetch(8, move || {
                // Simulate the dataset being republished mid-build.
                cache.invalidate(&8);
                Ok::<_, std::io::Error>(1)
            });
        }
        pf.wait_idle();
        assert!(cache.peek(&8).is_none(), "stale build must not land post-invalidation");
    }

    #[test]
    fn aborted_ticket_releases_the_claim_without_scheduling() {
        let (cache, pf) = setup(4);
        {
            let ticket = pf.begin(5).expect("cold key must claim");
            assert_eq!(pf.in_flight(), 1);
            assert!(pf.begin(5).is_none(), "claimed key coalesces");
            drop(ticket); // e.g. the request was rejected for backpressure
        }
        assert_eq!(pf.in_flight(), 0);
        assert_eq!(pf.stats().scheduled, 0, "an aborted claim never builds");
        // A consumer is not blocked by the released claim.
        let (v, hit) = pf.fetch(&5, || Ok::<_, &str>(1)).unwrap();
        assert_eq!((*v, hit), (1, false));
        assert!(cache.peek(&5).is_some());
    }

    #[test]
    fn versioned_commit_tags_the_epoch_and_fetch_respects_it() {
        let (cache, pf) = setup(4);
        {
            let ticket = pf.begin(2).expect("cold key claims");
            ticket.commit_versioned(|| Ok::<_, std::io::Error>((40, 1)));
        }
        pf.wait_idle();
        assert_eq!(pf.stats().completed, 1);
        // A consumer bound to the matching epoch hits...
        let (v, hit) = pf
            .fetch_versioned(&2, 1, || panic!("must not rebuild"))
            .unwrap_or_else(|e: std::io::Error| panic!("{e}"));
        assert_eq!((*v, hit), (40, true));
        // ...a consumer bound to a newer epoch (the dataset advanced)
        // must NOT be served the stale plan: it rebuilds inline, and
        // the rebuild's insert replaces the superseded entry.
        let (v, hit) = pf.fetch_versioned(&2, 2, || Ok::<_, std::io::Error>(41)).unwrap();
        assert_eq!((*v, hit), (41, false));
        assert!(cache.stale() >= 1, "the superseded plan was seen and bypassed");
        assert_eq!(*cache.peek_versioned(&2, 2).unwrap(), 41);
    }

    #[test]
    fn begin_versioned_ignores_stale_resident_entries() {
        let (cache, pf) = setup(4);
        cache.try_insert_versioned(&6, Arc::new(60), 0, cache.generation());
        // The epoch-blind begin coalesces on the resident entry...
        assert!(pf.begin(6).is_none());
        // ...but at a newer epoch that entry is useless: the versioned
        // begin must claim so staging happens off the critical path.
        let ticket = pf.begin_versioned(6, 1).expect("stale entry must not coalesce");
        drop(ticket);
        // A matching-epoch entry does coalesce.
        assert!(pf.begin_versioned(6, 0).is_none());
    }

    #[test]
    fn stale_epoch_inline_build_defers_to_a_newer_resident_plan() {
        let (cache, pf) = setup(4);
        cache.try_insert_versioned(&9, Arc::new(90), 5, cache.generation());
        // A reader still bound to epoch 4 misses (the entry is newer),
        // rebuilds inline, is served its own result — but its insert
        // must not clobber the epoch-5 plan.
        let (v, hit) = pf.fetch_versioned(&9, 4, || Ok::<_, std::io::Error>(44)).unwrap();
        assert_eq!((*v, hit), (44, false));
        assert_eq!(*cache.peek_versioned(&9, 5).unwrap(), 90, "newer plan survives");
    }

    #[test]
    fn stats_and_in_flight_track_the_lifecycle() {
        let (_cache, pf) = setup(4);
        assert_eq!(pf.in_flight(), 0);
        assert!(pf.prefetch(1, || Ok::<_, std::io::Error>(1)));
        pf.wait_idle();
        assert_eq!(pf.in_flight(), 0);
        assert!(!pf.prefetch(1, || Ok::<_, std::io::Error>(2)), "cached key coalesces");
        let s = pf.stats();
        assert_eq!((s.scheduled, s.completed, s.coalesced, s.errors), (1, 1, 1, 0));
    }
}
