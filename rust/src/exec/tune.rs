//! Measured cost model + offline autotuner behind `repro tune` — the
//! learned half of kernel dispatch (docs/dispatch.md).
//!
//! The hand-tuned heuristics in `dispatch` encode *assumptions* about
//! the machine (how expensive a pool fork-join is, when the row-cache
//! staging repays). The autotuner replaces assumptions with
//! measurements: it benches every admissible kernel×format×precision
//! cell over a grid of synthetic shard profiles — density × row-skew ×
//! feature width, the same axes [`ProfileBucket`] quantizes at serve
//! time — and records the argmin per cell in a schema-versioned JSON
//! profile. Serving installs that profile process-wide
//! ([`install_cost_model`]); [`super::select_kernel_tuned`] then
//! resolves each shard's bucket against the model and falls back to
//! the heuristics for unmeasured buckets, inadmissible picks, or when
//! no/an invalid model is installed.
//!
//! Loading is deliberately forgiving at the call site
//! ([`install_cost_model_from`]): a missing, corrupt, or
//! schema-mismatched profile logs one warning and leaves the heuristics
//! in charge — a stale tuning artifact must never take serving down.
//! Correctness never depends on the model either way: every admissible
//! kernel for a cell is bitwise-identical (`tests/format_equiv.rs`), so
//! the worst a bad model can do is pick a slower kernel.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::bench::Bencher;
use crate::gen;
use crate::graph::{Csr, Ell};
use crate::quant::ChunkedParams;
use crate::rng::Pcg32;
use crate::sampling::{sample_ell, Strategy};
use crate::spmm::{self, simd, AdjQuant, BlockedCsr, DenseTile};
use crate::util::{parse_json, JsonValue};

use super::dispatch::{
    admissible, ExecEnv, FormatKind, FormatMask, GraphProfile, KernelDomain, KernelKind,
};

/// Schema tag every cost-model JSON must carry.
pub const COST_MODEL_SCHEMA: &str = "aes-spmm-cost-model";

/// Current cost-model schema version; profiles with any other version
/// are stale and rejected at load (degrading to heuristics).
pub const COST_MODEL_VERSION: u64 = 1;

/// Padding slack for materializing a [`DenseTile`]: padded slots may be
/// at most this multiple of the stored entries.
pub const DENSE_TILE_SLACK: usize = 4;

/// The operand family a cost-model cell covers.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Family {
    /// Exact aggregation (CSR and its re-layouts).
    Exact,
    /// Sampled fixed-width (ELL) aggregation.
    Sampled,
}

impl Family {
    /// Stable label used in cell keys.
    pub fn name(self) -> &'static str {
        match self {
            Family::Exact => "exact",
            Family::Sampled => "sampled",
        }
    }
}

/// Density band of a profile bucket (mean edges per row).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Density {
    /// Mean row nnz below 8.
    Sparse,
    /// Mean row nnz in `[8, 64)`.
    Mid,
    /// Mean row nnz 64 and up.
    Dense,
}

/// Row-skew band of a profile bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Skew {
    /// Longest row within 8× the mean.
    Uniform,
    /// Longest row beyond 8× the mean (power-law tails).
    Skewed,
}

/// Feature-width band of a profile bucket.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FeatBand {
    /// Feature dim below 32.
    Narrow,
    /// Feature dim 32 and up.
    Wide,
}

/// The quantized shard profile cost-model cells are keyed by. Coarse on
/// purpose: buckets must generalize from the tuner's synthetic grid to
/// real shards, and every kernel choice within a bucket is
/// bitwise-equal, so a misbucketed shard costs only speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProfileBucket {
    /// Mean-degree band.
    pub density: Density,
    /// Longest-row-vs-mean band.
    pub skew: Skew,
    /// Feature-width band.
    pub feat: FeatBand,
}

impl ProfileBucket {
    /// Quantize a graph profile + feature dim into its bucket.
    pub fn of(profile: &GraphProfile, feat_dim: usize) -> ProfileBucket {
        let mean = profile.mean_nnz;
        let density = if mean < 8.0 {
            Density::Sparse
        } else if mean < 64.0 {
            Density::Mid
        } else {
            Density::Dense
        };
        let skew = if (profile.max_nnz as f64) > 8.0 * mean.max(1.0) {
            Skew::Skewed
        } else {
            Skew::Uniform
        };
        let feat = if feat_dim < 32 {
            FeatBand::Narrow
        } else {
            FeatBand::Wide
        };
        ProfileBucket { density, skew, feat }
    }

    /// Stable key prefix, e.g. `"mid/skewed/wide"`.
    pub fn key(&self) -> String {
        let d = match self.density {
            Density::Sparse => "sparse",
            Density::Mid => "mid",
            Density::Dense => "dense",
        };
        let s = match self.skew {
            Skew::Uniform => "uniform",
            Skew::Skewed => "skewed",
        };
        let f = match self.feat {
            FeatBand::Narrow => "narrow",
            FeatBand::Wide => "wide",
        };
        format!("{d}/{s}/{f}")
    }
}

/// Full cell key: bucket + family + domain, e.g.
/// `"dense/uniform/wide/exact/f32"`.
pub fn cell_key(bucket: &ProfileBucket, family: Family, domain: KernelDomain) -> String {
    format!("{}/{}/{}", bucket.key(), family.name(), domain.name())
}

/// A measured kernel-selection table: per-cell argmin kernels plus the
/// raw measurements they came from. Serialized as schema-versioned JSON
/// (`repro tune --out`), loaded and installed process-wide for serving.
#[derive(Clone, Debug, Default)]
pub struct CostModel {
    /// Advisory machine description recorded at tune time (threads,
    /// SIMD level, cache sizes). Never validated on load — a profile
    /// tuned elsewhere is legal, merely likely suboptimal.
    machine: BTreeMap<String, JsonValue>,
    /// Cell key → chosen kernel.
    cells: BTreeMap<String, KernelKind>,
    /// `(cell, kernel, median_ns)` for every candidate benched.
    measurements: Vec<(String, String, f64)>,
}

impl CostModel {
    /// Empty model stamped with this machine's description.
    pub fn new() -> CostModel {
        let env = ExecEnv::detect();
        let cache = simd::cache_profile();
        let mut machine = BTreeMap::new();
        machine.insert("threads".to_string(), JsonValue::Num(env.threads as f64));
        machine.insert("simd".to_string(), JsonValue::Str(simd::level().name().to_string()));
        machine.insert("l1d_bytes".to_string(), JsonValue::Num(cache.l1d_bytes as f64));
        machine.insert("llc_bytes".to_string(), JsonValue::Num(cache.llc_bytes as f64));
        CostModel { machine, cells: BTreeMap::new(), measurements: Vec::new() }
    }

    /// Set the kernel for one cell (the tuner's argmin; tests and
    /// benches build targeted models the same way).
    pub fn set_cell(
        &mut self,
        bucket: &ProfileBucket,
        family: Family,
        domain: KernelDomain,
        kind: KernelKind,
    ) {
        self.cells.insert(cell_key(bucket, family, domain), kind);
    }

    /// The kernel stored for `key`, if the cell was measured.
    pub fn cell(&self, key: &str) -> Option<KernelKind> {
        self.cells.get(key).copied()
    }

    /// Measured cells.
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    /// Whether no cell has been measured.
    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Resolve a selection against the model: bucket the profile, look
    /// up the (family, domain) cell. `None` for unmeasured buckets —
    /// the caller falls back to the heuristics.
    pub fn choose(
        &self,
        profile: &GraphProfile,
        feat_dim: usize,
        width: Option<usize>,
        domain: KernelDomain,
    ) -> Option<KernelKind> {
        let family = if width.is_some() { Family::Sampled } else { Family::Exact };
        let bucket = ProfileBucket::of(profile, feat_dim);
        self.cell(&cell_key(&bucket, family, domain))
    }

    /// FNV-1a over the selection table (cells only — measurements and
    /// machine info are advisory). Never 0: plan-cache keys reserve 0
    /// for "no model installed", so any installed model changes the
    /// [`super::ShardKey`] and cached units can never leak across model
    /// swaps.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        eat(&COST_MODEL_VERSION.to_le_bytes());
        for (k, v) in &self.cells {
            eat(k.as_bytes());
            eat(&[0]);
            eat(v.name().as_bytes());
            eat(&[0]);
        }
        if h == 0 {
            1
        } else {
            h
        }
    }

    fn push_measurement(&mut self, cell: &str, kernel: &str, median_ns: f64) {
        self.measurements.push((cell.to_string(), kernel.to_string(), median_ns));
    }

    /// Serialize to the schema-versioned JSON document.
    pub fn to_json(&self) -> JsonValue {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), JsonValue::Str(COST_MODEL_SCHEMA.to_string()));
        root.insert("version".to_string(), JsonValue::Num(COST_MODEL_VERSION as f64));
        root.insert("machine".to_string(), JsonValue::Obj(self.machine.clone()));
        let cells = self
            .cells
            .iter()
            .map(|(k, v)| (k.clone(), JsonValue::Str(v.name().to_string())))
            .collect();
        root.insert("cells".to_string(), JsonValue::Obj(cells));
        let meas = self
            .measurements
            .iter()
            .map(|(cell, kernel, ns)| {
                let mut m = BTreeMap::new();
                m.insert("cell".to_string(), JsonValue::Str(cell.clone()));
                m.insert("kernel".to_string(), JsonValue::Str(kernel.clone()));
                m.insert("median_ns".to_string(), JsonValue::Num(*ns));
                JsonValue::Obj(m)
            })
            .collect();
        root.insert("measurements".to_string(), JsonValue::Arr(meas));
        JsonValue::Obj(root)
    }

    /// Parse and validate a cost-model document. Errors (never panics)
    /// on a schema mismatch, a stale version, or an unknown kernel
    /// name — the degrade-to-heuristics cases.
    pub fn from_json(v: &JsonValue) -> Result<CostModel> {
        let schema = v.get("schema")?.as_str().context("schema tag")?;
        if schema != COST_MODEL_SCHEMA {
            bail!("schema {schema:?} is not {COST_MODEL_SCHEMA:?}");
        }
        let version = v.get("version")?.as_f64().context("schema version")? as u64;
        if version != COST_MODEL_VERSION {
            bail!("cost-model version {version} is stale (expected {COST_MODEL_VERSION})");
        }
        let machine = match v.get("machine") {
            Ok(m) => m.as_obj().context("machine info")?.clone(),
            Err(_) => BTreeMap::new(),
        };
        let mut cells = BTreeMap::new();
        for (key, val) in v.get("cells")?.as_obj().context("cells table")? {
            let name = val.as_str().with_context(|| format!("cell {key:?}"))?;
            let kind = KernelKind::parse(name)
                .with_context(|| format!("cell {key:?} names unknown kernel {name:?}"))?;
            cells.insert(key.clone(), kind);
        }
        Ok(CostModel { machine, cells, measurements: Vec::new() })
    }

    /// Read + parse + validate a profile from disk.
    pub fn load(path: &Path) -> Result<CostModel> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading cost model {}", path.display()))?;
        let doc = parse_json(&text)
            .with_context(|| format!("parsing cost model {}", path.display()))?;
        CostModel::from_json(&doc)
            .with_context(|| format!("validating cost model {}", path.display()))
    }
}

/// The process-wide installed model [`super::select_kernel_tuned`]
/// consults. `RwLock` (not OnceLock): eval and tests install/uninstall
/// around runs, and serving may hot-swap a freshly tuned profile.
static INSTALLED: RwLock<Option<Arc<CostModel>>> = RwLock::new(None);

/// Install (Some) or clear (None) the process-wide cost model; returns
/// the previous installation so callers can restore it.
pub fn install_cost_model(model: Option<Arc<CostModel>>) -> Option<Arc<CostModel>> {
    let mut slot = INSTALLED.write().unwrap_or_else(|e| e.into_inner());
    std::mem::replace(&mut *slot, model)
}

/// The currently installed cost model, if any.
pub fn installed_cost_model() -> Option<Arc<CostModel>> {
    INSTALLED.read().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Fingerprint of the installed model, 0 when running on heuristics —
/// mixed into [`super::ShardKey`] so cached shard units are scoped to
/// the selection table that built them.
pub fn installed_fingerprint() -> u64 {
    installed_cost_model().map(|m| m.fingerprint()).unwrap_or(0)
}

/// Load a profile and install it; on any validation failure, warn once
/// on stderr, leave the current installation untouched, and return
/// false. The never-panic half of the fallback contract.
pub fn install_cost_model_from(path: &Path) -> bool {
    match CostModel::load(path) {
        Ok(model) => {
            install_cost_model(Some(Arc::new(model)));
            true
        }
        Err(e) => {
            eprintln!("warning: ignoring cost model ({e:#}); dispatch stays on heuristics");
            false
        }
    }
}

/// Dispatch's hook: the installed model's pick for this selection, if
/// any. Admissibility is the caller's job ([`super::select_kernel_tuned`]).
pub(crate) fn consult(
    profile: &GraphProfile,
    feat_dim: usize,
    width: Option<usize>,
    domain: KernelDomain,
) -> Option<KernelKind> {
    installed_cost_model()?.choose(profile, feat_dim, width, domain)
}

/// Autotuner knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct TuneOptions {
    /// Shrink the synthetic graphs and per-candidate bench budget
    /// (CI's `repro tune --quick`): coarser medians, same schema.
    pub quick: bool,
}

/// The sampled-family width the tuner measures at — one representative
/// point; sampled cells vary far less across widths than across
/// density/skew, and the bucket already captures the post-sampling
/// profile.
const TUNE_SAMPLE_WIDTH: usize = 32;

/// Bench every admissible kernel×format×precision cell over the
/// synthetic profile grid (density × skew × feature width) and return
/// the per-cell argmin table. Prints progress like a bench target.
pub fn run_tune(opts: &TuneOptions) -> CostModel {
    let env = ExecEnv::detect();
    let bench = if opts.quick {
        Bencher {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 8,
            budget: Duration::from_millis(120),
        }
    } else {
        Bencher {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 25,
            budget: Duration::from_millis(400),
        }
    };
    super::warm_pool();
    let mut model = CostModel::new();
    let n: usize = if opts.quick { 1024 } else { 3072 };

    let degs = [4.0f64, 24.0, 96.0];
    let feats = [16usize, 64];
    let mut grid_idx: u64 = 0;
    for deg in degs {
        for skewed in [false, true] {
            let mut rng = Pcg32::new(0xC057_0000 + grid_idx);
            grid_idx += 1;
            // Uniform profiles from G(n, m) (binomial degrees), skewed
            // from a heavy-tailed Chung-Lu. Buckets are computed from
            // the *generated* operand's measured profile, so whatever
            // shape comes out lands in the cell real shards of that
            // shape will hit.
            let g = if skewed {
                gen::chung_lu(n, deg, 1.7, &mut rng)
            } else {
                gen::erdos_renyi(n, (deg * n as f64 / 2.0) as usize, &mut rng)
            };
            for f in feats {
                tune_one_operand(&g, f, &env, &bench, &mut rng, &mut model);
            }
        }
    }
    println!("\ntuned {} cells ({} measurements)", model.len(), model.measurements.len());
    model
}

/// Everything the candidate runner needs, pre-built once per operand.
struct Operands<'a> {
    g: &'a Csr,
    bcsr: &'a BlockedCsr,
    dense: Option<&'a DenseTile>,
    ell: &'a Ell,
    aq_csr: &'a AdjQuant,
    aq_ell: &'a AdjQuant,
    b: &'a [f32],
    qb: &'a [u8],
}

fn run_candidate(kind: KernelKind, ops: &Operands, f: usize, out: &mut [f32], threads: usize) {
    use super::dispatch as d;
    match (kind.format(), kind.is_i8()) {
        (FormatKind::Csr, false) => d::run_exact(kind, ops.g, ops.b, f, out, threads),
        (FormatKind::Csr, true) => {
            d::run_exact_i8(kind, ops.g, ops.aq_csr, ops.qb, f, out, threads)
        }
        (FormatKind::Ell, false) => d::run_ell(kind, ops.ell, ops.b, f, out, threads),
        (FormatKind::Ell, true) => {
            d::run_ell_i8(kind, ops.ell, ops.aq_ell, ops.qb, f, out, threads)
        }
        (FormatKind::Blocked, false) => d::run_blocked(kind, ops.bcsr, ops.b, f, out, threads),
        (FormatKind::Blocked, true) => {
            d::run_blocked_i8(kind, ops.bcsr, ops.aq_csr, ops.qb, f, out, threads)
        }
        (FormatKind::Dense, false) => {
            d::run_dense(kind, ops.dense.expect("dense operand"), ops.b, f, out, threads)
        }
        (FormatKind::Dense, true) => {
            let t = ops.dense.expect("dense operand");
            d::run_dense_i8(kind, t, ops.aq_csr, ops.qb, f, out, threads)
        }
    }
}

/// Measure all four (family × domain) cells for one synthetic operand
/// at one feature width, keeping first-measured cells (earlier grid
/// points win ties between grid shapes that bucket identically).
fn tune_one_operand(
    g: &Csr,
    f: usize,
    env: &ExecEnv,
    bench: &Bencher,
    rng: &mut Pcg32,
    model: &mut CostModel,
) {
    let n = g.n_rows;
    let b: Vec<f32> = (0..n * f).map(|_| rng.f32() - 0.5).collect();
    let params = ChunkedParams::of_rows(&b, n, f, (n / 8).max(1));
    let qb = params.quantize_rows(&b, f);
    let aq_csr = AdjQuant::from_csr(g, &params);
    let bcsr = BlockedCsr::from_csr(g, spmm::BCSR_BLOCK_ROWS);
    let dense = if spmm::dense_tile_viable(g, DENSE_TILE_SLACK) {
        Some(DenseTile::from_csr(g))
    } else {
        None
    };
    let ell = sample_ell(g, TUNE_SAMPLE_WIDTH, Strategy::Aes);
    let aq_ell = AdjQuant::from_ell(&ell, &params);
    let ops = Operands {
        g,
        bcsr: &bcsr,
        dense: dense.as_ref(),
        ell: &ell,
        aq_csr: &aq_csr,
        aq_ell: &aq_ell,
        b: &b,
        qb: &qb,
    };
    let mask = FormatMask { blocked: true, dense: dense.is_some() };
    let mut out = vec![0.0f32; n * f];

    for family in [Family::Exact, Family::Sampled] {
        let (profile, width) = match family {
            Family::Exact => (GraphProfile::of(g), None),
            Family::Sampled => (GraphProfile::of_ell(&ell), Some(TUNE_SAMPLE_WIDTH)),
        };
        let bucket = ProfileBucket::of(&profile, f);
        for domain in [KernelDomain::F32, KernelDomain::I8] {
            let key = cell_key(&bucket, family, domain);
            if model.cell(&key).is_some() {
                continue;
            }
            let mut best_kind: Option<KernelKind> = None;
            let mut best_ns = f64::INFINITY;
            for kind in KernelKind::ALL {
                if !admissible(kind, &profile, f, width, env, domain, mask) {
                    continue;
                }
                let name = kind.name();
                let r = bench.run(name, || run_candidate(kind, &ops, f, &mut out, env.threads));
                let ns = r.median.as_nanos() as f64;
                model.push_measurement(&key, name, ns);
                if ns < best_ns {
                    best_ns = ns;
                    best_kind = Some(kind);
                }
            }
            if let Some(kind) = best_kind {
                println!("{key:<32} -> {:<18} ({:.0} µs)", kind.name(), best_ns / 1e3);
                model.set_cell(&bucket, family, domain, kind);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile(n_rows: usize, nnz: usize, max_nnz: usize) -> GraphProfile {
        GraphProfile {
            n_rows,
            nnz,
            mean_nnz: nnz as f64 / n_rows.max(1) as f64,
            max_nnz,
        }
    }

    #[test]
    fn buckets_quantize_on_the_documented_thresholds() {
        // density: mean < 8 | < 64 | >= 64
        let b = ProfileBucket::of(&profile(100, 700, 20), 64);
        assert_eq!(b.density, Density::Sparse);
        assert_eq!((b.skew, b.feat), (Skew::Uniform, FeatBand::Wide));
        let b = ProfileBucket::of(&profile(100, 800, 20), 64);
        assert_eq!(b.density, Density::Mid);
        let b = ProfileBucket::of(&profile(100, 6_400, 80), 64);
        assert_eq!(b.density, Density::Dense);
        // skew: max > 8× mean
        let b = ProfileBucket::of(&profile(100, 1_000, 81), 16);
        assert_eq!((b.skew, b.feat), (Skew::Skewed, FeatBand::Narrow));
        assert_eq!(ProfileBucket::of(&profile(100, 1_000, 80), 16).skew, Skew::Uniform);
        assert_eq!(b.key(), "mid/skewed/narrow");
    }

    #[test]
    fn cells_round_trip_through_choose() {
        let mut m = CostModel::default();
        let p = profile(1000, 100_000, 150);
        let bucket = ProfileBucket::of(&p, 64);
        m.set_cell(&bucket, Family::Exact, KernelDomain::F32, KernelKind::CsrBlocked);
        m.set_cell(&bucket, Family::Sampled, KernelDomain::I8, KernelKind::EllSampledI8Par);
        assert_eq!(m.choose(&p, 64, None, KernelDomain::F32), Some(KernelKind::CsrBlocked));
        assert_eq!(
            m.choose(&p, 64, Some(16), KernelDomain::I8),
            Some(KernelKind::EllSampledI8Par)
        );
        // Unmeasured cells answer None (heuristic fallback).
        assert_eq!(m.choose(&p, 64, None, KernelDomain::I8), None);
        assert_eq!(m.choose(&p, 4, None, KernelDomain::F32), None);
    }

    #[test]
    fn json_round_trips_and_validates() {
        let mut m = CostModel::new();
        let p = profile(1000, 100_000, 150);
        let bucket = ProfileBucket::of(&p, 64);
        m.set_cell(&bucket, Family::Exact, KernelDomain::F32, KernelKind::ExactDense);
        m.push_measurement("dense/uniform/wide/exact/f32", "dense_spmm", 1234.0);
        let doc = m.to_json();
        let back = CostModel::from_json(&doc).unwrap();
        assert_eq!(back.len(), 1);
        let got = back.cell("dense/uniform/wide/exact/f32");
        assert_eq!(got, Some(KernelKind::ExactDense));
        // Measurements are advisory: dropped on load, absent from the
        // fingerprint.
        assert_eq!(back.fingerprint(), m.fingerprint());
    }

    #[test]
    fn stale_or_corrupt_documents_are_errors_not_panics() {
        // The schema tag is spelled out to pin the on-disk constant.
        let cases = [
            // Wrong schema tag.
            r#"{"schema":"bogus","version":1,"cells":{}}"#,
            // Stale version.
            r#"{"schema":"aes-spmm-cost-model","version":999,"cells":{}}"#,
            // Unknown kernel name.
            r#"{"schema":"aes-spmm-cost-model","version":1,"cells":{"x":"warp_drive"}}"#,
            // Missing cells table.
            r#"{"schema":"aes-spmm-cost-model","version":1}"#,
            // Cells is not an object.
            r#"{"schema":"aes-spmm-cost-model","version":1,"cells":7}"#,
        ];
        for raw in cases {
            let doc = parse_json(raw).unwrap();
            assert!(CostModel::from_json(&doc).is_err(), "accepted: {raw}");
        }
    }

    #[test]
    fn fingerprint_tracks_cells_and_is_never_zero() {
        let mut a = CostModel::default();
        assert_ne!(a.fingerprint(), 0);
        let fp_empty = a.fingerprint();
        let p = profile(1000, 100_000, 150);
        let bucket = ProfileBucket::of(&p, 64);
        a.set_cell(&bucket, Family::Exact, KernelDomain::F32, KernelKind::CsrBlocked);
        assert_ne!(a.fingerprint(), fp_empty);
        let fp_blocked = a.fingerprint();
        a.set_cell(&bucket, Family::Exact, KernelDomain::F32, KernelKind::CsrNaive);
        assert_ne!(a.fingerprint(), fp_blocked);
    }

    // NOTE: no test in this (lib) binary installs a global model — the
    // heuristic-pinning dispatch tests run in the same process, and a
    // concurrently installed model would flip their expectations. The
    // install/uninstall paths are covered by `tests/cost_model.rs`,
    // which serializes its global-state tests behind a mutex.
}
