//! Row planners: expand Table 1 + Eq. 3 into concrete ELL plans, and the
//! sampling-rate statistics behind Fig. 5.

use crate::graph::{Csr, Ell};

use super::strategy::{start_index, strategy_params, Strategy};

/// Within-row source offsets for each ELL slot of a row (Algorithm 1
/// lines 7–13): sample `s` writes its `j`-th element to slot
/// `s + j * sample_cnt`. Returns offsets for the `slots` valid entries.
pub fn plan_row(row_nnz: usize, width: usize, strategy: Strategy) -> Vec<usize> {
    let p = strategy_params(row_nnz, width, strategy);
    let mut out = Vec::with_capacity(p.slots);
    for k in 0..p.slots {
        let s = k % p.sample_cnt;
        let j = k / p.sample_cnt;
        out.push(start_index(s, row_nnz, p.n) + j);
    }
    out
}

/// Sample a CSR matrix into ELL form — the host-side mirror of the L1
/// `aes_sample` kernel (bit-exact on col indices and slot counts).
pub fn sample_ell(csr: &Csr, width: usize, strategy: Strategy) -> Ell {
    let mut ell = Ell::zeros(csr.n_rows, csr.n_cols, width);
    sample_rows_into(
        csr,
        width,
        strategy,
        0..csr.n_rows,
        &mut ell.val,
        &mut ell.col,
        &mut ell.slots,
    );
    ell
}

/// Allocation-free row-range sampler used by both the serial and parallel
/// paths. Slices are the *full-graph* buffers; only `rows` is written.
/// The inner loop inlines `plan_row`'s math (no per-row Vec), which is
/// what the GPU kernel does per thread.
fn sample_rows_into(
    csr: &Csr,
    width: usize,
    strategy: Strategy,
    rows: std::ops::Range<usize>,
    val_out: &mut [f32],
    col_out: &mut [i32],
    slots_out: &mut [i32],
) {
    for i in rows {
        let base = csr.row_ptr[i] as usize;
        let nnz = csr.row_nnz(i);
        let p = strategy_params(nnz, width, strategy);
        slots_out[i] = p.slots as i32;
        let row_val = &mut val_out[i * width..i * width + p.slots];
        let row_col = &mut col_out[i * width..i * width + p.slots];
        // Iterate sample-major: for each sample s, its run of N elements
        // lands at slots s, s+cnt, s+2cnt, ... (Algorithm 1's layout).
        for s in 0..p.sample_cnt.min(p.slots) {
            let start = base + start_index(s, nnz, p.n);
            let mut slot = s;
            let mut j = 0;
            while slot < p.slots && j < p.n {
                row_val[slot] = csr.val[start + j];
                row_col[slot] = csr.col_ind[start + j];
                slot += p.sample_cnt;
                j += 1;
            }
        }
        // Zero the padding tail (buffers may be reused across calls).
        for k in p.slots..width {
            val_out[i * width + k] = 0.0;
            col_out[i * width + k] = 0;
        }
    }
}

/// Parallel in-place sampling into a reusable [`Ell`] — the multi-core
/// mirror of the GPU kernel's lines 5–14, where thousands of threads
/// sample rows concurrently. `ell` must have matching dims. Chunks run
/// on the persistent [`crate::exec`] pool (no per-call thread spawns).
pub fn sample_ell_par(csr: &Csr, width: usize, strategy: Strategy, ell: &mut Ell, threads: usize) {
    assert_eq!(ell.n_rows, csr.n_rows);
    assert_eq!(ell.width, width);
    let parts = threads.max(1).min(csr.n_rows.max(1));
    let chunk = csr.n_rows.div_ceil(parts);
    // Split the output buffers along row boundaries for the workers.
    let mut val_rest: &mut [f32] = &mut ell.val;
    let mut col_rest: &mut [i32] = &mut ell.col;
    let mut slots_rest: &mut [i32] = &mut ell.slots;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(parts);
    for part in 0..parts {
        let lo = part * chunk;
        let hi = ((part + 1) * chunk).min(csr.n_rows);
        if lo >= hi {
            break;
        }
        let (val_chunk, vr) = val_rest.split_at_mut((hi - lo) * width);
        let (col_chunk, cr) = col_rest.split_at_mut((hi - lo) * width);
        let (slots_chunk, sr) = slots_rest.split_at_mut(hi - lo);
        val_rest = vr;
        col_rest = cr;
        slots_rest = sr;
        tasks.push(Box::new(move || {
            // Re-base the chunk slices to local row indices.
            for i in lo..hi {
                let li = i - lo;
                let base = csr.row_ptr[i] as usize;
                let nnz = csr.row_nnz(i);
                let p = strategy_params(nnz, width, strategy);
                slots_chunk[li] = p.slots as i32;
                for s_idx in 0..p.sample_cnt.min(p.slots) {
                    let start = base + start_index(s_idx, nnz, p.n);
                    let mut slot = s_idx;
                    let mut j = 0;
                    while slot < p.slots && j < p.n {
                        val_chunk[li * width + slot] = csr.val[start + j];
                        col_chunk[li * width + slot] = csr.col_ind[start + j];
                        slot += p.sample_cnt;
                        j += 1;
                    }
                }
                for k in p.slots..width {
                    val_chunk[li * width + k] = 0.0;
                    col_chunk[li * width + k] = 0;
                }
            }
        }));
    }
    crate::exec::global_pool().run(tasks);
}

/// Bytes one resident ELL slot costs with fp32 edge values: an `i32`
/// column index plus an `f32` coefficient. The global width W is
/// budgeted in these units, so passing this constant to [`shard_width`]
/// reproduces the original fp32 tile decision exactly.
pub const FP32_EDGE_BYTES: usize = 8;

/// Bytes one resident ELL slot costs on the true-INT8-compute path: an
/// `i32` column index plus an `i8` requantized coefficient
/// (`crate::spmm::AdjQuant` stores `qa: Vec<i8>`; the per-row scale and
/// base amortize to nothing across a tile).
pub const I8_EDGE_BYTES: usize = 5;

/// Shard-local ELL tile width — the shard analog of the paper's
/// shared-memory width W. A shard whose longest row fits the byte
/// budget keeps **every** edge regardless of strategy (Table 1's
/// `row_nnz <= W` fast path), so its tile can shrink to the power of
/// two covering its max degree: less padding memory, bit-identical
/// output. A shard with overflowing rows keeps the full global width so
/// its sampled rows match the unsharded plan exactly.
///
/// The budget is `width` slots **at fp32 edge cost**
/// ([`FP32_EDGE_BYTES`]): with `bytes_per_edge = FP32_EDGE_BYTES` the
/// exhaustive cap is exactly `width`, preserving the original decision
/// bit for bit. Lighter edges widen the exhaustive window — at
/// [`I8_EDGE_BYTES`] a shard whose max degree is up to `width * 8 / 5`
/// still fits the same memory and keeps every edge instead of
/// sampling. The serving path always passes [`FP32_EDGE_BYTES`]:
/// shard units are shared across precision siblings (one build warms
/// every route), so the tile decision must not depend on precision.
/// The i8 budget is for i8-only deployments that size their own plans.
pub fn shard_width(width: usize, shard_max_degree: usize, bytes_per_edge: usize) -> usize {
    let cap = (width.max(1) * FP32_EDGE_BYTES / bytes_per_edge.max(1)).max(1);
    if shard_max_degree <= cap {
        shard_max_degree.next_power_of_two().clamp(1, cap)
    } else {
        width
    }
}

/// Fraction of edges kept by sampling — Fig. 5's per-graph statistic.
/// Draws are capped at `row_nnz` per row (overlap never counts > 1).
pub fn sampling_rate(csr: &Csr, width: usize, strategy: Strategy) -> f64 {
    let mut kept = 0usize;
    let mut total = 0usize;
    for i in 0..csr.n_rows {
        let nnz = csr.row_nnz(i);
        let p = strategy_params(nnz, width, strategy);
        kept += p.slots.min(nnz);
        total += nnz;
    }
    if total == 0 {
        1.0
    } else {
        kept as f64 / total as f64
    }
}

/// Per-row sampling rates sorted ascending — the CDF series of Fig. 5.
/// Rows with no edges are reported as rate 1.0 (nothing to lose).
pub fn sampling_rate_cdf(csr: &Csr, width: usize, strategy: Strategy) -> Vec<f64> {
    let mut rates: Vec<f64> = (0..csr.n_rows)
        .map(|i| {
            let nnz = csr.row_nnz(i);
            if nnz == 0 {
                return 1.0;
            }
            let p = strategy_params(nnz, width, strategy);
            p.slots.min(nnz) as f64 / nnz as f64
        })
        .collect();
    rates.sort_by(|a, b| a.partial_cmp(b).unwrap());
    rates
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::rng::Pcg32;

    #[test]
    fn plan_offsets_in_bounds_and_layout() {
        for nnz in [0usize, 1, 5, 16, 63, 64, 65, 100, 999, 40_000] {
            for width in [16usize, 32, 64, 128, 256] {
                for strat in Strategy::ALL {
                    let offs = plan_row(nnz, width, strat);
                    let p = strategy_params(nnz, width, strat);
                    assert_eq!(offs.len(), p.slots);
                    for (k, &off) in offs.iter().enumerate() {
                        assert!(off < nnz.max(1), "off {off} nnz {nnz}");
                        // slot k's sample/run indices reconstruct its offset
                        let s = k % p.sample_cnt;
                        let j = k / p.sample_cnt;
                        assert_eq!(off, start_index(s, nnz, p.n) + j);
                        assert!(j < p.n);
                    }
                }
            }
        }
    }

    #[test]
    fn small_row_keeps_everything_in_order() {
        let offs = plan_row(7, 16, Strategy::Aes);
        assert_eq!(offs, vec![0, 1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn sfs_takes_prefix() {
        let offs = plan_row(100, 16, Strategy::Sfs);
        assert_eq!(offs, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn afs_is_spread_out() {
        let offs = plan_row(1000, 16, Strategy::Afs);
        // hash: (s*1429) % 1000 for s in 0..16 — distinct and spread.
        let max = *offs.iter().max().unwrap();
        let min = *offs.iter().min().unwrap();
        assert!(max > 800 && min < 100, "AFS should span the row: {offs:?}");
    }

    #[test]
    fn sample_ell_is_valid_and_matches_plan() {
        let mut rng = Pcg32::new(5);
        let csr = gen::chung_lu(500, 20.0, 1.8, &mut rng);
        for strat in Strategy::ALL {
            let ell = sample_ell(&csr, 32, strat);
            ell.validate().unwrap();
            // slot counts agree with strategy_params
            for i in 0..csr.n_rows {
                let p = strategy_params(csr.row_nnz(i), 32, strat);
                assert_eq!(ell.slots[i] as usize, p.slots);
            }
        }
    }

    #[test]
    fn parallel_sampler_matches_serial() {
        let mut rng = Pcg32::new(21);
        let csr = gen::chung_lu(700, 45.0, 1.8, &mut rng);
        for strat in Strategy::ALL {
            for width in [16usize, 32, 64] {
                let serial = sample_ell(&csr, width, strat);
                let mut par = crate::graph::Ell::zeros(csr.n_rows, csr.n_cols, width);
                // Dirty the buffers to prove padding gets re-zeroed.
                par.val.fill(7.0);
                par.col.fill(3);
                for threads in [1, 3, 8] {
                    sample_ell_par(&csr, width, strat, &mut par, threads);
                    assert_eq!(par, serial, "{strat:?} w{width} t{threads}");
                }
            }
        }
    }

    /// The live-mutation contract (`docs/mutation.md`): `shard_width`
    /// is a pure function of (W, shard max degree), so re-evaluating it
    /// after a delta changes a shard's max degree is what flips the
    /// shard between the exhaustive and sampled branches — in both
    /// directions, and exactly at the W boundary.
    #[test]
    fn shard_width_flips_branches_as_mutation_moves_max_degree() {
        let w = 8usize;
        let fp = FP32_EDGE_BYTES;
        // Uniform shard (max degree 3): exhaustive shrunken tile.
        assert_eq!(shard_width(w, 3, fp), 4);
        // A delta grows some row to degree 15: the re-evaluated tile
        // must be the full W (the sampled branch).
        assert_eq!(shard_width(w, 15, fp), w);
        // Deleting edges back below W flips it to exhaustive again.
        assert_eq!(shard_width(w, 6, fp), 8);
        assert_eq!(shard_width(w, 2, fp), 2);
        // The boundary itself: max degree == W stays exhaustive; one
        // past it samples.
        assert_eq!(shard_width(w, w, fp), w);
        assert_eq!(shard_width(w, w + 1, fp), w);
        assert!(w >= shard_width(w, w, fp), "fp32 tiles never exceed W");
    }

    #[test]
    fn shard_width_shrinks_only_when_everything_fits() {
        let fp = FP32_EDGE_BYTES;
        // Uniform shard: max degree 5 under W=16 → tile 8, exhaustive.
        assert_eq!(shard_width(16, 5, fp), 8);
        assert_eq!(shard_width(16, 16, fp), 16);
        assert_eq!(shard_width(16, 1, fp), 1);
        // Empty shard clamps to a 1-wide (all-padding) tile.
        assert_eq!(shard_width(16, 0, fp), 1);
        // Skewed shard: rows overflow → keep the global width verbatim.
        assert_eq!(shard_width(16, 17, fp), 16);
        assert_eq!(shard_width(16, 40_000, fp), 16);
        // Shrunken tiles still keep every edge (row_nnz <= width holds
        // for all rows), so sampled output is bit-identical.
        let mut rng = Pcg32::new(33);
        let csr = gen::chung_lu(200, 5.0, 2.0, &mut rng);
        let wmax = csr.max_degree();
        let local = shard_width(4 * wmax.max(1), wmax, fp);
        assert!(local >= wmax);
        let full = sample_ell(&csr, 4 * wmax.max(1), Strategy::Aes);
        let narrow = sample_ell(&csr, local, Strategy::Aes);
        for i in 0..csr.n_rows {
            assert_eq!(full.slots[i], narrow.slots[i]);
            let s = full.slots[i] as usize;
            assert_eq!(
                &full.val[i * full.width..i * full.width + s],
                &narrow.val[i * narrow.width..i * narrow.width + s]
            );
        }
    }

    /// The byte-budget contract: fp32 edge cost reproduces the original
    /// decision exactly, while the lighter i8 edges widen the
    /// exhaustive window to `W * 8 / 5` within the same memory.
    #[test]
    fn shard_width_budgets_like_units_per_edge_encoding() {
        // With fp32 edges the cap is W itself, for every W.
        for w in [1usize, 4, 8, 16, 64] {
            for d in [0usize, 1, w / 2 + 1, w, w + 1, 3 * w] {
                let got = shard_width(w, d, FP32_EDGE_BYTES);
                let want = if d <= w {
                    d.next_power_of_two().clamp(1, w)
                } else {
                    w
                };
                assert_eq!(got, want, "W={w} d={d}");
            }
        }
        // i8 edges: W=16 slots of 8 bytes buy 25 slots of 5 bytes, so
        // max degree 17..=25 stays exhaustive instead of sampling (the
        // pow2 rounding clamps to the 25-slot byte budget).
        assert_eq!(shard_width(16, 17, I8_EDGE_BYTES), 25);
        assert_eq!(shard_width(16, 25, I8_EDGE_BYTES), 25);
        // Inside the pow2 range the tile stays a power of two.
        assert_eq!(shard_width(16, 9, I8_EDGE_BYTES), 16);
        // Past the byte budget the sampled branch keeps the global W.
        assert_eq!(shard_width(16, 26, I8_EDGE_BYTES), 16);
        // Small shards shrink the same way in both encodings.
        assert_eq!(shard_width(16, 5, I8_EDGE_BYTES), 8);
        assert_eq!(shard_width(16, 0, I8_EDGE_BYTES), 1);
    }

    #[test]
    fn sampling_rate_monotone_in_width() {
        let mut rng = Pcg32::new(9);
        let csr = gen::chung_lu(800, 50.0, 1.8, &mut rng);
        let mut last = 0.0;
        for w in [16, 32, 64, 128, 256, 512] {
            let r = sampling_rate(&csr, w, Strategy::Aes);
            assert!(r >= last - 1e-12, "rate must grow with W");
            assert!((0.0..=1.0).contains(&r));
            last = r;
        }
        // At W >= max degree the rate must be exactly 1.
        let wmax = csr.max_degree();
        assert_eq!(sampling_rate(&csr, wmax, Strategy::Aes), 1.0);
    }

    #[test]
    fn cdf_sorted_and_bounded() {
        let mut rng = Pcg32::new(11);
        let csr = gen::chung_lu(300, 30.0, 1.7, &mut rng);
        let cdf = sampling_rate_cdf(&csr, 32, Strategy::Aes);
        assert_eq!(cdf.len(), csr.n_rows);
        for w in cdf.windows(2) {
            assert!(w[1] >= w[0]);
        }
        assert!(cdf.iter().all(|&r| (0.0..=1.0 + 1e-12).contains(&r)));
    }
}
