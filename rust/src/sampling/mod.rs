//! The paper's adaptive edge sampling strategy — rust mirror of the L1
//! Pallas kernel, bit-exact against `python/compile/kernels/ref.py`
//! (golden vectors in `tests/golden_sampling.rs`).
//!
//! Used for (a) the Fig. 5 sampling-rate CDF analysis, (b) CPU baseline
//! SpMM over sampled plans, and (c) cross-checking artifact numerics.

mod plan;
mod strategy;

pub use plan::{plan_row, sample_ell, sample_ell_par, sampling_rate, sampling_rate_cdf};
pub use strategy::{start_index, strategy_params, RowPlan, Strategy, PRIME};
