//! The paper's adaptive edge sampling strategy — rust mirror of the L1
//! Pallas kernel, bit-exact against `python/compile/kernels/ref.py`
//! (golden vectors in `tests/golden_sampling.rs`).
//!
//! # Purpose
//!
//! Decide, per row, which ≤ W edges survive (Table 1 + Eq. 3) and build
//! the fixed-width ELL plans the sampled SpMM kernels consume.
//!
//! # Structure
//!
//! | unit       | role                                                   |
//! |------------|--------------------------------------------------------|
//! | `strategy` | [`Strategy`] (AFS / SFS / AES) + per-row start-index hash (the `PRIME` stride of Eq. 3) |
//! | `plan`     | row planners and the parallel [`sample_ell_par`] ELL builder; [`shard_width`] shard-local tile budgets; sampling-rate CDFs for Fig. 5 |
//!
//! # Rules
//!
//! * Sampling is **deterministic** per (row, degree, W, strategy) — no
//!   RNG on the serving path; reproducibility is what lets the plan
//!   cache reuse a sampled plan across batches.
//! * Any change here must keep the golden vectors green — the python
//!   reference is the source of truth for kernel parity.
//! * Parallel planners fan out on the exec layer's global pool; never
//!   call them from inside a task already on that pool.
//!
//! Used for (a) the Fig. 5 sampling-rate CDF analysis, (b) CPU baseline
//! SpMM over sampled plans, and (c) cross-checking artifact numerics.

mod plan;
mod strategy;

pub use plan::{
    plan_row, sample_ell, sample_ell_par, sampling_rate, sampling_rate_cdf, shard_width,
    FP32_EDGE_BYTES, I8_EDGE_BYTES,
};
pub use strategy::{start_index, strategy_params, RowPlan, Strategy, PRIME};
