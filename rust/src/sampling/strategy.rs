//! Table 1 (strategy table) and Eq. 3 (start-index hash) of the paper.

/// Eq. 3's prime multiplier — "a large prime that ensures start_ind spans
/// the full range of row_nnz".
pub const PRIME: i64 = 1429;

/// Edge sampling strategies, encoded as the runtime scalar the compiled
/// artifacts take (so rust and HLO agree by construction).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// ES-SpMM accuracy-first: fine-grained, N=1, one hash per slot.
    Afs = 0,
    /// ES-SpMM speed-first: coarse, N=W — keeps the first W elements.
    Sfs = 1,
    /// The paper's adaptive Table 1 interpolation.
    Aes = 2,
}

impl Strategy {
    pub const ALL: [Strategy; 3] = [Strategy::Afs, Strategy::Sfs, Strategy::Aes];

    pub fn name(self) -> &'static str {
        match self {
            Strategy::Afs => "afs",
            Strategy::Sfs => "sfs",
            Strategy::Aes => "aes",
        }
    }

    pub fn from_name(s: &str) -> Option<Strategy> {
        match s {
            "afs" => Some(Strategy::Afs),
            "sfs" => Some(Strategy::Sfs),
            "aes" => Some(Strategy::Aes),
            _ => None,
        }
    }

    /// The int32 scalar fed to the compiled artifact's `strategy` input.
    pub fn code(self) -> i32 {
        self as i32
    }
}

/// Per-row sampling plan: `n` consecutive elements per sample,
/// `sample_cnt` samples, laid out in `slots = min(n*cnt, W)` ELL slots.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RowPlan {
    pub n: usize,
    pub sample_cnt: usize,
    pub slots: usize,
}

/// Table 1 + the implementation clamps (N >= 1, sample_cnt <= W) + the
/// universal `row_nnz <= W` fast path ("all elements are selected").
///
/// Must stay bit-identical to `ref.strategy_params` in python.
pub fn strategy_params(row_nnz: usize, width: usize, strategy: Strategy) -> RowPlan {
    let (n, cnt) = if row_nnz <= width {
        (row_nnz, 1)
    } else {
        match strategy {
            Strategy::Afs => (1, width),
            Strategy::Sfs => (width, 1),
            Strategy::Aes => {
                let (n0, c0) = if row_nnz <= 2 * width {
                    (width / 4, 4)
                } else if row_nnz <= 36 * width {
                    (width / 8, 8)
                } else if row_nnz <= 54 * width {
                    (width / 16, 16)
                } else {
                    (width / 32, 32)
                };
                (n0.max(1), c0.min(width))
            }
        }
    };
    RowPlan { n, sample_cnt: cnt, slots: (n * cnt).min(width) }
}

/// Eq. 3: `start_ind = (i * prime) mod (row_nnz - N + 1)`.
#[inline]
pub fn start_index(sample_idx: usize, row_nnz: usize, n: usize) -> usize {
    debug_assert!(n <= row_nnz || row_nnz == 0);
    let range = (row_nnz as i64 - n as i64 + 1).max(1);
    ((sample_idx as i64 * PRIME) % range) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_regimes() {
        let w = 64;
        let plan = |n, sample_cnt, slots| RowPlan { n, sample_cnt, slots };
        // R <= 1
        assert_eq!(strategy_params(40, w, Strategy::Aes), plan(40, 1, 40));
        // 1 < R <= 2
        assert_eq!(strategy_params(100, w, Strategy::Aes), plan(16, 4, 64));
        // 2 < R <= 36
        assert_eq!(strategy_params(1000, w, Strategy::Aes), plan(8, 8, 64));
        // 36 < R <= 54
        assert_eq!(strategy_params(64 * 40, w, Strategy::Aes), plan(4, 16, 64));
        // R > 54
        assert_eq!(strategy_params(64 * 60, w, Strategy::Aes), plan(2, 32, 64));
    }

    #[test]
    fn clamps_for_small_width() {
        // W=16, R>54: W/32 = 0 -> clamp N to 1; cnt stays 32 > W? min(32,16)=16.
        let p = strategy_params(16 * 60, 16, Strategy::Aes);
        assert_eq!(p, RowPlan { n: 1, sample_cnt: 16, slots: 16 });
    }

    #[test]
    fn afs_sfs_extremes() {
        let p = strategy_params(500, 64, Strategy::Afs);
        assert_eq!(p, RowPlan { n: 1, sample_cnt: 64, slots: 64 });
        let p = strategy_params(500, 64, Strategy::Sfs);
        assert_eq!(p, RowPlan { n: 64, sample_cnt: 1, slots: 64 });
    }

    #[test]
    fn small_rows_take_everything() {
        for strat in Strategy::ALL {
            let p = strategy_params(10, 64, strat);
            assert_eq!(p, RowPlan { n: 10, sample_cnt: 1, slots: 10 });
        }
        // nnz == 0
        for strat in Strategy::ALL {
            assert_eq!(strategy_params(0, 64, strat).slots, 0);
        }
    }

    #[test]
    fn hash_stays_in_range() {
        for nnz in [1usize, 2, 17, 100, 5000] {
            for n in [1usize, 2, 8, nnz.min(16)] {
                if n > nnz {
                    continue;
                }
                for s in 0..64 {
                    let start = start_index(s, nnz, n);
                    assert!(start + n <= nnz, "start {start} + n {n} > nnz {nnz}");
                }
            }
        }
    }

    #[test]
    fn hash_matches_eq3() {
        // Spot values: (i * 1429) mod (nnz - N + 1)
        assert_eq!(start_index(0, 100, 1), 0);
        assert_eq!(start_index(1, 100, 1), 1429 % 100);
        assert_eq!(start_index(3, 50, 2), (3 * 1429) % 49);
    }

    #[test]
    fn strategy_names_roundtrip() {
        for s in Strategy::ALL {
            assert_eq!(Strategy::from_name(s.name()), Some(s));
        }
        assert_eq!(Strategy::from_name("bogus"), None);
        assert_eq!(Strategy::Aes.code(), 2);
    }
}
