//! Layer-3 coordinator — the GNN inference serving system (the "modified
//! DGL framework" role in the paper's evaluation, §4.1, rebuilt as a
//! production-style service).
//!
//! Request path (all rust, no python):
//!
//! ```text
//! client → submit (bounded queue, backpressure)
//!        → dynamic batcher (group by RouteKey, flush on size/deadline)
//!        → exec::Pool (persistent workers, per-worker queues + stealing)
//!            → route plan cache (cold: feature store load — Table 3's
//!              stage — + sampling + kernel dispatch; warm: memory)
//!            → Backend execute: PJRT AOT artifact (sample→SpMM→MLP) or
//!              the rust host substrate (dispatched CPU kernels)
//!            → per-node argmax answers (NaN-safe)
//!        → per-request reply channels + metrics
//! ```
//!
//! Batching exploits the paper's full-graph inference shape: every request
//! for the same (model, dataset, W, strategy, precision) key is answered
//! by a single forward pass, so batch size N costs one execution.

mod batcher;
mod metrics;
mod request;
mod server;
mod store;

pub use batcher::{run_batcher, run_batcher_with, Batch, BatcherConfig};
pub use metrics::{Histogram, Metrics, MetricsSnapshot};
pub use request::{InferRequest, InferResponse, Prediction, RouteKey, SubmitError};
pub use server::{oneshot_accuracy, Coordinator, CoordinatorConfig};
pub use store::ModelStore;
