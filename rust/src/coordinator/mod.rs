//! Layer-3 coordinator — the GNN inference serving system (the "modified
//! DGL framework" role in the paper's evaluation, §4.1, rebuilt as a
//! production-style service).
//!
//! # Purpose
//!
//! Turn individual inference requests into batched, plan-cached,
//! prefetch-overlapped forward passes — the serving shell around the
//! exec layer.
//!
//! # Structure
//!
//! | unit      | role                                                   |
//! |-----------|--------------------------------------------------------|
//! | `request` | [`RouteKey`] / request + reply types, submit errors    |
//! | `batcher` | dynamic batching: group by route, flush on size/delay  |
//! | `server`  | [`Coordinator`]: intake queue, worker pool, plan cache + prefetcher + shard-unit cache wiring, route execution |
//! | `store`   | [`ModelStore`]: immutable datasets / weights / feature stores shared lock-free via `Arc` |
//! | `metrics` | lock-cheap counters + sub-bucketed latency histograms (p50/p99/p999, per route) |
//! | `wire`    | length-prefixed TCP frame codec, versioned request/response JSON (docs/serving.md) |
//! | `net`     | [`WireServer`]: accept loop, connection threads, admission control + load shedding, ops requests; shard-plane handlers (`shard_logits`/`shard_infer`/`apply_delta`) |
//! | `router`  | [`ShardRouter`]: multi-process sharded serving — scatter/gather over shard workers, delta-log replication with per-worker epoch watermarks, failover re-placement |
//!
//! # Request path (all rust, no python)
//!
//! ```text
//! TCP client → 4-byte-LE framed JSON → connection thread
//!            → admission control (high-water in-flight gauge → shed)
//! client → submit (bounded queue, backpressure)
//!        ├→ async prefetch: cold routes start feature staging + sampling
//!        │    on a private pool, overlapping the current batches' SpMM
//!        → dynamic batcher (group by RouteKey, flush on size/deadline)
//!        → exec::Pool (persistent workers, per-worker queues + stealing)
//!            → route plan cache (warm: memory; cold: wait for the
//!              prefetched build — Table 3's loading stage off the
//!              critical path — or build inline)
//!            → Backend execute: PJRT AOT artifact (sample→SpMM→MLP) or
//!              the rust host substrate; streamed INT8 routes dequantize
//!              lazily per row-block inside the worker
//!            → per-node argmax answers (NaN-safe)
//!        → per-request reply channels + metrics
//! ```
//!
//! # Rules
//!
//! * Batching exploits the paper's full-graph inference shape: every
//!   request for the same (model, dataset, W, strategy, precision) key
//!   is answered by a single forward pass, so batch size N costs one
//!   execution.
//! * The prefetch pool is never the batch pool — a batch worker may
//!   block waiting for a staging build and must not be able to queue
//!   that build behind itself.
//! * `ModelStore` weights/features are immutable after startup;
//!   datasets are **published by replacement** — a live
//!   [`crate::graph::GraphDelta`] goes through
//!   [`Coordinator::apply_delta`], which publishes the next epoch's
//!   graph first and then invalidates precisely: only the shard units
//!   of touched shards are re-sampled, untouched units are re-tagged
//!   and stay warm, and dropped route plans are re-staged through the
//!   prefetcher (docs/mutation.md). Wholesale republish (features
//!   rotated on disk) still uses `invalidate_route`.
//! * With sharding enabled ([`CoordinatorConfig::sharding`]), host plans
//!   carry a `ShardedPlan`; prepared shard units live in a cache of
//!   their own keyed by (dataset, width, strategy, row range) — shared
//!   across precisions, so a plan build re-samples only cold shards.
//!   Invalidating a route drops its dataset's units too.
//! * Accuracy conformance (`crate::eval`) enters through
//!   [`Coordinator::route_logits`]: the same plan resolution and
//!   backend execution as a batch worker, returning raw logits so
//!   every configuration — including [`CoordinatorConfig::streaming`]
//!   off (eager staging) — is scored against the exact oracle through
//!   this stack, never a side path.

mod batcher;
mod metrics;
mod net;
mod request;
mod router;
mod server;
mod store;
pub mod wire;

pub use batcher::{run_batcher, run_batcher_with, Batch, BatcherConfig};
pub use metrics::{Histogram, Metrics, MetricsSnapshot, RouteLatencySnapshot};
pub use net::{NetConfig, WireServer};
pub use router::{RouterConfig, ShardRouter};
pub use request::{InferRequest, InferResponse, Prediction, RouteKey, SubmitError};
pub use server::{
    oneshot_accuracy, Coordinator, CoordinatorConfig, DeltaOutcome, ShardCacheStats,
};
pub use store::ModelStore;
