//! Model store: preloaded datasets, weights, and feature stores shared by
//! the worker pool. Everything here is immutable after startup, so
//! workers read lock-free through `Arc`s.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::quant::FeatureStore;
use crate::runtime::{Dataset, Weights};

/// Immutable registry of loaded datasets + weights for serving.
pub struct ModelStore {
    artifacts_dir: PathBuf,
    datasets: HashMap<String, Arc<Dataset>>,
    weights: HashMap<(String, String), Arc<Weights>>,
    features: HashMap<String, Arc<FeatureStore>>,
}

impl ModelStore {
    /// Load the given datasets and both models' weights for each.
    pub fn load(
        artifacts_dir: impl AsRef<Path>,
        datasets: &[String],
        models: &[String],
    ) -> Result<ModelStore> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let mut store = ModelStore {
            artifacts_dir: dir.clone(),
            datasets: HashMap::new(),
            weights: HashMap::new(),
            features: HashMap::new(),
        };
        for ds in datasets {
            let data = Dataset::load(&dir, ds).with_context(|| format!("dataset {ds}"))?;
            store.datasets.insert(ds.clone(), Arc::new(data));
            store.features.insert(
                ds.clone(),
                Arc::new(FeatureStore::open(dir.join(format!("data_{ds}.nbt")))?),
            );
            for m in models {
                let w = Weights::load(&dir, m, ds).with_context(|| format!("weights {m}/{ds}"))?;
                store.weights.insert((m.clone(), ds.clone()), Arc::new(w));
            }
        }
        Ok(store)
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn dataset(&self, name: &str) -> Result<Arc<Dataset>> {
        self.datasets
            .get(name)
            .cloned()
            .with_context(|| format!("dataset {name:?} not loaded"))
    }

    pub fn weights(&self, model: &str, dataset: &str) -> Result<Arc<Weights>> {
        self.weights
            .get(&(model.to_string(), dataset.to_string()))
            .cloned()
            .with_context(|| format!("weights {model}/{dataset} not loaded"))
    }

    pub fn feature_store(&self, dataset: &str) -> Result<Arc<FeatureStore>> {
        self.features
            .get(dataset)
            .cloned()
            .with_context(|| format!("feature store {dataset:?} not loaded"))
    }

    pub fn dataset_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.datasets.keys().cloned().collect();
        v.sort();
        v
    }
}
