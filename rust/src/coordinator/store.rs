//! Model store: preloaded datasets, weights, and feature stores shared by
//! the worker pool. Weights and feature stores are immutable after
//! startup, so workers read them lock-free through `Arc`s. Datasets are
//! **published by replacement**: [`ModelStore::publish_dataset`] swaps
//! the `Arc` behind a short read-mostly lock so the live-mutation path
//! ([`crate::coordinator::Coordinator::apply_delta`]) can advance a
//! dataset's epoch without touching readers mid-batch — a reader that
//! already cloned the `Arc` keeps a consistent epoch-N snapshot for the
//! rest of its batch.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, RwLock};

use anyhow::{Context, Result};

use crate::quant::FeatureStore;
use crate::runtime::{validate_weights, Dataset, Weights};

/// Registry of loaded datasets + weights for serving. Datasets are
/// replaceable (epoch-versioned mutation); everything else is fixed at
/// load time.
pub struct ModelStore {
    artifacts_dir: PathBuf,
    datasets: RwLock<HashMap<String, Arc<Dataset>>>,
    weights: HashMap<(String, String), Arc<Weights>>,
    features: HashMap<String, Arc<FeatureStore>>,
}

impl ModelStore {
    /// Load the given datasets and both models' weights for each.
    pub fn load(
        artifacts_dir: impl AsRef<Path>,
        datasets: &[String],
        models: &[String],
    ) -> Result<ModelStore> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let mut store = ModelStore {
            artifacts_dir: dir.clone(),
            datasets: RwLock::new(HashMap::new()),
            weights: HashMap::new(),
            features: HashMap::new(),
        };
        for ds in datasets {
            let data = Dataset::load(&dir, ds).with_context(|| format!("dataset {ds}"))?;
            let (feats, classes) = (data.feats, data.classes);
            store.datasets.get_mut().unwrap().insert(ds.clone(), Arc::new(data));
            store.features.insert(
                ds.clone(),
                Arc::new(FeatureStore::open(dir.join(format!("data_{ds}.nbt")))?),
            );
            for m in models {
                let w = Weights::load(&dir, m, ds).with_context(|| format!("weights {m}/{ds}"))?;
                // Publish-time schema check: every tensor's shape must
                // satisfy the model IR against this dataset's dims, so a
                // mis-shaped artifact fails here with the tensor named
                // instead of panicking inside a worker's matmul.
                validate_weights(m, feats, classes, &w.tensors)
                    .with_context(|| format!("weights {m}/{ds}"))?;
                store.weights.insert((m.clone(), ds.clone()), Arc::new(w));
            }
        }
        Ok(store)
    }

    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    pub fn dataset(&self, name: &str) -> Result<Arc<Dataset>> {
        self.datasets
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("dataset {name:?} not loaded"))
    }

    /// Replace a dataset's published value (the next epoch after a
    /// [`crate::graph::GraphDelta`], or a wholesale republish). The name
    /// must already be loaded — publication changes *content*, never the
    /// serving roster. Readers holding the previous `Arc` are untouched.
    ///
    /// **Epochs never regress**: if the incoming dataset's epoch is not
    /// strictly greater than the published one (the wholesale-republish
    /// case — a freshly loaded `Dataset` restarts at epoch 0), it is
    /// re-stamped to `published.epoch + 1`. Every publication is
    /// therefore an epoch advance, which is what keeps the versioned
    /// plan caches sound: a builder that bound the pre-publish snapshot
    /// tagged its plan with the old epoch, and no new reader can ever
    /// look that epoch up again (`docs/mutation.md`) — even when the
    /// publisher forgot to bump the epoch itself.
    pub fn publish_dataset(&self, name: &str, dataset: Arc<Dataset>) -> Result<()> {
        let mut map = self.datasets.write().unwrap();
        let slot = map
            .get_mut(name)
            .with_context(|| format!("dataset {name:?} not loaded (publish is content-only)"))?;
        let dataset = if dataset.epoch > slot.epoch {
            dataset
        } else {
            let epoch = slot.epoch + 1;
            // Rare path (wholesale republish): the clone is dominated by
            // the reload that produced the dataset.
            let restamped = match Arc::try_unwrap(dataset) {
                Ok(owned) => Dataset { epoch, ..owned },
                Err(shared) => Dataset { epoch, ..(*shared).clone() },
            };
            Arc::new(restamped)
        };
        *slot = dataset;
        Ok(())
    }

    /// Compare-and-publish: replace the dataset only if the published
    /// epoch is still `expected_epoch`. Returns `false` (publishing
    /// nothing) when another publication won the race — the caller
    /// derived its value from a snapshot that is no longer current and
    /// must re-derive. `Coordinator::apply_delta` uses this so a
    /// concurrent wholesale [`ModelStore::publish_dataset`] is never
    /// silently overwritten by a splice of the data it just replaced.
    ///
    /// Like [`ModelStore::publish_dataset`], the epoch **never
    /// regresses or repeats**: a winning publication whose dataset does
    /// not already carry a newer epoch is re-stamped to
    /// `expected_epoch + 1` — enforced in release builds too, because a
    /// same-epoch republish of different content would poison every
    /// versioned cache entry tagged with that epoch.
    pub fn publish_dataset_cas(
        &self,
        name: &str,
        expected_epoch: u64,
        dataset: Arc<Dataset>,
    ) -> Result<bool> {
        let mut map = self.datasets.write().unwrap();
        let slot = map
            .get_mut(name)
            .with_context(|| format!("dataset {name:?} not loaded (publish is content-only)"))?;
        if slot.epoch != expected_epoch {
            return Ok(false);
        }
        *slot = if dataset.epoch > expected_epoch {
            dataset
        } else {
            let epoch = expected_epoch + 1;
            let restamped = match Arc::try_unwrap(dataset) {
                Ok(owned) => Dataset { epoch, ..owned },
                Err(shared) => Dataset { epoch, ..(*shared).clone() },
            };
            Arc::new(restamped)
        };
        Ok(true)
    }

    pub fn weights(&self, model: &str, dataset: &str) -> Result<Arc<Weights>> {
        self.weights
            .get(&(model.to_string(), dataset.to_string()))
            .cloned()
            .with_context(|| format!("weights {model}/{dataset} not loaded"))
    }

    pub fn feature_store(&self, dataset: &str) -> Result<Arc<FeatureStore>> {
        self.features
            .get(dataset)
            .cloned()
            .with_context(|| format!("feature store {dataset:?} not loaded"))
    }

    pub fn dataset_names(&self) -> Vec<String> {
        let mut v: Vec<_> = self.datasets.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Distinct models with loaded weights, sorted — the serving
    /// roster's model axis (`status` reports it to clients).
    pub fn model_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.weights.keys().map(|(m, _)| m.clone()).collect();
        v.sort();
        v.dedup();
        v
    }
}
