//! The TCP serving front-end: accept loop, per-connection threads,
//! admission control, and the ops request surface.
//!
//! The socket machinery is split from the request semantics so the two
//! wire-facing processes share one (debugged-once) connection layer:
//!
//! * [`WireListener`] + [`FrameHandler`] — the generic accept loop,
//!   per-connection threads, connection reaping, accept-error backoff,
//!   and shutdown choreography. The coordinator front-end here and the
//!   shard router ([`super::router`]) are both `FrameHandler`s behind
//!   the same listener.
//! * [`WireServer`] wraps an [`Arc<Coordinator>`]: every connection gets
//!   a thread that reads [`super::wire`] frames and dispatches them.
//!   `infer` frames go through [`Coordinator::submit`] — the same
//!   bounded intake, batcher, plan-cache/prefetcher/sharded path as
//!   in-process callers, so wire requests for the same route coalesce
//!   into one forward pass across connections. The connection thread
//!   then blocks on that request's reply channel; concurrency comes
//!   from the number of connections, exactly like one outstanding
//!   request per client.
//!
//! Any `WireServer` also answers the shard-serving plane
//! (`shard_logits` / `shard_infer` / `apply_delta`, docs/serving.md):
//! a shard worker is just `repro serve` addressed by a router, not a
//! different binary.
//!
//! # Admission control
//!
//! Two gates, both answered with an explicit `"shed"` response (a
//! distinct status, not an error — the client should back off and
//! retry), and both counted in [`super::Metrics::shed`]:
//!
//! 1. the server-level in-flight gauge against
//!    [`NetConfig::high_water`] — refusing before touching the
//!    coordinator, bounding the reply channels and blocked connection
//!    threads a burst can pin;
//! 2. [`SubmitError::Busy`] from the coordinator's bounded intake
//!    queue (backpressure racing the gauge is still never a silent
//!    drop).
//!
//! Responses already in flight when a burst arrives drain oldest-first
//! per the batcher contract (docs/mutation.md, PR 5): shedding refuses
//! *new* work, it never abandons admitted work.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::graph::GraphDelta;
use crate::util::JsonValue;

use super::request::{RouteKey, SubmitError};
use super::server::Coordinator;
use super::store::ModelStore;
use super::wire::{self, WireRequest};

/// Front-end knobs.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// In-flight `infer`/`logits` requests (all connections) beyond
    /// which new ones are shed. 0 sheds everything — useful in tests.
    pub high_water: usize,
    /// Per-frame byte cap (see [`wire::MAX_FRAME`]).
    pub max_frame: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { high_water: 256, max_frame: wire::MAX_FRAME }
    }
}

/// Request semantics behind a [`WireListener`]: one decoded-frame-in,
/// response-out call per request. Implementations must be infallible —
/// every failure mode maps to an `"error"`/`"shed"` response frame.
pub(crate) trait FrameHandler: Send + Sync + 'static {
    fn handle(&self, body: &[u8]) -> JsonValue;
}

/// Listener state shared between the accept loop, the connection
/// threads, and the handler (which surfaces it through `status`).
pub(crate) struct ListenerShared {
    max_frame: usize,
    shutdown: AtomicBool,
    /// Total accept-loop errors (failed `accept` or `try_clone`).
    /// A steadily climbing counter is the observable symptom of fd
    /// exhaustion — surfaced in `status` so an operator sees it before
    /// the box does.
    accept_errors: AtomicU64,
    /// Live connection threads + stream clones so shutdown can force
    /// blocked reads to return. Finished connections are reaped on
    /// every accept (and on [`ListenerShared::open_connections`]), so
    /// this tracks *live* connections, not total-ever-accepted — the
    /// bounded-churn regression test in `tests/serving_wire.rs` pins
    /// that invariant.
    conns: Mutex<Vec<(JoinHandle<()>, TcpStream)>>,
}

impl ListenerShared {
    pub(crate) fn new(max_frame: usize) -> Arc<ListenerShared> {
        Arc::new(ListenerShared {
            max_frame,
            shutdown: AtomicBool::new(false),
            accept_errors: AtomicU64::new(0),
            conns: Mutex::new(Vec::new()),
        })
    }

    /// Accept-loop error count since start.
    pub(crate) fn accept_errors(&self) -> u64 {
        self.accept_errors.load(Ordering::Relaxed)
    }

    /// Live connection count (reaps finished threads first, so the
    /// number reflects open sockets, not historical churn).
    pub(crate) fn open_connections(&self) -> usize {
        let mut conns = self.conns.lock().unwrap();
        reap_finished(&mut conns);
        conns.len()
    }
}

/// Drop finished connection threads: join them (instant — the thread
/// already returned) and actively close their stream clones so the fd
/// is released now, not at server shutdown. Called with the `conns`
/// lock held.
fn reap_finished(conns: &mut Vec<(JoinHandle<()>, TcpStream)>) {
    let mut i = 0;
    while i < conns.len() {
        if conns[i].0.is_finished() {
            let (handle, stream) = conns.swap_remove(i);
            let _ = stream.shutdown(Shutdown::Both);
            let _ = handle.join();
        } else {
            i += 1;
        }
    }
}

/// Backoff before retrying a failed accept: exponential from 1 ms,
/// capped at 100 ms. Persistent accept errors (EMFILE is the classic —
/// the listener fd is fine but every accepted socket fails) would
/// otherwise spin the accept thread at 100 % CPU; one successful accept
/// resets the streak.
pub(crate) fn accept_backoff(streak: u32) -> Duration {
    Duration::from_millis((1u64 << streak.min(7)).min(100))
}

/// The generic TCP listener: accepts connections, spawns one thread per
/// connection, frames bytes, and hands decoded bodies to a
/// [`FrameHandler`]. Dropping it stops the accept loop, closes every
/// live connection, and joins the threads.
pub(crate) struct WireListener {
    addr: SocketAddr,
    shared: Arc<ListenerShared>,
    accept: Option<JoinHandle<()>>,
}

impl WireListener {
    pub(crate) fn start(
        listener: TcpListener,
        shared: Arc<ListenerShared>,
        handler: Arc<dyn FrameHandler>,
    ) -> Result<WireListener> {
        let addr = listener.local_addr().context("reading bound address")?;
        let accept = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("wire-accept".into())
                .spawn(move || accept_loop(listener, shared, handler))
                .context("spawning accept thread")?
        };
        Ok(WireListener { addr, shared, accept: Some(accept) })
    }

    pub(crate) fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    fn shutdown_inner(&mut self) {
        if self.shared.shutdown.swap(true, Ordering::AcqRel) {
            return;
        }
        // Unblock the accept loop: it checks the flag after every
        // accept, so one throwaway connection gets it past the block.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // The accept loop is gone — no new entries can race this drain.
        let conns = std::mem::take(&mut *self.shared.conns.lock().unwrap());
        for (handle, stream) in conns {
            let _ = stream.shutdown(Shutdown::Both);
            let _ = handle.join();
        }
    }
}

impl Drop for WireListener {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(
    listener: TcpListener,
    shared: Arc<ListenerShared>,
    handler: Arc<dyn FrameHandler>,
) {
    let mut error_streak: u32 = 0;
    for stream in listener.incoming() {
        if shared.shutdown.load(Ordering::Acquire) {
            return;
        }
        let stream = match stream {
            Ok(s) => {
                error_streak = 0;
                s
            }
            Err(_) => {
                shared.accept_errors.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(accept_backoff(error_streak));
                error_streak = error_streak.saturating_add(1);
                continue;
            }
        };
        let Ok(clone) = stream.try_clone() else {
            shared.accept_errors.fetch_add(1, Ordering::Relaxed);
            continue;
        };
        let st = shared.clone();
        let h = handler.clone();
        let spawned = std::thread::Builder::new()
            .name("wire-conn".into())
            .spawn(move || connection_loop(stream, st, h));
        let mut conns = shared.conns.lock().unwrap();
        // Reap on every accept: churny clients (connect, one request,
        // disconnect) must not accumulate dead threads + fd clones.
        reap_finished(&mut conns);
        match spawned {
            Ok(handle) => conns.push((handle, clone)),
            Err(_) => {
                // Out of threads: refuse the connection outright rather
                // than hanging the client.
                let _ = clone.shutdown(Shutdown::Both);
            }
        }
    }
}

fn connection_loop(
    mut stream: TcpStream,
    shared: Arc<ListenerShared>,
    handler: Arc<dyn FrameHandler>,
) {
    let _ = stream.set_nodelay(true);
    loop {
        let body = match wire::read_frame(&mut stream, shared.max_frame) {
            Ok(Some(b)) => b,
            // Clean EOF, a reset, or an untrustworthy stream (oversize
            // length, mid-frame EOF): drop the connection.
            Ok(None) | Err(_) => break,
        };
        let reply = handler.handle(&body);
        if wire::write_frame(&mut stream, reply.to_string().as_bytes()).is_err() {
            break;
        }
    }
    // The accept loop holds a clone of this stream (so shutdown can
    // unblock the read above); dropping ours would leave the socket
    // half-alive until the reaper runs. Close it actively so the peer
    // sees EOF the moment the connection is dead.
    let _ = stream.shutdown(Shutdown::Both);
}

/// The coordinator front-end's request semantics (one per server, shared
/// by every connection thread).
struct CoordHandler {
    coord: Arc<Coordinator>,
    store: Arc<ModelStore>,
    cfg: NetConfig,
    inflight: AtomicUsize,
    started: Instant,
    shared: Arc<ListenerShared>,
}

impl FrameHandler for CoordHandler {
    fn handle(&self, body: &[u8]) -> JsonValue {
        handle_frame(self, body)
    }
}

/// The TCP front-end. Dropping it (or calling [`WireServer::shutdown`])
/// stops the accept loop, closes every live connection, and joins the
/// threads; the coordinator itself shuts down when its last `Arc`
/// drops.
pub struct WireServer {
    listener: WireListener,
    handler: Arc<CoordHandler>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving.
    pub fn bind(
        coord: Arc<Coordinator>,
        store: Arc<ModelStore>,
        addr: &str,
        cfg: NetConfig,
    ) -> Result<WireServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Self::start(coord, store, listener, cfg)
    }

    /// Start serving on an already-bound listener.
    pub fn start(
        coord: Arc<Coordinator>,
        store: Arc<ModelStore>,
        listener: TcpListener,
        cfg: NetConfig,
    ) -> Result<WireServer> {
        let shared = ListenerShared::new(cfg.max_frame);
        let handler = Arc::new(CoordHandler {
            coord,
            store,
            cfg,
            inflight: AtomicUsize::new(0),
            started: Instant::now(),
            shared: shared.clone(),
        });
        let listener = WireListener::start(listener, shared, handler.clone())?;
        Ok(WireServer { listener, handler })
    }

    /// The bound address (resolves the ephemeral port of `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr()
    }

    /// Live connection count (finished connections are reaped first).
    pub fn open_connections(&self) -> usize {
        self.handler.shared.open_connections()
    }

    /// Accept-loop error count since start.
    pub fn accept_errors(&self) -> u64 {
        self.handler.shared.accept_errors()
    }

    /// Stop accepting, close live connections, join every thread.
    pub fn shutdown(self) {
        // Drop order does the work: the listener's Drop joins the
        // accept loop and every connection thread.
    }
}

/// Decode and dispatch one frame; infallible — every failure mode maps
/// to an `"error"` (or `"shed"`) response frame.
fn handle_frame(state: &CoordHandler, body: &[u8]) -> JsonValue {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return wire::error_response(0, "frame is not UTF-8"),
    };
    let doc = match crate::util::parse_json(text) {
        Ok(d) => d,
        Err(e) => return wire::error_response(0, &format!("frame is not JSON: {e:#}")),
    };
    let req = match WireRequest::from_json(&doc) {
        Ok(r) => r,
        Err(e) => return wire::error_response(wire::request_id(&doc), &format!("{e:#}")),
    };
    match req {
        WireRequest::Infer { id, route, nodes } => handle_infer(state, id, route, nodes),
        WireRequest::Logits { id, route } => handle_logits(state, id, route),
        WireRequest::Mutate { id, dataset, ops } => handle_mutate(state, id, &dataset, &ops),
        WireRequest::ShardInfer { id, route, nodes } => {
            handle_shard_infer(state, id, route, nodes)
        }
        WireRequest::ShardLogits { id, route, row_start, row_end } => {
            handle_shard_logits(state, id, route, row_start, row_end)
        }
        WireRequest::ApplyDelta { id, dataset, ops, epoch } => {
            handle_apply_delta(state, id, &dataset, &ops, epoch)
        }
        WireRequest::Status { id } => handle_status(state, id),
        WireRequest::Metrics { id } => handle_metrics(state, id),
        WireRequest::Routes { id } => handle_routes(state, id),
    }
}

/// RAII in-flight slot: decrements the gauge however the handler exits.
struct Admission<'a>(&'a AtomicUsize);

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Claim an in-flight slot, or shed: past the high-water mark the
/// request is refused *before* it touches the coordinator.
fn admit(state: &CoordHandler) -> Option<Admission<'_>> {
    let prev = state.inflight.fetch_add(1, Ordering::AcqRel);
    if prev >= state.cfg.high_water {
        state.inflight.fetch_sub(1, Ordering::AcqRel);
        state.coord.metrics().shed.fetch_add(1, Ordering::Relaxed);
        return None;
    }
    Some(Admission(&state.inflight))
}

fn num(x: u64) -> JsonValue {
    JsonValue::Num(x as f64)
}

fn us(d: std::time::Duration) -> JsonValue {
    num(d.as_micros() as u64)
}

fn handle_infer(state: &CoordHandler, id: u64, route: RouteKey, nodes: Vec<usize>) -> JsonValue {
    let Some(_slot) = admit(state) else {
        return wire::shed_response(id, "in-flight high-water mark reached");
    };
    // Bounds-check against the dataset before the request reaches a
    // worker: an out-of-range node is a client error, not a panic.
    let ds = match state.store.dataset(&route.dataset) {
        Ok(d) => d,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    if let Some(&bad) = nodes.iter().find(|&&n| n >= ds.n) {
        return wire::error_response(
            id,
            &format!("node {bad} out of range (dataset {} has {} nodes)", route.dataset, ds.n),
        );
    }
    match state.coord.submit(route, nodes) {
        Ok((_, rx)) => match rx.recv() {
            Ok(resp) => {
                if let Some(err) = resp.error {
                    return wire::error_response(id, &err);
                }
                let predictions = resp
                    .predictions
                    .iter()
                    .map(|p| {
                        JsonValue::Obj(
                            [
                                ("node".to_string(), num(p.node as u64)),
                                ("class".to_string(), JsonValue::Num(p.class as f64)),
                            ]
                            .into_iter()
                            .collect(),
                        )
                    })
                    .collect();
                wire::ok_response(
                    id,
                    vec![
                        ("predictions", JsonValue::Arr(predictions)),
                        ("batch_size", num(resp.batch_size as u64)),
                        ("latency_us", us(resp.latency)),
                    ],
                )
            }
            Err(_) => wire::error_response(id, "coordinator dropped the reply channel"),
        },
        Err(SubmitError::Busy) => {
            state.coord.metrics().shed.fetch_add(1, Ordering::Relaxed);
            wire::shed_response(id, "intake queue full (backpressure)")
        }
        Err(SubmitError::Closed) => wire::error_response(id, "coordinator closed"),
    }
}

fn handle_logits(state: &CoordHandler, id: u64, route: RouteKey) -> JsonValue {
    let Some(_slot) = admit(state) else {
        return wire::shed_response(id, "in-flight high-water mark reached");
    };
    // The epoch label comes from the execution itself, never from a
    // separate `store.dataset` read: a `mutate` racing this request
    // would otherwise tag epoch-N+1 logits as epoch N (or vice versa),
    // and the replication log makes that tag load-bearing.
    let (logits, epoch, classes) = match state.coord.route_logits_versioned(&route) {
        Ok(t) => t,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    let vals = match logits.as_f32() {
        Ok(v) => v,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    if classes == 0 || vals.len() % classes != 0 {
        return wire::error_response(
            id,
            &format!("logits shape {} not divisible by {classes} classes", vals.len()),
        );
    }
    let rows = vals.len() / classes;
    let bits = vals.iter().map(|v| num(v.to_bits() as u64)).collect();
    wire::ok_response(
        id,
        vec![
            ("rows", num(rows as u64)),
            ("classes", num(classes as u64)),
            ("epoch", num(epoch)),
            ("logits_bits", JsonValue::Arr(bits)),
        ],
    )
}

/// `shard_infer`: classify nodes directly through the versioned route
/// execution (no batcher — the router already coalesced across its
/// clients) and report the epoch the served plan bound, so the router
/// can enforce read-your-writes across workers.
fn handle_shard_infer(
    state: &CoordHandler,
    id: u64,
    route: RouteKey,
    nodes: Vec<usize>,
) -> JsonValue {
    let Some(_slot) = admit(state) else {
        return wire::shed_response(id, "in-flight high-water mark reached");
    };
    let (logits, epoch, classes) = match state.coord.route_logits_versioned(&route) {
        Ok(t) => t,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    let vals = match logits.as_f32() {
        Ok(v) => v,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    let rows = if classes == 0 { 0 } else { vals.len() / classes };
    if let Some(&bad) = nodes.iter().find(|&&n| n >= rows) {
        return wire::error_response(
            id,
            &format!("node {bad} out of range (dataset {} has {rows} nodes)", route.dataset),
        );
    }
    let predictions = nodes
        .iter()
        .map(|&node| {
            let class = crate::util::argmax_f32(&vals[node * classes..(node + 1) * classes]);
            JsonValue::Obj(
                [
                    ("node".to_string(), num(node as u64)),
                    ("class".to_string(), num(class as u64)),
                ]
                .into_iter()
                .collect(),
            )
        })
        .collect();
    wire::ok_response(
        id,
        vec![("predictions", JsonValue::Arr(predictions)), ("epoch", num(epoch))],
    )
}

/// `shard_logits`: the scatter half of the router's row-concatenation
/// merge — execute the route and ship only the requested row slice.
/// The forward pass is complete (multi-layer aggregation needs every
/// row's neighborhood; row-restricted execution would change the
/// bits); ownership restricts what crosses the wire, not what is
/// computed.
fn handle_shard_logits(
    state: &CoordHandler,
    id: u64,
    route: RouteKey,
    row_start: usize,
    row_end: usize,
) -> JsonValue {
    let Some(_slot) = admit(state) else {
        return wire::shed_response(id, "in-flight high-water mark reached");
    };
    let (logits, epoch, classes) = match state.coord.route_logits_versioned(&route) {
        Ok(t) => t,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    let vals = match logits.as_f32() {
        Ok(v) => v,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    let rows = if classes == 0 { 0 } else { vals.len() / classes };
    if row_start > row_end || row_end > rows {
        return wire::error_response(
            id,
            &format!("row range {row_start}..{row_end} outside 0..{rows}"),
        );
    }
    let bits = vals[row_start * classes..row_end * classes]
        .iter()
        .map(|v| num(v.to_bits() as u64))
        .collect();
    wire::ok_response(
        id,
        vec![
            ("row_start", num(row_start as u64)),
            ("row_end", num(row_end as u64)),
            ("rows", num((row_end - row_start) as u64)),
            ("classes", num(classes as u64)),
            ("epoch", num(epoch)),
            ("logits_bits", JsonValue::Arr(bits)),
        ],
    )
}

fn handle_mutate(state: &CoordHandler, id: u64, dataset: &str, ops: &[String]) -> JsonValue {
    let delta = match GraphDelta::parse(&ops.join("\n")) {
        Ok(d) => d,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    match state.coord.apply_delta(dataset, &delta) {
        Ok(out) => wire::ok_response(
            id,
            vec![
                ("epoch", num(out.epoch)),
                ("inserted", num(out.report.inserted as u64)),
                ("deleted", num(out.report.deleted as u64)),
                ("reweighted", num(out.report.reweighted as u64)),
                ("noops", num(out.report.noops as u64)),
                ("touched_rows", num(out.report.touched_rows.len() as u64)),
                ("shards_resampled", num(out.shards_resampled as u64)),
                ("shards_retained", num(out.shards_retained as u64)),
                ("plans_invalidated", num(out.plans_invalidated as u64)),
                ("routes_restaged", num(out.routes_restaged as u64)),
            ],
        ),
        Err(e) => wire::error_response(id, &format!("{e:#}")),
    }
}

/// `apply_delta`: one replication-log entry. `epoch` is the epoch the
/// entry is expected to produce; the worker's reply always carries its
/// resulting epoch so the router can advance its watermark.
///
/// * already at (or past) `epoch` → ack without re-applying: replay
///   after failover is idempotent;
/// * exactly one behind → apply (the reported epoch may still equal the
///   old one if every op is a no-op — the store keeps the epoch then,
///   and the router trusts the worker's answer);
/// * further behind → "epoch gap" error: the router must replay earlier
///   log entries first.
///
/// Control plane: never shed, like `mutate` — replication must drain
/// even on an overloaded worker, or the router would stall every
/// dataset's writes behind one busy box.
fn handle_apply_delta(
    state: &CoordHandler,
    id: u64,
    dataset: &str,
    ops: &[String],
    epoch: u64,
) -> JsonValue {
    let current = match state.store.dataset(dataset) {
        Ok(d) => d.epoch,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    if current >= epoch {
        return wire::ok_response(
            id,
            vec![("epoch", num(current)), ("applied", JsonValue::Bool(false))],
        );
    }
    if current + 1 < epoch {
        return wire::error_response(
            id,
            &format!(
                "epoch gap: worker at {current}, log entry expects {epoch} — replay earlier entries"
            ),
        );
    }
    let delta = match GraphDelta::parse(&ops.join("\n")) {
        Ok(d) => d,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    match state.coord.apply_delta(dataset, &delta) {
        Ok(out) => wire::ok_response(
            id,
            vec![("epoch", num(out.epoch)), ("applied", JsonValue::Bool(true))],
        ),
        Err(e) => wire::error_response(id, &format!("{e:#}")),
    }
}

fn handle_status(state: &CoordHandler, id: u64) -> JsonValue {
    let datasets = state
        .store
        .dataset_names()
        .into_iter()
        .filter_map(|name| {
            let ds = state.store.dataset(&name).ok()?;
            let bounds = state.coord.shard_bounds(&name).unwrap_or_else(|_| vec![(0, ds.n)]);
            let bounds_json = bounds
                .iter()
                .map(|&(s, e)| JsonValue::Arr(vec![num(s as u64), num(e as u64)]))
                .collect();
            Some(JsonValue::Obj(
                [
                    ("name".to_string(), JsonValue::Str(name)),
                    ("nodes".to_string(), num(ds.n as u64)),
                    ("classes".to_string(), num(ds.classes as u64)),
                    ("epoch".to_string(), num(ds.epoch)),
                    // The shard-layout row cuts — deterministic in
                    // (graph, spec), which is how a router learns the
                    // placement universe without shipping the graph.
                    ("shard_bounds".to_string(), JsonValue::Arr(bounds_json)),
                ]
                .into_iter()
                .collect(),
            ))
        })
        .collect();
    wire::ok_response(
        id,
        vec![
            ("uptime_us", us(state.started.elapsed())),
            ("datasets", JsonValue::Arr(datasets)),
            (
                "models",
                JsonValue::Arr(
                    state.store.model_names().into_iter().map(JsonValue::Str).collect(),
                ),
            ),
            ("workers", num(state.coord.pool_workers() as u64)),
            ("inflight", num(state.inflight.load(Ordering::Acquire) as u64)),
            ("high_water", num(state.cfg.high_water as u64)),
            ("plans_resident", num(state.coord.plan_cache_len() as u64)),
            ("connections", num(state.shared.open_connections() as u64)),
            ("accept_errors", num(state.shared.accept_errors())),
        ],
    )
}

fn handle_metrics(state: &CoordHandler, id: u64) -> JsonValue {
    let snap = state.coord.metrics().snapshot();
    let route_latency = snap
        .route_latency
        .iter()
        .map(|(label, r)| {
            (
                label.clone(),
                JsonValue::Obj(
                    [
                        ("requests".to_string(), num(r.requests)),
                        ("p50_us".to_string(), us(r.p50)),
                        ("p99_us".to_string(), us(r.p99)),
                        ("p999_us".to_string(), us(r.p999)),
                    ]
                    .into_iter()
                    .collect(),
                ),
            )
        })
        .collect();
    wire::ok_response(
        id,
        vec![
            ("submitted", num(snap.submitted)),
            ("rejected", num(snap.rejected)),
            ("completed", num(snap.completed)),
            ("failed", num(snap.failed)),
            ("shed", num(snap.shed)),
            ("batches", num(snap.batches)),
            ("plan_hits", num(snap.plan_hits)),
            ("plan_misses", num(snap.plan_misses)),
            ("sharded_batches", num(snap.sharded_batches)),
            ("graph_epochs", num(snap.graph_epochs)),
            ("latency_p50_us", us(snap.latency_p50)),
            ("latency_p99_us", us(snap.latency_p99)),
            ("latency_p999_us", us(snap.latency_p999)),
            ("latency_mean_us", us(snap.latency_mean)),
            ("queue_wait_p50_us", us(snap.queue_wait_p50)),
            ("route_latency", JsonValue::Obj(route_latency)),
        ],
    )
}

fn handle_routes(state: &CoordHandler, id: u64) -> JsonValue {
    let snap = state.coord.metrics().snapshot();
    let routes = snap
        .per_route
        .iter()
        .map(|(label, &executions)| {
            let mut map: std::collections::BTreeMap<String, JsonValue> = [
                ("name".to_string(), JsonValue::Str(label.clone())),
                ("executions".to_string(), num(executions)),
            ]
            .into_iter()
            .collect();
            if let Some(r) = snap.route_latency.get(label) {
                map.insert("requests".to_string(), num(r.requests));
                map.insert("p50_us".to_string(), us(r.p50));
                map.insert("p99_us".to_string(), us(r.p99));
                map.insert("p999_us".to_string(), us(r.p999));
            }
            JsonValue::Obj(map)
        })
        .collect();
    wire::ok_response(id, vec![("routes", JsonValue::Arr(routes))])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accept_backoff_grows_and_caps() {
        // The hot-accept-loop fix: a persistent error stream must sleep,
        // and the sleep must neither start large (one transient error
        // should cost ~1 ms) nor grow without bound.
        assert_eq!(accept_backoff(0), Duration::from_millis(1));
        assert_eq!(accept_backoff(1), Duration::from_millis(2));
        assert_eq!(accept_backoff(3), Duration::from_millis(8));
        assert_eq!(accept_backoff(7), Duration::from_millis(100));
        assert_eq!(accept_backoff(30), Duration::from_millis(100));
        assert_eq!(accept_backoff(u32::MAX), Duration::from_millis(100));
        // Monotone: a longer streak never sleeps less.
        for s in 0..20u32 {
            assert!(accept_backoff(s + 1) >= accept_backoff(s));
        }
    }
}
