//! The TCP serving front-end: accept loop, per-connection threads,
//! admission control, and the ops request surface.
//!
//! [`WireServer`] wraps an [`Arc<Coordinator>`]: every connection gets
//! a thread that reads [`super::wire`] frames and dispatches them.
//! `infer` frames go through [`Coordinator::submit`] — the same bounded
//! intake, batcher, plan-cache/prefetcher/sharded path as in-process
//! callers, so wire requests for the same route coalesce into one
//! forward pass across connections. The connection thread then blocks
//! on that request's reply channel; concurrency comes from the number
//! of connections, exactly like one outstanding request per client.
//!
//! # Admission control
//!
//! Two gates, both answered with an explicit `"shed"` response (a
//! distinct status, not an error — the client should back off and
//! retry), and both counted in [`super::Metrics::shed`]:
//!
//! 1. the server-level in-flight gauge against
//!    [`NetConfig::high_water`] — refusing before touching the
//!    coordinator, bounding the reply channels and blocked connection
//!    threads a burst can pin;
//! 2. [`SubmitError::Busy`] from the coordinator's bounded intake
//!    queue (backpressure racing the gauge is still never a silent
//!    drop).
//!
//! Responses already in flight when a burst arrives drain oldest-first
//! per the batcher contract (docs/mutation.md, PR 5): shedding refuses
//! *new* work, it never abandons admitted work.

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::graph::GraphDelta;
use crate::util::JsonValue;

use super::request::SubmitError;
use super::server::Coordinator;
use super::store::ModelStore;
use super::wire::{self, WireRequest};

/// Front-end knobs.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// In-flight `infer`/`logits` requests (all connections) beyond
    /// which new ones are shed. 0 sheds everything — useful in tests.
    pub high_water: usize,
    /// Per-frame byte cap (see [`wire::MAX_FRAME`]).
    pub max_frame: usize,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig { high_water: 256, max_frame: wire::MAX_FRAME }
    }
}

/// Shared state behind the accept loop and every connection thread.
struct ServerState {
    coord: Arc<Coordinator>,
    store: Arc<ModelStore>,
    cfg: NetConfig,
    inflight: AtomicUsize,
    started: Instant,
    shutdown: AtomicBool,
    /// Connection threads + stream clones so shutdown can force
    /// blocked reads to return. Grows with total connections accepted;
    /// fine at serving scale (one entry per client connection).
    conns: Mutex<Vec<(JoinHandle<()>, TcpStream)>>,
}

/// The TCP front-end. Dropping it (or calling [`WireServer::shutdown`])
/// stops the accept loop, closes every live connection, and joins the
/// threads; the coordinator itself shuts down when its last `Arc`
/// drops.
pub struct WireServer {
    addr: SocketAddr,
    state: Arc<ServerState>,
    accept: Option<JoinHandle<()>>,
}

impl WireServer {
    /// Bind `addr` (e.g. `127.0.0.1:0` for an ephemeral port) and start
    /// serving.
    pub fn bind(
        coord: Arc<Coordinator>,
        store: Arc<ModelStore>,
        addr: &str,
        cfg: NetConfig,
    ) -> Result<WireServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        Self::start(coord, store, listener, cfg)
    }

    /// Start serving on an already-bound listener.
    pub fn start(
        coord: Arc<Coordinator>,
        store: Arc<ModelStore>,
        listener: TcpListener,
        cfg: NetConfig,
    ) -> Result<WireServer> {
        let addr = listener.local_addr().context("reading bound address")?;
        let state = Arc::new(ServerState {
            coord,
            store,
            cfg,
            inflight: AtomicUsize::new(0),
            started: Instant::now(),
            shutdown: AtomicBool::new(false),
            conns: Mutex::new(Vec::new()),
        });
        let accept = {
            let state = state.clone();
            std::thread::Builder::new()
                .name("wire-accept".into())
                .spawn(move || accept_loop(listener, state))
                .context("spawning accept thread")?
        };
        Ok(WireServer { addr, state, accept: Some(accept) })
    }

    /// The bound address (resolves the ephemeral port of `:0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close live connections, join every thread.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        if self.state.shutdown.swap(true, Ordering::AcqRel) {
            return;
        }
        // Unblock the accept loop: it checks the flag after every
        // accept, so one throwaway connection gets it past the block.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // The accept loop is gone — no new entries can race this drain.
        let conns = std::mem::take(&mut *self.state.conns.lock().unwrap());
        for (handle, stream) in conns {
            let _ = stream.shutdown(Shutdown::Both);
            let _ = handle.join();
        }
    }
}

impl Drop for WireServer {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    for stream in listener.incoming() {
        if state.shutdown.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = stream else { continue };
        let Ok(clone) = stream.try_clone() else { continue };
        let st = state.clone();
        let handle = std::thread::Builder::new()
            .name("wire-conn".into())
            .spawn(move || connection_loop(stream, st));
        match handle {
            Ok(h) => state.conns.lock().unwrap().push((h, clone)),
            Err(_) => {
                // Out of threads: refuse the connection outright rather
                // than hanging the client.
                let _ = clone.shutdown(Shutdown::Both);
            }
        }
    }
}

fn connection_loop(mut stream: TcpStream, state: Arc<ServerState>) {
    let _ = stream.set_nodelay(true);
    loop {
        let body = match wire::read_frame(&mut stream, state.cfg.max_frame) {
            Ok(Some(b)) => b,
            // Clean EOF, a reset, or an untrustworthy stream (oversize
            // length, mid-frame EOF): drop the connection.
            Ok(None) | Err(_) => break,
        };
        let reply = handle_frame(&state, &body);
        if wire::write_frame(&mut stream, reply.to_string().as_bytes()).is_err() {
            break;
        }
    }
    // The accept loop holds a clone of this stream (so shutdown can
    // unblock the read above); dropping ours would leave the socket
    // half-alive until server shutdown. Close it actively so the peer
    // sees EOF the moment the connection is dead.
    let _ = stream.shutdown(Shutdown::Both);
}

/// Decode and dispatch one frame; infallible — every failure mode maps
/// to an `"error"` (or `"shed"`) response frame.
fn handle_frame(state: &ServerState, body: &[u8]) -> JsonValue {
    let text = match std::str::from_utf8(body) {
        Ok(t) => t,
        Err(_) => return wire::error_response(0, "frame is not UTF-8"),
    };
    let doc = match crate::util::parse_json(text) {
        Ok(d) => d,
        Err(e) => return wire::error_response(0, &format!("frame is not JSON: {e:#}")),
    };
    let req = match WireRequest::from_json(&doc) {
        Ok(r) => r,
        Err(e) => return wire::error_response(wire::request_id(&doc), &format!("{e:#}")),
    };
    match req {
        WireRequest::Infer { id, route, nodes } => handle_infer(state, id, route, nodes),
        WireRequest::Logits { id, route } => handle_logits(state, id, route),
        WireRequest::Mutate { id, dataset, ops } => handle_mutate(state, id, &dataset, &ops),
        WireRequest::Status { id } => handle_status(state, id),
        WireRequest::Metrics { id } => handle_metrics(state, id),
        WireRequest::Routes { id } => handle_routes(state, id),
    }
}

/// RAII in-flight slot: decrements the gauge however the handler exits.
struct Admission<'a>(&'a AtomicUsize);

impl Drop for Admission<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Claim an in-flight slot, or shed: past the high-water mark the
/// request is refused *before* it touches the coordinator.
fn admit(state: &ServerState) -> Option<Admission<'_>> {
    let prev = state.inflight.fetch_add(1, Ordering::AcqRel);
    if prev >= state.cfg.high_water {
        state.inflight.fetch_sub(1, Ordering::AcqRel);
        state.coord.metrics().shed.fetch_add(1, Ordering::Relaxed);
        return None;
    }
    Some(Admission(&state.inflight))
}

fn num(x: u64) -> JsonValue {
    JsonValue::Num(x as f64)
}

fn us(d: std::time::Duration) -> JsonValue {
    num(d.as_micros() as u64)
}

fn handle_infer(
    state: &ServerState,
    id: u64,
    route: super::request::RouteKey,
    nodes: Vec<usize>,
) -> JsonValue {
    let Some(_slot) = admit(state) else {
        return wire::shed_response(id, "in-flight high-water mark reached");
    };
    // Bounds-check against the dataset before the request reaches a
    // worker: an out-of-range node is a client error, not a panic.
    let ds = match state.store.dataset(&route.dataset) {
        Ok(d) => d,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    if let Some(&bad) = nodes.iter().find(|&&n| n >= ds.n) {
        return wire::error_response(
            id,
            &format!("node {bad} out of range (dataset {} has {} nodes)", route.dataset, ds.n),
        );
    }
    match state.coord.submit(route, nodes) {
        Ok((_, rx)) => match rx.recv() {
            Ok(resp) => {
                if let Some(err) = resp.error {
                    return wire::error_response(id, &err);
                }
                let predictions = resp
                    .predictions
                    .iter()
                    .map(|p| {
                        JsonValue::Obj(
                            [
                                ("node".to_string(), num(p.node as u64)),
                                ("class".to_string(), JsonValue::Num(p.class as f64)),
                            ]
                            .into_iter()
                            .collect(),
                        )
                    })
                    .collect();
                wire::ok_response(
                    id,
                    vec![
                        ("predictions", JsonValue::Arr(predictions)),
                        ("batch_size", num(resp.batch_size as u64)),
                        ("latency_us", us(resp.latency)),
                    ],
                )
            }
            Err(_) => wire::error_response(id, "coordinator dropped the reply channel"),
        },
        Err(SubmitError::Busy) => {
            state.coord.metrics().shed.fetch_add(1, Ordering::Relaxed);
            wire::shed_response(id, "intake queue full (backpressure)")
        }
        Err(SubmitError::Closed) => wire::error_response(id, "coordinator closed"),
    }
}

fn handle_logits(state: &ServerState, id: u64, route: super::request::RouteKey) -> JsonValue {
    let Some(_slot) = admit(state) else {
        return wire::shed_response(id, "in-flight high-water mark reached");
    };
    let ds = match state.store.dataset(&route.dataset) {
        Ok(d) => d,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    let logits = match state.coord.route_logits(&route) {
        Ok(l) => l,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    let vals = match logits.as_f32() {
        Ok(v) => v,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    if vals.len() != ds.n * ds.classes {
        return wire::error_response(
            id,
            &format!("logits shape {} != {}x{}", vals.len(), ds.n, ds.classes),
        );
    }
    let bits = vals.iter().map(|v| num(v.to_bits() as u64)).collect();
    wire::ok_response(
        id,
        vec![
            ("rows", num(ds.n as u64)),
            ("classes", num(ds.classes as u64)),
            ("epoch", num(ds.epoch)),
            ("logits_bits", JsonValue::Arr(bits)),
        ],
    )
}

fn handle_mutate(state: &ServerState, id: u64, dataset: &str, ops: &[String]) -> JsonValue {
    let delta = match GraphDelta::parse(&ops.join("\n")) {
        Ok(d) => d,
        Err(e) => return wire::error_response(id, &format!("{e:#}")),
    };
    match state.coord.apply_delta(dataset, &delta) {
        Ok(out) => wire::ok_response(
            id,
            vec![
                ("epoch", num(out.epoch)),
                ("inserted", num(out.report.inserted as u64)),
                ("deleted", num(out.report.deleted as u64)),
                ("reweighted", num(out.report.reweighted as u64)),
                ("noops", num(out.report.noops as u64)),
                ("touched_rows", num(out.report.touched_rows.len() as u64)),
                ("shards_resampled", num(out.shards_resampled as u64)),
                ("shards_retained", num(out.shards_retained as u64)),
                ("plans_invalidated", num(out.plans_invalidated as u64)),
                ("routes_restaged", num(out.routes_restaged as u64)),
            ],
        ),
        Err(e) => wire::error_response(id, &format!("{e:#}")),
    }
}

fn handle_status(state: &ServerState, id: u64) -> JsonValue {
    let datasets = state
        .store
        .dataset_names()
        .into_iter()
        .filter_map(|name| {
            let ds = state.store.dataset(&name).ok()?;
            Some(JsonValue::Obj(
                [
                    ("name".to_string(), JsonValue::Str(name)),
                    ("nodes".to_string(), num(ds.n as u64)),
                    ("classes".to_string(), num(ds.classes as u64)),
                    ("epoch".to_string(), num(ds.epoch)),
                ]
                .into_iter()
                .collect(),
            ))
        })
        .collect();
    wire::ok_response(
        id,
        vec![
            ("uptime_us", us(state.started.elapsed())),
            ("datasets", JsonValue::Arr(datasets)),
            ("workers", num(state.coord.pool_workers() as u64)),
            ("inflight", num(state.inflight.load(Ordering::Acquire) as u64)),
            ("high_water", num(state.cfg.high_water as u64)),
            ("plans_resident", num(state.coord.plan_cache_len() as u64)),
        ],
    )
}

fn handle_metrics(state: &ServerState, id: u64) -> JsonValue {
    let snap = state.coord.metrics().snapshot();
    let route_latency = snap
        .route_latency
        .iter()
        .map(|(label, r)| {
            (
                label.clone(),
                JsonValue::Obj(
                    [
                        ("requests".to_string(), num(r.requests)),
                        ("p50_us".to_string(), us(r.p50)),
                        ("p99_us".to_string(), us(r.p99)),
                        ("p999_us".to_string(), us(r.p999)),
                    ]
                    .into_iter()
                    .collect(),
                ),
            )
        })
        .collect();
    wire::ok_response(
        id,
        vec![
            ("submitted", num(snap.submitted)),
            ("rejected", num(snap.rejected)),
            ("completed", num(snap.completed)),
            ("failed", num(snap.failed)),
            ("shed", num(snap.shed)),
            ("batches", num(snap.batches)),
            ("plan_hits", num(snap.plan_hits)),
            ("plan_misses", num(snap.plan_misses)),
            ("sharded_batches", num(snap.sharded_batches)),
            ("graph_epochs", num(snap.graph_epochs)),
            ("latency_p50_us", us(snap.latency_p50)),
            ("latency_p99_us", us(snap.latency_p99)),
            ("latency_p999_us", us(snap.latency_p999)),
            ("latency_mean_us", us(snap.latency_mean)),
            ("queue_wait_p50_us", us(snap.queue_wait_p50)),
            ("route_latency", JsonValue::Obj(route_latency)),
        ],
    )
}

fn handle_routes(state: &ServerState, id: u64) -> JsonValue {
    let snap = state.coord.metrics().snapshot();
    let routes = snap
        .per_route
        .iter()
        .map(|(label, &executions)| {
            let mut map: std::collections::BTreeMap<String, JsonValue> = [
                ("name".to_string(), JsonValue::Str(label.clone())),
                ("executions".to_string(), num(executions)),
            ]
            .into_iter()
            .collect();
            if let Some(r) = snap.route_latency.get(label) {
                map.insert("requests".to_string(), num(r.requests));
                map.insert("p50_us".to_string(), us(r.p50));
                map.insert("p99_us".to_string(), us(r.p99));
                map.insert("p999_us".to_string(), us(r.p999));
            }
            JsonValue::Obj(map)
        })
        .collect();
    wire::ok_response(id, vec![("routes", JsonValue::Arr(routes))])
}
